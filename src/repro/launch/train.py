"""End-to-end training launcher.

Wires together: FastMatch data selection (the paper's technique, phase 1)
-> TokenStream -> model -> optimizer -> jitted train loop with
checkpoint/auto-resume, NaN-step skipping, preemption handling (SIGTERM
triggers save+exit), and periodic eval. Runs single-device for local
smoke / examples, and under a mesh (pjit) when one is provided.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
      --steps 200 --batch 8 --seq 256
"""

from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, config_hash
from repro.configs.base import ALIASES, get_config, get_smoke_config
from repro.data.corpus import CorpusSpec, make_corpus
from repro.data.pipeline import TokenStream, select_domains
from repro.models.model_zoo import get_model
from repro.optimizer import get_optimizer
from repro.train import TrainState, make_train_step

__all__ = ["train_loop", "main"]


def train_loop(
    *,
    cfg,
    steps: int,
    batch_size: int,
    seq_len: int,
    lr: float = 3e-4,
    ckpt_dir: str = None,
    ckpt_every: int = 100,
    log_every: int = 10,
    corpus=None,
    select_k: int = 8,
    seed: int = 0,
    extra_batch_fn=None,
    log_fn=print,
) -> dict:
    model = get_model(cfg)
    optimizer = get_optimizer(cfg.optimizer, lr)
    rng = jax.random.PRNGKey(seed)

    # ---- phase 1: FastMatch distribution-matched data selection ----
    if corpus is None:
        corpus = make_corpus(
            CorpusSpec(vocab_size=cfg.vocab_size, num_blocks=512, block_tokens=2048, seed=seed)
        )
    report = select_domains(corpus, k=select_k, seed=seed)
    log_fn(
        f"[fastmatch] selected domains {sorted(report.selected_domains.tolist())} "
        f"scanning {report.blocks_scanned_frac:.1%} of blocks "
        f"(delta_upper={report.result.delta_upper:.2e}, exact={report.result.exact})"
    )
    stream = TokenStream(
        corpus, report.selected_domains, batch_size=batch_size, seq_len=seq_len, seed=seed
    )

    # ---- state init or resume ----
    params = model.init(rng)
    state = TrainState.create(params, optimizer)
    manager = None
    if ckpt_dir:
        manager = CheckpointManager(ckpt_dir, config_hash=config_hash(cfg))
        latest = manager.latest_step()
        if latest is not None:
            state = manager.restore(state, latest)
            log_fn(f"[resume] restored step {latest} from {ckpt_dir}")

    train_step = jax.jit(make_train_step(model, optimizer))

    # ---- preemption handling ----
    preempted = {"flag": False}

    def _on_term(signum, frame):
        preempted["flag"] = True

    old = signal.signal(signal.SIGTERM, _on_term)

    # ---- loop ----
    history = []
    t0 = time.time()
    start_step = int(state.step)
    # resume-exact data order: fast-forward the stream past consumed batches
    # (production would checkpoint StreamState; replay is equivalent here)
    for _ in range(start_step):
        next(stream)
    try:
        for it in range(start_step, steps):
            batch = next(stream)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if extra_batch_fn:
                batch.update(extra_batch_fn(batch))
            state, metrics = train_step(state, batch)
            if (it + 1) % log_every == 0 or it == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = it + 1
                m["tok_per_s"] = (it + 1 - start_step) * batch_size * seq_len / (time.time() - t0)
                history.append(m)
                log_fn(
                    f"[train] step {it+1}/{steps} loss={m['loss']:.4f} ce={m['ce']:.4f} "
                    f"gnorm={m['grad_norm']:.2f} ok={m['step_ok']:.0f} "
                    f"tok/s={m['tok_per_s']:.0f}"
                )
            if manager and ((it + 1) % ckpt_every == 0 or preempted["flag"]):
                manager.save(state, it + 1)
            if preempted["flag"]:
                log_fn(f"[preempt] SIGTERM received; saved at step {it+1}; exiting")
                break
    finally:
        signal.signal(signal.SIGTERM, old)

    return {
        "state": state,
        "history": history,
        "selection": report,
        "final_loss": history[-1]["loss"] if history else None,
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, required=True)
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = ALIASES.get(args.arch, args.arch)
    cfg = get_smoke_config(arch) if args.smoke else get_config(arch)
    out = train_loop(
        cfg=cfg,
        steps=args.steps,
        batch_size=args.batch,
        seq_len=args.seq,
        lr=args.lr,
        ckpt_dir=args.ckpt_dir,
        seed=args.seed,
    )
    print(f"final loss: {out['final_loss']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
