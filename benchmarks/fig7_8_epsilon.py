"""Figures 7 & 8: effect of eps on latency (Fig 7) and Delta_d (Fig 8).

Paper claims: latency decreases with eps; Delta_d grows with eps but
stays small ("never more than 6% larger than optimal ... even for the
largest values of eps").
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import delta_d, get_query, run_variant

# flights_q4 has a CONTINUUM of candidate distances to the (uniform)
# target — the regime where larger eps actually costs accuracy (Fig. 8's
# Delta_d > 0); flights_q1's planted gap gives Delta_d = 0 at every eps.
EPS_GRID = (0.05, 0.07, 0.1, 0.15, 0.2)
QUERY = "flights_q4"
ACCURACY_RUNS = 5


def run(csv_rows: list) -> None:
    for eps in EPS_GRID:
        res, wall, ds = run_variant(QUERY, "fastmatch", eps=eps, seed=0)
        dds = []
        for s in range(ACCURACY_RUNS):
            r, _, _ = run_variant(QUERY, "fastmatch", eps=eps, seed=100 + s, warm=False)
            dds.append(delta_d(r, ds))
        spec, _, blocked = get_query(QUERY)
        csv_rows.append(
            dict(
                name=f"fig7_8.eps_{eps}",
                us_per_call=wall * 1e6,
                derived=(
                    f"blocks_frac={res.blocks_read / blocked.num_blocks:.3f}"
                    f" delta_d_mean={np.mean(dds):.4f} delta_d_max={np.max(dds):.4f}"
                ),
            )
        )
