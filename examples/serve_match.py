"""Serving demo: many interactive matching queries, one sample stream.

Simulates the paper's interactive exploration scenario at serving scale:
a pool of analysts each picks a target income distribution and asks for
the k countries whose distributions match it best. A `MatchServer`
answers all of them from ONE shared pass over the data — every tuple
read advances every live query — and queries arriving later are served
from the already-accumulated counts, often with zero new I/O. At the
end the warm cache is checkpointed and the server "restarted" from it:
a restored server keeps the accumulated sample, so a restart no longer
pays the cold sampling cost.

  PYTHONPATH=src python examples/serve_match.py
"""

import tempfile

import numpy as np

from repro.core.histsim import HistSimParams
from repro.core.engine import EngineConfig, run_engine
from repro.data.layout import block_layout
from repro.data.synth import SynthSpec, make_dataset, perturb_distribution
from repro.serve.fastmatch_server import MatchServer

K, EPS, DELTA = 10, 0.07, 0.01


def main():
    spec = SynthSpec(
        v_z=161, v_x=24, num_tuples=4_000_000, k=K, n_close=10,
        close_distance=0.02, far_distance=0.3, zipf_a=1.0, seed=0,
    )
    print("generating synthetic census ...")
    ds = make_dataset(spec)
    blocked = block_layout(ds.z, ds.x, v_z=spec.v_z, v_x=spec.v_x, seed=0)
    print(f"dataset: {blocked.num_tuples:,} tuples in {blocked.num_blocks:,} blocks\n")

    # Eight analysts, eight targets: small perturbations of a base
    # distribution (think: nearby countries' income profiles).
    rng = np.random.default_rng(1)
    targets = [ds.target] + [
        perturb_distribution(ds.target, d, rng)
        for d in np.linspace(0.005, 0.05, 7)
    ]

    ckpt_dir = tempfile.mkdtemp(prefix="fastmatch_demo_ckpt_")
    server = MatchServer(
        blocked, max_queries=4, lookahead=512, seed=0, checkpoint_dir=ckpt_dir
    )
    rids = [server.submit(t, k=K, eps=EPS, delta=DELTA) for t in targets]
    print(f"submitted {len(rids)} queries into {server.spec.max_queries} slots ...")
    results = server.run_until_idle()

    print(f"\n{'query':>5} {'tuples while live':>18} {'blocks':>7} {'exact':>6}  top-3")
    for i, rid in enumerate(rids):
        r = results[rid]
        print(f"{i:>5} {r.tuples_read:>18,} {r.blocks_read:>7} {str(r.exact):>6}  {r.ids[:3].tolist()}")
    m = server.metrics
    print(f"\nshared stream: {m['total_tuples_read']:,} tuples "
          f"({100 * m['fraction_read']:.1f}% of the data) for {m['queries_done']} queries "
          f"-> {m['tuples_per_query']:,.0f} tuples/query amortized")

    # A latecomer: the counts cache is warm, so it usually costs nothing.
    print("\nlate query on the warm server ...")
    before = server.metrics["total_tuples_read"]
    late = server.submit(perturb_distribution(ds.target, 0.01, rng), k=K, eps=EPS, delta=DELTA)
    r = server.run_until_idle()[late]
    print(f"late query answered with {server.metrics['total_tuples_read'] - before:,} new tuples read "
          f"(delta_upper={r.delta_upper:.2e}); top-3 = {r.ids[:3].tolist()}")

    # Reference point: one engine per query re-reads the stream N times.
    solo = sum(
        run_engine(
            blocked, t,
            HistSimParams(v_z=spec.v_z, v_x=spec.v_x, k=K, eps=EPS, delta=DELTA),
            EngineConfig(variant="fastmatch", seed=100 + i),
        ).tuples_read
        for i, t in enumerate(targets)
    )
    print(f"\none-engine-per-query reference: {solo:,} tuples "
          f"({solo / max(m['total_tuples_read'], 1):.1f}x the shared stream)")

    # Warm restart: checkpoint the sample cache, "restart" the server
    # (a fresh MatchServer in a real deployment this is a new process —
    # see benchmarks/warm_restart.py), and serve from the restored
    # counts. A cold restart would pay the full sampling cost again.
    print("\ncheckpointing the warm cache and restarting ...")
    server.save_cache()
    restarted = MatchServer.restore(
        blocked, checkpoint_dir=ckpt_dir, max_queries=4, lookahead=512
    )
    before = restarted.metrics["total_tuples_read"]
    rid = restarted.submit(
        perturb_distribution(ds.target, 0.02, rng), k=K, eps=EPS, delta=DELTA
    )
    r = restarted.run_until_idle()[rid]
    print(f"restored server answered a fresh query with "
          f"{restarted.metrics['total_tuples_read'] - before:,} new tuples read "
          f"(cache: {100 * restarted.metrics['fraction_read']:.1f}% of the data already sampled); "
          f"top-3 = {r.ids[:3].tolist()}")


if __name__ == "__main__":
    main()
