"""Statistics-engine throughput: Q-batched tau vs the unrolled PR-2 path.

The multi-query statistics iteration is tau for every live slot. PR-2
unrolled one `ops.l1_distance` call per slot — Q HBM passes over the
shared (V_Z, V_X) counts matrix per round. The Q-batched
`ops.l1_distance_multi` streams the counts once for all slots, so the
tau bytes moved per round are independent of Q. This benchmark measures
both axes for Q in {1, 2, 4, 8}:

  * tau HBM bytes/round — the roofline bytes-moved model of each path
    (f32; unrolled: Q * (V_Z*V_X + V_X + V_Z); batched:
    sweeps * V_Z*V_X + Q * (V_X + V_Z), where sweeps = 1 while the
    padded V_X fits one 4096-lane VMEM block and 2 when lane-tiled).
    The statistics engine is memory-bound (|diff|+reduce per element),
    so bytes moved IS the roofline-projected round time on TPU.
  * rounds/sec — measured wall-clock of the jitted stats step on this
    host (CPU: the ref oracles — the batched form also wins there by
    normalizing the counts matrix once instead of Q times).

Plus the fused-ingest row-sum delta: `ops.histogram_with_rowsums` vs
the PR-2 two-step (histogram, then a separate full-matrix reduction) —
one avoided V_Z*V_X re-read per ingest round.

Reported rows (benchmarks/run.py CSV schema):

  stats_tau_q{Q}_unrolled  — us per stats round, derived = MB moved
  stats_tau_q{Q}_batched   — us per stats round, derived = MB moved
  stats_tau_bytes_q8       — derived = unrolled/batched bytes ratio (>=4 = pass)
  stats_tau_speedup_q8     — derived = measured unrolled/batched wall ratio
  stats_ingest_fused       — us per fused ingest, derived = MB saved/round

Machine-readable results land in benchmarks/results/BENCH_stats.json
(the bench trajectory for this engine) alongside the aggregate CSV.

Set STATS_BENCH_SMOKE=1 for the tiny CI configuration (same code path;
exits non-zero if the batched path is not bit-identical to the unrolled
one or the q=8 bytes reduction drops below 4x).
"""

from __future__ import annotations

import json
import os
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.l1_distance_multi import _X_TILE as _X_BLOCK  # single-sweep lane bound

SMOKE = bool(int(os.environ.get("STATS_BENCH_SMOKE", "0")))
QS = (1, 2, 4, 8)
V_Z, V_X = (256, 256) if SMOKE else (4096, 1024)
N_SAMPLES = 4_096 if SMOKE else 65_536
REPS = 3 if SMOKE else 10

RESULTS = pathlib.Path(__file__).parent / "results"


@jax.jit
def _tau_unrolled(counts, q_hat):
    """The PR-2 statistics tau: one kernel call-site per slot."""
    return jnp.stack(
        [ops.l1_distance(counts, q_hat[i]) for i in range(q_hat.shape[0])]
    )


@jax.jit
def _tau_batched(counts, q_hat):
    return ops.l1_distance_multi(counts, q_hat)


def _time(fn, *args) -> float:
    """Median seconds per call, jit-warmed."""
    jax.block_until_ready(fn(*args))
    t = []
    for _ in range(REPS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        t.append(time.perf_counter() - t0)
    return float(np.median(t))


def _tau_bytes(q: int) -> tuple:
    """(unrolled, batched) analytic HBM bytes per stats round, f32."""
    vx_pad = max(128, -(-V_X // 128) * 128)
    sweeps = 1 if vx_pad <= _X_BLOCK else 2
    unrolled = q * (V_Z * V_X + V_X + V_Z) * 4
    batched = (sweeps * V_Z * V_X + q * (V_X + V_Z)) * 4
    return unrolled, batched


def run(rows: list) -> None:
    rng = np.random.default_rng(12)
    counts = jnp.asarray(rng.integers(0, 50, size=(V_Z, V_X)).astype(np.float32))
    z = jnp.asarray(rng.integers(-1, V_Z, size=N_SAMPLES).astype(np.int32))
    x = jnp.asarray(rng.integers(-1, V_X, size=N_SAMPLES).astype(np.int32))

    tau_rows, identical = [], True
    for q in QS:
        q_hat = jnp.asarray(
            np.stack([rng.dirichlet(np.ones(V_X)).astype(np.float32) for _ in range(q)])
        )
        t_unrolled = _time(_tau_unrolled, counts, q_hat)
        t_batched = _time(_tau_batched, counts, q_hat)
        identical &= bool(
            np.array_equal(
                np.asarray(_tau_unrolled(counts, q_hat)),
                np.asarray(_tau_batched(counts, q_hat)),
            )
        )
        b_unrolled, b_batched = _tau_bytes(q)
        tau_rows.append(
            dict(
                q=q,
                bytes_unrolled=b_unrolled,
                bytes_batched=b_batched,
                bytes_reduction=round(b_unrolled / b_batched, 3),
                us_unrolled=round(1e6 * t_unrolled, 1),
                us_batched=round(1e6 * t_batched, 1),
                speedup=round(t_unrolled / max(t_batched, 1e-12), 3),
                rounds_per_sec_unrolled=round(1.0 / max(t_unrolled, 1e-12), 1),
                rounds_per_sec_batched=round(1.0 / max(t_batched, 1e-12), 1),
            )
        )
        rows.append(dict(name=f"stats_tau_q{q}_unrolled",
                         us_per_call=1e6 * t_unrolled,
                         derived=round(b_unrolled / 2**20, 3)))
        rows.append(dict(name=f"stats_tau_q{q}_batched",
                         us_per_call=1e6 * t_batched,
                         derived=round(b_batched / 2**20, 3)))

    # fused ingest: histogram + separate reduction vs one fused pass
    def two_step(z, x):
        c = ops.histogram(z, x, v_z=V_Z, v_x=V_X)
        return c, jnp.sum(c, axis=1)

    t_two = _time(jax.jit(two_step), z, x)
    t_fused = _time(
        jax.jit(lambda z, x: ops.histogram_with_rowsums(z, x, v_z=V_Z, v_x=V_X)), z, x
    )
    ingest_saved = V_Z * V_X * 4  # the avoided delta-matrix re-read

    by_q = {r["q"]: r for r in tau_rows}
    reduction_q8 = by_q[8]["bytes_reduction"]
    speedup_q8 = by_q[8]["speedup"]
    # "independent of Q": the counts-stream term doesn't scale with Q —
    # going 1 -> 8 queries grows batched bytes only by the tiny targets
    # term, so the q8/q1 ratio stays near 1 (vs 8 for unrolled).
    batched_growth = by_q[8]["bytes_batched"] / by_q[1]["bytes_batched"]

    rows.append(dict(name="stats_tau_bytes_q8", us_per_call=0.0, derived=reduction_q8))
    rows.append(dict(name="stats_tau_speedup_q8", us_per_call=0.0, derived=speedup_q8))
    rows.append(dict(name="stats_ingest_fused", us_per_call=1e6 * t_fused,
                     derived=round(ingest_saved / 2**20, 3)))

    ok = identical and reduction_q8 >= 4.0 and batched_growth < 2.0
    report = dict(
        config=dict(v_z=V_Z, v_x=V_X, n_samples=N_SAMPLES, reps=REPS,
                    smoke=SMOKE, backend=jax.default_backend()),
        tau=tau_rows,
        ingest=dict(us_two_step=round(1e6 * t_two, 1),
                    us_fused=round(1e6 * t_fused, 1),
                    speedup=round(t_two / max(t_fused, 1e-12), 3),
                    bytes_saved_per_round=ingest_saved),
        batched_bit_identical=identical,
        batched_bytes_growth_q1_to_q8=round(batched_growth, 3),
        tau_bytes_reduction_q8=reduction_q8,
        ok=ok,
    )
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "BENCH_stats.json").write_text(json.dumps(report, indent=2) + "\n")

    print(f"# stats_throughput: q8 tau bytes {by_q[8]['bytes_unrolled'] / 2**20:.1f}MB "
          f"-> {by_q[8]['bytes_batched'] / 2**20:.1f}MB ({reduction_q8:.1f}x, "
          f"growth q1->q8 {batched_growth:.2f}x), wall speedup {speedup_q8:.2f}x, "
          f"bit-identical={identical} -> {'PASS' if ok else 'FAIL'}")
    if SMOKE and not ok:
        raise SystemExit("stats_throughput smoke FAILED")


if __name__ == "__main__":
    rows: list = []
    run(rows)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
