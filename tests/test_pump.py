"""Golden equivalence: data-parallel pump vs the single-stream GSPMD round.

`repro.core.pump.DistributedPump` replaces the scheduler's one gathered
window stream with one `ShardedSource` stream per mesh worker feeding
the explicit shard_map pump round. This suite pins the refactor to the
single-stream semantics, in the spirit of `test_device_loop.py`:

  * LOCKSTEP — driven with the same global windows, a pump round must
    be bit-identical to the GSPMD `fused_round` on integer counts:
    counts / n / tau / read_mask / cursor counters for mesh shapes
    sweeping data x model in {1, 2, 8} x {1, 2}, with mid-stream
    admission AND retirement inside the drive (delta_upper is bit-exact
    with the model axis unsharded and allclose under model sharding —
    the GSPMD reference splits that V_Z reduction across shards);
  * the full pump() loop (per-worker visit interleaving) must resolve
    the same queries to the same matching sets as the unsharded server,
    and `prefetch=True` must not change a single bit;
  * the exact-completion fallback must land on identical true counts.

Multi-device cases run in subprocesses with their own XLA_FLAGS (the
main test process must keep 1 device); the single-worker TestPumpOnOneDevice
cases run in-process on a (1, 1) mesh and cover tier-1.
"""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_subprocess(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# Shared prologue for the subprocess cases (pre-dedented; the per-test
# bodies are dedented before concatenation, so the joined script is flat).
_DATASET = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np, json
    from jax.sharding import Mesh
    from repro.core import multiquery as mq
    from repro.core.pump import DistributedPump
    from repro.data.layout import block_layout
    from repro.data.synth import SynthSpec, make_dataset, perturb_distribution

    spec_s = SynthSpec(v_z=64, v_x=16, num_tuples=300_000, k=5, n_close=5, seed=3)
    ds = make_dataset(spec_s)
    blocked = block_layout(ds.z, ds.x, v_z=64, v_x=16, block_size=512, seed=3)
    spec = mq.MultiQuerySpec(v_z=64, v_x=16, max_queries=4)
    rng = np.random.default_rng(9)
    targets = [ds.target] + [
        perturb_distribution(ds.target, d, rng) for d in (0.01, 0.03, 0.05)
    ]
""")


@pytest.mark.slow
class TestPumpGolden:
    def test_lockstep_bit_identical_across_mesh_shapes(self):
        """Same global windows through pump and GSPMD scheduler: every
        per-round quantity must match bit for bit — including a query
        admitted mid-drive and queries retired mid-drive — for worker
        counts 1/2/8 and model shardings 1/2."""
        out = _run_subprocess(_DATASET + textwrap.dedent("""
            K, EPS, DELTA = 5, 0.08, 0.02

            def drive(shape):
                dsz, msz = shape
                mesh = Mesh(np.array(jax.devices()[: dsz * msz]).reshape(dsz, msz),
                            ("data", "model"))
                ref = mq.SharedCountsScheduler(
                    blocked, spec, window=32, seed=0, start_block=7, mesh=mesh)
                pmp = DistributedPump(
                    blocked, spec, mesh=mesh, window=32, seed=0, start_block=7)
                for t in targets[:3]:
                    ref.admit(t, k=K, eps=EPS, delta=DELTA)
                    pmp.admit(t, k=K, eps=EPS, delta=DELTA)
                # shuffled windows so every round straddles worker ranges
                order = np.random.default_rng(1).permutation(blocked.num_blocks)
                checks = []
                for r in range(12):
                    if r == 3:  # mid-stream admission into the free slot
                        ref.admit(targets[3], k=3, eps=0.1, delta=DELTA)
                        pmp.admit(targets[3], k=3, eps=0.1, delta=DELTA)
                    win = order[r * 32 : (r + 1) * 32]
                    ref.run_window(win)
                    pmp.run_window(win)
                    ref._poll_terminated()  # mid-stream retirement
                    pmp._poll_terminated()
                    checks.append(dict(
                        counts=bool(np.array_equal(np.asarray(ref.state.counts),
                                                   np.asarray(pmp.state.counts))),
                        n=bool(np.array_equal(np.asarray(ref.state.n),
                                              np.asarray(pmp.state.n))),
                        tau=bool(np.array_equal(np.asarray(ref.state.tau),
                                                np.asarray(pmp.state.tau))),
                        # delta_upper sums delta_i over V_Z: with the model
                        # axis sharded the GSPMD reference lets XLA split
                        # that reduction across shards, so its low bits
                        # differ from the pump's replicated tail (which
                        # reduces on one device, after the all-gather).
                        # Bit-exact when model=1; allclose when sharded.
                        du=bool(np.array_equal(
                                    np.asarray(ref.state.delta_upper),
                                    np.asarray(pmp.state.delta_upper))
                                if msz == 1 else
                                np.allclose(
                                    np.asarray(ref.state.delta_upper),
                                    np.asarray(pmp.state.delta_upper),
                                    rtol=1e-5, atol=1e-7)),
                        mask=bool(np.array_equal(ref.read_mask, pmp.read_mask)),
                        counters=(ref.blocks_read, ref.blocks_considered,
                                  ref.tuples_read, ref.rounds)
                                 == (pmp.blocks_read, pmp.blocks_considered,
                                     pmp.tuples_read, pmp.rounds),
                        live=sorted(ref.tickets) == sorted(pmp.tickets),
                    ))
                retired = len(ref.outcomes)
                ids_equal = all(
                    np.array_equal(ref.outcomes[q].ids, pmp.outcomes[q].ids)
                    for q in ref.outcomes)
                flat = {k: all(c[k] for c in checks) for k in checks[0]}
                flat.update(retired=retired,
                            same_retired=set(ref.outcomes) == set(pmp.outcomes),
                            ids=ids_equal)
                return flat

            results = {str(s): drive(s) for s in [(1, 1), (2, 1), (8, 1), (2, 2), (4, 2)]}
            ok = all(all(v for k, v in r.items() if k != "retired")
                     for r in results.values())
            # the drive must actually exercise retirement somewhere
            ok = ok and any(r["retired"] > 0 for r in results.values())
            print(json.dumps(dict(ok=ok, results=results)))
        """))
        res = json.loads(out.strip().splitlines()[-1])
        assert res["ok"], res["results"]

    def test_pump_loop_matches_single_stream_answers(self):
        """The full pump() loop — per-worker visit interleaving, its own
        pass structure — must resolve the same queries to the same
        matching sets as the unsharded server, and the prefetch-wrapped
        pump must reproduce the plain pump bit for bit."""
        out = _run_subprocess(_DATASET + textwrap.dedent("""
            from repro.serve.fastmatch_server import MatchServer

            ref = MatchServer(blocked, max_queries=4, lookahead=64, seed=11)
            rids_ref = [ref.submit(t, k=5, eps=0.08, delta=0.05) for t in targets]
            res_ref = ref.run_until_idle()

            mesh = Mesh(np.array(jax.devices()).reshape(8, 1), ("data", "model"))
            srv = MatchServer(blocked, max_queries=4, lookahead=64, seed=11,
                              mesh=mesh, pump=True)
            rids = [srv.submit(t, k=5, eps=0.08, delta=0.05) for t in targets]
            res = srv.run_until_idle()

            pre = MatchServer(blocked, max_queries=4, lookahead=64, seed=11,
                              mesh=mesh, pump=True, prefetch=True)
            rids_pre = [pre.submit(t, k=5, eps=0.08, delta=0.05) for t in targets]
            res_pre = pre.run_until_idle()

            ids_ok = all(
                sorted(res[r].ids.tolist()) == sorted(res_ref[rr].ids.tolist())
                and res[r].exact == res_ref[rr].exact
                for r, rr in zip(rids, rids_ref))
            pre_ok = all(
                np.array_equal(res_pre[a].ids, res[b].ids)
                for a, b in zip(rids_pre, rids))
            pre_bits = bool(np.array_equal(
                np.asarray(pre.scheduler.state.counts),
                np.asarray(srv.scheduler.state.counts)))
            # 8 parallel worker streams amortize the poll cadence: far
            # fewer dispatched rounds (hence host polls) per pass
            fewer_rounds = srv.scheduler.rounds < ref.scheduler.rounds
            print(json.dumps(dict(
                ok=bool(ids_ok and pre_ok and pre_bits and fewer_rounds),
                ids_ok=ids_ok, pre_ok=pre_ok, pre_bits=pre_bits,
                rounds=[int(srv.scheduler.rounds), int(ref.scheduler.rounds)],
                syncs=[int(srv.scheduler.host_syncs), int(ref.scheduler.host_syncs)])))
        """))
        res = json.loads(out.strip().splitlines()[-1])
        assert res["ok"], res

    def test_exact_completion_lockstep(self):
        """An unreachable bound forces the exact fallback: the pump's
        per-worker completion chunks must land on the same true counts
        and the same exact answers as the single-stream completion."""
        out = _run_subprocess(_DATASET + textwrap.dedent("""
            mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
            ref = mq.SharedCountsScheduler(
                blocked, spec, window=32, seed=0, start_block=3, mesh=mesh)
            pmp = DistributedPump(
                blocked, spec, mesh=mesh, window=32, seed=0, start_block=3)
            for s in (ref, pmp):
                s.admit(targets[0], k=3, eps=0.02, delta=1e-9)
            order = np.random.default_rng(2).permutation(blocked.num_blocks)
            for r in range(4):
                win = order[r * 32 : (r + 1) * 32]
                ref.run_window(win); pmp.run_window(win)
            ref.complete_remaining(); pmp.complete_remaining()
            eq = dict(
                counts=bool(np.array_equal(np.asarray(ref.state.counts),
                                           np.asarray(pmp.state.counts))),
                n=bool(np.array_equal(np.asarray(ref.state.n), np.asarray(pmp.state.n))),
                tau=bool(np.array_equal(np.asarray(ref.state.tau),
                                        np.asarray(pmp.state.tau))),
                all_read=bool(ref.read_mask.all() and pmp.read_mask.all()),
            )
            eq["ok"] = all(eq.values())
            print(json.dumps(eq))
        """))
        res = json.loads(out.strip().splitlines()[-1])
        assert res["ok"], res


class TestPumpOnOneDevice:
    """Tier-1 (single device) coverage: a (1, 1) mesh pump is the
    degenerate one-worker case and must reproduce the plain scheduler
    bit for bit; construction guards must fire early."""

    @pytest.fixture(scope="class")
    def small(self):
        from repro.data.layout import block_layout
        from repro.data.synth import SynthSpec, make_dataset

        spec = SynthSpec(v_z=24, v_x=8, num_tuples=40_000, k=3, n_close=3, seed=4)
        ds = make_dataset(spec)
        blocked = block_layout(ds.z, ds.x, v_z=24, v_x=8, block_size=256, seed=4)
        return ds, blocked

    def _mesh(self):
        import jax
        from jax.sharding import Mesh

        return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))

    def test_one_worker_pump_bit_identical_to_scheduler(self, small):
        from repro.core import multiquery as mq
        from repro.core.pump import DistributedPump

        ds, blocked = small
        spec = mq.MultiQuerySpec(v_z=24, v_x=8, max_queries=2)
        ref = mq.SharedCountsScheduler(blocked, spec, window=16, seed=0, start_block=5)
        pmp = DistributedPump(
            blocked, spec, mesh=self._mesh(), window=16, seed=0, start_block=5)
        for s in (ref, pmp):
            s.admit(ds.target, k=3, eps=0.08, delta=0.05)
        for s in (ref, pmp):
            s.pump(max_passes=2)
        np.testing.assert_array_equal(
            np.asarray(ref.state.counts), np.asarray(pmp.state.counts))
        np.testing.assert_array_equal(np.asarray(ref.state.n), np.asarray(pmp.state.n))
        np.testing.assert_array_equal(
            np.asarray(ref.state.tau), np.asarray(pmp.state.tau))
        np.testing.assert_array_equal(ref.read_mask, pmp.read_mask)
        assert ref.rounds == pmp.rounds and ref.tuples_read == pmp.tuples_read
        assert set(ref.outcomes) == set(pmp.outcomes)
        for q in ref.outcomes:
            np.testing.assert_array_equal(ref.outcomes[q].ids, pmp.outcomes[q].ids)

    def test_one_worker_cache_roundtrip_interchangeable(self, small):
        """A pump snapshot must import into a plain scheduler and vice
        versa — the CacheSnapshot layout is global, not per-worker."""
        from repro.core import multiquery as mq
        from repro.core.pump import DistributedPump

        ds, blocked = small
        spec = mq.MultiQuerySpec(v_z=24, v_x=8, max_queries=2)
        pmp = DistributedPump(
            blocked, spec, mesh=self._mesh(), window=16, seed=0, start_block=5)
        pmp.admit(ds.target, k=3, eps=0.08, delta=0.05)
        pmp.pump(max_passes=1)
        snap = pmp.export_cache()
        assert np.asarray(snap.read_mask).shape == (blocked.num_blocks,)

        plain = mq.SharedCountsScheduler(blocked, spec, window=16, seed=9)
        plain.import_cache(snap)
        np.testing.assert_array_equal(
            np.asarray(plain.state.counts), np.asarray(pmp.state.counts))
        np.testing.assert_array_equal(plain.read_mask, pmp.read_mask)

        back = DistributedPump(
            blocked, spec, mesh=self._mesh(), window=16, seed=7)
        back.import_cache(plain.export_cache())
        np.testing.assert_array_equal(back.read_mask, pmp.read_mask)
        assert back.rounds == pmp.rounds and back.tuples_read == pmp.tuples_read

    def test_construction_guards(self, small):
        from repro.core import multiquery as mq
        from repro.core.pump import DistributedPump
        from repro.io import InMemorySource
        from repro.serve.fastmatch_server import MatchServer

        ds, blocked = small
        spec = mq.MultiQuerySpec(v_z=24, v_x=8, max_queries=2)
        with pytest.raises(TypeError, match="BlockedDataset"):
            DistributedPump(InMemorySource(blocked), spec, mesh=self._mesh())
        with pytest.raises(ValueError, match="mesh"):
            MatchServer(blocked, pump=True)
        with pytest.raises(ValueError, match="no axis"):
            DistributedPump(blocked, spec, mesh=self._mesh(), data_axes=("pod",))
