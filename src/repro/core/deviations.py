"""Deviation selection (Sec 3.3 of the paper) — the heart of HistSim.

Given per-candidate distance estimates tau_i and sample counts n_i,
choose per-candidate deviations eps_i that satisfy the constraints of
Lemma 2 (so that eps_i-deviation for all i implies Guarantees 1 and 2)
while making each eps_i as large as possible (so the failure bound
delta_i = 2^V_X exp(-eps_i^2 n_i / 2) is as small as possible):

  * split point  s = midpoint between the k-th and (k+1)-th smallest tau
  * i in M (top-k):   eps_i = min(eps, s + eps/2 - tau_i)
  * j not in M:       eps_j = tau_j - max(s - eps/2, 0)

Then delta_upper = sum_i delta_i and the active set is
{i : delta_i > delta / V_Z} (the AnyActive threshold, Sec 4.2).

The metric layer generalizes both rules: tau may be ANY registry metric
(`repro.kernels.metrics`), and the failure bounds go through
`bounds.metric_log_delta` — Theorem 1 evaluated at the metric's ℓ1
budget (identity for l1, so the default path is unchanged bit for bit).
`assign_closeness` is the second retirement rule: a two-sided tolerance
(closeness) test over the same DeviationState shape, so the batched
multi-query engine, the AnyActive pruning flow, and the shared
``delta_upper < delta`` termination all serve both query types.

Everything here is branch-free, fixed-shape JAX, usable inside jit and
under shard_map (candidate-sharded with a tiny all-gather of tau).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import bounds

__all__ = [
    "DeviationState",
    "assign_deviations",
    "assign_deviations_dynamic",
    "assign_closeness",
    "prune_far",
    "split_point",
    "top_k_mask",
]


def _metric_log_delta(eps_i, tau, n, v_x, metric, bounds_mode):
    """Route the failure bound: conservative uniform budget vs the
    tau-aware native one (`bounds.metric_native_log_delta`). The l1 arm
    is Theorem 1 verbatim under EITHER mode — the native family only
    changes the compiled program for chi2/hellinger."""
    if bounds_mode == "conservative":
        return bounds.metric_log_delta(eps_i, n, v_x, metric=metric)
    if bounds_mode == "native":
        return bounds.metric_native_log_delta(
            eps_i, n, v_x, tau=tau, metric=metric
        )
    raise ValueError(
        f"bounds_mode must be 'native' or 'conservative', got {bounds_mode!r}"
    )


class DeviationState(NamedTuple):
    """Result of one statistics-engine iteration (Alg. 1 lines 8-14)."""

    tau: jax.Array  # (V_Z,) f32 — distance estimates d(r_hat_i, Q_hat)
    in_top_k: jax.Array  # (V_Z,) bool — membership in M
    split: jax.Array  # () f32 — split point s
    eps_i: jax.Array  # (V_Z,) f32 — assigned deviations
    log_delta_i: jax.Array  # (V_Z,) f32 — log failure bounds
    delta_upper: jax.Array  # () f32 — sum_i delta_i
    active: jax.Array  # (V_Z,) bool — delta_i > delta/V_Z


def top_k_mask(tau: jax.Array, k: int) -> jax.Array:
    """Boolean mask of the k smallest entries of tau (ties broken by index).

    Uses `lax.top_k` (stable: equal elements come out lower-index first)
    rather than a threshold comparison so exactly k entries are selected
    even under ties — HistSim's M must have |M| = k (Definition 3).
    """
    v_z = tau.shape[0]
    _, idx = jax.lax.top_k(-tau, min(k, v_z))
    return jnp.zeros((v_z,), bool).at[idx].set(True)


def split_point(tau: jax.Array, k: int) -> jax.Array:
    """Midpoint between the furthest in-M and closest out-of-M candidate.

    s = (tau_(k) + tau_(k+1)) / 2 in sorted order (paper Sec 3.3: "the
    midpoint halfway between the furthest candidate in M and the closest
    candidate not in M").
    """
    v_z = tau.shape[0]
    if k >= v_z:  # degenerate: everything matches
        return jnp.max(tau)
    neg_top = jax.lax.top_k(-tau, k + 1)[0]  # k+1 smallest tau, descending in -tau
    kth = -neg_top[k - 1] if k >= 1 else jnp.asarray(0.0, tau.dtype)
    k1th = -neg_top[k]
    return 0.5 * (kth + k1th)


def assign_deviations(
    tau: jax.Array,
    n: jax.Array,
    *,
    k: int,
    eps: float,
    delta: float,
    v_x: int,
) -> DeviationState:
    """One statistics iteration: eps_i, delta_i, delta_upper, active set.

    Thin static-parameter entry point over `assign_deviations_dynamic`
    (one copy of the Sec 3.3 math; the dynamic form is bitwise-identical
    — see tests/test_multiquery.py). The static k doubles as the order
    cap, so the selection is a true k+1-element `lax.top_k`.

    Args:
      tau: (V_Z,) distance estimates.
      n: (V_Z,) samples taken per candidate.
      k/eps/delta: user parameters of Problem 1.
      v_x: histogram support size |V_X|.
    """
    return assign_deviations_dynamic(
        tau, n, k=k, eps=eps, delta=delta, v_x=v_x, criterion="histsim", k_cap=k
    )


def assign_deviations_dynamic(
    tau: jax.Array,
    n: jax.Array,
    *,
    k: jax.Array,
    eps: jax.Array,
    delta: jax.Array,
    v_x: int,
    criterion: str = "histsim",
    k_cap: Optional[int] = None,
    metric: str = "l1",
    bounds_mode: str = "native",
) -> DeviationState:
    """`assign_deviations` with traced (k, eps, delta) — vmappable.

    The multi-query statistics engine (core/multiquery.py) runs one
    deviation assignment per live query with per-query Problem 1
    parameters, so k/eps/delta arrive as scalar arrays rather than
    Python statics. Selection uses `jax.lax.top_k` on -tau: the k+1
    smallest order statistics are all the assignment needs (membership
    in M plus the two split-point neighbors), so there is no full
    stable argsort + rank scatter per slot per round any more. top_k
    is documented to break ties by lower index — the same tie rule the
    argsort construction used — so the produced M, split point and
    deviations are unchanged, including on exact ties (pinned by
    tests/test_stats_batched.py::TestTopKSelectionRegression).

    k_cap: static upper bound on the traced k (top_k's k must be a
    Python int). None means "no bound known" and falls back to V_Z —
    correct for any k but no cheaper than a sort; callers that know
    their maximum k (HistSimParams.k, MultiQuerySpec.k_cap) pass it to
    get the O(V_Z * k) selection. Traced k larger than k_cap is a
    caller bug (admission validates); the selection would silently cap.

    criterion: "histsim" (delta_upper = sum delta_i) | "slowmatch"
    (delta_upper = V_Z * max delta_i), matching `slowmatch_deviations`.

    metric: which registry distance tau was computed under; eps and the
    assigned eps_i are in THAT metric's space, and the failure bounds
    go through `bounds.metric_log_delta` (identity budget for "l1" —
    zero extra ops, bit-identical to the pre-metric-layer path).

    bounds_mode: "native" (default) evaluates the failure bounds at the
    observation-aware ℓ1 budget `bounds.metric_native_log_delta(...,
    tau=tau_i)` — never more conservative than the uniform budget, and
    much tighter for chi2/hellinger candidates at small tau.
    "conservative" keeps the PR-9 uniform budgets. The l1 metric is
    bit-identical under both modes.
    """
    if criterion not in ("histsim", "slowmatch"):
        raise ValueError(criterion)
    tau = jnp.asarray(tau, jnp.float32)
    v_z = tau.shape[0]
    k = jnp.asarray(k, jnp.int32)
    eps = jnp.asarray(eps, jnp.float32)
    delta = jnp.asarray(delta, jnp.float32)

    cap = v_z if k_cap is None else int(k_cap)
    if cap < 1:
        raise ValueError(f"need k_cap >= 1, got {k_cap}")
    m = min(cap + 1, v_z)  # k+1 order statistics suffice
    neg_vals, small_idx = jax.lax.top_k(-tau, m)  # m smallest tau, ties by index
    sorted_small = -neg_vals  # ascending
    # Rank-based membership: the j-th returned index has rank j, and
    # every candidate outside the returned m has rank >= m > k.
    in_m = (
        jnp.zeros((v_z,), bool)
        .at[small_idx]
        .set(jnp.arange(m, dtype=jnp.int32) < k)
    )
    kth = sorted_small[jnp.clip(k - 1, 0, m - 1)]
    k1th = sorted_small[jnp.clip(k, 0, m - 1)]
    s = jnp.where(k >= v_z, jnp.max(tau), 0.5 * (kth + k1th))

    # Sec 3.3: in-M candidates must not cross s + eps/2 and must have
    # eps_i <= eps (reconstruction); out-of-M must not cross s - eps/2
    # (clamped at 0: no negative distances). Ties at the boundary can
    # produce eps_i = 0; delta_i then saturates at 1, which is
    # conservative.
    eps_in = jnp.minimum(eps, s + 0.5 * eps - tau)
    eps_out = tau - jnp.maximum(s - 0.5 * eps, 0.0)
    eps_i = jnp.maximum(jnp.where(in_m, eps_in, eps_out), 0.0)

    log_delta_i = _metric_log_delta(eps_i, tau, n, v_x, metric, bounds_mode)
    if criterion == "slowmatch":
        # Every candidate individually at confidence delta/V_Z (Sec 5.2).
        delta_upper = float(v_z) * jnp.exp(jnp.max(log_delta_i))
    else:
        # Sum in plain space is fine: each delta_i <= 1 and V_Z is at
        # most a few tens of thousands; underflow to 0 is what we want
        # for long-pruned candidates.
        delta_upper = jnp.sum(jnp.exp(log_delta_i))
    log_threshold = jnp.log(delta / float(v_z))
    return DeviationState(
        tau=tau,
        in_top_k=in_m,
        split=s,
        eps_i=eps_i,
        log_delta_i=log_delta_i,
        delta_upper=delta_upper,
        active=log_delta_i > log_threshold,
    )


def slowmatch_deviations(
    tau: jax.Array,
    n: jax.Array,
    *,
    k: int,
    eps: float,
    delta: float,
    v_x: int,
) -> DeviationState:
    """SlowMatch's termination state (paper Sec 5.2).

    Fixed-confidence intervals of width w_i = theorem1_epsilon(n_i,
    delta/V_Z, V_X) around every candidate; terminate iff
      (a) no top-k interval is wider than eps, and
      (b) no top-k interval overlaps a non-top-k interval by more than eps.
    Equivalent to requiring max_i delta_i <= delta/V_Z for the HistSim
    deviation assignment; we expose it in the same DeviationState shape by
    reporting delta_upper = V_Z * max_i delta_i so that the shared
    termination test `delta_upper < delta` implements the SlowMatch rule.
    """
    return assign_deviations_dynamic(
        tau, n, k=k, eps=eps, delta=delta, v_x=v_x, criterion="slowmatch", k_cap=k
    )


def assign_closeness(
    tau: jax.Array,
    n: jax.Array,
    *,
    eps: jax.Array,
    gap: jax.Array,
    delta: jax.Array,
    v_x: int,
    metric: str = "l1",
    bounds_mode: str = "native",
) -> DeviationState:
    """Tolerant closeness test over the shared counts matrix — the
    second retirement rule, in the same DeviationState shape as top-k.

    Problem (Diakonikolas-Kane-style tolerant testing, promise form):
    for every candidate i, decide "close" (true distance d_i <= eps) vs
    "far" (d_i >= eps + gap), with the whole label vector correct w.p.
    > 1 - delta; candidates inside the promise gap (eps, eps + gap) may
    be labeled either way. Labels are thresholded at the midpoint
    t = eps + gap/2, and the per-candidate DECISION MARGIN

        m_i = max(tau_i - eps, (eps + gap) - tau_i)   (>= gap/2 always)

    is the metric-space deviation that would have to occur for the
    label to break its promise: a "far" label (tau_i > t, margin
    tau_i - eps) is wrong only if d_i < eps <= tau_i - m_i + m_i, i.e.
    only if |tau_i - d_i| > m_i; symmetrically for "close". So
    delta_i = metric_delta(m_i, n_i) bounds candidate i's failure
    probability, delta_upper = sum_i delta_i bounds the union, and the
    shared termination test ``delta_upper < delta`` applies unchanged.

    Early-reject is emergent, not special-cased: a clearly-far
    candidate (tau_i >> eps + gap) has a huge margin, so its delta_i
    collapses after very few samples and it leaves the active set —
    AnyActive then stops reading its blocks — while borderline
    candidates (margin ~ gap/2) keep sampling. This is what makes
    mixed closeness + top-k workloads cheap: the closeness slots prune
    most of V_Z almost immediately.

    Returns a DeviationState where ``in_top_k`` holds the CLOSE label
    (tau_i <= t), ``split`` is the decision threshold t, and eps_i is
    the margin m_i. k plays no role.
    """
    tau = jnp.asarray(tau, jnp.float32)
    v_z = tau.shape[0]
    eps = jnp.asarray(eps, jnp.float32)
    gap = jnp.asarray(gap, jnp.float32)
    delta = jnp.asarray(delta, jnp.float32)

    threshold = eps + 0.5 * gap
    close = tau <= threshold
    margin = jnp.maximum(jnp.maximum(tau - eps, (eps + gap) - tau), 0.0)
    log_delta_i = _metric_log_delta(margin, tau, n, v_x, metric, bounds_mode)
    delta_upper = jnp.sum(jnp.exp(log_delta_i))
    log_threshold = jnp.log(delta / float(v_z))
    return DeviationState(
        tau=tau,
        in_top_k=close,
        split=threshold,
        eps_i=margin,
        log_delta_i=log_delta_i,
        delta_upper=delta_upper,
        active=log_delta_i > log_threshold,
    )


def prune_far(
    tau: jax.Array,
    n: jax.Array,
    *,
    far_edge: jax.Array,
    delta: jax.Array,
    v_x: int,
    metric: str = "l1",
) -> jax.Array:
    """Early-reject mask: candidates whose LOWER confidence bound
    already clears ``far_edge`` — the engine-shaped analogue of the
    closeness testers' cheap rejection of far distributions.

    conf_i = metric_native_epsilon(n_i, delta/V_Z, tau_i) is the
    metric-space deviation guaranteed w.p. > 1 - delta/V_Z, so
    ``tau_i - conf_i > far_edge`` certifies (at individual confidence
    delta/V_Z, union-bounded by the caller's sticky OR over rounds
    within the same delta budget the retirement math already spends)
    that the true distance exceeds far_edge: the candidate can never
    re-enter the answer set. Callers pass far_edge = eps + gap for
    closeness (certified "far") and the current split + eps/2 for
    top-k (certified outside M's reach). Fixed-shape, branch-free —
    safe inside the fused round.

    The returned mask only SHRINKS the I/O marking (which blocks get
    read); the failure bounds keep summing over every candidate, so
    the Theorem-1 union bound is untouched — pruning is a pure
    sampling-effort optimization, never a correctness shortcut.
    """
    tau = jnp.asarray(tau, jnp.float32)
    v_z = tau.shape[0]
    conf = bounds.metric_native_epsilon(
        n, jnp.asarray(delta, jnp.float32) / float(v_z), v_x, tau=tau,
        metric=metric,
    )
    return (tau - conf) > jnp.asarray(far_edge, jnp.float32)
