"""internvl2-76b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821].

Backbone-only per the assignment: the vision frontend is a STUB;
`input_specs()` provides precomputed patch embeddings for the first
`vision_tokens` positions. Adafactor (76B).
"""

from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch_id="internvl2_76b",
        family="vlm",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=28672,
        vocab_size=128256,
        rope_theta=1e6,
        norm_eps=1e-5,
        frontend="vision_stub",
        vision_tokens=256,
        optimizer="adafactor",
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="internvl2_76b_smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        frontend="vision_stub",
        vision_tokens=8,
    )
