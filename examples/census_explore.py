"""Interactive-style exploration: several matching queries on one dataset,
including target shapes from the paper (uniform target, explicit vector
target) and a comparison of all engine variants on one query.

  PYTHONPATH=src python examples/census_explore.py
"""

import numpy as np

from repro.core.engine import VARIANTS, EngineConfig, run_engine
from repro.core.histsim import HistSimParams
from repro.data.layout import block_layout
from repro.data.synth import SynthSpec, make_dataset


def main():
    spec = SynthSpec(
        v_z=191, v_x=5, num_tuples=5_000_000, k=10, n_close=10,
        close_distance=0.015, far_distance=0.3, zipf_a=0.9, seed=2,
    )
    print("generating POLICE-like dataset (191 candidates, 5 groups) ...")
    ds = make_dataset(spec)
    blocked = block_layout(ds.z, ds.x, v_z=spec.v_z, v_x=spec.v_x, seed=2)
    params = HistSimParams(v_z=spec.v_z, v_x=spec.v_x, k=10, eps=0.06, delta=0.01)

    # --- query 1: match the planted target (paper's "closest to target") ---
    res = run_engine(blocked, ds.target, params, EngineConfig(variant="fastmatch"))
    print(f"\n[q1: planted target]  ids={sorted(res.ids.tolist())} "
          f"blocks={res.blocks_read}/{blocked.num_blocks}")

    # --- query 2: uniform target (paper's POLICE-q1/q2 setup) ---
    uniform = np.full(spec.v_x, 1.0 / spec.v_x)
    res_u = run_engine(blocked, uniform, params, EngineConfig(variant="fastmatch"))
    true_u = np.argsort(np.abs(ds.true_hists - uniform[None]).sum(axis=1))[:10]
    print(f"[q2: uniform target]  ids={sorted(res_u.ids.tolist())} "
          f"truth={sorted(true_u.tolist())} blocks={res_u.blocks_read}")

    # --- query 3: explicit target vector (paper FLIGHTS-q3 style) ---
    explicit = np.asarray([0.4, 0.3, 0.15, 0.1, 0.05])
    res_e = run_engine(blocked, explicit, params, EngineConfig(variant="fastmatch"))
    print(f"[q3: explicit vector] ids={sorted(res_e.ids.tolist())} blocks={res_e.blocks_read}")

    # --- all variants on q1 ---
    print("\nvariant comparison on q1:")
    for variant in VARIANTS:
        cfg = EngineConfig(variant=variant, seed=1)
        r = run_engine(blocked, ds.target, params, cfg)
        print(f"  {variant:10s} blocks={r.blocks_read:6d} rounds={r.rounds:5d} "
              f"wall={r.wall_time_s:6.2f}s exact={r.exact}")


if __name__ == "__main__":
    main()
