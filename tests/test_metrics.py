"""Golden suite for the pluggable metric & query-type layer (PR 9).

Four contracts, each pinned here:

  1. ORACLE — every registry metric's distance ops (ref / xla / Pallas
     interpret, single- and two-sweep, Q in {1, 3, 8}) agree with a
     float64 numpy brute force, including the zero-mass-row and
     lane-padding conventions.
  2. BIT-IDENTITY — the l1 arm of the refactor reproduces the
     PRE-REFACTOR implementation bit for bit. The old ref bodies are
     FROZEN below verbatim (from the pre-metric-layer ref.py); if a
     metrics.py change makes l1 drift by even one ULP, this fails.
  3. BOUNDS — the per-metric bound family is registered for exactly the
     kernel registry's metrics, l1 composes to Theorem 1 unchanged, and
     `assign_closeness` labels/retires with the promised semantics
     (early-reject: clearly-far candidates leave the active set first).
  4. SERVE — a closeness query admitted MID-STREAM next to live top-k
     queries shares their counts and returns correct labels, for every
     metric.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import bounds
from repro.core import deviations as dev
from repro.kernels import autotune, metrics, ops

jax.config.update("jax_platform_name", "cpu")

METRIC_NAMES = list(metrics.METRIC_NAMES)
QS = [1, 3, 8]


# ---------------------------------------------------------------------------
# float64 numpy brute-force oracles (independent re-derivation, not jnp)
# ---------------------------------------------------------------------------


def _normalize_rows(counts):
    counts = np.asarray(counts, np.float64)
    row = counts.sum(axis=1, keepdims=True)
    return counts / np.maximum(row, 1.0)


def _oracle(counts, q_hat, metric):
    """(Q, V_Z) float64 distances, straight from the definitions."""
    r = _normalize_rows(counts)  # (V_Z, V_X)
    q = np.asarray(q_hat, np.float64)  # (Q, V_X)
    out = np.zeros((q.shape[0], r.shape[0]))
    for qi in range(q.shape[0]):
        for zi in range(r.shape[0]):
            p, t = r[zi], q[qi]
            if metric == "l1":
                out[qi, zi] = np.abs(p - t).sum()
            elif metric == "chi2":
                s = p + t
                d = p - t
                out[qi, zi] = np.where(s > 0, d * d / np.where(s > 0, s, 1), 0).sum()
            elif metric == "hellinger":
                out[qi, zi] = 0.5 * ((np.sqrt(p) - np.sqrt(t)) ** 2).sum()
            else:
                raise AssertionError(metric)
    return out


def _case(v_z, v_x, q, seed, zero_rows=True):
    rng = np.random.default_rng(seed)
    counts = rng.integers(0, 200, size=(v_z, v_x)).astype(np.float32)
    if zero_rows:
        counts[:: max(v_z // 7, 2)] = 0.0  # unsampled candidates
    q_hat = rng.dirichlet(np.full(v_x, 0.7), size=q).astype(np.float32)
    return jnp.asarray(counts), jnp.asarray(q_hat)


class TestOracle:
    @pytest.mark.parametrize("metric", METRIC_NAMES)
    @pytest.mark.parametrize("q", QS)
    def test_ref_and_xla_match_bruteforce(self, metric, q):
        counts, q_hat = _case(37, 24, q, seed=17 * METRIC_NAMES.index(metric) + q)
        want = _oracle(counts, q_hat, metric)
        got_ref = np.asarray(metrics.distance_multi_ref(counts, q_hat, metric=metric))
        got_xla = np.asarray(metrics.distance_multi_xla(counts, q_hat, metric=metric))
        np.testing.assert_allclose(got_ref, want, rtol=2e-5, atol=2e-6)
        np.testing.assert_allclose(got_xla, want, rtol=2e-5, atol=2e-6)

    @pytest.mark.parametrize("metric", METRIC_NAMES)
    @pytest.mark.parametrize("q", QS)
    @pytest.mark.parametrize("sweeps", [1, 2])
    def test_pallas_interpret_matches_ref(self, metric, q, sweeps):
        # Odd shapes exercise the padding paths; sweeps=2 the lane tiling.
        counts, q_hat = _case(37, 300, q, seed=7)  # 300 -> 3 lane tiles
        got = np.asarray(
            metrics.distance_multi_pallas(
                counts, q_hat, metric=metric, z_tile=8,
                x_tile=128 if sweeps == 2 else 4096,
                sweeps=sweeps, interpret=True,
            )
        )
        want = np.asarray(metrics.distance_multi_ref(counts, q_hat, metric=metric))
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    @pytest.mark.parametrize("metric", METRIC_NAMES)
    def test_single_query_is_row_zero(self, metric):
        counts, q_hat = _case(19, 12, 1, seed=11)
        one = np.asarray(metrics.distance_ref(counts, q_hat[0], metric=metric))
        multi = np.asarray(metrics.distance_multi_ref(counts, q_hat, metric=metric))
        np.testing.assert_array_equal(one, multi[0])

    @pytest.mark.parametrize("metric", METRIC_NAMES)
    def test_empty_row_convention(self, metric):
        # Zero-mass rows estimate the empty histogram: tau = ||q||_1 = 1
        # for l1/chi2, 0.5 * sum (sqrt 0 - sqrt q)^2 = 0.5 for hellinger.
        counts = jnp.zeros((3, 8), jnp.float32)
        q_hat = jnp.full((1, 8), 0.125, jnp.float32)
        tau = np.asarray(metrics.distance_multi_ref(counts, q_hat, metric=metric))
        want = metrics.coerce_metric(metric).empty_row_tau
        np.testing.assert_allclose(tau, want, rtol=1e-6)

    def test_ops_entrypoint_dispatches_metric(self):
        counts, q_hat = _case(29, 16, 3, seed=5)
        for metric in METRIC_NAMES:
            got = np.asarray(ops.distance_multi(counts, q_hat, metric=metric))
            want = _oracle(counts, q_hat, metric)
            np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)
        # distinct metrics give distinct answers (the axis is live)
        a = np.asarray(ops.distance_multi(counts, q_hat, metric="l1"))
        b = np.asarray(ops.distance_multi(counts, q_hat, metric="chi2"))
        assert not np.array_equal(a, b)

    def test_unknown_metric_rejected(self):
        counts, q_hat = _case(8, 8, 1, seed=0)
        with pytest.raises(ValueError, match="metric"):
            metrics.distance_multi_ref(counts, q_hat, metric="tv")


# ---------------------------------------------------------------------------
# l1 bit-identity against the FROZEN pre-refactor implementations
# ---------------------------------------------------------------------------

# Verbatim copies of the pre-metric-layer ref.py bodies (PR 8 tree).
# Do not "modernize" these — their whole value is staying frozen.


def _frozen_l1_distance_ref(counts, q_hat):
    counts = counts.astype(jnp.float32)
    row = jnp.sum(counts, axis=1, keepdims=True)
    r_hat = counts / jnp.maximum(row, 1.0)
    return jnp.sum(jnp.abs(r_hat - q_hat[None, :].astype(jnp.float32)), axis=1)


def _frozen_l1_distance_multi_ref(counts, q_hat):
    counts = counts.astype(jnp.float32)
    row = jnp.sum(counts, axis=1, keepdims=True)
    r_hat = counts / jnp.maximum(row, 1.0)
    q = q_hat.astype(jnp.float32)
    return jnp.stack(
        [jnp.sum(jnp.abs(r_hat - q[i][None, :]), axis=1) for i in range(q.shape[0])]
    )


def _frozen_l1_distance_multi_xla(counts, q_hat):
    counts = counts.astype(jnp.float32)
    row = jnp.sum(counts, axis=1, keepdims=True)
    r_hat = counts / jnp.maximum(row, 1.0)
    q = q_hat.astype(jnp.float32)
    return jnp.sum(jnp.abs(r_hat[None, :, :] - q[:, None, :]), axis=2)


class TestL1BitIdentity:
    @pytest.mark.parametrize("q", QS)
    def test_refs_bit_identical(self, q):
        counts, q_hat = _case(53, 24, q, seed=23)
        np.testing.assert_array_equal(
            np.asarray(metrics.distance_multi_ref(counts, q_hat, metric="l1")),
            np.asarray(_frozen_l1_distance_multi_ref(counts, q_hat)),
        )
        np.testing.assert_array_equal(
            np.asarray(metrics.distance_multi_xla(counts, q_hat, metric="l1")),
            np.asarray(_frozen_l1_distance_multi_xla(counts, q_hat)),
        )
        np.testing.assert_array_equal(
            np.asarray(metrics.distance_ref(counts, q_hat[0], metric="l1")),
            np.asarray(_frozen_l1_distance_ref(counts, q_hat[0])),
        )

    @pytest.mark.parametrize("q", QS)
    def test_jaxpr_identical(self, q):
        # Stronger than value equality: the l1 instance EMITS the same
        # program as the frozen body — zero added ops, so the compiled
        # artifact cannot differ either.
        counts, q_hat = _case(53, 24, q, seed=23)
        new = jax.make_jaxpr(
            lambda c, t: metrics.distance_multi_ref(c, t, metric="l1")
        )(counts, q_hat)
        old = jax.make_jaxpr(_frozen_l1_distance_multi_ref)(counts, q_hat)
        assert str(new) == str(old)

    def test_ops_l1_alias_bit_identical(self):
        counts, q_hat = _case(53, 24, 4, seed=29)
        np.testing.assert_array_equal(
            np.asarray(ops.l1_distance_multi(counts, q_hat)),
            np.asarray(ops.distance_multi(counts, q_hat, metric="l1")),
        )
        np.testing.assert_array_equal(
            np.asarray(ops.distance_multi(counts, q_hat, metric="l1")),
            np.asarray(_frozen_l1_distance_multi_ref(counts, q_hat)),
        )

    def test_metric_log_delta_l1_is_theorem1(self):
        eps = jnp.asarray([0.01, 0.06, 0.3], jnp.float32)
        n = jnp.asarray([10.0, 1e4, 1e6], jnp.float32)
        np.testing.assert_array_equal(
            np.asarray(bounds.metric_log_delta(eps, n, 24, metric="l1")),
            np.asarray(bounds.theorem1_log_delta(eps, n, 24)),
        )


# ---------------------------------------------------------------------------
# Bound family + closeness retirement rule
# ---------------------------------------------------------------------------


class TestBounds:
    def test_every_registry_metric_has_a_bound(self):
        # A metric cannot ship a kernel score without a bound family.
        assert tuple(bounds.BOUNDED_METRICS) == tuple(metrics.METRIC_NAMES)
        for m in metrics.METRIC_NAMES:
            v = float(bounds.metric_l1_budget(0.1, m))
            assert 0.0 < v <= 0.1  # budgets shrink (l1 is the identity)

    @pytest.mark.parametrize("metric", ["chi2", "hellinger"])
    def test_non_l1_bounds_are_conservative(self, metric):
        # Same eps, same n: a non-l1 metric may never claim MORE
        # confidence than the l1 bound it routes through.
        eps, n = 0.1, 5e4
        ld = float(bounds.metric_log_delta(eps, n, 24, metric=metric))
        ld_l1 = float(bounds.metric_log_delta(eps, n, 24, metric="l1"))
        assert ld >= ld_l1

    @pytest.mark.parametrize("metric", METRIC_NAMES)
    def test_metric_epsilon_inverts_budget(self, metric):
        # metric_epsilon(n, delta) is the metric-space radius whose
        # budget reproduces theorem1_epsilon(n, delta).
        n, delta, v_x = 3e4, 0.01, 24
        eps_m = float(bounds.metric_epsilon(n, delta, v_x, metric=metric))
        back = float(bounds.metric_l1_budget(eps_m, metric))
        want = float(bounds.theorem1_epsilon(n, delta, v_x))
        np.testing.assert_allclose(back, want, rtol=1e-5)

    def test_closeness_labels_and_termination(self):
        tau = jnp.asarray([0.02, 0.10, 0.19, 0.60], jnp.float32)
        st = dev.assign_closeness(
            tau, jnp.full((4,), 1e5, jnp.float32),
            eps=0.1, gap=0.1, delta=0.05, v_x=24,
        )
        # threshold = eps + gap/2 = 0.15
        np.testing.assert_array_equal(
            np.asarray(st.in_top_k), [True, True, False, False]
        )
        # margins: max(tau - eps, (eps + gap) - tau) — always >= gap/2
        np.testing.assert_allclose(
            np.asarray(st.eps_i), [0.18, 0.10, 0.09, 0.50], rtol=1e-5
        )
        # enough samples -> every slot certified, bound fired
        assert float(st.delta_upper) < 0.05
        assert not bool(np.asarray(st.active).any())

    def test_closeness_early_reject(self):
        # A clearly-far candidate (huge margin) must leave the active
        # set BEFORE a borderline one (margin == gap/2) — the engine
        # analogue of the closeness testers' cheap far-rejection.
        tau = jnp.asarray([0.21, 0.90], jnp.float32)  # borderline, far
        for n in (2e3, 1e4, 1e5):
            st = dev.assign_closeness(
                tau, jnp.full((2,), n, jnp.float32),
                eps=0.1, gap=0.2, delta=0.01, v_x=24,
            )
            a = np.asarray(st.active)
            if a[1]:
                assert a[0]  # far never outlasts borderline
        # and at moderate n, far already retired while borderline active
        st = dev.assign_closeness(
            tau, jnp.full((2,), 2e3, jnp.float32),
            eps=0.1, gap=0.2, delta=0.01, v_x=24,
        )
        assert bool(np.asarray(st.active)[0]) and not bool(np.asarray(st.active)[1])

    @pytest.mark.parametrize("metric", ["chi2", "hellinger"])
    def test_closeness_other_metrics(self, metric):
        tau = jnp.asarray([0.05, 0.5], jnp.float32)
        st = dev.assign_closeness(
            tau, jnp.full((2,), 1e6, jnp.float32),
            eps=0.2, gap=0.2, delta=0.05, v_x=24, metric=metric,
        )
        np.testing.assert_array_equal(np.asarray(st.in_top_k), [True, False])


# ---------------------------------------------------------------------------
# Autotune: per-metric plan keys
# ---------------------------------------------------------------------------


class TestPerMetricPlans:
    def test_tau_key_carries_metric(self):
        assert autotune.tau_key(64, 300, 4) == "vz=64,vx=300,q=4,dtype=float32,metric=l1"
        assert autotune.tau_key(64, 300, 4, metric="chi2").endswith(",metric=chi2")

    def test_plans_are_per_metric(self):
        reg = autotune.PlanRegistry(backend="cpu")
        reg.tau[autotune.tau_key(64, 300, 4, metric="chi2")] = autotune.TauPlan(
            variant="xla"
        )
        assert reg.tau_plan(64, 300, 4, metric="chi2") == autotune.TauPlan(variant="xla")
        # the l1 lookup at the same shape must NOT see the chi2 plan
        assert reg.tau_plan(64, 300, 4) == autotune.DEFAULT_TAU


# ---------------------------------------------------------------------------
# Mixed-type serving over one shared counts matrix
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served():
    from repro.data.layout import block_layout
    from repro.data.synth import SynthSpec, make_dataset

    spec = SynthSpec(
        v_z=48, v_x=16, num_tuples=120_000, k=5, n_close=6,
        close_distance=0.03, far_distance=0.4, zipf_a=1.0, seed=3,
    )
    ds = make_dataset(spec)
    blocked = block_layout(ds.z, ds.x, v_z=48, v_x=16, block_size=512, seed=3)
    return ds, blocked


class TestMixedServe:
    def test_topk_and_closeness_share_stream(self, served):
        from repro.serve.fastmatch_server import MatchServer

        ds, blocked = served
        srv = MatchServer(blocked, max_queries=4, lookahead=64, seed=3)
        rid_top = srv.submit(ds.target, k=5, eps=0.08, delta=0.05)
        rid_close = srv.submit_closeness(ds.target, eps=0.10, gap=0.25, delta=0.05)
        res = srv.run_until_idle()
        rt, rc = res[rid_top], res[rid_close]
        assert rt.qtype == "topk" and rc.qtype == "closeness"
        tau = ds.true_dists
        assert sorted(rt.ids.tolist()) == sorted(
            np.argsort(tau, kind="stable")[:5].tolist()
        )
        close_set = set(rc.ids.tolist())
        # promise: everything within eps labeled close, nothing beyond
        # eps + gap labeled close (gap region unconstrained)
        assert set(np.flatnonzero(tau <= 0.10).tolist()) <= close_set
        assert close_set.isdisjoint(np.flatnonzero(tau >= 0.35).tolist())
        # nearest-first by the scheduler's tau estimates at retirement
        est = np.asarray(rc.state.tau)
        assert list(rc.ids) == sorted(rc.ids.tolist(), key=lambda i: est[i])

    def test_mid_stream_admission_no_recompile(self, served):
        from repro.serve.fastmatch_server import MatchServer

        ds, blocked = served
        srv = MatchServer(blocked, max_queries=2, lookahead=32, seed=3)
        rid_top = srv.submit(ds.target, k=5, eps=0.08, delta=0.05)
        # drive a few windows so counts accumulate, then admit the
        # closeness query mid-stream into the live scheduler
        for _ in range(3):
            srv.step()
        tuples_before = srv.scheduler.tuples_read
        assert tuples_before > 0
        rid_close = srv.submit_closeness(ds.target, eps=0.10, gap=0.25, delta=0.05)
        res = srv.run_until_idle()
        rc = res[rid_close]
        # the late query rode the shared counts: its live-read counter
        # excludes what was sampled before admission
        assert rc.tuples_read <= srv.scheduler.tuples_read - tuples_before
        tau = ds.true_dists
        close_set = set(rc.ids.tolist())
        assert set(np.flatnonzero(tau <= 0.10).tolist()) <= close_set
        assert close_set.isdisjoint(np.flatnonzero(tau >= 0.35).tolist())

    @pytest.mark.parametrize("metric", ["chi2", "hellinger"])
    def test_non_l1_server_topk(self, served, metric):
        from repro.serve.fastmatch_server import MatchServer

        ds, blocked = served
        srv = MatchServer(blocked, max_queries=2, lookahead=64, seed=3, metric=metric)
        rid = srv.submit(ds.target, k=5, eps=0.3, delta=0.05)
        out = srv.run_until_idle()[rid]
        want = _oracle(
            ds.true_hists * 1.0, np.asarray([ds.target / ds.target.sum()]), metric
        )[0]
        # true_hists are already normalized rows — renormalize guard
        assert sorted(out.ids.tolist()) == sorted(
            np.argsort(want, kind="stable")[:5].tolist()
        )

    def test_closeness_rejects_bad_args(self, served):
        from repro.serve.fastmatch_server import MatchServer

        ds, blocked = served
        srv = MatchServer(blocked, max_queries=2, lookahead=64)
        with pytest.raises(ValueError, match="gap"):
            srv.submit_closeness(ds.target, eps=0.1, gap=0.0)
        with pytest.raises(ValueError, match="eps"):
            srv.submit_closeness(ds.target, eps=-0.1, gap=0.1)
