"""Multi-query serving subsystem: shared counts, vmapped stats, MatchServer.

The load-bearing property: a `MatchServer` running N queries over one
shared counts matrix must return the same top-k (and honor the same
delta_upper guarantee) as N independent `run_engine` calls, while
reading fewer tuples in total.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import deviations as dev
from repro.core import multiquery as mq
from repro.core.bitmap import unpack_mask
from repro.core.engine import EngineConfig, run_engine
from repro.core.histsim import HistSimParams
from repro.data.layout import block_layout
from repro.data.synth import SynthSpec, make_dataset, perturb_distribution

K, EPS, DELTA = 5, 0.08, 0.05


@pytest.fixture(scope="module")
def dataset():
    spec = SynthSpec(
        v_z=64, v_x=16, num_tuples=1_200_000, k=K, n_close=5,
        close_distance=0.02, far_distance=0.3, zipf_a=0.9, seed=5,
    )
    ds = make_dataset(spec)
    blocked = block_layout(ds.z, ds.x, v_z=spec.v_z, v_x=spec.v_x, block_size=512, seed=5)
    return spec, ds, blocked


@pytest.fixture(scope="module")
def targets(dataset):
    _, ds, _ = dataset
    rng = np.random.default_rng(9)
    return [ds.target] + [perturb_distribution(ds.target, d, rng) for d in (0.01, 0.03, 0.05)]


class TestDynamicDeviations:
    def test_matches_static_assignment_bitwise(self):
        rng = np.random.default_rng(0)
        for v_z, v_x, k in [(37, 16, 5), (8, 4, 8), (100, 24, 1)]:
            tau = jnp.asarray(rng.random(v_z), jnp.float32)
            n = jnp.asarray(rng.integers(0, 5000, v_z), jnp.float32)
            a = dev.assign_deviations(tau, n, k=k, eps=0.08, delta=0.05, v_x=v_x)
            b = dev.assign_deviations_dynamic(
                tau, n, k=jnp.int32(k), eps=jnp.float32(0.08),
                delta=jnp.float32(0.05), v_x=v_x,
            )
            for f in a._fields:
                np.testing.assert_array_equal(
                    np.asarray(getattr(a, f)), np.asarray(getattr(b, f)), err_msg=f
                )

    def test_matches_slowmatch_criterion(self):
        rng = np.random.default_rng(1)
        tau = jnp.asarray(rng.random(40), jnp.float32)
        n = jnp.asarray(rng.integers(1, 3000, 40), jnp.float32)
        a = dev.slowmatch_deviations(tau, n, k=6, eps=0.1, delta=0.02, v_x=12)
        b = dev.assign_deviations_dynamic(
            tau, n, k=jnp.int32(6), eps=jnp.float32(0.1),
            delta=jnp.float32(0.02), v_x=12, criterion="slowmatch",
        )
        np.testing.assert_array_equal(np.asarray(a.delta_upper), np.asarray(b.delta_upper))
        np.testing.assert_array_equal(np.asarray(a.active), np.asarray(b.active))


class TestMultiQueryState:
    def test_union_is_or_of_occupied_slots(self, dataset, targets):
        spec_s, ds, blocked = dataset
        spec = mq.MultiQuerySpec(v_z=spec_s.v_z, v_x=spec_s.v_x, max_queries=4)
        sched = mq.SharedCountsScheduler(blocked, spec, window=64, seed=0)
        for t in targets[:3]:
            sched.admit(t, k=K, eps=EPS, delta=DELTA)
        # read a little so active sets differentiate
        sched.run_window(sched.order[: sched.window])
        st = sched.state
        expect = np.zeros(spec_s.v_z, bool)
        for slot in range(4):
            expect |= np.asarray(st.active[slot])
        got = np.asarray(unpack_mask(st.union_words, spec_s.v_z))
        np.testing.assert_array_equal(got, expect)
        # empty slot contributes nothing
        assert not np.asarray(st.active[3]).any()
        assert float(st.delta_upper[3]) == 0.0

    def test_ingest_is_shared_and_target_independent(self, dataset, targets):
        spec_s, ds, blocked = dataset
        spec = mq.MultiQuerySpec(v_z=spec_s.v_z, v_x=spec_s.v_x, max_queries=2)
        sched = mq.SharedCountsScheduler(blocked, spec, window=32, seed=1)
        sched.admit(targets[0], k=K, eps=EPS, delta=DELTA)
        sched.admit(targets[1], k=K, eps=EPS, delta=DELTA)
        sched.run_window(sched.order[:32])
        counts = np.asarray(sched.state.counts)
        # counts equal the plain histogram of the blocks read — no
        # per-query copies, no target leakage
        read = sched.order[:32][np.asarray(sched.read_mask[sched.order[:32]])]
        z = blocked.z_blocks[read].reshape(-1)
        x = blocked.x_blocks[read].reshape(-1)
        ok = z >= 0
        expect = np.zeros((spec_s.v_z, spec_s.v_x))
        np.add.at(expect, (z[ok], x[ok]), 1.0)
        np.testing.assert_array_equal(counts, expect)
        np.testing.assert_array_equal(np.asarray(sched.state.n), expect.sum(axis=1))

    def test_slot_state_view_matches_slot(self, dataset, targets):
        spec_s, _, blocked = dataset
        spec = mq.MultiQuerySpec(v_z=spec_s.v_z, v_x=spec_s.v_x, max_queries=3)
        sched = mq.SharedCountsScheduler(blocked, spec, window=32, seed=2)
        sched.admit(targets[0], k=K, eps=EPS, delta=DELTA)
        sched.admit(targets[2], k=3, eps=0.1, delta=0.02)
        view = mq.slot_state(sched.state, 1)
        np.testing.assert_array_equal(np.asarray(view.tau), np.asarray(sched.state.tau[1]))
        assert view.counts is sched.state.counts  # genuinely shared


class TestOutcomeAccounting:
    def test_retire_before_any_window_reports_zero_passes(self, dataset, targets):
        """Regression: a query admitted mid-stream that terminates on the
        warm shared counts — before any window runs while it is live —
        must report passes=0 (and rounds=0), not a phantom pass."""
        spec_s, ds, blocked = dataset
        spec = mq.MultiQuerySpec(v_z=spec_s.v_z, v_x=spec_s.v_x, max_queries=2)
        sched = mq.SharedCountsScheduler(blocked, spec, window=64, seed=0)
        q0 = sched.admit(targets[0], k=K, eps=EPS, delta=DELTA)
        sched.pump()
        assert sched.passes > 0
        assert sched.outcomes[q0].passes >= 1
        # identical query against the warm cache: the bound already holds
        q1 = sched.admit(targets[0], k=K, eps=EPS, delta=DELTA)
        sched.pump()
        out = sched.outcomes[q1]
        assert out.terminated
        assert out.rounds == 0
        assert out.passes == 0  # used to report 1

    def test_mid_pass_query_counts_its_partial_pass(self, dataset, targets):
        """A query that did see windows inside one running pass still
        reports passes >= 1."""
        spec_s, ds, blocked = dataset
        spec = mq.MultiQuerySpec(v_z=spec_s.v_z, v_x=spec_s.v_x, max_queries=2)
        sched = mq.SharedCountsScheduler(blocked, spec, window=64, seed=0)
        qid = sched.admit(targets[0], k=K, eps=EPS, delta=DELTA)
        sched.pump()
        out = sched.outcomes[qid]
        assert out.rounds >= 1
        assert out.passes >= 1


class TestSlotMasking:
    def test_readmission_into_retired_slot_matches_fresh_server(self, dataset, targets):
        """Regression for the empty-slot tau masking: a query admitted
        into a slot another query retired from must resolve exactly as
        on a server that never reused the slot."""
        from repro.serve.fastmatch_server import MatchServer

        spec_s, ds, blocked = dataset
        recycled = MatchServer(blocked, max_queries=1, lookahead=256, seed=42)
        recycled.submit(targets[0], k=K, eps=EPS, delta=DELTA)
        recycled.run_until_idle()  # slot 0 retires here
        late = recycled.submit(targets[2], k=3, eps=0.1, delta=DELTA)
        r_late = recycled.run_until_idle()[late]

        fresh = MatchServer(blocked, max_queries=1, lookahead=256, seed=42)
        fresh.submit(targets[0], k=K, eps=EPS, delta=DELTA)
        fresh.run_until_idle()
        # same warm cache, but this server's slot 0 has never been
        # cleared+reused before `late2` (fresh scheduler state otherwise)
        late2 = fresh.submit(targets[2], k=3, eps=0.1, delta=DELTA)
        r2 = fresh.run_until_idle()[late2]
        np.testing.assert_array_equal(r_late.ids, r2.ids)
        assert r_late.exact == r2.exact
        assert r_late.tuples_read == r2.tuples_read

    def test_cleared_slot_tau_masked_at_init_value(self, dataset, targets):
        """After retirement an empty slot's tau reads 1.0 (the init
        value) and stays there through further stats — not a stale-q_hat
        distance snapshot."""
        spec_s, _, blocked = dataset
        spec = mq.MultiQuerySpec(v_z=spec_s.v_z, v_x=spec_s.v_x, max_queries=2)
        sched = mq.SharedCountsScheduler(blocked, spec, window=64, seed=0)
        sched.admit(targets[0], k=K, eps=EPS, delta=DELTA)
        sched.admit(targets[1], k=K, eps=EPS, delta=DELTA)
        sched.run_window(sched.order[: sched.window])
        sched.retire(1, exact=False, terminated=False)
        st = mq.stats_step(sched.state, spec=spec)
        np.testing.assert_array_equal(
            np.asarray(st.tau[1]), np.ones(spec_s.v_z, np.float32)
        )
        assert float(st.delta_upper[1]) == 0.0

    def test_k_cap_validated_at_admission(self, dataset, targets):
        from repro.serve.fastmatch_server import MatchServer

        spec_s, _, blocked = dataset
        server = MatchServer(blocked, max_queries=2, lookahead=64, seed=0, k_cap=4)
        with pytest.raises(ValueError, match="k_cap"):
            server.submit(targets[0], k=5, eps=EPS, delta=DELTA)
        rid = server.submit(targets[0], k=4, eps=EPS, delta=DELTA)
        assert len(server.run_until_idle()[rid].ids) == 4


class TestServerEquivalence:
    def test_matches_independent_engines(self, dataset, targets):
        """Tentpole acceptance: same top-k as N run_engine calls, same
        delta guarantee, fewer total tuples read."""
        from repro.serve.fastmatch_server import MatchServer

        spec_s, ds, blocked = dataset
        params = HistSimParams(v_z=spec_s.v_z, v_x=spec_s.v_x, k=K, eps=EPS, delta=DELTA)
        solo = [
            run_engine(blocked, t, params, EngineConfig(variant="fastmatch", seed=100 + i))
            for i, t in enumerate(targets)
        ]
        server = MatchServer(blocked, max_queries=len(targets), lookahead=512, seed=100)
        rids = [server.submit(t, k=K, eps=EPS, delta=DELTA) for t in targets]
        results = server.run_until_idle()

        total_shared = server.metrics["total_tuples_read"]
        total_solo = sum(r.tuples_read for r in solo)
        assert total_shared < total_solo

        for i, rid in enumerate(rids):
            r = results[rid]
            assert sorted(r.ids.tolist()) == sorted(solo[i].ids.tolist()), i
            if not r.exact:
                assert r.delta_upper < DELTA

    def test_more_queries_than_slots_queue_up(self, dataset, targets):
        from repro.serve.fastmatch_server import MatchServer

        spec_s, ds, blocked = dataset
        server = MatchServer(blocked, max_queries=2, lookahead=256, seed=3)
        rids = [server.submit(t, k=K, eps=EPS, delta=DELTA) for t in targets]
        # metrics must split saturation (full slots) from backlog (queue):
        # 4 submitted into 2 slots -> all 4 queued until the drain admits
        m = server.metrics
        assert m["queries_queued"] == len(targets) and m["queries_live"] == 0
        assert m["queries_pending"] == m["queries_queued"] + m["queries_live"]
        results = server.run_until_idle()
        m = server.metrics
        assert m["queries_queued"] == m["queries_live"] == m["queries_pending"] == 0
        assert set(results) == set(rids)
        for rid in rids:
            assert len(results[rid].ids) == K

    def test_late_admission_starts_from_shared_counts(self, dataset, targets):
        """A query admitted on a warm server must use the accumulated
        counts (full shared n_i) — costing (much) less I/O than solo."""
        from repro.serve.fastmatch_server import MatchServer

        spec_s, ds, blocked = dataset
        params = HistSimParams(v_z=spec_s.v_z, v_x=spec_s.v_x, k=K, eps=EPS, delta=DELTA)
        solo = run_engine(
            blocked, targets[1], params, EngineConfig(variant="fastmatch", seed=7)
        )

        server = MatchServer(blocked, max_queries=2, lookahead=512, seed=7)
        server.submit(targets[0], k=K, eps=EPS, delta=DELTA)
        server.run_until_idle()
        warm_tuples = server.metrics["total_tuples_read"]
        assert warm_tuples > 0

        late = server.submit(targets[1], k=K, eps=EPS, delta=DELTA)
        r = server.run_until_idle()[late]
        new_io = server.metrics["total_tuples_read"] - warm_tuples
        assert new_io < solo.tuples_read
        assert sorted(r.ids.tolist()) == sorted(solo.ids.tolist())
        if not r.exact:
            assert r.delta_upper < DELTA

    def test_step_driven_serving_terminates(self, dataset, targets):
        """step() — the incremental serving unit — must make progress
        every pass and resolve queries without run_until_idle."""
        from repro.serve.fastmatch_server import MatchServer

        spec_s, ds, blocked = dataset
        server = MatchServer(blocked, max_queries=2, lookahead=128, seed=0)
        rids = [server.submit(t, k=K, eps=EPS, delta=DELTA) for t in targets[:2]]
        steps = 0
        while not all(rid in server.results for rid in rids):
            server.step()
            steps += 1
            assert steps < 10_000, "step() made no progress"
        for rid in rids:
            r = server.results[rid]
            assert len(r.ids) == K
            assert r.exact or r.delta_upper < DELTA

    def test_step_stalled_pass_falls_back_to_exact(self):
        """A pass that reads nothing must trigger the exact completion
        under step(), not an infinite re-marking loop (regression)."""
        from repro.serve.fastmatch_server import MatchServer

        spec = SynthSpec(v_z=30, v_x=8, num_tuples=40_000, k=3, n_close=3, seed=11)
        ds = make_dataset(spec)
        blocked = block_layout(ds.z, ds.x, v_z=spec.v_z, v_x=spec.v_x, block_size=256, seed=11)
        server = MatchServer(blocked, max_queries=1, lookahead=64, seed=0)
        rid = server.submit(ds.target, k=3, eps=0.02, delta=1e-6)  # unreachable bound
        steps = 0
        while rid not in server.results:
            server.step()
            steps += 1
            assert steps < 10_000, "step() livelocked on a zero-read pass"
        assert server.results[rid].exact

    def test_exhausted_dataset_serves_exactly(self, targets):
        """Once every block is read, new queries resolve instantly and
        exactly from the cached counts."""
        from repro.serve.fastmatch_server import MatchServer

        spec = SynthSpec(v_z=30, v_x=8, num_tuples=20_000, k=3, n_close=3, seed=11)
        ds = make_dataset(spec)
        blocked = block_layout(ds.z, ds.x, v_z=spec.v_z, v_x=spec.v_x, block_size=256, seed=11)
        server = MatchServer(blocked, max_queries=2, seed=0)
        first = server.submit(ds.target, k=3, eps=0.02, delta=0.001)
        r1 = server.run_until_idle()[first]
        assert r1.exact  # tiny dataset forces the complete read
        before = server.metrics["total_tuples_read"]
        late = server.submit(ds.target, k=3, eps=0.02, delta=0.001)
        r2 = server.run_until_idle()[late]
        assert r2.exact
        assert server.metrics["total_tuples_read"] == before  # zero new I/O
        assert sorted(r2.ids.tolist()) == sorted(ds.true_top_k.tolist())
        # exact contract regression: even when the statistical bound
        # fires (loose delta), an answer over fully-read data is exact
        loose = server.submit(ds.target, k=3, eps=0.2, delta=0.5)
        r3 = server.run_until_idle()[loose]
        assert r3.exact
