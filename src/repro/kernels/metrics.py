"""Pluggable distance metrics over the shared row-normalized counts matrix.

Every query type this engine serves reduces to the same per-round
computation: normalize each candidate row of the shared (V_Z, V_X)
counts matrix once, then reduce an ELEMENTWISE score against each of Q
target distributions,

    tau[q, i] = sum_x score(r_hat[i, x], q_hat[q, x]).

The score is the only thing that differs between distances, so the
whole kernel zoo — the XLA reference forms, the fused-3D broadcast
variant, and the Pallas single-/two-sweep Q-batched tile kernels — is
written ONCE here, parameterized by a `MetricDef`, and ℓ1 becomes one
registry instance (`ops.l1_distance_multi` is now a thin alias; its
output is bit-identical to the pre-metric-layer kernels because the l1
instance emits the exact same op sequence).

Registry entries are `(score, l1_budget, native_l1_budget, bytes_model)`:

  score      — the elementwise lane term (runs inside the kernels);
  l1_budget  — the deviation half of the metric: an inverse modulus of
               continuity mapping a tolerated metric-space deviation to
               the ℓ1 deviation that implies it, which is what lets
               `core.bounds.metric_log_delta` reuse Theorem 1's ℓ1
               concentration bound for every metric (see bounds.py for
               the derivations — conservative for chi2/hellinger);
  native_l1_budget — the metric-native refinement: the same inverse
               modulus made OBSERVATION-AWARE (it may read the measured
               tau), always >= l1_budget by construction (each form is
               a max over independently valid budgets), so the implied
               sample complexity never exceeds the conservative one.
               Derivations in `core/bounds.py`.
  bytes_model — analytic HBM traffic per tau round. All three metrics
               stream the same bytes (they differ in VPU flops only),
               so they share `streaming_tau_bytes`; the field exists so
               a metric with different traffic (e.g. one needing a
               second statistics pass) can say so to the autotuner.

Metrics ship three instances:

  l1         sum |r - q|            in [0, 2]; empty row -> 1
  chi2       sum (r-q)^2 / (r+q)    in [0, 2]; 0/0 lanes -> 0; empty
             row -> 1 (= sum q). The classic chi-square distance;
             dominated pointwise by |r - q| so also <= l1.
  hellinger  0.5 * sum (sqrt(r) - sqrt(q))^2   — SQUARED Hellinger,
             in [0, 1]; empty row -> 0.5. Additive over lanes (which is
             what the accumulating two-sweep kernel needs) and monotone
             in the Hellinger distance proper, so top-k rankings agree.

All scores are 0 on padded lanes (r = q = 0), so the kernels' lane
padding needs no masking — the same property the l1 kernels relied on.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "METRICS",
    "METRIC_NAMES",
    "MetricDef",
    "coerce_metric",
    "distance_ref",
    "distance_multi_ref",
    "distance_multi_xla",
    "distance_pallas",
    "distance_multi_pallas",
    "streaming_tau_bytes",
    "MAX_SINGLE_BLOCK_VX",
]

_Z_TILE = 256
# Lane-tile width: one (Z_TILE x X_TILE) f32 block must fit VMEM with
# headroom (256 x 4096 x 4B = 4 MiB). V_X beyond this is lane-tiled.
_X_TILE = 4096
# Single-block V_X bound for the Q=1 (unrolled) kernel form.
MAX_SINGLE_BLOCK_VX = 4096


# ---------------------------------------------------------------------------
# Elementwise scores (run inside the Pallas kernels AND the XLA forms)
# ---------------------------------------------------------------------------


def _score_l1(r: jax.Array, q: jax.Array) -> jax.Array:
    return jnp.abs(r - q)


def _score_chi2(r: jax.Array, q: jax.Array) -> jax.Array:
    # 0/0 -> 0 by convention; since r, q >= 0, the denominator is zero
    # only when both are (|r - q| <= r + q), so the guarded divide is
    # exact — no mass is ever dropped.
    s = r + q
    d = r - q
    return jnp.where(s > 0.0, (d * d) / jnp.where(s > 0.0, s, 1.0), 0.0)


def _score_hellinger(r: jax.Array, q: jax.Array) -> jax.Array:
    d = jnp.sqrt(r) - jnp.sqrt(q)
    return 0.5 * (d * d)


def streaming_tau_bytes(
    v_z: int, v_x: int, q: int, *, passes: int, counts_itemsize: int
) -> int:
    """HBM bytes per tau round for a streaming (counts-pass) metric:
    ``passes`` reads of the counts matrix plus targets in / taus out."""
    return passes * v_z * v_x * counts_itemsize + q * (v_x + v_z) * 4


@dataclasses.dataclass(frozen=True)
class MetricDef:
    """One pluggable distance: score + deviation budget + traffic model."""

    name: str
    score: Callable[[jax.Array, jax.Array], jax.Array]
    # Inverse modulus of continuity w.r.t. ℓ1: the ℓ1 deviation that
    # guarantees a metric-space deviation <= eps. Pure scalar math
    # (works on floats and traced jnp scalars alike); the l1 instance
    # is the IDENTITY — it must add zero ops so the refactored l1
    # bound path stays bit-identical to Theorem 1 as previously coded.
    l1_budget: Callable
    bytes_model: Callable[..., int] = streaming_tau_bytes
    # tau of a candidate with zero sampled mass (r_hat = 0 vs a
    # normalized target): documentation + oracle value for tests.
    empty_row_tau: float = 1.0
    # Observation-aware inverse modulus (eps, tau) -> ℓ1 budget; None
    # falls back to the uniform `l1_budget`. Must dominate `l1_budget`
    # pointwise (it is a max over valid budgets including the uniform
    # one), so switching the engine to native bounds can only retire
    # queries EARLIER, never claim less than the conservative family.
    native_l1_budget: Optional[Callable] = None


def _budget_l1(eps):
    return eps


def _budget_chi2(eps):
    # chi2(p, q) is 3-Lipschitz in p under ℓ1 (|d/dp (p-q)^2/(p+q)| =
    # |(p - q)(p + 3q)| / (p + q)^2 <= 3), so an ℓ1 deviation of eps/3
    # moves the chi2 distance by at most eps. See bounds.py.
    return eps / 3.0


def _budget_hellinger(eps):
    # |H^2(p, t) - H^2(q, t)| <= sqrt(l1) + l1/2 (Cauchy-Schwarz on the
    # sqrt difference), so l1 <= eps^2/4 keeps the squared-Hellinger
    # deviation within eps/2 + eps^2/8 <= eps for eps <= 1. See bounds.py.
    return 0.25 * eps * eps


def _native_budget_chi2(eps, tau):
    # max of two independently valid ℓ1 budgets for a chi2 deviation of
    # eps (derivations in core/bounds.py):
    #   eps/3                        — the uniform 3-Lipschitz modulus
    #                                  (tight at tau = 2, cannot be
    #                                  uniformly improved);
    #   (sqrt(tau+eps) - sqrt(tau))^2 — via the Le Cam metric sqrt(Δ/2)
    #                                  and the observed tau (-> eps at
    #                                  tau = 0: 3x the uniform budget,
    #                                  9x fewer samples for close
    #                                  candidates).
    t = jnp.maximum(tau, 0.0)
    tri = jnp.square(jnp.sqrt(t + eps) - jnp.sqrt(t))
    return jnp.maximum(eps / 3.0, tri)


def _native_budget_hellinger(eps, tau):
    # max of three independently valid ℓ1 budgets for a squared-
    # Hellinger deviation of eps (derivations in core/bounds.py):
    #   eps^2/4                       — the conservative PR-9 floor;
    #   (sqrt(1+2 eps) - 1)^2         — EXACT inverse of the Cauchy-
    #                                   Schwarz modulus sqrt(l1)+l1/2
    #                                   (~eps^2 for small eps, 4x the
    #                                   floor);
    #   2 (sqrt(tau+eps)-sqrt(tau))^2 — via the Hellinger metric,
    #                                   H <= sqrt(l1/2), and the
    #                                   observed tau (-> 2 eps at
    #                                   tau = 0).
    t = jnp.maximum(tau, 0.0)
    cs = jnp.square(jnp.sqrt(1.0 + 2.0 * eps) - 1.0)
    tri = 2.0 * jnp.square(jnp.sqrt(t + eps) - jnp.sqrt(t))
    return jnp.maximum(jnp.maximum(0.25 * eps * eps, cs), tri)


METRICS = {
    "l1": MetricDef("l1", _score_l1, _budget_l1, empty_row_tau=1.0),
    "chi2": MetricDef(
        "chi2", _score_chi2, _budget_chi2, empty_row_tau=1.0,
        native_l1_budget=_native_budget_chi2,
    ),
    "hellinger": MetricDef(
        "hellinger", _score_hellinger, _budget_hellinger, empty_row_tau=0.5,
        native_l1_budget=_native_budget_hellinger,
    ),
}
METRIC_NAMES = tuple(METRICS)


def coerce_metric(metric) -> MetricDef:
    """Registry lookup with a helpful error; accepts a MetricDef as-is."""
    if isinstance(metric, MetricDef):
        return metric
    try:
        return METRICS[metric]
    except (KeyError, TypeError):
        raise ValueError(
            f"unknown metric {metric!r}; have {METRIC_NAMES}"
        ) from None


# ---------------------------------------------------------------------------
# XLA reference forms (semantics of record — see kernels/ref.py)
# ---------------------------------------------------------------------------


def distance_ref(counts: jax.Array, q_hat: jax.Array, *, metric="l1") -> jax.Array:
    """(V_Z,) float32 tau_i = sum_x score(normalize(counts_i), q_hat).

    Rows with zero mass score the empty histogram against q_hat (tau =
    the metric's ``empty_row_tau``); their delta_i is 1 anyway (n_i = 0)
    so the engine never terminates on their account.
    """
    m = coerce_metric(metric)
    counts = counts.astype(jnp.float32)
    row = jnp.sum(counts, axis=1, keepdims=True)
    r_hat = counts / jnp.maximum(row, 1.0)
    return jnp.sum(m.score(r_hat, q_hat[None, :].astype(jnp.float32)), axis=1)


def distance_multi_ref(counts: jax.Array, q_hat: jax.Array, *, metric="l1") -> jax.Array:
    """(Q, V_Z) batched tau: normalization hoisted ONCE for all queries,
    per-query lane reductions unrolled over the static leading axis
    (each 2D reduce runs on XLA:CPU's full thread pool — measured ~2x
    faster than the fused-3D broadcast at Q=8). Elementwise ops and the
    lane reduction match `distance_ref` exactly, so each tau row is
    bit-identical to the corresponding single-query call.
    """
    m = coerce_metric(metric)
    counts = counts.astype(jnp.float32)
    row = jnp.sum(counts, axis=1, keepdims=True)
    r_hat = counts / jnp.maximum(row, 1.0)
    q = q_hat.astype(jnp.float32)
    return jnp.stack(
        [jnp.sum(m.score(r_hat, q[i][None, :]), axis=1) for i in range(q.shape[0])]
    )


def distance_multi_xla(counts: jax.Array, q_hat: jax.Array, *, metric="l1") -> jax.Array:
    """(Q, V_Z) batched tau as one fused (Q, V_Z, V_X) broadcast — "let
    XLA schedule it". Addition order over the lane axis matches the
    stacked-2D form, so the result is bit-identical to
    `distance_multi_ref`; only measured wall time differs (exactly what
    `kernels.autotune` measures).
    """
    m = coerce_metric(metric)
    counts = counts.astype(jnp.float32)
    row = jnp.sum(counts, axis=1, keepdims=True)
    r_hat = counts / jnp.maximum(row, 1.0)
    q = q_hat.astype(jnp.float32)
    return jnp.sum(m.score(r_hat[None, :, :], q[:, None, :]), axis=2)


# ---------------------------------------------------------------------------
# Pallas TPU kernels: the l1_distance_multi tile structure, score-generic
# ---------------------------------------------------------------------------


def _distance_multi_kernel(counts_ref, q_ref, out_ref, *, num_q: int, score):
    """Single-sweep: whole (padded) V_X in one block."""
    counts = counts_ref[...].astype(jnp.float32)  # (Z_TILE, V_X)
    row = jnp.sum(counts, axis=1, keepdims=True)
    r_hat = counts / jnp.maximum(row, 1.0)
    q = q_ref[...].astype(jnp.float32)  # (Q, V_X)
    for i in range(num_q):  # unrolled: counts tile stays VMEM-resident
        out_ref[i, :] = jnp.sum(score(r_hat, q[i][None, :]), axis=1)


def _distance_multi_tiled_kernel(counts_ref, q_ref, out_ref, row_ref, *, num_q: int, score):
    """Lane-tiled: phase 0 row sums, phase 1 per-query tau partials.

    Requires the score to be additive over lanes — true of every
    registry metric (l1 / chi2 / squared Hellinger are all plain lane
    sums of an elementwise term).
    """
    phase = pl.program_id(1)
    xb = pl.program_id(2)
    counts = counts_ref[...].astype(jnp.float32)  # (Z_TILE, X_TILE)

    @pl.when((phase == 0) & (xb == 0))
    def _init_row():
        row_ref[...] = jnp.zeros_like(row_ref)

    @pl.when(phase == 0)
    def _accum_row():
        row_ref[...] += jnp.sum(counts, axis=1, keepdims=True)

    @pl.when((phase == 1) & (xb == 0))
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(phase == 1)
    def _accum_tau():
        r_hat = counts / jnp.maximum(row_ref[:, 0:1], 1.0)
        q = q_ref[...].astype(jnp.float32)  # (Q, X_TILE)
        for i in range(num_q):
            out_ref[i, :] += jnp.sum(score(r_hat, q[i][None, :]), axis=1)


def distance_multi_pallas(
    counts: jax.Array,
    q_hat: jax.Array,
    *,
    metric="l1",
    z_tile: int = _Z_TILE,
    x_tile: int = _X_TILE,
    sweeps: int = 0,
    interpret: bool = False,
) -> jax.Array:
    """(Q, V_Z) float32 distances tau[q, i] for a (Q, V_X) target batch.

    Each (Z_TILE, V_X) counts tile is loaded into VMEM ONCE,
    row-normalized once, and scored against the whole (Q, V_X) target
    matrix before the next tile is fetched: HBM traffic is
    V_Z * V_X + Q * V_X per round, independent of Q, for EVERY metric —
    the score only changes the VPU lane term. V_X and V_Z are padded
    internally; q_hat padding is 0 and every registry score is 0 at
    (0, 0), so padded lanes contribute nothing.

    ``sweeps`` selects the layout (an autotuner knob — both layouts are
    bit-identical): 0 picks by padded V_X (single-sweep when V_X fits
    one ``x_tile`` VMEM block, else the two-sweep lane-tiled form whose
    phase 0 accumulates row sums into a VMEM scratch and phase 1
    accumulates the per-query score partials), 1 forces single-sweep
    (raises if V_X does not fit), 2 forces two-sweep.
    """
    score = coerce_metric(metric).score
    v_z, v_x = counts.shape
    num_q, v_xq = q_hat.shape
    if v_xq != v_x:
        raise ValueError(f"q_hat V_X={v_xq} does not match counts V_X={v_x}")
    if x_tile % 128 != 0:
        raise ValueError(f"x_tile must be a lane multiple of 128, got {x_tile}")
    if sweeps not in (0, 1, 2):
        raise ValueError(f"sweeps must be 0 (auto), 1 or 2, got {sweeps}")

    z_tile = min(z_tile, v_z)
    vz_pad = -(-v_z // z_tile) * z_tile
    vx_pad = max(128, -(-v_x // 128) * 128)
    if sweeps == 1 and vx_pad > x_tile:
        raise ValueError(
            f"sweeps=1 needs padded V_X ({vx_pad}) <= x_tile ({x_tile})"
        )
    if vx_pad <= x_tile and sweeps != 2:
        x_tile, tiled = vx_pad, False
    else:
        x_tile = min(x_tile, vx_pad)  # forced two-sweep on a small V_X
        vx_pad, tiled = -(-v_x // x_tile) * x_tile, True
    if (vz_pad, vx_pad) != (v_z, v_x):
        counts = jnp.pad(counts, ((0, vz_pad - v_z), (0, vx_pad - v_x)))
        q_hat = jnp.pad(q_hat, ((0, 0), (0, vx_pad - v_x)))

    out_shape = jax.ShapeDtypeStruct((num_q, vz_pad), jnp.float32)
    if not tiled:
        out = pl.pallas_call(
            functools.partial(_distance_multi_kernel, num_q=num_q, score=score),
            grid=(vz_pad // z_tile,),
            in_specs=[
                pl.BlockSpec((z_tile, vx_pad), lambda zb: (zb, 0)),
                pl.BlockSpec((num_q, vx_pad), lambda zb: (0, 0)),
            ],
            out_specs=pl.BlockSpec((num_q, z_tile), lambda zb: (0, zb)),
            out_shape=out_shape,
            interpret=interpret,
        )(counts, q_hat)
    else:
        out = pl.pallas_call(
            functools.partial(_distance_multi_tiled_kernel, num_q=num_q, score=score),
            grid=(vz_pad // z_tile, 2, vx_pad // x_tile),
            in_specs=[
                pl.BlockSpec((z_tile, x_tile), lambda zb, ph, xb: (zb, xb)),
                pl.BlockSpec((num_q, x_tile), lambda zb, ph, xb: (0, xb)),
            ],
            out_specs=pl.BlockSpec((num_q, z_tile), lambda zb, ph, xb: (0, zb)),
            out_shape=out_shape,
            scratch_shapes=[pltpu.VMEM((z_tile, 128), jnp.float32)],
            interpret=interpret,
        )(counts, q_hat)
    return out[:, :v_z]


def distance_pallas(
    counts: jax.Array,
    q_hat: jax.Array,
    *,
    metric="l1",
    z_tile: int = _Z_TILE,
    interpret: bool = False,
) -> jax.Array:
    """(V_Z,) float32 single-query tau — the Q=1 instance of the batched
    kernel (what the autotuner's "unrolled" variant stacks Q times).
    V_X must fit one VMEM block (<= `MAX_SINGLE_BLOCK_VX`).
    """
    if counts.shape[1] > MAX_SINGLE_BLOCK_VX:
        raise ValueError(
            f"V_X={counts.shape[1]} exceeds single-block bound {MAX_SINGLE_BLOCK_VX}"
        )
    return distance_multi_pallas(
        counts, q_hat[None, :], metric=metric, z_tile=z_tile, interpret=interpret
    )[0]
