"""Theorem 1 of the paper and related concentration bounds.

The paper's central statistical tool (Sec 3.4):

    With n_i samples for candidate i over a support of size ``V_X``,
    the empirical distribution is within eps_i of the true one in l1
    with probability > 1 - delta_i, where

        eps_i = sqrt( (2 * V_X / n_i) * log(2 / delta_i**(1/V_X)) )

    equivalently (the form used inside HistSim, Alg. 1 line 12):

        delta_i = 2**V_X * exp(-eps_i**2 * n_i / 2)

All computations are done in log space for numerical robustness: for
moderate V_X (say 161 or 7548-candidate queries with V_X up to 161) the
term 2**V_X overflows float64 long before the bound becomes vacuous.

Also provided, for the paper's Fig. 4 and the SlowMatch baseline:

* ``waggoner_epsilon`` — the prior-art optimal bound of Waggoner '15
  (Theorem 3.1 there, as cited by the paper): the l1 learning bound with
  larger constants,  eps = sqrt(V_X/n) + sqrt((2/n) * log(1/delta)).
* ``slowmatch_epsilon`` — the fixed-confidence (1 - delta/|V_Z|) interval
  width used by the SlowMatch termination criterion.

Per-metric bound family (the deviation half of the pluggable-metric
layer; scores live in `repro.kernels.metrics`):

Theorem 1 concentrates the EMPIRICAL DISTRIBUTION in ℓ1. Any distance
t(p, q) that is uniformly continuous in its first argument under ℓ1
inherits a concentration bound through its inverse modulus of
continuity B_t: if ||p' - p||_1 <= B_t(eps) implies
|t(p', q) - t(p, q)| <= eps for every q, then

    Pr[ |t(r_hat, q) - t(r, q)| > eps ] <= delta_theorem1(B_t(eps), n).

`metric_log_delta` is exactly that composition, with B_t from the
metric registry:

  l1         B(eps) = eps — the identity, zero extra ops, so the l1
             arm of the refactor is Theorem 1 verbatim (bit-identical
             to the pre-metric-layer code).
  chi2       B(eps) = eps/3. chi2(p,q) = sum (p-q)^2/(p+q) is
             3-Lipschitz in p under ℓ1: per coordinate
             |d/dp (p-q)^2/(p+q)| = |(p-q)(p+3q)|/(p+q)^2 <= 3 because
             |p-q| <= p+q and p+3q <= 3(p+q); summing per-coordinate
             mean-value bounds along the segment p -> p' gives
             |chi2(p',q) - chi2(p,q)| <= 3 ||p' - p||_1.
             DELIBERATELY CONSERVATIVE: metric-native chi-square tail
             bounds (Canonne et al. 2022) are tighter, but this one is
             valid for every (p, q) pair and reuses the exact Theorem-1
             machinery the engine already trusts.
  hellinger  B(eps) = eps^2/4 (squared Hellinger, the registry's tau).
             By Cauchy-Schwarz, |H^2(p,t) - H^2(q,t)| <=
             sqrt(||p-q||_1) + ||p-q||_1/2, so an ℓ1 deviation of
             eps^2/4 moves H^2 by at most eps/2 + eps^2/8 <= eps for
             eps <= 1 (and H^2 itself is <= 1, so eps > 1 is vacuous).
             Also conservative — the square-root modulus is what makes
             Hellinger queries the most sample-hungry of the three.

The closeness (two-sided tolerance) test built on these bounds lives in
`repro.core.deviations.assign_closeness`; the early-reject behavior for
clearly-far candidates is emergent there — a candidate far outside
[eps, eps+gap] gets a large decision margin, hence a tiny delta_i,
hence drops out of the active sampling set after few samples, which is
the engine-shaped analogue of the Diakonikolas-Kane closeness testers'
"cheap rejection of far distributions".

Metric-native bounds (`metric_native_*`, default since the anytime PR):

The uniform budgets above hold for EVERY (p, q) pair, which makes them
worst-case — the chi2 constant 3 is attained only at tau = 2 and the
Hellinger square-root modulus only matters near tau = 1. The native
family sharpens them with the candidate's own OBSERVED distance tau,
in the spirit of the instance-near-optimal identity testers of Canonne
et al. (2022): each metric's `native_l1_budget(eps, tau)` is a max
over several independently valid ℓ1 budgets, so it dominates the
uniform `l1_budget(eps)` pointwise BY CONSTRUCTION (never fewer
samples, usually far fewer).

  chi2       max(eps/3, (sqrt(tau+eps) - sqrt(tau))^2).
             chi2(p,q) here is the triangular discrimination
             Δ(p,q) = sum (p-q)^2/(p+q) ∈ [0,2]; LC = Δ/2 is the
             Le Cam divergence and sqrt(LC) is a metric satisfying
             sqrt(LC) <= sqrt(l1/2) [since Δ <= l1]. The triangle
             inequality in sqrt(LC) space gives: an ℓ1 learning error
             of b moves Δ by at most (sqrt(tau + eps') - sqrt(tau))
             ... inverted: b = (sqrt(tau+eps) - sqrt(tau))^2 keeps the
             Δ deviation within eps at observed distance tau. At
             tau = 0 this is eps — 3x the uniform budget, 9x fewer
             samples for the near candidates the top-k set actually
             needs resolved.
  hellinger  max(eps^2/4, (sqrt(1+2 eps) - 1)^2,
                 2 (sqrt(tau+eps) - sqrt(tau))^2).
             The middle term is the EXACT inverse of the Cauchy-
             Schwarz modulus sqrt(b) + b/2 <= eps (solve the
             quadratic), ~eps^2 for small eps — 4x the conservative
             floor. The last is the triangle inequality in the
             Hellinger metric H <= sqrt(l1/2) at observed tau = H^2;
             at tau = 0 it is 2 eps.

Both tau-dependent budgets use the observed (empirical) tau exactly
the way the engine already uses the empirical split point to set
eps_i — the same plug-in convention, applied to the tail bound's
radius. `metric_native_log_delta(..., metric="l1")` short-circuits to
`theorem1_log_delta` at the PYTHON level: the l1 arm compiles the
exact pre-anytime program, bit-identical.

`metric_native_epsilon` is the inverse direction (host-side, for
anytime confidence statements and pruning): given the ℓ1 radius
b = theorem1_epsilon(n, delta), the guaranteed metric-space deviation
is the min over the inverted moduli —
  l1: b; chi2: min(3 b, b + 2 sqrt(tau b));
  hellinger: min(sqrt(b) + b/2, b/2 + sqrt(2 tau b)).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import metrics as _metrics

__all__ = [
    "theorem1_epsilon",
    "theorem1_delta",
    "theorem1_log_delta",
    "theorem1_samples",
    "metric_l1_budget",
    "metric_log_delta",
    "metric_epsilon",
    "metric_native_l1_budget",
    "metric_native_log_delta",
    "metric_native_epsilon",
    "BOUNDED_METRICS",
    "waggoner_epsilon",
    "slowmatch_epsilon",
]

# Metrics this bound family covers — pinned by tests/test_metrics.py to
# the kernel registry, so a metric cannot ship a score without a bound.
BOUNDED_METRICS = _metrics.METRIC_NAMES

_LOG2 = 0.6931471805599453


def theorem1_epsilon(n: jax.Array, delta: jax.Array, v_x: int) -> jax.Array:
    """eps such that ||r_hat - r*||_1 < eps w.p. > 1 - delta after n samples.

    eps = sqrt( (2 V_X / n) * log(2 / delta^(1/V_X)) )
        = sqrt( (2 V_X / n) * (log 2 - log(delta)/V_X) )
        = sqrt( (2 / n) * (V_X log 2 - log delta) )
    """
    n = jnp.asarray(n, jnp.float32)
    log_delta = jnp.log(jnp.asarray(delta, jnp.float32))
    n = jnp.maximum(n, 1.0)
    return jnp.sqrt((2.0 / n) * (v_x * _LOG2 - log_delta))


def theorem1_log_delta(eps: jax.Array, n: jax.Array, v_x: int) -> jax.Array:
    """log of the failure probability after n samples at deviation eps.

    log delta = V_X log 2 - eps^2 n / 2, clamped to <= 0 (delta <= 1).
    """
    eps = jnp.asarray(eps, jnp.float32)
    n = jnp.asarray(n, jnp.float32)
    log_delta = v_x * _LOG2 - 0.5 * eps * eps * n
    return jnp.minimum(log_delta, 0.0)


def theorem1_delta(eps: jax.Array, n: jax.Array, v_x: int) -> jax.Array:
    """delta_i = min(1, 2^V_X exp(-eps^2 n / 2))."""
    return jnp.exp(theorem1_log_delta(eps, n, v_x))


def theorem1_samples(eps: float, delta: float, v_x: int) -> int:
    """Samples needed for eps-deviation w.p. > 1-delta (Theorem 1 inverted).

    n = (2 / eps^2) * (V_X log 2 - log delta)
    """
    import math

    n = (2.0 / (eps * eps)) * (v_x * _LOG2 - math.log(delta))
    return int(math.ceil(n))


def metric_l1_budget(eps, metric: str = "l1"):
    """The ℓ1 deviation that guarantees a ``metric``-space deviation of
    at most ``eps`` (the inverse modulus of continuity B_t — derivations
    in the module docstring). Pure scalar math from the kernel registry;
    works on host floats and traced jnp scalars alike. The l1 branch is
    the IDENTITY at the Python level — zero extra ops, so l1 callers
    compile the exact pre-metric-layer program.
    """
    return _metrics.coerce_metric(metric).l1_budget(eps)


def metric_log_delta(eps, n, v_x: int, metric: str = "l1") -> jax.Array:
    """log failure probability for deviation ``eps`` IN METRIC SPACE:
    Theorem 1 evaluated at the metric's ℓ1 budget. For metric="l1" this
    IS `theorem1_log_delta` (same ops, bit-identical)."""
    return theorem1_log_delta(metric_l1_budget(eps, metric), n, v_x)


def metric_epsilon(n, delta, v_x: int, metric: str = "l1"):
    """Metric-space deviation guaranteed w.p. > 1 - delta after n
    samples — `theorem1_epsilon` pushed through the inverse of the
    metric's budget (host-side telemetry/benchmark helper; accepts
    numpy arrays). l1: eps; chi2: 3 eps; hellinger: 2 sqrt(eps)."""
    eps1 = theorem1_epsilon(n, delta, v_x)
    if metric == "l1":
        return eps1
    if metric == "chi2":
        return 3.0 * eps1
    if metric == "hellinger":
        return 2.0 * jnp.sqrt(eps1)
    raise ValueError(f"unknown metric {metric!r}; have {BOUNDED_METRICS}")


def metric_native_l1_budget(eps, tau, metric: str = "l1"):
    """Observation-aware ℓ1 budget for a ``metric`` deviation of ``eps``
    at observed distance ``tau`` (derivations in the module docstring).
    A max over independently valid budgets, so it dominates the uniform
    `metric_l1_budget` pointwise by construction. Metrics without a
    native budget (l1 itself) fall back to the uniform one — for l1
    that is the identity, zero extra ops.
    """
    mdef = _metrics.coerce_metric(metric)
    if mdef.native_l1_budget is None:
        return mdef.l1_budget(eps)
    return mdef.native_l1_budget(eps, tau)


def metric_native_log_delta(eps, n, v_x: int, *, tau, metric: str = "l1") -> jax.Array:
    """log failure probability for a metric-space deviation ``eps`` at
    observed distance ``tau`` — Theorem 1 at the native ℓ1 budget.
    The l1 arm short-circuits at the Python level to
    `theorem1_log_delta` (bit-identical to the pre-anytime program);
    other metrics get log-deltas <= the conservative `metric_log_delta`
    (budget dominance), i.e. retirement never later, usually earlier.
    """
    mdef = _metrics.coerce_metric(metric)
    if mdef.native_l1_budget is None:
        return theorem1_log_delta(mdef.l1_budget(eps), n, v_x)
    return theorem1_log_delta(mdef.native_l1_budget(eps, tau), n, v_x)


def metric_native_epsilon(n, delta, v_x: int, *, tau, metric: str = "l1"):
    """Metric-space deviation guaranteed w.p. > 1 - delta after n
    samples at observed distance ``tau`` — the inverse direction of
    `metric_native_log_delta`, used by anytime confidence statements
    and far-candidate pruning. Min over the inverted moduli (module
    docstring), so it never exceeds the uniform `metric_epsilon`.
    Host-side helper; accepts numpy arrays and jnp scalars.
    """
    b = theorem1_epsilon(n, delta, v_x)
    if metric == "l1":
        return b
    t = jnp.maximum(jnp.asarray(tau, jnp.float32), 0.0)
    if metric == "chi2":
        return jnp.minimum(3.0 * b, b + 2.0 * jnp.sqrt(t * b))
    if metric == "hellinger":
        return jnp.minimum(
            jnp.sqrt(b) + 0.5 * b, 0.5 * b + jnp.sqrt(2.0 * t * b)
        )
    raise ValueError(f"unknown metric {metric!r}; have {BOUNDED_METRICS}")


def waggoner_epsilon(n: jax.Array, delta: jax.Array, v_x: int) -> jax.Array:
    """Prior-art l1 learning bound (Waggoner '15), for Fig. 4 comparison.

    For learning a discrete distribution over [V_X] in l1 w.p. 1 - delta:
        eps = sqrt(2 V_X / n) + sqrt((2 / n) * log(1 / delta))
    (mean-deviation term + McDiarmid tail term). Reconstructed from the
    asymptotics cited by the FastMatch paper; with these constants the
    Fig. 4 claim — "our bound typically requires half or fewer samples to
    make the same level of guarantee" — reproduces (see fig4 benchmark).
    """
    n = jnp.maximum(jnp.asarray(n, jnp.float32), 1.0)
    log_inv_delta = -jnp.log(jnp.asarray(delta, jnp.float32))
    return jnp.sqrt(2.0 * v_x / n) + jnp.sqrt(2.0 * log_inv_delta / n)


def slowmatch_epsilon(n: jax.Array, delta: float, v_z: int, v_x: int) -> jax.Array:
    """Fixed-width CI used by SlowMatch: Theorem 1 at confidence delta/|V_Z|.

    SlowMatch terminates only once every candidate individually satisfies
    delta_i <= delta/|V_Z| (paper Sec 5.2), i.e. it runs HistSim with
    max_i delta_i <= delta/|V_Z| instead of sum_i delta_i <= delta.
    """
    return theorem1_epsilon(n, delta / float(v_z), v_x)
