"""Data-parallel pump scaling: ingest throughput vs worker count.

The pump's claim (`repro.core.pump`): with W data-parallel workers each
feeding its own `ShardedSource` window into the explicit-collective
round, a pass over the dataset takes ~W times fewer dispatched rounds —
and with them ~W times fewer host polls at a fixed ``poll_every`` —
while the answers stay at single-stream recall. This benchmark serves
the same query batch through `MatchServer(mesh=..., pump=True)` at
worker counts 1 / 2 / 8 (forced host devices, spawned in a subprocess
so it runs anywhere) plus the plain single-stream server, and measures:

  * tuples ingested/sec — wall-clock ingest bandwidth of the batch
    (on real accelerator pods this scales with aggregate worker I/O;
    on the CPU test substrate the *structural* metrics below are the
    machine-checkable scaling claim)
  * rounds + host syncs — dispatched device rounds and device↔host
    polls for the batch; the W-worker pump covers a pass in ~1/W the
    rounds, so both drop ~Wx
  * recall — against planted ground truth, must match the single
    stream at every width

Embedded golden check: the 1-worker pump IS the single stream (same
visit order, same windows), so its trajectory must reproduce the plain
server's tuple count exactly.

Reported rows (benchmarks/run.py CSV schema):

  pump_w{W}_serve       — us per served batch, derived = tuples read
  pump_tuples_per_sec_w8 — derived = tuples ingested/sec at 8 workers
  pump_sync_reduction_w8 — derived = host syncs w1 / w8 (>= 2 = pass)
  pump_rounds_reduction_w8 — derived = rounds w1 / w8 (>= 2 = pass)

Machine-readable results land in benchmarks/results/BENCH_pump.json
and are regression-gated against benchmarks/baselines/BENCH_pump.json
by benchmarks/check_regression.py on the multi-device CI job.

Set PUMP_BENCH_SMOKE=1 for the tiny CI configuration (same code path;
exits non-zero if recall degrades vs the single stream, the 1-worker
pump diverges from it, or the w8 sync/round reduction drops below 2x).
"""

from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import env_stamp

SMOKE = bool(int(os.environ.get("PUMP_BENCH_SMOKE", "0")))
WORKERS = (1, 2, 8)
N_QUERIES = 8
K, DELTA, EPS = 10, 0.01, 0.07
LOOKAHEAD = 8 if SMOKE else 64  # per-worker window: small enough for many rounds

RESULTS = pathlib.Path(__file__).parent / "results"


def _build():
    from repro.data.layout import block_layout
    from repro.data.synth import SynthSpec, make_dataset

    spec = SynthSpec(
        v_z=64, v_x=16, num_tuples=300_000 if SMOKE else 4_000_000, k=K, n_close=10,
        close_distance=0.02, far_distance=0.3, zipf_a=1.0, close_rank="head", seed=42,
    )
    ds = make_dataset(spec)
    blocked = block_layout(ds.z, ds.x, v_z=64, v_x=16, block_size=512, seed=42)
    return spec, ds, blocked


def _targets(ds):
    from repro.data.synth import perturb_distribution

    rng = np.random.default_rng(7)
    return [ds.target] + [
        perturb_distribution(ds.target, d, rng)
        for d in np.linspace(0.004, 0.04, N_QUERIES - 1)
    ]


def _recall(ds, targets, results) -> float:
    def truth(t):
        dists = np.abs(ds.true_hists - np.asarray(t)[None, :]).sum(axis=1)
        return set(np.argsort(dists, kind="stable")[:K].tolist())

    return float(np.mean([
        len(set(r.ids.tolist()) & truth(t)) / K for t, r in zip(targets, results)
    ]))


def measure_phase() -> None:
    """Entry point executed with 8 forced host devices: serve the batch
    through the plain server and through the pump at each worker count,
    print one JSON line consumed by `run` in the parent."""
    import jax
    from jax.sharding import Mesh

    from repro.serve.fastmatch_server import MatchServer

    _, ds, blocked = _build()
    targets = _targets(ds)

    def serve(**kw):
        server = MatchServer(
            blocked, max_queries=N_QUERIES, lookahead=LOOKAHEAD, seed=200,
            poll_every=1, k_cap=K, **kw,
        )
        rids = [server.submit(t, k=K, eps=EPS, delta=DELTA) for t in targets]
        t0 = time.perf_counter()
        results = server.run_until_idle()
        wall = time.perf_counter() - t0
        sched = server.scheduler
        return dict(
            wall_s=round(wall, 4),
            tuples=int(server.metrics["total_tuples_read"]),
            tuples_per_sec=round(server.metrics["total_tuples_read"] / wall, 1),
            rounds=int(sched.rounds),
            host_syncs=int(sched.host_syncs),
            loop_syncs=int(sched.loop_syncs),
            recall=_recall(ds, targets, [results[r] for r in rids]),
        )

    out = {"single": serve()}
    for w in WORKERS:
        mesh = Mesh(np.array(jax.devices()[:w]).reshape(w, 1), ("data", "model"))
        out[f"w{w}"] = serve(mesh=mesh, pump=True, prefetch=not SMOKE)
    print(json.dumps(out))


def run(rows: list) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = (
        str(pathlib.Path(__file__).parent.parent / "src")
        + os.pathsep + env.get("PYTHONPATH", "")
    )
    out = subprocess.run(
        [sys.executable, "-c",
         "from benchmarks.pump_throughput import measure_phase; measure_phase()"],
        env=env, capture_output=True, text=True, timeout=3600,
        cwd=str(pathlib.Path(__file__).parent.parent),
    )
    if out.returncode != 0:
        raise SystemExit(f"pump measure phase failed:\n{out.stderr[-4000:]}")
    m = json.loads(out.stdout.strip().splitlines()[-1])

    single, w1, w8 = m["single"], m["w1"], m["w8"]
    sync_reduction = w1["loop_syncs"] / max(w8["loop_syncs"], 1)
    rounds_reduction = w1["rounds"] / max(w8["rounds"], 1)
    recall_min = min(m[k]["recall"] for k in m)
    # golden embed: the 1-worker pump IS the single stream
    w1_equivalent = w1["tuples"] == single["tuples"] and w1["rounds"] == single["rounds"]

    for w in WORKERS:
        r = m[f"w{w}"]
        rows.append(dict(name=f"pump_w{w}_serve",
                         us_per_call=1e6 * r["wall_s"], derived=r["tuples"]))
    rows.append(dict(name="pump_tuples_per_sec_w8", us_per_call=0.0,
                     derived=w8["tuples_per_sec"]))
    rows.append(dict(name="pump_sync_reduction_w8", us_per_call=0.0,
                     derived=round(sync_reduction, 2)))
    rows.append(dict(name="pump_rounds_reduction_w8", us_per_call=0.0,
                     derived=round(rounds_reduction, 2)))

    ok = (
        w1_equivalent
        and recall_min >= single["recall"]
        and sync_reduction >= 2.0
        and rounds_reduction >= 2.0
    )
    report = dict(
        config=dict(
            workers=list(WORKERS), n_queries=N_QUERIES, lookahead=LOOKAHEAD,
            k=K, eps=EPS, delta=DELTA, smoke=SMOKE, **env_stamp(),
        ),
        serve=m,
        sync_reduction_w8=round(sync_reduction, 3),
        rounds_reduction_w8=round(rounds_reduction, 3),
        recall_min=recall_min,
        w1_equivalent=w1_equivalent,
        ok=ok,
    )
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "BENCH_pump.json").write_text(json.dumps(report, indent=2) + "\n")

    print(f"# pump_throughput: rounds w1={w1['rounds']} -> w8={w8['rounds']} "
          f"({rounds_reduction:.1f}x), syncs {w1['loop_syncs']} -> {w8['loop_syncs']} "
          f"({sync_reduction:.1f}x), w8 {w8['tuples_per_sec']:,.0f} tuples/s, "
          f"recall min {recall_min:.3f} vs single {single['recall']:.3f}, "
          f"w1==single={w1_equivalent} -> {'PASS' if ok else 'FAIL'}")
    if SMOKE and not ok:
        raise SystemExit("pump_throughput smoke FAILED")


if __name__ == "__main__":
    rows: list = []
    run(rows)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
