from repro.serve.engine import ServeEngine, Request
from repro.serve.fastmatch_server import MatchQuery, MatchServer

__all__ = ["ServeEngine", "Request", "MatchQuery", "MatchServer"]
