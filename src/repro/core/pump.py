"""Data-parallel pump: per-worker `ShardedSource` streams driving the
explicit-collective distributed round.

The paper's 35x speedup rests on many asynchronous block-based samplers
feeding one statistics engine (FastMatch Sec 5). Until this module the
mesh serving path was GSPMD-only: `SharedCountsScheduler(mesh=...)`
shards the counts matrix, but every window is still gathered by ONE
host stream and handed to the jitted round as replicated data — ingest
bandwidth does not grow with the mesh. `DistributedPump` closes that
gap: it is a `SharedCountsScheduler` whose sampling side is
data-parallel end to end.

How the two mesh paths dispatch (see also
`repro.serve.fastmatch_server.MatchServer`):

  GSPMD (``MatchServer(mesh=...)``)
      One global block stream; `multiquery.fused_round` jitted over
      state placed per `distributed.multi_state_pspecs`. XLA's sharding
      propagation decides the collectives; window bytes are gathered
      centrally.

  PUMP (``MatchServer(mesh=..., pump=True)``)
      One `ShardedSource` per data-parallel worker (optionally
      `PrefetchSource`-wrapped, so each worker's next window gather
      overlaps the current round). Each round, every worker takes the
      next lookahead window of ITS contiguous global-id block range
      from the shared cyclic visit order and the explicit shard_map
      round (`distributed.make_pump_round`) runs mark + masked ingest +
      Q-batched stats + cursor bookkeeping.

Collectives per pump round — auditable, independent of window bytes:

  * ONE psum over the data axes of the ((V_Z/m, V_X) counts delta,
    (V_Z/m,) row-sum delta, 3 counter increments) pytree — the only
    cross-WORKER traffic; sample bytes never leave the worker that
    read them.
  * ONE tiled all-gather over the model axis of the (Q, V_Z) tau +
    (V_Z,) row sums — the statistics "control plane", after which the
    per-query deviation assignment (`multiquery.apply_stats`) runs
    replicated, exactly as in the single-stream round.

Block marking uses the union-of-active-sets words carried replicated
in the per-query statistics, so AnyActive stays mesh-wide consistent;
each worker's slice of the `SampleCursor` read_mask covers exactly its
own id range (`distributed.cursor_pspecs`), which is what makes the
without-replacement guarantee per-worker local — no read_mask traffic.

Golden contract (tests/test_pump.py): driven with the same global
windows, a pump round is bit-identical to the single-stream GSPMD
`fused_round` — counts, n, tau, bounds, read_mask and counters — for
any mesh shape, mid-stream admission and retirement included. The
host-side loop (pass structure, poll_every staleness, exact-completion
fallback, warm-start snapshots) is inherited from
`SharedCountsScheduler` unchanged; `export_cache`/`import_cache`
convert between the data-sharded padded read_mask and the global
`CacheSnapshot` layout, so snapshots are interchangeable across pump
widths and with the single-stream scheduler (elastic restart, e.g.
checkpoint under 8 workers, restore under 4 — `cache_pspecs` re-places
the candidate-sharded counts exactly as in the GSPMD path).

`benchmarks/pump_throughput.py` measures the scaling claim: rounds
(and with them host polls) per pass drop ~Wx with W workers at equal
recall, and tuples ingested/sec scales with the workers' aggregate
I/O bandwidth.
"""

from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.core.distributed import (
    cursor_pspecs,
    make_pump_ingest_round,
    make_pump_round,
    window_pspecs,
)
from repro.core.multiquery import (
    MultiQuerySpec,
    SampleCursor,
    SharedCountsScheduler,
)
from repro.data.layout import BlockedDataset
from repro.io import InMemorySource, PrefetchSource, ShardedSource, WindowData

__all__ = ["DistributedPump"]


class DistributedPump(SharedCountsScheduler):
    """Data-parallel `SharedCountsScheduler`: one shard-local window
    stream per mesh worker feeding the explicit-collective pump round.

    Owns the raw `BlockedDataset` (it must shard it — an opaque
    `BlockSource` cannot be split by block ownership) and builds one
    `ShardedSource` per worker over the contiguous global-id ranges of
    `BlockedDataset.shard`. All scheduler semantics — admission,
    retirement, poll_every staleness, pass structure, exact completion,
    warm-start snapshots — are inherited; only where window data comes
    from and how a round is dispatched differ. ``host_syncs`` /
    ``loop_syncs`` keep counting mesh-wide device↔host polls (one poll
    gathers every worker's counters in a single fused device_get), so
    the poll_every amortization stays observable per worker count.

    ``prefetch=True`` wraps each worker's stream in a `PrefetchSource`
    so all W next-window gathers overlap the current round.
    """

    def __init__(
        self,
        dataset: BlockedDataset,
        spec: MultiQuerySpec,
        *,
        mesh,
        data_axes=("data",),
        model_axis: str = "model",
        policy: str = "anyactive",
        window: int = 512,
        seed: int = 0,
        start_block: Optional[int] = None,
        poll_every: int = 1,
        prefetch: bool = False,
        histogram_impl: str = "auto",
        onehot_dtype=jnp.float32,
        telemetry=None,
        plans=None,
    ):
        if not isinstance(dataset, BlockedDataset):
            raise TypeError(
                "DistributedPump shards the raw BlockedDataset per worker; "
                f"got {type(dataset)!r} (wrap sources only in single-stream mode)"
            )
        self.mesh = mesh
        self.data_axes = tuple(data_axes)
        self.model_axis = model_axis
        for ax in self.data_axes + (model_axis,):
            if ax not in mesh.shape:
                raise ValueError(f"mesh has no axis {ax!r}; axes are {dict(mesh.shape)}")
        self.num_workers = int(np.prod([mesh.shape[a] for a in self.data_axes]))
        nb = dataset.num_blocks
        # ShardedSource's ceil-division ranges; the sharded read_mask is
        # padded to the full worker grid (the tail ids are never in any
        # window, so they can never be marked).
        self._blocks_per_worker = -(-nb // self.num_workers)
        self._padded_num_blocks = self._blocks_per_worker * self.num_workers
        self.shards = [
            ShardedSource(dataset, self.num_workers, w, device_resident=False)
            for w in range(self.num_workers)
        ]
        if any(s.num_blocks == 0 for s in self.shards):
            raise ValueError(
                f"{self.num_workers} workers over {nb} blocks leaves a worker "
                "with no blocks; use fewer workers (or more blocks)"
            )
        self._stream_sources = [
            PrefetchSource(s, telemetry=telemetry) if prefetch else s
            for s in self.shards
        ]
        # Per-worker ingest-side timing, drained into each round_batch
        # event (`_round_batch_extra`): how long each worker's next-window
        # gather took (per-worker I/O skew) + the host assemble/device_put
        # cost of stacking the W shards.
        self._worker_gather_s = np.zeros(self.num_workers)
        self._assemble_s = 0.0
        self._cursor_shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), cursor_pspecs(data_axes=self.data_axes)
        )
        self._wd_shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), window_pspecs(data_axes=self.data_axes)
        )
        # The scheduler's own source stays host-resident: it serves only
        # random access (config-hash probes, ad-hoc fetches) — the hot
        # path reads through the per-worker shards.
        super().__init__(
            InMemorySource(dataset, device_resident=False),
            spec,
            policy=policy,
            window=window,
            seed=seed,
            start_block=start_block,
            poll_every=poll_every,
            mesh=mesh,
            model_axis=model_axis,
            telemetry=telemetry,
            plans=plans,
        )
        # The shard rounds key their plans on the per-worker kernel
        # shapes (vz_shard rows), not the scheduler-level full V_Z —
        # resolve separately unless the caller pinned a pair explicitly.
        self._round = make_pump_round(
            mesh, spec, blocks_per_worker=self._blocks_per_worker,
            data_axes=self.data_axes, model_axis=model_axis, policy=self.policy,
            histogram_impl=histogram_impl, onehot_dtype=onehot_dtype, plans=plans,
        )
        self._ingest_only_round = make_pump_ingest_round(
            mesh, spec, blocks_per_worker=self._blocks_per_worker,
            data_axes=self.data_axes, model_axis=model_axis,
            histogram_impl=histogram_impl, onehot_dtype=onehot_dtype, plans=plans,
        )

    # -- cursor placement / snapshot layout --------------------------------

    def _place_cursor(self, cursor: SampleCursor) -> SampleCursor:
        """Pad the global read_mask to the worker grid and shard it over
        the data axes; counters replicate (they hold mesh-wide totals)."""
        host = jax.device_get(cursor)
        mask = np.zeros(self._padded_num_blocks, bool)
        mask[: host.read_mask.shape[0]] = np.asarray(host.read_mask, bool)
        return jax.tree.map(
            jax.device_put, host._replace(read_mask=mask), self._cursor_shardings
        )

    def _global_read_mask(self) -> jax.Array:
        nb = self.source.num_blocks
        return jnp.asarray(np.asarray(jax.device_get(self.cursor.read_mask), bool)[:nb])

    def _sync(self) -> None:
        super()._sync()
        self.read_mask = self.read_mask[: self.source.num_blocks]

    def _quarantine_sources(self) -> tuple:
        """Drain quarantine from every per-worker stream source too —
        a `ResilientSource` under one worker's prefetch wrapper
        quarantines GLOBAL block ids (`ShardedSource` speaks global),
        so the base bookkeeping applies unchanged and the degraded
        bound covers faults on any worker's I/O path."""
        return (self.source, *self._stream_sources)

    # -- data-parallel window plumbing -------------------------------------

    def _plan_pass(self, pass_order: np.ndarray) -> tuple:
        """Split a global visit order into per-worker window lists.

        Worker w's list is the order restricted to its contiguous id
        range, chunked into lookahead windows; lists are aligned to one
        length with empty windows so round r zips worker windows
        one-to-one (a worker whose share ran out contributes an
        all-padding shard that marks nothing).
        """
        per = [
            pass_order[(pass_order >= s.lo) & (pass_order < s.hi)] for s in self.shards
        ]
        n_rounds = max(-(-p.size // self.window) for p in per)
        return (
            [
                [p[r * self.window : (r + 1) * self.window] for r in range(n_rounds)]
                for p in per
            ],
            n_rounds,
        )

    def _assemble(self, wds) -> WindowData:
        """Stack per-worker windows into the round's sharded WindowData:
        dim 0 concatenates the W windows, placed so each worker's shard
        is exactly the window its own source gathered (window_pspecs).

        The shard sources are host-resident, so their leaves are numpy
        and the device_put below is the window's ONLY host→device
        transfer (device_get is a passthrough on numpy; it only pays a
        gather if a custom source hands back device arrays)."""
        def cat(field):
            return np.concatenate(
                [np.asarray(jax.device_get(getattr(w, field))) for w in wds], axis=0
            )

        host = WindowData(
            indices=cat("indices"), z=cat("z"), x=cat("x"),
            bitmap=cat("bitmap"), valid=cat("valid"),
        )
        return jax.tree.map(jax.device_put, host, self._wd_shardings)

    def _open_pass_stream(self, pass_order: np.ndarray) -> tuple:
        win_lists, n_rounds = self._plan_pass(pass_order)

        def rounds():
            streams = [
                src.stream(wins, pad_to=self.window)
                for src, wins in zip(self._stream_sources, win_lists)
            ]
            try:
                if self.telemetry is None:
                    for wds in zip(*streams):
                        yield self._assemble(wds)
                else:
                    # zip with per-worker gather timing: worker w's
                    # accumulator measures how long ITS next window took
                    # (the per-worker I/O skew the psum round then has
                    # to wait out).
                    while True:
                        wds = []
                        for w, st in enumerate(streams):
                            t0 = time.perf_counter()
                            try:
                                wd = next(st)
                            except StopIteration:
                                return
                            self._worker_gather_s[w] += time.perf_counter() - t0
                            wds.append(wd)
                        t0 = time.perf_counter()
                        out = self._assemble(wds)
                        self._assemble_s += time.perf_counter() - t0
                        yield out
            finally:
                for st in streams:
                    st.close()

        return rounds(), n_rounds

    def _round_batch_extra(self) -> dict:
        """Per-worker gather + assemble wall accumulated since the last
        poll (see `SharedCountsScheduler._emit_round_batch`)."""
        extra = {
            "worker_gather_s": [float(s) for s in self._worker_gather_s],
            "assemble_s": float(self._assemble_s),
        }
        self._worker_gather_s[:] = 0.0
        self._assemble_s = 0.0
        return extra

    def _fetch_window(self, win: np.ndarray) -> WindowData:
        """Ad-hoc global window (MatchServer.step / run_window): split
        by block ownership, fetch shard-locally, assemble. One pump
        round regardless of how the window straddles workers."""
        pieces = [s.owned(win) for s in self.shards]
        pad = max(self.window, max(p.size for p in pieces))
        return self._assemble(
            [s.fetch(p, pad_to=pad) for s, p in zip(self.shards, pieces)]
        )

    def _dispatch_round(self, wd: WindowData) -> None:
        self.state, self.cursor = self._round(self.state, self.cursor, wd)

    def _dispatch_ingest(self, wd: WindowData) -> None:
        self.state, self.cursor = self._ingest_only_round(self.state, self.cursor, wd)
