"""Activation-distribution drift monitoring — the paper's matcher applied
to the training loop itself.

Candidates Z = monitored tensors (per-layer activations / gradients),
groups X = histogram bins over a fixed range, target Q = the reference
distribution captured from a known-good step. Each monitoring tick
histograms the current tensors (same one-hot-contraction op as the data
engine), and Theorem 1 turns the distance into a calibrated drift test:
we flag a tensor only when its empirical distribution is PROVABLY (at
confidence 1 - delta) further than `drift_eps` from the reference —
i.e. the tensor's deviation bound eps(n) plus drift_eps is exceeded.

This gives pod-scale jobs a statistically sound "layer k drifted"
alarm with one cheap jitted call per tick (used by launch/train.py via
`--monitor`; tested in tests/test_extensions.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bounds
from repro.kernels import ops

__all__ = ["ActivationMonitor"]


def _bin_ids(x: jax.Array, lo: float, hi: float, bins: int) -> jax.Array:
    xf = jnp.ravel(x).astype(jnp.float32)
    ids = jnp.floor((xf - lo) / (hi - lo) * bins).astype(jnp.int32)
    return jnp.clip(ids, 0, bins - 1)


@dataclasses.dataclass
class ActivationMonitor:
    names: List[str]
    bins: int = 64
    lo: float = -8.0
    hi: float = 8.0
    delta: float = 0.01
    drift_eps: float = 0.15
    reference: Optional[np.ndarray] = None  # (num_tensors, bins)

    def _histogram(self, tensors: Dict[str, jax.Array]) -> np.ndarray:
        rows = []
        for name in self.names:
            ids = _bin_ids(tensors[name], self.lo, self.hi, self.bins)
            h = ops.histogram(
                jnp.zeros_like(ids), ids, v_z=1, v_x=self.bins
            )[0]
            rows.append(np.asarray(h))
        return np.stack(rows)

    def capture_reference(self, tensors: Dict[str, jax.Array]) -> None:
        h = self._histogram(tensors)
        self.reference = h / np.maximum(h.sum(axis=1, keepdims=True), 1.0)

    def check(self, tensors: Dict[str, jax.Array]) -> Dict[str, dict]:
        """Returns per-tensor {distance, bound, drifted}. `drifted` is a
        calibrated decision: true iff d(emp, ref) - eps(n) > drift_eps,
        which by Theorem 1 holds with prob < delta under no-drift."""
        if self.reference is None:
            raise RuntimeError("capture_reference first")
        h = self._histogram(tensors)
        out = {}
        per_tensor_delta = self.delta / max(len(self.names), 1)
        for i, name in enumerate(self.names):
            n = h[i].sum()
            emp = h[i] / max(n, 1.0)
            d = float(np.abs(emp - self.reference[i]).sum())
            eps_n = float(bounds.theorem1_epsilon(n, per_tensor_delta, self.bins))
            out[name] = {
                "distance": d,
                "sampling_bound": eps_n,
                "drifted": d - eps_n > self.drift_eps,
            }
        return out
