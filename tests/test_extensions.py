"""Appendix A extensions + activation-drift monitor."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; skip on minimal installs
from hypothesis import given, settings, strategies as st

from repro.core import deviations as dev
from repro.core.extensions import (
    DensityMap,
    PredicateNode,
    assign_deviations_two_eps,
    estimate_block_counts,
    measure_biased_sample,
    pick_k_in_range,
)
from repro.train.monitor import ActivationMonitor


class TestMeasureBiasedSampling:
    def test_sum_histogram_recovered(self, rng):
        """COUNT over the biased sample ~ SUM(Y) histogram of the data."""
        n = 200_000
        z = rng.integers(0, 20, n).astype(np.int32)
        x = rng.integers(0, 8, n).astype(np.int32)
        y = rng.exponential(scale=2.0, size=n)
        zs, xs = measure_biased_sample(z, x, y, target_size=400_000, seed=1)
        # true SUM histogram for candidate 3
        mask = z == 3
        true = np.zeros(8)
        np.add.at(true, x[mask], y[mask])
        true /= true.sum()
        emp = np.bincount(xs[zs == 3], minlength=8).astype(float)
        emp /= emp.sum()
        assert np.abs(emp - true).sum() < 0.03

    def test_sample_size_near_target(self, rng):
        z = rng.integers(0, 5, 10_000).astype(np.int32)
        x = rng.integers(0, 4, 10_000).astype(np.int32)
        y = rng.random(10_000)
        zs, _ = measure_biased_sample(z, x, y, target_size=30_000)
        assert abs(len(zs) - 30_000) < 500

    def test_rejects_negative_measure(self):
        with pytest.raises(ValueError):
            measure_biased_sample(
                np.zeros(4, np.int32), np.zeros(4, np.int32), np.asarray([1.0, -1, 1, 1]),
                target_size=10,
            )


class TestDensityMaps:
    @pytest.fixture()
    def data(self, rng):
        nb, bs = 40, 64
        blocks = {
            "country": rng.integers(0, 10, (nb, bs)).astype(np.int32),
            "religion": rng.integers(0, 4, (nb, bs)).astype(np.int32),
        }
        dmap = DensityMap.build(blocks, {"country": 10, "religion": 4})
        return blocks, dmap, bs

    def test_leaf_counts_exact(self, data):
        blocks, dmap, bs = data
        est = estimate_block_counts(dmap, PredicateNode.leaf("country", 3), bs)
        true = (blocks["country"] == 3).sum(axis=1)
        np.testing.assert_array_equal(est, true)

    def test_and_upper_bound(self, data):
        """AND estimate never underestimates -> AnyActive skip stays safe."""
        blocks, dmap, bs = data
        pred = PredicateNode.and_(
            PredicateNode.leaf("country", 3), PredicateNode.leaf("religion", 1)
        )
        est = estimate_block_counts(dmap, pred, bs)
        true = ((blocks["country"] == 3) & (blocks["religion"] == 1)).sum(axis=1)
        assert (est >= true).all()

    def test_or_upper_bound(self, data):
        blocks, dmap, bs = data
        pred = PredicateNode.or_(
            PredicateNode.leaf("country", 0), PredicateNode.leaf("country", 1)
        )
        est = estimate_block_counts(dmap, pred, bs)
        true = np.isin(blocks["country"], [0, 1]).sum(axis=1)
        assert (est >= true).all()
        assert (est <= bs).all()

    def test_zero_estimate_is_exact(self, data):
        """A skipped block (estimate 0) must truly contain no match."""
        blocks, dmap, bs = data
        pred = PredicateNode.and_(
            PredicateNode.leaf("country", 7), PredicateNode.leaf("religion", 2)
        )
        est = estimate_block_counts(dmap, pred, bs)
        true = ((blocks["country"] == 7) & (blocks["religion"] == 2)).sum(axis=1)
        assert (true[est == 0] == 0).all()

    def test_predicate_evaluate(self):
        pred = PredicateNode.or_(
            PredicateNode.and_(
                PredicateNode.leaf("a", 1), PredicateNode.leaf("b", 2)
            ),
            PredicateNode.leaf("a", 5),
        )
        assert pred.evaluate({"a": 1, "b": 2})
        assert pred.evaluate({"a": 5, "b": 0})
        assert not pred.evaluate({"a": 1, "b": 0})


class TestTwoEps:
    @given(seed=st.integers(0, 200))
    @settings(deadline=None, max_examples=50)
    def test_equal_eps_matches_base(self, seed):
        rng = np.random.default_rng(seed)
        tau = jnp.asarray(rng.random(24) * 0.6, jnp.float32)
        n = jnp.asarray(rng.integers(100, 10**6, 24), jnp.float32)
        a = dev.assign_deviations(tau, n, k=5, eps=0.08, delta=0.01, v_x=16)
        b = assign_deviations_two_eps(
            tau, n, k=5, eps_sep=0.08, eps_rec=0.08, delta=0.01, v_x=16
        )
        np.testing.assert_allclose(np.asarray(a.eps_i), np.asarray(b.eps_i), atol=1e-6)
        assert float(a.delta_upper) == pytest.approx(float(b.delta_upper), rel=1e-5)

    def test_tighter_reconstruction_caps_in_m(self):
        tau = jnp.asarray([0.02, 0.03, 0.4, 0.5], jnp.float32)
        n = jnp.full((4,), 1e5)
        d = assign_deviations_two_eps(
            tau, n, k=2, eps_sep=0.2, eps_rec=0.05, delta=0.01, v_x=8
        )
        in_m = np.asarray(d.in_top_k)
        assert (np.asarray(d.eps_i)[in_m] <= 0.05 + 1e-6).all()


class TestKRange:
    def test_picks_widest_gap(self):
        tau = jnp.asarray([0.01, 0.02, 0.03, 0.30, 0.31, 0.32, 0.9])
        assert pick_k_in_range(tau, 2, 5) == 3  # gap 0.03 -> 0.30

    def test_respects_bounds(self):
        tau = jnp.asarray([0.1, 0.2, 0.3, 0.4])
        k = pick_k_in_range(tau, 2, 3)
        assert k in (2, 3)

    def test_rejects_empty_range(self):
        with pytest.raises(ValueError):
            pick_k_in_range(jnp.asarray([0.1, 0.2]), 3, 5)


class TestActivationMonitor:
    def test_no_drift_no_alarm(self):
        rng = jax.random.PRNGKey(0)
        mon = ActivationMonitor(names=["h0", "h1"], bins=32, drift_eps=0.2)
        ref = {"h0": jax.random.normal(rng, (4096,)), "h1": jax.random.normal(rng, (4096,)) * 2}
        mon.capture_reference(ref)
        again = {
            "h0": jax.random.normal(jax.random.PRNGKey(1), (4096,)),
            "h1": jax.random.normal(jax.random.PRNGKey(2), (4096,)) * 2,
        }
        rep = mon.check(again)
        assert not rep["h0"]["drifted"] and not rep["h1"]["drifted"]

    def test_real_drift_flagged(self):
        rng = jax.random.PRNGKey(0)
        mon = ActivationMonitor(names=["h"], bins=32, drift_eps=0.2)
        mon.capture_reference({"h": jax.random.normal(rng, (8192,))})
        rep = mon.check({"h": jax.random.normal(rng, (8192,)) * 4 + 3})  # blown-up scale+shift
        assert rep["h"]["drifted"]
        assert rep["h"]["distance"] > rep["h"]["sampling_bound"] + 0.2
