"""ServeSupervisor: crash recovery, deadlines, load shedding (PR 8).

The recovery acceptance test is the tentpole contract: kill the serving
loop mid-round with an injected unrecoverable fault, restart through the
supervisor, and every queued query completes from the last snapshot with
answers BIT-IDENTICAL to a run that never crashed (re-submission is
lossless because sampling is target-independent — a re-admitted query
inherits the restored shared counts at its full budget).
"""

import time

import numpy as np
import pytest

from repro.data.layout import block_layout
from repro.data.synth import SynthSpec, make_dataset, perturb_distribution
from repro.io import InMemorySource
from repro.io.faults import (
    FaultPlan,
    FaultySource,
    ResilientSource,
    RetryPolicy,
    UnrecoverableIOError,
)
from repro.serve import ServeSupervisor, SupervisorPolicy

K, EPS, DELTA = 5, 0.08, 0.05


@pytest.fixture(scope="module")
def dataset():
    spec = SynthSpec(
        v_z=32, v_x=16, num_tuples=120_000, k=K, n_close=5,
        close_distance=0.02, far_distance=0.3, zipf_a=0.9, seed=3,
    )
    ds = make_dataset(spec)
    blocked = block_layout(
        ds.z, ds.x, v_z=spec.v_z, v_x=spec.v_x, block_size=512, seed=5
    )
    return spec, ds, blocked


@pytest.fixture(scope="module")
def targets(dataset):
    _, ds, _ = dataset
    rng = np.random.default_rng(9)
    return [perturb_distribution(ds.target, d, rng) for d in (0.01, 0.04, 0.1)]


def _chaos_source(blocked, *, crash_at=None, seed=0):
    return ResilientSource(
        FaultySource(
            InMemorySource(blocked, device_resident=False),
            FaultPlan(crash_at=crash_at),
            seed=seed,
        ),
        policy=RetryPolicy(max_retries=2, backoff_s=0.0005),
    )


_SERVER_KW = dict(max_queries=2, lookahead=64, poll_every=2, seed=11)


class TestCrashRecovery:
    def test_kill_mid_round_recovers_bit_identical(self, dataset, targets, tmp_path):
        """Acceptance: crash at fetch attempt 2, supervisor restores the
        autosaved snapshot, re-queues, completes — answers match the
        never-crashed supervisor run exactly."""
        _, _, blocked = dataset
        ref_sup = ServeSupervisor(
            _chaos_source(blocked),
            checkpoint_dir=tmp_path / "ref", autosave_rounds=2, telemetry=True,
            **_SERVER_KW,
        )
        ref_rids = [ref_sup.submit(t, k=K, eps=EPS, delta=DELTA) for t in targets]
        ref = ref_sup.run_until_idle()
        assert ref_sup.restarts == 0

        sup = ServeSupervisor(
            _chaos_source(blocked, crash_at=2),
            policy=SupervisorPolicy(max_restarts=2),
            checkpoint_dir=tmp_path / "crash", autosave_rounds=2, telemetry=True,
            **_SERVER_KW,
        )
        rids = [sup.submit(t, k=K, eps=EPS, delta=DELTA) for t in targets]
        res = sup.run_until_idle()
        assert sup.restarts == 1  # the crash fired and was recovered once
        assert "UnrecoverableIOError" in sup.last_error
        assert len(res) == len(targets) and sup.unresolved == 0
        for rid, ref_rid in zip(rids, ref_rids):
            np.testing.assert_array_equal(res[rid].ids, ref[ref_rid].ids)
        # observability: crash + recovery landed in counters and events
        reg = sup.telemetry.registry
        assert reg.get("serve_crashes_total").value == 1
        assert reg.get("serve_recoveries_total").value == 1
        assert reg.get("serve_recovery_seconds").count == 1
        (crash_ev,) = sup.telemetry.tracer.events("serve_crash")
        assert "UnrecoverableIOError" in crash_ev["error"]
        (rec_ev,) = sup.telemetry.tracer.events("serve_recovered")
        assert rec_ev["resubmitted"] >= 1 and rec_ev["recovery_s"] > 0.0
        m = sup.metrics
        assert m["restarts"] == 1 and m["recovery_s_total"] > 0.0
        assert "UnrecoverableIOError" in m["last_error"]

    def test_cold_recovery_without_checkpoint_dir(self, dataset, targets):
        """No snapshot on disk: recovery restarts cold and re-samples —
        still answer-complete, still bit-identical (warm restarts are
        exact, and a cold rebuild IS the from-scratch run)."""
        _, _, blocked = dataset
        sup = ServeSupervisor(
            _chaos_source(blocked, crash_at=2),
            policy=SupervisorPolicy(max_restarts=1),
            **_SERVER_KW,
        )
        rids = [sup.submit(t, k=K, eps=EPS, delta=DELTA) for t in targets[:2]]
        res = sup.run_until_idle()
        assert sup.restarts == 1 and len(res) == 2
        plain = ServeSupervisor(_chaos_source(blocked), **_SERVER_KW)
        prids = [plain.submit(t, k=K, eps=EPS, delta=DELTA) for t in targets[:2]]
        pres = plain.run_until_idle()
        for rid, prid in zip(rids, prids):
            np.testing.assert_array_equal(res[rid].ids, pres[prid].ids)

    def test_max_restarts_exhausted_reraises(self, dataset, targets):
        """The (N+1)-th crash is a bug, not an operational event: it
        propagates with the original exception."""
        _, _, blocked = dataset
        sup = ServeSupervisor(
            _chaos_source(blocked, crash_at=2),
            policy=SupervisorPolicy(max_restarts=0),
            **_SERVER_KW,
        )
        sup.submit(targets[0], k=K, eps=EPS, delta=DELTA)
        with pytest.raises(UnrecoverableIOError):
            sup.run_until_idle()
        assert sup.restarts == 1  # counted before the bound check


class TestSheddingAndDeadlines:
    def test_overload_sheds_at_the_door(self, dataset, targets):
        _, _, blocked = dataset
        sup = ServeSupervisor(
            InMemorySource(blocked, device_resident=False),
            policy=SupervisorPolicy(max_queue=1),
            max_queries=1, lookahead=64, poll_every=2, seed=11, telemetry=True,
        )
        rids = [sup.submit(t, k=K, eps=EPS, delta=DELTA) for t in targets]
        res = sup.run_until_idle()
        shed = [r for r in rids if r in sup.shed]
        answered = [r for r in rids if r in res]
        assert shed and sup.shed[shed[0]] == "overload"
        assert len(answered) + len(shed) == len(rids)
        assert sup.metrics["queries_shed"] == len(shed)
        assert sup.telemetry.registry.get("serve_queries_shed_total").value == len(shed)
        assert {e["reason"] for e in sup.telemetry.tracer.events("query_shed")} == {
            "overload"
        }

    def test_queued_query_shed_at_deadline(self, dataset, targets):
        """A query whose deadline passes while still QUEUED consumed no
        I/O — it is shed, never half-answered."""
        _, _, blocked = dataset
        sup = ServeSupervisor(
            InMemorySource(blocked, device_resident=False),
            max_queries=2, lookahead=64, poll_every=2, seed=11,
        )
        ok = sup.submit(targets[0], k=K, eps=EPS, delta=DELTA)
        late = sup.submit(targets[1], k=K, eps=EPS, delta=DELTA, deadline_s=0.0)
        res = sup.run_until_idle()
        assert sup.shed[late] == "deadline" and late not in res
        assert ok in res and len(res[ok].ids) == K

    def test_live_query_early_retired_at_deadline(self, dataset, targets):
        """A LIVE query at its deadline returns its best-effort answer
        (exact=False) instead of being dropped."""
        _, _, blocked = dataset
        sup = ServeSupervisor(
            InMemorySource(blocked, device_resident=False),
            max_queries=2, lookahead=16, poll_every=2, seed=11, telemetry=True,
        )
        rid = sup.submit(targets[2], k=K, eps=EPS, delta=DELTA)
        sup.server.step()  # admit + first window: the query is now live
        assert sup.server.scheduler.tickets  # still running
        sup._requests[rid].deadline = time.monotonic() - 1.0
        res = sup.run_until_idle()
        assert rid in res and rid not in sup.shed
        assert res[rid].exact is False and len(res[rid].ids) == K
        (ev,) = sup.telemetry.tracer.events("query_deadline_retire")
        assert ev["rid"] == rid

    def test_default_deadline_from_policy(self, dataset, targets):
        _, _, blocked = dataset
        sup = ServeSupervisor(
            InMemorySource(blocked, device_resident=False),
            policy=SupervisorPolicy(default_deadline_s=0.0),
            max_queries=2, lookahead=64, seed=11,
        )
        rid = sup.submit(targets[0], k=K, eps=EPS, delta=DELTA)
        sup.run_until_idle()
        assert sup.shed[rid] == "deadline"

    def test_metrics_surface_merges_server_and_supervisor(self, dataset, targets):
        _, _, blocked = dataset
        sup = ServeSupervisor(
            InMemorySource(blocked, device_resident=False), **_SERVER_KW
        )
        sup.submit(targets[0], k=K, eps=EPS, delta=DELTA)
        sup.run_until_idle()
        m = sup.metrics
        for key in (
            "queries_done", "blocks_quarantined", "degraded",  # server side
            "restarts", "recovery_s_total", "queries_shed", "last_error",
        ):
            assert key in m
        assert m["queries_done"] == 1 and m["restarts"] == 0
