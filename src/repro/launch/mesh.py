"""Production mesh construction.

`make_production_mesh` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state. The production
topology is a v5e pod of 256 chips arranged (16, 16) = ("data", "model"),
and the 2-pod job (2, 16, 16) = ("pod", "data", "model").
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_mesh_for"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == ndev:
        return jax.make_mesh(shape, axes)
    if len(devices) < ndev:
        raise RuntimeError(
            f"need {ndev} devices for mesh {shape}, have {len(devices)} — "
            "run under dryrun.py (it forces 512 host devices)"
        )
    # more devices than needed (e.g. 512 host devices, single-pod mesh):
    # use a prefix slice so both meshes can be built in one process.
    return Mesh(np.asarray(devices[:ndev]).reshape(shape), axes)


def make_mesh_for(shape: tuple, axes: tuple) -> Mesh:
    """Arbitrary mesh over a device prefix (tests, elastic restarts)."""
    ndev = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(f"need {ndev} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:ndev]).reshape(shape), axes)
