"""Telemetry subsystem: registry/tracer units, instrumented-layer
integration, and the two contracts the tentpole hangs on —

  * bit-equivalence: every engine output (counts, n, tau, read_mask,
    results, host-sync count) is identical with telemetry on and off;
  * curve fidelity: the recorded tuples-to-confidence trajectory
    reproduces the stats tail (eps(n) from `core.bounds.theorem1_epsilon`
    at the per-candidate budget, delta_upper from the device poll).
"""

import json
import threading
import time

import numpy as np
import pytest

from repro.core.bounds import theorem1_epsilon
from repro.data.layout import block_layout
from repro.data.synth import SynthSpec, make_dataset, perturb_distribution
from repro.obs import (
    CURVE_COLUMNS,
    TIMING_FIELDS,
    MetricsRegistry,
    Telemetry,
    Tracer,
)
from repro.serve.fastmatch_server import MatchServer

K, EPS, DELTA = 5, 0.08, 0.05


@pytest.fixture(scope="module")
def dataset():
    spec = SynthSpec(
        v_z=32, v_x=16, num_tuples=200_000, k=K, n_close=5,
        close_distance=0.02, far_distance=0.3, zipf_a=0.9, seed=3,
    )
    ds = make_dataset(spec)
    blocked = block_layout(ds.z, ds.x, v_z=spec.v_z, v_x=spec.v_x, block_size=512, seed=5)
    return spec, ds, blocked


@pytest.fixture(scope="module")
def targets(dataset):
    _, ds, _ = dataset
    rng = np.random.default_rng(9)
    return [perturb_distribution(ds.target, d, rng) for d in (0.01, 0.04)]


# ---------------------------------------------------------------- registry


class TestRegistry:
    def test_counter_monotone(self):
        reg = MetricsRegistry()
        c = reg.counter("x_total", "help text")
        c.inc()
        c.inc(2.5)
        assert c.value == 3.5
        with pytest.raises(ValueError, match="cannot decrease"):
            c.inc(-1)
        assert reg.counter("x_total") is c  # get-or-create

    def test_gauge_last_write_wins(self):
        reg = MetricsRegistry()
        g = reg.gauge("depth")
        g.set(4)
        g.inc(-1)
        assert g.value == 3.0

    def test_kind_conflict_raises(self):
        reg = MetricsRegistry()
        reg.counter("m")
        with pytest.raises(ValueError, match="already registered"):
            reg.gauge("m")

    def test_bad_name_rejected(self):
        reg = MetricsRegistry()
        with pytest.raises(ValueError, match="invalid metric name"):
            reg.counter("has space")

    def test_histogram_binning_dogfoods_kernel(self):
        """Bucket counts from the repo's own histogram op must equal a
        plain numpy reference, including the v == edge boundary (le
        semantics: the sample belongs to that edge's bucket)."""
        reg = MetricsRegistry()
        h = reg.histogram("lat_seconds", edges=(0.01, 0.1, 1.0))
        samples = [0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 7.0, 0.2]
        for s in samples:
            h.observe(s)
        counts = h.bucket_counts()
        # reference: non-cumulative per-bin counts with overflow last
        ref = np.zeros(4, np.int64)
        for s in samples:
            ref[int(np.searchsorted((0.01, 0.1, 1.0), s, side="left"))] += 1
        np.testing.assert_array_equal(counts, ref)
        assert h.count == len(samples)
        assert h.sum == pytest.approx(sum(samples))

    def test_histogram_thread_safe_observe(self):
        reg = MetricsRegistry()
        h = reg.histogram("t_seconds", edges=(0.5,))
        def burst():
            for _ in range(500):
                h.observe(0.1)
        threads = [threading.Thread(target=burst) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert h.count == 2000
        assert h.bucket_counts().sum() == 2000

    def test_prometheus_exposition(self):
        reg = MetricsRegistry()
        reg.counter("reads_total", "total reads").inc(7)
        reg.gauge("queue_depth").set(2)
        h = reg.histogram("lat_seconds", edges=(0.1, 1.0))
        h.observe(0.05)
        h.observe(0.5)
        h.observe(5.0)
        text = reg.to_prometheus()
        lines = text.splitlines()
        assert "# HELP reads_total total reads" in lines
        assert "# TYPE reads_total counter" in lines
        assert "reads_total 7" in lines
        assert "queue_depth 2" in lines
        # cumulative le buckets; +Inf bucket equals the total count
        assert 'lat_seconds_bucket{le="0.1"} 1' in lines
        assert 'lat_seconds_bucket{le="1"} 2' in lines
        assert 'lat_seconds_bucket{le="+Inf"} 3' in lines
        assert "lat_seconds_count 3" in lines

    def test_snapshot_is_json_able(self):
        reg = MetricsRegistry()
        reg.counter("a_total").inc()
        reg.histogram("b_seconds", edges=(1.0,)).observe(0.5)
        round_trip = json.loads(reg.to_json())
        assert round_trip["a_total"]["value"] == 1.0
        assert round_trip["b_seconds"]["buckets"] == [1, 0]


# ------------------------------------------------------------------ tracer


class TestTracer:
    def test_emit_sequencing_and_ring_bound(self):
        tr = Tracer(capacity=3, clock=lambda: 0.0)
        for i in range(5):
            tr.emit("e", i=i)
        evs = tr.events()
        assert [e["i"] for e in evs] == [2, 3, 4]  # oldest dropped
        assert [e["seq"] for e in evs] == [2, 3, 4]
        assert tr.events_total == 5  # truncation stays visible

    def test_skeleton_strips_timing_only(self):
        tr = Tracer(clock=lambda: 0.0)
        tr.emit("round_batch", rounds=4, gather_s=0.1, sync_s=0.2, stall_frac=0.3)
        (sk,) = tr.skeleton()
        assert sk == {"seq": 0, "kind": "round_batch", "rounds": 4}
        assert TIMING_FIELDS.issuperset({"ts", "gather_s", "sync_s", "stall_frac"})

    def test_span_records_duration(self):
        ticks = iter([0.0, 0.0, 1.5, 1.5])  # epoch, enter, exit, emit-ts
        tr = Tracer(clock=lambda: next(ticks))
        with tr.span("work", tag="x") as ev:
            ev["extra"] = 1
        (e,) = tr.events("work")
        assert e["dur_s"] == 1.5 and e["tag"] == "x" and e["extra"] == 1

    def test_export_jsonl_round_trip(self, tmp_path):
        tr = Tracer(clock=lambda: 0.0)
        tr.emit("a", v=1)
        tr.emit("b", v=[1, 2])
        p = tmp_path / "trace.jsonl"
        assert tr.export_jsonl(p) == 2
        back = [json.loads(line) for line in p.read_text().splitlines()]
        assert [e["kind"] for e in back] == ["a", "b"]
        assert back[1]["v"] == [1, 2]


# ----------------------------------------------------------- telemetry facade


class TestTelemetryCurves:
    def test_dedupe_and_cap(self):
        tel = Telemetry(max_curve_points=3)
        pt = dict.fromkeys(CURVE_COLUMNS, 0.0)
        tel.record_curve_point(1, dict(pt))
        tel.record_curve_point(1, dict(pt))  # same (round, tuples, delta_upper)
        assert len(tel.trajectory(1)) == 1
        for r in (1, 2, 3, 4):
            tel.record_curve_point(1, dict(pt, round=r))
        assert len(tel.trajectory(1)) == 3  # earliest kept
        assert tel.curve_drops == 2

    def test_confidence_curve_array_and_csv(self, tmp_path):
        tel = Telemetry()
        for r in (0, 1):
            tel.record_curve_point(7, dict.fromkeys(CURVE_COLUMNS, float(r)))
        arr = tel.confidence_curve(7)
        assert arr.shape == (2, len(CURVE_COLUMNS))
        assert tel.confidence_curve(99).shape == (0, len(CURVE_COLUMNS))
        p = tmp_path / "curve.csv"
        assert tel.export_confidence_csv(p) == 2
        header, *rows = p.read_text().splitlines()
        assert header == "qid," + ",".join(CURVE_COLUMNS)
        assert len(rows) == 2 and rows[0].startswith("7,")


# ---------------------------------------------------- server integration


def _drain(blocked, targets, *, telemetry, seed=11):
    srv = MatchServer(
        blocked, max_queries=2, lookahead=64, poll_every=2, seed=seed,
        telemetry=telemetry,
    )
    rids = [srv.submit(t, k=K, eps=EPS, delta=DELTA) for t in targets]
    return srv, rids, srv.run_until_idle()


class TestServerTelemetry:
    # Satellite: the full metrics-dict schema is a public contract.
    SCHEMA = {
        "queries_done": int,
        "queries_queued": int,
        "queries_live": int,
        "queries_pending": int,
        "total_blocks_read": int,
        "total_tuples_read": int,
        "total_rounds": int,
        "fraction_read": float,
        "tuples_per_query": float,
        # PR 8 health surface: fault/degradation observability.
        "last_error": str,
        "queries_shed": int,
        "blocks_quarantined": int,
        "degraded": bool,
        "eps_inflation": float,
    }

    def test_metrics_schema_pinned(self, dataset, targets):
        _, _, blocked = dataset
        srv = MatchServer(blocked, max_queries=2, lookahead=64)
        m = srv.metrics
        assert set(m) == set(self.SCHEMA)
        for key, typ in self.SCHEMA.items():
            assert isinstance(m[key], typ), (key, type(m[key]))
        # nan regression: before any completion the ratio is 0.0, and the
        # dict must survive a strict-JSON round trip (nan would not)
        assert m["tuples_per_query"] == 0.0
        json.loads(json.dumps(m, allow_nan=False))
        srv.submit(targets[0], k=K, eps=EPS, delta=DELTA)
        srv.run_until_idle()
        m = srv.metrics
        assert m["queries_done"] == 1 and m["tuples_per_query"] > 0.0
        for key, typ in self.SCHEMA.items():
            assert isinstance(m[key], typ), (key, type(m[key]))

    def test_bit_equivalence_on_off(self, dataset, targets):
        """Tentpole acceptance: telemetry must observe, never perturb.
        Same seeds -> identical results, identical device-poll count,
        bit-identical cache state (counts/n/read_mask/cursors)."""
        _, _, blocked = dataset
        srv_on, rids_on, res_on = _drain(blocked, targets, telemetry=True)
        srv_off, rids_off, res_off = _drain(blocked, targets, telemetry=None)
        assert rids_on == rids_off
        for rid in rids_on:
            a, b = res_on[rid], res_off[rid]
            np.testing.assert_array_equal(a.ids, b.ids)
            assert (a.rounds, a.blocks_read, a.tuples_read, a.exact, a.passes) == (
                b.rounds, b.blocks_read, b.tuples_read, b.exact, b.passes
            )
        assert srv_on.scheduler.host_syncs == srv_off.scheduler.host_syncs
        snap_on = srv_on.scheduler.export_cache()
        snap_off = srv_off.scheduler.export_cache()
        for leaf_on, leaf_off in zip(snap_on, snap_off):
            np.testing.assert_array_equal(np.asarray(leaf_on), np.asarray(leaf_off))

    def test_golden_span_tree(self, dataset, targets):
        """The event skeleton of a scripted 2-query run is deterministic:
        two identically-seeded servers produce byte-identical skeletons,
        and the per-query lifecycle reads enqueue -> admit -> retire ->
        done in submission order."""
        _, _, blocked = dataset
        srv_a, rids, _ = _drain(blocked, targets, telemetry=True)
        srv_b, _, _ = _drain(blocked, targets, telemetry=True)
        sk_a = srv_a.telemetry.tracer.skeleton()
        sk_b = srv_b.telemetry.tracer.skeleton()
        assert sk_a == sk_b
        for ev in sk_a:  # no wall-clock leaks into the deterministic view
            assert not TIMING_FIELDS.intersection(ev)

        kinds = [e["kind"] for e in sk_a]
        assert kinds.count("query_enqueue") == len(rids)
        assert kinds.count("query_admit") == len(rids)
        assert kinds.count("query_retire") == len(rids)
        assert kinds.count("query_done") == len(rids)
        assert kinds.count("pass_start") >= 1 and kinds.count("round_batch") >= 1
        # submission order is admission order (both queries fit the pool)
        admits = [e["qid"] for e in sk_a if e["kind"] == "query_admit"]
        assert admits == sorted(admits)
        # every lifecycle is ordered within the trace
        for qid in admits:
            seqs = {
                e["kind"]: e["seq"] for e in sk_a
                if e.get("qid") == qid and e["kind"] in
                ("query_admit", "query_retire", "query_done")
            }
            assert seqs["query_admit"] < seqs["query_retire"] < seqs["query_done"]
        # retire events agree with round_batch totals
        last_rb = [e for e in sk_a if e["kind"] == "round_batch"][-1]
        assert last_rb["rounds"] == srv_a.scheduler.rounds

    def test_confidence_curve_matches_stats_tail(self, dataset, targets):
        """Curve fidelity: eps_n is Theorem 1's bound at the polled
        n_min and per-candidate budget delta/V_Z; delta_upper decreases
        to below delta for a terminated query; counters agree with the
        scheduler mirrors."""
        spec, _, blocked = dataset
        srv, rids, res = _drain(blocked, targets, telemetry=True)
        tel = srv.telemetry
        sched = srv.scheduler
        assert sorted(tel.query_ids()) == sorted(
            e["qid"] for e in tel.tracer.skeleton("query_admit")
        )
        for qid in tel.query_ids():
            traj = tel.trajectory(qid)
            assert traj, qid
            for p in traj:
                ref = float(theorem1_epsilon(
                    max(p["n_min"], 1.0), DELTA / spec.v_z, spec.v_x
                ))
                np.testing.assert_allclose(p["eps_n"], ref, rtol=1e-4)
                assert p["confidence"] == pytest.approx(
                    max(0.0, 1.0 - p["delta_upper"])
                )
            # the curve rises: final confidence is the best recorded
            finals = traj[-1]
            assert finals["delta_upper"] <= traj[0]["delta_upper"]
            assert finals["tuples"] >= traj[0]["tuples"]
        # a terminated (non-exact) query crossed its bound on record
        terminated = [
            e for e in tel.tracer.skeleton("query_retire") if e["terminated"]
        ]
        for ev in terminated:
            assert tel.trajectory(ev["qid"])[-1]["delta_upper"] < DELTA
        reg = tel.registry
        assert reg.get("fastmatch_rounds_total").value == sched.rounds
        assert reg.get("fastmatch_tuples_read_total").value == sched.tuples_read
        assert reg.get("fastmatch_host_syncs_total").value == sched.host_syncs
        assert reg.get("fastmatch_queries_retired_total").value == len(res)

    def test_trace_and_prometheus_exports(self, dataset, targets, tmp_path):
        _, _, blocked = dataset
        srv, _, _ = _drain(blocked, targets, telemetry=True)
        p = tmp_path / "trace.jsonl"
        n = srv.export_trace(p)
        assert n == len(p.read_text().splitlines()) > 0
        text = srv.prometheus_metrics()
        assert "# TYPE fastmatch_rounds_total counter" in text
        assert "# TYPE fastmatch_round_batch_seconds histogram" in text
        plain = MatchServer(blocked, max_queries=2, lookahead=64)
        with pytest.raises(RuntimeError, match="without telemetry"):
            plain.export_trace(p)


# ------------------------------------------------------------- prefetch


class _SlowSource:
    """Minimal BlockSource: fetch sleeps, so waits are guaranteed."""

    def __init__(self, *, fetch_delay=0.02, fail_at=None, windows=6):
        self.num_blocks = windows
        self.block_size = 4
        self.v_z = 2
        self.v_x = 2
        self.tuples_per_block = np.full(windows, 4, np.int64)
        self.fetch_delay = fetch_delay
        self.fail_at = fail_at
        self.calls = 0

    def fetch(self, win, pad_to=None):
        self.calls += 1
        if self.fail_at is not None and self.calls >= self.fail_at:
            raise RuntimeError("disk on fire")
        time.sleep(self.fetch_delay)
        return ("window", int(np.asarray(win)[0]))

    def stream(self, windows, pad_to=None):
        for w in windows:
            yield self.fetch(w, pad_to)


class TestPrefetchTelemetry:
    def test_slow_source_records_nonzero_wait(self):
        """Satellite: a source slower than the consumer must show up as
        nonzero prefetch_wait samples and a stall fraction, not vanish."""
        from repro.io import PrefetchSource

        tel = Telemetry()
        src = PrefetchSource(_SlowSource(fetch_delay=0.02), telemetry=tel)
        wins = [np.array([i]) for i in range(6)]
        out = list(src.stream(wins))
        assert [o[1] for o in out] == list(range(6))
        h_wait = tel.registry.get("prefetch_wait_seconds")
        h_fetch = tel.registry.get("prefetch_fetch_seconds")
        assert h_wait.count >= len(wins) and h_wait.sum > 0.0
        assert h_fetch.count == len(wins) and h_fetch.sum >= 6 * 0.02
        (ev,) = tel.tracer.events("prefetch_stream")
        assert ev["windows"] == len(wins) + 1  # + the "done" hand-off
        assert ev["wait_s"] > 0.0 and ev["fetch_s"] > 0.0
        assert 0.0 <= ev["stall_frac"] <= 1.0
        assert ev["hidden_s"] == pytest.approx(
            max(ev["fetch_s"] - ev["wait_s"], 0.0)
        )

    def test_worker_error_is_structured_event(self):
        from repro.io import PrefetchSource

        tel = Telemetry()
        src = PrefetchSource(
            _SlowSource(fetch_delay=0.0, fail_at=3), telemetry=tel
        )
        with pytest.raises(RuntimeError, match="disk on fire"):
            list(src.stream([np.array([i]) for i in range(6)]))
        assert tel.registry.get("prefetch_worker_errors_total").value == 1
        (ev,) = tel.tracer.events("prefetch_worker_error")
        assert ev["source"] == "_SlowSource" and "disk on fire" in ev["error"]

    def test_join_timeout_is_structured_event(self):
        from repro.io import PrefetchSource

        tel = Telemetry()
        src = PrefetchSource(
            _SlowSource(fetch_delay=0.5, windows=4),
            telemetry=tel, join_timeout=0.0,
        )
        it = src.stream([np.array([i]) for i in range(4)])
        next(it)  # worker is now blocked inside the next slow fetch
        it.close()  # join(0.0) cannot outwait a 0.5s fetch
        assert tel.registry.get("prefetch_join_timeouts_total").value == 1
        (ev,) = tel.tracer.events("prefetch_join_timeout")
        assert ev["source"] == "_SlowSource" and ev["timeout_s"] == 0.0


# ------------------------------------------------------------ checkpoint


class TestCheckpointTelemetry:
    def test_save_metrics_and_event(self, tmp_path):
        from repro.checkpoint import CheckpointManager

        tel = Telemetry()
        mgr = CheckpointManager(tmp_path, telemetry=tel)
        state = {"a": np.arange(10, dtype=np.int64), "b": np.ones(3, np.float32)}
        mgr.save(state, step=4)
        reg = tel.registry
        assert reg.get("checkpoint_saves_total").value == 1
        assert reg.get("checkpoint_save_bytes_total").value == 10 * 8 + 3 * 4
        assert reg.get("checkpoint_save_seconds").count == 1
        (ev,) = tel.tracer.events("checkpoint_save")
        assert ev["step"] == 4 and ev["bytes"] == 92 and ev["save_s"] > 0.0
        assert mgr.save_failures == 0

    def test_save_failure_counted_and_reraised(self, tmp_path):
        import os

        from repro.checkpoint import CheckpointManager

        tel = Telemetry()
        mgr = CheckpointManager(tmp_path, telemetry=tel)
        # a FILE squatting on the tmp dir name makes the save's own
        # staging mkdir fail -> the failure path, deterministically
        (tmp_path / f"step_9.tmp.{os.getpid()}").write_text("squatter")
        with pytest.raises(OSError):
            mgr.save({"a": np.zeros(2)}, step=9)
        assert mgr.save_failures == 1
        assert tel.registry.get("checkpoint_save_failures_total").value == 1
        assert tel.registry.get("checkpoint_saves_total").value == 0

    def test_orphan_gc_counted(self, tmp_path):
        from repro.checkpoint import CheckpointManager

        tel = Telemetry()
        mgr = CheckpointManager(tmp_path, telemetry=tel)
        (tmp_path / "step_1.tmp.999999999").mkdir()  # dead-pid orphan
        (tmp_path / "LATEST.tmp.999999998").write_text("step_1")
        mgr.save({"a": np.zeros(2)}, step=2)  # save's GC sweeps them
        assert mgr.gc_swept == 2
        assert tel.registry.get("checkpoint_gc_swept_total").value == 2
        (ev,) = tel.tracer.events("checkpoint_gc")
        assert ev["swept"] == 2
        assert not list(tmp_path.glob("*.tmp.*"))

    def test_counters_exist_without_telemetry(self, tmp_path):
        from repro.checkpoint import CheckpointManager

        mgr = CheckpointManager(tmp_path)
        mgr.save({"a": np.zeros(2)}, step=1)
        assert mgr.gc_swept == 0 and mgr.save_failures == 0
