"""Packed per-block candidate-presence bitmaps (paper Sec 4.1).

The paper stores, per attribute value, one bit per 4 KiB disk block
("orders-of-magnitude cheaper than a bit per tuple"). We keep the same
layout transposed for SIMD/VPU access: a (num_blocks, W) uint32 matrix
with W = ceil(V_Z / 32); bit j of word (b, w) says whether data block b
contains at least one tuple of candidate 32w + j.

Bitmaps are built once per (dataset, candidate attribute) as a
preprocessing step — the analogue of the paper's index build.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["words_for", "build_block_bitmap", "pack_active_mask", "unpack_mask"]


def words_for(v_z: int) -> int:
    return -(-v_z // 32)


def build_block_bitmap(z_blocks: np.ndarray, v_z: int) -> np.ndarray:
    """Build the packed bitmap from blocked candidate ids.

    Args:
      z_blocks: (num_blocks, block_size) int array of candidate ids per
        tuple; ids < 0 (padding) are ignored.
      v_z: number of candidates.

    Returns:
      (num_blocks, W) uint32 packed presence bitmap.
    """
    z_blocks = np.asarray(z_blocks)
    nb = z_blocks.shape[0]
    w = words_for(v_z)
    present = np.zeros((nb, v_z), dtype=bool)
    rows = np.repeat(np.arange(nb), z_blocks.shape[1])
    vals = z_blocks.reshape(-1)
    ok = (vals >= 0) & (vals < v_z)
    present[rows[ok], vals[ok]] = True
    # pack: candidate c -> word c//32, bit c%32
    padded = np.zeros((nb, w * 32), dtype=bool)
    padded[:, :v_z] = present
    bits = padded.reshape(nb, w, 32).astype(np.uint32)
    weights = (np.uint32(1) << np.arange(32, dtype=np.uint32))[None, None, :]
    return (bits * weights).sum(axis=2, dtype=np.uint32)


def pack_active_mask(active: jax.Array) -> jax.Array:
    """Pack a (V_Z,) bool active mask into (W,) uint32 words (jit-safe)."""
    v_z = active.shape[0]
    w = words_for(v_z)
    padded = jnp.zeros((w * 32,), jnp.uint32).at[: v_z].set(active.astype(jnp.uint32))
    bits = padded.reshape(w, 32)
    weights = jnp.left_shift(jnp.uint32(1), jnp.arange(32, dtype=jnp.uint32))[None, :]
    return jnp.sum(bits * weights, axis=1, dtype=jnp.uint32)


def unpack_mask(words: jax.Array, v_z: int) -> jax.Array:
    """Inverse of pack_active_mask (for tests)."""
    w = words.shape[0]
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, :]
    bits = jnp.right_shift(words[:, None], shifts) & jnp.uint32(1)
    return bits.reshape(w * 32)[:v_z].astype(bool)
