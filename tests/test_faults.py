"""Fault injection + the resilient source boundary (PR 8 tentpole).

The two contracts this suite pins:

  * fault-free invisibility — `ResilientSource(FaultySource(p=0))`
    streams bit-identical `WindowData` leaves to the bare source
    (property-tested when hypothesis is available, deterministically
    always), and a serve run whose injected faults are all transient
    (every retry heals) is bit-identical END TO END to the fault-free
    run: same top-k ids, same rounds, same tuples read.
  * honest degradation — windows that exhaust retries or fail
    integrity validation never reach ingest: their blocks quarantine,
    the scheduler re-derives (eps, delta) over the surviving
    population, and results/metrics say so (``degraded``,
    ``eps_effective``, ``blocks_quarantined``) instead of silently
    reporting the fault-free guarantee.
"""

import threading
import time

import numpy as np
import pytest

from repro.data.layout import block_layout
from repro.data.synth import SynthSpec, make_dataset, perturb_distribution
from repro.io import InMemorySource, PrefetchSource
from repro.io.block_source import WindowData
from repro.io.faults import (
    CorruptWindowError,
    FaultInjector,
    FaultPlan,
    FaultySource,
    FetchCancelled,
    ResilientSource,
    RetryPolicy,
    TransientIOError,
    UnrecoverableIOError,
    WindowQuarantined,
    find_resilient,
    maybe_chaos,
    validate_window,
)
from repro.serve.fastmatch_server import MatchServer

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # minimal installs: the deterministic tests still run
    HAVE_HYPOTHESIS = False

K, EPS, DELTA = 5, 0.08, 0.05


@pytest.fixture(scope="module")
def dataset():
    spec = SynthSpec(
        v_z=32, v_x=16, num_tuples=120_000, k=K, n_close=5,
        close_distance=0.02, far_distance=0.3, zipf_a=0.9, seed=3,
    )
    ds = make_dataset(spec)
    blocked = block_layout(
        ds.z, ds.x, v_z=spec.v_z, v_x=spec.v_x, block_size=512, seed=5
    )
    return spec, ds, blocked


@pytest.fixture(scope="module")
def host_source(dataset):
    _, _, blocked = dataset
    return InMemorySource(blocked, device_resident=False)


@pytest.fixture(scope="module")
def targets(dataset):
    _, ds, _ = dataset
    rng = np.random.default_rng(9)
    return [perturb_distribution(ds.target, d, rng) for d in (0.01, 0.04)]


def _windows(nb, width=8, count=6):
    return [np.arange(i * width, min((i + 1) * width, nb)) for i in range(count)]


def _assert_windows_equal(a: WindowData, b: WindowData):
    for leaf_a, leaf_b in zip(a, b):
        np.testing.assert_array_equal(np.asarray(leaf_a), np.asarray(leaf_b))


class _FlakySource:
    """Deterministic failure scripting: ``script[i]`` is what fetch
    attempt i does — None (serve), or an exception instance to raise.
    Off-script attempts serve."""

    def __init__(self, inner, script):
        self.inner = inner
        self.script = list(script)
        self.calls = 0
        self.num_blocks = inner.num_blocks
        self.block_size = inner.block_size
        self.v_z = inner.v_z
        self.v_x = inner.v_x
        self.tuples_per_block = inner.tuples_per_block

    def fetch(self, win, pad_to=None):
        i = self.calls
        self.calls += 1
        if i < len(self.script) and self.script[i] is not None:
            raise self.script[i]
        return self.inner.fetch(win, pad_to)

    def stream(self, windows, pad_to=None):
        for w in windows:
            yield self.fetch(w, pad_to)


# ------------------------------------------------------------- validation


class TestValidateWindow:
    def _kwargs(self, src):
        return dict(
            num_blocks=src.num_blocks, block_size=src.block_size,
            v_z=src.v_z, v_x=src.v_x,
        )

    def test_good_window_passes_content(self, host_source):
        wd = host_source.fetch(np.arange(4), pad_to=8)
        validate_window(wd, **self._kwargs(host_source), pad_to=8, level="content")

    def test_truncated_window_rejected(self, host_source):
        wd = host_source.fetch(np.arange(4))
        cut = WindowData(*(leaf[:-1] for leaf in wd))
        with pytest.raises(CorruptWindowError, match="truncated"):
            validate_window(cut, **self._kwargs(host_source), pad_to=4)

    def test_out_of_range_z_rejected_by_content_only(self, host_source):
        wd = host_source.fetch(np.arange(4))
        z = np.asarray(wd.z).copy()
        z[0, 0] = host_source.v_z + 7
        bad = wd._replace(z=z)
        kw = self._kwargs(host_source)
        validate_window(bad, **kw, level="structural")  # shape-only: blind
        with pytest.raises(CorruptWindowError, match="z values"):
            validate_window(bad, **kw, level="content")

    def test_bitmap_inconsistency_rejected(self, host_source):
        wd = host_source.fetch(np.arange(4))
        bm = np.asarray(wd.bitmap).copy()
        bm[0, 0] ^= np.uint32(1 << 5)
        with pytest.raises(CorruptWindowError, match="bitmap inconsistent"):
            validate_window(wd._replace(bitmap=bm), **self._kwargs(host_source),
                            level="content")

    def test_padding_pairing_rejected(self, host_source):
        wd = host_source.fetch(np.arange(4))
        x = np.asarray(wd.x).copy()
        x[0, 0] = -1  # z still >= 0 there
        with pytest.raises(CorruptWindowError, match="padding mismatch"):
            validate_window(wd._replace(x=x), **self._kwargs(host_source),
                            level="content")

    def test_wrong_dtype_rejected_structurally(self, host_source):
        wd = host_source.fetch(np.arange(4))
        bad = wd._replace(z=np.asarray(wd.z).astype(np.float32))
        with pytest.raises(CorruptWindowError, match="dtype"):
            validate_window(bad, **self._kwargs(host_source), level="structural")

    def test_auto_is_content_for_host_arrays(self, host_source):
        wd = host_source.fetch(np.arange(4))
        z = np.asarray(wd.z).copy()
        z[0, 0] = host_source.v_z + 1
        with pytest.raises(CorruptWindowError):
            validate_window(wd._replace(z=z), **self._kwargs(host_source),
                            level="auto")


# ---------------------------------------------------------- fault injection


class TestFaultInjector:
    def test_seeded_schedule_is_reproducible(self):
        plan = FaultPlan(p_transient=0.3, p_corrupt=0.2)
        a = FaultInjector(plan, seed=7)
        b = FaultInjector(plan, seed=7)
        seq_a = [a.next_fault() for _ in range(200)]
        seq_b = [b.next_fault() for _ in range(200)]
        assert seq_a == seq_b
        assert a.injected["transient"] > 0 and a.injected["corrupt"] > 0

    def test_one_shots_fire_exactly_once_and_keep_schedule(self):
        base = FaultInjector(FaultPlan(p_transient=0.3), seed=1)
        shot = FaultInjector(FaultPlan(p_transient=0.3, crash_at=5), seed=1)
        seq_base = [base.next_fault() for _ in range(20)]
        seq_shot = [shot.next_fault() for _ in range(20)]
        assert seq_shot[5] == "crash" and shot.injected["crash"] == 1
        # the probability draw at index 5 was still consumed: every other
        # index matches the no-one-shot schedule
        assert seq_shot[:5] == seq_base[:5] and seq_shot[6:] == seq_base[6:]

    def test_probability_sum_validated(self):
        with pytest.raises(ValueError, match="probabilities"):
            FaultPlan(p_transient=0.8, p_corrupt=0.4)

    def test_faulty_source_raises_and_mutates(self, host_source):
        win = np.arange(4)
        src = FaultySource(host_source, FaultPlan(p_transient=1.0))
        with pytest.raises(TransientIOError):
            src.fetch(win)
        src = FaultySource(host_source, FaultPlan(p_corrupt=1.0))
        wd = src.fetch(win)
        assert int(np.asarray(wd.z).max()) >= host_source.v_z  # out of range
        src = FaultySource(host_source, FaultPlan(p_truncate=1.0))
        wd = src.fetch(win)
        assert wd.indices.shape[0] == win.size - 1
        src = FaultySource(host_source, FaultPlan(crash_at=0))
        with pytest.raises(UnrecoverableIOError):
            src.fetch(win)


# ------------------------------------------------------- resilient boundary


class TestResilientSource:
    def test_p0_stream_bit_identical_deterministic(self, host_source):
        """Satellite golden: the p=0 wrapper is bit-invisible."""
        wins = _windows(host_source.num_blocks)
        wrapped = ResilientSource(FaultySource(host_source, FaultPlan()))
        for a, b in zip(wrapped.stream(wins, pad_to=8),
                        host_source.stream(wins, pad_to=8)):
            _assert_windows_equal(a, b)
        assert wrapped.retries_total == 0 and wrapped.blocks_quarantined == 0

    if HAVE_HYPOTHESIS:

        @settings(max_examples=25, deadline=None)
        @given(
            seed=st.integers(0, 2**16),
            width=st.integers(1, 16),
            pad=st.booleans(),
        )
        def test_p0_stream_bit_identical_property(self, host_source, seed, width, pad):
            rng = np.random.default_rng(seed)
            nb = host_source.num_blocks
            blocks = rng.permutation(nb)[: 4 * width]
            wins = [blocks[i : i + width] for i in range(0, blocks.size, width)]
            pad_to = width if pad else None
            wrapped = ResilientSource(
                FaultySource(host_source, FaultPlan(), seed=seed),
                policy=RetryPolicy(seed=seed),
            )
            for a, b in zip(wrapped.stream(wins, pad_to=pad_to),
                            host_source.stream(wins, pad_to=pad_to)):
                _assert_windows_equal(a, b)
            assert wrapped.retries_total == 0

    def test_transient_heals_on_retry(self, host_source):
        flaky = _FlakySource(host_source, [TransientIOError("x"),
                                           TransientIOError("x"), None])
        src = ResilientSource(flaky, policy=RetryPolicy(max_retries=4, backoff_s=0.0))
        wd = src.fetch(np.arange(4))
        _assert_windows_equal(wd, host_source.fetch(np.arange(4)))
        assert src.retries_total == 2 and src.transient_faults == 2
        assert src.permanent_faults == 0 and src.take_quarantined().size == 0

    def test_retries_exhausted_quarantines(self, host_source):
        flaky = _FlakySource(host_source, [TransientIOError("x")] * 10)
        src = ResilientSource(flaky, policy=RetryPolicy(max_retries=2, backoff_s=0.0))
        win = np.array([3, 5, 7])
        with pytest.raises(WindowQuarantined) as ei:
            src.fetch(win)
        np.testing.assert_array_equal(ei.value.block_ids, win)
        assert src.permanent_faults == 1 and src.blocks_quarantined == 3
        np.testing.assert_array_equal(src.take_quarantined(), win)
        assert src.take_quarantined().size == 0  # drained

    def test_corrupt_window_is_immediately_permanent(self, host_source):
        src = ResilientSource(
            FaultySource(host_source, FaultPlan(p_corrupt=1.0)),
            policy=RetryPolicy(max_retries=5, backoff_s=0.0),
        )
        with pytest.raises(WindowQuarantined):
            src.fetch(np.arange(4))
        # no retry burned: corrupt bytes re-read identically corrupt
        assert src.retries_total == 0 and src.validation_failures == 1

    def test_truncated_window_fails_validation(self, host_source):
        src = ResilientSource(FaultySource(host_source, FaultPlan(p_truncate=1.0)))
        with pytest.raises(WindowQuarantined):
            src.fetch(np.arange(4), pad_to=4)
        assert src.validation_failures == 1

    def test_unrecoverable_propagates_untouched(self, host_source):
        src = ResilientSource(
            FaultySource(host_source, FaultPlan(crash_at=0)),
            policy=RetryPolicy(max_retries=8, backoff_s=0.0),
        )
        with pytest.raises(UnrecoverableIOError):
            src.fetch(np.arange(4))
        # not a quarantine verdict: the supervisor owns this failure
        assert src.take_quarantined().size == 0 and src.permanent_faults == 0

    def test_deadline_escalates_with_retries_left(self, host_source):
        clock = iter([0.0, 10.0, 20.0]).__next__
        flaky = _FlakySource(host_source, [TransientIOError("x")] * 10)
        src = ResilientSource(
            flaky,
            policy=RetryPolicy(max_retries=100, backoff_s=0.0, deadline_s=5.0),
            clock=clock,
        )
        with pytest.raises(WindowQuarantined) as ei:
            src.fetch(np.arange(2))
        assert "deadline" in str(ei.value.cause) or src.permanent_faults == 1
        assert flaky.calls == 1  # first attempt already blew the budget

    def test_backoff_schedule_seeded_and_exponential(self, host_source):
        def run(seed):
            sleeps = []
            flaky = _FlakySource(host_source, [TransientIOError("x")] * 3 + [None])
            src = ResilientSource(
                flaky,
                policy=RetryPolicy(max_retries=5, backoff_s=0.01, seed=seed),
                sleep=sleeps.append,
            )
            src.fetch(np.arange(2))
            return sleeps

        a, b = run(3), run(3)
        assert a == b and len(a) == 3  # deterministic per seed
        assert a != run(4)  # distinct seeds de-synchronize
        # exponential shape survives +-25% jitter at mult=2
        assert a[1] > a[0] and a[2] > a[1]

    def test_stream_skips_quarantined_window(self, host_source):
        wins = _windows(host_source.num_blocks, width=4, count=4)
        # fail only attempt 1 (second window) beyond the retry budget
        script = [None] + [TransientIOError("x")] * 3 + [None, None]
        src = ResilientSource(
            _FlakySource(host_source, script),
            policy=RetryPolicy(max_retries=2, backoff_s=0.0),
        )
        out = list(src.stream(wins, pad_to=4))
        assert len(out) == len(wins) - 1
        np.testing.assert_array_equal(src.take_quarantined(), wins[1])

    def test_cancel_event_stops_retry_loop(self, host_source):
        ev = threading.Event()
        ev.set()
        src = ResilientSource(_FlakySource(host_source, []))
        src.set_cancel_event(ev)
        with pytest.raises(FetchCancelled):
            src.fetch(np.arange(2))
        assert src.take_quarantined().size == 0  # cancellation != fault

    def test_telemetry_counters(self, host_source):
        from repro.obs import Telemetry

        tel = Telemetry()
        flaky = _FlakySource(host_source, [TransientIOError("x")] * 10)
        src = ResilientSource(
            flaky, policy=RetryPolicy(max_retries=1, backoff_s=0.0), telemetry=tel
        )
        with pytest.raises(WindowQuarantined):
            src.fetch(np.array([1, 2]))
        reg = tel.registry
        assert reg.get("io_fetch_retries_total").value == 1
        assert reg.get("io_transient_faults_total").value == 2
        assert reg.get("io_permanent_faults_total").value == 1
        assert reg.get("io_blocks_quarantined_total").value == 2
        (ev,) = tel.tracer.events("window_quarantine")
        assert ev["blocks"] == 2 and ev["why"] == "retries-exhausted"

    def test_find_resilient_walks_wrapper_chain(self, host_source):
        res = ResilientSource(FaultySource(host_source, FaultPlan()))
        assert find_resilient(PrefetchSource(res)) is res
        assert find_resilient(host_source) is None

    def test_maybe_chaos_env_gate(self, host_source):
        assert maybe_chaos(host_source, env={}) is host_source
        wrapped = maybe_chaos(host_source, env={"FASTMATCH_CHAOS": "1"})
        assert isinstance(wrapped, ResilientSource)
        assert isinstance(wrapped.inner, FaultySource)


# ------------------------------------------------ prefetch cancellation


class _HangingSource:
    """First window serves; every later fetch is transient forever —
    without cancellation a retry loop would ride out huge backoffs."""

    def __init__(self, inner):
        self.inner = inner
        self.calls = 0
        self.num_blocks = inner.num_blocks
        self.block_size = inner.block_size
        self.v_z = inner.v_z
        self.v_x = inner.v_x
        self.tuples_per_block = inner.tuples_per_block

    def fetch(self, win, pad_to=None):
        self.calls += 1
        if self.calls > 1:
            raise TransientIOError("flaky forever")
        return self.inner.fetch(win, pad_to)

    def stream(self, windows, pad_to=None):
        for w in windows:
            yield self.fetch(w, pad_to)


class TestPrefetchCancellation:
    def test_close_cancels_inflight_retry(self, host_source):
        """Satellite: stream close must stop a worker stuck in backoff
        at the next cancellation check, not after the backoff schedule
        (60s+ here) or the join timeout."""
        from repro.obs import Telemetry

        tel = Telemetry()
        res = ResilientSource(
            _HangingSource(host_source),
            policy=RetryPolicy(max_retries=100, backoff_s=30.0),
        )
        pf = PrefetchSource(res, telemetry=tel, join_timeout=5.0)
        wins = _windows(host_source.num_blocks, width=4, count=4)
        it = pf.stream(wins, pad_to=4)
        next(it)  # worker is now retrying window 2's hopeless fetch
        t0 = time.perf_counter()
        it.close()
        assert time.perf_counter() - t0 < 5.0  # cancelled, not joined-out
        # clean shutdown: no error, no quarantine, no abandoned worker
        assert tel.registry.get("prefetch_worker_errors_total").value == 0
        assert tel.registry.get("prefetch_join_timeouts_total").value == 0
        assert res.take_quarantined().size == 0
        assert res.cancel_event is None  # flag uninstalled at close

    def test_post_close_failure_is_structured_event(self, host_source):
        """Satellite: the 'worker failed after the stream was closed'
        warn now also lands as a counter + structured event."""
        from repro.obs import Telemetry

        class _LateFailSource(_HangingSource):
            def fetch(self, win, pad_to=None):
                self.calls += 1
                if self.calls > 1:
                    time.sleep(0.1)  # lets the consumer close first
                    raise RuntimeError("disk on fire")
                return self.inner.fetch(win, pad_to)

        tel = Telemetry()
        pf = PrefetchSource(_LateFailSource(host_source), telemetry=tel)
        it = pf.stream(_windows(host_source.num_blocks, width=4, count=4), pad_to=4)
        next(it)
        it.close()  # the worker's RuntimeError lands after this
        assert tel.registry.get("prefetch_dropped_errors_total").value == 1
        (ev,) = tel.tracer.events("prefetch_dropped_error")
        assert ev["source"] == "_LateFailSource" and "disk on fire" in ev["error"]


# ----------------------------------------- end-to-end: degraded guarantees


def _serve(source_or_blocked, targets, **kw):
    srv = MatchServer(
        source_or_blocked, max_queries=2, lookahead=64, poll_every=2, seed=11, **kw
    )
    rids = [srv.submit(t, k=K, eps=EPS, delta=DELTA) for t in targets]
    res = srv.run_until_idle()
    return srv, [res[r] for r in rids]


class TestServeUnderFaults:
    def test_transient_faults_bit_identical_golden(self, dataset, targets, host_source):
        """Satellite golden: a run whose every fault is transient (retry
        re-reads the same immutable blocks) ends bit-identical to the
        fault-free run — ids, rounds, tuples, exactness."""
        _, _, blocked = dataset
        _, ref = _serve(blocked, targets)
        chaotic = ResilientSource(
            FaultySource(host_source, FaultPlan(p_transient=0.4), seed=2),
            policy=RetryPolicy(max_retries=32, backoff_s=0.0),
        )
        srv, got = _serve(chaotic, targets)
        assert chaotic.retries_total > 0  # chaos actually happened
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(a.ids, b.ids)
            assert (a.rounds, a.blocks_read, a.tuples_read, a.exact) == (
                b.rounds, b.blocks_read, b.tuples_read, b.exact
            )
            assert not a.degraded and not b.degraded
        m = srv.metrics
        assert m["blocks_quarantined"] == 0 and m["degraded"] is False

    def test_corruption_quarantines_and_degrades_honestly(self, targets, host_source):
        """Permanent faults shrink the population; results and metrics
        must say so. The run still completes every query."""
        chaotic = ResilientSource(
            FaultySource(
                host_source, FaultPlan(p_transient=0.2, p_corrupt=0.3), seed=2
            ),
            policy=RetryPolicy(max_retries=1, backoff_s=0.0),
        )
        srv, res = _serve(chaotic, targets)
        sched = srv.scheduler
        assert sched.blocks_quarantined > 0
        m = srv.metrics
        assert m["degraded"] is True
        assert m["blocks_quarantined"] == sched.blocks_quarantined
        assert m["eps_inflation"] == pytest.approx(2.0 * sched.quarantine_fraction)
        # every answer still has k ids; results retired after the first
        # quarantine carry the widened bound
        degraded = [r for r in res if r.degraded]
        assert degraded, "no result observed the quarantine"
        for r in degraded:
            # widened by the inflation AT ITS retirement — bounded by the
            # run's final inflation, never the bare eps
            assert EPS < r.eps_effective <= EPS + sched.eps_inflation + 1e-9
        for r in res:
            assert len(r.ids) == K

    def test_quarantine_blocks_scheduler_semantics(self, host_source, targets):
        """Unit: already-read blocks are never quarantined (history is
        validated), eps widening is 2x the quarantined TUPLE fraction,
        and exact means complete over the survivors."""
        from repro.core.multiquery import MultiQuerySpec, SharedCountsScheduler

        spec = MultiQuerySpec(
            v_z=host_source.v_z, v_x=host_source.v_x, max_queries=2, k_cap=K
        )
        sched = SharedCountsScheduler(
            host_source, spec, policy="scan", window=8, seed=0, start_block=0
        )
        sched.admit(targets[0], k=K, eps=EPS, delta=DELTA)
        sched.run_window(np.arange(8))
        read = np.where(sched.read_mask)[0]
        assert read.size
        assert sched.quarantine_blocks(read[:2]) == 0  # history immune
        fresh = np.where(~sched.read_mask)[0][:10]
        assert sched.quarantine_blocks(fresh) == 10
        assert sched.quarantine_blocks(fresh) == 0  # idempotent
        tpb = np.asarray(host_source.tuples_per_block, np.int64)
        q = tpb[fresh].sum() / tpb.sum()
        assert sched.eps_inflation == pytest.approx(2.0 * q)
        sched.complete_remaining()
        out = sched.retire(0, exact=False, terminated=False)
        assert out.degraded and out.exact  # complete over survivors
        assert out.eps_effective == pytest.approx(EPS + 2.0 * q)
        assert not sched.read_mask[fresh].any()  # never fetched
