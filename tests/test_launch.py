"""Launcher-layer tests: dry-run machinery, cell gating, opt-state specs,
elastic restore across different mesh shapes."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_subprocess(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


class TestCellGating:
    def test_long_context_gating(self):
        from repro.launch.dryrun import cell_supported

        ok, _ = cell_supported("recurrentgemma_2b", "long_500k")
        assert ok
        ok, why = cell_supported("llama3_405b", "long_500k")
        assert not ok and "full-attention" in why
        assert cell_supported("xlstm_125m", "long_500k")[0]
        assert not cell_supported("whisper_medium", "long_500k")[0]

    def test_all_archs_all_other_shapes_supported(self):
        from repro.configs import list_archs
        from repro.launch.dryrun import cell_supported

        for arch in list_archs():
            for shape in ("train_4k", "prefill_32k", "decode_32k"):
                assert cell_supported(arch, shape)[0]


class TestOptStatePspecs:
    def test_adamw_state_mirrors_param_specs(self):
        import jax
        from jax.sharding import Mesh, PartitionSpec as P

        from repro.configs import get_smoke_config
        from repro.distributed.sharding import param_pspecs
        from repro.launch import specs as S
        from repro.models.model_zoo import get_model
        from repro.optimizer import get_optimizer

        mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        S._MESH[0] = mesh
        cfg = get_smoke_config("granite_8b")
        model = get_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        p_pspecs = param_pspecs(shapes, mesh)
        opt = get_optimizer("adamw", 1e-3)
        o_shapes = jax.eval_shape(opt.init, shapes)
        o_pspecs = S.opt_state_pspecs(o_shapes, p_pspecs)
        assert o_pspecs["mu"]["layers"][0]["attn"]["wq"] == P("data", "model")
        assert o_pspecs["nu"]["embed"]["table"] == P("model", "data")

    def test_adafactor_factored_specs(self):
        import jax
        from jax.sharding import Mesh, PartitionSpec as P

        from repro.configs import get_smoke_config
        from repro.distributed.sharding import param_pspecs
        from repro.launch import specs as S
        from repro.models.model_zoo import get_model
        from repro.optimizer import get_optimizer

        mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        S._MESH[0] = mesh
        cfg = get_smoke_config("llama3_405b")  # adafactor config
        model = get_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        p_pspecs = param_pspecs(shapes, mesh)
        opt = get_optimizer("adafactor", 1e-3)
        o_shapes = jax.eval_shape(opt.init, shapes)
        o_pspecs = S.opt_state_pspecs(o_shapes, p_pspecs)
        # wq (D, H*hd) -> P("data","model"); row drops last dim, col drops -2
        assert o_pspecs["layers"][0]["attn"]["wq"]["row"] == P("data")
        assert o_pspecs["layers"][0]["attn"]["wq"]["col"] == P("model")


@pytest.mark.slow
class TestElasticRestart:
    def test_restore_across_mesh_shapes(self, tmp_path):
        """Save sharded on a (4,2) mesh, restore sharded on (2,4) and (1,1)
        — the elastic-restart path with real multi-device placement."""
        out = _run_subprocess(f"""
            import jax, jax.numpy as jnp, numpy as np, json
            from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
            from repro.checkpoint import CheckpointManager

            state = {{"w": jnp.arange(64.0).reshape(8, 8), "step": jnp.asarray(3)}}
            mesh_a = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
            pspecs = {{"w": P("data", "model"), "step": P()}}
            sharded = jax.device_put(state, jax.tree.map(lambda s: NamedSharding(mesh_a, s), pspecs))
            m = CheckpointManager(r"{tmp_path}")
            m.save(sharded, 3)

            ok = True
            for shape, axes in [((2, 4), ("data", "model")), ((8,), ("data",)), ((1, 1), ("data", "model"))]:
                devs = np.array(jax.devices()[: int(np.prod(shape))]).reshape(shape)
                mesh_b = Mesh(devs, axes)
                specs_b = {{"w": P("data") if len(axes) == 1 else P("data", "model"), "step": P()}}
                back = m.restore_resharded(state, mesh_b, specs_b)
                ok &= bool(np.array_equal(np.asarray(back["w"]), np.arange(64.0).reshape(8, 8)))
            print(json.dumps({{"ok": ok}}))
        """)
        assert json.loads(out.strip().splitlines()[-1])["ok"]


@pytest.mark.slow
class TestDryRunEndToEnd:
    def test_dryrun_cli_one_cell(self, tmp_path):
        """The dry-run launcher compiles a real cell on the 256-chip mesh
        (xlstm decode: the cheapest full-config cell) and writes a sane
        JSON artifact."""
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        env.pop("XLA_FLAGS", None)  # dryrun.py sets its own 512 devices
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", "xlstm-125m",
             "--shape", "decode_32k", "--mesh", "pod", "--out", str(tmp_path)],
            capture_output=True, text=True, env=env, timeout=900, cwd=REPO,
        )
        assert out.returncode == 0, out.stderr[-3000:]
        d = json.loads((tmp_path / "xlstm_125m_decode_32k_pod.json").read_text())
        assert d["ok"] and d["chips"] == 256
        assert d["flops_per_device"] > 0
        assert d["roofline"]["bottleneck"] in ("compute", "memory", "collective")
