from repro.serve.engine import ServeEngine, Request
from repro.serve.fastmatch_server import MatchQuery, MatchServer
from repro.serve.supervisor import ServeSupervisor, SupervisorPolicy

__all__ = [
    "ServeEngine",
    "Request",
    "MatchQuery",
    "MatchServer",
    "ServeSupervisor",
    "SupervisorPolicy",
]
