"""Benchmark-regression gate: compare smoke-run metrics to committed baselines.

The CI smoke steps have always written machine-readable reports
(BENCH_stats.json / BENCH_restart.json / BENCH_pump.json) and uploaded
them as artifacts — but nothing ever compared two runs, so a metric
could halve silently as long as the suite's own hard floor still held.
This module closes the loop: `benchmarks/baselines/` holds a committed
snapshot of each smoke report, and the CI step

    python -m benchmarks.check_regression stats restart   # tier-1 lane
    python -m benchmarks.check_regression pump            # multi-device lane

fails the workflow when a gated metric of the fresh run regresses past
its tolerance.

Gate design: only metrics that are deterministic-per-config (seeded
sampling counts, analytic byte models, recalls, pass/fail booleans) are
gated — never wall-clock, which varies by runner. Tolerances are
generous (floats may drift in low bits across jax/jaxlib versions, and
the tier-1 matrix runs both a pinned floor and latest); a real
regression — a lost amortization, a broken equivalence — lands far
outside them. The smoke flag of both runs must agree, so a full-config
report is never judged against a smoke baseline — and when both reports
carry a hardware stamp (``config.backend`` via `common.env_stamp`), a
backend mismatch refuses the comparison outright: an XLA:CPU baseline
cannot gate a GPU run. Device-kind and jax-version drift are printed as
notes, not failures.

Refreshing a baseline after an intentional change: run the smoke
benchmark locally and copy the report over the baseline file, e.g.

    PUMP_BENCH_SMOKE=1 python -m benchmarks.run pump
    cp benchmarks/results/BENCH_pump.json benchmarks/baselines/
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import sys
from typing import Iterable, List

RESULTS = pathlib.Path(__file__).parent / "results"
BASELINES = pathlib.Path(__file__).parent / "baselines"


@dataclasses.dataclass(frozen=True)
class Gate:
    """One gated metric: a top-level key of the benchmark report.

    kind:
      "min"   — result must stay >= baseline * (1 - tol)  (bigger = better)
      "max"   — result must stay <= baseline * (1 + tol)  (smaller = better)
      "exact" — result must equal the baseline (booleans / equivalences)
    """

    key: str
    kind: str = "min"
    tol: float = 0.25

    def check(self, base, res) -> str:
        """Empty string if the gate holds, else a failure description."""
        if self.kind == "exact":
            if res != base:
                return f"{self.key}: {res!r} != baseline {base!r}"
            return ""
        base_f, res_f = float(base), float(res)
        if self.kind == "min":
            floor = base_f * (1 - self.tol)
            if res_f < floor:
                return (f"{self.key}: {res_f:g} fell below "
                        f"{floor:g} (baseline {base_f:g} - {self.tol:.0%})")
        elif self.kind == "max":
            ceil = base_f * (1 + self.tol)
            if res_f > ceil:
                return (f"{self.key}: {res_f:g} rose above "
                        f"{ceil:g} (baseline {base_f:g} + {self.tol:.0%})")
        else:
            return f"{self.key}: unknown gate kind {self.kind!r}"
        return ""


# suite name (as passed to `benchmarks.run`) -> (report file, gates)
GATES = {
    "stats": ("BENCH_stats.json", [
        Gate("tau_bytes_reduction_q8", "min", 0.10),       # analytic byte model
        Gate("batched_bytes_growth_q1_to_q8", "max", 0.10),
        Gate("batched_bit_identical", "exact"),
        # Tuned-dispatch determinism: with the SAME committed plan file,
        # the chosen variant per Q, the tuned analytic bytes, and the
        # tuned-arm bit-identity are exact — only the tuned wall-clock
        # (reported, not gated) may move between runners.
        Gate("tuned_bit_identical", "exact"),
        Gate("tuned_variants", "exact"),
        Gate("ingest_winner", "exact"),
        Gate("tuned_tau_bytes_reduction_q8", "min", 0.10),
        Gate("ok", "exact"),
    ]),
    "restart": ("BENCH_restart.json", [
        Gate("amortization", "min", 0.30),  # cold/warm tuple ratio, seeded
        Gate("ok", "exact"),
    ]),
    "pump": ("BENCH_pump.json", [
        Gate("sync_reduction_w8", "min", 0.30),
        Gate("rounds_reduction_w8", "min", 0.30),
        Gate("recall_min", "min", 0.05),
        Gate("w1_equivalent", "exact"),
        Gate("ok", "exact"),
    ]),
    # Deterministic contracts only: the overhead ratio is wall-clock
    # (runner-dependent) and is enforced by the suite itself ("ok"
    # folds it in), so gating it here twice would just double the noise.
    "telemetry": ("BENCH_telemetry.json", [
        Gate("bit_identical", "exact"),
        Gate("curve_matches", "exact"),
        Gate("trace_events", "min", 0.25),  # seeded event count
        Gate("ok", "exact"),
    ]),
    # Anytime serving: the bit-identity of SLA stops vs polls, native
    # no-slower-than-conservative, and pruning soundness are exact per
    # config; native-arm recall is a seeded float floor. Curve shapes
    # and rounds are reported, never gated.
    "anytime": ("BENCH_anytime.json", [
        Gate("stop_poll_identical", "exact"),
        Gate("stopped_not_exact", "exact"),
        Gate("native_no_slower_chi2", "exact"),
        Gate("native_no_slower_hellinger", "exact"),
        Gate("prune_sound_chi2", "exact"),
        Gate("prune_sound_hellinger", "exact"),
        Gate("recall_chi2_native", "min", 0.15),
        Gate("recall_hellinger_native", "min", 0.15),
        Gate("ok", "exact"),
    ]),
    # Tuner winners are timing-dependent (never gated); the persistence
    # contracts and the tuned key counts are deterministic.
    "autotune": ("BENCH_autotune.json", [
        Gate("n_tau_keys", "exact"),
        Gate("n_ingest_keys", "exact"),
        Gate("roundtrip_byte_stable", "exact"),
        Gate("stale_schema_fallback", "exact"),
        Gate("ok", "exact"),
    ]),
    # Fault tolerance: the equivalence/recovery booleans and the seeded
    # quarantine count are deterministic per config; recall is a seeded
    # float floor. Wall-clock (recovery_wall_s, wall_overhead_frac) is
    # never gated — the suite itself enforces the accounted < 2%
    # wrapper-overhead limit and folds it into "ok".
    "faults": ("BENCH_faults.json", [
        Gate("transient_bit_identical", "exact"),
        Gate("recovered", "exact"),
        Gate("recovery_answers_match", "exact"),
        Gate("degraded_ran", "exact"),
        Gate("blocks_quarantined", "exact"),
        Gate("recall_degraded", "min", 0.15),
        Gate("ok", "exact"),
    ]),
    # Pluggable-metric matrix: the closeness promise booleans and the
    # l1 exact-recall bit are deterministic per config; per-metric
    # top-k recall is a seeded float floor. Rounds-to-retire is
    # reported, never gated (the conservatism ordering is documented,
    # not promised numerically).
    "metrics": ("BENCH_metrics.json", [
        Gate("l1_matches_brute", "exact"),
        Gate("closeness_ok_l1", "exact"),
        Gate("closeness_ok_chi2", "exact"),
        Gate("closeness_ok_hellinger", "exact"),
        Gate("recall_l1", "min", 0.05),
        Gate("recall_chi2", "min", 0.15),
        Gate("recall_hellinger", "min", 0.15),
        Gate("ok", "exact"),
    ]),
}


def check_suite(
    name: str,
    *,
    results_dir: pathlib.Path = RESULTS,
    baselines_dir: pathlib.Path = BASELINES,
) -> List[str]:
    """All gate failures for one suite (empty = pass)."""
    if name not in GATES:
        return [f"{name}: no regression gates defined; have {sorted(GATES)}"]
    fname, gates = GATES[name]
    base_path = baselines_dir / fname
    res_path = results_dir / fname
    if not base_path.exists():
        return [f"{name}: missing baseline {base_path}"]
    if not res_path.exists():
        return [f"{name}: missing result {res_path} — did the smoke step run?"]
    base = json.loads(base_path.read_text())
    res = json.loads(res_path.read_text())
    smoke_b = base.get("config", {}).get("smoke")
    smoke_r = res.get("config", {}).get("smoke")
    if smoke_b != smoke_r:
        return [
            f"{name}: config.smoke mismatch (baseline {smoke_b!r} vs run {smoke_r!r})"
            " — smoke baselines only gate smoke runs"
        ]
    failures = []
    # Hardware provenance: an XLA:CPU baseline says nothing about a GPU
    # run, so a backend mismatch is a hard failure when both reports are
    # stamped. Device-kind / jax-version drift is informational only —
    # the tier-1 matrix deliberately runs both a pinned floor and
    # latest, and tolerances already absorb low-bit float drift.
    backend_b = base.get("config", {}).get("backend")
    backend_r = res.get("config", {}).get("backend")
    if backend_b is not None and backend_r is not None and backend_b != backend_r:
        return [
            f"{name}: config.backend mismatch (baseline {backend_b!r} vs run"
            f" {backend_r!r}) — refusing to compare across hardware"
        ]
    if backend_b is None or backend_r is None:
        print(f"# note: {name} {'baseline' if backend_b is None else 'result'} "
              "has no backend stamp; cross-hardware comparison not checked")
    for key in ("device_kind", "jax_version"):
        kb = base.get("config", {}).get(key)
        kr = res.get("config", {}).get(key)
        if kb is not None and kr is not None and kb != kr:
            print(f"# note: {name} config.{key} differs (baseline {kb!r} vs run {kr!r})")
    for gate in gates:
        if gate.key not in base:
            failures.append(f"{name}: baseline lacks gated key {gate.key!r}")
            continue
        if gate.key not in res:
            failures.append(f"{name}: result lacks gated key {gate.key!r}")
            continue
        msg = gate.check(base[gate.key], res[gate.key])
        if msg:
            failures.append(f"{name}: {msg}")
    return failures


def main(argv: Iterable[str]) -> int:
    wanted = list(argv) or sorted(GATES)
    unknown = [n for n in wanted if n not in GATES]
    if unknown:
        print(f"unknown suite(s) {unknown}; have {sorted(GATES)}", file=sys.stderr)
        return 2
    all_failures = []
    for name in wanted:
        failures = check_suite(name)
        status = "PASS" if not failures else "FAIL"
        print(f"# regression gate {name}: {status}")
        for f in failures:
            print(f"  REGRESSION {f}")
        all_failures.extend(failures)
    return 1 if all_failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
