"""Quickstart: top-k histogram matching with HistSim/FastMatch.

Recreates the paper's running example (Q1): "which countries have income
distributions most similar to Greece's?" on a synthetic census, and shows
the engine touching a small fraction of the data while satisfying the
separation/reconstruction guarantees.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.engine import EngineConfig, run_engine
from repro.core.histsim import HistSimParams
from repro.data.layout import block_layout
from repro.data.synth import SynthSpec, make_dataset


def main():
    # A census-like table: Z = country (161 of them), X = income bracket
    # (7 brackets, paper Fig. 1), ~6M rows. Ten countries are planted with
    # income distributions close to the target country's.
    spec = SynthSpec(
        v_z=161, v_x=7, num_tuples=6_000_000, k=10, n_close=10,
        close_distance=0.02, far_distance=0.3, zipf_a=1.0, seed=0,
    )
    print("generating synthetic census ...")
    ds = make_dataset(spec)
    blocked = block_layout(ds.z, ds.x, v_z=spec.v_z, v_x=spec.v_x, seed=0)

    # "Greece" = the planted target distribution; eps/delta = paper defaults
    params = HistSimParams(v_z=spec.v_z, v_x=spec.v_x, k=10, eps=0.06, delta=0.01)
    print(f"matching against target across {blocked.num_blocks} blocks ...")
    res = run_engine(blocked, ds.target, params, EngineConfig(variant="fastmatch"))

    print(f"\ntop-{params.k} matching countries (ids): {sorted(res.ids.tolist())}")
    print(f"planted ground truth:                    {sorted(ds.true_top_k.tolist())}")
    print(
        f"\nread {res.blocks_read}/{blocked.num_blocks} blocks "
        f"({res.blocks_read / blocked.num_blocks:.1%}) in {res.rounds} rounds, "
        f"{res.wall_time_s:.2f}s wall"
    )
    print(f"certified failure probability delta_upper = {res.delta_upper:.2e} (< 0.01)")
    est = np.asarray(res.state.tau)[res.ids]
    true = ds.true_dists[res.ids]
    print("\n  id   est-dist  true-dist")
    for i, e, t in zip(res.ids, est, true):
        print(f"  {i:4d}  {e:.4f}    {t:.4f}")


if __name__ == "__main__":
    main()
