"""Parse collective ops + byte volumes out of post-optimization HLO text.

`compiled.cost_analysis()` does not report collective bytes, so the
roofline's collective term is derived here: we scan `compiled.as_text()`
(post-SPMD-partitioning HLO, where every collective is explicit and all
shapes are PER-DEVICE) and charge each op its ring-algorithm wire bytes:

    all-reduce          2 x result_bytes   (reduce-scatter + all-gather)
    all-gather          1 x result_bytes   (each device receives ~full)
    reduce-scatter      1 x operand_bytes  (each device sends ~full input)
    all-to-all          1 x result_bytes
    collective-permute  1 x result_bytes

(The exact ring factor is (N-1)/N; we use 1 — a <7% overstatement at
N >= 16, consistent across all cells.)
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

__all__ = ["collective_bytes", "parse_hlo_collectives", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# a shape token: bf16[8,128,2048]{2,1,0} or f32[] ; tuples handled separately
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES[dtype]


def _result_bytes(lhs: str) -> int:
    """Bytes of the result type on the left of '= ... op(...)'."""
    return sum(_shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(lhs))


def parse_hlo_collectives(hlo_text: str) -> Dict[str, dict]:
    """Returns {op_kind: {"count": int, "bytes": int}} (per-device bytes)."""
    stats = defaultdict(lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        m = re.search(r"=\s*(.+?)\s+(%?[\w-]*?)(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)(-start|-done)?\(", line)
        if not m:
            continue
        kind = m.group(3)
        suffix = m.group(4) or ""
        if suffix == "-done":
            continue  # counted at -start
        lhs = m.group(1)
        rhs = line[m.end() - 1 :]
        result_b = _result_bytes(lhs)
        operand_b = sum(
            _shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(rhs)
        )
        if kind == "all-reduce":
            wire = 2 * result_b
        elif kind == "reduce-scatter":
            wire = operand_b if operand_b else result_b
        else:
            wire = result_b
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += wire
    return dict(stats)


def collective_bytes(hlo_text: str) -> int:
    return sum(v["bytes"] for v in parse_hlo_collectives(hlo_text).values())
