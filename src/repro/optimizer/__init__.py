from repro.optimizer.adamw import adamw
from repro.optimizer.adafactor import adafactor
from repro.optimizer.base import Optimizer, clip_by_global_norm
from repro.optimizer.compress import compress_gradients

__all__ = [
    "Optimizer",
    "adafactor",
    "adamw",
    "clip_by_global_norm",
    "compress_gradients",
    "get_optimizer",
]


def get_optimizer(name: str, lr, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr, **kw)
    if name == "adafactor":
        return adafactor(lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
