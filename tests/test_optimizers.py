"""Optimizers: reference-math checks + convergence on a quadratic."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optimizer import adafactor, adamw
from repro.optimizer.base import clip_by_global_norm, global_norm
from repro.optimizer.compress import (
    compress_gradients,
    dequantize_int8,
    init_error_feedback,
    quantize_int8,
)


class TestAdamW:
    def test_first_step_matches_reference(self):
        opt = adamw(1e-2, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
        p = {"w": jnp.asarray([[1.0, 2.0]], jnp.float32)}
        g = {"w": jnp.asarray([[0.1, -0.2]], jnp.float32)}
        st = opt.init(p)
        up, st = opt.update(g, st, p, jnp.asarray(0))
        # after bias correction the first update is -lr * sign-ish g / (|g| + eps)
        expect = -1e-2 * np.asarray([[0.1, -0.2]]) / (np.abs([[0.1, -0.2]]) + 1e-8)
        np.testing.assert_allclose(np.asarray(up["w"]), expect, rtol=1e-4)

    def test_weight_decay_applies_to_matrices_only(self):
        opt = adamw(1e-2, weight_decay=0.5)
        p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
        g = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
        st = opt.init(p)
        up, _ = opt.update(g, st, p, jnp.asarray(0))
        assert float(jnp.abs(up["w"]).sum()) > 0  # decay pulls weights
        assert float(jnp.abs(up["b"]).sum()) == 0  # biases not decayed

    def test_converges_quadratic(self):
        opt = adamw(0.1, weight_decay=0.0)
        p = {"w": jnp.asarray([5.0, -3.0])}
        st = opt.init(p)
        step = jnp.asarray(0)
        for i in range(200):
            g = jax.tree.map(lambda x: 2 * x, p)  # grad of ||w||^2
            up, st = opt.update(g, st, p, step + i)
            p = jax.tree.map(lambda a, b: a + b, p, up)
        assert float(jnp.abs(p["w"]).max()) < 1e-2


class TestAdafactor:
    def test_factored_state_memory(self):
        opt = adafactor(1e-2)
        p = {"w": jnp.zeros((128, 256)), "b": jnp.zeros((256,))}
        st = opt.init(p)
        assert st["w"]["row"].shape == (128,)
        assert st["w"]["col"].shape == (256,)
        assert st["b"]["nu"].shape == (256,)
        state_elems = sum(x.size for x in jax.tree.leaves(st))
        assert state_elems < 128 * 256  # factored: far below O(rows*cols)

    def test_converges_quadratic(self):
        opt = adafactor(0.3)
        p = {"w": jnp.full((4, 4), 5.0)}
        st = opt.init(p)
        for i in range(300):
            g = jax.tree.map(lambda x: 2 * x, p)
            up, st = opt.update(g, st, p, jnp.asarray(i))
            p = jax.tree.map(lambda a, b: a + b, p, up)
        assert float(jnp.abs(p["w"]).max()) < 0.3


class TestClipping:
    def test_clip_by_global_norm(self):
        g = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(5.0)
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)

    def test_no_clip_below_threshold(self):
        g = {"a": jnp.asarray([0.3, 0.4])}
        clipped, _ = clip_by_global_norm(g, 1.0)
        np.testing.assert_allclose(np.asarray(clipped["a"]), [0.3, 0.4], rtol=1e-6)


class TestCompression:
    def test_int8_roundtrip_error_bounded(self, rng):
        x = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
        q, scale = quantize_int8(x)
        err = jnp.abs(dequantize_int8(q, scale) - x).max()
        assert float(err) <= float(scale) / 2 + 1e-6

    def test_error_feedback_preserves_sum(self, rng):
        """With EF, accumulated quantized gradients track the true sum."""
        g_true = [rng.normal(size=(32,)).astype(np.float32) * 0.1 for _ in range(50)]
        ef = init_error_feedback({"w": jnp.zeros((32,))})
        acc = np.zeros(32, np.float32)
        for g in g_true:
            cg, ef = compress_gradients({"w": jnp.asarray(g)}, scheme="int8", error_feedback=ef)
            acc += np.asarray(cg["w"])
        np.testing.assert_allclose(acc, np.sum(g_true, axis=0), atol=0.02)

    def test_bf16_halves_bytes(self):
        g = {"w": jnp.zeros((16, 16), jnp.float32)}
        cg, _ = compress_gradients(g, scheme="bf16")
        assert cg["w"].dtype == jnp.bfloat16
