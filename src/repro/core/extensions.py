"""Paper Appendix A extensions.

A.1.1  SUM aggregations via measure-biased sampling: pre-build a sample
       where tuple t is replicated proportionally to its measure Y; then
       COUNT-matching over the biased sample equals SUM-matching over
       the original data (Ding et al.'s measure-biased trick, one extra
       pass per measure attribute).
A.1.2  Candidates defined by boolean predicates over multiple attributes,
       supported by DENSITY MAPS (per-block per-value tuple counts, not
       just presence bits) with AND/OR count estimation for AnyActive.
A.2.1  Distinct eps_1 (separation) / eps_2 (reconstruction).
A.2.3  A range [k_lo, k_hi]: HistSim picks the k in the range with the
       widest tau-gap (easiest to certify), exactly as described.
A.3.1  No-index operation = the ScanMatch variant (core/engine.py).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bounds
from repro.core.deviations import DeviationState, split_point, top_k_mask

__all__ = [
    "measure_biased_sample",
    "DensityMap",
    "PredicateNode",
    "estimate_block_counts",
    "assign_deviations_two_eps",
    "pick_k_in_range",
]


# ---------------------------------------------------------------------------
# A.1.1 measure-biased sampling for SUM aggregations
# ---------------------------------------------------------------------------

def measure_biased_sample(
    z: np.ndarray,
    x: np.ndarray,
    y: np.ndarray,
    *,
    target_size: int,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Build a measure-biased sample for `SELECT X, SUM(Y) ... GROUP BY X`.

    Tuple t is included with multiplicity proportional to its measure
    y_t >= 0 (systematic residual sampling keeps the estimator unbiased
    while bounding the sample size). Running COUNT-based HistSim over the
    returned (z', x') matches SUM-based histograms of the original data.
    """
    y = np.asarray(y, np.float64)
    if (y < 0).any():
        raise ValueError("measure attribute must be nonnegative")
    total = y.sum()
    if total <= 0:
        raise ValueError("measure attribute sums to zero")
    rng = np.random.default_rng(seed)
    expect = y * (target_size / total)
    base = np.floor(expect).astype(np.int64)
    frac = expect - base
    extra = (rng.random(len(y)) < frac).astype(np.int64)
    reps = base + extra
    idx = np.repeat(np.arange(len(y)), reps)
    perm = rng.permutation(len(idx))
    idx = idx[perm]
    return np.asarray(z)[idx].astype(np.int32), np.asarray(x)[idx].astype(np.int32)


# ---------------------------------------------------------------------------
# A.1.2 density maps + boolean predicates
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DensityMap:
    """Per-block tuple counts for each value of each candidate attribute.

    counts[attr][block, value] = #tuples in `block` with attr == value,
    saturated to 255 (uint8 — "slightly costlier" than bitmaps, paper).
    """

    counts: dict  # attr name -> (num_blocks, |V_attr|) uint8

    @classmethod
    def build(cls, blocks_by_attr: dict, cardinalities: dict) -> "DensityMap":
        out = {}
        for attr, blocks in blocks_by_attr.items():
            blocks = np.asarray(blocks)
            nb = blocks.shape[0]
            v = cardinalities[attr]
            c = np.zeros((nb, v), np.uint16)
            rows = np.repeat(np.arange(nb), blocks.shape[1])
            vals = blocks.reshape(-1)
            ok = (vals >= 0) & (vals < v)
            np.add.at(c, (rows[ok], vals[ok]), 1)
            out[attr] = np.minimum(c, 255).astype(np.uint8)
        return cls(counts=out)


@dataclasses.dataclass(frozen=True)
class PredicateNode:
    """Boolean predicate tree over attribute values: leaf | AND | OR."""

    op: str  # "leaf" | "and" | "or"
    attr: Optional[str] = None
    value: Optional[int] = None
    children: Tuple["PredicateNode", ...] = ()

    @classmethod
    def leaf(cls, attr: str, value: int) -> "PredicateNode":
        return cls(op="leaf", attr=attr, value=value)

    @classmethod
    def and_(cls, *children) -> "PredicateNode":
        return cls(op="and", children=tuple(children))

    @classmethod
    def or_(cls, *children) -> "PredicateNode":
        return cls(op="or", children=tuple(children))

    def evaluate(self, tuple_values: dict) -> bool:
        if self.op == "leaf":
            return tuple_values[self.attr] == self.value
        results = [c.evaluate(tuple_values) for c in self.children]
        return all(results) if self.op == "and" else any(results)


def estimate_block_counts(dmap: DensityMap, pred: PredicateNode, block_size: int) -> np.ndarray:
    """Upper-bound estimate of tuples per block satisfying `pred`.

    leaf  -> exact per-block count of the value;
    AND   -> min of children (can overestimate, never underestimates);
    OR    -> sum of children clipped at block size (likewise an upper
             bound). Upper bounds are safe for AnyActive: a block is only
             skipped when the estimate is 0, which then is exact — so the
             guarantees are untouched (paper A.1.2).
    """
    if pred.op == "leaf":
        return dmap.counts[pred.attr][:, pred.value].astype(np.int32)
    child = [estimate_block_counts(dmap, c, block_size) for c in pred.children]
    if pred.op == "and":
        return np.minimum.reduce(child)
    return np.minimum(np.add.reduce(child), block_size).astype(np.int32)


# ---------------------------------------------------------------------------
# A.2.1 distinct eps_1 / eps_2
# ---------------------------------------------------------------------------

def assign_deviations_two_eps(
    tau: jax.Array,
    n: jax.Array,
    *,
    k: int,
    eps_sep: float,
    eps_rec: float,
    delta: float,
    v_x: int,
) -> DeviationState:
    """Sec 3.3 deviation assignment with separate guarantee tolerances.

    eps_sep bounds Guarantee 1 (separation), eps_rec Guarantee 2
    (reconstruction): i in M gets eps_i = min(eps_rec, s + eps_sep/2 -
    tau_i); j not in M gets eps_j = tau_j - max(s - eps_sep/2, 0).
    With eps_sep == eps_rec this is exactly assign_deviations.
    """
    tau = jnp.asarray(tau, jnp.float32)
    v_z = tau.shape[0]
    in_m = top_k_mask(tau, k)
    s = split_point(tau, k)
    eps_in = jnp.minimum(eps_rec, s + 0.5 * eps_sep - tau)
    eps_out = tau - jnp.maximum(s - 0.5 * eps_sep, 0.0)
    eps_i = jnp.maximum(jnp.where(in_m, eps_in, eps_out), 0.0)
    log_delta_i = bounds.theorem1_log_delta(eps_i, n, v_x)
    delta_i = jnp.exp(log_delta_i)
    delta_upper = jnp.sum(delta_i)
    log_threshold = jnp.log(jnp.asarray(delta / float(v_z), jnp.float32))
    return DeviationState(
        tau=tau,
        in_top_k=in_m,
        split=s,
        eps_i=eps_i,
        log_delta_i=log_delta_i,
        delta_upper=delta_upper,
        active=log_delta_i > log_threshold,
    )


# ---------------------------------------------------------------------------
# A.2.3 k ranges
# ---------------------------------------------------------------------------

def pick_k_in_range(tau: jax.Array, k_lo: int, k_hi: int) -> int:
    """Choose k in [k_lo, k_hi] with the widest gap tau_(k+1) - tau_(k).

    "there may be a very large separation between the 7th- and 8th-closest
    candidates, in which case HistSim can automatically choose k = 7, as
    this likely provides a small delta_upper as soon as possible."
    """
    tau = np.sort(np.asarray(tau, np.float64))
    v_z = len(tau)
    k_hi = min(k_hi, v_z - 1)
    k_lo = max(1, k_lo)
    if k_lo > k_hi:
        raise ValueError(f"empty k range [{k_lo}, {k_hi}] for V_Z={v_z}")
    gaps = tau[k_lo : k_hi + 1] - tau[k_lo - 1 : k_hi]
    return int(k_lo + np.argmax(gaps))
