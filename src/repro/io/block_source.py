"""`BlockSource` — where the sampling loop's window data comes from.

A source serves fixed-shape windows of blocked (z, x) tuples plus the
packed presence bitmap. The contract is shaped by the device-resident
round in `repro.core.multiquery`: every `WindowData` is padded to one
static length (`pad_to`) so the jitted round never retraces, and padded
rows carry ``valid=False`` so the round masks them out of marking,
ingest and the read bookkeeping.

`fetch` is random access (used by exact completion); `stream` is the
sequential hot path a pass runs on, and is the hook `PrefetchSource`
overrides to overlap the next window's gather with the current round.
"""

from __future__ import annotations

from typing import Iterable, Iterator, NamedTuple, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.layout import BlockedDataset

__all__ = ["BlockSource", "InMemorySource", "ShardedSource", "WindowData", "as_block_source"]


class WindowData(NamedTuple):
    """One padded lookahead window of block data, ready for the round.

    Leaves are device arrays from a device-resident source and host
    numpy arrays from a host-resident one (jit converts at dispatch —
    one host→device transfer, paid exactly once; the data-parallel pump
    relies on this to assemble per-worker windows on host and place the
    sharded result in a single device_put)."""

    indices: jax.Array  # (L,) i32 global block ids (padding repeats a real id)
    z: jax.Array  # (L, B) i32 candidate ids, -1 padded within blocks
    x: jax.Array  # (L, B) i32 attribute values, -1 padded
    bitmap: jax.Array  # (L, W) uint32 packed presence bitmap rows
    valid: jax.Array  # (L,) bool — False on window padding rows


@runtime_checkable
class BlockSource(Protocol):
    """What the sampling loop needs from an I/O backend."""

    num_blocks: int
    block_size: int
    v_z: int
    v_x: int
    tuples_per_block: np.ndarray  # (num_blocks,) host-side, for accounting

    def fetch(self, win: np.ndarray, pad_to: Optional[int] = None) -> WindowData: ...

    def stream(
        self, windows: Iterable[np.ndarray], pad_to: Optional[int] = None
    ) -> Iterator[WindowData]: ...


class InMemorySource:
    """The whole blocked dataset behind the source protocol.

    ``device_resident=True`` (default) keeps the block arrays on device:
    a fetch is a device-side gather and costs no host traffic. With
    ``device_resident=False`` blocks stay in host memory (a stand-in for
    disk or a remote FS) and each fetch gathers on host and transfers
    one window — the case `PrefetchSource` exists to overlap.
    """

    def __init__(self, dataset: BlockedDataset, *, device_resident: bool = True):
        self.num_blocks = dataset.num_blocks
        self.block_size = dataset.block_size
        self.v_z = dataset.v_z
        self.v_x = dataset.v_x
        self.tuples_per_block = (dataset.z_blocks >= 0).sum(axis=1)
        self.device_resident = device_resident
        if device_resident:
            self._z = jnp.asarray(dataset.z_blocks)
            self._x = jnp.asarray(dataset.x_blocks)
            self._bitmap = jnp.asarray(dataset.bitmap)
        else:
            self._z = np.asarray(dataset.z_blocks, np.int32)
            self._x = np.asarray(dataset.x_blocks, np.int32)
            self._bitmap = np.asarray(dataset.bitmap, np.uint32)

    def _pad(self, win: np.ndarray, pad_to: Optional[int]):
        win = np.asarray(win, np.int32).ravel()
        length = len(win) if pad_to is None else pad_to
        if len(win) > length:
            raise ValueError(f"window of {len(win)} blocks exceeds pad_to={length}")
        idx = np.zeros(length, np.int32)
        idx[: len(win)] = win
        valid = np.zeros(length, bool)
        valid[: len(win)] = True
        return idx, valid

    def fetch(self, win: np.ndarray, pad_to: Optional[int] = None) -> WindowData:
        idx, valid = self._pad(win, pad_to)
        if self.device_resident:
            j = jnp.asarray(idx)
            return WindowData(j, self._z[j], self._x[j], self._bitmap[j], jnp.asarray(valid))
        # Host-resident: stay numpy — the consumer decides when the one
        # host→device transfer happens (jit dispatch, or the pump's
        # sharded device_put of the assembled multi-worker window).
        return WindowData(idx, self._z[idx], self._x[idx], self._bitmap[idx], valid)

    def stream(
        self, windows: Iterable[np.ndarray], pad_to: Optional[int] = None
    ) -> Iterator[WindowData]:
        for win in windows:
            yield self.fetch(win, pad_to)


class ShardedSource(InMemorySource):
    """One data-parallel worker's contiguous block range.

    Built on `BlockedDataset.shard`; callers keep speaking GLOBAL block
    ids (so one read_mask/visit order spans the mesh) and the source
    translates to its local range. `owned(win)` filters a global window
    down to this worker's share.

    This is the per-worker feed for the manually driven
    `repro.core.distributed.make_distributed_round` ingest — it is NOT a
    drop-in dataset for `SharedCountsScheduler`/`run_engine`, whose
    visit order is 0-based over the whole dataset (the scheduler rejects
    it explicitly).
    """

    def __init__(
        self,
        dataset: BlockedDataset,
        num_shards: int,
        shard_id: int,
        *,
        device_resident: bool = True,
    ):
        if not (0 <= shard_id < num_shards):
            raise ValueError(f"need 0 <= shard_id < num_shards, got {shard_id}/{num_shards}")
        shard = dataset.shard(num_shards, shard_id)
        super().__init__(shard, device_resident=device_resident)
        per = -(-dataset.num_blocks // num_shards)
        self.lo = shard_id * per
        self.hi = self.lo + shard.num_blocks
        self.global_num_blocks = dataset.num_blocks

    def owned(self, win: np.ndarray) -> np.ndarray:
        win = np.asarray(win, np.int32).ravel()
        return win[(win >= self.lo) & (win < self.hi)]

    def fetch(self, win: np.ndarray, pad_to: Optional[int] = None) -> WindowData:
        win = np.asarray(win, np.int32).ravel()
        if win.size and ((win < self.lo) | (win >= self.hi)).any():
            raise ValueError(
                f"block ids outside shard range [{self.lo}, {self.hi}); filter with owned()"
            )
        wd = super().fetch(win - self.lo, pad_to)
        # match the leaf residency: a jnp scalar would silently drag a
        # host-resident window onto the default device
        lo = (np.int32 if isinstance(wd.indices, np.ndarray) else jnp.int32)(self.lo)
        return wd._replace(indices=wd.indices + lo)


def as_block_source(data) -> BlockSource:
    """BlockedDataset -> InMemorySource; an existing source passes through."""
    if isinstance(data, BlockedDataset):
        return InMemorySource(data)
    if isinstance(data, BlockSource):
        return data
    raise TypeError(f"expected BlockedDataset or BlockSource, got {type(data)!r}")
