"""Pallas TPU kernel: row-normalized l1 distance to a target distribution.

Computes, for every candidate row i of a (V_Z, V_X) counts matrix,

    tau_i = || counts_i / max(sum_x counts_i, 1)  -  q_hat ||_1

in a single VMEM pass: the row block (Z_TILE x V_X) is loaded once, the
row sum, normalization, absolute difference and lane reduction are all
fused. This is the statistics engine's hot loop (paper Sec 3: "each
iteration ... O(|V_Z| * |V_X|)"); fusing it keeps the statistics step far
cheaper than an ingest round, which is what lets FastMatch run the
termination test "frequently enough to ensure timely termination".

Rows with zero mass return ||q_hat||_1 (= 1), matching ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["l1_distance_pallas"]

_Z_TILE = 256
# Single-block V_X bound: (Z_TILE x V_X) f32 must fit VMEM with headroom.
_MAX_VX = 4096


def _l1_kernel(counts_ref, q_ref, out_ref):
    counts = counts_ref[...].astype(jnp.float32)  # (Z_TILE, V_X)
    q = q_ref[...].astype(jnp.float32)  # (1, V_X)
    row = jnp.sum(counts, axis=1, keepdims=True)
    r_hat = counts / jnp.maximum(row, 1.0)
    out_ref[...] = jnp.sum(jnp.abs(r_hat - q), axis=1)


def l1_distance_pallas(
    counts: jax.Array,
    q_hat: jax.Array,
    *,
    z_tile: int = _Z_TILE,
    interpret: bool = False,
) -> jax.Array:
    """(V_Z,) float32 distances tau_i. V_X must be <= 4096 (one VMEM block).

    V_X and V_Z are padded internally; q_hat padding is 0 so padded lanes
    contribute |0 - 0| = 0.
    """
    v_z, v_x = counts.shape
    if v_x > _MAX_VX:
        raise ValueError(f"V_X={v_x} exceeds single-block bound {_MAX_VX}")

    z_tile = min(z_tile, v_z)
    vz_pad = -(-v_z // z_tile) * z_tile
    vx_pad = max(128, -(-v_x // 128) * 128)
    if (vz_pad, vx_pad) != (v_z, v_x):
        counts = jnp.pad(counts, ((0, vz_pad - v_z), (0, vx_pad - v_x)))
        q_hat = jnp.pad(q_hat, (0, vx_pad - v_x))
    q2d = q_hat.reshape(1, vx_pad)

    out = pl.pallas_call(
        functools.partial(_l1_kernel),
        grid=(vz_pad // z_tile,),
        in_specs=[
            pl.BlockSpec((z_tile, vx_pad), lambda zb: (zb, 0)),
            pl.BlockSpec((1, vx_pad), lambda zb: (0, 0)),
        ],
        out_specs=pl.BlockSpec((z_tile,), lambda zb: (zb,)),
        out_shape=jax.ShapeDtypeStruct((vz_pad,), jnp.float32),
        interpret=interpret,
    )(counts, q2d)
    return out[:v_z]
