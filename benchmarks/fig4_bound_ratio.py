"""Figure 4: ratio of Theorem 1's bound to Waggoner '15, vs |V_X|.

Paper claim: "our bound typically requires half or fewer samples to make
the same level of guarantee" at delta = 0.01 (the eps-dependence cancels,
so the sample ratio is (eps_ours / eps_waggoner)^-2 at fixed n —
equivalently we report n_ours/n_waggoner at fixed eps).
"""

from __future__ import annotations


from repro.core import bounds


def run(csv_rows: list) -> None:
    delta = 0.01
    n = 100_000
    for v_x in (2, 7, 24, 64, 161, 512, 2110):
        ours = float(bounds.theorem1_epsilon(n, delta, v_x))
        wagg = float(bounds.waggoner_epsilon(n, delta, v_x))
        sample_ratio = (ours / wagg) ** 2  # n scales as eps^-2
        csv_rows.append(
            dict(
                name=f"fig4.vx_{v_x}",
                us_per_call=0.0,
                derived=f"eps_ratio={ours / wagg:.3f} sample_ratio={sample_ratio:.3f}",
            )
        )
