"""Synthetic categorical datasets with the paper's query shapes.

The paper evaluates on FLIGHTS (|V_Z|=161, |V_X| in {7,24,161}), TAXI
(|V_Z|=7548, |V_X| in {12,24}) and POLICE (|V_Z| in {191,2110}, |V_X| in
{2,5}). Those raw files are not available offline, so we generate
datasets with the same statistical structure and *planted ground truth*:

* a target distribution Q over V_X;
* `n_close` candidates whose true distribution sits at controlled l1
  distances from Q (the planted top-k, with a controllable separation
  gap — this is what stresses Guarantee 1);
* remaining candidates drawn from a Dirichlet prior, rejected into a
  band of distances >= far_distance from Q;
* candidate frequencies following a Zipf law (the paper's "rare top-k"
  FLIGHTS-q2/q3 regime corresponds to planting the close candidates in
  the Zipf tail via `close_rank`).

Ground truth (true candidate distributions + true distances) ships with
the dataset so tests/benchmarks can check Guarantees 1 and 2 exactly.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["SynthSpec", "SynthDataset", "make_dataset", "perturb_distribution"]


@dataclasses.dataclass(frozen=True)
class SynthSpec:
    v_z: int = 161
    v_x: int = 24
    num_tuples: int = 2_000_000
    k: int = 10
    n_close: int = 10  # candidates planted near the target
    close_distance: float = 0.02  # l1 distance of planted matches
    far_distance: float = 0.25  # minimum l1 distance of non-matches
    zipf_a: float = 1.2  # candidate frequency skew (1.0 = flat-ish)
    close_rank: str = "head"  # "head" | "tail" — where matches sit in the Zipf order
    target_kind: str = "peaked"  # "peaked" | "uniform"
    seed: int = 0


@dataclasses.dataclass
class SynthDataset:
    spec: SynthSpec
    z: np.ndarray  # (N,) int32 candidate ids
    x: np.ndarray  # (N,) int32 group ids
    target: np.ndarray  # (V_X,) f64 target distribution Q_hat
    true_dists: np.ndarray  # (V_Z,) f64 DATASET-empirical distance to Q (the paper's tau*)
    true_hists: np.ndarray  # (V_Z, V_X) f64 DATASET-empirical candidate distributions (r*)
    gen_hists: np.ndarray  # (V_Z, V_X) f64 generating distributions (before sampling noise)
    close_ids: np.ndarray  # ids of planted close candidates

    @property
    def true_top_k(self) -> np.ndarray:
        return np.argsort(self.true_dists, kind="stable")[: self.spec.k]


def perturb_distribution(p: np.ndarray, dist: float, rng: np.random.Generator) -> np.ndarray:
    """A distribution at l1 distance ~`dist` from p (mass moved randomly)."""
    v = p.copy()
    d = rng.dirichlet(np.ones_like(p))
    e = rng.dirichlet(np.ones_like(p))
    move = (d - e) * (dist / max(np.abs(d - e).sum(), 1e-12))
    v = np.clip(v + move, 1e-9, None)
    return v / v.sum()


def _target(spec: SynthSpec, rng: np.random.Generator) -> np.ndarray:
    if spec.target_kind == "uniform":
        q = np.full(spec.v_x, 1.0 / spec.v_x)
    else:
        q = rng.dirichlet(np.full(spec.v_x, 2.0))
    return q / q.sum()


def make_dataset(spec: SynthSpec) -> SynthDataset:
    rng = np.random.default_rng(spec.seed)
    q = _target(spec, rng)

    # Candidate frequencies: Zipf over ranks, assigned to candidate ids.
    ranks = np.arange(1, spec.v_z + 1, dtype=np.float64)
    freq = ranks ** (-spec.zipf_a)
    freq /= freq.sum()

    # Planted close candidates occupy the head or tail of the Zipf order.
    ids = np.arange(spec.v_z)
    if spec.close_rank == "tail":
        close_ids = ids[-spec.n_close :]
    else:
        close_ids = ids[: spec.n_close]

    # Per-candidate true distributions.
    hists = np.zeros((spec.v_z, spec.v_x))
    spread = np.linspace(0.5, 1.5, num=max(spec.n_close, 1))
    ci = 0
    for z in range(spec.v_z):
        if z in set(close_ids.tolist()):
            d = spec.close_distance * spread[ci % len(spread)]
            ci += 1
            hists[z] = perturb_distribution(q, d, rng)
        else:
            # Rejection sample into the far band.
            for _ in range(64):
                h = rng.dirichlet(np.full(spec.v_x, 0.8))
                if np.abs(h - q).sum() >= spec.far_distance:
                    break
            else:  # force it far: move mass to a random corner
                h = perturb_distribution(q, spec.far_distance * 1.5, rng)
            hists[z] = h

    # Sample tuples: z ~ freq, x | z ~ hists[z].
    z = rng.choice(spec.v_z, size=spec.num_tuples, p=freq).astype(np.int32)
    x = np.empty(spec.num_tuples, dtype=np.int32)
    # Vectorized per-candidate sampling.
    order = np.argsort(z, kind="stable")
    z_sorted = z[order]
    boundaries = np.searchsorted(z_sorted, np.arange(spec.v_z + 1))
    for zv in range(spec.v_z):
        lo, hi = boundaries[zv], boundaries[zv + 1]
        if hi > lo:
            x[order[lo:hi]] = rng.choice(spec.v_x, size=hi - lo, p=hists[zv])

    # Ground truth in the paper's sense: r*_i is the histogram a COMPLETE
    # SCAN of the dataset would produce (not the generating distribution).
    emp = np.zeros((spec.v_z, spec.v_x))
    np.add.at(emp, (z, x), 1.0)
    row = np.maximum(emp.sum(axis=1, keepdims=True), 1.0)
    emp_hat = emp / row
    true_dists = np.abs(emp_hat - q[None, :]).sum(axis=1)
    return SynthDataset(
        spec=spec,
        z=z,
        x=x,
        target=q,
        true_dists=true_dists,
        true_hists=emp_hat,
        gen_hists=hists,
        close_ids=np.asarray(close_ids),
    )
