"""Autotuner end-to-end smoke: tune, persist, reload, dispatch — deterministically.

Runs the real `repro.kernels.autotune` tuner over a small grid of shape
keys and proves the machinery round-trips:

  1. tune_tau / tune_ingest measure the full candidate space per key and
     pick a winner (margin-biased toward the unrolled/fused comparator);
  2. the winning plans persist to ``results/tuned_smoke/<backend>.json``
     (a scratch dir — NEVER the committed ``results/tuned/`` artifact,
     which this benchmark must not clobber with noisy-runner timings);
  3. a fresh `PlanRegistry.load` of that file reproduces byte-identical
     ``decisions()`` — the determinism contract CI gates on: two
     processes loading the same plan file dispatch the same programs;
  4. a deliberately stale-schema copy falls back to default plans with
     a warning instead of crashing.

What is and is not gated: the ROUND-TRIP and FALLBACK booleans and the
key counts are deterministic and gated by check_regression.py; the
*winners* are timing-dependent on a shared runner and are reported in
BENCH_autotune.json for inspection only.

Set AUTOTUNE_SMOKE=1 for the tiny CI grid (exits non-zero on any
contract failure).
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import time
import warnings

from benchmarks.common import env_stamp
from repro.kernels import autotune

SMOKE = bool(int(os.environ.get("AUTOTUNE_SMOKE", "0")))
# (v_z, v_x, [qs]) tuning grid; smoke stays tiny so the CI step is seconds.
GRID = [(64, 64, (1, 2))] if SMOKE else [(256, 256, (1, 2, 4, 8)), (4096, 1024, (1, 2, 4, 8))]
REPS = 3 if SMOKE else 15

RESULTS = pathlib.Path(__file__).parent / "results"


def run(rows: list) -> None:
    out_dir = RESULTS / "tuned_smoke"
    out_dir.mkdir(parents=True, exist_ok=True)
    backend = env_stamp()["backend"]
    reg = autotune.PlanRegistry(backend=backend)

    t0 = time.time()
    winners = {"tau": {}, "ingest": {}}
    n_candidates = 0
    for v_z, v_x, qs in GRID:
        for q in qs:
            plan, timed = autotune.tune_tau(v_z, v_x, q, reps=REPS)
            reg.tau[autotune.tau_key(v_z, v_x, q)] = plan
            winners["tau"][autotune.tau_key(v_z, v_x, q)] = dict(
                **dataclasses.asdict(plan),
                us=round(1e6 * timed[plan], 1),
                n_candidates=len(timed),
            )
            n_candidates += len(timed)
        plan, timed = autotune.tune_ingest(v_z, v_x, reps=REPS)
        reg.ingest[autotune.ingest_key(v_z, v_x)] = plan
        winners["ingest"][autotune.ingest_key(v_z, v_x)] = dict(
            **dataclasses.asdict(plan),
            us=round(1e6 * timed[plan], 1),
            n_candidates=len(timed),
        )
        n_candidates += len(timed)
    tune_wall = time.time() - t0

    # contract 3: save -> load reproduces byte-identical decisions
    path = reg.save(out_dir / f"{backend}.json")
    reloaded = autotune.PlanRegistry.load(path=path, backend=backend)
    roundtrip = reloaded.decisions() == reg.decisions()
    # and a second independent load is byte-stable too (no dict-order or
    # float-repr drift between loads of the same file)
    roundtrip &= (
        autotune.PlanRegistry.load(path=path, backend=backend).decisions()
        == reloaded.decisions()
    )

    # contract 4: stale schema -> warn + default plans, never a crash
    stale_path = out_dir / f"{backend}.stale.json"
    doc = json.loads(path.read_text())
    doc["schema"] = autotune.PLAN_SCHEMA + 999
    stale_path.write_text(json.dumps(doc))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        stale_reg = autotune.PlanRegistry.load(path=stale_path, backend=backend)
    stale_fallback = (
        not stale_reg.tau
        and not stale_reg.ingest
        and stale_reg.tau_plan(64, 64, 1) == autotune.DEFAULT_TAU
        and any("schema" in str(w.message) for w in caught)
    )
    stale_path.unlink()

    ok = roundtrip and stale_fallback and bool(reg.tau) and bool(reg.ingest)
    report = dict(
        config=dict(grid=[[v_z, v_x, list(qs)] for v_z, v_x, qs in GRID],
                    reps=REPS, smoke=SMOKE, **env_stamp()),
        plan_file=str(path),
        n_tau_keys=len(reg.tau),
        n_ingest_keys=len(reg.ingest),
        n_candidates_measured=n_candidates,
        tune_wall_s=round(tune_wall, 2),
        winners=winners,  # timing-dependent: reported, never gated
        roundtrip_byte_stable=roundtrip,
        stale_schema_fallback=stale_fallback,
        ok=ok,
    )
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "BENCH_autotune.json").write_text(json.dumps(report, indent=2) + "\n")

    rows.append(dict(name="autotune_keys", us_per_call=1e6 * tune_wall,
                     derived=len(reg.tau) + len(reg.ingest)))
    rows.append(dict(name="autotune_roundtrip", us_per_call=0.0,
                     derived=1.0 if roundtrip else 0.0))
    rows.append(dict(name="autotune_stale_fallback", us_per_call=0.0,
                     derived=1.0 if stale_fallback else 0.0))

    print(f"# autotune_smoke: {len(reg.tau)} tau + {len(reg.ingest)} ingest keys "
          f"({n_candidates} candidates) tuned in {tune_wall:.1f}s -> {path}, "
          f"roundtrip={roundtrip}, stale_fallback={stale_fallback} "
          f"-> {'PASS' if ok else 'FAIL'}")
    if SMOKE and not ok:
        raise SystemExit("autotune smoke FAILED")


if __name__ == "__main__":
    rows: list = []
    run(rows)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
