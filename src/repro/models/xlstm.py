"""xLSTM: mLSTM (matrix-memory) + sLSTM (scalar-memory) blocks.

Faithful to Beck et al. 2024 at block granularity:

* mLSTM block — up-projection (factor 2), short causal conv feeding q/k,
  matrix memory C_t = f_t C_{t-1} + i_t v_t k_t^T with exponential gating
  and max-stabilizer m_t, gated output, down-projection. Implemented in
  the CHUNK-RECURRENT form: a lax.scan over chunks carries (C, n, m);
  within a chunk everything is parallel einsum work (the TPU-friendly
  evaluation — quadratic only within the chunk). Decode is the O(1)
  single-step recurrence, which is why this arch runs `long_500k`.
* sLSTM block — scalar memory with hidden-to-gate recurrence; inherently
  sequential, evaluated with lax.scan over time (per the paper: "the
  sLSTM has memory mixing and is not parallelizable").

Layer pattern: one sLSTM block every `cfg.slstm_every` blocks (the paper's
xLSTM[7:1] ratio), mLSTM elsewhere.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.layers import shard

__all__ = ["init_params", "forward", "init_cache", "prefill", "decode_step", "is_slstm"]


def is_slstm(cfg: ModelConfig, layer_idx: int) -> bool:
    if cfg.slstm_every <= 0:
        return False
    return layer_idx % cfg.slstm_every == cfg.slstm_every - 1


def _dims(cfg: ModelConfig):
    d = cfg.d_model
    d_inner = int(cfg.proj_factor_mlstm * d)
    h = cfg.num_heads
    dh = d_inner // h
    return d, d_inner, h, dh


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_mlstm_block(key, cfg: ModelConfig, dt) -> dict:
    d, d_inner, h, dh = _dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "w_up": L.dense_init(ks[0], (d, 2 * d_inner), dt),
        "conv_w": (jax.random.normal(ks[1], (4, d_inner), jnp.float32) * 0.02).astype(dt),
        "conv_b": jnp.zeros((d_inner,), dt),
        "wq": L.dense_init(ks[2], (d_inner, d_inner), dt),
        "wk": L.dense_init(ks[3], (d_inner, d_inner), dt),
        "wv": L.dense_init(ks[4], (d_inner, d_inner), dt),
        "w_if": L.dense_init(ks[5], (d_inner, 2 * h), jnp.float32),
        "b_i": jnp.zeros((h,), jnp.float32),
        "b_f": jnp.full((h,), 3.0, jnp.float32),  # forget-dominant init
        "mix_norm": L.init_rmsnorm(d_inner, dt),
        "w_down": L.dense_init(ks[6], (d_inner, d), dt),
    }


def init_slstm_block(key, cfg: ModelConfig, dt) -> dict:
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    ff = int(cfg.proj_factor_slstm * d)
    ks = jax.random.split(key, 6)
    return {
        # gates z,i,f,o each (d -> d) input + (dh -> dh per head) recurrent
        "w_gates": L.dense_init(ks[0], (d, 4 * d), dt),
        "r_gates": (jax.random.normal(ks[1], (4, h, dh, dh), jnp.float32) * 0.02).astype(dt),
        "b_gates": jnp.concatenate(
            [jnp.zeros((2 * d,)), jnp.full((d,), 3.0), jnp.zeros((d,))]
        ).astype(jnp.float32),  # z,i | f (high) | o
        "group_norm": L.init_rmsnorm(d, dt),
        "w_ff_gate": L.dense_init(ks[2], (d, ff), dt),
        "w_ff_up": L.dense_init(ks[3], (d, ff), dt),
        "w_ff_down": L.dense_init(ks[4], (ff, d), dt),
    }


def init_layer(key, cfg: ModelConfig, li: int) -> dict:
    dt = jnp.dtype(cfg.dtype)
    p = {"norm": L.init_rmsnorm(cfg.d_model, dt)}
    if is_slstm(cfg, li):
        p["slstm"] = init_slstm_block(key, cfg, dt)
    else:
        p["mlstm"] = init_mlstm_block(key, cfg, dt)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, cfg.num_layers + 2)
    dt = jnp.dtype(cfg.dtype)
    return {
        "embed": {"table": L.embed_init(keys[0], (cfg.vocab_size, cfg.d_model), dt)},
        "final_norm": L.init_rmsnorm(cfg.d_model, dt),
        "lm_head": {"w": L.dense_init(keys[-1], (cfg.d_model, cfg.vocab_size), dt)},
        "layers": [init_layer(keys[i + 1], cfg, i) for i in range(cfg.num_layers)],
    }


# ---------------------------------------------------------------------------
# mLSTM cell — chunk-recurrent evaluation
# ---------------------------------------------------------------------------

class MLSTMState(NamedTuple):
    c: jax.Array  # (B,H,dh,dh) f32 matrix memory
    n: jax.Array  # (B,H,dh) f32 normalizer
    m: jax.Array  # (B,H) f32 stabilizer
    conv: jax.Array  # (B,K-1,d_inner) streaming causal-conv state


def _mlstm_chunk_scan(q, k, v, log_i, log_f, chunk: int, state: MLSTMState):
    """q,k,v: (B,S,H,dh); log_i/log_f: (B,S,H). Returns (h (B,S,H,dh), state)."""
    b, s, h, dh = q.shape
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))

    def to_chunks(x):
        return x.reshape(b, nc, chunk, *x.shape[2:]).transpose(1, 0, 2, *range(3, x.ndim + 1))

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    lic, lfc = to_chunks(log_i), to_chunks(log_f)
    scale = dh ** -0.5

    def body(carry, xs):
        c_mat, n_vec, m = carry  # (B,H,dh,dh), (B,H,dh), (B,H)
        qi, ki, vi, li, lf = xs  # (B,Cn,H,dh) / (B,Cn,H)
        bcum = jnp.cumsum(lf, axis=1)  # inclusive cumsum of log f
        g = li - bcum  # (B,Cn,H)
        gmax = jax.lax.cummax(g, axis=1)
        m_t = bcum + jnp.maximum(m[:, None, :], gmax)  # (B,Cn,H)

        # inter-chunk: q_t C_prev, scaled exp(m_prev - (m_t - b_t))
        inter_scale = jnp.exp(m[:, None, :] + bcum - m_t)  # (B,Cn,H)
        inter = jnp.einsum("bthd,bhde->bthe", qi * scale, c_mat) * inter_scale[..., None]
        inter_n = jnp.einsum("bthd,bhd->bth", qi * scale, n_vec) * inter_scale

        # intra-chunk: D[t,s] = exp(g_s - max(m_prev, gmax_t)) for s<=t
        mt_rel = m_t - bcum  # = max(m_prev, gmax_t)
        dmat = jnp.exp(g[:, None, :, :] - mt_rel[:, :, None, :])  # (B,t,s,H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        dmat = jnp.where(tri[None, :, :, None], dmat, 0.0)
        qk = jnp.einsum("bthd,bshd->btsh", qi * scale, ki)  # (B,t,s,H)
        w = qk * dmat
        intra = jnp.einsum("btsh,bshd->bthd", w, vi)
        intra_n = jnp.sum(w, axis=2)  # (B,t,H)

        num = inter + intra  # (B,Cn,H,dh)
        den = inter_n + intra_n
        denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        h_out = num / denom[..., None]

        # carry update to end of chunk
        b_tot = bcum[:, -1, :]  # (B,H)
        m_last = m_t[:, -1, :]
        c_scale = jnp.exp(m[:, :] + b_tot - m_last)  # (B,H)
        kv_scale = jnp.exp(g + (b_tot[:, None, :] - m_last[:, None, :]))  # (B,Cn,H)
        c_new = c_mat * c_scale[..., None, None] + jnp.einsum(
            "bshd,bsh,bshe->bhde", ki, kv_scale, vi
        )
        n_new = n_vec * c_scale[..., None] + jnp.einsum("bshd,bsh->bhd", ki, kv_scale)
        return (c_new, n_new, m_last), h_out

    (c, n, m), hs = jax.lax.scan(body, (state.c, state.n, state.m), (qc, kc, vc, lic, lfc))
    h_full = hs.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, h, dh)[:, :s]
    return h_full, MLSTMState(c, n, m, state.conv)


def _mlstm_step(q, k, v, log_i, log_f, state: MLSTMState):
    """Single-token recurrence. q,k,v: (B,H,dh); log_i/f: (B,H)."""
    dh = q.shape[-1]
    scale = dh ** -0.5
    m_new = jnp.maximum(log_f + state.m, log_i)
    i_p = jnp.exp(log_i - m_new)
    f_p = jnp.exp(log_f + state.m - m_new)
    c = state.c * f_p[..., None, None] + i_p[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n = state.n * f_p[..., None] + i_p[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q * scale, c)
    den = jnp.einsum("bhd,bhd->bh", q * scale, n)
    denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_new))
    return num / denom[..., None], MLSTMState(c, n, m_new, state.conv)


def mlstm_block(p: dict, x: jax.Array, cfg: ModelConfig, *, state=None, single_step=False):
    """x: (B,S,D). Returns (y (B,S,D), MLSTMState)."""
    d, d_inner, h, dh = _dims(cfg)
    dt = x.dtype
    b, s, _ = x.shape
    up = jnp.dot(x, p["w_up"], preferred_element_type=jnp.float32).astype(dt)
    inner, z = up[..., :d_inner], up[..., d_inner:]

    # short causal conv on the q/k path (streaming form carries K-1 taps)
    kw = p["conv_w"].shape[0]
    if single_step:
        xs_cat = jnp.concatenate([state.conv.astype(dt), inner], axis=1)  # (B,K,d)
        conv = sum(
            xs_cat[:, i : i + 1, :] * p["conv_w"][i][None, None, :].astype(dt)
            for i in range(kw)
        ) + p["conv_b"].astype(dt)
        new_conv_state = xs_cat[:, 1:, :]
    else:
        xp = jnp.pad(inner, ((0, 0), (kw - 1, 0), (0, 0)))
        conv = sum(
            xp[:, i : i + s, :] * p["conv_w"][i][None, None, :].astype(dt) for i in range(kw)
        ) + p["conv_b"].astype(dt)
        new_conv_state = xp[:, kw - 1 + s - (kw - 1) : kw - 1 + s, :]  # last K-1 inputs
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(dt)

    q = jnp.dot(conv, p["wq"], preferred_element_type=jnp.float32).astype(dt).reshape(b, s, h, dh)
    k = jnp.dot(conv, p["wk"], preferred_element_type=jnp.float32).astype(dt).reshape(b, s, h, dh)
    v = jnp.dot(inner, p["wv"], preferred_element_type=jnp.float32).astype(dt).reshape(b, s, h, dh)
    gates = jnp.dot(inner.astype(jnp.float32), p["w_if"])  # (B,S,2H)
    log_i = gates[..., :h] + p["b_i"]
    log_f = jax.nn.log_sigmoid(gates[..., h:] + p["b_f"])

    if state is None:
        state = MLSTMState(
            c=jnp.zeros((b, h, dh, dh), jnp.float32),
            n=jnp.zeros((b, h, dh), jnp.float32),
            m=jnp.zeros((b, h), jnp.float32),
            conv=jnp.zeros((b, kw - 1, d_inner), dt),
        )
    if single_step:
        h_out, state = _mlstm_step(
            q[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32),
            v[:, 0].astype(jnp.float32), log_i[:, 0], log_f[:, 0], state
        )
        h_out = h_out[:, None]
    else:
        h_out, state = _mlstm_chunk_scan(
            q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
            log_i, log_f, cfg.mlstm_chunk, state
        )
    state = state._replace(conv=new_conv_state)
    h_mixed = L.rms_norm(p["mix_norm"], h_out.reshape(b, s, d_inner).astype(dt), cfg.norm_eps)
    y = h_mixed * jax.nn.silu(z.astype(jnp.float32)).astype(dt)
    return jnp.dot(y, p["w_down"], preferred_element_type=jnp.float32).astype(dt), state


# ---------------------------------------------------------------------------
# sLSTM cell — sequential scan
# ---------------------------------------------------------------------------

class SLSTMState(NamedTuple):
    c: jax.Array  # (B,D) f32
    n: jax.Array  # (B,D) f32
    h: jax.Array  # (B,D) f32
    m: jax.Array  # (B,D) f32


def _slstm_scan(p, x_gates, cfg: ModelConfig, state: SLSTMState):
    """x_gates: (B,S,4D) input contributions to z,i,f,o gates."""
    b, s, _ = x_gates.shape
    d = cfg.d_model
    h_heads = cfg.num_heads
    dh = d // h_heads
    r = p["r_gates"].astype(jnp.float32)  # (4,H,dh,dh)

    def step(st: SLSTMState, xg):
        hprev = st.h.reshape(b, h_heads, dh)
        rec = jnp.einsum("bhd,ghde->gbhe", hprev, r).reshape(4, b, d)
        zi = xg[:, 0 * d : 1 * d] + rec[0]
        ii = xg[:, 1 * d : 2 * d] + rec[1]
        ff = xg[:, 2 * d : 3 * d] + rec[2]
        oo = xg[:, 3 * d : 4 * d] + rec[3]
        z = jnp.tanh(zi)
        o = jax.nn.sigmoid(oo)
        log_f = jax.nn.log_sigmoid(ff)
        m_new = jnp.maximum(log_f + st.m, ii)
        i_p = jnp.exp(ii - m_new)
        f_p = jnp.exp(log_f + st.m - m_new)
        c = f_p * st.c + i_p * z
        n = f_p * st.n + i_p
        h = o * c / jnp.maximum(n, 1.0)
        return SLSTMState(c, n, h, m_new), h

    state, hs = jax.lax.scan(step, state, x_gates.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2), state  # (B,S,D)


def slstm_block(p: dict, x: jax.Array, cfg: ModelConfig, *, state=None):
    b, s, d = x.shape
    dt = x.dtype
    xg = jnp.dot(x, p["w_gates"], preferred_element_type=jnp.float32) + p["b_gates"]
    if state is None:
        z = jnp.zeros((b, d), jnp.float32)
        state = SLSTMState(z, z, z, z)
    h, state = _slstm_scan(p, xg, cfg, state)
    h = L.rms_norm(p["group_norm"], h.astype(dt), cfg.norm_eps)
    g = jnp.dot(h, p["w_ff_gate"], preferred_element_type=jnp.float32)
    u = jnp.dot(h, p["w_ff_up"], preferred_element_type=jnp.float32)
    y = (jax.nn.gelu(g) * u).astype(dt)
    return jnp.dot(y, p["w_ff_down"], preferred_element_type=jnp.float32).astype(dt), state


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

class XLSTMCache(NamedTuple):
    mlstm: list  # MLSTMState or None per layer
    slstm: list  # SLSTMState or None per layer
    length: jax.Array


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig, **_) -> tuple:
    x = params["embed"]["table"][tokens]
    x = shard(x, "batch", "seq", None)
    for li, lp in enumerate(params["layers"]):
        h = L.rms_norm(lp["norm"], x, cfg.norm_eps)
        if is_slstm(cfg, li):
            y, _ = slstm_block(lp["slstm"], h, cfg)
        else:
            y, _ = mlstm_block(lp["mlstm"], h, cfg)
        x = x + y
        x = shard(x, "batch", "seq", None)
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.dot(x, params["lm_head"]["w"], preferred_element_type=jnp.float32)
    return shard(logits, "batch", "seq", "vocab"), {}


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> XLSTMCache:
    d, d_inner, h, dh = _dims(cfg)
    ms, ss = [], []
    for li in range(cfg.num_layers):
        if is_slstm(cfg, li):
            z = jnp.zeros((batch, cfg.d_model), jnp.float32)
            ss.append(SLSTMState(z, z, z, z))
            ms.append(None)
        else:
            ms.append(
                MLSTMState(
                    c=jnp.zeros((batch, h, dh, dh), jnp.float32),
                    n=jnp.zeros((batch, h, dh), jnp.float32),
                    m=jnp.zeros((batch, h), jnp.float32),
                    conv=jnp.zeros((batch, 3, d_inner), jnp.dtype(cfg.dtype)),
                )
            )
            ss.append(None)
    return XLSTMCache(ms, ss, jnp.asarray(0, jnp.int32))


def prefill(params: dict, tokens: jax.Array, cfg: ModelConfig, max_len: int) -> tuple:
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_len)
    ms, ss = list(cache.mlstm), list(cache.slstm)
    x = params["embed"]["table"][tokens]
    for li, lp in enumerate(params["layers"]):
        h = L.rms_norm(lp["norm"], x, cfg.norm_eps)
        if is_slstm(cfg, li):
            y, ss[li] = slstm_block(lp["slstm"], h, cfg, state=ss[li])
        else:
            y, ms[li] = mlstm_block(lp["mlstm"], h, cfg, state=ms[li])
        x = x + y
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.dot(x, params["lm_head"]["w"], preferred_element_type=jnp.float32)
    return logits, XLSTMCache(ms, ss, jnp.asarray(s, jnp.int32))


def decode_step(params: dict, cache: XLSTMCache, token: jax.Array, cfg: ModelConfig) -> tuple:
    x = params["embed"]["table"][token[:, None]]
    ms, ss = list(cache.mlstm), list(cache.slstm)
    for li, lp in enumerate(params["layers"]):
        h = L.rms_norm(lp["norm"], x, cfg.norm_eps)
        if is_slstm(cfg, li):
            y, ss[li] = slstm_block(lp["slstm"], h, cfg, state=ss[li])
        else:
            y, ms[li] = mlstm_block(lp["mlstm"], h, cfg, state=ms[li], single_step=True)
        x = x + y
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.dot(x, params["lm_head"]["w"], preferred_element_type=jnp.float32)[:, 0]
    return logits, XLSTMCache(ms, ss, cache.length + 1)
