"""Whisper-style encoder-decoder transformer (audio backbone).

Per the assignment, only the transformer BACKBONE is modeled: the conv
mel-spectrogram frontend is a STUB — `input_specs()` feeds precomputed
frame embeddings (B, encoder_seq, D) directly to the encoder (the shape
the two stride-2 convs would produce: 1500 frames for 30 s audio).

Structure (Radford et al. 2022): pre-LN transformer, learned/sinusoidal
positions, encoder bidirectional self-attn, decoder causal self-attn +
cross-attn, GELU MLPs, LayerNorm (not RMSNorm), tied unembedding.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.layers import AttnSpec, shard

__all__ = ["init_params", "encode", "forward", "init_cache", "prefill", "decode_step"]


def _spec(cfg: ModelConfig, causal: bool) -> AttnSpec:
    return AttnSpec(
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim,
        causal=causal,
        chunk=cfg.attn_chunk,
        impl=cfg.attn_impl,
    )


def _sinusoids(length: int, channels: int) -> jax.Array:
    half = channels // 2
    log_timescale = jnp.log(10_000.0) / (half - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(half, dtype=jnp.float32))
    t = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(t), jnp.cos(t)], axis=1)


def init_enc_layer(key, cfg: ModelConfig, dt) -> dict:
    ka, km = jax.random.split(key)
    return {
        "attn_norm": L.init_layernorm(cfg.d_model, dt),
        "attn": L.init_attention(ka, cfg.d_model, _spec(cfg, False), dt, True),
        "mlp_norm": L.init_layernorm(cfg.d_model, dt),
        "mlp": L.init_mlp_gelu(km, cfg.d_model, cfg.d_ff, dt),
    }


def init_dec_layer(key, cfg: ModelConfig, dt) -> dict:
    ka, kx, km = jax.random.split(key, 3)
    return {
        "self_norm": L.init_layernorm(cfg.d_model, dt),
        "self_attn": L.init_attention(ka, cfg.d_model, _spec(cfg, True), dt, True),
        "cross_norm": L.init_layernorm(cfg.d_model, dt),
        "cross_attn": L.init_attention(kx, cfg.d_model, _spec(cfg, False), dt, True),
        "mlp_norm": L.init_layernorm(cfg.d_model, dt),
        "mlp": L.init_mlp_gelu(km, cfg.d_model, cfg.d_ff, dt),
    }


def init_params(key, cfg: ModelConfig) -> dict:
    n_enc = cfg.encoder_layers
    keys = jax.random.split(key, n_enc + cfg.num_layers + 3)
    dt = jnp.dtype(cfg.dtype)
    return {
        "embed": {"table": L.embed_init(keys[0], (cfg.vocab_size, cfg.d_model), dt)},
        "enc_layers": [init_enc_layer(keys[1 + i], cfg, dt) for i in range(n_enc)],
        "enc_norm": L.init_layernorm(cfg.d_model, dt),
        "dec_layers": [
            init_dec_layer(keys[1 + n_enc + i], cfg, dt) for i in range(cfg.num_layers)
        ],
        "dec_norm": L.init_layernorm(cfg.d_model, dt),
        "dec_pos": L.embed_init(keys[-1], (448, cfg.d_model), dt),  # whisper max targets
    }


def encode(params: dict, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames: (B, S_enc, D) stub conv-frontend output -> encoder states."""
    b, s, d = frames.shape
    x = frames + _sinusoids(s, d).astype(frames.dtype)[None]
    x = shard(x, "batch", "seq", None)
    spec = _spec(cfg, causal=False)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    for lp in params["enc_layers"]:
        h = L.layer_norm(lp["attn_norm"], x, cfg.norm_eps)
        q, k, v = L.qkv_proj(lp["attn"], h, spec)
        x = x + L.attention_out(lp["attn"], L.attention(q, k, v, spec, pos[0], pos[0]))
        h = L.layer_norm(lp["mlp_norm"], x, cfg.norm_eps)
        x = x + L.mlp_gelu(lp["mlp"], h)
        x = shard(x, "batch", "seq", None)
    return L.layer_norm(params["enc_norm"], x, cfg.norm_eps)


def _dec_positions(cfg: ModelConfig, start: jax.Array, length: int, b: int):
    pos = start + jnp.arange(length, dtype=jnp.int32)
    return jnp.broadcast_to(pos, (b, length))


def _dec_pos_embed(params: dict, pos: jax.Array) -> jax.Array:
    table = params["dec_pos"]
    return table[pos % table.shape[0]]  # wrap beyond whisper's 448 for long shapes


def forward(
    params: dict,
    tokens: jax.Array,
    cfg: ModelConfig,
    *,
    encoder_frames: jax.Array = None,
    **_,
) -> tuple:
    """Teacher-forced decoder over stub-encoded audio."""
    b, s = tokens.shape
    if encoder_frames is None:
        dt = jnp.dtype(cfg.dtype)
        encoder_frames = jnp.zeros((b, cfg.encoder_seq, cfg.d_model), dt)
    enc = encode(params, encoder_frames, cfg)
    enc_pos = jnp.arange(enc.shape[1], dtype=jnp.int32)

    x = params["embed"]["table"][tokens]
    pos = _dec_positions(cfg, jnp.asarray(0, jnp.int32), s, b)
    x = x + _dec_pos_embed(params, pos)
    x = shard(x, "batch", "seq", None)
    self_spec = _spec(cfg, causal=True)
    cross_spec = _spec(cfg, causal=False)

    for lp in params["dec_layers"]:
        h = L.layer_norm(lp["self_norm"], x, cfg.norm_eps)
        q, k, v = L.qkv_proj(lp["self_attn"], h, self_spec)
        x = x + L.attention_out(
            lp["self_attn"], L.attention(q, k, v, self_spec, pos[0], pos[0])
        )
        h = L.layer_norm(lp["cross_norm"], x, cfg.norm_eps)
        q, _, _ = L.qkv_proj(lp["cross_attn"], h, cross_spec)
        _, ck, cv = L.qkv_proj(lp["cross_attn"], enc, cross_spec)
        x = x + L.attention_out(
            lp["cross_attn"], L.attention(q, ck, cv, cross_spec, pos[0], enc_pos)
        )
        h = L.layer_norm(lp["mlp_norm"], x, cfg.norm_eps)
        x = x + L.mlp_gelu(lp["mlp"], h)
        x = shard(x, "batch", "seq", None)

    x = L.layer_norm(params["dec_norm"], x, cfg.norm_eps)
    logits = jnp.dot(
        x, params["embed"]["table"].T, preferred_element_type=jnp.float32
    )  # tied
    return shard(logits, "batch", "seq", "vocab"), {}


class WhisperCache(NamedTuple):
    self_k: list  # (B, S_max, Hkv, hd) per decoder layer
    self_v: list
    cross_k: list  # (B, S_enc, Hkv, hd) — computed once at prefill
    cross_v: list
    length: jax.Array


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> WhisperCache:
    dt = jnp.dtype(cfg.dtype)
    kshape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    xshape = (batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim)
    n = cfg.num_layers
    return WhisperCache(
        self_k=[jnp.zeros(kshape, dt) for _ in range(n)],
        self_v=[jnp.zeros(kshape, dt) for _ in range(n)],
        cross_k=[jnp.zeros(xshape, dt) for _ in range(n)],
        cross_v=[jnp.zeros(xshape, dt) for _ in range(n)],
        length=jnp.asarray(0, jnp.int32),
    )


def prefill(
    params: dict,
    tokens: jax.Array,
    cfg: ModelConfig,
    max_len: int,
    *,
    encoder_frames: jax.Array = None,
) -> tuple:
    b, s = tokens.shape
    dt = jnp.dtype(cfg.dtype)
    if encoder_frames is None:
        encoder_frames = jnp.zeros((b, cfg.encoder_seq, cfg.d_model), dt)
    enc = encode(params, encoder_frames, cfg)
    enc_pos = jnp.arange(enc.shape[1], dtype=jnp.int32)

    x = params["embed"]["table"][tokens]
    pos = _dec_positions(cfg, jnp.asarray(0, jnp.int32), s, b)
    x = x + _dec_pos_embed(params, pos)
    self_spec = _spec(cfg, causal=True)
    cross_spec = _spec(cfg, causal=False)

    sk, sv, xk, xv = [], [], [], []
    for lp in params["dec_layers"]:
        h = L.layer_norm(lp["self_norm"], x, cfg.norm_eps)
        q, k, v = L.qkv_proj(lp["self_attn"], h, self_spec)
        x = x + L.attention_out(
            lp["self_attn"], L.attention(q, k, v, self_spec, pos[0], pos[0])
        )
        pad = max_len - s
        sk.append(jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))))
        sv.append(jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))))
        h = L.layer_norm(lp["cross_norm"], x, cfg.norm_eps)
        q, _, _ = L.qkv_proj(lp["cross_attn"], h, cross_spec)
        _, ck, cv = L.qkv_proj(lp["cross_attn"], enc, cross_spec)
        xk.append(ck)
        xv.append(cv)
        x = x + L.attention_out(
            lp["cross_attn"], L.attention(q, ck, cv, cross_spec, pos[0], enc_pos)
        )
        h = L.layer_norm(lp["mlp_norm"], x, cfg.norm_eps)
        x = x + L.mlp_gelu(lp["mlp"], h)

    x = L.layer_norm(params["dec_norm"], x, cfg.norm_eps)
    logits = jnp.dot(x, params["embed"]["table"].T, preferred_element_type=jnp.float32)
    return logits, WhisperCache(sk, sv, xk, xv, jnp.asarray(s, jnp.int32))


def decode_step(params: dict, cache: WhisperCache, token: jax.Array, cfg: ModelConfig) -> tuple:
    b = token.shape[0]
    x = params["embed"]["table"][token[:, None]]
    pos = jnp.broadcast_to(cache.length, (b,))
    x = x + _dec_pos_embed(params, pos[:, None])
    self_spec = _spec(cfg, causal=True)
    cross_spec = _spec(cfg, causal=False)

    sk, sv = list(cache.self_k), list(cache.self_v)
    for li, lp in enumerate(params["dec_layers"]):
        h = L.layer_norm(lp["self_norm"], x, cfg.norm_eps)
        attn_out, nk, nv = L.decode_attention(
            lp["self_attn"], h, sk[li], sv[li], pos, self_spec, rope_theta=0.0
        )
        sk[li], sv[li] = nk, nv
        x = x + attn_out

        h = L.layer_norm(lp["cross_norm"], x, cfg.norm_eps)
        q, _, _ = L.qkv_proj(lp["cross_attn"], h, cross_spec)
        groups = cross_spec.num_heads // cross_spec.num_kv_heads
        kk = jnp.repeat(cache.cross_k[li], groups, axis=2)
        vv = jnp.repeat(cache.cross_v[li], groups, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kk, preferred_element_type=jnp.float32)
        s = s * (cross_spec.head_dim ** -0.5)
        p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, vv, preferred_element_type=jnp.float32)
        x = x + L.attention_out(lp["cross_attn"], o.astype(x.dtype))

        h = L.layer_norm(lp["mlp_norm"], x, cfg.norm_eps)
        x = x + L.mlp_gelu(lp["mlp"], h)

    x = L.layer_norm(params["dec_norm"], x, cfg.norm_eps)
    logits = jnp.dot(x, params["embed"]["table"].T, preferred_element_type=jnp.float32)[:, 0]
    return logits, WhisperCache(sk, sv, cache.cross_k, cache.cross_v, cache.length + 1)
