"""Interactive-style exploration: several matching queries on one dataset,
including target shapes from the paper (uniform target, explicit vector
target), a comparison of all engine variants on one query, and the
PR-9 pluggable-metric layer — a chi-square top-k server and a tolerant
closeness test sharing a top-k query's sample stream.

  PYTHONPATH=src python examples/census_explore.py
"""

import numpy as np

from repro.core.engine import VARIANTS, EngineConfig, run_engine
from repro.core.histsim import HistSimParams
from repro.data.layout import block_layout
from repro.data.synth import SynthSpec, make_dataset
from repro.serve.fastmatch_server import MatchServer


def main():
    spec = SynthSpec(
        v_z=191, v_x=5, num_tuples=5_000_000, k=10, n_close=10,
        close_distance=0.015, far_distance=0.3, zipf_a=0.9, seed=2,
    )
    print("generating POLICE-like dataset (191 candidates, 5 groups) ...")
    ds = make_dataset(spec)
    blocked = block_layout(ds.z, ds.x, v_z=spec.v_z, v_x=spec.v_x, seed=2)
    params = HistSimParams(v_z=spec.v_z, v_x=spec.v_x, k=10, eps=0.06, delta=0.01)

    # --- query 1: match the planted target (paper's "closest to target") ---
    res = run_engine(blocked, ds.target, params, EngineConfig(variant="fastmatch"))
    print(f"\n[q1: planted target]  ids={sorted(res.ids.tolist())} "
          f"blocks={res.blocks_read}/{blocked.num_blocks}")

    # --- query 2: uniform target (paper's POLICE-q1/q2 setup) ---
    uniform = np.full(spec.v_x, 1.0 / spec.v_x)
    res_u = run_engine(blocked, uniform, params, EngineConfig(variant="fastmatch"))
    true_u = np.argsort(np.abs(ds.true_hists - uniform[None]).sum(axis=1))[:10]
    print(f"[q2: uniform target]  ids={sorted(res_u.ids.tolist())} "
          f"truth={sorted(true_u.tolist())} blocks={res_u.blocks_read}")

    # --- query 3: explicit target vector (paper FLIGHTS-q3 style) ---
    explicit = np.asarray([0.4, 0.3, 0.15, 0.1, 0.05])
    res_e = run_engine(blocked, explicit, params, EngineConfig(variant="fastmatch"))
    print(f"[q3: explicit vector] ids={sorted(res_e.ids.tolist())} blocks={res_e.blocks_read}")

    # --- all variants on q1 ---
    print("\nvariant comparison on q1:")
    for variant in VARIANTS:
        cfg = EngineConfig(variant=variant, seed=1)
        r = run_engine(blocked, ds.target, params, cfg)
        print(f"  {variant:10s} blocks={r.blocks_read:6d} rounds={r.rounds:5d} "
              f"wall={r.wall_time_s:6.2f}s exact={r.exact}")

    # --- query 4: chi-square metric (pluggable-metric layer) ---
    # Same dataset, same counts machinery — only the registry distance
    # the shared tau pass computes changes. chi2 taus live in [0, 2] and
    # route through a conservative bound (core/bounds.py), so give the
    # query a wider radius than the l1 eps.
    print("\n[q4: chi-square top-k] serving with metric='chi2' ...")
    srv_chi = MatchServer(blocked, max_queries=2, lookahead=512, metric="chi2")
    rid = srv_chi.submit(ds.target, k=10, eps=0.15, delta=0.01)
    res_chi = srv_chi.run_until_idle()[rid]
    q = ds.target / ds.target.sum()
    s_ = ds.true_hists + q[None, :]
    d_ = ds.true_hists - q[None, :]
    chi_true = np.where(s_ > 0, d_ * d_ / np.where(s_ > 0, s_, 1), 0).sum(1)
    print(f"  ids={sorted(res_chi.ids.tolist())} "
          f"truth={sorted(np.argsort(chi_true)[:10].tolist())} "
          f"blocks={res_chi.blocks_read} exact={res_chi.exact}")

    # --- query 5: closeness test riding a top-k query's samples -------
    # A distribution-testing query through the same queue: label every
    # candidate within eps of the target as close, everything beyond
    # eps + gap as far (labels inside the gap are unconstrained). It
    # shares the counts matrix with the concurrent top-k query, so the
    # pair costs barely more I/O than either alone.
    print("\n[q5: mixed top-k + closeness on one stream]")
    srv = MatchServer(blocked, max_queries=2, lookahead=512)
    rid_top = srv.submit(ds.target, k=10, eps=0.06, delta=0.01)
    rid_close = srv.submit_closeness(ds.target, eps=0.08, gap=0.15, delta=0.01)
    res = srv.run_until_idle()
    rt, rc = res[rid_top], res[rid_close]
    n_true_close = int((ds.true_dists <= 0.08).sum())
    print(f"  top-k:     ids={sorted(rt.ids.tolist())} tuples={rt.tuples_read}")
    print(f"  closeness: {len(rc.ids)} candidates labeled close "
          f"(truth: {n_true_close} within eps) tuples={rc.tuples_read}")
    print(f"  shared-stream total reads: {srv.scheduler.tuples_read}")


if __name__ == "__main__":
    main()
