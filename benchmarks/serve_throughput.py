"""Serving throughput: `MatchServer` vs one `run_engine` per query.

The acceptance measurement for the multi-query serving subsystem: N = 8
concurrent queries over the same dataset must read FEWER total tuples
through the shared-counts scheduler than 8 sequential `run_engine`
calls, with identical top-k accuracy against planted ground truth.

Reported rows (benchmarks/run.py CSV schema):

  serve_solo_total      — us per solo batch, derived = total tuples read
  serve_shared_total    — us per served batch, derived = total tuples read
  serve_io_amortization — derived = solo_tuples / shared_tuples (>1 = win)
  serve_qps             — derived = queries/sec through the server
  serve_accuracy        — derived = "shared_acc/solo_acc" top-k recall
  serve_late_query      — derived = new tuples read for a warm-cache query
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import EPS_DEFAULT
from repro.core.engine import EngineConfig, run_engine
from repro.core.histsim import HistSimParams
from repro.data.layout import block_layout
from repro.data.synth import SynthSpec, make_dataset, perturb_distribution
from repro.serve.fastmatch_server import MatchServer

N_QUERIES = 8
K = 10
DELTA = 0.01
EPS = max(EPS_DEFAULT, 0.07)

SPEC = SynthSpec(
    v_z=161, v_x=24, num_tuples=6_000_000, k=K, n_close=10,
    close_distance=0.02, far_distance=0.3, zipf_a=1.0, close_rank="head", seed=42,
)


def _targets(ds, n: int):
    """n distinct targets near the dataset's base target."""
    rng = np.random.default_rng(7)
    out = [ds.target]
    for d in np.linspace(0.004, 0.04, n - 1):
        out.append(perturb_distribution(ds.target, d, rng))
    return out


def _true_top_k(ds, target, k: int) -> set:
    dists = np.abs(ds.true_hists - np.asarray(target)[None, :]).sum(axis=1)
    return set(np.argsort(dists, kind="stable")[:k].tolist())


def _recall(ids, truth: set) -> float:
    return len(set(ids.tolist()) & truth) / len(truth)


def run(rows: list) -> None:
    ds = make_dataset(SPEC)
    blocked = block_layout(ds.z, ds.x, v_z=SPEC.v_z, v_x=SPEC.v_x, block_size=512, seed=42)
    targets = _targets(ds, N_QUERIES)
    params = HistSimParams(v_z=SPEC.v_z, v_x=SPEC.v_x, k=K, eps=EPS, delta=DELTA)

    # jit warmup for both paths (compile ingest/stats/marking once)
    run_engine(blocked, targets[0], params,
               EngineConfig(variant="fastmatch", seed=999, max_rounds=1))
    warm = MatchServer(blocked, max_queries=N_QUERIES, lookahead=512, seed=999)
    warm.submit(targets[0], k=K, eps=EPS, delta=DELTA)
    warm.run_until_idle(max_rounds=1)

    # -- solo: one engine per query -------------------------------------
    t0 = time.perf_counter()
    solo = [
        run_engine(blocked, t, params, EngineConfig(variant="fastmatch", seed=100 + i))
        for i, t in enumerate(targets)
    ]
    solo_wall = time.perf_counter() - t0
    solo_tuples = sum(r.tuples_read for r in solo)

    # -- shared: one MatchServer, all queries concurrent ----------------
    server = MatchServer(blocked, max_queries=N_QUERIES, lookahead=512, seed=200)
    t0 = time.perf_counter()
    rids = [server.submit(t, k=K, eps=EPS, delta=DELTA) for t in targets]
    results = server.run_until_idle()
    shared_wall = time.perf_counter() - t0
    shared_tuples = server.metrics["total_tuples_read"]

    truths = [_true_top_k(ds, t, K) for t in targets]
    solo_acc = float(np.mean([_recall(r.ids, tr) for r, tr in zip(solo, truths)]))
    shared_acc = float(np.mean(
        [_recall(results[rid].ids, tr) for rid, tr in zip(rids, truths)]
    ))

    # -- late query against the warm cache ------------------------------
    before = server.metrics["total_tuples_read"]
    late = server.submit(targets[1], k=K, eps=EPS, delta=DELTA)
    server.run_until_idle()[late]
    late_tuples = server.metrics["total_tuples_read"] - before

    rows.append(dict(name="serve_solo_total",
                     us_per_call=1e6 * solo_wall, derived=solo_tuples))
    rows.append(dict(name="serve_shared_total",
                     us_per_call=1e6 * shared_wall, derived=int(shared_tuples)))
    rows.append(dict(name="serve_io_amortization", us_per_call=0.0,
                     derived=round(solo_tuples / max(shared_tuples, 1), 2)))
    rows.append(dict(name="serve_qps", us_per_call=1e6 * shared_wall / N_QUERIES,
                     derived=round(N_QUERIES / shared_wall, 2)))
    rows.append(dict(name="serve_accuracy", us_per_call=0.0,
                     derived=f"{shared_acc:.3f}/{solo_acc:.3f}"))
    rows.append(dict(name="serve_late_query", us_per_call=0.0, derived=int(late_tuples)))

    ok = shared_tuples < solo_tuples and shared_acc >= solo_acc
    print(f"# serve_throughput: shared={int(shared_tuples):,} tuples vs "
          f"solo={solo_tuples:,} ({solo_tuples / max(shared_tuples, 1):.1f}x), "
          f"recall {shared_acc:.3f} vs {solo_acc:.3f} -> {'PASS' if ok else 'FAIL'}")


if __name__ == "__main__":
    rows: list = []
    run(rows)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
