"""§Perf optimization paths: bit-exactness and fallback behavior.

Every flag-gated optimization must match the baseline math on CPU (no
mesh): grouped-GQA attention, flash-decoding decode path, local MoE
dispatch, bf16 boundaries (tolerance), matmul-form histogram.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.kernels import ops, ref
from repro.models import layers as L
from repro.models.model_zoo import get_model


class TestGroupedGQA:
    @pytest.mark.parametrize("arch", ["granite_8b", "mixtral_8x7b", "llama3_405b"])
    def test_forward_bit_exact(self, arch):
        cfg = get_smoke_config(arch)
        m1 = get_model(cfg)
        m2 = get_model(dataclasses.replace(cfg, attn_gqa_grouped=True))
        params = m1.init(jax.random.PRNGKey(0))
        tok = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab_size)
        l1, _ = m1.forward(params, tok)
        l2, _ = m2.forward(params, tok)
        np.testing.assert_array_equal(np.asarray(l1, np.float32), np.asarray(l2, np.float32))

    def test_chunked_grouped_matches_chunked(self):
        cfg = get_smoke_config("granite_8b")
        m1 = get_model(dataclasses.replace(cfg, attn_impl="chunked", attn_chunk=8))
        m2 = get_model(
            dataclasses.replace(cfg, attn_impl="chunked", attn_chunk=8, attn_gqa_grouped=True)
        )
        params = m1.init(jax.random.PRNGKey(0))
        tok = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab_size)
        l1, _ = m1.forward(params, tok)
        l2, _ = m2.forward(params, tok)
        np.testing.assert_array_equal(np.asarray(l1, np.float32), np.asarray(l2, np.float32))


class TestFlashDecodingPath:
    def test_decode_bit_exact(self):
        cfg = get_smoke_config("llama3_405b")
        m1 = get_model(cfg)
        m2 = get_model(dataclasses.replace(cfg, decode_seq_shard=True))
        params = m1.init(jax.random.PRNGKey(0))
        tok = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
        _, cache = m1.prefill(params, tok[:, :6], 12)
        l1, _ = m1.decode_step(params, cache, tok[:, 6])
        l2, _ = m2.decode_step(params, cache, tok[:, 6])
        np.testing.assert_array_equal(np.asarray(l1, np.float32), np.asarray(l2, np.float32))


class TestLocalMoE:
    def test_no_mesh_fallback_matches_gather(self):
        cfg = get_smoke_config("mixtral_8x7b")
        m1 = get_model(cfg)
        m2 = get_model(dataclasses.replace(cfg, moe_impl="local"))
        params = m1.init(jax.random.PRNGKey(0))
        tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
        l1, _ = m1.forward(params, tok)
        l2, _ = m2.forward(params, tok)
        np.testing.assert_array_equal(np.asarray(l1, np.float32), np.asarray(l2, np.float32))


class TestBF16Boundaries:
    def test_close_to_f32_baseline(self):
        cfg = get_smoke_config("granite_8b")
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
        l1, _ = model.forward(params, tok)
        try:
            L.set_tp_reduce_dtype(jnp.bfloat16)
            l2, _ = model.forward(params, tok)
        finally:
            L.set_tp_reduce_dtype(None)
        np.testing.assert_allclose(
            np.asarray(l1, np.float32), np.asarray(l2, np.float32), atol=0.25
        )


class TestMatmulHistogram:
    @pytest.mark.parametrize("v_z,v_x,n", [(161, 24, 5000), (472, 128, 3000), (16, 4, 99)])
    def test_matches_scatter_ref(self, v_z, v_x, n, rng):
        z = jnp.asarray(rng.integers(-1, v_z, n), jnp.int32)
        x = jnp.asarray(rng.integers(-1, v_x, n), jnp.int32)
        a = ref.histogram_matmul(z, x, v_z=v_z, v_x=v_x, chunk=512)
        b = ref.histogram_ref(z, x, v_z=v_z, v_x=v_x)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))

    def test_bf16_onehot_exact_counts(self, rng):
        z = jnp.asarray(rng.integers(0, 50, 4000), jnp.int32)
        x = jnp.asarray(rng.integers(0, 7, 4000), jnp.int32)
        a = ref.histogram_matmul(z, x, v_z=50, v_x=7, onehot_dtype=jnp.bfloat16)
        b = ref.histogram_ref(z, x, v_z=50, v_x=7)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))  # exact: 0/1 x f32 acc

    def test_ops_dispatch(self, rng):
        z = jnp.asarray(rng.integers(0, 10, 100), jnp.int32)
        x = jnp.asarray(rng.integers(0, 5, 100), jnp.int32)
        a = ops.histogram(z, x, v_z=10, v_x=5, impl="matmul")
        b = ops.histogram(z, x, v_z=10, v_x=5, impl="ref")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
