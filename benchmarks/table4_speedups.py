"""Table 4: variant speedups over Scan (wall-clock + blocks-read ratios).

Paper claim being reproduced: Scan >> SlowMatch >= ScanMatch >= SyncMatch
>= FastMatch in latency, with FastMatch consistently near-interactive;
speedups of 7x-136x on I/O-bound hardware. On this box the exact ratios
differ (CPU compute vs the paper's disk/memory I/O), so we report BOTH
wall time and the machine-independent tuples-read fraction.
"""

from __future__ import annotations

from benchmarks.common import QUERIES, delta_d, get_query, run_variant

VARIANTS = ("slowmatch", "scanmatch", "syncmatch", "fastmatch")


def run(csv_rows: list) -> None:
    for q in QUERIES:
        scan_res, scan_wall, ds = run_variant(q, "scan")
        spec, _, blocked = get_query(q)
        for variant in VARIANTS:
            if variant == "syncmatch" and spec.v_z > 1000:
                # paper: SyncMatch pathological on TAXI (0.14x); cap rounds
                res, wall, _ = run_variant(q, variant)
            else:
                res, wall, _ = run_variant(q, variant)
            csv_rows.append(
                dict(
                    name=f"table4.{q}.{variant}",
                    us_per_call=wall * 1e6,
                    derived=(
                        f"speedup={scan_wall / wall:.2f}x"
                        f" tuples_frac={res.tuples_read / blocked.num_tuples:.3f}"
                        f" blocks_frac={res.blocks_read / blocked.num_blocks:.3f}"
                        f" exact={int(res.exact)} delta_d={delta_d(res, ds):.4f}"
                    ),
                )
            )
        csv_rows.append(
            dict(
                name=f"table4.{q}.scan",
                us_per_call=scan_wall * 1e6,
                derived="speedup=1.00x tuples_frac=1.000 blocks_frac=1.000 exact=1 delta_d=0.0",
            )
        )
