"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1; unverified].

Adafactor (314B total params; see DESIGN.md Sec 7 memory budget).
"""

from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch_id="grok_1_314b",
        family="moe",
        num_layers=64,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=32768,
        vocab_size=131072,
        num_experts=8,
        experts_per_token=2,
        rope_theta=1e4,
        norm_eps=1e-5,
        optimizer="adafactor",
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="grok_1_314b_smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=192,
        vocab_size=512,
        num_experts=4,
        experts_per_token=2,
        expert_capacity_factor=4.0,  # dropless in smoke tests
    )
