"""The FastMatch engine: HistSim + block policies + lookahead staleness.

This is the executable analogue of the paper's Figure 5 architecture,
mapped onto a device-resident execution model:

  I/O manager        — a pluggable `repro.io.BlockSource`: where window
                       block data comes from. `InMemorySource` (device-
                       or host-resident arrays), `ShardedSource` (one
                       data-parallel worker's contiguous range), and
                       `PrefetchSource` — a double-buffered background
                       thread that gathers window t+1 while the device
                       runs round t, the paper's "sampling engine must
                       never stall the statistics engine" made literal.
  sampling engine    — AnyActive marking of a lookahead window against
                       the packed bitmap, using the FRESHEST statistics
                       posted so far. Staleness is now a dial, not an
                       accident of the loop: marking, ingest, stats and
                       the read bookkeeping are ONE jitted
                       `multiquery.fused_round`, and the host polls the
                       device only every ``poll_every`` windows. The
                       paper's Sec 4.2 relaxation (statistics one window
                       stale) is ``poll_every=1``; larger values bound
                       retirement/admission staleness by ``poll_every``
                       windows and cut device↔host round-trips by the
                       same factor (`SharedCountsScheduler.host_syncs`
                       counts them; benchmarks/serve_throughput.py
                       reports the ratio).
  statistics engine  — the jitted HistSim ingest+stats round, vmapped
                       over query slots. On a mesh the SAME round runs
                       candidate-sharded (counts P("model", None), one
                       psum per round) via the unified
                       `repro.core.distributed.make_distributed_round`
                       over `MultiQueryState` — single-query and
                       N-query, one device and many, are one loop.

Variants (paper Sec 5.2) are configuration points of this single engine:

  variant     policy      lookahead   stats cadence        criterion
  ---------   ---------   ---------   ------------------   ---------
  fastmatch   anyactive   L (512)     once per window      histsim
  syncmatch   anyactive   1           once per block       histsim
  scanmatch   scan        L           once per window      histsim
  slowmatch   scan        L           once per window      slowmatch
  scan        scan        —           exact full pass      —

Sampling is WITHOUT replacement from a random start position in the
pre-shuffled layout. A pass visits every not-yet-read block in cyclic
order; AnyActive may skip blocks, and skipped blocks remain eligible for
later passes (candidates can re-activate when the split point moves).
If a whole pass reads nothing and HistSim still has not terminated, the
engine completes exactly (reads the remainder) — at that point empirical
counts equal the true ones and the guarantees hold deterministically.
The Scan baseline IS that completion path on a fresh scheduler
(`SharedCountsScheduler.complete_remaining`), not a separate loop.

The window-marking/ingest loop itself lives in `repro.core.multiquery`
(`SharedCountsScheduler`): `run_engine` is its ``max_queries=1``
specialization, and the N-query serving frontend over the same loop is
`repro.serve.fastmatch_server.MatchServer`.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.core.histsim import HistSimParams, HistSimState
from repro.core.multiquery import MultiQuerySpec, QueryOutcome, SharedCountsScheduler
from repro.io import PrefetchSource, as_block_source

__all__ = ["EngineConfig", "MatchResult", "run_engine", "VARIANTS"]

VARIANTS = ("fastmatch", "syncmatch", "scanmatch", "slowmatch", "scan")

# The paper's Scan baseline reads the heap in big sequential chunks; at
# 512-tuple blocks this is ~2M tuples per ingest dispatch.
_SCAN_CHUNK_BLOCKS = 4096


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    variant: str = "fastmatch"
    lookahead: int = 512
    seed: int = 0
    max_rounds: int = 1_000_000
    max_passes: int = 4
    start_block: Optional[int] = None  # None -> random
    # Device↔host decoupling: poll termination/counters every this many
    # windows (1 = the paper's per-window cadence). prefetch=True wraps
    # the block source in a background-thread double buffer.
    poll_every: int = 1
    prefetch: bool = False

    def __post_init__(self):
        if self.variant not in VARIANTS:
            raise ValueError(f"unknown variant {self.variant!r}")
        if self.poll_every < 1:
            raise ValueError(f"need poll_every >= 1, got {self.poll_every}")

    @property
    def policy(self) -> str:
        return "anyactive" if self.variant in ("fastmatch", "syncmatch") else "scan"

    @property
    def window(self) -> int:
        return 1 if self.variant == "syncmatch" else self.lookahead

    @property
    def criterion(self) -> str:
        return "slowmatch" if self.variant == "slowmatch" else "histsim"


@dataclasses.dataclass
class MatchResult:
    ids: np.ndarray  # (k,) matching candidate ids, closest first
    state: HistSimState
    rounds: int
    blocks_read: int
    blocks_considered: int
    tuples_read: int
    wall_time_s: float
    exact: bool  # True iff the answer rests on a COMPLETE read of the data
    passes: int
    # I/O degradation contract (see QueryOutcome): when blocks were
    # quarantined, ``exact`` means complete over the SURVIVING block
    # population and ``eps_effective`` is the widened full-data bound.
    degraded: bool = False
    eps_effective: float = float("nan")
    # Query type this result answers: "topk" (ids = the k matches) or
    # "closeness" (ids = every candidate labeled close, tau order).
    qtype: str = "topk"
    # SLA early stop (see multiquery.StopPolicy): True when a stop
    # policy or supervisor deadline retired the query before its
    # statistical bound fired — the result is the honest anytime
    # answer at that poll (exact=False, achieved delta_upper).
    stopped: bool = False
    stop_reason: str = ""  # "confidence" | "tuples" | "wall_ms" | "deadline"

    @property
    def delta_upper(self) -> float:
        return float(self.state.delta_upper)


def _to_match_result(out: QueryOutcome, t0: float) -> MatchResult:
    return MatchResult(
        ids=out.ids,
        state=out.state,
        rounds=out.rounds,
        blocks_read=out.blocks_read,
        blocks_considered=out.blocks_considered,
        tuples_read=out.tuples_read,
        wall_time_s=time.perf_counter() - t0,
        exact=out.exact,
        passes=out.passes,
        degraded=out.degraded,
        eps_effective=out.eps_effective,
        qtype=out.qtype,
        stopped=out.stopped,
        stop_reason=out.stop_reason,
    )


def run_engine(
    dataset,
    target: np.ndarray,
    params: HistSimParams,
    config: EngineConfig = EngineConfig(),
) -> MatchResult:
    """Run one matching query to termination. Returns the top-k + stats.

    ``dataset`` is a `BlockedDataset` or any `repro.io.BlockSource`.

    This is the ``max_queries=1`` specialization of the shared
    window-marking/ingest loop (`multiquery.SharedCountsScheduler`);
    `MatchServer` runs the same loop with many concurrent queries.

    ``exact`` in the result means what the docstring says: True iff the
    answer rests on a complete read of the dataset (either the exact
    fallback fired, or sampling happened to exhaust every block). A
    ``max_rounds`` budget cut returns the best-effort sampled answer
    with ``exact=False`` — it never silently completes the scan.
    """
    source = as_block_source(dataset)
    if params.v_z != source.v_z or params.v_x != source.v_x:
        raise ValueError("params/dataset dimension mismatch")
    if config.criterion != params.criterion:
        params = dataclasses.replace(params, criterion=config.criterion)
    if config.prefetch and not isinstance(source, PrefetchSource):
        source = PrefetchSource(source)

    t0 = time.perf_counter()
    # The single query's k is static here, so it doubles as the top_k
    # selection cap in the deviation assignment.
    spec = MultiQuerySpec(
        v_z=params.v_z, v_x=params.v_x, max_queries=1, criterion=params.criterion,
        k_cap=params.k,
    )

    if config.variant == "scan":
        # The paper's Scan baseline: the exact-completion path of the one
        # loop, run immediately on a fresh scheduler (complete heap read,
        # exact answer by construction).
        sched = SharedCountsScheduler(
            source, spec, policy="scan", window=_SCAN_CHUNK_BLOCKS, seed=config.seed,
            start_block=0,
        )
        sched.admit(target, k=params.k, eps=params.eps, delta=params.delta)
        sched.complete_remaining()
        fired = bool(sched._delta_upper[0] < params.delta)
        out = sched.retire(0, exact=True, terminated=fired)
        return _to_match_result(out, t0)

    sched = SharedCountsScheduler(
        source,
        spec,
        policy=config.policy,
        window=config.window,
        seed=config.seed,
        start_block=config.start_block,
        poll_every=config.poll_every,
    )
    qid = sched.admit(target, k=params.k, eps=params.eps, delta=params.delta)
    sched.pump(max_rounds=config.max_rounds, max_passes=config.max_passes)
    if qid not in sched.outcomes:
        # max_rounds budget cut: best-effort sampled answer, NOT exact.
        out = sched.retire(0, exact=False, terminated=False)
    else:
        out = sched.outcomes[qid]
    return _to_match_result(out, t0)
