"""Crash-recovery / QoS supervision of a `MatchServer` loop.

`MatchServer` owns correctness of the answers; `ServeSupervisor` owns
liveness of the service. It wraps the incremental serving loop
(`MatchServer.step`) with the three policies a long-running deployment
needs and the server itself deliberately does not hard-code:

  per-query deadlines — a request carries an optional wall deadline.
      A query still QUEUED at its deadline is shed (it never consumed
      I/O); a query already LIVE is early-retired with its current
      best-effort answer — the degradation contract: a looser
      guarantee beats blocking forever (the retired `MatchResult`
      carries ``exact=False``/``terminated`` honestly).
  overload shedding — a bounded admission queue. When ``max_queue``
      pending requests are already waiting, new submissions are shed
      at the door with an explicit outcome instead of growing the
      queue without bound; shed requests are listed in ``shed`` with a
      reason, never silently dropped.
  crash recovery — an unrecoverable round failure (a poisoned device
      loop, `repro.io.faults.UnrecoverableIOError`, anything a retry
      cannot heal) discards the wounded server, rebuilds it, restores
      the last `CheckpointManager` snapshot (checksum-verified — a
      truncated snapshot falls back to the previous step), and
      re-submits every incomplete query. The re-submission is LOSSLESS
      for the same reason warm restarts are exact: sampling is
      target-independent, so a re-admitted query starts from the
      restored shared counts with its full ``n_i`` — it loses the
      rounds since the last snapshot, never its statistical position.

Every decision is observable through the shared `repro.obs` registry /
tracer: ``serve_crashes_total`` / ``serve_recoveries_total`` /
``serve_queries_shed_total`` counters, a ``serve_recovery_seconds``
histogram, and ``serve_crash`` / ``serve_recovered`` / ``query_shed``
events; `MatchServer.metrics` surfaces ``last_error`` and
``queries_shed`` for scraping.

The supervisor has its own request-id space (stable across server
rebuilds — a server's rids restart at 0 when it is rebuilt after a
crash); ``results`` / ``shed`` are keyed by supervisor rids.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Dict, Optional

import numpy as np

from repro.core.engine import MatchResult
from repro.serve.fastmatch_server import (
    AnytimeAnswer,
    MatchServer,
    StopPolicy,
    answer_from_result,
)

__all__ = ["ServeSupervisor", "SupervisorPolicy"]

logger = logging.getLogger(__name__)


@dataclasses.dataclass(frozen=True)
class SupervisorPolicy:
    """Liveness policy knobs.

    ``max_restarts`` bounds crash-recovery attempts per supervisor
    lifetime — a server that keeps dying is a bug, and the (N+1)-th
    crash propagates to the caller with the original exception.
    ``max_queue`` bounds the server's pending queue (None = unbounded);
    ``default_deadline_s`` applies to submissions that set none.
    """

    max_restarts: int = 3
    restart_backoff_s: float = 0.0
    max_queue: Optional[int] = None
    default_deadline_s: Optional[float] = None


@dataclasses.dataclass
class _Request:
    """One supervised request across server rebuilds."""

    rid: int  # supervisor rid
    target: np.ndarray
    k: int
    eps: float
    delta: float
    deadline: Optional[float]  # absolute monotonic time, None = none
    submit_time: float
    stop: Optional[StopPolicy] = None  # SLA stop policy, survives rebuilds
    server_rid: Optional[int] = None  # rid on the CURRENT server


class ServeSupervisor:
    """Run a `MatchServer` with deadlines, shedding, and crash recovery.

    Construction arguments mirror `MatchServer.__init__` — they are
    stored and replayed on every (re)build, so a recovered server is
    configured identically to the crashed one. Pass ``checkpoint_dir``
    to make recovery warm (restore the last verified snapshot); without
    it recovery is cold but still answer-lossless (queries re-sample).
    """

    def __init__(self, dataset, *, policy: SupervisorPolicy = SupervisorPolicy(),
                 **server_kwargs):
        self.policy = policy
        self._dataset = dataset
        self._server_kwargs = dict(server_kwargs)
        # One telemetry instance across rebuilds: a crash must not
        # reset the counters that count crashes.
        tel = self._server_kwargs.get("telemetry")
        if tel is True:
            from repro.obs import Telemetry

            tel = Telemetry()
            self._server_kwargs["telemetry"] = tel
        self.telemetry = tel or None
        if self.telemetry is not None:
            reg = self.telemetry.registry
            self._c_crashes = reg.counter(
                "serve_crashes_total", "unrecoverable serving-loop failures")
            self._c_recoveries = reg.counter(
                "serve_recoveries_total", "successful crash recoveries")
            self._c_shed = reg.counter(
                "serve_queries_shed_total", "requests shed (overload or deadline)")
            self._h_recovery = reg.histogram(
                "serve_recovery_seconds", help="crash-to-serving recovery wall time")
        self.restarts = 0
        self.last_error = ""
        self.recovery_s_total = 0.0
        self.results: Dict[int, MatchResult] = {}
        self.shed: Dict[int, str] = {}  # rid -> reason
        self._requests: Dict[int, _Request] = {}
        self._next_rid = 0
        self.server = self._build_server(restore=True)

    # -- server lifecycle --------------------------------------------------

    def _build_server(self, *, restore: bool) -> MatchServer:
        server = MatchServer(self._dataset, **self._server_kwargs)
        if restore and server._manager is not None:
            try:
                server.restore_cache()
            except FileNotFoundError:
                pass  # nothing on disk yet: cold start
        server.last_error = self.last_error
        server.queries_shed = len(self.shed)
        return server

    def _recover(self, exc: BaseException) -> None:
        self.restarts += 1
        self.last_error = repr(exc)
        logger.warning(
            "serving loop crashed (%r); recovery %d/%d",
            exc, self.restarts, self.policy.max_restarts,
        )
        if self.telemetry is not None:
            self._c_crashes.inc(1)
            self.telemetry.tracer.emit(
                "serve_crash", error=repr(exc), restarts=self.restarts,
            )
        if self.restarts > self.policy.max_restarts:
            raise exc
        if self.policy.restart_backoff_s:
            time.sleep(self.policy.restart_backoff_s)
        t0 = time.perf_counter()
        # Discard the wounded server wholesale — after an arbitrary
        # mid-round failure its host mirrors / pass cursor are not
        # trustworthy. The snapshot restore + re-submission below is
        # the documented lossless path.
        self.server = self._build_server(restore=True)
        resubmitted = 0
        for req in self._requests.values():
            if req.rid in self.results or req.rid in self.shed:
                continue
            req.server_rid = self.server.submit(
                req.target, k=req.k, eps=req.eps, delta=req.delta, stop=req.stop
            )
            resubmitted += 1
        recovery_s = time.perf_counter() - t0
        self.recovery_s_total += recovery_s
        if self.telemetry is not None:
            self._c_recoveries.inc(1)
            self._h_recovery.observe(recovery_s)
            self.telemetry.tracer.emit(
                "serve_recovered", recovery_s=recovery_s,
                resumed_step=self.server.scheduler.rounds,
                resubmitted=resubmitted,
            )

    # -- requests ----------------------------------------------------------

    def submit(self, target, *, k: int, eps: float = 0.06, delta: float = 0.01,
               deadline_s: Optional[float] = None,
               stop: Optional[StopPolicy] = None) -> int:
        """Queue a supervised query; returns a supervisor rid resolved
        in ``results`` (answered) or ``shed`` (refused/expired).

        ``stop`` is the per-query SLA policy (see `StopPolicy`); it is
        carried on the supervised request and re-applied on crash
        re-submission. Supervisor deadlines compose with it: whichever
        fires first retires the query (a live deadline retirement is
        reported as ``stop_reason="deadline"``).
        """
        rid = self._next_rid
        self._next_rid += 1
        if deadline_s is None:
            deadline_s = self.policy.default_deadline_s
        now = time.monotonic()
        req = _Request(
            rid=rid, target=np.asarray(target, np.float64).ravel(),
            k=k, eps=eps, delta=delta,
            deadline=None if deadline_s is None else now + deadline_s,
            submit_time=now, stop=stop,
        )
        self._requests[rid] = req
        if (
            self.policy.max_queue is not None
            and len(self.server.pending) >= self.policy.max_queue
        ):
            self._shed(req, "overload")
            return rid
        req.server_rid = self.server.submit(target, k=k, eps=eps, delta=delta,
                                            stop=stop)
        return rid

    def _shed(self, req: _Request, reason: str) -> None:
        self.shed[req.rid] = reason
        self.server.queries_shed = len(self.shed)
        if self.telemetry is not None:
            self._c_shed.inc(1)
            self.telemetry.tracer.emit("query_shed", rid=req.rid, reason=reason)

    def _enforce_deadlines(self) -> None:
        now = time.monotonic()
        expired = [
            r for r in self._requests.values()
            if r.deadline is not None and now >= r.deadline
            and r.rid not in self.results and r.rid not in self.shed
        ]
        if not expired:
            return
        server = self.server
        sched = server.scheduler
        queued = {q.rid: q for q in server.pending}
        qid_by_srv_rid = {
            srv_rid: qid for qid, srv_rid in server._rid_of_qid.items()
        }
        retired_any = False
        for req in expired:
            if req.server_rid in queued:
                # Never admitted: zero I/O spent, nothing to answer.
                server.pending = type(server.pending)(
                    q for q in server.pending if q.rid != req.server_rid
                )
                server._submit_time.pop(req.server_rid, None)
                self._shed(req, "deadline")
            elif req.server_rid in qid_by_srv_rid:
                # Live: early-retire with the current best-effort
                # answer — degraded service, not a dropped query.
                qid = qid_by_srv_rid[req.server_rid]
                slot = next(
                    s for s, t in sched.tickets.items() if t.qid == qid
                )
                if not retired_any:
                    sched._sync()  # fresh mirrors: retire() runs on them
                    retired_any = True
                fired = bool(sched._delta_upper[slot] < sched.tickets[slot].delta)
                sched.retire(slot, exact=False, terminated=fired,
                             stopped=True, stop_reason="deadline")
                if self.telemetry is not None:
                    self.telemetry.tracer.emit(
                        "query_deadline_retire", rid=req.rid, qid=qid,
                    )
            # else: already resolved between the scan and here — done.
        if retired_any:
            server._collect()

    def _collect(self) -> None:
        """Map newly finished server results into supervisor rids."""
        srv_results = self.server.results
        for req in self._requests.values():
            if req.rid in self.results or req.rid in self.shed:
                continue
            if req.server_rid is not None and req.server_rid in srv_results:
                self.results[req.rid] = srv_results[req.server_rid]

    def poll_result(self, rid: int) -> AnytimeAnswer:
        """The current anytime answer for supervisor request ``rid``.

        Passthrough to `MatchServer.poll_result` on the live server. A
        shed request (overload or queued-at-deadline) has no answer —
        it never consumed I/O — and raises KeyError, as does an unknown
        rid. A request resolved before a crash rebuild is answered from
        the stored `MatchResult` (the rebuilt server no longer knows
        its rid).
        """
        if rid in self.shed:
            raise KeyError(f"request {rid} was shed ({self.shed[rid]})")
        req = self._requests[rid]
        if rid in self.results:
            ans = self.server._anytime.get(req.server_rid)
            if ans is not None and ans.result is self.results[rid]:
                return ans
            return answer_from_result(
                self.results[rid], metric=self.server.spec.metric
            )
        return self.server.poll_result(req.server_rid)

    # -- the supervised loop -----------------------------------------------

    @property
    def unresolved(self) -> int:
        return len(self._requests) - len(self.results) - len(self.shed)

    def run_until_idle(self, *, max_steps: int = 1_000_000) -> Dict[int, MatchResult]:
        """Drive `MatchServer.step` until every supervised request is
        answered or shed, recovering from crashes along the way."""
        steps = 0
        while self.unresolved:
            self._enforce_deadlines()
            self._collect()
            if not self.unresolved:
                break
            try:
                self.server.step()
            except (KeyboardInterrupt, SystemExit):
                raise
            except BaseException as exc:
                self._recover(exc)
            self._collect()
            steps += 1
            if steps >= max_steps:
                break
        return dict(self.results)

    # -- observability -----------------------------------------------------

    @property
    def metrics(self) -> Dict[str, object]:
        m = dict(self.server.metrics)
        m.update(
            restarts=self.restarts,
            recovery_s_total=self.recovery_s_total,
            queries_shed=len(self.shed),
            last_error=self.last_error or m.get("last_error", ""),
        )
        return m
