"""Checkpoint manager: atomicity, resume, GC, elastic reshard."""

import json
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, config_hash


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32)),
                   "layers": [{"a": jnp.asarray(rng.normal(size=(4,)).astype(np.float32))}]},
        "step": jnp.asarray(7, jnp.int32),
    }


class TestRoundtrip:
    def test_save_restore_identical(self, tmp_path):
        m = CheckpointManager(str(tmp_path))
        s = _state()
        m.save(s, 10)
        back = m.restore(s)
        for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_pointer(self, tmp_path):
        m = CheckpointManager(str(tmp_path))
        s = _state()
        m.save(s, 1)
        m.save(s, 5)
        assert m.latest_step() == 5

    def test_restore_specific_step(self, tmp_path):
        m = CheckpointManager(str(tmp_path), keep_last=10)
        m.save(_state(0), 1)
        m.save(_state(1), 2)
        b1 = m.restore(_state(0), step=1)
        b2 = m.restore(_state(0), step=2)
        assert not np.array_equal(np.asarray(b1["params"]["w"]), np.asarray(b2["params"]["w"]))


class TestFaultTolerance:
    def test_no_tmp_left_after_save(self, tmp_path):
        m = CheckpointManager(str(tmp_path))
        m.save(_state(), 3)
        assert not list(tmp_path.glob("*.tmp.*"))

    def test_missing_latest_falls_back(self, tmp_path):
        m = CheckpointManager(str(tmp_path))
        m.save(_state(), 4)
        (tmp_path / "LATEST").unlink()
        assert m.latest_step() == 4

    def test_corrupt_latest_ignored(self, tmp_path):
        m = CheckpointManager(str(tmp_path))
        m.save(_state(), 4)
        (tmp_path / "LATEST").write_text("step_99999")  # dangling pointer
        assert m.latest_step() == 4

    def test_keep_last_gc(self, tmp_path):
        m = CheckpointManager(str(tmp_path), keep_last=2)
        for i in range(5):
            m.save(_state(), i)
        assert m.all_steps() == [3, 4]

    def test_structure_mismatch_rejected(self, tmp_path):
        m = CheckpointManager(str(tmp_path))
        m.save(_state(), 1)
        with pytest.raises(ValueError):
            m.restore({"different": jnp.zeros(3)})

    def test_config_hash_mismatch_rejected(self, tmp_path):
        m1 = CheckpointManager(str(tmp_path), config_hash="aaaa")
        m1.save(_state(), 1)
        m2 = CheckpointManager(str(tmp_path), config_hash="bbbb")
        with pytest.raises(ValueError):
            m2.restore(_state())


class TestElasticReshard:
    def test_restore_resharded_roundtrip(self, tmp_path):
        """Save on one 'mesh', restore under a different sharding — the
        elastic-restart path (single-device here; placement API exercised)."""
        from jax.sharding import Mesh, PartitionSpec as P

        m = CheckpointManager(str(tmp_path))
        s = _state()
        m.save(s, 1)
        mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        pspecs = jax.tree.map(lambda _: P(), s)
        back = m.restore_resharded(s, mesh, pspecs)
        np.testing.assert_array_equal(np.asarray(back["params"]["w"]), np.asarray(s["params"]["w"]))
