"""Warm-restart amortization: a restored `MatchServer` vs a cold one.

The serving subsystem's speedup lives in the persistent cross-query
sample cache; until PR 4 that cache died with the process, so every
restart paid the full sampling cost again — the brute-force regime the
paper's speedups are measured against. This benchmark measures the
restart analogue of the serve benchmark's I/O amortization:

  1. A "day 1" server serves a warmup batch and checkpoints its cache.
  2. The cache is restored in a NEW PROCESS (genuine cross-process
     persistence, not a same-process object copy) and a batch of fresh
     queries is served from the warm cache.
  3. A cold server (fresh cache, same configuration) serves the same
     fresh batch.

Acceptance: the warm-restored server must read STRICTLY fewer tuples
per query than the cold server at no recall loss against planted
ground truth.

Reported rows (benchmarks/run.py CSV schema):

  restart_cold_total   — us for the cold fresh batch, derived = tuples read
  restart_warm_total   — us for the warm fresh batch, derived = tuples read
  restart_amortization — derived = cold_tuples / warm_tuples (>1 = win)
  restart_save         — us per cache checkpoint save
  restart_restore      — us per cross-process cache restore

Machine-readable results land in benchmarks/results/BENCH_restart.json
(tuples read per query, cold vs warm-restored, plus save/restore wall
times) alongside the aggregate CSV.

Set RESTART_BENCH_SMOKE=1 for the tiny CI configuration (same code
path; exits non-zero if the warm server does not strictly win or loses
recall).
"""

from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import tempfile
import time

import numpy as np

from benchmarks.common import env_stamp
from repro.data.layout import block_layout
from repro.data.synth import SynthSpec, make_dataset, perturb_distribution
from repro.serve.fastmatch_server import MatchServer

SMOKE = bool(int(os.environ.get("RESTART_BENCH_SMOKE", "0")))
K, DELTA, EPS = 10, 0.01, 0.07
N_WARMUP, N_FRESH = 6, 4
MAX_QUERIES = 8
SPEC = SynthSpec(
    v_z=161, v_x=24, num_tuples=300_000 if SMOKE else 4_000_000, k=K, n_close=10,
    close_distance=0.02, far_distance=0.3, zipf_a=1.0, close_rank="head", seed=42,
)
LOOKAHEAD = 16 if SMOKE else 512

RESULTS = pathlib.Path(__file__).parent / "results"


def _build():
    ds = make_dataset(SPEC)
    blocked = block_layout(
        ds.z, ds.x, v_z=SPEC.v_z, v_x=SPEC.v_x, block_size=512, seed=42
    )
    return ds, blocked


def _warmup_targets(ds):
    rng = np.random.default_rng(7)
    return [ds.target] + [
        perturb_distribution(ds.target, d, rng)
        for d in np.linspace(0.004, 0.03, N_WARMUP - 1)
    ]


def _fresh_targets(ds):
    """The post-restart workload — deterministic, so the warm (restored,
    other process) and cold servers serve the exact same queries."""
    rng = np.random.default_rng(21)
    return [
        perturb_distribution(ds.target, d, rng)
        for d in np.linspace(0.008, 0.05, N_FRESH)
    ]


def _serve(server: MatchServer, targets):
    rids = [server.submit(t, k=K, eps=EPS, delta=DELTA) for t in targets]
    results = server.run_until_idle()
    return [results[r] for r in rids]


def _true_top_k(ds, target, k: int) -> set:
    dists = np.abs(ds.true_hists - np.asarray(target)[None, :]).sum(axis=1)
    return set(np.argsort(dists, kind="stable")[:k].tolist())


def _recall(ds, targets, results) -> float:
    return float(np.mean([
        len(set(r.ids.tolist()) & _true_top_k(ds, t, K)) / K
        for t, r in zip(targets, results)
    ]))


def restore_phase() -> None:
    """Entry point executed in a NEW process: warm-restore the server
    from $RESTART_BENCH_CKPT and serve the fresh batch. Prints one JSON
    line consumed by `run` in the parent."""
    ckpt = os.environ["RESTART_BENCH_CKPT"]
    ds, blocked = _build()
    t0 = time.perf_counter()
    server = MatchServer.restore(
        blocked, checkpoint_dir=ckpt,
        max_queries=MAX_QUERIES, lookahead=LOOKAHEAD, k_cap=K,
    )
    restore_s = time.perf_counter() - t0
    targets = _fresh_targets(ds)
    # the restored cursor CONTINUES the day-1 counters, so actual new
    # I/O is the delta — per-query counters are while-live deltas already
    before = server.metrics["total_tuples_read"]
    t0 = time.perf_counter()
    results = _serve(server, targets)
    print(json.dumps(dict(
        tuples=[int(r.tuples_read) for r in results],
        total_tuples=int(server.metrics["total_tuples_read"] - before),
        recall=_recall(ds, targets, results),
        restore_s=restore_s,
        serve_s=time.perf_counter() - t0,
    )))


def run(rows: list) -> None:
    ds, blocked = _build()
    ckpt = tempfile.mkdtemp(prefix="fastmatch_restart_bench_")

    # -- day 1: warm the cache, checkpoint it ---------------------------
    day1 = MatchServer(
        blocked, max_queries=MAX_QUERIES, lookahead=LOOKAHEAD, seed=200, k_cap=K,
        checkpoint_dir=ckpt,
    )
    _serve(day1, _warmup_targets(ds))
    t0 = time.perf_counter()
    day1.save_cache()
    save_s = time.perf_counter() - t0

    # -- warm restart: restore + serve in a NEW process -----------------
    env = dict(os.environ)
    env["RESTART_BENCH_CKPT"] = ckpt
    env["PYTHONPATH"] = (
        str(pathlib.Path(__file__).parent.parent / "src")
        + os.pathsep + env.get("PYTHONPATH", "")
    )
    out = subprocess.run(
        [sys.executable, "-c",
         "from benchmarks.warm_restart import restore_phase; restore_phase()"],
        env=env, capture_output=True, text=True, timeout=1800,
        cwd=str(pathlib.Path(__file__).parent.parent),
    )
    if out.returncode != 0:
        raise SystemExit(f"restore phase failed:\n{out.stderr[-4000:]}")
    warm = json.loads(out.stdout.strip().splitlines()[-1])

    # -- cold restart: same fresh workload, empty cache -----------------
    cold_server = MatchServer(
        blocked, max_queries=MAX_QUERIES, lookahead=LOOKAHEAD, seed=200, k_cap=K
    )
    fresh = _fresh_targets(ds)
    t0 = time.perf_counter()
    cold_results = _serve(cold_server, fresh)
    cold_s = time.perf_counter() - t0
    cold_tuples = [int(r.tuples_read) for r in cold_results]
    cold_recall = _recall(ds, fresh, cold_results)

    # totals are ACTUAL I/O (shared reads counted once), per-query
    # numbers in the report are the usual while-live amortized counters
    warm_total = warm["total_tuples"]
    cold_total = int(cold_server.metrics["total_tuples_read"])
    amortization = cold_total / max(warm_total, 1)
    ok = warm_total < cold_total and warm["recall"] >= cold_recall

    rows.append(dict(name="restart_cold_total",
                     us_per_call=1e6 * cold_s, derived=cold_total))
    rows.append(dict(name="restart_warm_total",
                     us_per_call=1e6 * warm["serve_s"], derived=warm_total))
    rows.append(dict(name="restart_amortization", us_per_call=0.0,
                     derived=round(amortization, 2)))
    rows.append(dict(name="restart_save", us_per_call=1e6 * save_s, derived=0))
    rows.append(dict(name="restart_restore",
                     us_per_call=1e6 * warm["restore_s"], derived=0))

    report = dict(
        config=dict(
            v_z=SPEC.v_z, v_x=SPEC.v_x, num_tuples=SPEC.num_tuples,
            n_warmup=N_WARMUP, n_fresh=N_FRESH, lookahead=LOOKAHEAD,
            k=K, eps=EPS, delta=DELTA, smoke=SMOKE, **env_stamp(),
        ),
        cold=dict(tuples_per_query=cold_tuples, total_tuples=cold_total,
                  recall=cold_recall, serve_s=round(cold_s, 4)),
        warm=dict(tuples_per_query=warm["tuples"], total_tuples=warm_total,
                  recall=warm["recall"], serve_s=round(warm["serve_s"], 4),
                  restore_s=round(warm["restore_s"], 4)),
        save_s=round(save_s, 4),
        amortization=round(amortization, 2),
        ok=ok,
    )
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "BENCH_restart.json").write_text(json.dumps(report, indent=2) + "\n")

    print(f"# warm_restart: cold={cold_total:,} tuples vs warm-restored="
          f"{warm_total:,} ({amortization:.1f}x), recall "
          f"{warm['recall']:.3f} vs {cold_recall:.3f}, save {save_s * 1e3:.0f}ms / "
          f"restore {warm['restore_s'] * 1e3:.0f}ms -> {'PASS' if ok else 'FAIL'}")
    if SMOKE and not ok:
        raise SystemExit("warm_restart smoke FAILED")


if __name__ == "__main__":
    rows: list = []
    run(rows)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
