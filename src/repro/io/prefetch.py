"""Double-buffered background-thread block prefetch (paper Sec 4.2).

"The sampling engine must never stall the statistics engine": while the
device runs round t's ingest+stats, a worker thread gathers window t+1
from the wrapped source into a bounded queue. With a queue depth of 2
this is classic double buffering — the consumer always finds the next
window staged unless the underlying source is genuinely slower than the
compute, in which case the queue provides back-pressure instead of
unbounded memory growth.

Abandonment-safe: closing the stream generator mid-pass (a query
retires, the budget cuts) signals the worker and drains the queue so
a blocked `put` can never leak the thread.
"""

from __future__ import annotations

import queue
import threading
from typing import Iterable, Iterator, Optional

import numpy as np

from repro.io.block_source import BlockSource, WindowData

__all__ = ["PrefetchSource"]


class PrefetchSource:
    """Wrap any `BlockSource`; `stream` overlaps fetch with consumption."""

    def __init__(self, inner: BlockSource, *, depth: int = 2):
        if depth < 1:
            raise ValueError(f"need depth >= 1, got {depth}")
        self.inner = inner
        self.depth = depth
        self.num_blocks = inner.num_blocks
        self.block_size = inner.block_size
        self.v_z = inner.v_z
        self.v_x = inner.v_x
        self.tuples_per_block = inner.tuples_per_block

    def fetch(self, win: np.ndarray, pad_to: Optional[int] = None) -> WindowData:
        return self.inner.fetch(win, pad_to)

    def stream(
        self, windows: Iterable[np.ndarray], pad_to: Optional[int] = None
    ) -> Iterator[WindowData]:
        windows = list(windows)
        q: queue.Queue = queue.Queue(maxsize=self.depth)
        stop = threading.Event()

        def _put(item) -> bool:
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def worker():
            try:
                for win in windows:
                    if stop.is_set() or not _put(("data", self.inner.fetch(win, pad_to))):
                        return
                _put(("done", None))
            except BaseException as exc:  # surfaced in the consumer
                _put(("error", exc))

        t = threading.Thread(target=worker, name="block-prefetch", daemon=True)
        t.start()
        try:
            while True:
                kind, payload = q.get()
                if kind == "done":
                    break
                if kind == "error":
                    raise payload
                yield payload
        finally:
            stop.set()
            try:
                while True:
                    q.get_nowait()
            except queue.Empty:
                pass
            t.join(timeout=10)
