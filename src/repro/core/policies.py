"""Block-selection policies (paper Sec 4.2 & 5.2).

A policy decides, given the current HistSim statistics and a window of
upcoming block positions, which blocks the I/O manager should read:

  * scan      — read every block (ScanMatch / SlowMatch / Scan)
  * anyactive — read a block iff it contains a tuple of an active
                candidate (delta_i > delta/|V_Z|), evaluated over a whole
                lookahead window against the packed bitmap (Alg. 3)

The *staleness* of the statistics a policy sees is the engine's concern
(engine.py): FastMatch evaluates AnyActive with the freshest delta
posted by the statistics engine, which is one lookahead-window old —
exactly the paper's asynchronous relaxation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops

__all__ = ["mark_window"]


def mark_window(
    bitmap_window: jax.Array,
    active_words: jax.Array,
    *,
    policy: str,
) -> jax.Array:
    """(L,) bool read-marks for a lookahead window of L blocks."""
    if policy == "scan":
        return jnp.ones((bitmap_window.shape[0],), bool)
    if policy == "anyactive":
        return ops.anyactive(bitmap_window, active_words)
    raise ValueError(f"unknown policy {policy!r}")
