"""xlstm-125m [ssm] — sLSTM + mLSTM blocks (xLSTM[7:1]) [arXiv:2405.04517].

d_ff=0 per assignment: block-internal widths come from projection
factors (mLSTM up-factor 2, sLSTM ff-factor 4/3), as in the paper.
Sub-quadratic: runs long_500k (O(1) recurrent state).
"""

from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch_id="xlstm_125m",
        family="ssm",
        num_layers=12,
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        head_dim=192,
        d_ff=0,
        vocab_size=50304,
        slstm_every=8,  # 1 sLSTM per 8 blocks ~ the paper's 7:1 ratio
        mlstm_chunk=128,
        proj_factor_mlstm=2.0,
        proj_factor_slstm=1.3333,
        norm_eps=1e-5,
        optimizer="adamw",
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="xlstm_125m_smoke",
        family="ssm",
        num_layers=3,
        d_model=64,
        num_heads=2,
        num_kv_heads=2,
        head_dim=64,
        d_ff=0,
        vocab_size=256,
        slstm_every=3,
        mlstm_chunk=16,
        norm_eps=1e-5,
    )
