"""Pallas TPU kernel: AnyActive block marking over a packed bitmap.

The paper's Algorithm 3 marks a lookahead batch of data blocks for
:read/:skip by testing, per block, whether ANY active candidate has a
tuple in it — and observes that evaluating whole batches at once is what
makes the policy cheap (one cache line of bitmap bits serves many
blocks). The TPU translation is direct: the bitmap is packed 32
candidates per uint32 lane, a VMEM tile covers thousands of data blocks,
and the mark is a bitwise AND with the packed active mask followed by a
lane-reduction OR. One tile = one VPU pass over (B_TILE x W) words.

bitmap[b, w] bit j  <=>  data block b contains a tuple of candidate 32w+j.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["anyactive_pallas"]

_B_TILE = 1024


def _anyactive_kernel(bitmap_ref, mask_ref, out_ref):
    bits = bitmap_ref[...]  # (B_TILE, W) uint32
    mask = mask_ref[...]  # (1, W) uint32
    hits = jnp.bitwise_and(bits, mask)
    out_ref[...] = jnp.any(hits != 0, axis=1)


def anyactive_pallas(
    bitmap: jax.Array,
    active_words: jax.Array,
    *,
    b_tile: int = _B_TILE,
    interpret: bool = False,
) -> jax.Array:
    """(num_blocks,) bool marks: True = :read, False = :skip.

    Args:
      bitmap: (num_blocks, W) uint32 packed candidate-presence bitmap.
      active_words: (W,) uint32 packed active mask.
    """
    nb, w = bitmap.shape
    b_tile = min(b_tile, nb)
    nb_pad = -(-nb // b_tile) * b_tile
    w_pad = max(8, -(-w // 8) * 8)
    if (nb_pad, w_pad) != (nb, w):
        bitmap = jnp.pad(bitmap, ((0, nb_pad - nb), (0, w_pad - w)))
        active_words = jnp.pad(active_words, (0, w_pad - w))
    mask2d = active_words.reshape(1, w_pad)

    out = pl.pallas_call(
        _anyactive_kernel,
        grid=(nb_pad // b_tile,),
        in_specs=[
            pl.BlockSpec((b_tile, w_pad), lambda bb: (bb, 0)),
            pl.BlockSpec((1, w_pad), lambda bb: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b_tile,), lambda bb: (bb,)),
        out_shape=jax.ShapeDtypeStruct((nb_pad,), jnp.bool_),
        interpret=interpret,
    )(bitmap, mask2d)
    return out[:nb]
