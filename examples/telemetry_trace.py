"""Telemetry demo: trace a served batch, render the confidence curves.

Runs a `MatchServer` with `repro.obs` telemetry attached, serves a
small batch of matching queries, then shows everything the subsystem
captured:

  * the per-query lifecycle trace (enqueue -> admit -> round batches ->
    retire), dumped as JSONL — the file a dashboard or `jq` consumes;
  * the tuples-to-confidence curve of each query — the measurable form
    of Theorem 1's n -> eps(n): at every poll boundary the scheduler
    records how many tuples the shared stream has read and how much
    failure probability (delta_upper) remains, written as CSV;
  * the Prometheus-format metrics scrape body (counters for tuples /
    rounds / blocks, latency histograms binned by the repo's own
    histogram kernel).

Telemetry observes without perturbing: the engine's outputs are
bit-identical with and without it (tests/test_obs.py), so what this
demo traces IS the normal serving behavior.

  PYTHONPATH=src python examples/telemetry_trace.py
"""

import json
import pathlib
import tempfile

import numpy as np

from repro.data.layout import block_layout
from repro.data.synth import SynthSpec, make_dataset, perturb_distribution
from repro.serve.fastmatch_server import MatchServer

K, EPS, DELTA = 10, 0.07, 0.01


def main():
    spec = SynthSpec(
        v_z=161, v_x=24, num_tuples=1_000_000, k=K, n_close=10,
        close_distance=0.02, far_distance=0.3, zipf_a=1.0, seed=0,
    )
    print("generating synthetic census ...")
    ds = make_dataset(spec)
    blocked = block_layout(ds.z, ds.x, v_z=spec.v_z, v_x=spec.v_x, seed=0)
    print(f"dataset: {blocked.num_tuples:,} tuples in {blocked.num_blocks:,} blocks\n")

    rng = np.random.default_rng(1)
    targets = [ds.target] + [
        perturb_distribution(ds.target, d, rng)
        for d in np.linspace(0.005, 0.05, 5)
    ]

    server = MatchServer(
        blocked, max_queries=4, lookahead=256, poll_every=4, seed=0,
        prefetch=True, telemetry=True,
    )
    rids = [server.submit(t, k=K, eps=EPS, delta=DELTA) for t in targets]
    print(f"serving {len(rids)} queries with telemetry attached ...")
    server.run_until_idle()

    out = pathlib.Path(tempfile.mkdtemp(prefix="fastmatch_telemetry_"))
    tel = server.telemetry

    # 1. lifecycle trace -> JSONL
    trace_path = out / "trace.jsonl"
    n = server.export_trace(trace_path)
    print(f"\n-- trace: {n} events -> {trace_path}")
    for line in trace_path.read_text().splitlines():
        ev = json.loads(line)
        if ev["kind"] in ("query_admit", "query_retire", "round_batch"):
            keys = ("qid", "slot", "rounds", "tuples", "tuples_read", "windows")
            brief = {k: ev[k] for k in keys if k in ev}
            print(f"   [{ev['seq']:>3}] {ev['kind']:<13} {brief}")

    # 2. tuples-to-confidence curves -> CSV (+ a terminal sketch)
    csv_path = out / "confidence_curves.csv"
    rows = tel.export_confidence_csv(csv_path)
    print(f"\n-- confidence curves: {rows} points -> {csv_path}")
    for qid in tel.query_ids():
        curve = tel.confidence_curve(qid)  # columns: obs.CURVE_COLUMNS
        tuples, conf = curve[:, 1], curve[:, 7]
        steps = " ".join(
            f"{int(t):>9,}:{c:5.3f}" for t, c in zip(tuples, conf)
        )
        print(f"   q{qid}: tuples:confidence  {steps}")

    # 3. Prometheus scrape body
    prom_path = out / "metrics.prom"
    prom_path.write_text(server.prometheus_metrics())
    wanted = ("fastmatch_tuples_read_total", "fastmatch_rounds_total",
              "fastmatch_queries_retired_total")
    print(f"\n-- metrics -> {prom_path}")
    for line in server.prometheus_metrics().splitlines():
        if line.startswith(wanted):
            print(f"   {line}")

    m = server.metrics
    print(f"\nserved {m['queries_done']} queries from "
          f"{m['total_tuples_read']:,} shared tuples "
          f"({m['tuples_per_query']:,.0f} amortized per query)")


if __name__ == "__main__":
    main()
