from repro.configs.base import (
    ModelConfig,
    Shape,
    SHAPES,
    get_config,
    get_smoke_config,
    list_archs,
)

__all__ = [
    "ModelConfig",
    "Shape",
    "SHAPES",
    "get_config",
    "get_smoke_config",
    "list_archs",
]
