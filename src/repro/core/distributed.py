"""Multi-pod distributed HistSim — the unified MULTI-QUERY round.

One round over `repro.core.multiquery.MultiQueryState` on a
("pod", "data", "model") mesh; the single-query case is just
``max_queries=1`` (the parallel single-query `ShardedHistSimState` this
module used to carry is gone — one loop, one state, every width):

  * corpus blocks   — range-sharded over ("pod", "data"): each worker
                      owns a contiguous range of the shuffled layout
                      (`repro.io.ShardedSource`, locality, Challenge 1)
                      and ingests only its own blocks.
  * counts matrix   — candidate-sharded over "model": each model shard
                      owns V_Z / |model| rows of the SHARED counts —
                      P("model", None) — and of n — P("model").
  * per round       — each (pod, data) shard histograms its local
                      samples *restricted to the candidate rows of its
                      model shard* (one-hot matmul, so restriction is an
                      index shift, not a gather; the kernel emits the
                      row-sum delta from the same pass), then a single
                      psum over ("pod", "data") merges the partial
                      (counts, rows) pair: the paper's r_partial
                      spinlock handoff becomes one fused all-reduce of
                      a (V_Z/m, V_X) f32 tile.
  * statistics      — per-query tau rows computed locally per model
                      shard with ONE Q-batched `ops.distance_multi`
                      call (the spec's static metric) (the shard's counts rows are streamed once
                      for all query slots; unoccupied slots masked),
                      then one tiled all-gather of (Q, V_Z) + (V_Z,)
                      floats and the same vmapped per-query deviation
                      assignment the single-device scheduler uses
                      (`multiquery.apply_stats` — the two paths share
                      the code, so they cannot drift). The per-query
                      active words and their union (V_Z bits packed)
                      return to every shard — the only "control plane"
                      traffic.

Communication per round: one psum of the (counts, row-sum) delta pair
+ one all-gather of (Q+1) x V_Z f32 — independent of the number of
samples ingested AND of the number of query slots (the batched tau
reads each shard's counts rows once, not Q times).
Sample bytes never cross the network; this is what makes the engine
scale to 1000+ nodes. `SharedCountsScheduler(mesh=...)` is the GSPMD
(sharding-propagation) counterpart for serving; this explicit
shard_map round is the collective-auditable data-parallel ingest path.

The PUMP round (`make_pump_round`) is the self-feeding variant of the
same collective structure, built for `repro.core.pump.DistributedPump`:
each data-parallel worker brings its OWN window of block data (gathered
shard-locally from its `ShardedSource`), and the round additionally
runs the AnyActive marking against the replicated union active words
and advances a `SampleCursor` whose ``read_mask`` is sharded over the
data axes (`cursor_pspecs`) so each worker owns exactly its contiguous
global-id range. Per-round cross-worker traffic stays the single psum
of the (counts, rows, counter-increment) pytree + the tiny stats
all-gather — window bytes never leave the worker that read them.
"""

from __future__ import annotations

import inspect

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.multiquery import (
    CacheSnapshot,
    MultiQuerySpec,
    MultiQueryState,
    SampleCursor,
    apply_stats,
)
from repro.core.policies import mark_window
from repro.io import WindowData
from repro.kernels import autotune, ops

__all__ = [
    "cache_pspecs",
    "cursor_pspecs",
    "make_distributed_round",
    "make_pump_ingest_round",
    "make_pump_round",
    "multi_state_pspecs",
    "place_cache",
    "shard_map_compat",
    "window_pspecs",
]


def shard_map_compat(f, mesh, in_specs, out_specs):
    """shard_map across jax versions (jax.shard_map / experimental;
    check_vma / check_rep) with replication checking off — the round's
    replicated outputs come out of collectives the checker can't see
    through on every version we support."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    params = inspect.signature(sm).parameters
    kwargs = {}
    if "check_vma" in params:
        kwargs["check_vma"] = False
    elif "check_rep" in params:
        kwargs["check_rep"] = False
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def multi_state_pspecs(model_axis: str = "model") -> MultiQueryState:
    """PartitionSpecs for MultiQueryState: shared counts candidate-sharded
    over the model axis, all per-query statistics replicated."""
    return MultiQueryState(
        counts=P(model_axis, None),
        n=P(model_axis),
        q_hat=P(),
        k=P(),
        eps=P(),
        delta=P(),
        gap=P(),
        qtype=P(),
        tau=P(),
        eps_i=P(),
        log_delta_i=P(),
        delta_upper=P(),
        active=P(),
        active_words=P(),
        union_words=P(),
        in_top_k=P(),
        pruned=P(),
        occupied=P(),
        round_idx=P(),
    )


def cache_pspecs(model_axis: str = "model") -> CacheSnapshot:
    """PartitionSpecs for the warm-start `CacheSnapshot`: the shared
    counts/n leaves carry the SAME candidate sharding as the live
    `MultiQueryState` (derived from `multi_state_pspecs`, so the two
    cannot drift); the sampling cursor and host bookkeeping replicate.

    This is the elastic-restart contract: a snapshot host-gathered from
    one mesh shape is re-placed onto another by
    ``CheckpointManager.restore_resharded(like, mesh, cache_pspecs())``
    — e.g. a cache accumulated on 1 device restored candidate-sharded
    onto 8, or an 8-way cache restored onto a 4-device mesh."""
    ms = multi_state_pspecs(model_axis=model_axis)
    return CacheSnapshot(
        counts=ms.counts,
        n=ms.n,
        read_mask=P(),
        blocks_read=P(),
        blocks_considered=P(),
        tuples_read=P(),
        rounds=P(),
        passes=P(),
        start=P(),
    )


def cursor_pspecs(data_axes=("data",)) -> SampleCursor:
    """PartitionSpecs for the pump's device `SampleCursor`: the
    without-replacement ``read_mask`` is sharded over the data axes —
    worker w owns exactly the mask slice for its contiguous global-id
    block range [w*per, (w+1)*per) (`ShardedSource` ordering, padded to
    per * num_workers) — while the monotone counters stay replicated
    (every worker holds the mesh-wide totals; the round psums the
    per-worker increments)."""
    return SampleCursor(
        read_mask=P(tuple(data_axes)),
        blocks_read=P(),
        blocks_considered=P(),
        tuples_read=P(),
        rounds=P(),
    )


def window_pspecs(data_axes=("data",)) -> WindowData:
    """PartitionSpecs for a pump round's `WindowData`: dim 0 (the
    lookahead-window axis) carries one window per data-parallel worker,
    so each worker's shard IS the window its own `ShardedSource`
    gathered; block contents replicate over the model axis."""
    d = tuple(data_axes)
    return WindowData(
        indices=P(d),
        z=P(d, None),
        x=P(d, None),
        bitmap=P(d, None),
        valid=P(d),
    )


def place_cache(snap: CacheSnapshot, mesh, model_axis: str = "model") -> CacheSnapshot:
    """Host-gather a (possibly sharded) snapshot and re-place it on
    ``mesh`` per `cache_pspecs` — the in-memory reshard twin of the
    checkpoint round-trip, for handing a live scheduler's cache to a
    differently-shaped mesh without touching disk."""
    from jax.sharding import NamedSharding

    host = jax.device_get(snap)  # gather: full leaves on host
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), cache_pspecs(model_axis=model_axis)
    )
    return jax.tree.map(jax.device_put, host, shardings)


def make_distributed_round(
    mesh,
    spec: MultiQuerySpec,
    *,
    data_axes=("data",),
    model_axis: str = "model",
    histogram_impl: str = "auto",
    onehot_dtype=jnp.float32,
    plans=None,
):
    """Build the jitted shard_map multi-query round for a given mesh.

    The returned function has signature (state, z_idx, x_idx) -> state,
    where state is a `MultiQueryState` placed per `multi_state_pspecs`
    and z_idx/x_idx are (N,) int32 sharded over ``data_axes`` — the
    samples each worker read from its own block range this round
    (padding = -1). All-reduce structure is as documented above; the
    statistics tail is `multiquery.apply_stats`, identical to the
    single-device scheduler's.

    ``plans`` (an `autotune.PlanPair`) pins the tuned kernel variants;
    None resolves from the plan registry at build time using the SHARD
    shapes — each worker's kernels see (vz_shard, V_X), not the global
    V_Z, so that is the key the tuner must have measured.
    """
    model_size = mesh.shape[model_axis]
    if spec.v_z % model_size != 0:
        raise ValueError(
            f"V_Z={spec.v_z} must divide by model axis size {model_size} "
            "(pad candidates to a multiple; padded rows are never sampled)"
        )
    vz_shard = spec.v_z // model_size
    sample_axes = tuple(data_axes)
    if plans is None:
        plans = autotune.resolve_plans(
            vz_shard, spec.v_x, spec.max_queries, metric=spec.metric
        )

    def round_fn(state: MultiQueryState, z_idx: jax.Array, x_idx: jax.Array):
        state = _shard_ingest(
            state, z_idx, x_idx, spec=spec, vz_shard=vz_shard,
            sample_axes=sample_axes, model_axis=model_axis,
            histogram_impl=histogram_impl, onehot_dtype=onehot_dtype,
            plan=plans.ingest,
        )
        return _shard_stats(state, spec=spec, model_axis=model_axis, plan=plans.tau)

    specs = multi_state_pspecs(model_axis=model_axis)
    sample_spec = P(sample_axes)
    shmapped = shard_map_compat(
        round_fn, mesh, in_specs=(specs, sample_spec, sample_spec), out_specs=specs
    )
    return jax.jit(shmapped)


def _shard_ingest(
    state: MultiQueryState,
    z_idx: jax.Array,
    x_idx: jax.Array,
    *,
    spec: MultiQuerySpec,
    vz_shard: int,
    sample_axes,
    model_axis: str,
    histogram_impl: str,
    onehot_dtype,
    plan=None,
) -> MultiQueryState:
    """Ingest (inside shard_map): local histogram restricted to this
    model shard's candidate rows — an index shift, not a gather — with
    the row-sum delta emitted from the same kernel pass (or the tuned
    two-step form, per ``plan``), then ONE fused all-reduce of the
    (counts, row-sum) delta pair over the data axes (a single psum
    call, XLA fuses the pytree)."""
    shard_id = jax.lax.axis_index(model_axis)
    z_local = z_idx - shard_id * vz_shard
    z_local = jnp.where((z_local >= 0) & (z_local < vz_shard), z_local, -1)
    h, rows = ops.histogram_with_rowsums(
        z_local, x_idx, v_z=vz_shard, v_x=spec.v_x,
        impl=histogram_impl, onehot_dtype=onehot_dtype,
        plan=plan if plan is not None else "auto",
    )
    h, rows = jax.lax.psum((h, rows), sample_axes)
    return state._replace(counts=state.counts + h, n=state.n + rows)


def _shard_stats(
    state: MultiQueryState, *, spec: MultiQuerySpec, model_axis: str, plan=None
) -> MultiQueryState:
    """Statistics tail (inside shard_map): row-local Q-batched tau (ONE
    kernel pass over this shard's counts rows scores every slot — or
    the tuned variant ``plan`` selected; unoccupied slots masked to the
    init value), tiny all-gather, then the shared vmapped per-query
    assignment."""
    tau_shard = ops.distance_multi(
        state.counts, state.q_hat, metric=spec.metric,
        plan=plan if plan is not None else "auto",
    )  # (Q, vz_shard)
    tau_shard = jnp.where(state.occupied[:, None], tau_shard, 1.0)
    tau = jax.lax.all_gather(tau_shard, model_axis, axis=1, tiled=True)
    n_full = jax.lax.all_gather(state.n, model_axis, axis=0, tiled=True)
    return apply_stats(state, tau, n_full, spec=spec)


def _worker_lo(mesh, data_axes, blocks_per_worker: int) -> jax.Array:
    """This worker's first owned global block id (inside shard_map).

    The linear worker index folds the data axes in mesh-row-major order
    — the same order `P(tuple(data_axes))` lays shards out in — so the
    read_mask shard at linear position w is exactly the id range of
    `ShardedSource(dataset, num_workers, w)`."""
    wid = jax.lax.axis_index(data_axes[0])
    for ax in data_axes[1:]:
        wid = wid * mesh.shape[ax] + jax.lax.axis_index(ax)
    return wid * blocks_per_worker


def _advance_shard_cursor(
    cursor: SampleCursor,
    wd: WindowData,
    marks: jax.Array,
    local_idx: jax.Array,
    sample_axes,
) -> SampleCursor:
    """Per-worker twin of `multiquery._advance_cursor`: the scatter hits
    only this worker's read_mask shard (local ids; window padding
    repeats an owned id with a zero contribution), while the counter
    increments are psum'd so every worker carries the mesh-wide totals
    — one fused collective for the whole increment pytree."""
    read_mask = (
        cursor.read_mask.astype(jnp.int32).at[local_idx].add(marks.astype(jnp.int32)) > 0
    )
    inc_read, inc_considered, inc_tuples = jax.lax.psum(
        (
            jnp.sum(marks.astype(jnp.int32)),
            jnp.sum(wd.valid.astype(jnp.int32)),
            jnp.sum(jnp.where(marks, jnp.sum((wd.z >= 0).astype(jnp.int32), axis=1), 0)),
        ),
        sample_axes,
    )
    return SampleCursor(
        read_mask=read_mask,
        blocks_read=cursor.blocks_read + inc_read,
        blocks_considered=cursor.blocks_considered + inc_considered,
        tuples_read=cursor.tuples_read + inc_tuples,
        rounds=cursor.rounds + 1,
    )


def _check_vz(spec: MultiQuerySpec, mesh, model_axis: str) -> int:
    model_size = mesh.shape[model_axis]
    if spec.v_z % model_size != 0:
        raise ValueError(
            f"V_Z={spec.v_z} must divide by model axis size {model_size} "
            "(pad candidates to a multiple; padded rows are never sampled)"
        )
    return spec.v_z // model_size


def make_pump_round(
    mesh,
    spec: MultiQuerySpec,
    *,
    blocks_per_worker: int,
    data_axes=("data",),
    model_axis: str = "model",
    policy: str = "anyactive",
    histogram_impl: str = "auto",
    onehot_dtype=jnp.float32,
    plans=None,
):
    """Build the jitted shard_map PUMP round: the fused sampling round
    (`multiquery.fused_round` semantics — mark + gather-mask + ingest +
    stats + read bookkeeping) where each data-parallel worker feeds
    itself from its own window.

    Signature of the returned function: (state, cursor, wd) ->
    (state, cursor), with state placed per `multi_state_pspecs`, cursor
    per `cursor_pspecs` (read_mask length blocks_per_worker *
    num_workers) and wd a `WindowData` whose dim 0 stacks one
    per-worker window, placed per `window_pspecs`.

    Semantics are pinned to `fused_round` on the union of the worker
    windows: marking uses the replicated union active words and each
    worker's own read_mask shard, and an all-empty round (no block
    marked mesh-wide) leaves the statistics — including ``round_idx`` —
    untouched. The empty-round guard is a branchless select rather than
    fused_round's lax.cond (collectives inside a cond branch do not
    lower reliably under shard_map); selected leaves are bit-identical
    either way.

    ``plans`` follows the `make_distributed_round` contract (shard-shape
    plan key).
    """
    vz_shard = _check_vz(spec, mesh, model_axis)
    sample_axes = tuple(data_axes)
    if plans is None:
        plans = autotune.resolve_plans(
            vz_shard, spec.v_x, spec.max_queries, metric=spec.metric
        )

    def round_fn(state: MultiQueryState, cursor: SampleCursor, wd: WindowData):
        local_idx = wd.indices - _worker_lo(mesh, sample_axes, blocks_per_worker)
        marks = mark_window(wd.bitmap, state.union_words, policy=policy)
        marks = marks & wd.valid & ~cursor.read_mask[local_idx]
        zw = jnp.where(marks[:, None], wd.z, jnp.int32(-1)).reshape(-1)
        xw = jnp.where(marks[:, None], wd.x, jnp.int32(-1)).reshape(-1)
        new_state = _shard_ingest(
            state, zw, xw, spec=spec, vz_shard=vz_shard,
            sample_axes=sample_axes, model_axis=model_axis,
            histogram_impl=histogram_impl, onehot_dtype=onehot_dtype,
            plan=plans.ingest,
        )
        new_state = _shard_stats(
            new_state, spec=spec, model_axis=model_axis, plan=plans.tau
        )
        n_marked = jax.lax.psum(jnp.sum(marks.astype(jnp.int32)), sample_axes)
        state = jax.tree.map(
            lambda new, old: jnp.where(n_marked > 0, new, old), new_state, state
        )
        return state, _advance_shard_cursor(cursor, wd, marks, local_idx, sample_axes)

    specs = multi_state_pspecs(model_axis=model_axis)
    cspecs = cursor_pspecs(data_axes=sample_axes)
    wspecs = window_pspecs(data_axes=sample_axes)
    shmapped = shard_map_compat(
        round_fn, mesh, in_specs=(specs, cspecs, wspecs), out_specs=(specs, cspecs)
    )
    return jax.jit(shmapped)


def make_pump_ingest_round(
    mesh,
    spec: MultiQuerySpec,
    *,
    blocks_per_worker: int,
    data_axes=("data",),
    model_axis: str = "model",
    histogram_impl: str = "auto",
    onehot_dtype=jnp.float32,
    plans=None,
):
    """Build the jitted shard_map exact-completion round — the pump twin
    of `multiquery.ingest_round`: every unread block of each worker's
    window goes into the shared counts, no marking, no stats (the
    caller runs one stats step after the last chunk). Same signature
    and placement contract as `make_pump_round` (including the
    shard-shape ``plans`` key)."""
    vz_shard = _check_vz(spec, mesh, model_axis)
    sample_axes = tuple(data_axes)
    if plans is None:
        plans = autotune.resolve_plans(
            vz_shard, spec.v_x, spec.max_queries, metric=spec.metric
        )

    def round_fn(state: MultiQueryState, cursor: SampleCursor, wd: WindowData):
        local_idx = wd.indices - _worker_lo(mesh, sample_axes, blocks_per_worker)
        marks = wd.valid & ~cursor.read_mask[local_idx]
        zw = jnp.where(marks[:, None], wd.z, jnp.int32(-1)).reshape(-1)
        xw = jnp.where(marks[:, None], wd.x, jnp.int32(-1)).reshape(-1)
        state = _shard_ingest(
            state, zw, xw, spec=spec, vz_shard=vz_shard,
            sample_axes=sample_axes, model_axis=model_axis,
            histogram_impl=histogram_impl, onehot_dtype=onehot_dtype,
            plan=plans.ingest,
        )
        return state, _advance_shard_cursor(cursor, wd, marks, local_idx, sample_axes)

    specs = multi_state_pspecs(model_axis=model_axis)
    cspecs = cursor_pspecs(data_axes=sample_axes)
    wspecs = window_pspecs(data_axes=sample_axes)
    shmapped = shard_map_compat(
        round_fn, mesh, in_specs=(specs, cspecs, wspecs), out_specs=(specs, cspecs)
    )
    return jax.jit(shmapped)
