"""Kernel autotuner: candidate equivalence, plan persistence, dispatch determinism.

The tuner's license to pick any candidate purely by measured wall time
rests on the equivalence contract this file enforces:

  * every tau / ingest candidate on the REF engine (the production
    XLA:CPU path) is BIT-identical to the pre-autotune reference —
    including the uint16 low-precision path, whose runtime overflow
    gate must fall back to full precision rather than wrap;
  * Pallas tile/sweep candidates (TPU knobs, exercised in interpret
    mode) reassociate the f32 lane reduce, so they get the same
    contract `tests/test_stats_batched.py` pins: allclose(3e-6) plus a
    golden top-k recall gate;
  * a committed plan file yields byte-stable dispatch across loads and
    processes, and a stale / corrupt / malformed plan file degrades to
    the default plans with a warning — never a crash.
"""

import dataclasses
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops
from repro.kernels.l1_distance_multi import l1_distance_multi_pallas


def _case(v_z, v_x, q, seed=0, hi=50):
    """Integer-valued f32 counts + dirichlet targets, the production regime."""
    rng = np.random.default_rng(seed)
    counts = jnp.asarray(rng.integers(0, hi, size=(v_z, v_x)).astype(np.float32))
    q_hat = jnp.asarray(
        np.stack([rng.dirichlet(np.ones(v_x)).astype(np.float32) for _ in range(q)])
    )
    return counts, q_hat


def _baseline(counts, q_hat):
    """The PR-2 reference: per-slot unrolled tau on the ref engine."""
    return np.asarray(
        autotune.run_tau(counts, q_hat, plan=autotune.TauPlan(variant="unrolled"),
                         engine="ref")
    )


@pytest.fixture()
def clean_warnings():
    """_warn_once dedupes process-wide; reset so each test sees its warning."""
    autotune._warned.clear()
    yield
    autotune._warned.clear()


class TestRefCandidateSpace:
    """Full candidate sweep on the production CPU engine: bit-identical."""

    @pytest.mark.parametrize("v_z,v_x,q", [(64, 300, 3), (128, 64, 1), (96, 128, 8)])
    def test_every_ref_candidate_bit_identical(self, v_z, v_x, q):
        counts, q_hat = _case(v_z, v_x, q)
        want = _baseline(counts, q_hat)
        cands = autotune.tau_candidates("ref", v_z, v_x, q)
        # the sweep must cover every variant, full- and low-precision
        assert {c.variant for c in cands} == set(autotune.TAU_VARIANTS)
        assert any(c.lowprec for c in cands)
        for cand in cands:
            got = np.asarray(autotune.run_tau(counts, q_hat, plan=cand, engine="ref"))
            np.testing.assert_array_equal(got, want, err_msg=repr(cand))

    def test_every_ingest_candidate_bit_identical(self):
        v_z, v_x, n = 64, 48, 4096
        rng = np.random.default_rng(3)
        z = jnp.asarray(rng.integers(-1, v_z, size=n).astype(np.int32))
        x = jnp.asarray(rng.integers(-1, v_x, size=n).astype(np.int32))
        base_c, base_n = autotune.run_ingest(
            z, x, v_z=v_z, v_x=v_x, plan=autotune.DEFAULT_INGEST, engine="ref"
        )
        for cand in autotune.ingest_candidates("ref", v_z, v_x):
            c, rows = autotune.run_ingest(z, x, v_z=v_z, v_x=v_x, plan=cand, engine="ref")
            np.testing.assert_array_equal(np.asarray(c), np.asarray(base_c), err_msg=repr(cand))
            np.testing.assert_array_equal(np.asarray(rows), np.asarray(base_n), err_msg=repr(cand))

    def test_lowprec_in_range_is_exact_and_jittable(self):
        counts, q_hat = _case(80, 96, 4, hi=60_000)  # near the uint16 ceiling
        plan = autotune.TauPlan(lowprec=True)
        got = jax.jit(
            lambda c, t: autotune.run_tau(c, t, plan=plan, engine="ref")
        )(counts, q_hat)
        np.testing.assert_array_equal(np.asarray(got), _baseline(counts, q_hat))

    def test_lowprec_overflow_gate_falls_back_exactly(self):
        counts, q_hat = _case(32, 64, 2)
        counts = counts.at[3, 5].set(70_000.0)  # above uint16 range
        got = np.asarray(
            autotune.run_tau(counts, q_hat, plan=autotune.TauPlan(lowprec=True),
                             engine="ref")
        )
        # a uint16 cast would wrap 70000 -> 4464 and shift tau; the
        # lax.cond gate must instead route the full-precision path
        np.testing.assert_array_equal(got, _baseline(counts, q_hat))


class TestPallasCandidateSpace:
    """Tile/sweep candidates (interpret mode): allclose + golden recall."""

    def test_tiled_candidates_allclose_with_golden_recall(self):
        v_z, v_x, q, k = 64, 300, 4, 8
        counts, q_hat = _case(v_z, v_x, q)
        want = _baseline(counts, q_hat)
        for cand in autotune.tau_candidates("pallas", v_z, v_x, q):
            got = np.asarray(
                autotune.run_tau(counts, q_hat, plan=cand, engine="pallas",
                                 interpret=True)
            )
            # same tolerance test_stats_batched.py pins for lane-tiled configs
            np.testing.assert_allclose(got, want, atol=3e-6, err_msg=repr(cand))
            # golden recall: every candidate top-k entry is a true
            # member of the reference top-k up to reduce-order jitter
            for s in range(q):
                kth = np.sort(want[s])[k - 1]
                top = np.argsort(got[s], kind="stable")[:k]
                assert (want[s][top] <= kth + 1e-5).all(), (cand, s)

    def test_sweeps1_rejects_tile_smaller_than_vx(self):
        counts, q_hat = _case(16, 300, 2)
        with pytest.raises(ValueError, match="sweep"):
            l1_distance_multi_pallas(counts, q_hat, x_tile=128, sweeps=1,
                                     interpret=True)

    def test_unusable_plan_falls_back_with_warning(self, clean_warnings):
        # pallas-unrolled is rejected above the lane bound; run_tau must
        # warn once and dispatch the default plan instead of crashing
        counts, q_hat = _case(8, 4224, 2)
        bad = autotune.TauPlan(variant="unrolled")
        with pytest.warns(UserWarning, match="fall"):
            got = autotune.run_tau(counts, q_hat, plan=bad, engine="pallas",
                                   interpret=True)
        want = autotune.run_tau(counts, q_hat, plan=autotune.DEFAULT_TAU,
                                engine="pallas", interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestRegistryPersistence:
    def _populated(self, backend="cpu"):
        reg = autotune.PlanRegistry(backend=backend)
        reg.tau[autotune.tau_key(64, 300, 4)] = autotune.TauPlan(variant="xla")
        reg.tau[autotune.tau_key(256, 256, 8)] = autotune.TauPlan(lowprec=True)
        reg.ingest[autotune.ingest_key(64, 300)] = autotune.IngestPlan(fused=False)
        return reg

    def test_save_load_roundtrip_byte_stable(self, tmp_path):
        reg = self._populated()
        path = reg.save(tmp_path / "cpu.json")
        loaded = autotune.PlanRegistry.load(path=path, backend="cpu")
        assert loaded.decisions() == reg.decisions()
        assert loaded.tau_plan(64, 300, 4) == autotune.TauPlan(variant="xla")
        assert loaded.ingest_plan(64, 300) == autotune.IngestPlan(fused=False)
        # a second save of the loaded registry emits identical bytes
        bytes1 = path.read_text()
        loaded.save(tmp_path / "again.json")
        assert (tmp_path / "again.json").read_text() == bytes1

    def test_missing_file_is_silent_defaults(self, tmp_path, clean_warnings):
        import warnings as w
        with w.catch_warnings():
            w.simplefilter("error")  # any warning would raise
            reg = autotune.PlanRegistry.load(path=tmp_path / "absent.json",
                                             backend="cpu")
        assert reg.tau_plan(64, 300, 4) == autotune.DEFAULT_TAU
        assert reg.ingest_plan(64, 300) == autotune.DEFAULT_INGEST

    def test_stale_schema_warns_and_defaults(self, tmp_path, clean_warnings):
        reg = self._populated()
        path = reg.save(tmp_path / "cpu.json")
        doc = json.loads(path.read_text())
        doc["schema"] = autotune.PLAN_SCHEMA + 1
        path.write_text(json.dumps(doc))
        with pytest.warns(UserWarning, match="schema"):
            loaded = autotune.PlanRegistry.load(path=path, backend="cpu")
        assert not loaded.tau and not loaded.ingest
        assert loaded.tau_plan(64, 300, 4) == autotune.DEFAULT_TAU

    def test_pre_metric_schema1_warns_and_defaults(self, tmp_path, clean_warnings):
        # The exact committed shape BEFORE the metric axis (PR <= 8):
        # schema 1, tau keys without a metric= field. Such files must
        # warn once and serve defaults — old keys must never be
        # misread as plans for the current schema.
        path = tmp_path / "cpu.json"
        path.write_text(json.dumps({
            "schema": 1,
            "backend": "cpu",
            "tau": {"vz=256,vx=256,q=4,dtype=float32": {"variant": "pallas"}},
            "ingest": {"vz=256,vx=256": {"fused": True}},
        }))
        with pytest.warns(UserWarning, match="schema"):
            loaded = autotune.PlanRegistry.load(path=path, backend="cpu")
        assert not loaded.tau and not loaded.ingest
        assert loaded.tau_plan(256, 256, 4) == autotune.DEFAULT_TAU
        assert loaded.ingest_plan(256, 256) == autotune.DEFAULT_INGEST

    def test_corrupt_json_warns_and_defaults(self, tmp_path, clean_warnings):
        path = tmp_path / "cpu.json"
        path.write_text("{not json")
        with pytest.warns(UserWarning, match="unreadable"):
            loaded = autotune.PlanRegistry.load(path=path, backend="cpu")
        assert loaded.tau_plan(1, 1, 1) == autotune.DEFAULT_TAU

    def test_backend_mismatch_warns_and_defaults(self, tmp_path, clean_warnings):
        path = self._populated(backend="tpu").save(tmp_path / "tpu.json")
        with pytest.warns(UserWarning, match="backend"):
            loaded = autotune.PlanRegistry.load(path=path, backend="cpu")
        assert not loaded.tau

    def test_malformed_entry_dropped_not_fatal(self, tmp_path, clean_warnings):
        reg = self._populated()
        path = reg.save(tmp_path / "cpu.json")
        doc = json.loads(path.read_text())
        doc["tau"][autotune.tau_key(64, 300, 4)]["variant"] = "warp-drive"
        path.write_text(json.dumps(doc))
        with pytest.warns(UserWarning, match="malformed"):
            loaded = autotune.PlanRegistry.load(path=path, backend="cpu")
        # the bad entry is gone (lookup -> default), the good ones survive
        assert loaded.tau_plan(64, 300, 4) == autotune.DEFAULT_TAU
        assert loaded.tau_plan(256, 256, 8) == autotune.TauPlan(lowprec=True)
        assert loaded.ingest_plan(64, 300) == autotune.IngestPlan(fused=False)


class TestDispatch:
    def test_plan_arg_coercion_rejects_junk(self):
        with pytest.raises(TypeError):
            autotune.coerce_tau_plan(42, 8, 8, 1)
        with pytest.raises(TypeError):
            autotune.coerce_ingest_plan("fastest", 8, 8)

    def test_auto_dispatch_traces_the_registered_plan(self, tmp_path, monkeypatch):
        """plan="auto" is resolved at trace time from the process
        registry: with a plan file mapping this exact shape to the xla
        variant, the traced program IS the xla program."""
        reg = autotune.PlanRegistry(backend=jax.default_backend())
        reg.tau[autotune.tau_key(48, 96, 3)] = autotune.TauPlan(variant="xla")
        path = reg.save(tmp_path / f"{reg.backend}.json")
        monkeypatch.setenv("FASTMATCH_PLANS_DIR", str(tmp_path))
        autotune.reload()
        try:
            counts, q_hat = _case(48, 96, 3)
            jx_auto = str(jax.make_jaxpr(
                lambda c, t: ops.l1_distance_multi(c, t, plan="auto"))(counts, q_hat))
            jx_xla = str(jax.make_jaxpr(
                lambda c, t: ops.l1_distance_multi(
                    c, t, plan=autotune.TauPlan(variant="xla")))(counts, q_hat))
            jx_default = str(jax.make_jaxpr(
                lambda c, t: ops.l1_distance_multi(c, t, plan="default"))(counts, q_hat))
            assert jx_auto == jx_xla
            assert jx_auto != jx_default
            # an unregistered shape traces the default program
            counts2, q_hat2 = _case(40, 96, 3)
            jx_miss = str(jax.make_jaxpr(
                lambda c, t: ops.l1_distance_multi(c, t, plan="auto"))(counts2, q_hat2))
            jx_def2 = str(jax.make_jaxpr(
                lambda c, t: ops.l1_distance_multi(c, t, plan="default"))(counts2, q_hat2))
            assert jx_miss == jx_def2
        finally:
            monkeypatch.delenv("FASTMATCH_PLANS_DIR")
            autotune.reload()
        assert path.exists()

    def test_resolve_plans_tunes_on_miss_and_persists(self, tmp_path, monkeypatch):
        monkeypatch.setenv("FASTMATCH_PLANS_DIR", str(tmp_path))
        monkeypatch.setenv("FASTMATCH_AUTOTUNE", "1")
        autotune.reload()
        try:
            pair = autotune.resolve_plans(32, 32, 1, n_samples=512)
            path = autotune.plan_path()
            assert path.exists()
            doc = json.loads(path.read_text())
            assert autotune.tau_key(32, 32, 1) in doc["tau"]
            assert autotune.ingest_key(32, 32) in doc["ingest"]
            # a second resolve hits the persisted plans, no re-tuning
            monkeypatch.delenv("FASTMATCH_AUTOTUNE")
            again = autotune.reload().tau_plan(32, 32, 1)
            assert again == pair.tau
            assert autotune.resolve_plans(32, 32, 1).tau == pair.tau
        finally:
            monkeypatch.delenv("FASTMATCH_PLANS_DIR", raising=False)
            monkeypatch.delenv("FASTMATCH_AUTOTUNE", raising=False)
            autotune.reload()

    def test_without_plan_file_dispatch_matches_pre_autotune(self, tmp_path, monkeypatch):
        """Registry miss == the hard-coded pre-autotune kernels: same
        traced program as plan=None (the PR-2 dispatch), bit-stable."""
        monkeypatch.setenv("FASTMATCH_PLANS_DIR", str(tmp_path))  # empty dir
        autotune.reload()
        try:
            counts, q_hat = _case(64, 300, 3)
            jx_auto = str(jax.make_jaxpr(
                lambda c, t: ops.l1_distance_multi(c, t, plan="auto"))(counts, q_hat))
            jx_none = str(jax.make_jaxpr(
                lambda c, t: ops.l1_distance_multi(c, t, plan=None))(counts, q_hat))
            assert jx_auto == jx_none
        finally:
            monkeypatch.delenv("FASTMATCH_PLANS_DIR")
            autotune.reload()


class TestSchedulerPlans:
    def test_explicit_plans_bit_equivalent_to_default(self):
        from repro.core import multiquery as mq
        from repro.data.layout import block_layout
        from repro.data.synth import SynthSpec, make_dataset

        spec_s = SynthSpec(v_z=48, v_x=12, num_tuples=200_000, k=5, n_close=5,
                           close_distance=0.02, far_distance=0.3, zipf_a=0.9, seed=11)
        ds = make_dataset(spec_s)
        blocked = block_layout(ds.z, ds.x, v_z=spec_s.v_z, v_x=spec_s.v_x,
                               block_size=256, seed=11)
        spec = mq.MultiQuerySpec(v_z=spec_s.v_z, v_x=spec_s.v_x, max_queries=2)
        exotic = autotune.PlanPair(tau=autotune.TauPlan(variant="xla", lowprec=True),
                                   ingest=autotune.IngestPlan(fused=False))
        results = []
        for plans in (None, exotic):
            sched = mq.SharedCountsScheduler(blocked, spec, window=32, seed=0,
                                             plans=plans)
            sched.admit(ds.target, k=5, eps=0.08, delta=0.05)
            sched.run_window(sched.order[:32])
            results.append((np.asarray(sched.state.counts),
                            np.asarray(sched.state.n),
                            np.asarray(sched.state.delta_upper)))
        for a, b in zip(results[0], results[1]):
            np.testing.assert_array_equal(a, b)


@pytest.mark.slow
class TestCrossProcess:
    def test_committed_plan_dispatches_byte_stable_across_processes(self, tmp_path):
        reg = autotune.PlanRegistry(backend="cpu")
        reg.tau[autotune.tau_key(64, 300, 4)] = autotune.TauPlan(variant="xla")
        reg.ingest[autotune.ingest_key(64, 300)] = autotune.IngestPlan(fused=False)
        reg.save(tmp_path / "cpu.json")
        prog = (
            "import os; os.environ['FASTMATCH_PLANS_DIR'] = r'%s'\n"
            "from repro.kernels import autotune\n"
            "import sys; sys.stdout.write(autotune.registry().decisions())\n"
        ) % str(tmp_path)
        outs = [
            subprocess.run([sys.executable, "-c", prog], capture_output=True,
                           text=True, check=True).stdout
            for _ in range(2)
        ]
        assert outs[0] == outs[1] == reg.decisions()


def test_tau_bytes_model_orders_variants_sanely():
    v_z, v_x = 4096, 1024
    b = {v: autotune.tau_bytes(v_z, v_x, 8, autotune.TauPlan(variant=v))
         for v in autotune.TAU_VARIANTS}
    assert b["batched"] < b["unrolled"]  # one counts sweep vs Q sweeps
    low = autotune.tau_bytes(v_z, v_x, 8, autotune.TauPlan(lowprec=True))
    assert low < b["batched"]  # uint16 halves the counts term
    asdict = dataclasses.asdict(autotune.TauPlan())
    assert set(asdict) == {"variant", "z_tile", "x_tile", "sweeps", "lowprec"}
