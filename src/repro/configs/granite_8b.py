"""granite-8b [dense] — llama-arch code model [arXiv:2405.04324; hf]."""

from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch_id="granite_8b",
        family="dense",
        num_layers=36,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=49152,
        rope_theta=1e4,
        norm_eps=1e-5,
        optimizer="adamw",
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="granite_8b_smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=160,
        vocab_size=512,
        norm_eps=1e-5,
    )
