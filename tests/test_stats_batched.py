"""Golden equivalence: Q-batched statistics kernels vs the unrolled PR-2 path.

The PR-2 multi-query loop unrolled `ops.l1_distance` once per query
slot — Q separate HBM passes over the shared counts matrix per
statistics iteration — and `ingest` re-read the delta matrix for a
separate ``jnp.sum(delta, axis=1)``. This suite pins the batched
engine to those semantics:

  * `ops.l1_distance_multi` (interpret-mode Pallas AND the batched ref)
    must be bit-identical to Q unrolled `ops.l1_distance` calls on
    integer-valued counts, sweeping Q in {1, 3, 8} and V_X in
    {64, 4096, 8192} — the last exercising the lifted `_MAX_VX = 4096`
    single-block rejection of the PR-2 kernel;
  * `ops.histogram_with_rowsums` must equal `ops.histogram` plus the
    separate full-matrix reduction, exactly;
  * `multiquery.stats_step` must reproduce the PR-2 unrolled loop for
    every OCCUPIED slot, with empty slots masked (tau pinned at the
    init value 1.0) instead of burning a pass against a stale q_hat;
  * mid-stream admission into a previously-retired slot must behave as
    if the slot had never been used.

Counts are integer-valued f32 throughout (they are histograms): every
f32 sum below 2^24 is exact regardless of reduction order, which is
what makes bit-equality across kernel layouts a meaningful contract.
"""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import multiquery as mq
from repro.data.layout import block_layout
from repro.data.synth import SynthSpec, make_dataset, perturb_distribution
from repro.kernels import ops, ref
from repro.kernels.l1_distance import l1_distance_pallas
from repro.kernels.l1_distance_multi import l1_distance_multi_pallas

_MAX_VX_PR2 = 4096  # the single-query kernel's single-block bound


def _counts(rng, v_z, v_x, zero_rows=0.2):
    c = rng.integers(0, 40, size=(v_z, v_x)).astype(np.float32)
    c[rng.random(v_z) < zero_rows] = 0.0  # some never-sampled candidates
    return c


def _targets(rng, q, v_x):
    return np.stack([rng.dirichlet(np.ones(v_x)).astype(np.float32) for _ in range(q)])


class TestL1DistanceMultiGolden:
    @pytest.mark.parametrize("q", [1, 3, 8])
    @pytest.mark.parametrize("v_x", [64, 4096, 8192])
    def test_bit_identical_to_unrolled(self, q, v_x, rng):
        """Batched ref == Q unrolled PR-2 ref calls, bit for bit; the
        interpret-mode Pallas kernel matches on its single-sweep path
        and to 1 ulp per lane tile when V_X is lane-tiled."""
        v_z = 96
        counts = jnp.asarray(_counts(rng, v_z, v_x))
        q_hat = jnp.asarray(_targets(rng, q, v_x))

        unrolled = np.stack(
            [np.asarray(ops.l1_distance(counts, q_hat[i])) for i in range(q)]
        )
        batched = np.asarray(ops.l1_distance_multi(counts, q_hat))
        np.testing.assert_array_equal(batched, unrolled)

        got = np.asarray(l1_distance_multi_pallas(counts, q_hat, interpret=True))
        if v_x <= _MAX_VX_PR2:  # single sweep: same reduction order
            np.testing.assert_array_equal(got, unrolled)
        else:  # lane-tiled: per-tile partial sums may differ in the last ulp
            np.testing.assert_allclose(got, unrolled, atol=3e-6)

    @pytest.mark.parametrize("v_x", [64, 512])
    def test_pallas_matches_pr2_kernel_bitwise(self, v_x, rng):
        """On the PR-2 kernel's own domain the batched kernel is the
        same arithmetic: interpret-mode outputs are bit-identical."""
        v_z, q = 200, 4
        counts = jnp.asarray(_counts(rng, v_z, v_x))
        q_hat = jnp.asarray(_targets(rng, q, v_x))
        multi = np.asarray(l1_distance_multi_pallas(counts, q_hat, interpret=True))
        for i in range(q):
            single = np.asarray(l1_distance_pallas(counts, q_hat[i], interpret=True))
            np.testing.assert_array_equal(multi[i], single, err_msg=f"slot {i}")

    def test_lifts_pr2_vx_bound(self, rng):
        """V_X past 4096: the PR-2 kernel rejects, the batched kernel
        lane-tiles and matches the oracle."""
        v_z, v_x = 48, 6000
        counts = jnp.asarray(_counts(rng, v_z, v_x))
        q_hat = jnp.asarray(_targets(rng, 2, v_x))
        with pytest.raises(ValueError, match="exceeds single-block"):
            l1_distance_pallas(counts, q_hat[0], interpret=True)
        got = l1_distance_multi_pallas(counts, q_hat, interpret=True)
        want = ref.l1_distance_multi_ref(counts, q_hat)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-6)

    def test_zero_mass_rows_and_q1_specialization(self, rng):
        """Empty candidates report ||q_hat||_1 (= 1) in every slot; the
        Q=1 batch equals the single-query entry point exactly."""
        v_z, v_x = 33, 24
        counts = _counts(rng, v_z, v_x)
        counts[5] = 0.0
        q_hat = jnp.asarray(_targets(rng, 1, v_x))
        batched = np.asarray(ops.l1_distance_multi(jnp.asarray(counts), q_hat))
        assert batched[0, 5] == pytest.approx(1.0, abs=1e-6)
        single = np.asarray(ops.l1_distance(jnp.asarray(counts), q_hat[0]))
        np.testing.assert_array_equal(batched[0], single)


class TestHistogramWithRowsumsGolden:
    @pytest.mark.parametrize("v_z,v_x,n", [(161, 24, 5000), (64, 161, 1000), (10, 2, 100)])
    def test_equals_histogram_plus_reduction(self, v_z, v_x, n, rng):
        """The fused pass == the PR-2 two-step (histogram, then a
        separate jnp.sum over the delta matrix), exactly — every impl."""
        z = jnp.asarray(rng.integers(-1, v_z, size=n).astype(np.int32))
        x = jnp.asarray(rng.integers(-1, v_x, size=n).astype(np.int32))
        want_c = ops.histogram(z, x, v_z=v_z, v_x=v_x)
        want_r = jnp.sum(want_c, axis=1)
        for kwargs in (
            dict(impl="ref"),
            dict(impl="matmul"),
            dict(impl="pallas", interpret=True),
        ):
            c, r = ops.histogram_with_rowsums(z, x, v_z=v_z, v_x=v_x, **kwargs)
            np.testing.assert_array_equal(np.asarray(c), np.asarray(want_c), err_msg=str(kwargs))
            np.testing.assert_array_equal(np.asarray(r), np.asarray(want_r), err_msg=str(kwargs))

    def test_rowsums_count_only_fully_valid_pairs(self):
        """A sample with valid z but invalid x must not advance n_i —
        rows are the row sums of what was actually binned."""
        z = jnp.asarray([0, 1, 1, 2, -1], jnp.int32)
        x = jnp.asarray([0, -1, 1, 99, 0], jnp.int32)
        c, r = ops.histogram_with_rowsums(z, x, v_z=3, v_x=2)
        np.testing.assert_array_equal(np.asarray(r), [1.0, 1.0, 0.0])
        np.testing.assert_array_equal(np.asarray(r), np.asarray(c).sum(axis=1))


def _argsort_assignment(tau, n, *, k, eps, delta, v_x):
    """The pre-top_k deviation selection (full stable argsort + rank
    scatter), kept verbatim as the tie-behavior oracle."""
    from repro.core import bounds

    tau = jnp.asarray(tau, jnp.float32)
    v_z = tau.shape[0]
    kj = jnp.asarray(k, jnp.int32)
    order = jnp.argsort(tau, stable=True)
    ranks = jnp.zeros((v_z,), jnp.int32).at[order].set(jnp.arange(v_z, dtype=jnp.int32))
    in_m = ranks < kj
    sorted_tau = tau[order]
    kth = sorted_tau[jnp.clip(kj - 1, 0, v_z - 1)]
    k1th = sorted_tau[jnp.clip(kj, 0, v_z - 1)]
    s = jnp.where(kj >= v_z, jnp.max(tau), 0.5 * (kth + k1th))
    eps_in = jnp.minimum(eps, s + 0.5 * eps - tau)
    eps_out = tau - jnp.maximum(s - 0.5 * eps, 0.0)
    eps_i = jnp.maximum(jnp.where(in_m, eps_in, eps_out), 0.0)
    log_delta_i = bounds.theorem1_log_delta(eps_i, jnp.asarray(n, jnp.float32), v_x)
    delta_upper = jnp.sum(jnp.exp(log_delta_i))
    active = log_delta_i > jnp.log(delta / float(v_z))
    return in_m, s, eps_i, delta_upper, active


class TestTopKSelectionRegression:
    def test_identical_on_ties(self):
        """Regression for the argsort -> lax.top_k rewrite in
        `assign_deviations_dynamic`: heavy ties across the k boundary
        must produce the same M (by-index tie break), split point,
        eps_i, delta_upper and active set — for every k_cap, including
        the None (V_Z order stats) fallback."""
        from repro.core import deviations as dev

        rng = np.random.default_rng(3)
        eps, delta, v_x = 0.06, 0.01, 24
        tie_vectors = [
            np.repeat([0.1, 0.1, 0.3, 0.3, 0.3, 0.7], 4),  # ties straddle k
            np.zeros(17, np.float32),  # everything tied at zero
            np.repeat(0.42, 9),  # everything tied, nonzero
            np.asarray([0.2, 0.1, 0.2, 0.1, 0.2, 0.1, 0.2, 0.1]),  # interleaved
        ]
        for tau in tie_vectors:
            tau = np.asarray(tau, np.float32)
            n = rng.integers(1, 10**5, size=len(tau)).astype(np.float32)
            for k in (1, 2, len(tau) // 2, len(tau) - 1):
                want = _argsort_assignment(tau, n, k=k, eps=eps, delta=delta, v_x=v_x)
                for k_cap in (None, k, k + 3, len(tau)):
                    d = dev.assign_deviations_dynamic(
                        jnp.asarray(tau), jnp.asarray(n),
                        k=jnp.int32(k), eps=jnp.float32(eps),
                        delta=jnp.float32(delta), v_x=v_x, k_cap=k_cap,
                    )
                    got = (d.in_top_k, d.split, d.eps_i, d.delta_upper, d.active)
                    names = ("in_top_k", "split", "eps_i", "delta_upper", "active")
                    for g, w, name in zip(got, want, names):
                        np.testing.assert_array_equal(
                            np.asarray(g), np.asarray(w),
                            err_msg=f"{name} k={k} k_cap={k_cap} tau={tau[:6]}",
                        )

    def test_static_entry_point_matches_dynamic(self):
        """`assign_deviations` (k_cap = its static k) stays bitwise equal
        to the uncapped dynamic path on tied inputs."""
        from repro.core import deviations as dev

        tau = jnp.asarray(np.repeat([0.05, 0.2, 0.2, 0.6], 3), jnp.float32)
        n = jnp.full((12,), 4e4, jnp.float32)
        a = dev.assign_deviations(tau, n, k=4, eps=0.06, delta=0.01, v_x=24)
        b = dev.assign_deviations_dynamic(
            tau, n, k=jnp.int32(4), eps=jnp.float32(0.06),
            delta=jnp.float32(0.01), v_x=24, k_cap=None,
        )
        for f in a._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(a, f)), np.asarray(getattr(b, f)), err_msg=f
            )

    def test_top_k_mask_ties_by_index(self):
        """`top_k_mask` keeps the |M| = k contract under ties and picks
        the lower-index candidates (the stable-argsort rule)."""
        from repro.core import deviations as dev

        tau = jnp.asarray([0.5, 0.2, 0.2, 0.2, 0.9], jnp.float32)
        m = np.asarray(dev.top_k_mask(tau, 2))
        np.testing.assert_array_equal(m, [False, True, True, False, False])
        assert m.sum() == 2


@partial(jax.jit, static_argnames=("spec",))
def _pr2_stats_step(state, *, spec):
    """The PR-2 statistics iteration, reconstructed: one `ops.l1_distance`
    call per slot (including empty ones), then the shared assignment.
    Jitted exactly like `mq.stats_step` so the comparison isolates the
    tau computation (XLA fuses an eager tail differently at the ulp
    level, which would test the compiler, not the kernels)."""
    tau = jnp.stack(
        [ops.l1_distance(state.counts, state.q_hat[i]) for i in range(spec.max_queries)]
    )
    return mq.apply_stats(state, tau, state.n, spec=spec)


class TestStatsStepGolden:
    @pytest.fixture(scope="class")
    def setting(self):
        spec_s = SynthSpec(
            v_z=48, v_x=16, num_tuples=200_000, k=5, n_close=5,
            close_distance=0.02, far_distance=0.3, zipf_a=0.9, seed=31,
        )
        ds = make_dataset(spec_s)
        blocked = block_layout(ds.z, ds.x, v_z=48, v_x=16, block_size=512, seed=31)
        rng = np.random.default_rng(17)
        targets = [ds.target] + [
            perturb_distribution(ds.target, d, rng) for d in (0.01, 0.03, 0.05)
        ]
        return spec_s, ds, blocked, targets

    @staticmethod
    def _admit(state, spec, slot, target, k=5, eps=0.08, delta=0.05):
        q = np.asarray(target, np.float64).ravel()
        q = (q / q.sum()).astype(np.float32)
        return mq.admit_slot(
            state, jnp.asarray(slot, jnp.int32), jnp.asarray(q),
            jnp.asarray(k, jnp.int32), jnp.asarray(eps, jnp.float32),
            jnp.asarray(delta, jnp.float32), spec=spec,
        )

    def _ingested_state(self, setting, spec, slots_targets):
        _, _, blocked, _ = setting
        state = mq.init_multi_state(spec)
        for slot, t in slots_targets:
            state = self._admit(state, spec, slot, t)
        z = jnp.asarray(blocked.z_blocks[:40].reshape(-1))
        x = jnp.asarray(blocked.x_blocks[:40].reshape(-1))
        return mq.ingest(state, z, x, spec=spec)

    def test_occupied_slots_bit_identical_to_pr2(self, setting):
        """Full house: every per-slot statistic out of the batched step
        equals the PR-2 unrolled step bit for bit."""
        _, _, _, targets = setting
        spec = mq.MultiQuerySpec(v_z=48, v_x=16, max_queries=4)
        state = self._ingested_state(setting, spec, list(enumerate(targets)))
        got = mq.stats_step(state, spec=spec)
        want = _pr2_stats_step(state, spec=spec)
        for f in ("tau", "eps_i", "log_delta_i", "delta_upper", "active",
                  "active_words", "union_words", "in_top_k"):
            np.testing.assert_array_equal(
                np.asarray(getattr(got, f)), np.asarray(getattr(want, f)), err_msg=f
            )

    def test_empty_slots_masked_not_stale(self, setting):
        """Slots 2-3 empty: occupied statistics still match PR-2, and the
        empty slots' tau is pinned at the init value 1.0 instead of a
        stale-q_hat l1 pass. Downstream per-slot outputs stay masked."""
        _, _, _, targets = setting
        spec = mq.MultiQuerySpec(v_z=48, v_x=16, max_queries=4)
        state = self._ingested_state(setting, spec, [(0, targets[0]), (1, targets[1])])
        got = mq.stats_step(state, spec=spec)
        want = _pr2_stats_step(state, spec=spec)
        for slot in (0, 1):
            np.testing.assert_array_equal(
                np.asarray(got.tau[slot]), np.asarray(want.tau[slot]), err_msg=str(slot)
            )
        np.testing.assert_array_equal(np.asarray(got.union_words), np.asarray(want.union_words))
        for slot in (2, 3):
            np.testing.assert_array_equal(np.asarray(got.tau[slot]), np.ones(48, np.float32))
            assert float(got.delta_upper[slot]) == 0.0
            assert not np.asarray(got.active[slot]).any()
            assert not np.asarray(got.in_top_k[slot]).any()

    def test_readmission_into_retired_slot_unaffected(self, setting):
        """Retire slot 0, admit a different query into it: every statistic
        must equal a fresh state that only ever saw the new query."""
        _, _, _, targets = setting
        spec = mq.MultiQuerySpec(v_z=48, v_x=16, max_queries=2)
        state = self._ingested_state(setting, spec, [(0, targets[0]), (1, targets[1])])
        state = mq.stats_step(state, spec=spec)
        state = mq.clear_slot(state, jnp.asarray(0, jnp.int32), spec=spec)
        state = self._admit(state, spec, 0, targets[2], k=3, eps=0.1, delta=0.02)
        got = mq.stats_step(state, spec=spec)

        fresh = self._ingested_state(setting, spec, [(1, targets[1])])
        fresh = self._admit(fresh, spec, 0, targets[2], k=3, eps=0.1, delta=0.02)
        want = mq.stats_step(fresh, spec=spec)
        for f in ("tau", "eps_i", "log_delta_i", "delta_upper", "active",
                  "active_words", "union_words", "in_top_k", "occupied"):
            np.testing.assert_array_equal(
                np.asarray(getattr(got, f)), np.asarray(getattr(want, f)), err_msg=f
            )

    def test_mid_stream_admission_sees_shared_counts(self, setting):
        """An admission between rounds picks up the accumulated shared
        counts: its post-admission stats equal a PR-2 unrolled step on
        the same state (the late-query soundness property)."""
        _, _, blocked, targets = setting
        spec = mq.MultiQuerySpec(v_z=48, v_x=16, max_queries=3)
        state = self._ingested_state(setting, spec, [(0, targets[0])])
        state = mq.stats_step(state, spec=spec)
        z = jnp.asarray(blocked.z_blocks[40:80].reshape(-1))
        x = jnp.asarray(blocked.x_blocks[40:80].reshape(-1))
        state = mq.ingest(state, z, x, spec=spec)
        state = self._admit(state, spec, 1, targets[3])
        got = mq.stats_step(state, spec=spec)
        want = _pr2_stats_step(state, spec=spec)
        for slot in (0, 1):
            np.testing.assert_array_equal(
                np.asarray(got.tau[slot]), np.asarray(want.tau[slot]), err_msg=str(slot)
            )
            np.testing.assert_array_equal(
                np.asarray(got.eps_i[slot]), np.asarray(want.eps_i[slot]), err_msg=str(slot)
            )
        assert float(got.n.sum()) == float(state.n.sum()) > 0
