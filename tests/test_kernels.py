"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; skip on minimal installs
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.anyactive import anyactive_pallas
from repro.kernels.histogram import histogram_pallas
from repro.kernels.l1_distance import l1_distance_pallas
from repro.kernels import ops


HIST_SHAPES = [
    (161, 24, 5_000),
    (7548, 24, 2_000),
    (64, 161, 1_000),
    (10, 2, 100),
    (300, 7, 777),
    (1, 1, 16),
    (2110, 5, 3_000),
]


class TestHistogramKernel:
    @pytest.mark.parametrize("v_z,v_x,n", HIST_SHAPES)
    def test_matches_oracle(self, v_z, v_x, n, rng):
        z = rng.integers(-1, v_z, size=n).astype(np.int32)
        x = rng.integers(-1, v_x, size=n).astype(np.int32)
        got = histogram_pallas(jnp.asarray(z), jnp.asarray(x), v_z=v_z, v_x=v_x, interpret=True)
        want = ref.histogram_ref(jnp.asarray(z), jnp.asarray(x), v_z=v_z, v_x=v_x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("s_tile,z_tile", [(64, 32), (512, 256), (128, 1024)])
    def test_tile_sweep(self, s_tile, z_tile, rng):
        v_z, v_x, n = 200, 30, 1500
        z = rng.integers(0, v_z, size=n).astype(np.int32)
        x = rng.integers(0, v_x, size=n).astype(np.int32)
        got = histogram_pallas(
            jnp.asarray(z), jnp.asarray(x), v_z=v_z, v_x=v_x,
            s_tile=s_tile, z_tile=z_tile, interpret=True,
        )
        want = ref.histogram_ref(jnp.asarray(z), jnp.asarray(x), v_z=v_z, v_x=v_x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want))

    def test_total_mass_conserved(self, rng):
        v_z, v_x, n = 50, 11, 999
        z = rng.integers(0, v_z, size=n).astype(np.int32)
        x = rng.integers(0, v_x, size=n).astype(np.int32)
        got = histogram_pallas(jnp.asarray(z), jnp.asarray(x), v_z=v_z, v_x=v_x, interpret=True)
        assert float(got.sum()) == n

    def test_out_of_range_dropped(self):
        z = jnp.asarray([0, 5, 99, -1], jnp.int32)
        x = jnp.asarray([0, 1, 0, 0], jnp.int32)
        got = histogram_pallas(z, x, v_z=4, v_x=2, interpret=True)
        assert float(got.sum()) == 1.0  # only (0, 0) is in range


class TestL1DistanceKernel:
    @pytest.mark.parametrize("v_z,v_x", [(161, 24), (7548, 12), (33, 161), (5, 2), (256, 2048)])
    def test_matches_oracle(self, v_z, v_x, rng):
        counts = (rng.random((v_z, v_x)) * 100).astype(np.float32)
        counts[rng.random(v_z) < 0.2] = 0.0  # some empty rows
        q = rng.dirichlet(np.ones(v_x)).astype(np.float32)
        got = l1_distance_pallas(jnp.asarray(counts), jnp.asarray(q), interpret=True)
        want = ref.l1_distance_ref(jnp.asarray(counts), jnp.asarray(q))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

    def test_distance_range(self, rng):
        counts = (rng.random((64, 16)) * 50).astype(np.float32)
        q = rng.dirichlet(np.ones(16)).astype(np.float32)
        tau = np.asarray(l1_distance_pallas(jnp.asarray(counts), jnp.asarray(q), interpret=True))
        assert (tau >= -1e-6).all() and (tau <= 2.0 + 1e-5).all()

    def test_identical_distribution_zero(self):
        q = jnp.asarray([0.25, 0.25, 0.5], jnp.float32)
        counts = q[None, :] * 400
        tau = l1_distance_pallas(counts, q, interpret=True)
        assert float(tau[0]) == pytest.approx(0.0, abs=1e-6)

    def test_rejects_oversize_vx(self):
        with pytest.raises(ValueError):
            l1_distance_pallas(jnp.zeros((8, 5000)), jnp.zeros((5000,)), interpret=True)


class TestAnyActiveKernel:
    @pytest.mark.parametrize("nb,v_z", [(1000, 161), (333, 7548), (17, 33), (4096, 64)])
    def test_matches_oracle(self, nb, v_z, rng):
        w = -(-v_z // 32)
        bm = rng.integers(0, 2**32, size=(nb, w), dtype=np.uint32)
        mask = rng.integers(0, 2**32, size=(w,), dtype=np.uint32)
        got = anyactive_pallas(jnp.asarray(bm), jnp.asarray(mask), interpret=True)
        want = ref.anyactive_ref(jnp.asarray(bm), jnp.asarray(mask))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_empty_mask_skips_all(self, rng):
        bm = rng.integers(0, 2**32, size=(100, 3), dtype=np.uint32)
        got = anyactive_pallas(jnp.asarray(bm), jnp.zeros((3,), jnp.uint32), interpret=True)
        assert not np.asarray(got).any()

    def test_full_mask_reads_nonempty(self, rng):
        bm = rng.integers(0, 2**32, size=(100, 3), dtype=np.uint32)
        bm[0] = 0
        mask = np.full((3,), 0xFFFFFFFF, dtype=np.uint32)
        got = np.asarray(anyactive_pallas(jnp.asarray(bm), jnp.asarray(mask), interpret=True))
        assert not got[0]
        assert got[1:].sum() == (np.asarray(bm[1:]).any(axis=1)).sum()


class TestOpsDispatch:
    def test_ref_on_cpu_by_default(self):
        assert ops.default_impl() == ("pallas" if jax.default_backend() == "tpu" else "ref")

    def test_histogram_jit_shapes(self, rng):
        z = jnp.asarray(rng.integers(0, 10, 100), jnp.int32)
        x = jnp.asarray(rng.integers(0, 5, 100), jnp.int32)
        out = ops.histogram(z, x, v_z=10, v_x=5)
        assert out.shape == (10, 5) and out.dtype == jnp.float32

    @given(seed=st.integers(0, 100))
    @settings(deadline=None, max_examples=20)
    def test_pallas_ref_agree_property(self, seed):
        rng = np.random.default_rng(seed)
        v_z = int(rng.integers(2, 400))
        v_x = int(rng.integers(2, 200))
        n = int(rng.integers(1, 2000))
        z = jnp.asarray(rng.integers(-1, v_z, n), jnp.int32)
        x = jnp.asarray(rng.integers(-1, v_x, n), jnp.int32)
        a = ops.histogram(z, x, v_z=v_z, v_x=v_x, impl="pallas", interpret=True)
        b = ops.histogram(z, x, v_z=v_z, v_x=v_x, impl="ref")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
