"""Multi-pod distributed HistSim (DESIGN.md Sec 2, last row).

Production layout on a ("pod", "data", "model") mesh:

  * corpus blocks   — range-sharded over ("pod", "data"): each worker owns
                      a contiguous range of the shuffled layout (locality,
                      Challenge 1) and ingests only its own blocks.
  * counts matrix   — candidate-sharded over "model": each model shard
                      owns V_Z / |model| candidate rows.
  * per round       — each (pod, data) shard histograms its local samples
                      *restricted to the candidate rows of its model
                      shard* (one-hot matmul, so restriction is an index
                      shift, not a gather), then a single psum over
                      ("pod", "data") merges partial counts: the paper's
                      r_partial spinlock handoff becomes one fused
                      all-reduce of a (V_Z/m, V_X) f32 tile.
  * statistics      — tau_i computed locally per model shard (row-local),
                      then one all-gather of (V_Z,) floats + replicated
                      deviation assignment (O(V_Z log V_Z), trivially
                      cheap). The active mask (V_Z bits packed) returns to
                      every shard — the only "control plane" traffic.

Communication per round: one psum of the counts delta + one all-gather
of V_Z f32 — independent of the number of samples ingested. Sample bytes
never cross the network; this is what makes the engine scale to 1000+
nodes (see EXPERIMENTS.md §Dry-run for measured collective bytes).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import deviations as dev
from repro.core.bitmap import pack_active_mask
from repro.core.histsim import HistSimParams
from repro.kernels import ops

__all__ = ["ShardedHistSimState", "init_sharded_state", "make_distributed_round"]


class ShardedHistSimState(NamedTuple):
    counts: jax.Array  # (V_Z, V_X) — sharded P("model", None)
    n: jax.Array  # (V_Z,) — sharded P("model")
    q_hat: jax.Array  # (V_X,) — replicated
    tau: jax.Array  # (V_Z,) — replicated (post all-gather)
    delta_upper: jax.Array  # () — replicated
    active_words: jax.Array  # (W,) uint32 — replicated
    in_top_k: jax.Array  # (V_Z,) bool — replicated
    round_idx: jax.Array  # () i32


def init_sharded_state(params: HistSimParams, target: jax.Array) -> ShardedHistSimState:
    target = jnp.asarray(target, jnp.float32)
    q_hat = target / jnp.maximum(jnp.sum(target), 1e-30)
    v_z, v_x = params.v_z, params.v_x
    return ShardedHistSimState(
        counts=jnp.zeros((v_z, v_x), jnp.float32),
        n=jnp.zeros((v_z,), jnp.float32),
        q_hat=q_hat,
        tau=jnp.ones((v_z,), jnp.float32),
        delta_upper=jnp.asarray(float(v_z), jnp.float32),
        active_words=pack_active_mask(jnp.ones((v_z,), bool)),
        in_top_k=jnp.zeros((v_z,), bool),
        round_idx=jnp.asarray(0, jnp.int32),
    )


def state_pspecs(data_axes=("data",), model_axis="model"):
    """PartitionSpecs for ShardedHistSimState fields."""
    return ShardedHistSimState(
        counts=P(model_axis, None),
        n=P(model_axis),
        q_hat=P(),
        tau=P(),
        delta_upper=P(),
        active_words=P(),
        in_top_k=P(),
        round_idx=P(),
    )


def make_distributed_round(
    mesh,
    params: HistSimParams,
    *,
    data_axes=("data",),
    model_axis="model",
    histogram_impl: str = "auto",
    onehot_dtype=jnp.float32,
):
    """Build the jitted shard_map round for a given mesh.

    The returned function has signature (state, z_idx, x_idx) -> state,
    where z_idx/x_idx are (N,) int32 sharded over ``data_axes`` — the
    samples each worker read from its own block range this round
    (padding = -1). All-reduce structure is as documented above.
    """
    model_size = mesh.shape[model_axis]
    if params.v_z % model_size != 0:
        raise ValueError(
            f"V_Z={params.v_z} must divide by model axis size {model_size} "
            "(pad candidates to a multiple; padded rows are never sampled)"
        )
    vz_shard = params.v_z // model_size
    sample_axes = tuple(data_axes)

    def round_fn(state: ShardedHistSimState, z_idx: jax.Array, x_idx: jax.Array):
        # ---- ingest: local histogram restricted to this model shard's rows
        shard_id = jax.lax.axis_index(model_axis)
        z_local = z_idx - shard_id * vz_shard
        z_local = jnp.where((z_local >= 0) & (z_local < vz_shard), z_local, -1)
        h = ops.histogram(
            z_local, x_idx, v_z=vz_shard, v_x=params.v_x,
            impl=histogram_impl, onehot_dtype=onehot_dtype,
        )
        # one fused all-reduce of the counts delta over the data axes
        h = jax.lax.psum(h, sample_axes)
        counts = state.counts + h
        n = state.n + jnp.sum(h, axis=1)

        # ---- statistics: row-local tau, tiny all-gather, replicated assign
        tau_shard = ops.l1_distance(counts, state.q_hat)
        tau = jax.lax.all_gather(tau_shard, model_axis, tiled=True)
        n_full = jax.lax.all_gather(n, model_axis, tiled=True)
        d = dev.assign_deviations(
            tau, n_full, k=params.k, eps=params.eps, delta=params.delta, v_x=params.v_x
        )
        return ShardedHistSimState(
            counts=counts,
            n=n,
            q_hat=state.q_hat,
            tau=d.tau,
            delta_upper=d.delta_upper,
            active_words=pack_active_mask(d.active),
            in_top_k=d.in_top_k,
            round_idx=state.round_idx + 1,
        )

    specs = state_pspecs(data_axes=data_axes, model_axis=model_axis)
    sample_spec = P(sample_axes)
    shmapped = jax.shard_map(
        round_fn,
        mesh=mesh,
        in_specs=(specs, sample_spec, sample_spec),
        out_specs=specs,
        check_vma=False,
    )
    return jax.jit(shmapped)
