"""Multi-device numerical check of the §Perf serving path.

Runs the flash-decoding decode step (seq-sharded cache + grouped GQA +
TP-only weights) on a real (2 data x 4 model) device mesh and asserts
the logits match the single-device baseline — i.e. the optimized layout
is a pure re-sharding, not a different computation.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
class TestShardedDecode:
    def test_flash_decoding_matches_single_device(self):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        code = textwrap.dedent("""
            import dataclasses, json
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
            from repro.configs import get_smoke_config
            from repro.distributed.sharding import (
                cache_pspecs, serving_param_pspecs, batch_pspec,
            )
            from repro.models import layers as L
            from repro.models.model_zoo import get_model

            cfg = dataclasses.replace(
                get_smoke_config("llama3_405b"), d_model=128, num_heads=8,
                num_kv_heads=2, d_ff=256,
            )
            model = get_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            B, CTX = 4, 32
            tok = jax.random.randint(jax.random.PRNGKey(1), (B, CTX), 0, cfg.vocab_size)
            _, cache = model.prefill(params, tok[:, :16], CTX)

            # single-device reference (legacy path)
            ref, _ = model.decode_step(params, cache, tok[:, 16])

            # sharded flash-decoding path
            mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
            L.set_sharding_rules(None, mesh.axis_names, mesh)
            cfg_opt = dataclasses.replace(cfg, decode_seq_shard=True)
            model_opt = get_model(cfg_opt)
            p_spec = serving_param_pspecs(params, mesh)
            p_sh = jax.device_put(params, jax.tree.map(lambda s: NamedSharding(mesh, s), p_spec))
            c_spec = cache_pspecs(cache, mesh, B, seq_shard=True)
            c_sh = jax.device_put(cache, jax.tree.map(lambda s: NamedSharding(mesh, s), c_spec))
            t_sh = jax.device_put(tok[:, 16], NamedSharding(mesh, P("data")))
            with mesh:
                out, _ = jax.jit(model_opt.decode_step)(p_sh, c_sh, t_sh)
            L.clear_sharding_rules()
            diff = float(jnp.max(jnp.abs(ref - out)))
            print(json.dumps({"diff": diff}))
        """)
        out = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=900,
        )
        assert out.returncode == 0, out.stderr[-4000:]
        diff = json.loads(out.stdout.strip().splitlines()[-1])["diff"]
        assert diff < 0.05, diff  # bf16 reduction-order tolerance


@pytest.mark.slow
class TestShardedMoE:
    def test_local_dispatch_matches_single_device(self):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        code = textwrap.dedent("""
            import dataclasses, json
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
            from repro.configs import get_smoke_config
            from repro.distributed.sharding import param_pspecs
            from repro.models import layers as L
            from repro.models.model_zoo import get_model

            cfg = get_smoke_config("mixtral_8x7b")  # dropless cf=4.0 smoke
            model = get_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            B, S = 4, 16
            tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
            ref, _ = model.forward(params, tok)

            mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
            L.set_sharding_rules(None, mesh.axis_names, mesh)
            cfg_opt = dataclasses.replace(cfg, moe_impl="local")
            model_opt = get_model(cfg_opt)
            p_spec = param_pspecs(params, mesh)
            p_sh = jax.device_put(params, jax.tree.map(lambda s: NamedSharding(mesh, s), p_spec))
            t_sh = jax.device_put(tok, NamedSharding(mesh, P("data", None)))
            with mesh:
                out, _ = jax.jit(lambda p, t: model_opt.forward(p, t))(p_sh, t_sh)
            L.clear_sharding_rules()
            diff = float(jnp.max(jnp.abs(ref - out)))
            print(json.dumps({"diff": diff}))
        """)
        out = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=900,
        )
        assert out.returncode == 0, out.stderr[-4000:]
        diff = json.loads(out.stdout.strip().splitlines()[-1])["diff"]
        # dropless smoke config: no capacity drops, so only reduction-order noise
        assert diff < 0.05, diff
