"""Shared-counts multi-query HistSim — the FastMatch serving core.

The key structural fact enabling a serving layer on top of HistSim: the
counts matrix ``r_i`` accumulated by `ingest` is *target-independent* —
only ``q_hat``, ``tau``, ``eps_i`` and ``delta_i`` depend on the query.
N concurrent queries over the same dataset can therefore share ONE
counts matrix and ONE I/O stream:

  shared   — counts (V_Z, V_X), n (V_Z,), the block read_mask / cursor
  per-query — q_hat, (k, eps, delta), tau, eps_i, log_delta_i,
              delta_upper, active set, matching set M

`ingest` runs once per window for everybody (reusing the one-hot-
contraction histogram kernel); `stats_step` is vmapped over the query
axis, so each query keeps its own Problem 1 parameters and its own
termination bound. The union active set — the bitwise OR of the
per-query packed ``active_words`` — feeds the AnyActive kernel, so the
I/O manager reads a block iff *any* live query still needs it.

Sample-complexity intuition (Diakonikolas et al., Canonne et al.: the
cost of testing closeness is driven by the number of samples, not the
number of hypotheses tested against them): every tuple read is charged
once but advances all N queries, so the per-query I/O cost shrinks
roughly as 1/N, and queries admitted late start from the accumulated
shared counts instead of from zero. Soundness of a late query using
the full accumulated ``n_i`` for its Theorem 1 bounds: WHICH blocks
were read does depend on the earlier queries' targets (AnyActive marks
via their active sets), but the layout pre-shuffle assigns tuples to
blocks independently of their x-values, so for each candidate any
block-granular read policy yields a uniform without-replacement sample
of that candidate's tuples — the same paper-Sec 4.2 property the
single-query engine already relies on when AnyActive is driven by its
OWN target. Hence a late query's ``n_i`` IS the shared ``n_i``, with
no discounting. (This rests on the shuffle; on a non-shuffled layout
neither the single- nor the multi-query bounds are valid.)

Query slots are padded to a fixed ``max_queries`` so every jitted
function sees stable shapes; empty slots are masked out of the active
union and report delta_upper = 0.

`SharedCountsScheduler` below is the window-marking/ingest loop that
used to live inline in `engine.run_engine`; the single-query engine is
now the ``max_queries=1`` specialization of this loop, and
`repro.serve.fastmatch_server.MatchServer` is the many-query frontend
with admission/retirement.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import deviations as dev
from repro.core import histsim
from repro.core.bitmap import pack_active_mask, words_for
from repro.core.histsim import HistSimState
from repro.core.policies import mark_window
from repro.data.layout import BlockedDataset
from repro.kernels import ops

__all__ = [
    "MultiQuerySpec",
    "MultiQueryState",
    "QueryOutcome",
    "SharedCountsScheduler",
    "init_multi_state",
    "admit_slot",
    "clear_slot",
    "ingest",
    "stats_step",
    "run_round",
    "slot_state",
]


@dataclasses.dataclass(frozen=True)
class MultiQuerySpec:
    """Static shape/criterion configuration shared by all query slots."""

    v_z: int
    v_x: int
    max_queries: int = 8
    criterion: str = "histsim"  # "histsim" | "slowmatch", applies to all slots

    def __post_init__(self):
        if self.max_queries < 1:
            raise ValueError(f"need max_queries >= 1, got {self.max_queries}")
        if self.criterion not in ("histsim", "slowmatch"):
            raise ValueError(self.criterion)


class MultiQueryState(NamedTuple):
    """One shared counts matrix + per-slot query statistics (Q = max_queries)."""

    counts: jax.Array  # (V_Z, V_X) f32 — SHARED empirical counts r_i
    n: jax.Array  # (V_Z,) f32 — SHARED samples per candidate n_i
    q_hat: jax.Array  # (Q, V_X) f32 normalized targets
    k: jax.Array  # (Q,) i32 per-query k
    eps: jax.Array  # (Q,) f32 per-query eps
    delta: jax.Array  # (Q,) f32 per-query delta
    tau: jax.Array  # (Q, V_Z) f32 per-query distance estimates
    eps_i: jax.Array  # (Q, V_Z) f32 assigned deviations
    log_delta_i: jax.Array  # (Q, V_Z) f32
    delta_upper: jax.Array  # (Q,) f32 — 0 for empty slots
    active: jax.Array  # (Q, V_Z) bool — per-query AnyActive candidates
    active_words: jax.Array  # (Q, W) uint32 packed per-query active masks
    union_words: jax.Array  # (W,) uint32 — OR over slots; drives block marking
    in_top_k: jax.Array  # (Q, V_Z) bool — per-query matching set M
    occupied: jax.Array  # (Q,) bool — slot holds a live query
    round_idx: jax.Array  # () i32 — statistics iterations so far


def init_multi_state(spec: MultiQuerySpec) -> MultiQueryState:
    """All slots empty, counts at zero."""
    q, v_z, v_x = spec.max_queries, spec.v_z, spec.v_x
    w = words_for(v_z)
    return MultiQueryState(
        counts=jnp.zeros((v_z, v_x), jnp.float32),
        n=jnp.zeros((v_z,), jnp.float32),
        q_hat=jnp.full((q, v_x), 1.0 / v_x, jnp.float32),
        k=jnp.ones((q,), jnp.int32),
        eps=jnp.ones((q,), jnp.float32),
        delta=jnp.ones((q,), jnp.float32),
        tau=jnp.ones((q, v_z), jnp.float32),
        eps_i=jnp.zeros((q, v_z), jnp.float32),
        log_delta_i=jnp.zeros((q, v_z), jnp.float32),
        delta_upper=jnp.zeros((q,), jnp.float32),
        active=jnp.zeros((q, v_z), bool),
        active_words=jnp.zeros((q, w), jnp.uint32),
        union_words=jnp.zeros((w,), jnp.uint32),
        in_top_k=jnp.zeros((q, v_z), bool),
        occupied=jnp.zeros((q,), bool),
        round_idx=jnp.asarray(0, jnp.int32),
    )


@partial(jax.jit, static_argnames=("spec",))
def admit_slot(
    state: MultiQueryState,
    slot: jax.Array,
    q_hat: jax.Array,
    k: jax.Array,
    eps: jax.Array,
    delta: jax.Array,
    *,
    spec: MultiQuerySpec,
) -> MultiQueryState:
    """Install a query into `slot`. Run `stats_step` before the next marking
    so the new query's active set reflects the accumulated shared counts."""
    del spec  # shapes carried by state
    slot = jnp.asarray(slot, jnp.int32)
    return state._replace(
        q_hat=state.q_hat.at[slot].set(jnp.asarray(q_hat, jnp.float32)),
        k=state.k.at[slot].set(jnp.asarray(k, jnp.int32)),
        eps=state.eps.at[slot].set(jnp.asarray(eps, jnp.float32)),
        delta=state.delta.at[slot].set(jnp.asarray(delta, jnp.float32)),
        occupied=state.occupied.at[slot].set(True),
    )


@partial(jax.jit, static_argnames=("spec",))
def clear_slot(state: MultiQueryState, slot: jax.Array, *, spec: MultiQuerySpec) -> MultiQueryState:
    """Free a slot (query retired): drop it from the active union."""
    del spec
    slot = jnp.asarray(slot, jnp.int32)
    active_words = state.active_words.at[slot].set(jnp.uint32(0))
    return state._replace(
        occupied=state.occupied.at[slot].set(False),
        active=state.active.at[slot].set(False),
        active_words=active_words,
        delta_upper=state.delta_upper.at[slot].set(0.0),
        union_words=_or_reduce(active_words),
    )


def _or_reduce(words: jax.Array) -> jax.Array:
    """(Q, W) uint32 -> (W,) bitwise OR over the query axis."""
    return jax.lax.reduce(words, jnp.uint32(0), jax.lax.bitwise_or, dimensions=[0])


@partial(jax.jit, static_argnames=("spec",))
def ingest(
    state: MultiQueryState, z_idx: jax.Array, x_idx: jax.Array, *, spec: MultiQuerySpec
) -> MultiQueryState:
    """Accumulate a padded sample batch into the SHARED counts — one
    histogram-kernel launch serves every live query."""
    delta_counts = ops.histogram(z_idx, x_idx, v_z=spec.v_z, v_x=spec.v_x)
    return state._replace(
        counts=state.counts + delta_counts,
        n=state.n + jnp.sum(delta_counts, axis=1),
    )


@partial(jax.jit, static_argnames=("spec",))
def stats_step(state: MultiQueryState, *, spec: MultiQuerySpec) -> MultiQueryState:
    """One statistics-engine iteration for every slot, vmapped.

    tau goes through the `ops.l1_distance` kernel call-site once per
    slot (unrolled — Pallas kernels carry no batching rule, and Q is
    small); the deviation assignment with each slot's (k, eps, delta)
    is vmapped over the query axis.
    """
    counts, n = state.counts, state.n
    tau = jnp.stack(
        [ops.l1_distance(counts, state.q_hat[i]) for i in range(spec.max_queries)]
    )

    def one(tau_q, k, eps, delta, occupied):
        d = dev.assign_deviations_dynamic(
            tau_q, n, k=k, eps=eps, delta=delta, v_x=spec.v_x, criterion=spec.criterion
        )
        active = d.active & occupied
        return (
            d.eps_i,
            d.log_delta_i,
            jnp.where(occupied, d.delta_upper, 0.0),
            active,
            pack_active_mask(active),
            d.in_top_k & occupied,
        )

    eps_i, log_delta_i, delta_upper, active, words, in_top_k = jax.vmap(one)(
        tau, state.k, state.eps, state.delta, state.occupied
    )
    return state._replace(
        tau=tau,
        eps_i=eps_i,
        log_delta_i=log_delta_i,
        delta_upper=delta_upper,
        active=active,
        active_words=words,
        union_words=_or_reduce(words),
        in_top_k=in_top_k,
        round_idx=state.round_idx + 1,
    )


def run_round(
    state: MultiQueryState, z_idx: jax.Array, x_idx: jax.Array, *, spec: MultiQuerySpec
) -> MultiQueryState:
    """Shared ingest + vmapped stats — one full multi-query round."""
    return stats_step(ingest(state, z_idx, x_idx, spec=spec), spec=spec)


def slot_state(state: MultiQueryState, slot: int) -> HistSimState:
    """Single-query `HistSimState` view of one slot (counts/n are shared)."""
    return HistSimState(
        counts=state.counts,
        n=state.n,
        q_hat=state.q_hat[slot],
        tau=state.tau[slot],
        eps_i=state.eps_i[slot],
        log_delta_i=state.log_delta_i[slot],
        delta_upper=state.delta_upper[slot],
        active=state.active[slot],
        active_words=state.active_words[slot],
        in_top_k=state.in_top_k[slot],
        round_idx=state.round_idx,
    )


# ---------------------------------------------------------------------------
# The shared window-marking / ingest loop
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Ticket:
    """Host-side bookkeeping for one live query slot."""

    qid: int
    slot: int
    k: int
    eps: float
    delta: float
    admit_time: float
    admit_rounds: int
    admit_passes: int
    admit_blocks_read: int
    admit_blocks_considered: int
    admit_tuples_read: int


@dataclasses.dataclass
class QueryOutcome:
    """Per-query result produced at retirement."""

    qid: int
    ids: np.ndarray  # (k,) matching candidate ids, closest first
    state: HistSimState  # single-query view snapshot at retirement
    delta_upper: float
    exact: bool  # the answer rests on a complete read of the data
    terminated: bool  # the statistical rule delta_upper < delta fired
    rounds: int  # windows processed while this query was live
    passes: int
    blocks_read: int
    blocks_considered: int
    tuples_read: int  # tuples ingested while this query was live
    wall_time_s: float


class SharedCountsScheduler:
    """The FastMatch execution loop over a shared counts matrix.

    Owns the dataset-side sampling state — the cyclic visit order, the
    global without-replacement ``read_mask``, and pass structure — plus
    the `MultiQueryState`. Queries enter via `admit` (any time, into a
    free slot), leave via `retire` (collected in `outcomes`), and `pump`
    drives windows until every live query resolves:

      mark   — AnyActive over the UNION active words (one kernel call)
      ingest — marked blocks into the shared counts (one kernel call)
      stats  — vmapped per-query deviation assignment + bounds

    A pass visits every not-yet-read block in cyclic order; blocks
    skipped by AnyActive stay eligible for later passes (a newly
    admitted query can re-activate them). If a whole pass reads nothing
    while queries remain live, the scheduler completes exactly — reads
    the remainder so empirical counts equal the true ones — and retires
    the stragglers with ``exact=True``. A `max_rounds` budget instead
    stops the loop with live queries left best-effort (the caller
    retires them with ``exact=False``).
    """

    def __init__(
        self,
        dataset: BlockedDataset,
        spec: MultiQuerySpec,
        *,
        policy: str = "anyactive",
        window: int = 512,
        seed: int = 0,
        start_block: Optional[int] = None,
    ):
        if spec.v_z != dataset.v_z or spec.v_x != dataset.v_x:
            raise ValueError("spec/dataset dimension mismatch")
        if policy not in ("anyactive", "scan"):
            raise ValueError(f"unknown policy {policy!r}")
        self.dataset = dataset
        self.spec = spec
        self.policy = policy
        nb = dataset.num_blocks
        self.window = max(1, min(window, nb))

        rng = np.random.default_rng(seed)
        start = start_block if start_block is not None else int(rng.integers(nb))
        self.order = np.roll(np.arange(nb), -start)  # cyclic visit order
        self.read_mask = np.zeros(nb, dtype=bool)

        self.z_blocks = jnp.asarray(dataset.z_blocks)
        self.x_blocks = jnp.asarray(dataset.x_blocks)
        self.bitmap = jnp.asarray(dataset.bitmap)
        self.tuples_per_block = (dataset.z_blocks >= 0).sum(axis=1)

        self.state = init_multi_state(spec)
        self.tickets: Dict[int, _Ticket] = {}  # slot -> ticket
        self.outcomes: Dict[int, QueryOutcome] = {}  # qid -> outcome
        self._next_qid = 0

        # global counters (monotone; per-query numbers are deltas vs admit)
        self.rounds = 0
        self.passes = 0
        self.blocks_read = 0
        self.blocks_considered = 0
        self.tuples_read = 0
        self.budget_exhausted = False

    # -- admission / retirement -------------------------------------------

    @property
    def free_slots(self) -> list:
        return [s for s in range(self.spec.max_queries) if s not in self.tickets]

    @property
    def num_live(self) -> int:
        return len(self.tickets)

    def admit(self, target: np.ndarray, *, k: int, eps: float, delta: float) -> int:
        """Place a query into a free slot; returns its qid.

        The immediate `stats_step` makes the query see the accumulated
        shared counts — with its full shared ``n_i`` — before the next
        window is marked, so a late query never starts from zero.
        """
        free = self.free_slots
        if not free:
            raise RuntimeError("no free query slot; retire a query first")
        if not (0 < k <= self.spec.v_z):
            raise ValueError(f"need 0 < k <= V_Z, got k={k}")
        slot = free[0]
        target = np.asarray(target, np.float64).ravel()
        if target.shape != (self.spec.v_x,):
            raise ValueError(f"target must have shape ({self.spec.v_x},)")
        q_hat = (target / max(target.sum(), 1e-30)).astype(np.float32)
        self.state = admit_slot(
            self.state,
            jnp.asarray(slot, jnp.int32),
            jnp.asarray(q_hat),
            jnp.asarray(k, jnp.int32),
            jnp.asarray(eps, jnp.float32),
            jnp.asarray(delta, jnp.float32),
            spec=self.spec,
        )
        self.state = stats_step(self.state, spec=self.spec)
        qid = self._next_qid
        self._next_qid += 1
        self.tickets[slot] = _Ticket(
            qid=qid,
            slot=slot,
            k=int(k),
            eps=float(eps),
            delta=float(delta),
            admit_time=time.perf_counter(),
            admit_rounds=self.rounds,
            admit_passes=self.passes,
            admit_blocks_read=self.blocks_read,
            admit_blocks_considered=self.blocks_considered,
            admit_tuples_read=self.tuples_read,
        )
        return qid

    def retire(self, slot: int, *, exact: bool, terminated: bool) -> QueryOutcome:
        """Snapshot a slot's answer, free the slot, record the outcome.

        ``exact`` is forced True whenever the whole dataset has been
        read — the answer then rests on a complete read no matter why
        the query is retiring (MatchResult.exact's contract).
        """
        t = self.tickets.pop(slot)
        exact = exact or bool(self.read_mask.all())
        view = slot_state(self.state, slot)
        ids = np.asarray(histsim.top_k_ids(view, t.k))
        outcome = QueryOutcome(
            qid=t.qid,
            ids=ids,
            state=view,
            delta_upper=float(view.delta_upper),
            exact=exact,
            terminated=terminated,
            rounds=self.rounds - t.admit_rounds,
            passes=max(self.passes - t.admit_passes, 1 if self.passes else 0),
            blocks_read=self.blocks_read - t.admit_blocks_read,
            blocks_considered=self.blocks_considered - t.admit_blocks_considered,
            tuples_read=self.tuples_read - t.admit_tuples_read,
            wall_time_s=time.perf_counter() - t.admit_time,
        )
        self.state = clear_slot(self.state, jnp.asarray(slot, jnp.int32), spec=self.spec)
        self.outcomes[t.qid] = outcome
        return outcome

    def _poll_terminated(self) -> None:
        """Retire every live query whose termination bound has fired."""
        if not self.tickets:
            return
        du = np.asarray(self.state.delta_upper)
        for slot in list(self.tickets):
            if du[slot] < self.tickets[slot].delta:
                self.retire(slot, exact=False, terminated=True)

    # -- the loop ----------------------------------------------------------

    def run_window(self, win: np.ndarray) -> int:
        """Mark one lookahead window against the union active set and
        ingest the marked blocks. Returns the number of blocks read."""
        win_j = jnp.asarray(win, jnp.int32)
        self.blocks_considered += len(win)
        marks = mark_window(self.bitmap[win_j], self.state.union_words, policy=self.policy)
        marks_np = np.asarray(marks)
        n_marked = int(marks_np.sum())
        if n_marked:
            zw = jnp.where(marks[:, None], self.z_blocks[win_j], jnp.int32(-1))
            xw = jnp.where(marks[:, None], self.x_blocks[win_j], jnp.int32(-1))
            self.state = run_round(self.state, zw.reshape(-1), xw.reshape(-1), spec=self.spec)
            read = win[marks_np]
            self.read_mask[read] = True
            self.blocks_read += n_marked
            self.tuples_read += int(self.tuples_per_block[read].sum())
        self.rounds += 1
        return n_marked

    def complete_remaining(self) -> None:
        """Exact completion: read every unread block into the shared counts.

        Afterwards the empirical counts equal the true ones, so every
        answer drawn from them is exact and the guarantees hold
        deterministically.
        """
        remaining = np.where(~self.read_mask)[0]
        if remaining.size == 0:
            return
        for s in range(0, remaining.size, self.window):
            chunk = remaining[s : s + self.window]
            cj = jnp.asarray(chunk, jnp.int32)
            self.state = ingest(
                self.state,
                self.z_blocks[cj].reshape(-1),
                self.x_blocks[cj].reshape(-1),
                spec=self.spec,
            )
            self.blocks_read += len(chunk)
            self.tuples_read += int(self.tuples_per_block[chunk].sum())
        self.read_mask[remaining] = True
        self.state = stats_step(self.state, spec=self.spec)

    def pump(
        self,
        *,
        max_rounds: int = 1_000_000,
        max_passes: int = 4,
        on_round: Optional[Callable[["SharedCountsScheduler"], None]] = None,
    ) -> None:
        """Drive windows until every live query resolves.

        on_round: called after each window (post-retirement) — the
        serving frontend uses it to admit pending queries into slots
        freed mid-stream.

        max_rounds/max_passes budget THIS call, not the scheduler's
        lifetime: a long-lived server calling pump per batch gets the
        full budget every time.
        """
        rounds0, passes0 = self.rounds, self.passes
        self.budget_exhausted = False
        # A late-admitted query may already terminate on the accumulated
        # shared counts, before any new window is read.
        self._poll_terminated()
        while self.tickets and self.passes - passes0 < max_passes:
            pass_order = self.order[~self.read_mask[self.order]]
            if pass_order.size == 0:
                break
            self.passes += 1
            pass_start_rounds = self.rounds
            read_this_pass = 0
            pos = 0
            while pos < pass_order.size and self.tickets:
                win = pass_order[pos : pos + self.window]
                pos += len(win)
                read_this_pass += self.run_window(win)
                self._poll_terminated()
                if on_round is not None:
                    on_round(self)
                if self.rounds - rounds0 >= max_rounds:
                    # Budget cut: live queries stay best-effort (the
                    # caller decides; no silent exact completion).
                    self.budget_exhausted = True
                    return
            if read_this_pass == 0:
                # "No unread block can help" was judged against the
                # active sets live DURING the pass — a query admitted in
                # its final windows deserves one fresh pass of its own
                # before we give up on sampling.
                fresh = any(
                    t.admit_rounds >= pass_start_rounds for t in self.tickets.values()
                )
                if not fresh:
                    break
        if self.tickets:
            # Exact fallback for the stragglers.
            self.complete_remaining()
            du = np.asarray(self.state.delta_upper)
            for slot in list(self.tickets):
                fired = bool(du[slot] < self.tickets[slot].delta)
                self.retire(slot, exact=True, terminated=fired)
            if on_round is not None:
                on_round(self)
