"""Train / eval step factories.

`make_train_step(model, optimizer)` returns a pure (state, batch) ->
(state, metrics) function suitable for jit/pjit. Loss is token-level
softmax cross-entropy with z-loss; MoE aux losses are added when the
model reports them. Gradients are clipped by global norm; a NaN/Inf
guard SKIPS the update for bad batches (fault tolerance: a corrupt batch
or a transient numeric excursion must not poison a 1000-node run —
the step increments, metrics record the skip).
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.model_zoo import Model
from repro.optimizer.base import Optimizer, clip_by_global_norm, global_norm
from repro.train.train_state import TrainState

__all__ = ["cross_entropy_loss", "make_train_step", "make_eval_step"]


def cross_entropy_loss(
    logits: jax.Array,
    targets: jax.Array,
    mask: Optional[jax.Array] = None,
    z_loss: float = 1e-4,
) -> tuple:
    """Next-token CE. logits (B,S,V) f32, targets (B,S) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt_logit = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = logz - tgt_logit
    zl = z_loss * jnp.square(logz)
    if mask is None:
        mask = jnp.ones_like(ce)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum((ce + zl) * mask) / denom
    return loss, jnp.sum(ce * mask) / denom


def make_train_step(
    model: Model,
    optimizer: Optimizer,
    *,
    clip_norm: float = 1.0,
    aux_weight: float = 1e-2,
    z_loss: float = 1e-4,
    skip_nonfinite: bool = True,
) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    batch = {"tokens": (B,S) int32, "loss_mask": optional (B,S),
             + modality extras (vision_embeds / encoder_frames)}.
    Targets are tokens shifted left (next-token prediction).
    """
    cfg = model.cfg

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        extras = {k: v for k, v in batch.items() if k not in ("tokens", "loss_mask")}
        logits, aux = model.forward(params, tokens, **extras)
        targets = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones(tokens.shape, jnp.float32)
        mask = mask.at[:, -1].set(0.0)  # no target for last position
        if cfg.vision_tokens:
            mask = mask.at[:, : cfg.vision_tokens].set(0.0)
        loss, ce = cross_entropy_loss(logits, targets, mask, z_loss)
        if aux:
            loss = loss + aux_weight * (
                aux.get("load_balance_loss", 0.0) + cfg.router_z_loss * aux.get("router_z_loss", 0.0)
            )
        return loss, (ce, aux)

    def train_step(state: TrainState, batch) -> tuple:
        (loss, (ce, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, batch
        )
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, new_opt = optimizer.update(grads, state.opt_state, state.params, state.step)
        new_params = jax.tree.map(lambda p, u: p + u.astype(p.dtype), state.params, updates)

        if skip_nonfinite:
            ok = jnp.isfinite(loss) & jnp.isfinite(gnorm)
            new_params = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_params, state.params
            )
            new_opt = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_opt, state.opt_state
            )
        else:
            ok = jnp.asarray(True)

        metrics = {
            "loss": loss,
            "ce": ce,
            "grad_norm": gnorm,
            "step_ok": ok.astype(jnp.float32),
            "param_norm": global_norm(new_params),
        }
        for k, v in (aux or {}).items():
            metrics[f"aux/{k}"] = v
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def make_eval_step(model: Model) -> Callable:
    def eval_step(params, batch):
        tokens = batch["tokens"]
        extras = {k: v for k, v in batch.items() if k not in ("tokens", "loss_mask")}
        logits, _ = model.forward(params, tokens, **extras)
        targets = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
        mask = jnp.ones(tokens.shape, jnp.float32).at[:, -1].set(0.0)
        _, ce = cross_entropy_loss(logits, targets, mask, z_loss=0.0)
        return {"ce": ce, "ppl": jnp.exp(ce)}

    return eval_step
