"""Roofline table from the dry-run artifacts (EXPERIMENTS.md source).

Reads benchmarks/results/dryrun/*.json and emits one row per
(arch x shape x mesh): the three roofline terms, the bottleneck, and the
MODEL_FLOPS / HLO_FLOPS usefulness ratio.
"""

from __future__ import annotations

import json
import pathlib

RESULTS = pathlib.Path(__file__).parent / "results" / "dryrun"


def load_cells(mesh: str = None) -> list:
    cells = []
    for p in sorted(RESULTS.glob("*.json")):
        d = json.loads(p.read_text())
        if mesh and d.get("mesh") != mesh:
            continue
        cells.append(d)
    return cells


def run(csv_rows: list) -> None:
    if not RESULTS.exists():
        csv_rows.append(dict(name="roofline.missing", us_per_call=0.0,
                             derived="run launch/dryrun.py --all first"))
        return
    for d in load_cells():
        tag = f"roofline.{d['arch']}.{d['shape']}.{d['mesh']}"
        if d.get("skipped"):
            csv_rows.append(dict(name=tag, us_per_call=0.0, derived="skipped:" + d["reason"][:40]))
            continue
        if not d.get("ok"):
            csv_rows.append(dict(name=tag, us_per_call=0.0, derived="FAILED " + d.get("error", "")[:60]))
            continue
        r = d["roofline"]
        dominant = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        frac = r["t_compute_s"] / max(dominant, 1e-30)
        csv_rows.append(
            dict(
                name=tag,
                us_per_call=dominant * 1e6,  # roofline-projected step time
                derived=(
                    f"bottleneck={r['bottleneck']}"
                    f" compute_ms={r['t_compute_s']*1e3:.2f}"
                    f" memory_ms={r['t_memory_s']*1e3:.2f}"
                    f" collective_ms={r['t_collective_s']*1e3:.2f}"
                    f" roofline_frac={frac:.3f}"
                    f" useful_flops={d['useful_flops_ratio']:.3f}"
                ),
            )
        )
