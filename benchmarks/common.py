"""Shared benchmark fixtures: paper-shaped synthetic datasets + runners.

Query shapes follow Table 3 of the paper (|V_Z|, |V_X|, k, rarity of the
top-k) scaled to what a single CPU core processes in minutes rather than
the authors' 30+ GiB in-memory runs. The machine-independent quantities —
fraction of blocks/tuples read, rounds, guarantee satisfaction — are the
reproduction targets; wall-clock ratios are reported for the same binary
on the same box.
"""

from __future__ import annotations

import dataclasses
import functools
import time

import numpy as np

from repro.core.engine import EngineConfig, run_engine
from repro.core.histsim import HistSimParams
from repro.data.layout import block_layout
from repro.data.synth import SynthSpec, make_dataset

def env_stamp() -> dict:
    """Hardware/runtime provenance stamped into every BENCH_*.json
    ``config`` block: `check_regression.py` refuses to compare reports
    whose ``backend`` differs (an XLA:CPU baseline says nothing about a
    GPU run) and annotates device-kind / jax-version drift, so results
    from different hardware can't be silently gated against each
    other."""
    import jax

    return {
        "backend": jax.default_backend(),
        "device_kind": jax.devices()[0].device_kind,
        "device_count": jax.device_count(),
        "jax_version": jax.__version__,
    }


# paper defaults (Sec 5.2)
EPS_DEFAULT = 0.06
DELTA_DEFAULT = 0.01
LOOKAHEAD_DEFAULT = 512

# Paper-shaped queries (Table 3 analogues, scaled so that Theorem 1's
# sample complexity is comfortably below the dataset size — the paper's
# datasets are 380-677M tuples; ours are sized to keep CPU wall time in
# minutes while preserving the sampling regime). Per-query eps follows
# the paper's practice of adjusting eps per query (their q4 runs at 0.07).
#   flights_q1: common top-k, moderate V_Z     (FLIGHTS-q1)
#   flights_q2: rare top-k (zipf tail)         (FLIGHTS-q2/q3)
#   flights_q4: continuum of distances         (FLIGHTS-q4, uniform target)
#   taxi_q1:    very high V_Z                  (TAXI-q1/q2)
#   police_q1:  tiny V_X                       (POLICE-q1/q2)
QUERIES = {
    "flights_q1": SynthSpec(
        v_z=161, v_x=24, num_tuples=6_000_000, k=10, n_close=10,
        close_distance=0.02, far_distance=0.3, zipf_a=1.0, close_rank="head", seed=42,
    ),
    "flights_q2": SynthSpec(
        v_z=161, v_x=24, num_tuples=30_000_000, k=10, n_close=10,
        close_distance=0.02, far_distance=0.3, zipf_a=1.2, close_rank="tail", seed=43,
    ),
    "flights_q4": SynthSpec(
        v_z=161, v_x=24, num_tuples=6_000_000, k=5, n_close=40,
        close_distance=0.16, far_distance=0.3, zipf_a=1.0, close_rank="head",
        target_kind="uniform", seed=46,
    ),
    "taxi_q1": SynthSpec(
        v_z=7548, v_x=24, num_tuples=32_000_000, k=10, n_close=10,
        close_distance=0.05, far_distance=0.45, zipf_a=0.3, close_rank="head", seed=44,
    ),
    "police_q1": SynthSpec(
        v_z=191, v_x=2, num_tuples=6_000_000, k=10, n_close=10,
        close_distance=0.01, far_distance=0.35, zipf_a=0.9, close_rank="head", seed=45,
    ),
}

# per-query eps (paper default 0.06; rare/high-V_Z queries need a larger
# tolerance to terminate inside the dataset, exactly as the paper bumps
# FLIGHTS-q4 to 0.07)
QUERY_EPS = {
    "flights_q1": 0.06,
    "flights_q2": 0.08,
    "flights_q4": 0.07,
    "taxi_q1": 0.12,
    "police_q1": 0.06,
}


@functools.lru_cache(maxsize=None)
def get_query(name: str):
    spec = QUERIES[name]
    ds = make_dataset(spec)
    blocked = block_layout(ds.z, ds.x, v_z=spec.v_z, v_x=spec.v_x, block_size=512, seed=spec.seed)
    return spec, ds, blocked


def run_variant(name: str, variant: str, *, eps=None, delta=DELTA_DEFAULT,
                lookahead=LOOKAHEAD_DEFAULT, seed=0, warm=True):
    eps = eps if eps is not None else QUERY_EPS.get(name, EPS_DEFAULT)
    spec, ds, blocked = get_query(name)
    params = HistSimParams(v_z=spec.v_z, v_x=spec.v_x, k=spec.k, eps=eps, delta=delta)
    cfg = EngineConfig(variant=variant, lookahead=lookahead, seed=seed)
    if warm:  # jit warmup outside the timed run
        run_engine(blocked, ds.target, params,
                   dataclasses.replace(cfg, max_rounds=1, seed=seed + 1))
    t0 = time.perf_counter()
    res = run_engine(blocked, ds.target, params, cfg)
    wall = time.perf_counter() - t0
    return res, wall, ds


def delta_d(res, ds) -> float:
    """Total relative error in visual distance (paper Sec 5.3)."""
    true_sorted = np.sort(ds.true_dists)[: len(res.ids)]
    got = np.sort(ds.true_dists[res.ids])
    denom = max(true_sorted.sum(), 1e-12)
    return max(0.0, (got.sum() - true_sorted.sum()) / denom)


def guarantees_hold(res, ds, eps: float) -> bool:
    """Check Guarantees 1 & 2 against planted ground truth."""
    ids = res.ids
    worst = max(ds.true_dists[i] for i in ids)
    for j in set(np.argsort(ds.true_dists)[: len(ids)].tolist()) - set(ids.tolist()):
        if worst - ds.true_dists[j] >= eps:
            return False
    counts = np.asarray(res.state.counts)
    for i in ids:
        r_hat = counts[i] / max(counts[i].sum(), 1.0)
        if np.abs(r_hat - ds.true_hists[i]).sum() >= eps:
            return False
    return True
