"""Collective-parsing unit tests (the roofline's data source)."""

from repro.launch.hlo_parse import DTYPE_BYTES, collective_bytes, parse_hlo_collectives

HLO = """
HloModule test
ENTRY main {
  %p0 = bf16[32,128]{1,0} parameter(0)
  %ar = bf16[32,128]{1,0} all-reduce(bf16[32,128]{1,0} %p0), replica_groups={{0,1}}
  %ag = f32[64,128]{1,0} all-gather(f32[32,128]{1,0} %x), dimensions={0}
  %rs = f32[16,128]{1,0} reduce-scatter(f32[64,128]{1,0} %y), dimensions={0}
  %cp = bf16[8,8]{1,0} collective-permute(bf16[8,8]{1,0} %z), source_target_pairs={{0,1}}
  %aas = (f32[4,4]{1,0}) all-to-all(f32[4,4]{1,0} %w)
  %ard = f32[2,2]{1,0} all-reduce-start(f32[2,2]{1,0} %v)
  %ard2 = f32[2,2]{1,0} all-reduce-done(f32[2,2]{1,0} %ard)
}
"""


class TestParse:
    def test_kinds_and_counts(self):
        stats = parse_hlo_collectives(HLO)
        assert stats["all-reduce"]["count"] == 2  # plain + -start (not -done)
        assert stats["all-gather"]["count"] == 1
        assert stats["reduce-scatter"]["count"] == 1
        assert stats["collective-permute"]["count"] == 1
        assert stats["all-to-all"]["count"] == 1

    def test_ring_cost_accounting(self):
        stats = parse_hlo_collectives(HLO)
        # all-reduce: 2x result bytes (bf16 32x128 = 8192 B -> 16384)
        # + the -start one: 2 * 2*2*4 = 32
        assert stats["all-reduce"]["bytes"] == 2 * 32 * 128 * 2 + 2 * 2 * 2 * 4
        # all-gather: 1x result (f32 64x128)
        assert stats["all-gather"]["bytes"] == 64 * 128 * 4
        # reduce-scatter: operand bytes (f32 64x128)
        assert stats["reduce-scatter"]["bytes"] == 64 * 128 * 4

    def test_total(self):
        total = collective_bytes(HLO)
        assert total == sum(v["bytes"] for v in parse_hlo_collectives(HLO).values())

    def test_ignores_non_collectives(self):
        assert parse_hlo_collectives("%d = f32[8]{0} dot(f32[8] %a, f32[8] %b)") == {}

    def test_dtype_table(self):
        assert DTYPE_BYTES["bf16"] == 2 and DTYPE_BYTES["f32"] == 4
