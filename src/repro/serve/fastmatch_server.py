"""FastMatch query server: N concurrent matching queries, one I/O stream.

`MatchServer` is the interactive frontend the paper positions FastMatch
as ("identify the top-k closest histograms" for a user-specified
target), generalized to a query population: a request queue feeding a
fixed pool of ``max_queries`` slots (padded for stable jit shapes) over
one `SharedCountsScheduler`. Mechanics:

  admission  — pending requests enter free slots at every round
               boundary, mid-stream; a newly admitted query starts from
               the already-accumulated shared counts (with the full
               shared ``n_i`` — sampling was target-independent), which
               is where the serving speedup over one-engine-per-query
               comes from
  serving    — one AnyActive marking per window against the UNION of
               per-query active sets, one shared ingest, one vmapped
               stats step for all live queries
  retirement — a query leaves its slot the moment its own
               ``delta_upper < delta`` bound fires and is returned as a
               per-query `MatchResult`; the freed slot is refilled from
               the queue
  cache      — the shared counts matrix and the global read_mask
               persist across the server's lifetime: once the sampled
               prefix covers a later query's needs it terminates
               without any new I/O, and after an exact completion every
               subsequent query is answered instantly and exactly

The loop underneath is the device-resident `multiquery.fused_round`:
block data arrives through a pluggable `repro.io.BlockSource` (pass a
`PrefetchSource` to overlap next-window gathering with the current
round), and with ``poll_every > 1`` the scheduler dispatches that many
windows between device polls — admission and retirement then lag the
device by at most ``poll_every - 1`` windows (bounded staleness; the
generalized paper-Sec 4.2 relaxation) in exchange for ~``poll_every``x
fewer device↔host round-trips (`scheduler.host_syncs`). With ``mesh``
given, the shared counts matrix is candidate-sharded over the mesh's
model axis, so one server spans a data-parallel mesh.

Per-query `MatchResult` counters (blocks/tuples/rounds) measure what
was read WHILE that query was live — the amortized per-query I/O the
`benchmarks/serve_throughput.py` benchmark compares against running
`run_engine` once per query.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, Optional

import numpy as np

from repro.core.engine import MatchResult
from repro.core.multiquery import MultiQuerySpec, QueryOutcome, SharedCountsScheduler
from repro.io import as_block_source

__all__ = ["MatchQuery", "MatchServer"]


@dataclasses.dataclass
class MatchQuery:
    """One queued matching request (Problem 1 instance)."""

    rid: int
    target: np.ndarray  # (V_X,) unnormalized or normalized target histogram
    k: int
    eps: float
    delta: float
    submit_time: float


class MatchServer:
    """Serve top-k histogram-matching queries over one shared sample stream."""

    def __init__(
        self,
        dataset,
        *,
        max_queries: int = 8,
        criterion: str = "histsim",
        policy: str = "anyactive",
        lookahead: int = 512,
        seed: int = 0,
        start_block: Optional[int] = None,
        max_passes: int = 64,
        poll_every: int = 1,
        mesh=None,
        model_axis: str = "model",
        k_cap: Optional[int] = None,
    ):
        # k_cap: static bound on any query's k — lets the per-slot
        # deviation assignment use a (k_cap+1)-element top_k instead of
        # V_Z order stats; submissions with k > k_cap are rejected.
        source = as_block_source(dataset)
        self.spec = MultiQuerySpec(
            v_z=source.v_z,
            v_x=source.v_x,
            max_queries=max_queries,
            criterion=criterion,
            k_cap=k_cap,
        )
        self.scheduler = SharedCountsScheduler(
            source,
            self.spec,
            policy=policy,
            window=lookahead,
            seed=seed,
            start_block=start_block,
            poll_every=poll_every,
            mesh=mesh,
            model_axis=model_axis,
        )
        self.max_passes = max_passes
        self.pending: Deque[MatchQuery] = deque()
        self.results: Dict[int, MatchResult] = {}
        self._rid_of_qid: Dict[int, int] = {}
        self._submit_time: Dict[int, float] = {}
        self._next_rid = 0
        # step()'s pass cursor (None = start a fresh pass next step)
        self._pass_order: Optional[np.ndarray] = None
        self._pass_pos = 0
        self._pass_read = 0
        self._pass_start_rounds = 0

    # -- request queue -----------------------------------------------------

    def submit(self, target: np.ndarray, *, k: int, eps: float = 0.06, delta: float = 0.01) -> int:
        """Queue a query; returns a request id resolved in `results`.

        Validates here, at the caller's call site — a malformed request
        must not sit in the queue and blow up mid-drain.
        """
        target = np.asarray(target, np.float64).ravel()
        if target.shape != (self.spec.v_x,):
            raise ValueError(f"target must have shape ({self.spec.v_x},), got {target.shape}")
        if not (0 < k <= self.spec.v_z):
            raise ValueError(f"need 0 < k <= V_Z={self.spec.v_z}, got k={k}")
        if self.spec.k_cap is not None and k > self.spec.k_cap:
            raise ValueError(f"k={k} exceeds the server's k_cap={self.spec.k_cap}")
        rid = self._next_rid
        self._next_rid += 1
        self.pending.append(
            MatchQuery(
                rid=rid,
                target=target,
                k=k,
                eps=eps,
                delta=delta,
                submit_time=time.perf_counter(),
            )
        )
        return rid

    def _admit_free(self, _sched: Optional[SharedCountsScheduler] = None) -> None:
        """Fill free slots from the queue (the scheduler's on_round hook)."""
        while self.pending and self.scheduler.free_slots:
            q = self.pending.popleft()
            qid = self.scheduler.admit(q.target, k=q.k, eps=q.eps, delta=q.delta)
            self._rid_of_qid[qid] = q.rid
            self._submit_time[q.rid] = q.submit_time
        self._collect()

    def _collect(self) -> None:
        """Convert freshly retired scheduler outcomes into MatchResults."""
        for qid, out in list(self.scheduler.outcomes.items()):
            rid = self._rid_of_qid.pop(qid, None)
            if rid is None:
                continue  # already collected
            del self.scheduler.outcomes[qid]
            self.results[rid] = self._to_result(rid, out)

    def _to_result(self, rid: int, out: QueryOutcome) -> MatchResult:
        wall = time.perf_counter() - self._submit_time.pop(rid)
        return MatchResult(
            ids=out.ids,
            state=out.state,
            rounds=out.rounds,
            blocks_read=out.blocks_read,
            blocks_considered=out.blocks_considered,
            tuples_read=out.tuples_read,
            wall_time_s=wall,
            exact=out.exact,
            passes=out.passes,
        )

    # -- serving loop ------------------------------------------------------

    def step(self) -> None:
        """Admit + one window + retire: the unit of incremental serving.

        Keeps the same cyclic pass structure as `pump`: a pass visits
        every currently-unread block window by window; when a whole
        pass reads nothing for the remaining live queries (or no
        unread block is left), they are completed exactly instead of
        re-marking the same window forever.
        """
        self._admit_free()
        sched = self.scheduler
        if not sched.tickets:
            return
        if self._pass_order is None or self._pass_pos >= len(self._pass_order):
            unread = sched.order[~sched.read_mask[sched.order]]
            # A zero-read pass only proves sampling is exhausted for the
            # queries that were live during it — a query admitted in its
            # final windows gets a fresh pass before the exact fallback.
            fresh = any(
                t.admit_rounds >= self._pass_start_rounds
                for t in sched.tickets.values()
            )
            stalled = self._pass_order is not None and self._pass_read == 0 and not fresh
            if unread.size == 0 or stalled:
                # Counts complete (or sampling can no longer help) —
                # finish exactly; every live answer becomes exact.
                sched.complete_remaining()
                du = sched._delta_upper  # fresh: complete_remaining polls
                for slot in list(sched.tickets):
                    fired = bool(du[slot] < sched.tickets[slot].delta)
                    sched.retire(slot, exact=True, terminated=fired)
                self._pass_order = None
                self._collect()
                return
            self._pass_order = unread
            self._pass_pos = 0
            self._pass_read = 0
            self._pass_start_rounds = sched.rounds
            sched.passes += 1
        win = self._pass_order[self._pass_pos : self._pass_pos + sched.window]
        self._pass_pos += len(win)
        # Guard against blocks read since this pass was snapshotted
        # (e.g. a run_until_idle interleaved between steps).
        win = win[~sched.read_mask[win]]
        if win.size:
            self._pass_read += sched.run_window(win)
            sched._poll_terminated()
        self._collect()

    def run_until_idle(self, *, max_rounds: int = 1_000_000) -> Dict[int, MatchResult]:
        """Drain the queue: serve until every submitted query has a result."""
        self._pass_order = None  # invalidate step()'s cursor
        while self.pending or self.scheduler.tickets:
            self._admit_free()
            if not self.scheduler.tickets:
                break  # nothing admissible (no pending either, per loop cond)
            self.scheduler.pump(
                max_rounds=max_rounds,
                max_passes=self.max_passes,
                on_round=self._admit_free,
            )
            if self.scheduler.budget_exhausted:
                # A query admitted in the budget's final round may already
                # satisfy its bound from the warm cache — poll before
                # stamping anything best-effort.
                self.scheduler._poll_terminated()
                for slot in list(self.scheduler.tickets):
                    self.scheduler.retire(slot, exact=False, terminated=False)
            self._collect()
        return dict(self.results)

    # -- observability -----------------------------------------------------

    @property
    def metrics(self) -> Dict[str, float]:
        sched = self.scheduler
        done = len(self.results)
        return {
            "queries_done": done,
            "queries_pending": len(self.pending) + sched.num_live,
            "total_blocks_read": sched.blocks_read,
            "total_tuples_read": sched.tuples_read,
            "total_rounds": sched.rounds,
            "fraction_read": float(sched.read_mask.mean()) if sched.read_mask.size else 0.0,
            "tuples_per_query": sched.tuples_read / done if done else float("nan"),
        }
