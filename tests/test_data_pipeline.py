"""Corpus generation + FastMatch-driven selection + token stream."""

import numpy as np
import pytest

from repro.data.corpus import CorpusSpec, make_corpus
from repro.data.pipeline import StreamState, TokenStream, corpus_as_blocked, select_domains


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(
        CorpusSpec(num_domains=32, num_buckets=64, num_blocks=3000, block_tokens=1024,
                   n_reference=6, close_distance=0.03, far_distance=0.4, seed=5)
    )


class TestCorpus:
    def test_shapes(self, corpus):
        assert corpus.tokens.shape == (3000, 1024)
        assert (corpus.tokens >= 0).all() and (corpus.tokens < corpus.spec.vocab_size).all()

    def test_planted_domains_are_closest(self, corpus):
        d = corpus.true_dists
        top = np.argsort(d)[: corpus.spec.n_reference]
        assert set(top.tolist()) == set(corpus.close_ids.tolist())

    def test_bucket_distribution_matches_plan(self, corpus):
        """Tokens of a domain's blocks follow its planted bucket mix."""
        dom = int(corpus.close_ids[0])
        blocks = corpus.tokens[corpus.domains == dom]
        buckets = corpus.bucket_of(blocks).reshape(-1)
        emp = np.bincount(buckets, minlength=corpus.spec.num_buckets) / buckets.size
        assert np.abs(emp - corpus.domain_bucket_dists[dom]).sum() < 0.1


class TestSelection:
    def test_selects_planted_domains(self, corpus):
        rep = select_domains(corpus, k=6, eps=0.1, delta=0.05, seed=0)
        assert set(rep.selected_domains.tolist()) == set(corpus.close_ids.tolist())

    def test_sublinear_scan(self, corpus):
        rep = select_domains(corpus, k=6, eps=0.15, delta=0.05, seed=1)
        assert rep.blocks_scanned_frac < 1.0

    def test_blocked_view_consistent(self, corpus):
        blocked = corpus_as_blocked(corpus)
        assert blocked.num_blocks == corpus.spec.num_blocks
        b = 17
        assert (blocked.z_blocks[b] == corpus.domains[b]).all()


class TestTokenStream:
    def test_batch_shapes(self, corpus):
        rep = select_domains(corpus, k=6, eps=0.1, seed=0)
        st = TokenStream(corpus, rep.selected_domains, batch_size=4, seq_len=512)
        batch = next(st)
        assert batch["tokens"].shape == (4, 512)
        assert batch["tokens"].dtype == np.int32

    def test_only_selected_domains(self, corpus):
        rep = select_domains(corpus, k=6, eps=0.1, seed=0)
        sel = set(rep.selected_domains.tolist())
        st = TokenStream(corpus, rep.selected_domains, batch_size=2, seq_len=1024)
        # every block is domain-pure, so every 1024-token row maps to one block
        batch = next(st)
        for row in batch["tokens"]:
            # find which block this came from by matching content
            buckets = row % corpus.spec.num_buckets
            emp = np.bincount(buckets, minlength=corpus.spec.num_buckets) / buckets.size
            dists = np.abs(corpus.domain_bucket_dists - emp[None]).sum(axis=1)
            assert int(np.argmin(dists)) in sel

    def test_worker_partition_disjoint(self, corpus):
        rep = select_domains(corpus, k=6, eps=0.1, seed=0)
        s0 = TokenStream(corpus, rep.selected_domains, batch_size=1, seq_len=64, worker=0, num_workers=4)
        s1 = TokenStream(corpus, rep.selected_domains, batch_size=1, seq_len=64, worker=1, num_workers=4)
        assert not set(s0.owned.tolist()) & set(s1.owned.tolist())

    def test_cursor_resume_exact(self, corpus):
        """Stream state is checkpointable: resuming reproduces the batches."""
        rep = select_domains(corpus, k=6, eps=0.1, seed=0)
        kw = dict(batch_size=2, seq_len=256, seed=3)
        s = TokenStream(corpus, rep.selected_domains, **kw)
        for _ in range(3):
            next(s)
        saved = StreamState(**vars(s.state))
        want = [next(s)["tokens"] for _ in range(2)]
        s2 = TokenStream(corpus, rep.selected_domains, state=saved, **kw)
        got = [next(s2)["tokens"] for _ in range(2)]
        for a, b in zip(want, got):
            np.testing.assert_array_equal(a, b)

    def test_work_stealing_kicks_in(self, corpus):
        rep = select_domains(corpus, k=6, eps=0.1, seed=0)
        st = TokenStream(corpus, rep.selected_domains, batch_size=1, seq_len=1024,
                         worker=0, num_workers=16, seed=0)
        own = st.owned.size
        for _ in range(own + 5):  # exhaust owned blocks -> steal
            next(st)
        assert st.state.stolen > 0 or st.state.epoch > 0

    def test_work_stealing_is_without_replacement(self, corpus):
        """Regression: steals used to draw WITH replacement, so a worker
        could ingest the same stolen block twice in one epoch."""
        rep = select_domains(corpus, k=6, eps=0.1, seed=0)
        st = TokenStream(corpus, rep.selected_domains, batch_size=1, seq_len=64,
                         worker=0, num_workers=16, seed=0)
        for _ in range(st.owned.size):  # drain owned; next calls steal
            st._next_block()
        limit = st.others.size // st.num_workers
        stolen = [st._next_block() for _ in range(limit)]
        assert st.state.stolen == limit
        keys = {blk.tobytes() for blk in stolen}
        assert len(keys) == limit  # every stolen block distinct
        # and the steal order is checkpoint-deterministic
        st2 = TokenStream(corpus, rep.selected_domains, batch_size=1, seq_len=64,
                          worker=0, num_workers=16, seed=0)
        np.testing.assert_array_equal(st._steal_order, st2._steal_order)
