"""Decoder-only transformer LM (dense GQA and MoE variants).

Covers: qwen2.5-3b, granite-8b, llama3-405b, codeqwen1.5-7b,
internvl2-76b (text backbone + vision-stub prefix), mixtral-8x7b,
grok-1-314b.

Three entry points per model:
  forward(params, tokens[, vision_embeds]) -> logits        (teacher-forced)
  prefill(params, tokens) -> (logits, cache)                (serving)
  decode_step(params, cache, token, pos) -> (logits, cache) (serving)

Layers run unrolled (exact dry-run accounting) or under jax.lax.scan
(production training; config.scan_layers) over stacked per-layer params.
Remat policy applies per layer.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.layers import AttnSpec, shard
from repro.models.moe import init_moe, moe_ffn, moe_ffn_local

__all__ = ["init_params", "forward", "prefill", "decode_step", "init_cache"]


def _attn_spec(cfg: ModelConfig) -> AttnSpec:
    return AttnSpec(
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim,
        causal=True,
        sliding_window=cfg.sliding_window,
        chunk=cfg.attn_chunk,
        impl=cfg.attn_impl,
        decode_seq_shard=cfg.decode_seq_shard,
        gqa_grouped=cfg.attn_gqa_grouped,
    )


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig) -> dict:
    ka, km = jax.random.split(key)
    dt = _dtype(cfg)
    p = {
        "attn_norm": L.init_rmsnorm(cfg.d_model, dt),
        "attn": L.init_attention(ka, cfg.d_model, _attn_spec(cfg), dt, cfg.qkv_bias),
        "mlp_norm": L.init_rmsnorm(cfg.d_model, dt),
    }
    if cfg.num_experts > 0:
        p["moe"] = init_moe(km, cfg.d_model, cfg.d_ff, cfg.num_experts, dt)
    else:
        p["mlp"] = L.init_mlp(km, cfg.d_model, cfg.d_ff, dt)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, cfg.num_layers + 2)
    dt = _dtype(cfg)
    params = {
        "embed": {"table": L.embed_init(keys[0], (cfg.vocab_size, cfg.d_model), dt)},
        "final_norm": L.init_rmsnorm(cfg.d_model, dt),
        "layers": [init_layer(keys[i + 1], cfg) for i in range(cfg.num_layers)],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": L.dense_init(keys[-1], (cfg.d_model, cfg.vocab_size), dt)}
    if cfg.scan_layers:
        params["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *params["layers"])
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _layer_fn(p: dict, x: jax.Array, positions: jax.Array, cfg: ModelConfig):
    """One transformer block; returns (x, aux)."""
    spec = _attn_spec(cfg)
    h = L.rms_norm(p["attn_norm"], x, cfg.norm_eps)
    q, k, v = L.qkv_proj(p["attn"], h, spec)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    attn = L.attention(q, k, v, spec, positions[0], positions[0])
    x = x + L.attention_out(p["attn"], attn)
    x = shard(x, "batch", "seq", None)

    h = L.rms_norm(p["mlp_norm"], x, cfg.norm_eps)
    if cfg.num_experts > 0:
        ffn = moe_ffn_local if cfg.moe_impl == "local" else moe_ffn
        y, aux = ffn(
            p["moe"], h,
            num_experts=cfg.num_experts,
            top_k=cfg.experts_per_token,
            capacity_factor=cfg.expert_capacity_factor,
        )
    else:
        y, aux = L.mlp_swiglu(p["mlp"], h), {}
    x = x + y
    x = shard(x, "batch", "seq", None)
    return x, aux


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return fn


def embed_tokens(params: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = params["embed"]["table"][tokens]
    return x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)


def unembed(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        w = params["embed"]["table"].T
    else:
        w = params["lm_head"]["w"]
    logits = jnp.dot(x, w, preferred_element_type=jnp.float32)
    return shard(logits, "batch", "seq", "vocab")


def forward(
    params: dict,
    tokens: jax.Array,
    cfg: ModelConfig,
    *,
    vision_embeds: Optional[jax.Array] = None,
) -> tuple:
    """(B, S) tokens -> ((B, S, V) f32 logits, aux dict).

    For VLM configs, `vision_embeds` (B, vision_tokens, D) replaces the
    embeddings of the first `vision_tokens` positions (the stub frontend).
    """
    b, s = tokens.shape
    x = embed_tokens(params, tokens, cfg)
    if cfg.vision_tokens and vision_embeds is not None:
        nv = cfg.vision_tokens
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x[:, nv:]], axis=1)
    x = shard(x, "batch", "seq", None)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    layer = _maybe_remat(functools.partial(_layer_fn, positions=positions, cfg=cfg), cfg)
    aux_sum = {}
    if cfg.scan_layers:
        def body(carry, lp):
            y, aux = layer(lp, carry)
            return y, aux
        x, auxes = jax.lax.scan(lambda c, lp: body(c, lp), x, params["layers"])
        aux_sum = jax.tree.map(jnp.sum, auxes) if auxes else {}
    else:
        auxes = []
        for lp in params["layers"]:
            x, aux = layer(lp, x)
            if aux:
                auxes.append(aux)
        if auxes:
            aux_sum = jax.tree.map(lambda *xs: jnp.sum(jnp.stack(xs)), *auxes)

    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return unembed(params, x, cfg), aux_sum


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: list  # per layer (B, S_max, Hkv, hd)
    v: list
    length: jax.Array  # () int32 — tokens already written


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> KVCache:
    dt = _dtype(cfg)
    shape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return KVCache(
        k=[jnp.zeros(shape, dt) for _ in range(cfg.num_layers)],
        v=[jnp.zeros(shape, dt) for _ in range(cfg.num_layers)],
        length=jnp.asarray(0, jnp.int32),
    )


def prefill(params: dict, tokens: jax.Array, cfg: ModelConfig, max_len: int) -> tuple:
    """Run the prompt through the model, returning logits + filled cache."""
    b, s = tokens.shape
    x = embed_tokens(params, tokens, cfg)
    x = shard(x, "batch", "seq", None)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    spec = _attn_spec(cfg)

    ks, vs = [], []
    layer_params = params["layers"]
    if cfg.scan_layers:
        layer_params = [
            jax.tree.map(lambda a, i=i: a[i], params["layers"]) for i in range(cfg.num_layers)
        ]
    for lp in layer_params:
        h = L.rms_norm(lp["attn_norm"], x, cfg.norm_eps)
        q, k, v = L.qkv_proj(lp["attn"], h, spec)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        attn = L.attention(q, k, v, spec, positions[0], positions[0])
        x = x + L.attention_out(lp["attn"], attn)
        h = L.rms_norm(lp["mlp_norm"], x, cfg.norm_eps)
        if cfg.num_experts > 0:
            # serving uses dropless capacity: at cf = E the buffers can
            # absorb the worst-case routing, so no token is ever dropped
            y, _ = moe_ffn(
                lp["moe"], h,
                num_experts=cfg.num_experts,
                top_k=cfg.experts_per_token,
                capacity_factor=float(cfg.num_experts),
            )
        else:
            y = L.mlp_swiglu(lp["mlp"], h)
        x = x + y
        pad = max_len - s
        ks.append(jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))))
        vs.append(jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))))

    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params, x, cfg)
    cache = KVCache(k=ks, v=vs, length=jnp.asarray(s, jnp.int32))
    return logits, cache


def decode_step(params: dict, cache: KVCache, token: jax.Array, cfg: ModelConfig) -> tuple:
    """One decode step. token: (B,) int32. Returns (logits (B, V), cache)."""
    b = token.shape[0]
    x = embed_tokens(params, token[:, None], cfg)
    pos = jnp.broadcast_to(cache.length, (b,))
    spec = _attn_spec(cfg)

    layer_params = params["layers"]
    if cfg.scan_layers:
        layer_params = [
            jax.tree.map(lambda a, i=i: a[i], params["layers"]) for i in range(cfg.num_layers)
        ]
    new_k, new_v = [], []
    for li, lp in enumerate(layer_params):
        h = L.rms_norm(lp["attn_norm"], x, cfg.norm_eps)
        attn_out, ck, cv = L.decode_attention(
            lp["attn"], h, cache.k[li], cache.v[li], pos, spec, cfg.rope_theta
        )
        new_k.append(ck)
        new_v.append(cv)
        x = x + attn_out
        h = L.rms_norm(lp["mlp_norm"], x, cfg.norm_eps)
        if cfg.num_experts > 0:
            y, _ = moe_ffn(
                lp["moe"], h,
                num_experts=cfg.num_experts,
                top_k=cfg.experts_per_token,
                capacity_factor=float(cfg.num_experts),  # dropless at decode
            )
        else:
            y = L.mlp_swiglu(lp["mlp"], h)
        x = x + y

    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params, x, cfg)[:, 0]
    return logits, KVCache(k=new_k, v=new_v, length=cache.length + 1)
