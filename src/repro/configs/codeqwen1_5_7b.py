"""codeqwen1.5-7b [dense] — qwen1.5-arch, MHA (kv=32) [hf:Qwen/CodeQwen1.5-7B; hf]."""

from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch_id="codeqwen1_5_7b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=32,
        d_ff=13440,
        vocab_size=92416,
        qkv_bias=True,
        rope_theta=1e6,
        norm_eps=1e-6,
        optimizer="adamw",
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="codeqwen1_5_7b_smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        qkv_bias=True,
        norm_eps=1e-6,
    )
