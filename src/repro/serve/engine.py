"""Batched serving engine: continuous prefill + decode over a request queue.

A deliberately compact production shape: fixed decode batch of `slots`,
each slot holding one active request. Incoming prompts are prefilled
(padded to a bucket) and their KV state inserted into the batch cache;
every decode tick advances all live slots by one token; finished slots
(EOS or max_tokens) are released and refilled from the queue.

This is the component the `decode_32k` / `long_500k` dry-run shapes
lower: `serve_step` = one decode tick against a seq_len-deep cache.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model_zoo import Model

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 32
    eos_id: int = -1  # -1 = never
    # filled by the engine:
    output: Optional[list] = None
    done: bool = False


class ServeEngine:
    def __init__(
        self,
        model: Model,
        params,
        *,
        slots: int = 8,
        max_len: int = 512,
        greedy: bool = True,
        seed: int = 0,
    ):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        self.rng = jax.random.PRNGKey(seed)
        self._decode = jax.jit(model.decode_step)
        self.queue: List[Request] = []
        self.active: List[Optional[Request]] = [None] * slots
        self.metrics = {"prefills": 0, "decode_ticks": 0, "tokens_out": 0}

    def submit(self, req: Request):
        req.output = []
        self.queue.append(req)

    # -- single-sequence serving path (one cache per slot batch) ----------
    def run(self, budget_ticks: int = 10_000) -> List[Request]:
        """Drain the queue: batch prompts of equal length, prefill, decode."""
        done: List[Request] = []
        while self.queue and budget_ticks > 0:
            batch = self.queue[: self.slots]
            self.queue = self.queue[self.slots :]
            # bucket-pad prompts to the longest in batch
            plen = max(len(r.prompt) for r in batch)
            toks = np.zeros((len(batch), plen), np.int32)
            for i, r in enumerate(batch):
                toks[i, plen - len(r.prompt) :] = r.prompt  # left-pad
            logits, cache = self.model.prefill(self.params, jnp.asarray(toks), self.max_len)
            self.metrics["prefills"] += 1
            last = np.asarray(jnp.argmax(logits[:, -1], axis=-1)).astype(np.int32)
            live = np.ones(len(batch), bool)
            # the prefill's last logits produce the FIRST new token
            for i, r in enumerate(batch):
                r.output.append(int(last[i]))
                self.metrics["tokens_out"] += 1
                if len(r.output) >= r.max_new_tokens or last[i] == r.eos_id:
                    live[i] = False
                    r.done = True
            steps = max(r.max_new_tokens for r in batch) - 1
            for _ in range(steps):
                if budget_ticks <= 0 or not live.any():
                    break
                logits_t, cache = self._decode(self.params, cache, jnp.asarray(last))
                self.metrics["decode_ticks"] += 1
                budget_ticks -= 1
                nxt = np.asarray(jnp.argmax(logits_t, axis=-1)).astype(np.int32)
                for i, r in enumerate(batch):
                    if not live[i]:
                        continue
                    r.output.append(int(nxt[i]))
                    self.metrics["tokens_out"] += 1
                    if len(r.output) >= r.max_new_tokens or nxt[i] == r.eos_id:
                        live[i] = False
                        r.done = True
                last = nxt
            for r in batch:
                r.done = True
                done.append(r)
        return done
