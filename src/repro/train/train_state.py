"""Training state: params + optimizer state + step, as a plain pytree."""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["TrainState"]


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array  # () int32

    @classmethod
    def create(cls, params, optimizer):
        return cls(params=params, opt_state=optimizer.init(params), step=jnp.asarray(0, jnp.int32))
