"""Figure 9: effect of the lookahead parameter on latency.

Paper claims: latency robust to lookahead for moderate |V_Z|; large
|V_Z| (TAXI) benefits from larger lookahead; default 512 acceptable
everywhere.
"""

from __future__ import annotations

from benchmarks.common import get_query, run_variant

GRID = (32, 128, 512, 2048)


def run(csv_rows: list) -> None:
    for q in ("flights_q1", "taxi_q1"):
        spec, _, blocked = get_query(q)
        for la in GRID:
            res, wall, _ = run_variant(q, "fastmatch", lookahead=la)
            csv_rows.append(
                dict(
                    name=f"fig9.{q}.lookahead_{la}",
                    us_per_call=wall * 1e6,
                    derived=(
                        f"rounds={res.rounds}"
                        f" blocks_frac={res.blocks_read / blocked.num_blocks:.3f}"
                    ),
                )
            )
