"""The benchmark aggregator's CLI contract.

A typo'd suite name must exit non-zero BEFORE any suite runs: CI steps
invoke `python -m benchmarks.run <names>`, and a renamed benchmark that
silently ran nothing (or ran the other requested suites first and then
died after minutes) would green-light a workflow that measured nothing.
"""

import os
import subprocess
import sys

import pytest

import benchmarks.run as brun

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class TestUnknownSuiteName:
    def test_exits_before_running_anything(self, monkeypatch):
        """One bad name in a multi-suite request must abort up front —
        even the VALID names requested alongside it must not run."""
        ran = []
        monkeypatch.setattr(
            brun, "SUITES", {"good": lambda rows: ran.append("good")}
        )
        monkeypatch.setattr(sys, "argv", ["run", "good", "nonsense"])
        with pytest.raises(SystemExit) as exc:
            brun.main()
        assert "nonsense" in str(exc.value)
        assert exc.value.code != 0
        assert ran == []  # the valid suite was NOT run first

    def test_known_names_listed_in_error(self, monkeypatch):
        monkeypatch.setattr(brun, "SUITES", {"only": lambda rows: None})
        monkeypatch.setattr(sys, "argv", ["run", "bogus"])
        with pytest.raises(SystemExit, match="only"):
            brun.main()

    @pytest.mark.slow
    def test_cli_process_exits_nonzero(self):
        """End to end through the real interpreter: the exact command a
        CI step would run."""
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "no_such_bench"],
            capture_output=True, text=True, env=env, cwd=REPO, timeout=300,
        )
        assert out.returncode != 0
        assert "no_such_bench" in out.stderr

    def test_pump_suite_registered(self):
        assert "pump" in brun.SUITES
