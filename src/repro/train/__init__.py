from repro.train.step import make_train_step, make_eval_step, cross_entropy_loss
from repro.train.train_state import TrainState

__all__ = ["make_train_step", "make_eval_step", "cross_entropy_loss", "TrainState"]
