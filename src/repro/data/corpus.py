"""Synthetic token corpus with domain structure, for LM training.

The corpus is organized exactly like the paper's datasets: tuples are
(domain_id = Z, token-bucket = X) pairs living in blocks of a shuffled
layout. Domains are synthetic "sources" (web, code, forums, ...) with
distinct token-class distributions; some domains are planted close to a
reference distribution — the ground truth the FastMatch selector should
recover. Tokens themselves are drawn per-domain from a power-law over
the vocab, bucketed into X = token_id % num_buckets classes for the
histogram layer.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.synth import perturb_distribution

__all__ = ["CorpusSpec", "TokenCorpus", "make_corpus"]


@dataclasses.dataclass(frozen=True)
class CorpusSpec:
    num_domains: int = 64
    num_buckets: int = 128  # |V_X| for the matching layer
    vocab_size: int = 50304
    block_tokens: int = 2048  # tokens per corpus block
    num_blocks: int = 4096
    n_reference: int = 8  # domains planted near the reference mix
    close_distance: float = 0.03
    far_distance: float = 0.35
    reference_alpha: float = 4.0  # dirichlet concentration of the target mix
    domain_alpha: float = 0.7  # concentration of non-reference domains
    seed: int = 0


@dataclasses.dataclass
class TokenCorpus:
    spec: CorpusSpec
    tokens: np.ndarray  # (num_blocks, block_tokens) int32
    domains: np.ndarray  # (num_blocks,) int32 — domain of each block
    reference: np.ndarray  # (num_buckets,) f64 — the target bucket mix
    domain_bucket_dists: np.ndarray  # (num_domains, num_buckets)
    close_ids: np.ndarray

    @property
    def true_dists(self) -> np.ndarray:
        return np.abs(self.domain_bucket_dists - self.reference[None, :]).sum(axis=1)

    def bucket_of(self, tokens: np.ndarray) -> np.ndarray:
        return tokens % self.spec.num_buckets


def make_corpus(spec: CorpusSpec) -> TokenCorpus:
    rng = np.random.default_rng(spec.seed)
    nb, bt, vd = spec.num_blocks, spec.block_tokens, spec.num_domains

    # Reference bucket mix (e.g. the "high-quality corpus" token profile).
    reference = rng.dirichlet(np.full(spec.num_buckets, spec.reference_alpha))

    # Per-domain bucket distributions.
    dists = np.zeros((vd, spec.num_buckets))
    close_ids = rng.choice(vd, size=spec.n_reference, replace=False)
    close_set = set(close_ids.tolist())
    for d in range(vd):
        if d in close_set:
            dists[d] = perturb_distribution(
                reference, spec.close_distance * rng.uniform(0.5, 1.5), rng
            )
        else:
            for _ in range(64):
                h = rng.dirichlet(np.full(spec.num_buckets, spec.domain_alpha))
                if np.abs(h - reference).sum() >= spec.far_distance:
                    break
            dists[d] = h

    # Blocks: each block belongs to one domain (documents cluster in
    # storage); block order is shuffled (Challenge 1 layout).
    domains = rng.integers(0, vd, size=nb).astype(np.int32)
    tokens = np.empty((nb, bt), dtype=np.int32)
    n_rep = spec.vocab_size // spec.num_buckets
    for b in range(nb):
        # sample buckets, then a token within the bucket (token = bucket + k*B)
        buckets = rng.choice(spec.num_buckets, size=bt, p=dists[domains[b]])
        offsets = rng.integers(0, n_rep, size=bt)
        tokens[b] = buckets + offsets * spec.num_buckets

    return TokenCorpus(
        spec=spec,
        tokens=tokens,
        domains=domains,
        reference=reference,
        domain_bucket_dists=dists,
        close_ids=np.sort(close_ids),
    )
