"""Double-buffered background-thread block prefetch (paper Sec 4.2).

"The sampling engine must never stall the statistics engine": while the
device runs round t's ingest+stats, a worker thread gathers window t+1
from the wrapped source into a bounded queue. With a queue depth of 2
this is classic double buffering — the consumer always finds the next
window staged unless the underlying source is genuinely slower than the
compute, in which case the queue provides back-pressure instead of
unbounded memory growth.

Abandonment-safe: closing the stream generator mid-pass (a query
retires, the budget cuts) signals the worker and drains the queue so
a blocked `put` can never leak the thread. A worker exception is
re-raised at the consumer's next pull while the stream is being
driven; if the stream was already closed when the worker failed (the
error has nowhere to surface) it is logged instead of vanishing, as is
a worker that outlives the closing join (blocked inside a slow
``inner.fetch``).
"""

from __future__ import annotations

import logging
import queue
import threading
from typing import Iterable, Iterator, Optional

import numpy as np

from repro.io.block_source import BlockSource, WindowData

__all__ = ["PrefetchSource"]

logger = logging.getLogger(__name__)


class PrefetchSource:
    """Wrap any `BlockSource`; `stream` overlaps fetch with consumption.

    ``join_timeout`` bounds how long closing a stream waits for the
    worker thread (it is a daemon, so an over-timeout worker cannot
    hang interpreter exit — but it IS still running, which is why the
    timeout warns instead of passing silently).
    """

    def __init__(self, inner: BlockSource, *, depth: int = 2, join_timeout: float = 10.0):
        if depth < 1:
            raise ValueError(f"need depth >= 1, got {depth}")
        self.inner = inner
        self.depth = depth
        self.join_timeout = join_timeout
        self.num_blocks = inner.num_blocks
        self.block_size = inner.block_size
        self.v_z = inner.v_z
        self.v_x = inner.v_x
        self.tuples_per_block = inner.tuples_per_block

    def fetch(self, win: np.ndarray, pad_to: Optional[int] = None) -> WindowData:
        return self.inner.fetch(win, pad_to)

    def stream(
        self, windows: Iterable[np.ndarray], pad_to: Optional[int] = None
    ) -> Iterator[WindowData]:
        windows = list(windows)
        q: queue.Queue = queue.Queue(maxsize=self.depth)
        stop = threading.Event()
        failure: list = []  # the worker's exception, whether or not it queued

        def _put(item) -> bool:
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def worker():
            try:
                for win in windows:
                    if stop.is_set() or not _put(("data", self.inner.fetch(win, pad_to))):
                        return
                _put(("done", None))
            except BaseException as exc:
                # Recorded unconditionally: the queued ("error", ...) item
                # is lost when the consumer is already closing (stop set,
                # queue being drained), and an error must never vanish.
                failure.append(exc)
                _put(("error", exc))

        t = threading.Thread(target=worker, name="block-prefetch", daemon=True)
        t.start()
        raised = False
        try:
            while True:
                kind, payload = q.get()
                if kind == "done":
                    break
                if kind == "error":
                    raised = True
                    raise payload
                yield payload
        finally:
            stop.set()
            try:
                while True:
                    q.get_nowait()
            except queue.Empty:
                pass
            t.join(timeout=self.join_timeout)
            if t.is_alive():
                logger.warning(
                    "prefetch worker still running %.1fs after stream close "
                    "(blocked in %s.fetch?); abandoning daemon thread",
                    self.join_timeout, type(self.inner).__name__,
                )
            elif failure and not raised:
                logger.warning(
                    "prefetch worker failed after the stream was closed; "
                    "dropping: %r", failure[0],
                )
