"""The `Telemetry` facade: one handle wired through the serving stack.

Bundles the three concerns every instrumented layer needs:

  registry — `MetricsRegistry` (counters / gauges / latency histograms)
  tracer   — `Tracer` (per-query lifecycle + per-round-batch events)
  curves   — per-query confidence trajectories: the (tuples, eps(n),
             delta_upper) points the scheduler records at every poll
             boundary, i.e. the tuples-to-confidence curve of each
             query (the measurable form of Theorem 1's n ↦ eps(n); the
             anytime API's `AnytimeAnswer.curve_point` speaks the same
             column vocabulary — see `record_anytime`)

A `MatchServer(telemetry=True)` owns one instance and threads it into
its scheduler/pump, each `PrefetchSource`, and the `CheckpointManager`;
every instrumentation point in those layers guards on ``telemetry is
not None`` so the default (off) path stays untouched. One Telemetry
instance belongs to one server — query ids key the curve store.

Curve points are dicts with a fixed column set (`CURVE_COLUMNS`);
`confidence_curve` returns them as a float ndarray and
`export_confidence_csv` writes the classic curve file the
`examples/telemetry_trace.py` demo renders. Per-query point count is
bounded (``max_curve_points``, earliest points kept — the interesting
shape of a confidence curve is its rise); drops are counted, never
silent.
"""

from __future__ import annotations

import threading
from typing import Dict, List, Optional

import numpy as np

from repro.obs.registry import MetricsRegistry
from repro.obs.tracer import Tracer

__all__ = ["Telemetry", "CURVE_COLUMNS"]

# Column order of a confidence-trajectory point (see
# `SharedCountsScheduler.flush_telemetry` for where each is measured).
CURVE_COLUMNS = (
    "round",        # device rounds (windows dispatched) at the poll
    "tuples",       # shared tuples_read total at the poll
    "tuples_live",  # tuples read while THIS query was live (cost accounting)
    "n_min",        # min_i n_i — the worst-sampled candidate's sample count
    "tau_min",      # min_i tau_i — distance estimate of the current best
    "eps_n",        # Theorem 1 eps at n_min and per-candidate budget delta/V_Z
    "delta_upper",  # the stats tail's failure bound sum_i delta_i
    "confidence",   # max(0, 1 - delta_upper)
)


class Telemetry:
    """Registry + tracer + per-query confidence-trajectory store."""

    def __init__(self, *, tracer_capacity: int = 8192,
                 max_curve_points: int = 4096, clock=None):
        self.registry = MetricsRegistry()
        self.tracer = Tracer(capacity=tracer_capacity, clock=clock)
        self.max_curve_points = max_curve_points
        self._curves: Dict[int, List[dict]] = {}
        self.curve_drops = 0  # points not recorded due to the per-query cap
        self._lock = threading.Lock()
        self._flush_hooks: List = []

    # -- producer flush hooks ----------------------------------------------

    def add_flush_hook(self, fn) -> None:
        """Register a producer-side drain (e.g. the scheduler's
        `flush_telemetry`). Producers may stage raw measurements and
        shape them in batches off their hot path; every read accessor
        below runs the hooks first, so readers always see current data.
        """
        self._flush_hooks.append(fn)

    def _flush(self) -> None:
        # outside self._lock: hooks call record_curve_point themselves
        for fn in self._flush_hooks:
            fn()

    # -- confidence trajectories -------------------------------------------

    def record_curve_point(self, qid: int, point: dict) -> None:
        """Append one poll-boundary point to a query's trajectory."""
        with self._lock:
            pts = self._curves.setdefault(qid, [])
            if pts and all(
                pts[-1][c] == point[c] for c in ("round", "tuples", "delta_upper")
            ):
                return  # repeat poll at the same round (e.g. an admission
                # boundary right after a loop poll) — nothing new to plot
            if len(pts) >= self.max_curve_points:
                self.curve_drops += 1
                return
            pts.append(point)

    def record_anytime(self, qid: int, answer) -> None:
        """Append an `AnytimeAnswer`'s curve point to its trajectory.

        The anytime API (`MatchServer.poll_result`) and the telemetry
        curve store describe the same poll boundary; this keeps them in
        the same column vocabulary — ``answer.curve_point()`` emits
        exactly `CURVE_COLUMNS`, so an externally polled statement lands
        on the query's confidence curve like any scheduler-recorded one
        (same dedup on repeat polls, same per-query cap).
        """
        self.record_curve_point(qid, answer.curve_point())

    def trajectory(self, qid: int) -> List[dict]:
        """The recorded points for one query (oldest first)."""
        self._flush()
        with self._lock:
            return list(self._curves.get(qid, ()))

    def query_ids(self) -> List[int]:
        self._flush()
        with self._lock:
            return sorted(self._curves)

    def confidence_curve(self, qid: int) -> np.ndarray:
        """(num_points, len(CURVE_COLUMNS)) float64 array for one query."""
        pts = self.trajectory(qid)
        if not pts:
            return np.zeros((0, len(CURVE_COLUMNS)))
        return np.asarray(
            [[float(p[c]) for c in CURVE_COLUMNS] for p in pts], np.float64
        )

    def export_confidence_csv(self, path, qid: Optional[int] = None) -> int:
        """Write trajectories (one query, or all) as CSV; returns rows."""
        qids = [qid] if qid is not None else self.query_ids()
        rows = 0
        with open(path, "w") as f:
            f.write("qid," + ",".join(CURVE_COLUMNS) + "\n")
            for q in qids:
                for p in self.trajectory(q):
                    f.write(
                        f"{q}," + ",".join(repr(float(p[c])) for c in CURVE_COLUMNS) + "\n"
                    )
                    rows += 1
        return rows
