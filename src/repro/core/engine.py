"""The FastMatch engine: HistSim + block policies + lookahead staleness.

This is the executable analogue of the paper's Figure 5 architecture.
The three components map onto the execution model as follows:

  I/O manager        — gathers marked blocks from the blocked dataset
                       (host memory here; disk/remote-FS in production)
  sampling engine    — AnyActive marking of a lookahead window of blocks
                       against the packed bitmap, using the FRESHEST
                       delta_i posted so far (which is one window stale —
                       the paper's asynchronous relaxation, Sec 4.2)
  statistics engine  — the jitted HistSim ingest+stats round

Variants (paper Sec 5.2) are configuration points of this single engine:

  variant     policy      lookahead   stats cadence        criterion
  ---------   ---------   ---------   ------------------   ---------
  fastmatch   anyactive   L (512)     once per window      histsim
  syncmatch   anyactive   1           once per block       histsim
  scanmatch   scan        L           once per window      histsim
  slowmatch   scan        L           once per window      slowmatch
  scan        scan        —           exact full pass      —

Sampling is WITHOUT replacement from a random start position in the
pre-shuffled layout. A pass visits every not-yet-read block in cyclic
order; AnyActive may skip blocks, and skipped blocks remain eligible for
later passes (candidates can re-activate when the split point moves).
If a whole pass reads nothing and HistSim still has not terminated, the
engine completes exactly (reads the remainder) — at that point empirical
counts equal the true ones and the guarantees hold deterministically.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import histsim
from repro.core.histsim import HistSimParams, HistSimState
from repro.core.policies import mark_window
from repro.data.layout import BlockedDataset

__all__ = ["EngineConfig", "MatchResult", "run_engine", "VARIANTS"]

VARIANTS = ("fastmatch", "syncmatch", "scanmatch", "slowmatch", "scan")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    variant: str = "fastmatch"
    lookahead: int = 512
    seed: int = 0
    max_rounds: int = 1_000_000
    max_passes: int = 4
    start_block: Optional[int] = None  # None -> random

    def __post_init__(self):
        if self.variant not in VARIANTS:
            raise ValueError(f"unknown variant {self.variant!r}")

    @property
    def policy(self) -> str:
        return "anyactive" if self.variant in ("fastmatch", "syncmatch") else "scan"

    @property
    def window(self) -> int:
        return 1 if self.variant == "syncmatch" else self.lookahead

    @property
    def criterion(self) -> str:
        return "slowmatch" if self.variant == "slowmatch" else "histsim"


@dataclasses.dataclass
class MatchResult:
    ids: np.ndarray  # (k,) matching candidate ids, closest first
    state: HistSimState
    rounds: int
    blocks_read: int
    blocks_considered: int
    tuples_read: int
    wall_time_s: float
    exact: bool  # True if the engine fell back to a complete read
    passes: int

    @property
    def delta_upper(self) -> float:
        return float(self.state.delta_upper)


def _run_exact_scan(dataset: BlockedDataset, state, params, t0) -> "MatchResult":
    """The paper's Scan baseline: complete heap scan, exact answer."""
    z_blocks = jnp.asarray(dataset.z_blocks)
    x_blocks = jnp.asarray(dataset.x_blocks)
    nb = dataset.num_blocks
    chunk = 4096
    for s in range(0, nb, chunk):
        cj = jnp.arange(s, min(s + chunk, nb), dtype=jnp.int32)
        state = histsim.ingest(
            state, z_blocks[cj].reshape(-1), x_blocks[cj].reshape(-1), params=params
        )
    state = histsim.stats_step(state, params=params)
    ids = np.asarray(histsim.top_k_ids(state, params.k))
    return MatchResult(
        ids=ids,
        state=state,
        rounds=-(-nb // chunk),
        blocks_read=nb,
        blocks_considered=nb,
        tuples_read=dataset.num_tuples,
        wall_time_s=time.perf_counter() - t0,
        exact=True,
        passes=1,
    )


def _ingest_window(state, z_blocks, x_blocks, win_j, marks, params):
    """Gather marked blocks (unmarked -> padding) and run one round."""
    zw = jnp.where(marks[:, None], z_blocks[win_j], jnp.int32(-1))
    xw = jnp.where(marks[:, None], x_blocks[win_j], jnp.int32(-1))
    return histsim.run_round(state, zw.reshape(-1), xw.reshape(-1), params=params)


def run_engine(
    dataset: BlockedDataset,
    target: np.ndarray,
    params: HistSimParams,
    config: EngineConfig = EngineConfig(),
) -> MatchResult:
    """Run one matching query to termination. Returns the top-k + stats."""
    if params.v_z != dataset.v_z or params.v_x != dataset.v_x:
        raise ValueError("params/dataset dimension mismatch")
    if config.criterion != params.criterion:
        params = dataclasses.replace(params, criterion=config.criterion)

    t0 = time.perf_counter()
    rng = np.random.default_rng(config.seed)
    nb = dataset.num_blocks
    window = min(config.window, nb)

    state = histsim.init_state(params, jnp.asarray(target))

    if config.variant == "scan":
        return _run_exact_scan(dataset, state, params, t0)

    start = config.start_block if config.start_block is not None else int(rng.integers(nb))
    order = np.roll(np.arange(nb), -start)  # cyclic visit order
    read_mask = np.zeros(nb, dtype=bool)

    z_blocks = jnp.asarray(dataset.z_blocks)
    x_blocks = jnp.asarray(dataset.x_blocks)
    bitmap = jnp.asarray(dataset.bitmap)
    tuples_per_block = (dataset.z_blocks >= 0).sum(axis=1)

    rounds = blocks_read = blocks_considered = tuples_read = passes = 0
    terminated = False

    while not terminated and passes < config.max_passes:
        pass_order = order[~read_mask[order]]
        if pass_order.size == 0:
            break
        passes += 1
        read_this_pass = 0
        pos = 0
        while pos < pass_order.size and not terminated:
            win = pass_order[pos : pos + window]
            pos += len(win)
            blocks_considered += len(win)
            win_j = jnp.asarray(win, jnp.int32)

            # sampling engine: mark with the freshest (= one-round-stale) delta
            marks = mark_window(bitmap[win_j], state.active_words, policy=config.policy)
            marks_np = np.asarray(marks)
            n_marked = int(marks_np.sum())
            if n_marked:
                state = _ingest_window(state, z_blocks, x_blocks, win_j, marks, params)
                read = win[marks_np]
                read_mask[read] = True
                blocks_read += n_marked
                read_this_pass += n_marked
                tuples_read += int(tuples_per_block[read].sum())
            else:
                # nothing to read: statistics unchanged, no stats step needed
                pass
            rounds += 1
            if n_marked and histsim.should_terminate(state, params):
                terminated = True
            if rounds >= config.max_rounds:
                terminated = True  # budget cut; result is best-effort
        if read_this_pass == 0:
            break  # no unread block can help; fall through to exact fallback

    exact = False
    if not terminated or not histsim.should_terminate(state, params):
        # Exact completion: read everything left, answer becomes exact.
        remaining = np.where(~read_mask)[0]
        if remaining.size:
            exact = True
            for s in range(0, remaining.size, max(window, 1)):
                chunk = remaining[s : s + window]
                cj = jnp.asarray(chunk, jnp.int32)
                state = histsim.ingest(
                    state, z_blocks[cj].reshape(-1), x_blocks[cj].reshape(-1), params=params
                )
                blocks_read += len(chunk)
                tuples_read += int(tuples_per_block[chunk].sum())
            read_mask[remaining] = True
            state = histsim.stats_step(state, params=params)
        exact = True  # all data read either way

    ids = np.asarray(histsim.top_k_ids(state, params.k))
    return MatchResult(
        ids=ids,
        state=state,
        rounds=rounds,
        blocks_read=blocks_read,
        blocks_considered=blocks_considered,
        tuples_read=tuples_read,
        wall_time_s=time.perf_counter() - t0,
        exact=exact,
        passes=passes,
    )
