"""Telemetry overhead + fidelity: observing the server must not change it.

The `repro.obs` subsystem records only at host-sync/poll boundaries, so
the jitted `fused_round` path is byte-for-byte the same program with
telemetry on and off. This benchmark holds the subsystem to its two
contracts:

  1. overhead  — telemetry must add < 2% to the cost of serving the
     seeded batch. Gated on the ACCOUNTED cost: a dedicated run with
     every host-side telemetry entry point wrapped in a reentrancy-
     guarded timer (poll staging, batched flushes, round-batch emits,
     tracer/registry writes), plus the measured marginal cost of the
     two extra `device_get` leaves per poll and a rounded-up charge for
     the per-window `perf_counter` pairs the wrappers cannot see. The
     sum over everything telemetry executes must stay under 2% of the
     telemetry-off wall floor. An interleaved off/on A/B wall
     comparison is also run and REPORTED (floors = mean of each arm's
     3 fastest of ``REPEATS`` alternating runs) as corroborating
     evidence, but not gated: per-process code/data-layout bias on
     shared hosts measured at +-3..8% of a ~250ms serve — an order of
     magnitude above the thing being measured — makes a one-process
     2% wall gate a coin flip, while the accounted sum is stable and
     measures exactly the work telemetry adds.
  2. fidelity  — every engine output is bit-identical across the pair
     (top-k ids, per-query counters, host-sync count, the full
     exported cache: counts/n/read_mask/cursors), and the recorded
     tuples-to-confidence curve reproduces the stats tail: eps_n equals
     `core.bounds.theorem1_epsilon` at the polled n_min and
     per-candidate budget delta/|V_Z|, and a terminated query's final
     recorded delta_upper is below its delta.

The workload oversubscribes the server (18 queries over 6 slots at
tight eps/delta) so admission waves, retire-boundary flushes, and the
multi-pass tail are all inside the measured region.

Reported rows (benchmarks/run.py CSV schema):

  telemetry_off_serve — us per batch, telemetry off (floor estimate)
  telemetry_on_serve  — us per batch, telemetry on  (floor estimate)
  telemetry_overhead  — derived = wall (on - off) / off  [informational]
  telemetry_accounted — derived = accounted_s / off      [the gate]
  telemetry_events    — derived = trace events recorded by one run

Machine-readable results land in benchmarks/results/BENCH_telemetry.json
(gated by benchmarks/check_regression.py on the DETERMINISTIC keys —
bit_identical / curve_matches / ok — never on the wall-clock ratio),
next to the run's trace (telemetry_trace.jsonl) and confidence curves
(telemetry_curves.csv).

Set TELEMETRY_BENCH_SMOKE=1 for the smaller CI configuration (same code
path; exits non-zero if any contract fails).
"""

from __future__ import annotations

import json
import os
import pathlib
import time

import jax
import numpy as np

from benchmarks.common import env_stamp
from repro.core import multiquery
from repro.core.bounds import theorem1_epsilon
from repro.data.layout import block_layout
from repro.data.synth import SynthSpec, make_dataset, perturb_distribution
from repro.obs import registry as obs_registry
from repro.obs import tracer as obs_tracer
from repro.obs import telemetry as obs_telemetry
from repro.serve.fastmatch_server import MatchServer

N_QUERIES = 18   # submitted; oversubscribes MAX_ACTIVE slots -> admission waves
MAX_ACTIVE = 6
K, DELTA, EPS = 10, 0.001, 0.04
SMOKE = bool(int(os.environ.get("TELEMETRY_BENCH_SMOKE", "0")))
REPEATS = 9 if SMOKE else 5
OVERHEAD_LIMIT = 0.02

SPEC = SynthSpec(
    v_z=161, v_x=24, num_tuples=2_000_000 if SMOKE else 4_000_000, k=K, n_close=10,
    close_distance=0.02, far_distance=0.3, zipf_a=1.0, close_rank="head", seed=42,
)
# Smoke keeps a real window size: tiny lookahead makes per-dispatch host
# overhead dominate rather than the sampling engine being measured.
LOOKAHEAD = 128 if SMOKE else 512

RESULTS = pathlib.Path(__file__).parent / "results"


def _targets(ds):
    rng = np.random.default_rng(7)
    return [
        perturb_distribution(ds.target, d, rng)
        for d in np.linspace(0.002, 0.05, N_QUERIES)
    ]


def _serve(blocked, targets, *, telemetry):
    server = MatchServer(
        blocked, max_queries=MAX_ACTIVE, lookahead=LOOKAHEAD, seed=200,
        poll_every=4, prefetch=True, k_cap=K, telemetry=telemetry,
    )
    t0 = time.perf_counter()
    rids = [server.submit(t, k=K, eps=EPS, delta=DELTA) for t in targets]
    results = server.run_until_idle()
    wall = time.perf_counter() - t0
    return server, [results[r] for r in rids], wall


def _fingerprint(server, results):
    """Everything the engine computed, as an exactly-comparable tuple."""
    snap = server.scheduler.export_cache()
    leaves = tuple(np.asarray(leaf) for leaf in snap)
    per_query = tuple(
        (tuple(r.ids.tolist()), r.rounds, r.blocks_read, r.tuples_read,
         r.exact, r.passes)
        for r in results
    )
    return server.scheduler.host_syncs, per_query, leaves


def _identical(fp_a, fp_b) -> bool:
    if fp_a[0] != fp_b[0] or fp_a[1] != fp_b[1]:
        return False
    return all(np.array_equal(a, b) for a, b in zip(fp_a[2], fp_b[2]))


def _curves_match_tail(server) -> bool:
    """Recorded eps_n must BE Theorem 1 at the polled n_min; a
    terminated query's final delta_upper must have crossed its delta."""
    tel = server.telemetry
    retired = {e["qid"]: e for e in tel.tracer.skeleton("query_retire")}
    if set(tel.query_ids()) != set(retired):
        return False
    for qid in tel.query_ids():
        traj = tel.trajectory(qid)
        if not traj:
            return False
        for p in traj:
            ref = float(theorem1_epsilon(
                max(p["n_min"], 1.0), DELTA / SPEC.v_z, SPEC.v_x
            ))
            if not np.isclose(p["eps_n"], ref, rtol=1e-4):
                return False
            if not np.isclose(p["confidence"], max(0.0, 1.0 - p["delta_upper"])):
                return False
        if retired[qid]["terminated"] and traj[-1]["delta_upper"] >= DELTA:
            return False
    return True


# -- accounted-cost machinery ----------------------------------------------

class _CostAccount:
    """Times every wrapped call, reentrancy-guarded so nested wrapped
    calls (e.g. `flush_telemetry` -> `Counter.inc`) count once. The
    depth guard is a plain int: every wrapped entry point runs on the
    serve loop's thread (the prefetch worker only appends to plain
    lists; its measurements are flushed at stream close, on this
    thread). Wrapper cost itself lands INSIDE the measured span, so the
    account can only overstate telemetry's cost — the safe direction
    for a < limit gate.
    """

    def __init__(self):
        self.total_s = 0.0
        self.by_site: dict = {}
        self._depth = 0
        self._saved: list = []

    def _wrap(self, fn, site: str):
        def timed(*args, **kwargs):
            if self._depth:
                return fn(*args, **kwargs)
            self._depth += 1
            t0 = time.perf_counter()
            try:
                return fn(*args, **kwargs)
            finally:
                dt = time.perf_counter() - t0
                self.total_s += dt
                self.by_site[site] = self.by_site.get(site, 0.0) + dt
                self._depth -= 1
        return timed

    def patch(self, *targets):
        for cls, name in targets:
            fn = getattr(cls, name)
            self._saved.append((cls, name, fn))
            setattr(cls, name, self._wrap(fn, f"{cls.__name__}.{name}"))

    def unpatch(self):
        for cls, name, fn in self._saved:
            setattr(cls, name, fn)
        self._saved.clear()


def _timer_call_residual() -> float:
    """Per-window cost of what the wrappers cannot see: the bare
    `perf_counter` pairs in the pump's gather timing and the prefetch
    worker/consumer, plus their list appends. Charged as 8 timer calls
    + 3 appends per window — a rounded-UP census (the off arm pays some
    of these branches too), measured here rather than assumed."""
    sink: list = []
    reps = 20_000
    t0 = time.perf_counter()
    for _ in range(reps):
        time.perf_counter(); time.perf_counter()  # noqa: E702
        time.perf_counter(); time.perf_counter()  # noqa: E702
        time.perf_counter(); time.perf_counter()  # noqa: E702
        time.perf_counter(); time.perf_counter()  # noqa: E702
        sink.append(0.0)
        sink.append(0.0)
        sink.append(0.0)
        if len(sink) >= 30_000:
            sink.clear()
    return (time.perf_counter() - t0) / reps


def _transfer_delta(sch) -> float:
    """Marginal cost of the two extra leaves telemetry adds to the
    single batched `device_get` in `_sync` (tau: (Q, V_Z) f32, n:
    (V_Z,) f32), measured on the final device state."""
    base = (sch.cursor, sch.state.delta_upper)
    full = (sch.cursor, sch.state.delta_upper, sch.state.tau, sch.state.n)
    for _ in range(3):
        jax.device_get(base)
        jax.device_get(full)

    def floor(tree):
        ts = []
        for _ in range(60):
            t0 = time.perf_counter()
            jax.device_get(tree)
            ts.append(time.perf_counter() - t0)
        # mean of the 3 fastest — the marginal floor, insensitive to
        # scheduler blips that a median still feels
        return float(np.mean(sorted(ts)[:3]))

    return max(floor(full) - floor(base), 0.0)


def _accounted_cost(blocked, targets) -> dict:
    """One serve with every telemetry entry point timed; returns the
    breakdown in seconds plus the run's round/sync counts."""
    acc = _CostAccount()
    acc.patch(
        (multiquery.SharedCountsScheduler, "_record_poll"),
        (multiquery.SharedCountsScheduler, "flush_telemetry"),
        (multiquery.SharedCountsScheduler, "_emit_round_batch"),
        (obs_tracer.Tracer, "emit"),
        (obs_registry.Counter, "inc"),
        (obs_registry.Gauge, "set"),
        (obs_registry.Histogram, "observe"),
        (obs_registry.Histogram, "observe_many"),
        (obs_telemetry.Telemetry, "record_curve_point"),
    )
    try:
        server, _results, wall = _serve(blocked, targets, telemetry=True)
    finally:
        acc.unpatch()
    sch = server.scheduler
    per_window = _timer_call_residual()
    leaf_delta = _transfer_delta(sch)
    hooks_s = acc.total_s
    timers_s = sch.rounds * per_window
    transfer_s = sch.host_syncs * leaf_delta
    return dict(
        hooks_s=hooks_s,
        by_site={k: round(v, 6) for k, v in sorted(
            acc.by_site.items(), key=lambda kv: -kv[1])},
        timers_s=timers_s,
        transfer_s=transfer_s,
        total_s=hooks_s + timers_s + transfer_s,
        rounds=sch.rounds,
        host_syncs=sch.host_syncs,
        wall_s=wall,
    )


def run(rows: list) -> None:
    ds = make_dataset(SPEC)
    blocked = block_layout(
        ds.z, ds.x, v_z=SPEC.v_z, v_x=SPEC.v_x, block_size=512, seed=42
    )
    targets = _targets(ds)

    # warmup: compiles the fused round and pays each arm's one-time
    # lazy-init costs outside the timed region
    _serve(blocked, targets, telemetry=None)
    _serve(blocked, targets, telemetry=True)

    # -- interleaved floor timing (reported, not gated) -----------------
    # Floor estimate per arm: mean of the 3 fastest runs — converges to
    # the same floor as a raw min but with less order-statistic jitter.
    # Arm order alternates so slow drift (thermal, co-tenant load)
    # charges both arms equally.
    off_walls, on_walls = [], []
    fp_off = fp_on = None
    last_on = None
    for i in range(REPEATS):
        arms = ((None, off_walls), (True, on_walls))
        for telemetry, walls in arms if i % 2 == 0 else arms[::-1]:
            srv, res, wall = _serve(blocked, targets, telemetry=telemetry)
            walls.append(wall)
            if telemetry is None:
                fp_off = _fingerprint(srv, res)
            else:
                fp_on = _fingerprint(srv, res)
                last_on = srv
    off_s = float(np.mean(sorted(off_walls)[:3]))
    on_s = float(np.mean(sorted(on_walls)[:3]))
    wall_overhead = (on_s - off_s) / off_s

    # -- accounted cost (the gate) --------------------------------------
    account = _accounted_cost(blocked, targets)
    accounted_frac = account["total_s"] / off_s

    bit_identical = _identical(fp_off, fp_on)
    curve_matches = _curves_match_tail(last_on)
    trace_events = last_on.telemetry.tracer.events_total

    RESULTS.mkdir(exist_ok=True)
    last_on.export_trace(RESULTS / "telemetry_trace.jsonl")
    curve_rows = last_on.telemetry.export_confidence_csv(
        RESULTS / "telemetry_curves.csv"
    )
    (RESULTS / "telemetry_metrics.prom").write_text(last_on.prometheus_metrics())

    ok = bit_identical and curve_matches and accounted_frac < OVERHEAD_LIMIT

    rows.append(dict(name="telemetry_off_serve",
                     us_per_call=1e6 * off_s, derived=0))
    rows.append(dict(name="telemetry_on_serve",
                     us_per_call=1e6 * on_s, derived=0))
    rows.append(dict(name="telemetry_overhead", us_per_call=0.0,
                     derived=round(wall_overhead, 4)))
    rows.append(dict(name="telemetry_accounted", us_per_call=0.0,
                     derived=round(accounted_frac, 4)))
    rows.append(dict(name="telemetry_events", us_per_call=0.0,
                     derived=int(trace_events)))

    report = dict(
        config=dict(
            v_z=SPEC.v_z, v_x=SPEC.v_x, num_tuples=SPEC.num_tuples,
            n_queries=N_QUERIES, max_active=MAX_ACTIVE, lookahead=LOOKAHEAD,
            poll_every=4, k=K, eps=EPS, delta=DELTA, repeats=REPEATS,
            smoke=SMOKE, **env_stamp(),
        ),
        off_s=round(off_s, 4),
        on_s=round(on_s, 4),
        wall_overhead_frac=round(wall_overhead, 4),
        accounted=dict(
            hooks_s=round(account["hooks_s"], 6),
            by_site=account["by_site"],
            timers_s=round(account["timers_s"], 6),
            transfer_s=round(account["transfer_s"], 6),
            total_s=round(account["total_s"], 6),
            rounds=account["rounds"],
            host_syncs=account["host_syncs"],
        ),
        accounted_frac=round(accounted_frac, 4),
        overhead_limit=OVERHEAD_LIMIT,
        bit_identical=bit_identical,
        curve_matches=curve_matches,
        trace_events=int(trace_events),
        curve_rows=int(curve_rows),
        ok=ok,
    )
    (RESULTS / "BENCH_telemetry.json").write_text(json.dumps(report, indent=2) + "\n")

    print(f"# telemetry_overhead: off={off_s * 1e3:.0f}ms on={on_s * 1e3:.0f}ms "
          f"(wall {wall_overhead:+.2%} informational; accounted "
          f"{accounted_frac:.2%} of limit {OVERHEAD_LIMIT:.0%}), "
          f"bit_identical={bit_identical}, curve_matches={curve_matches}, "
          f"{trace_events} events -> {'PASS' if ok else 'FAIL'}")
    if SMOKE and not ok:
        raise SystemExit("telemetry_overhead smoke FAILED")


if __name__ == "__main__":
    rows: list = []
    run(rows)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
