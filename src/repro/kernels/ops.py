"""Public jit'd entry points for the kernels package.

Dispatch is three-axis — every hot-path call is the product of three
independent, jit-static choices:

1. **Engine** (the ``impl`` argument): which code family runs.
   ``"auto"`` picks Pallas on TPU and the pure-jnp `metrics`/`ref`
   oracles on CPU (interpret-mode Pallas is far slower than XLA:CPU for
   the same math). Tests force ``impl="pallas"`` with
   ``interpret=True`` to validate the kernels themselves against the
   oracles.

2. **Plan** (the ``plan`` argument on the two hot-path entry points):
   WHICH measured-fastest variant of that engine runs, resolved from
   `repro.kernels.autotune`'s committed per-backend plan file. Shapes
   are concrete at trace time, so a ``plan="auto"`` registry lookup is
   a plain dict get baked into the compiled program — zero dispatch
   cost per call. A lookup miss, a stale plan file, or a plan the
   engine/shape can't run falls back to `autotune.DEFAULT_TAU` /
   `DEFAULT_INGEST`, which reproduce the pre-autotune dispatch bit for
   bit. Pass an explicit `autotune.TauPlan` / `IngestPlan` to pin a
   variant (the round-builders thread a resolved `PlanPair` through
   statically), or ``plan="default"`` to ignore the registry.

3. **Metric** (the ``metric`` argument on `distance_multi`): WHAT the
   per-round computation scores against the shared counts matrix — an
   elementwise-lane distance from the `repro.kernels.metrics` registry
   ("l1" | "chi2" | "hellinger"). The metric is a plain string, so it
   is hashable and jit-static exactly like ``plan``; it is threaded the
   same way (MultiQuerySpec -> fused_round / make_distributed_round /
   make_pump_round -> this module). Metric and plan compose freely —
   every tuned variant runs every metric — and autotune plan keys are
   per-metric, because the score changes the VPU cost that decides
   which variant wins. The ``metric="l1"`` default reproduces the
   pre-metric-layer l1 ops bit for bit.

Plan-driven entry points (variants per engine; every variant of one
metric is bit-identical on integer-valued counts — see
tests/test_autotune.py and tests/test_metrics.py):

  ======================  ==============================================
  op                      plan knobs
  ======================  ==============================================
  distance_multi          variant: "batched" (one counts pass scores all
                          Q targets — `metrics.distance_multi_pallas` /
                          `metrics.distance_multi_ref`), "unrolled" (Q
                          single-query passes stacked), "xla" (fused 3D
                          broadcast, `metrics.distance_multi_xla`);
                          z_tile / x_tile / sweeps (Pallas tiling and
                          single- vs two-sweep V_X phase); lowprec
                          (uint16 counts traffic behind a runtime
                          overflow gate, exact by construction and
                          metric-agnostic — kernels upcast per tile).
  histogram_with_rowsums  fused: one pass with rows reduced from the
                          VMEM-resident counts block
                          (`histogram_with_rowsums_pallas` /
                          `histogram_with_rowsums_ref`) vs the two-step
                          histogram + separate row reduction;
                          s_tile / z_tile (Pallas tiling).
                          ``impl="matmul"`` (chunked one-hot
                          contraction) bypasses the plan — it is an
                          explicit engine request, not a tuned variant.
                          No metric axis: the counts matrix is shared
                          by every metric and query type.
  ======================  ==============================================

Fixed-dispatch entry points (no plan — one variant per engine):
`histogram` (histogram_pallas / histogram_ref / "matmul"),
`l1_distance` (Q=1 l1 — `l1_distance_pallas`, V_X <= 4096 /
`ref.l1_distance_ref`), `anyactive` (anyactive_pallas / anyactive_ref).
`l1_distance_multi` is the l1 pin of `distance_multi`, kept for its
import surface.

`l1_distance` is the Q=1 legacy entry point; every round in the engine
(histsim / multiquery / distributed / pump) routes through
`distance_multi` and `histogram_with_rowsums`, so the plan file is
what the serving loop actually runs. After editing the plan file on
disk, call `autotune.reload()` — it clears the jit caches that hold the
previously-baked plans.
"""

from __future__ import annotations

import functools
from typing import Literal, Union

import jax
import jax.numpy as jnp

from repro.kernels import autotune, ref
from repro.kernels.anyactive import anyactive_pallas
from repro.kernels.histogram import histogram_pallas
from repro.kernels.l1_distance import l1_distance_pallas

__all__ = [
    "histogram",
    "histogram_with_rowsums",
    "distance_multi",
    "l1_distance",
    "l1_distance_multi",
    "anyactive",
    "default_impl",
]

Impl = Literal["auto", "pallas", "ref"]
# "auto": trace-time registry lookup; "default": pin the pre-autotune
# dispatch; or an explicit plan instance (hashable -> jit-static).
TauPlanArg = Union[str, None, autotune.TauPlan]
IngestPlanArg = Union[str, None, autotune.IngestPlan]


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _resolve(impl: Impl) -> str:
    return default_impl() if impl == "auto" else impl


@functools.partial(jax.jit, static_argnames=("v_z", "v_x", "impl", "interpret", "onehot_dtype"))
def histogram(
    z_idx: jax.Array,
    x_idx: jax.Array,
    *,
    v_z: int,
    v_x: int,
    impl: Impl = "auto",
    interpret: bool = False,
    onehot_dtype=jnp.float32,
) -> jax.Array:
    """(V_Z, V_X) f32 histogram of (z, x) pairs; negative ids dropped.

    impl: "pallas" (TPU kernel) | "ref" (scatter-add) | "matmul"
    (chunked one-hot contraction — the MXU formulation in plain jnp).
    """
    if _resolve(impl) == "pallas":
        return histogram_pallas(z_idx, x_idx, v_z=v_z, v_x=v_x, interpret=interpret)
    if impl == "matmul":
        return ref.histogram_matmul(
            z_idx, x_idx, v_z=v_z, v_x=v_x, onehot_dtype=onehot_dtype
        )
    return ref.histogram_ref(z_idx, x_idx, v_z=v_z, v_x=v_x)


@functools.partial(
    jax.jit,
    static_argnames=("v_z", "v_x", "impl", "interpret", "onehot_dtype", "plan"),
)
def histogram_with_rowsums(
    z_idx: jax.Array,
    x_idx: jax.Array,
    *,
    v_z: int,
    v_x: int,
    impl: Impl = "auto",
    interpret: bool = False,
    onehot_dtype=jnp.float32,
    plan: IngestPlanArg = "auto",
) -> tuple:
    """((V_Z, V_X), (V_Z,)) histogram + row-sum delta.

    rows == counts.sum(axis=1) exactly (integer-valued f32 counts), so
    `ingest` can advance ``n_i`` without re-reading the delta matrix.
    Same impl choices as `histogram`; ``plan`` picks the tuned variant
    (fused one-pass vs two-step, Pallas tiles — see the module
    docstring). ``impl="matmul"`` bypasses the plan.
    """
    if impl == "matmul":
        counts = ref.histogram_matmul(
            z_idx, x_idx, v_z=v_z, v_x=v_x, onehot_dtype=onehot_dtype
        )
        return counts, jnp.sum(counts, axis=1)
    return autotune.run_ingest(
        z_idx,
        x_idx,
        v_z=v_z,
        v_x=v_x,
        plan=autotune.coerce_ingest_plan(plan, v_z, v_x),
        engine=_resolve(impl),
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("impl", "interpret"))
def l1_distance(
    counts: jax.Array,
    q_hat: jax.Array,
    *,
    impl: Impl = "auto",
    interpret: bool = False,
) -> jax.Array:
    """(V_Z,) f32 distances tau_i = ||normalize(counts_i) - q_hat||_1."""
    if _resolve(impl) == "pallas":
        return l1_distance_pallas(counts, q_hat, interpret=interpret)
    return ref.l1_distance_ref(counts, q_hat)


@functools.partial(jax.jit, static_argnames=("metric", "impl", "interpret", "plan"))
def distance_multi(
    counts: jax.Array,
    q_hat: jax.Array,
    *,
    metric: str = "l1",
    impl: Impl = "auto",
    interpret: bool = False,
    plan: TauPlanArg = "auto",
) -> jax.Array:
    """(Q, V_Z) f32 batched distances for a (Q, V_X) target matrix.

    ``metric`` picks WHAT is computed (registry score: "l1" | "chi2" |
    "hellinger" — squared Hellinger); ``plan`` picks the tuned variant
    of HOW (batched one-pass / Q-unrolled / fused-3D "xla", plus Pallas
    tiles, sweep phase, and the uint16 low-precision counts path — see
    the module docstring). The default plan is the batched form: HBM
    traffic Q * V_Z * V_X -> V_Z * V_X + Q * V_X, independent of Q and
    of the metric. Within one metric all variants are bit-identical on
    integer-valued counts, so the plan is a pure wall-clock choice.
    Unlike the Q=1 `l1_distance`, V_X is unbounded (lane-tiled on TPU).
    """
    tau_plan = autotune.coerce_tau_plan(
        plan, counts.shape[0], counts.shape[1], q_hat.shape[0], metric
    )
    return autotune.run_tau(
        counts, q_hat, plan=tau_plan, engine=_resolve(impl),
        interpret=interpret, metric=metric,
    )


def l1_distance_multi(
    counts: jax.Array,
    q_hat: jax.Array,
    *,
    impl: Impl = "auto",
    interpret: bool = False,
    plan: TauPlanArg = "auto",
) -> jax.Array:
    """`distance_multi` pinned to metric="l1" (the pre-metric-layer
    entry point, bit-identical to it; kept for its import surface)."""
    return distance_multi(
        counts, q_hat, metric="l1", impl=impl, interpret=interpret, plan=plan
    )


@functools.partial(jax.jit, static_argnames=("impl", "interpret"))
def anyactive(
    bitmap: jax.Array,
    active_words: jax.Array,
    *,
    impl: Impl = "auto",
    interpret: bool = False,
) -> jax.Array:
    """(num_blocks,) bool AnyActive marks from a packed bitmap."""
    if _resolve(impl) == "pallas":
        return anyactive_pallas(bitmap, active_words, interpret=interpret)
    return ref.anyactive_ref(bitmap, active_words)
