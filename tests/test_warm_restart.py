"""Warm-start persistence: checkpoint/restore of the shared sample cache.

The load-bearing property (golden equivalence): a `MatchServer` restored
from a snapshot must answer a freshly submitted query with BIT-IDENTICAL
counts, tau, and result to the uninterrupted server it was saved from —
the warm cache is the whole serving speedup, so a restart must not
degrade it, and a stale cache (different layout/spec) must be rejected
rather than silently corrupting bounds.
"""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import multiquery as mq
from repro.data.layout import block_layout
from repro.data.synth import SynthSpec, make_dataset, perturb_distribution
from repro.serve.fastmatch_server import MatchServer

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
K, EPS, DELTA = 5, 0.08, 0.05


@pytest.fixture(scope="module")
def dataset():
    spec = SynthSpec(
        v_z=64, v_x=16, num_tuples=600_000, k=K, n_close=5,
        close_distance=0.02, far_distance=0.3, zipf_a=0.9, seed=5,
    )
    ds = make_dataset(spec)
    blocked = block_layout(ds.z, ds.x, v_z=64, v_x=16, block_size=512, seed=5)
    return ds, blocked


@pytest.fixture(scope="module")
def targets(dataset):
    ds, _ = dataset
    rng = np.random.default_rng(9)
    return [ds.target] + [perturb_distribution(ds.target, d, rng) for d in (0.01, 0.03)]


def _server(blocked, ckpt_dir=None, **kw):
    kw.setdefault("max_queries", 4)
    kw.setdefault("lookahead", 64)
    kw.setdefault("seed", 3)
    return MatchServer(blocked, checkpoint_dir=ckpt_dir, **kw)


def _serve_and_save(blocked, targets, ckpt_dir, **kw):
    server = _server(blocked, str(ckpt_dir), **kw)
    for t in targets:
        server.submit(t, k=K, eps=EPS, delta=DELTA)
    server.run_until_idle()
    server.save_cache()
    return server


class TestSchedulerHooks:
    """export_cache / import_cache on the scheduler itself."""

    def test_export_import_roundtrip(self, dataset, targets):
        _, blocked = dataset
        spec = mq.MultiQuerySpec(v_z=blocked.v_z, v_x=blocked.v_x, max_queries=2)
        a = mq.SharedCountsScheduler(blocked, spec, window=64, seed=1)
        a.admit(targets[0], k=K, eps=EPS, delta=DELTA)
        a.pump()
        snap = a.export_cache()

        b = mq.SharedCountsScheduler(blocked, spec, window=64, seed=777)
        b.import_cache(snap)
        np.testing.assert_array_equal(np.asarray(a.state.counts), np.asarray(b.state.counts))
        np.testing.assert_array_equal(np.asarray(a.state.n), np.asarray(b.state.n))
        np.testing.assert_array_equal(a.read_mask, b.read_mask)
        np.testing.assert_array_equal(a.order, b.order)  # visit order restored, not seed 777's
        assert (a.rounds, a.passes, a.blocks_read, a.tuples_read) == (
            b.rounds, b.passes, b.blocks_read, b.tuples_read)

    def test_place_cache_reshard_in_memory(self, dataset, targets):
        """place_cache re-places a snapshot per cache_pspecs without the
        disk round-trip (single-device mesh here; placement API +
        value preservation exercised)."""
        import jax
        from jax.sharding import Mesh

        from repro.core.distributed import place_cache

        _, blocked = dataset
        spec = mq.MultiQuerySpec(v_z=blocked.v_z, v_x=blocked.v_x, max_queries=2)
        a = mq.SharedCountsScheduler(blocked, spec, window=64, seed=1)
        a.admit(targets[0], k=K, eps=EPS, delta=DELTA)
        a.pump()
        snap = a.export_cache()
        mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        placed = place_cache(snap, mesh)
        assert "model" in str(placed.counts.sharding)
        np.testing.assert_array_equal(np.asarray(snap.counts), np.asarray(placed.counts))
        np.testing.assert_array_equal(np.asarray(snap.read_mask), np.asarray(placed.read_mask))

    def test_import_with_live_queries_refused(self, dataset, targets):
        _, blocked = dataset
        spec = mq.MultiQuerySpec(v_z=blocked.v_z, v_x=blocked.v_x, max_queries=2)
        a = mq.SharedCountsScheduler(blocked, spec, window=64, seed=1)
        snap = a.export_cache()
        a.admit(targets[0], k=K, eps=EPS, delta=DELTA)
        with pytest.raises(RuntimeError, match="live queries"):
            a.import_cache(snap)

    def test_import_wrong_layout_shape_refused(self, dataset, targets):
        _, blocked = dataset
        spec = mq.MultiQuerySpec(v_z=blocked.v_z, v_x=blocked.v_x, max_queries=2)
        snap = mq.SharedCountsScheduler(blocked, spec, window=64, seed=1).export_cache()
        other = block_layout(
            np.zeros(1024, np.int64), np.zeros(1024, np.int64),
            v_z=blocked.v_z, v_x=blocked.v_x, block_size=512, seed=0,
        )
        b = mq.SharedCountsScheduler(other, spec, window=2, seed=1)
        with pytest.raises(ValueError, match="read_mask"):
            b.import_cache(snap)


class TestGoldenEquivalence:
    """restart == no restart, bit for bit, for the next query."""

    def test_restored_server_bit_identical(self, dataset, targets, tmp_path):
        ds, blocked = dataset
        a = _serve_and_save(blocked, targets, tmp_path)
        b = MatchServer.restore(
            blocked, checkpoint_dir=str(tmp_path), max_queries=4, lookahead=64, seed=999,
        )
        np.testing.assert_array_equal(
            np.asarray(a.scheduler.state.counts), np.asarray(b.scheduler.state.counts))
        np.testing.assert_array_equal(a.scheduler.read_mask, b.scheduler.read_mask)

        # a demanding fresh query: must keep sampling on BOTH servers,
        # exercising identical continued marking/ingest trajectories
        rng = np.random.default_rng(4)
        fresh = perturb_distribution(ds.target, 0.05, rng)
        ra_id = a.submit(fresh, k=K, eps=0.04, delta=0.01)
        ra = a.run_until_idle()[ra_id]
        rb_id = b.submit(fresh, k=K, eps=0.04, delta=0.01)
        rb = b.run_until_idle()[rb_id]

        np.testing.assert_array_equal(ra.ids, rb.ids)
        np.testing.assert_array_equal(  # tau of the served slot, bit for bit
            np.asarray(ra.state.tau), np.asarray(rb.state.tau))
        np.testing.assert_array_equal(
            np.asarray(a.scheduler.state.counts), np.asarray(b.scheduler.state.counts))
        assert ra.exact == rb.exact
        assert ra.tuples_read == rb.tuples_read
        assert ra.rounds == rb.rounds
        assert ra.delta_upper == rb.delta_upper

    def test_warm_restart_answers_covered_query_with_zero_io(
        self, dataset, targets, tmp_path
    ):
        ds, blocked = dataset
        _serve_and_save(blocked, targets, tmp_path)
        b = MatchServer.restore(
            blocked, checkpoint_dir=str(tmp_path), max_queries=4, lookahead=64,
        )
        before = b.metrics["total_tuples_read"]
        rng = np.random.default_rng(11)
        rid = b.submit(perturb_distribution(ds.target, 0.02, rng), k=K, eps=EPS, delta=DELTA)
        res = b.run_until_idle()[rid]
        assert res.tuples_read == 0
        assert b.metrics["total_tuples_read"] == before  # zero new I/O after restart


class TestCrashAtomicityAndStaleness:
    def test_kill_mid_save_falls_back_to_newest_complete_step(
        self, dataset, targets, tmp_path
    ):
        ds, blocked = dataset
        a = _serve_and_save(blocked, targets, tmp_path)
        want_counts = np.asarray(a.scheduler.state.counts)
        # simulate a process dying mid-save: a populated .tmp.<pid> dir
        # (dead pid) and a truncated LATEST pointer
        orphan = tmp_path / "step_9999.tmp.4190001"
        orphan.mkdir()
        (orphan / "arr_0.npy").write_bytes(b"half-written junk")
        (tmp_path / "LATEST").write_text("")
        b = MatchServer.restore(
            blocked, checkpoint_dir=str(tmp_path), max_queries=4, lookahead=64,
        )
        np.testing.assert_array_equal(want_counts, np.asarray(b.scheduler.state.counts))
        # the next successful save sweeps the orphan
        b.save_cache()
        assert not orphan.exists()
        assert (tmp_path / "LATEST").read_text().startswith("step_")

    def test_stale_layout_rejected(self, dataset, targets, tmp_path):
        ds, blocked = dataset
        _serve_and_save(blocked, targets, tmp_path)
        reshuffled = block_layout(
            ds.z, ds.x, v_z=blocked.v_z, v_x=blocked.v_x, block_size=512, seed=6,
        )
        with pytest.raises(ValueError, match="config hash"):
            MatchServer.restore(
                reshuffled, checkpoint_dir=str(tmp_path), max_queries=4, lookahead=64,
            )

    def test_stale_v_x_rejected(self, dataset, targets, tmp_path):
        ds, blocked = dataset
        _serve_and_save(blocked, targets, tmp_path)
        coarser = block_layout(
            ds.z, np.minimum(ds.x, 7), v_z=blocked.v_z, v_x=8, block_size=512, seed=5,
        )
        # max_queries matches the saved spec, so the ONLY hash difference
        # is the layout/content side (v_x) — isolates what this test pins
        with pytest.raises(ValueError, match="config hash"):
            MatchServer.restore(
                coarser, checkpoint_dir=str(tmp_path), max_queries=4, lookahead=64,
            )

    def test_stale_spec_rejected(self, dataset, targets, tmp_path):
        _, blocked = dataset
        _serve_and_save(blocked, targets, tmp_path)
        with pytest.raises(ValueError, match="config hash"):
            MatchServer.restore(
                blocked, checkpoint_dir=str(tmp_path), max_queries=8, lookahead=64,
            )

    def test_missing_checkpoint_raises(self, dataset, tmp_path):
        _, blocked = dataset
        with pytest.raises(FileNotFoundError):
            MatchServer.restore(blocked, checkpoint_dir=str(tmp_path / "empty"))


class TestAutosave:
    def test_retirement_cadence(self, dataset, targets, tmp_path):
        _, blocked = dataset
        server = _server(blocked, str(tmp_path), autosave_every=1)
        for t in targets:
            server.submit(t, k=K, eps=EPS, delta=DELTA)
        server.run_until_idle()
        # retirements alone must have produced a restorable snapshot
        assert server._manager.latest_step() is not None
        b = MatchServer.restore(
            blocked, checkpoint_dir=str(tmp_path), max_queries=4, lookahead=64,
        )
        np.testing.assert_array_equal(
            np.asarray(server.scheduler.state.counts), np.asarray(b.scheduler.state.counts))

    def test_round_cadence(self, dataset, targets, tmp_path):
        _, blocked = dataset
        server = _server(
            blocked, str(tmp_path), autosave_every=0, autosave_rounds=1,
        )
        server.submit(targets[0], k=K, eps=EPS, delta=DELTA)
        server.run_until_idle()
        assert server._manager.latest_step() is not None

    def test_save_without_new_rounds_bumps_step(self, dataset, targets, tmp_path):
        """restore -> save_cache with zero new rounds must write a NEW
        step, never re-write the one LATEST points at (re-writing it
        would reopen the mid-save crash window on the only snapshot)."""
        _, blocked = dataset
        _serve_and_save(blocked, targets, tmp_path)
        b = MatchServer.restore(
            blocked, checkpoint_dir=str(tmp_path), max_queries=4, lookahead=64,
        )
        before = b._manager.latest_step()
        b.save_cache()
        assert b._manager.latest_step() == before + 1

    def test_no_checkpoint_dir_save_refused(self, dataset):
        _, blocked = dataset
        server = _server(blocked, None)
        with pytest.raises(RuntimeError, match="checkpoint_dir"):
            server.save_cache()


@pytest.mark.slow
class TestReshardedRestore:
    """Elastic restart: a snapshot written under one mesh shape restores
    candidate-sharded onto another (1 -> 8 and 8 -> 4 device splits)."""

    def test_reshard_1_to_8_to_4(self, tmp_path):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        code = textwrap.dedent(f"""
            import json, numpy as np, jax
            from jax.sharding import Mesh
            from repro.data.layout import block_layout
            from repro.data.synth import SynthSpec, make_dataset, perturb_distribution
            from repro.serve.fastmatch_server import MatchServer

            ckpt = {str(tmp_path)!r}
            spec = SynthSpec(v_z=64, v_x=16, num_tuples=400_000, k=5, n_close=5,
                             close_distance=0.02, far_distance=0.3, zipf_a=0.9, seed=5)
            ds = make_dataset(spec)
            blocked = block_layout(ds.z, ds.x, v_z=64, v_x=16, block_size=512, seed=5)
            rng = np.random.default_rng(9)
            fresh = perturb_distribution(ds.target, 0.05, rng)
            kw = dict(max_queries=4, lookahead=64)

            a = MatchServer(blocked, seed=3, checkpoint_dir=ckpt, **kw)
            a.submit(ds.target, k=5, eps=0.08, delta=0.05)
            a.run_until_idle()
            a.save_cache()

            mesh8 = Mesh(np.array(jax.devices()).reshape(1, 8), ("data", "model"))
            b = MatchServer.restore(blocked, checkpoint_dir=ckpt, mesh=mesh8, **kw)
            eq_18 = bool(np.array_equal(np.asarray(a.scheduler.state.counts),
                                        np.asarray(b.scheduler.state.counts)))
            sharded = "model" in str(b.scheduler.state.counts.sharding)
            # re-save the SAME cache from the 8-way sharded server (the
            # snapshot host-gathers the sharded counts) before any new
            # sampling, then restore it onto a 4-device mesh
            b.save_cache()
            mesh4 = Mesh(np.array(jax.devices()[:4]).reshape(1, 4), ("data", "model"))
            c = MatchServer.restore(blocked, checkpoint_dir=ckpt, mesh=mesh4, **kw)
            eq_84 = bool(np.array_equal(np.asarray(b.scheduler.state.counts),
                                        np.asarray(c.scheduler.state.counts)))

            # the same demanding fresh query must now follow an identical
            # continued-sampling trajectory on all three mesh shapes
            results = []
            for srv in (a, b, c):
                rid = srv.submit(fresh, k=5, eps=0.04, delta=0.01)
                results.append(srv.run_until_idle()[rid])
            ra, rb, rc = results

            print(json.dumps(dict(
                eq_18=eq_18, eq_84=eq_84, sharded=sharded,
                ids_18=bool(np.array_equal(ra.ids, rb.ids)),
                ids_84=bool(np.array_equal(rb.ids, rc.ids)),
                tuples=[int(ra.tuples_read), int(rb.tuples_read), int(rc.tuples_read)],
            )))
        """)
        out = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True, env=env,
            timeout=900,
        )
        assert out.returncode == 0, out.stderr[-4000:]
        res = json.loads(out.stdout.strip().splitlines()[-1])
        assert res["eq_18"] and res["eq_84"] and res["sharded"], res
        assert res["ids_18"] and res["ids_84"], res
        assert res["tuples"][0] == res["tuples"][1] == res["tuples"][2], res

    def test_pump_reshard_8_to_4_workers(self, tmp_path):
        """Pump-mode elastic restart: a cache checkpointed under an
        8-worker pump restores into a 4-worker pump (and into the
        single-stream GSPMD server — snapshots are global, not
        per-worker) with bit-identical counts/read_mask/counters, and a
        fresh query covered by the warm cache answers with bit-identical
        counts/tau/result on every restored width. (A query that must
        KEEP sampling sees each width's own per-worker visit
        interleaving — answers agree as matching sets, compared below —
        but the warm prefix itself must be width-invariant bit for bit.)
        """
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        code = textwrap.dedent(f"""
            import json, numpy as np, jax
            from jax.sharding import Mesh
            from repro.data.layout import block_layout
            from repro.data.synth import SynthSpec, make_dataset, perturb_distribution
            from repro.serve.fastmatch_server import MatchServer

            ckpt = {str(tmp_path)!r}
            spec = SynthSpec(v_z=64, v_x=16, num_tuples=400_000, k=5, n_close=5,
                             close_distance=0.02, far_distance=0.3, zipf_a=0.9, seed=5)
            ds = make_dataset(spec)
            blocked = block_layout(ds.z, ds.x, v_z=64, v_x=16, block_size=512, seed=5)
            rng = np.random.default_rng(9)
            kw = dict(max_queries=4, lookahead=64)

            mesh8 = Mesh(np.array(jax.devices()).reshape(8, 1), ("data", "model"))
            a = MatchServer(blocked, seed=3, checkpoint_dir=ckpt, mesh=mesh8,
                            pump=True, **kw)
            for d in (0.0, 0.01, 0.03):
                a.submit(perturb_distribution(ds.target, d, rng) if d else ds.target,
                         k=5, eps=0.08, delta=0.05)
            a.run_until_idle()
            a.save_cache()

            mesh4 = Mesh(np.array(jax.devices()[:4]).reshape(4, 1), ("data", "model"))
            b = MatchServer.restore(blocked, checkpoint_dir=ckpt, mesh=mesh4,
                                    pump=True, **kw)
            plain = MatchServer.restore(blocked, checkpoint_dir=ckpt, **kw)
            eq = lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y)))
            restore_ok = (
                eq(a.scheduler.state.counts, b.scheduler.state.counts)
                and eq(a.scheduler.read_mask, b.scheduler.read_mask)
                and eq(a.scheduler.state.counts, plain.scheduler.state.counts)
                and a.scheduler.rounds == b.scheduler.rounds == plain.scheduler.rounds
                and a.scheduler.tuples_read == b.scheduler.tuples_read
                    == plain.scheduler.tuples_read)

            # covered fresh query: zero new I/O on every width -> the
            # whole answer (ids, tau, counts) must be bit-identical
            covered = perturb_distribution(ds.target, 0.02, np.random.default_rng(4))
            outs = []
            for srv in (a, b, plain):
                rid = srv.submit(covered, k=5, eps=0.08, delta=0.05)
                outs.append(srv.run_until_idle()[rid])
            ra, rb, rp = outs
            covered_ok = (
                eq(ra.ids, rb.ids) and eq(ra.ids, rp.ids)
                and eq(ra.state.tau, rb.state.tau) and eq(ra.state.tau, rp.state.tau)
                and ra.tuples_read == rb.tuples_read == rp.tuples_read == 0)

            # demanding fresh query: must keep sampling; widths may
            # interleave blocks differently but the matching SET agrees
            hard = perturb_distribution(ds.target, 0.05, np.random.default_rng(11))
            sets = []
            for srv in (a, b, plain):
                rid = srv.submit(hard, k=5, eps=0.04, delta=0.01)
                r = srv.run_until_idle()[rid]
                sets.append((sorted(r.ids.tolist()), r.exact))
            print(json.dumps(dict(
                restore_ok=restore_ok, covered_ok=covered_ok,
                hard_ok=sets[0] == sets[1] == sets[2])))
        """)
        out = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True, env=env,
            timeout=900,
        )
        assert out.returncode == 0, out.stderr[-4000:]
        res = json.loads(out.stdout.strip().splitlines()[-1])
        assert res["restore_ok"], res
        assert res["covered_ok"], res
        assert res["hard_ok"], res
