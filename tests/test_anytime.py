"""Golden suite for the anytime serving API (PR 10).

Five contracts, each pinned here:

  1. STOP == POLL — an SLA-stopped query's answer at round r is
     bit-identical to what `poll_result` reports at round r on an
     unstopped twin of the same seeded stream (`retire` assembles the
     stopped answer through `SharedCountsScheduler.peek`, the same
     host code path serving live polls).
  2. STREAM ENDS AT BLOCKING — a converged `iter_results` stream's
     final answer matches the blocking `run_until_idle` result bit for
     bit (ids, tau, delta_upper, exact) on an identical twin.
  3. PRUNE SOUND — with early-reject pruning on, the pruned mask is
     sticky and a pruned candidate never reappears in any later best
     set (polled every round), and the final answer matches the
     unpruned run.
  4. NATIVE <= CONSERVATIVE — the tau-aware native budget family
     dominates the uniform per-metric budgets pointwise (so the sample
     requirement never exceeds the conservative one), collapses to the
     l1 arm bit-identically, and its epsilon inversion round-trips.
  5. SLA PLUMBING — StopPolicy validation/ordering, supervisor
     threading (deadline composition, crash-resubmission carry,
     shed-poll KeyError), and the CURVE_COLUMNS vocabulary equality
     between polls and telemetry.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import bounds
from repro.core.multiquery import AnytimeAnswer, StopPolicy
from repro.data.layout import block_layout
from repro.data.synth import SynthSpec, make_dataset
from repro.obs import CURVE_COLUMNS
from repro.serve.fastmatch_server import MatchServer
from repro.serve.supervisor import ServeSupervisor, SupervisorPolicy

jax.config.update("jax_platform_name", "cpu")

K, DELTA, SEED = 5, 0.05, 3


@pytest.fixture(scope="module")
def served():
    spec = SynthSpec(
        v_z=48, v_x=16, num_tuples=120_000, k=K, n_close=6,
        close_distance=0.03, far_distance=0.4, zipf_a=1.0, seed=SEED,
    )
    ds = make_dataset(spec)
    blocked = block_layout(
        ds.z, ds.x, v_z=48, v_x=16, block_size=512, seed=SEED
    )
    return ds, blocked


def _server(blocked, **kw):
    kw.setdefault("max_queries", 2)
    kw.setdefault("lookahead", 8)
    kw.setdefault("seed", SEED)
    return MatchServer(blocked, **kw)


# ---------------------------------------------------------------------------
# 1. STOP == POLL
# ---------------------------------------------------------------------------


class TestStopEqualsPoll:
    def test_stopped_answer_is_the_poll_at_that_round(self, served):
        ds, blocked = served
        budget = 20_000
        a = _server(blocked)
        rid_a = a.submit(ds.target, k=K, eps=0.02, delta=0.01,
                         stop=StopPolicy(tuples=budget))
        res = a.run_until_idle()[rid_a]
        assert res.stopped and res.stop_reason == "tuples" and not res.exact
        assert res.tuples_read >= budget
        ans_a = a.poll_result(rid_a)
        assert ans_a.status == "done" and ans_a.result is res

        # unstopped twin of the same seeded stream, stepped to the
        # stopping round, then polled: bit-identical statement
        b = _server(blocked)
        rid_b = b.submit(ds.target, k=K, eps=0.02, delta=0.01)
        while b.scheduler.rounds < ans_a.round and rid_b not in b.results:
            b.step()
        ans_b = b.poll_result(rid_b)
        assert ans_b.status == "live" and ans_b.round == ans_a.round
        assert ans_a.ids.tobytes() == ans_b.ids.tobytes()
        assert ans_a.tau.tobytes() == ans_b.tau.tobytes()
        assert ans_a.margin.tobytes() == ans_b.margin.tobytes()
        assert ans_a.split == ans_b.split
        assert ans_a.delta_upper == ans_b.delta_upper
        assert ans_a.n_min == ans_b.n_min
        assert ans_a.tuples == ans_b.tuples
        assert ans_a.eps_n == ans_b.eps_n
        b.run_until_idle()

    def test_result_mirrors_the_anytime_statement(self, served):
        ds, blocked = served
        srv = _server(blocked)
        rid = srv.submit(ds.target, k=K, eps=0.02, delta=0.01,
                         stop=StopPolicy(tuples=15_000))
        res = srv.run_until_idle()[rid]
        ans = srv.poll_result(rid)
        assert np.array_equal(ans.ids, np.asarray(res.ids))
        assert ans.stopped and ans.stop_reason == res.stop_reason
        assert not ans.exact

    def test_statistical_convergence_beats_the_sla(self, served):
        # a policy that would fire is ignored when the bound fires
        # first at the same poll: the answer retires as terminated
        ds, blocked = served
        srv = _server(blocked, lookahead=64)
        rid = srv.submit(ds.target, k=K, eps=0.08, delta=DELTA,
                         stop=StopPolicy(tuples=10**9))
        res = srv.run_until_idle()[rid]
        assert not res.stopped and res.stop_reason == ""


# ---------------------------------------------------------------------------
# 2. STREAM ENDS AT BLOCKING
# ---------------------------------------------------------------------------


class TestStreamEndsAtBlocking:
    @pytest.mark.parametrize("metric", ["l1", "chi2"])
    def test_converged_stream_matches_blocking_twin(self, served, metric):
        ds, blocked = served
        eps = 0.08 if metric == "l1" else 0.15

        a = _server(blocked, metric=metric)
        rid_a = a.submit(ds.target, k=K, eps=eps, delta=DELTA)
        stream = list(a.iter_results(rid_a))
        final = stream[-1]
        assert final.status == "done"
        assert [s.status for s in stream[:-1]].count("done") == 0

        b = _server(blocked, metric=metric)
        rid_b = b.submit(ds.target, k=K, eps=eps, delta=DELTA)
        blocking = b.run_until_idle()[rid_b]
        # ids: exact same candidates in the same order (the outcome's
        # device ids are int32, the poll's host ids int64 — value-exact)
        assert final.ids.tolist() == np.asarray(blocking.ids).tolist()
        assert final.result.state.tau.tobytes() == blocking.state.tau.tobytes()
        assert final.delta_upper == blocking.delta_upper
        assert final.exact == blocking.exact
        assert final.round == a.scheduler.rounds == b.scheduler.rounds

    def test_stream_is_at_poll_granularity_and_dedups(self, served):
        ds, blocked = served
        srv = _server(blocked)
        rid = srv.submit(ds.target, k=K, eps=0.08, delta=DELTA)
        rounds = [a.round for a in srv.iter_results(rid) if a.status == "live"]
        assert rounds == sorted(set(rounds))  # strictly refining polls

    def test_queued_statement_is_vacuous(self, served):
        ds, blocked = served
        srv = _server(blocked, max_queries=1, lookahead=64)
        ra = srv.submit(ds.target, k=K, eps=0.08, delta=DELTA)
        rb = srv.submit(ds.target, k=3, eps=0.08, delta=DELTA)
        srv.step()
        live, queued = srv.poll_result(ra), srv.poll_result(rb)
        assert live.status == "live" and live.ids.size == K
        assert queued.status == "queued"
        assert queued.delta_upper == 1.0 and queued.confidence == 0.0
        assert queued.ids.size == 0 and queued.n_min == 0.0
        with pytest.raises(KeyError):
            srv.poll_result(999)
        srv.run_until_idle()

    def test_curve_vocabulary_matches_telemetry(self, served):
        ds, blocked = served
        srv = _server(blocked, lookahead=64, telemetry=True)
        rid = srv.submit(ds.target, k=K, eps=0.08, delta=DELTA)
        polls = []
        for ans in srv.iter_results(rid):
            assert tuple(ans.curve_point()) == CURVE_COLUMNS
            polls.append(ans)
            if ans.status != "queued":  # queued statements are vacuous
                srv.telemetry.record_anytime(99, ans)  # side curve, poll-fed
        # an externally recorded poll point equals the scheduler's own
        # trajectory point at the same round
        own = {p["round"]: p for p in srv.telemetry.trajectory(0)}
        fed = srv.telemetry.trajectory(99)
        assert fed, "polled points must land on the side curve"
        for p in fed:
            if p["round"] in own and p["tuples"] == own[p["round"]]["tuples"]:
                assert p == own[p["round"]]


# ---------------------------------------------------------------------------
# 3. PRUNE SOUND
# ---------------------------------------------------------------------------


class TestPruneSound:
    def test_pruned_never_reappears_and_answer_unchanged(self, served):
        ds, blocked = served
        runs = {}
        for prune in (False, True):
            srv = _server(blocked, metric="chi2", prune=prune)
            rid = srv.submit(ds.target, k=K, eps=0.15, delta=DELTA)
            best_sets, masks = [], []
            for ans in srv.iter_results(rid):
                if ans.status == "live":
                    best_sets.append(set(ans.ids.tolist()))
                    masks.append(srv.scheduler._pruned_host[0].copy())
            runs[prune] = (srv.results[rid], best_sets, masks)

        res, best_sets, masks = runs[True]
        assert masks[-1].any(), "chi2 at this radius must actually prune"
        # sticky: the mask only grows
        for a, b in zip(masks, masks[1:]):
            assert not (a & ~b).any()
        # a pruned candidate is out of every later best set, final included
        final_set = set(res.ids.tolist())
        for i, m in enumerate(masks):
            pruned = set(np.flatnonzero(m).tolist())
            for later in best_sets[i:] + [final_set]:
                assert not (pruned & later)
        # and pruning changed no answer
        assert sorted(res.ids.tolist()) == sorted(runs[False][0].ids.tolist())

    def test_prune_off_is_the_default_and_mask_stays_empty(self, served):
        ds, blocked = served
        srv = _server(blocked)
        assert srv.spec.prune is False
        rid = srv.submit(ds.target, k=K, eps=0.08, delta=DELTA)
        srv.run_until_idle()
        assert not srv.scheduler._pruned_host.any()
        assert rid in srv.results


# ---------------------------------------------------------------------------
# 4. NATIVE <= CONSERVATIVE
# ---------------------------------------------------------------------------


class TestNativeBounds:
    EPS_GRID = np.asarray([0.01, 0.05, 0.15, 0.3, 0.6, 1.0], np.float32)
    TAU_GRID = np.asarray([0.0, 0.02, 0.1, 0.3, 0.8, 1.5], np.float32)

    @pytest.mark.parametrize("metric", ["chi2", "hellinger"])
    def test_native_budget_dominates_uniform(self, metric):
        for eps in self.EPS_GRID:
            uni = float(bounds.metric_l1_budget(eps, metric))
            for tau in self.TAU_GRID:
                nat = float(bounds.metric_native_l1_budget(eps, tau, metric))
                # bigger l1 budget == fewer samples needed
                assert nat >= uni - 1e-7, (metric, eps, tau, nat, uni)
                assert bounds.theorem1_samples(nat, 1e-3, 16) <= (
                    bounds.theorem1_samples(uni, 1e-3, 16)
                )

    @pytest.mark.parametrize("metric", ["chi2", "hellinger"])
    def test_native_strictly_better_somewhere(self, metric):
        # the tau-aware route must actually buy something at small tau
        eps = 0.3
        uni = float(bounds.metric_l1_budget(eps, metric))
        nat = float(bounds.metric_native_l1_budget(eps, 0.0, metric))
        assert nat > uni * 1.5

    def test_l1_arm_is_bit_identical(self):
        eps = jnp.asarray(self.EPS_GRID)
        n = jnp.asarray([10.0, 100.0, 5000.0])[:, None]
        old = bounds.theorem1_log_delta(eps, n, 16)
        new = bounds.metric_native_log_delta(eps, n, 16, tau=0.5, metric="l1")
        assert np.asarray(old).tobytes() == np.asarray(new).tobytes()

    @pytest.mark.parametrize("metric", ["l1", "chi2", "hellinger"])
    def test_epsilon_inversion_round_trips(self, metric):
        # eps(n) must be spendable: plugging it back yields <= delta
        for tau in self.TAU_GRID:
            for delta_i in (1e-2, 1e-4):
                n = jnp.asarray([50.0, 500.0, 20_000.0])
                eps = bounds.metric_native_epsilon(
                    n, delta_i, 16, tau=tau, metric=metric
                )
                ld = bounds.metric_native_log_delta(
                    eps, n, 16, tau=tau, metric=metric
                )
                assert np.all(np.asarray(ld) <= np.log(delta_i) + 1e-4)

    @pytest.mark.parametrize("metric", ["chi2", "hellinger"])
    def test_serving_native_no_slower_same_answer(self, served, metric):
        ds, blocked = served
        eps = {"chi2": 0.15, "hellinger": 0.25}[metric]
        got = {}
        for mode in ("conservative", "native"):
            srv = _server(blocked, metric=metric, bounds_mode=mode)
            rid = srv.submit(ds.target, k=K, eps=eps, delta=DELTA)
            got[mode] = srv.run_until_idle()[rid]
        assert got["native"].rounds <= got["conservative"].rounds
        assert sorted(got["native"].ids.tolist()) == sorted(
            got["conservative"].ids.tolist()
        )

    def test_bounds_mode_rejects_unknown(self, served):
        ds, blocked = served
        with pytest.raises(ValueError, match="bounds_mode"):
            _server(blocked, bounds_mode="optimistic")


# ---------------------------------------------------------------------------
# 5. SLA PLUMBING
# ---------------------------------------------------------------------------


class TestStopPolicy:
    def test_needs_at_least_one_criterion(self):
        with pytest.raises(ValueError):
            StopPolicy()

    @pytest.mark.parametrize(
        "kw", [dict(wall_ms=-1), dict(confidence=1.5), dict(tuples=-1)]
    )
    def test_rejects_bad_ranges(self, kw):
        with pytest.raises(ValueError):
            StopPolicy(**kw)

    def test_fired_prefers_strongest_answer_first(self):
        p = StopPolicy(wall_ms=1.0, confidence=0.5, tuples=100)
        assert p.fired(wall_s=1.0, confidence=0.9, tuples=200) == "confidence"
        assert p.fired(wall_s=1.0, confidence=0.1, tuples=200) == "tuples"
        assert p.fired(wall_s=1.0, confidence=0.1, tuples=50) == "wall_ms"
        assert p.fired(wall_s=1e-6, confidence=0.1, tuples=50) == ""


class TestSupervisorSLA:
    def test_stop_threads_through_and_shed_polls_raise(self, served):
        ds, blocked = served
        sup = ServeSupervisor(
            blocked, policy=SupervisorPolicy(max_queue=1),
            max_queries=1, lookahead=8, seed=SEED,
        )
        r1 = sup.submit(ds.target, k=K, eps=0.03, delta=DELTA,
                        stop=StopPolicy(tuples=15_000))
        r2 = sup.submit(ds.target, k=K, eps=0.03, delta=DELTA)
        sup.run_until_idle()
        res = sup.results[r1]
        assert res.stopped and res.stop_reason == "tuples"
        ans = sup.poll_result(r1)
        assert ans.status == "done" and ans.stopped
        assert np.array_equal(ans.ids, np.asarray(res.ids))
        assert sup.shed.get(r2) == "overload"
        with pytest.raises(KeyError, match="shed"):
            sup.poll_result(r2)

    def test_deadline_retire_reports_deadline_reason(self, served):
        ds, blocked = served
        sup = ServeSupervisor(blocked, max_queries=1, lookahead=8, seed=SEED)
        rid = sup.submit(ds.target, k=K, eps=0.02, delta=0.01,
                         deadline_s=0.0)
        sup.server.step()  # admit, then the deadline fires on the next tick
        sup.run_until_idle()
        res = sup.results[rid]
        assert res.stopped and res.stop_reason == "deadline"
        assert not res.exact
        assert sup.poll_result(rid).stop_reason == "deadline"


class TestAnytimeAnswerShape:
    def test_default_flags(self):
        ans = AnytimeAnswer(
            qid=0, qtype="topk", status="live", ids=np.zeros(0, np.int64),
            tau=np.zeros(0, np.float32), margin=np.zeros(0, np.float32),
            split=0.0, n_min=0.0, tau_min=0.0, eps_n=1.0, delta_upper=1.0,
            confidence=0.0, round=0, tuples=0, tuples_live=0, eps=0.1,
            delta=0.05, metric="l1",
        )
        assert not ans.exact and not ans.stopped and ans.result is None
        assert set(ans.curve_point()) == set(CURVE_COLUMNS)
