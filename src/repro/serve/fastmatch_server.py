"""FastMatch query server: N concurrent matching queries, one I/O stream.

`MatchServer` is the interactive frontend the paper positions FastMatch
as ("identify the top-k closest histograms" for a user-specified
target), generalized to a query population: a request queue feeding a
fixed pool of ``max_queries`` slots (padded for stable jit shapes) over
one `SharedCountsScheduler`. The server is metric-pluggable
(``metric="l1" | "chi2" | "hellinger"`` selects the registry distance
the shared tau pass computes) and serves TWO query types through the
same queue and counts matrix: top-k matching (`submit`) and tolerant
closeness testing (`submit_closeness`) — a closeness query admitted
mid-stream next to live top-k queries shares their samples and triggers
no recompilation. Mechanics:

  admission  — pending requests enter free slots at every round
               boundary, mid-stream; a newly admitted query starts from
               the already-accumulated shared counts (with the full
               shared ``n_i`` — sampling was target-independent), which
               is where the serving speedup over one-engine-per-query
               comes from
  serving    — one AnyActive marking per window against the UNION of
               per-query active sets, one shared ingest, one vmapped
               stats step for all live queries
  retirement — a query leaves its slot the moment its own
               ``delta_upper < delta`` bound fires and is returned as a
               per-query `MatchResult`; the freed slot is refilled from
               the queue
  cache      — the shared counts matrix and the global read_mask
               persist across the server's lifetime: once the sampled
               prefix covers a later query's needs it terminates
               without any new I/O, and after an exact completion every
               subsequent query is answered instantly and exactly

The loop underneath is the device-resident `multiquery.fused_round`:
block data arrives through a pluggable `repro.io.BlockSource` (pass a
`PrefetchSource` to overlap next-window gathering with the current
round), and with ``poll_every > 1`` the scheduler dispatches that many
windows between device polls — admission and retirement then lag the
device by at most ``poll_every - 1`` windows (bounded staleness; the
generalized paper-Sec 4.2 relaxation) in exchange for ~``poll_every``x
fewer device↔host round-trips (`scheduler.host_syncs`). With ``mesh``
given, the shared counts matrix is candidate-sharded over the mesh's
model axis, so one server spans a data-parallel mesh; add ``pump=True``
to replace the single gathered window stream with one `ShardedSource`
stream per data-parallel worker feeding the explicit-collective pump
round — ingest bandwidth then scales with worker count (see the
GSPMD-vs-pump dispatch table and per-round collective inventory in
`repro.core.pump`).

Per-query `MatchResult` counters (blocks/tuples/rounds) measure what
was read WHILE that query was live — the amortized per-query I/O the
`benchmarks/serve_throughput.py` benchmark compares against running
`run_engine` once per query.

Warm-start persistence (the restart analogue of the serving speedup):
with ``checkpoint_dir=`` the server snapshots the warm cache — the
shared counts matrix, per-candidate row sums, the without-replacement
``read_mask`` + read counters, and the pass/visit-order bookkeeping —
crash-atomically through `repro.checkpoint.CheckpointManager`, bound to
the dataset layout + `MultiQuerySpec` by a config hash so a stale cache
is rejected at restore rather than silently corrupting bounds. The
contract:

  persisted   — everything target-independent (`multiquery.CacheSnapshot`):
                counts, n, read_mask, blocks/tuples/rounds counters,
                passes, the cyclic visit-order offset
  re-queued   — live query slots and the pending queue are NOT
                persisted: in-flight queries must be resubmitted after a
                restart. Because sampling is target-independent this is
                lossless — a resubmitted query admits against the full
                restored counts with its full shared ``n_i``, exactly as
                a late query on an uninterrupted server would.
  consistency — autosave runs at poll boundaries (after retirements),
                never per window. Even with ``poll_every > 1`` a
                snapshot is internally consistent: counts and cursor are
                outputs of the SAME fused dispatch, so the saved
                read_mask always matches the saved counts — staleness
                with respect to still-live queries only shortens the
                warm prefix, it never invalidates it.

`MatchServer.restore(dataset, checkpoint_dir=...)` is warm
construction: build, load the newest complete snapshot (elastic across
mesh shapes via `core.distributed.cache_pspecs` when ``mesh=`` is
given), and serve — a restarted server answers a fresh query with
bit-identical counts/tau/result to an uninterrupted one
(tests/test_warm_restart.py; benchmarks/warm_restart.py measures the
tuples-per-query gap vs a cold restart).

Anytime serving (progressive results + SLA stopping)
----------------------------------------------------

Every live query has a valid Theorem-1-style statement at every poll
boundary, not just at retirement. `poll_result(rid)` returns the
current `AnytimeAnswer` — best set so far (closest first), per-
candidate margin, ``eps_n`` (the metric-space deviation guaranteed at
the per-candidate budget delta/|V_Z|), ``delta_upper`` and
``confidence`` — assembled host-side from the last poll's mirrors, so
polling never dispatches device work or perturbs the loop.
`iter_results(rid)` drives `step()` and yields each answer as it
tightens, ending with the ``status="done"`` final answer; the fully
converged stream ends bit-identically to the blocking result.

SLA-driven stopping: pass ``stop=StopPolicy(wall_ms=...,
confidence=..., tuples=...)`` to `submit`/`submit_closeness` (or
``default_stop=`` at construction for a server-wide default). A
stopped query retires with the honest anytime answer of its stopping
poll — ``exact=False``, ``stopped=True`` with the reason, the achieved
``delta_upper`` attached — bit-identical to what `poll_result` would
have said at that round. The statistical rule always wins a tie, and
supervisor deadline shedding (`ServeSupervisor`) composes as
``stop_reason="deadline"``.

Guarantees and failure modes
----------------------------

The complete guarantee contract — Theorem-1 (eps, delta), the
closeness promise band [eps, eps+gap], metric-native vs conservative
bounds (``bounds_mode``), early-reject pruning (``prune``), SLA
early-stop semantics, quarantine degradation (``eps_effective = eps +
2q``) and the four-tier fault taxonomy (transient I/O retries that
stay bit-identical, permanent-I/O quarantine, crash recovery via
`ServeSupervisor`, overload shedding) — lives in ``docs/guarantees.md``
with exactly which server knobs weaken which guarantee. The short
version: serving degrades honestly, it never blocks and never lies;
every weakened answer says so on the result (``exact`` / ``degraded``
/ ``stopped`` / ``eps_effective``).

`metrics` exposes the health surface: ``last_error`` (most recent
crash/shed cause, "" when healthy), ``queries_shed``,
``blocks_quarantined``, ``degraded`` and ``eps_inflation`` (the 2q
widening every in-flight guarantee currently carries).
"""

from __future__ import annotations

import dataclasses
import pathlib
import time
from collections import deque
from typing import Deque, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.engine import MatchResult
from repro.core.multiquery import (
    AnytimeAnswer,
    MultiQuerySpec,
    QueryOutcome,
    SharedCountsScheduler,
    StopPolicy,
    cache_config_hash,
)
from repro.io import as_block_source, maybe_chaos
from repro.obs import Telemetry

__all__ = [
    "AnytimeAnswer",
    "MatchQuery",
    "MatchServer",
    "StopPolicy",
    "answer_from_result",
]


def answer_from_result(res: MatchResult, *, metric: str) -> AnytimeAnswer:
    """Degrade a blocking `MatchResult` to a ``status="done"`` anytime
    answer.

    Used when only the retired result survives — e.g. polling a query
    resolved before a supervisor crash rebuild. The per-round fields the
    retirement poll would have carried (split, eps_n, the query's
    eps/delta) are not recoverable from the result alone and come back
    NaN; the set, tau, margin and delta_upper are exact.
    """
    ids = np.asarray(res.ids)
    tau_full = np.asarray(res.state.tau)
    du = float(res.delta_upper)
    return AnytimeAnswer(
        qid=-1, qtype=res.qtype, status="done", ids=ids,
        tau=tau_full[ids], margin=np.asarray(res.state.eps_i)[ids],
        split=float("nan"), n_min=float(np.asarray(res.state.n).min()),
        tau_min=float(tau_full.min()), eps_n=float("nan"),
        delta_upper=du, confidence=max(0.0, 1.0 - du),
        round=res.rounds, tuples=res.tuples_read,
        tuples_live=res.tuples_read, eps=float("nan"),
        delta=float("nan"), metric=metric,
        exact=res.exact, stopped=res.stopped,
        stop_reason=res.stop_reason, result=res,
    )


@dataclasses.dataclass
class MatchQuery:
    """One queued request: a top-k match (Problem 1 instance) or a
    tolerant closeness test (qtype="closeness", k unused, gap > 0)."""

    rid: int
    target: np.ndarray  # (V_X,) unnormalized or normalized target histogram
    k: int
    eps: float
    delta: float
    submit_time: float
    qtype: str = "topk"  # "topk" | "closeness"
    gap: float = 0.0  # closeness promise gap
    stop: Optional[StopPolicy] = None  # SLA policy; None = server default


class MatchServer:
    """Serve top-k histogram-matching queries over one shared sample stream."""

    def __init__(
        self,
        dataset,
        *,
        max_queries: int = 8,
        criterion: str = "histsim",
        policy: str = "anyactive",
        lookahead: int = 512,
        seed: int = 0,
        start_block: Optional[int] = None,
        max_passes: int = 64,
        poll_every: int = 1,
        mesh=None,
        model_axis: str = "model",
        pump: bool = False,
        data_axes=("data",),
        prefetch: bool = False,
        k_cap: Optional[int] = None,
        checkpoint_dir: Optional[str] = None,
        autosave_every: int = 8,
        autosave_rounds: Optional[int] = None,
        checkpoint_keep_last: int = 3,
        telemetry=None,
        kernel_plans=None,
        metric: str = "l1",
        bounds_mode: str = "native",
        prune: bool = False,
        default_stop: Optional[StopPolicy] = None,
    ):
        # k_cap: static bound on any query's k — lets the per-slot
        # deviation assignment use a (k_cap+1)-element top_k instead of
        # V_Z order stats; submissions with k > k_cap are rejected.
        #
        # pump: with mesh given, serve through the data-parallel
        # `repro.core.pump.DistributedPump` — one ShardedSource window
        # stream per worker along ``data_axes`` feeding the explicit
        # shard_map round — instead of the GSPMD fused round over one
        # global stream (see the dispatch table in core/pump.py).
        # Requires the raw BlockedDataset. ``prefetch`` overlaps the
        # next-window gather with the current round — per worker in
        # pump mode, on the single stream otherwise.
        #
        # checkpoint_dir: enable warm-start persistence (see module
        # docstring). autosave_every: snapshot after this many query
        # retirements (0 disables retirement-cadence autosave);
        # autosave_rounds: additionally snapshot whenever this many new
        # device rounds have run since the last save. Both fire at poll
        # boundaries, off the per-window hot path; `save_cache()` forces
        # a snapshot at any time.
        #
        # telemetry: True builds a fresh `repro.obs.Telemetry`; an
        # existing instance is adopted as-is (one instance per server —
        # query ids key its curve store). The handle is threaded into
        # the scheduler/pump, every PrefetchSource, and the
        # CheckpointManager; None (default) leaves every layer on its
        # untouched zero-overhead path.
        #
        # kernel_plans: an `autotune.PlanPair` pinning the tuned kernel
        # variants for every round this server dispatches; None (the
        # default) resolves from the committed per-backend plan file at
        # scheduler construction. `server.kernel_plans` exposes what
        # was resolved.
        #
        # metric: the registry distance every query on this server is
        # stated in ("l1" | "chi2" | "hellinger") — static per server,
        # like the kernel plan; see docs/guarantees.md for what to
        # expect from non-l1 bounds.
        #
        # bounds_mode: "native" (default) routes failure bounds through
        # the observation-aware per-metric budgets (never looser than
        # the uniform ones; l1 is bit-identical either way);
        # "conservative" keeps the PR-9 uniform budgets. prune: enable
        # early-reject pruning of clearly-far candidates from the I/O
        # marking (static flag — flipping it recompiles). default_stop:
        # server-wide SLA StopPolicy for queries submitted without one.
        if telemetry is True:
            telemetry = Telemetry()
        elif telemetry is False:
            telemetry = None
        self.telemetry = telemetry
        if telemetry is not None:
            self._c_submitted = telemetry.registry.counter(
                "fastmatch_queries_submitted_total",
                "requests accepted into the queue",
            )
        if pump:
            if mesh is None:
                raise ValueError("pump=True is the data-parallel mesh path; pass mesh=")
            from repro.core.pump import DistributedPump

            self.spec = MultiQuerySpec(
                v_z=dataset.v_z,
                v_x=dataset.v_x,
                max_queries=max_queries,
                criterion=criterion,
                k_cap=k_cap,
                metric=metric,
                bounds_mode=bounds_mode,
                prune=prune,
                default_stop=default_stop,
            )
            self.scheduler = DistributedPump(
                dataset,
                self.spec,
                mesh=mesh,
                data_axes=data_axes,
                model_axis=model_axis,
                policy=policy,
                window=lookahead,
                seed=seed,
                start_block=start_block,
                poll_every=poll_every,
                prefetch=prefetch,
                telemetry=telemetry,
                plans=kernel_plans,
            )
        else:
            if tuple(data_axes) != ("data",):
                raise ValueError(
                    "data_axes only shapes the data-parallel pump; pass pump=True"
                )
            source = maybe_chaos(as_block_source(dataset))
            if prefetch:
                # Same semantics as pump mode: overlap the next window's
                # gather with the current round (worthwhile when the
                # source is host-resident or remote).
                from repro.io import PrefetchSource

                source = PrefetchSource(source, telemetry=telemetry)
            self.spec = MultiQuerySpec(
                v_z=source.v_z,
                v_x=source.v_x,
                max_queries=max_queries,
                criterion=criterion,
                k_cap=k_cap,
                metric=metric,
                bounds_mode=bounds_mode,
                prune=prune,
                default_stop=default_stop,
            )
            self.scheduler = SharedCountsScheduler(
                source,
                self.spec,
                policy=policy,
                window=lookahead,
                seed=seed,
                start_block=start_block,
                poll_every=poll_every,
                mesh=mesh,
                model_axis=model_axis,
                telemetry=telemetry,
                plans=kernel_plans,
            )
        self.max_passes = max_passes
        self._mesh = mesh
        self._model_axis = model_axis
        self._manager: Optional[CheckpointManager] = None
        if checkpoint_dir is not None:
            self._manager = CheckpointManager(
                checkpoint_dir,
                keep_last=checkpoint_keep_last,
                config_hash=cache_config_hash(self.scheduler.source, self.spec),
                telemetry=telemetry,
            )
        self.autosave_every = autosave_every
        self.autosave_rounds = autosave_rounds
        self._retired_since_save = 0
        self._rounds_at_save = 0
        self.pending: Deque[MatchQuery] = deque()
        self.results: Dict[int, MatchResult] = {}
        # Health surface (scraped via `metrics`; the supervisor writes
        # these on crash recovery / load shedding).
        self.last_error = ""
        self.queries_shed = 0
        self._rid_of_qid: Dict[int, int] = {}
        self._qid_of_rid: Dict[int, int] = {}  # live queries only
        # Retirement-time anytime statements, kept so poll_result on a
        # done query replays the exact final answer.
        self._anytime: Dict[int, AnytimeAnswer] = {}
        self._submit_time: Dict[int, float] = {}
        self._next_rid = 0
        # step()'s pass cursor (None = start a fresh pass next step)
        self._pass_order: Optional[np.ndarray] = None
        self._pass_pos = 0
        self._pass_read = 0
        self._pass_start_rounds = 0

    @property
    def kernel_plans(self):
        """The `autotune.PlanPair` this server's scheduler-level rounds
        run (the pump's shard rounds key on the per-worker shard shapes
        — see `core.pump.DistributedPump`)."""
        return self.scheduler.plans

    # -- request queue -----------------------------------------------------

    def submit(
        self,
        target: np.ndarray,
        *,
        k: int,
        eps: float = 0.06,
        delta: float = 0.01,
        stop: Optional[StopPolicy] = None,
    ) -> int:
        """Queue a top-k query; returns a request id resolved in `results`.

        Validates here, at the caller's call site — a malformed request
        must not sit in the queue and blow up mid-drain. ``stop``
        attaches an SLA `StopPolicy` (None inherits the server's
        ``default_stop``).
        """
        target = np.asarray(target, np.float64).ravel()
        if target.shape != (self.spec.v_x,):
            raise ValueError(f"target must have shape ({self.spec.v_x},), got {target.shape}")
        if not (0 < k <= self.spec.v_z):
            raise ValueError(f"need 0 < k <= V_Z={self.spec.v_z}, got k={k}")
        if self.spec.k_cap is not None and k > self.spec.k_cap:
            raise ValueError(f"k={k} exceeds the server's k_cap={self.spec.k_cap}")
        return self._enqueue(target, k=k, eps=eps, delta=delta, stop=stop)

    def submit_closeness(
        self,
        target: np.ndarray,
        *,
        eps: float,
        gap: float,
        delta: float = 0.01,
        stop: Optional[StopPolicy] = None,
    ) -> int:
        """Queue a tolerant closeness test; returns a request id.

        The result's ``ids`` are ALL candidates labeled close (within
        ``eps`` of the target in the server's metric), nearest first —
        w.p. >= 1 - delta no candidate beyond ``eps + gap`` is among
        them and none within ``eps`` is missing; labels inside the gap
        are unconstrained (the promise region). Shares slots, samples,
        and the counts matrix with top-k queries.
        """
        target = np.asarray(target, np.float64).ravel()
        if target.shape != (self.spec.v_x,):
            raise ValueError(f"target must have shape ({self.spec.v_x},), got {target.shape}")
        if not gap > 0.0:
            raise ValueError(f"closeness needs gap > 0, got gap={gap}")
        if not eps >= 0.0:
            raise ValueError(f"closeness needs eps >= 0, got eps={eps}")
        return self._enqueue(
            target, k=1, eps=eps, delta=delta, qtype="closeness", gap=gap,
            stop=stop,
        )

    def _enqueue(
        self, target, *, k, eps, delta, qtype: str = "topk", gap: float = 0.0,
        stop: Optional[StopPolicy] = None,
    ) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.pending.append(
            MatchQuery(
                rid=rid,
                target=target,
                k=k,
                eps=eps,
                delta=delta,
                submit_time=time.perf_counter(),
                qtype=qtype,
                gap=gap,
                stop=stop,
            )
        )
        if self.telemetry is not None:
            self._c_submitted.inc(1)
            self.telemetry.tracer.emit(
                "query_enqueue", rid=rid, k=k, eps=eps, delta=delta,
                qtype=qtype, gap=gap, queued=len(self.pending),
            )
        return rid

    def _admit_free(self, _sched: Optional[SharedCountsScheduler] = None) -> None:
        """Fill free slots from the queue (the scheduler's on_round hook)."""
        while self.pending and self.scheduler.free_slots:
            q = self.pending.popleft()
            qid = self.scheduler.admit(
                q.target, k=q.k, eps=q.eps, delta=q.delta,
                qtype=q.qtype, gap=q.gap, stop=q.stop,
            )
            self._rid_of_qid[qid] = q.rid
            self._qid_of_rid[q.rid] = qid
            self._submit_time[q.rid] = q.submit_time
        self._collect()

    def _collect(self) -> None:
        """Convert freshly retired scheduler outcomes into MatchResults."""
        for qid, out in list(self.scheduler.outcomes.items()):
            rid = self._rid_of_qid.pop(qid, None)
            if rid is None:
                continue  # already collected
            self._qid_of_rid.pop(rid, None)
            del self.scheduler.outcomes[qid]
            res = self.results[rid] = self._to_result(rid, out)
            if out.anytime is not None:
                out.anytime.result = res
                self._anytime[rid] = out.anytime
            self._retired_since_save += 1
            if self.telemetry is not None:
                # The rid↔qid join point: query_enqueue events carry the
                # request id, the scheduler's admit/retire events the
                # slot-assigned qid — this event links the two.
                self.telemetry.tracer.emit(
                    "query_done", rid=rid, qid=qid, exact=res.exact,
                    tuples=res.tuples_read, wall_s=res.wall_time_s,
                )
        self._maybe_autosave()

    def _to_result(self, rid: int, out: QueryOutcome) -> MatchResult:
        wall = time.perf_counter() - self._submit_time.pop(rid)
        return MatchResult(
            ids=out.ids,
            state=out.state,
            rounds=out.rounds,
            blocks_read=out.blocks_read,
            blocks_considered=out.blocks_considered,
            tuples_read=out.tuples_read,
            wall_time_s=wall,
            exact=out.exact,
            passes=out.passes,
            degraded=out.degraded,
            eps_effective=out.eps_effective,
            qtype=out.qtype,
            stopped=out.stopped,
            stop_reason=out.stop_reason,
        )

    # -- warm-start persistence --------------------------------------------

    def _maybe_autosave(self) -> None:
        """Autosave cadence check — runs at poll/retirement boundaries
        (from `_collect`), never inside the window loop."""
        if self._manager is None:
            return
        if self.autosave_every and self._retired_since_save >= self.autosave_every:
            self.save_cache()
            return
        if self.autosave_rounds:
            # Host mirror of the device round counter: fresh as of the
            # last poll, which is exactly the cadence autosave rides.
            if self.scheduler.rounds - self._rounds_at_save >= self.autosave_rounds:
                self.save_cache()

    def save_cache(self) -> pathlib.Path:
        """Crash-atomically persist the warm cache; returns the step dir.

        The checkpoint step is the device round counter, so snapshot
        steps are monotone across restarts (the restored cursor resumes
        the count) and a newer snapshot always supersedes an older one.
        A save with no new rounds since the last snapshot bumps past the
        newest existing step instead of re-writing it: overwriting the
        step that LATEST points at would reopen the crash window the
        atomic-rename protocol exists to close.
        """
        if self._manager is None:
            raise RuntimeError("MatchServer was constructed without checkpoint_dir")
        snap = self.scheduler.export_cache()
        step = int(jax.device_get(snap.rounds))
        newest = self._manager.latest_step()
        if newest is not None and step <= newest:
            step = newest + 1
        path = self._manager.save(snap, step)
        self._retired_since_save = 0
        self._rounds_at_save = step
        return path

    def restore_cache(self, step: Optional[int] = None) -> None:
        """Adopt the newest complete snapshot (or ``step``) from
        ``checkpoint_dir``. Stale snapshots — different dataset layout
        or `MultiQuerySpec` — are rejected with ValueError via the
        config hash; a missing checkpoint raises FileNotFoundError.
        With ``mesh=`` the candidate-sharded leaves are re-placed onto
        THIS server's mesh shape, whatever shape wrote the snapshot
        (elastic restart)."""
        if self._manager is None:
            raise RuntimeError("MatchServer was constructed without checkpoint_dir")
        like = self.scheduler.export_cache()  # fresh-state shapes/dtypes
        if self._mesh is not None:
            from repro.core.distributed import cache_pspecs

            snap = self._manager.restore_resharded(
                like, self._mesh, cache_pspecs(model_axis=self._model_axis), step=step
            )
        else:
            snap = self._manager.restore(like, step=step)
        self.scheduler.import_cache(snap)
        self._retired_since_save = 0
        self._rounds_at_save = self.scheduler.rounds
        self._pass_order = None  # step()'s cursor must rebuild from the restored mask

    @classmethod
    def restore(
        cls, dataset, *, checkpoint_dir: str, step: Optional[int] = None, **kwargs
    ) -> "MatchServer":
        """Warm construction: build a server over ``dataset`` and adopt
        the newest complete snapshot in ``checkpoint_dir``. Serving
        parameters (lookahead, poll_every, ...) come from ``kwargs``
        exactly as in `__init__`; the snapshot only has to match the
        dataset layout and the spec-shaping arguments
        (max_queries/criterion/k_cap), which the config hash enforces."""
        server = cls(dataset, checkpoint_dir=checkpoint_dir, **kwargs)
        server.restore_cache(step=step)
        return server

    # -- serving loop ------------------------------------------------------

    def step(self) -> None:
        """Admit + one window + retire: the unit of incremental serving.

        Keeps the same cyclic pass structure as `pump`: a pass visits
        every currently-unread block window by window; when a whole
        pass reads nothing for the remaining live queries (or no
        unread block is left), they are completed exactly instead of
        re-marking the same window forever.
        """
        self._admit_free()
        sched = self.scheduler
        if not sched.tickets:
            return
        if self._pass_order is None or self._pass_pos >= len(self._pass_order):
            eligible = ~sched.read_mask[sched.order] & ~sched.quarantined[sched.order]
            unread = sched.order[eligible]
            # A zero-read pass only proves sampling is exhausted for the
            # queries that were live during it — a query admitted in its
            # final windows gets a fresh pass before the exact fallback.
            fresh = any(
                t.admit_rounds >= self._pass_start_rounds
                for t in sched.tickets.values()
            )
            stalled = self._pass_order is not None and self._pass_read == 0 and not fresh
            if unread.size == 0 or stalled:
                # Counts complete (or sampling can no longer help) —
                # finish exactly; every live answer becomes exact.
                sched.complete_remaining()
                du = sched._delta_upper  # fresh: complete_remaining polls
                for slot in list(sched.tickets):
                    fired = bool(du[slot] < sched.tickets[slot].delta)
                    sched.retire(slot, exact=True, terminated=fired)
                self._pass_order = None
                self._collect()
                return
            self._pass_order = unread
            self._pass_pos = 0
            self._pass_read = 0
            self._pass_start_rounds = sched.rounds
            sched.passes += 1
        win = self._pass_order[self._pass_pos : self._pass_pos + sched.window]
        self._pass_pos += len(win)
        # Guard against blocks read (or quarantined) since this pass was
        # snapshotted (e.g. a run_until_idle interleaved between steps).
        win = win[~sched.read_mask[win] & ~sched.quarantined[win]]
        if win.size:
            self._pass_read += sched.run_window(win)
            sched._poll_terminated()
        self._collect()

    def run_until_idle(self, *, max_rounds: int = 1_000_000) -> Dict[int, MatchResult]:
        """Drain the queue: serve until every submitted query has a result."""
        self._pass_order = None  # invalidate step()'s cursor
        while self.pending or self.scheduler.tickets:
            self._admit_free()
            if not self.scheduler.tickets:
                break  # nothing admissible (no pending either, per loop cond)
            self.scheduler.pump(
                max_rounds=max_rounds,
                max_passes=self.max_passes,
                on_round=self._admit_free,
            )
            if self.scheduler.budget_exhausted:
                # A query admitted in the budget's final round may already
                # satisfy its bound from the warm cache — poll before
                # stamping anything best-effort.
                self.scheduler._poll_terminated()
                for slot in list(self.scheduler.tickets):
                    self.scheduler.retire(slot, exact=False, terminated=False)
            self._collect()
        return dict(self.results)

    # -- anytime API -------------------------------------------------------

    def poll_result(self, rid: int) -> AnytimeAnswer:
        """The current progressive answer for ``rid`` — valid at any
        poll boundary, host-only (never dispatches device work).

        status="live": the best set so far with its Theorem-1-style
        statement, assembled by `SharedCountsScheduler.peek` from the
        last poll's mirrors. status="queued": a vacuous statement
        (delta_upper=1, empty set) — the query is waiting for a slot.
        status="done": the exact final statement of the retirement
        poll, with ``.result`` holding the blocking `MatchResult`.
        Unknown (or shed) request ids raise KeyError.
        """
        self._collect()  # fold already-retired outcomes; host-only
        if rid in self._anytime:
            return self._anytime[rid]
        if rid in self.results:
            # Retired through a path that predates anytime bookkeeping
            # (e.g. results dict populated by a restore) — degrade to a
            # minimal done statement rather than failing the poll.
            return answer_from_result(self.results[rid], metric=self.spec.metric)
        qid = self._qid_of_rid.get(rid)
        if qid is not None:
            sched = self.scheduler
            for slot, t in sched.tickets.items():
                if t.qid == qid:
                    return sched.peek(slot)
        for q in self.pending:
            if q.rid == rid:
                return AnytimeAnswer(
                    qid=-1, qtype=q.qtype, status="queued",
                    ids=np.zeros(0, np.int64), tau=np.zeros(0, np.float32),
                    margin=np.zeros(0, np.float32), split=float("nan"),
                    n_min=0.0, tau_min=float("nan"), eps_n=float("inf"),
                    delta_upper=1.0, confidence=0.0,
                    round=self.scheduler.rounds,
                    tuples=self.scheduler.tuples_read, tuples_live=0,
                    eps=q.eps, delta=q.delta, metric=self.spec.metric,
                )
        raise KeyError(f"unknown request id {rid}")

    def iter_results(self, rid: int, *, max_steps: int = 100_000):
        """Stream progressively refining answers for ``rid``.

        Drives the incremental serving unit `step()` between polls (so
        OTHER queued/live queries advance too) and yields an
        `AnytimeAnswer` each time the statement changes — tighter
        delta_upper, a new round, or a different best set — ending with
        the ``status="done"`` final answer, which for a fault-free
        converged query is bit-identical to the blocking result.
        ``max_steps`` bounds the drive (the generator just stops
        yielding if it is exhausted; the query keeps its slot).
        """
        last = None
        for _ in range(max_steps):
            ans = self.poll_result(rid)
            key = (ans.status, ans.round, ans.delta_upper, ans.ids.tobytes())
            if key != last:
                last = key
                yield ans
            if ans.status == "done":
                return
            self.step()
        ans = self.poll_result(rid)
        if ans.status == "done":
            yield ans

    # -- observability -----------------------------------------------------

    @property
    def metrics(self) -> Dict[str, object]:
        sched = self.scheduler
        done = len(self.results)
        return {
            "queries_done": done,
            # queued (waiting for a slot) vs live (admitted, burning I/O)
            # are different saturation signals: a deep queue with full
            # slots means add capacity; empty queue with live queries is
            # just work in flight. queries_pending stays as their sum
            # for dashboard compatibility.
            "queries_queued": len(self.pending),
            "queries_live": sched.num_live,
            "queries_pending": len(self.pending) + sched.num_live,
            "total_blocks_read": sched.blocks_read,
            "total_tuples_read": sched.tuples_read,
            "total_rounds": sched.rounds,
            "fraction_read": float(sched.read_mask.mean()) if sched.read_mask.size else 0.0,
            # 0.0, not nan, before the first completion: nan poisons any
            # dashboard aggregation and JSON round-trips it as a string.
            "tuples_per_query": float(sched.tuples_read / done) if done else 0.0,
            # Health surface (failure-modes contract, module docstring):
            # "" / 0 / False across the board on a healthy server.
            "last_error": self.last_error,
            "queries_shed": self.queries_shed,
            "blocks_quarantined": sched.blocks_quarantined,
            "degraded": sched.blocks_quarantined > 0,
            "eps_inflation": float(sched.eps_inflation),
        }

    def export_trace(self, path) -> int:
        """Dump the lifecycle/round trace as JSONL; returns event count."""
        if self.telemetry is None:
            raise RuntimeError("MatchServer was constructed without telemetry")
        self.scheduler.flush_telemetry()
        return self.telemetry.tracer.export_jsonl(path)

    def prometheus_metrics(self) -> str:
        """The registry in Prometheus text exposition format."""
        if self.telemetry is None:
            raise RuntimeError("MatchServer was constructed without telemetry")
        self.scheduler.flush_telemetry()
        return self.telemetry.registry.to_prometheus()
