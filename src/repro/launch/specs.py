"""input_specs(): ShapeDtypeStruct stand-ins for every dry-run case.

Weak-type-correct, shardable, zero allocation — the compile-only analogue
of the real training/serving inputs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

from repro.configs.base import SHAPES, ModelConfig, Shape, get_config
from repro.distributed import sharding as shr
from repro.models.model_zoo import get_model
from repro.optimizer import get_optimizer
from repro.train.step import make_train_step
from repro.train.train_state import TrainState

__all__ = ["DryRunCase", "build_case", "input_specs"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: Shape) -> Dict[str, Any]:
    """Model inputs for one (arch x shape) cell, as ShapeDtypeStructs."""
    b = shape.global_batch
    if shape.kind == "train":
        d = {"tokens": _sds((b, shape.seq_len), jnp.int32)}
        model = get_model(cfg)
        d.update(model.extra_input_shapes(b, shape.seq_len))
        return d
    if shape.kind == "prefill":
        d = {"tokens": _sds((b, shape.seq_len), jnp.int32)}
        model = get_model(cfg)
        extras = model.extra_input_shapes(b, shape.seq_len)
        if "encoder_frames" in extras:
            d["encoder_frames"] = extras["encoder_frames"]
        return d
    # decode: one new token against a seq_len-deep cache
    return {"token": _sds((b,), jnp.int32)}


@dataclasses.dataclass
class DryRunCase:
    name: str
    fn: Callable
    args: tuple
    in_shardings: Any
    out_shardings: Any
    donate_argnums: tuple = ()
    model_flops: float = 0.0  # 6*N*D (dense) / 6*N_active*D (MoE) per step


def _flatten_pspec_index(tree):
    """dict: path-name-tuple -> PartitionSpec."""
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        names = tuple(
            str(k.key) if isinstance(k, DictKey) else f"[{k.idx}]"
            for k in path
            if isinstance(k, (DictKey, SequenceKey))
        )
        out[names] = leaf
    return out


def opt_state_pspecs(opt_shapes, params_pspecs):
    """Shard optimizer state congruent with its parameters.

    AdamW: state['mu'|'nu'][<param path>] -> param spec.
    Adafactor: state[<param path>]['row'|'col'|'nu'] -> derived spec.
    """
    index = _flatten_pspec_index(params_pspecs)

    def per_leaf(path, leaf):
        names = tuple(
            str(k.key) if isinstance(k, DictKey) else f"[{k.idx}]"
            for k in path
            if isinstance(k, (DictKey, SequenceKey))
        )
        shape = leaf.shape
        # AdamW layout: ('mu'|'nu', *param_path)
        if names and names[0] in ("mu", "nu") and names[1:] in index:
            spec = index[names[1:]]
            return shr.guard_pspec(shape, spec, _MESH[0])
        # Adafactor layout: (*param_path, 'row'|'col'|'nu')
        if names and names[-1] in ("row", "col", "nu") and names[:-1] in index:
            spec = index[names[:-1]]
            entries = list(spec)
            if names[-1] == "row":
                entries = entries[:-1]
            elif names[-1] == "col":
                entries = entries[:-2] + entries[-1:]
            return shr.guard_pspec(shape, P(*entries), _MESH[0])
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(per_leaf, opt_shapes)


_MESH = [None]  # set by build_case; avoids threading mesh through tree_map


def build_case(
    arch: str,
    shape_name: str,
    mesh: Mesh,
    *,
    lr: float = 3e-4,
    cfg: ModelConfig = None,
    profile: str = "baseline",
) -> DryRunCase:
    """Construct (fn, specs, shardings) for one dry-run cell.

    profile: "baseline" = one layout for everything (FSDP x TP);
             "opt"      = §Perf optimizations (TP-only weights at serving).
    """
    from repro.models import layers as Lyr

    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    tp = mesh.shape.get("model", 1)
    if profile == "opt":
        # local MoE dispatch at train/decode; prefill keeps the gather
        # path (the dropless per-shard capacity of serving would blow the
        # dispatch buffers to T_local x topk at 32k prompts — measured
        # 2.5x regression; a sort-based dropless dispatch is future work)
        if cfg.num_experts > 0 and shape.kind != "prefill":
            cfg = dataclasses.replace(cfg, moe_impl="local")
        # flash-decoding only where head-sharding is impossible: MHA archs
        # (codeqwen, whisper) shard kv heads over TP just fine, and the
        # seq-sharded layout is strictly worse there (measured 0.78x)
        if shape.kind == "decode" and cfg.num_kv_heads % tp != 0:
            cfg = dataclasses.replace(cfg, decode_seq_shard=True)
        # grouped-GQA only at decode: there q is explicitly replicated so
        # the grouped einsum removes the KV gather; at train/prefill q
        # inherits the TP head-sharding and the (hkv, group) reshape makes
        # SPMD gather q/k/v instead (measured neutral on train, 2.5x WORSE
        # on mixtral prefill) — ring/sequence-parallel attention is the
        # right prefill fix, left as future work.
        if shape.kind == "decode":
            cfg = dataclasses.replace(cfg, attn_gqa_grouped=True)
        Lyr.set_tp_reduce_dtype(jnp.bfloat16)  # bf16 TP partial reductions
    else:
        Lyr.set_tp_reduce_dtype(None)
    model = get_model(cfg)
    _MESH[0] = mesh

    rng = jax.random.PRNGKey(0)
    params_shapes = jax.eval_shape(model.init, rng)
    # TP-only weights at DECODE only: at prefill the FSDP layout is fine
    # (weight gathers amortize over 32k tokens of compute) and the MoE
    # gather dispatch interacts badly with replicated-over-data experts
    # (measured 2.5x collective regression on mixtral prefill).
    if profile == "opt" and shape.kind == "decode":
        p_pspecs = shr.serving_param_pspecs(params_shapes, mesh)
    else:
        p_pspecs = shr.param_pspecs(params_shapes, mesh)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_pspecs)
    ins = input_specs(cfg, shape)
    bspec = shr.batch_pspec(mesh, shape.global_batch)
    token_shard = {
        k: NamedSharding(mesh, shr.guard_pspec(v.shape, P(bspec[0], *([None] * (len(v.shape) - 1))), mesh))
        for k, v in ins.items()
    }
    n_active = float(cfg.active_param_count)

    if shape.kind == "train":
        optimizer = get_optimizer(cfg.optimizer, lr)
        opt_shapes = jax.eval_shape(optimizer.init, params_shapes)
        o_pspecs = opt_state_pspecs(opt_shapes, p_pspecs)
        o_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), o_pspecs)
        state = TrainState(
            params=params_shapes, opt_state=opt_shapes, step=_sds((), jnp.int32)
        )
        state_shard = TrainState(
            params=p_shard, opt_state=o_shard, step=NamedSharding(mesh, P())
        )
        train_step = make_train_step(model, optimizer)
        metrics_shapes = jax.eval_shape(train_step, state, ins)[1]
        metrics_shard = jax.tree.map(lambda _: NamedSharding(mesh, P()), metrics_shapes)
        return DryRunCase(
            name=f"{arch}.{shape_name}",
            fn=train_step,
            args=(state, ins),
            in_shardings=(state_shard, token_shard),
            out_shardings=(state_shard, metrics_shard),
            donate_argnums=(0,),
            model_flops=6.0 * n_active * shape.global_batch * shape.seq_len,
        )

    if shape.kind == "prefill":
        def prefill_fn(params, batch):
            tokens = batch["tokens"]
            extras = {k: v for k, v in batch.items() if k != "tokens"}
            return model.prefill(params, tokens, shape.seq_len, **extras)

        out_shapes = jax.eval_shape(prefill_fn, params_shapes, ins)
        logits_shard = NamedSharding(
            mesh, shr.guard_pspec(out_shapes[0].shape, P(bspec[0], None, "model"), mesh)
        )
        cache_shard = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            shr.cache_pspecs(out_shapes[1], mesh, shape.global_batch),
        )
        return DryRunCase(
            name=f"{arch}.{shape_name}",
            fn=prefill_fn,
            args=(params_shapes, ins),
            in_shardings=(p_shard, token_shard),
            out_shardings=(logits_shard, cache_shard),
            model_flops=2.0 * n_active * shape.global_batch * shape.seq_len,  # fwd only
        )

    # decode
    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len)
    )
    cache_shapes = jax.tree.map(
        lambda x: _sds(x.shape, x.dtype), cache_shapes
    )
    cache_pspec = shr.cache_pspecs(
        cache_shapes, mesh, shape.global_batch, seq_shard=cfg.decode_seq_shard
    )
    cache_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), cache_pspec)

    def serve_step(params, cache, token):
        return model.decode_step(params, cache, token)

    out_shapes = jax.eval_shape(serve_step, params_shapes, cache_shapes, ins["token"])
    logits_shard = NamedSharding(
        mesh, shr.guard_pspec(out_shapes[0].shape, P(bspec[0], "model"), mesh)
    )
    return DryRunCase(
        name=f"{arch}.{shape_name}",
        fn=serve_step,
        args=(params_shapes, cache_shapes, ins["token"]),
        in_shardings=(p_shard, cache_shard, token_shard["token"]),
        out_shardings=(logits_shard, cache_shard),
        donate_argnums=(1,),
        model_flops=2.0 * n_active * shape.global_batch,  # 2N per new token
    )
