from repro.distributed.sharding import (
    param_pspecs,
    param_shardings,
    batch_pspec,
    guard_pspec,
    data_axes,
    cache_pspecs,
)

__all__ = [
    "param_pspecs",
    "param_shardings",
    "batch_pspec",
    "guard_pspec",
    "data_axes",
    "cache_pspecs",
]
