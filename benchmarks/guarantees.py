"""Sec 5.4 'Satisfaction of Guarantees': violation counting.

Paper claim: guarantees held across ALL runs for all queries (delta is a
loose upper bound on the true failure probability).
"""

from __future__ import annotations

from benchmarks.common import QUERY_EPS, guarantees_hold, run_variant

RUNS = 10


def run(csv_rows: list) -> None:
    for q in ("flights_q1", "flights_q2", "flights_q4", "police_q1"):
        violations = 0
        for s in range(RUNS):
            res, _, ds = run_variant(q, "fastmatch", seed=200 + s, warm=(s == 0))
            if not guarantees_hold(res, ds, eps=QUERY_EPS[q]):
                violations += 1
        csv_rows.append(
            dict(
                name=f"guarantees.{q}",
                us_per_call=0.0,
                derived=f"violations={violations}/{RUNS} (delta=0.01 bound)",
            )
        )
