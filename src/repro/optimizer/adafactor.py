"""Adafactor (Shazeer & Stern 2018) with factored second moments.

Used for the 405B/314B/76B configs: the factored statistics need
O(rows + cols) memory instead of O(rows * cols), which is what lets the
optimizer state of a 405B model fit 16 GiB/chip at 256 chips
(see DESIGN.md Sec 7). Relative step sizes and update clipping per the
paper; momentum off (memory).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optimizer.base import Optimizer

__all__ = ["adafactor"]


def adafactor(
    lr,
    *,
    decay: float = 0.8,  # beta2 exponent: 1 - step^-decay
    eps1: float = 1e-30,
    eps2: float = 1e-3,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))

    def _factored(p) -> bool:
        return p.ndim >= 2

    def init(params):
        def per_param(p):
            if _factored(p):
                return {
                    "row": jnp.zeros(p.shape[:-1], jnp.float32),
                    "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"nu": jnp.zeros(p.shape, jnp.float32)}

        return jax.tree.map(per_param, params)

    def update(grads, state, params, step):
        stepf = step.astype(jnp.float32) + 1.0
        beta2 = 1.0 - stepf ** (-decay)
        lr_t = lr_fn(step)

        def upd(g, st, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps1
            if _factored(p):
                row = beta2 * st["row"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                col = beta2 * st["col"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                row_mean = jnp.mean(row, axis=-1, keepdims=True)
                r = row / jnp.maximum(row_mean, eps1)
                v = r[..., None] * col[..., None, :]
                new_st = {"row": row, "col": col}
            else:
                v = beta2 * st["nu"] + (1 - beta2) * g2
                new_st = {"nu": v}
            u = g * jax.lax.rsqrt(jnp.maximum(v, eps1))
            # update clipping by RMS
            rms_u = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            # relative step scale
            scale = jnp.maximum(
                jnp.sqrt(jnp.mean(jnp.square(p.astype(jnp.float32)))), eps2
            )
            du = -lr_t * scale * u
            if weight_decay and p.ndim >= 2:
                du = du - lr_t * weight_decay * p.astype(jnp.float32)
            return du.astype(p.dtype), new_st

        out = jax.tree.map(upd, grads, state, params, is_leaf=lambda x: isinstance(x, dict) and ("row" in x or "nu" in x))
        # out is a tree of (update, state) tuples at param positions
        updates = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_state = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return updates, new_state

    return Optimizer(init=init, update=update)
