"""llama3-405b [dense] — GQA, 128k vocab [arXiv:2407.21783; unverified].

Adafactor optimizer so optimizer state fits 16 GiB/chip HBM at 256 chips
(AdamW fp32 moments for 405B would need ~4.9 TiB; see DESIGN.md Sec 7).
"""

from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch_id="llama3_405b",
        family="dense",
        num_layers=126,
        d_model=16384,
        num_heads=128,
        num_kv_heads=8,
        d_ff=53248,
        vocab_size=128256,
        rope_theta=5e5,
        norm_eps=1e-5,
        optimizer="adafactor",
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="llama3_405b_smoke",
        family="dense",
        num_layers=3,
        d_model=128,
        num_heads=8,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        rope_theta=5e5,
        optimizer="adafactor",
    )
