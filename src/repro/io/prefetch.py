"""Double-buffered background-thread block prefetch (paper Sec 4.2).

"The sampling engine must never stall the statistics engine": while the
device runs round t's ingest+stats, a worker thread gathers window t+1
from the wrapped source into a bounded queue. With a queue depth of 2
this is classic double buffering — the consumer always finds the next
window staged unless the underlying source is genuinely slower than the
compute, in which case the queue provides back-pressure instead of
unbounded memory growth.

Abandonment-safe: closing the stream generator mid-pass (a query
retires, the budget cuts) signals the worker and drains the queue so
a blocked `put` can never leak the thread. A worker exception is
re-raised at the consumer's next pull while the stream is being
driven; if the stream was already closed when the worker failed (the
error has nowhere to surface) it is logged instead of vanishing, as is
a worker that outlives the closing join (blocked inside a slow
``inner.fetch``).

With ``telemetry=`` attached the stream measures the stall-vs-hide
balance the double buffer exists for: per window, the producer-side
fetch cost (``prefetch_fetch_seconds`` — what is being hidden) and the
consumer-side residual wait (``prefetch_wait_seconds`` — what leaked
through), plus queue depth at each hand-off; one ``prefetch_stream``
trace event per stream summarizes windows, total wait/fetch, the
hidden fraction, and the stall fraction. The failure warnings above
are mirrored as structured events (``prefetch_worker_error`` /
``prefetch_join_timeout``) with matching counters, so a dashboard sees
them even when nobody greps logs.
"""

from __future__ import annotations

import logging
import queue
import threading
import time
from typing import Iterable, Iterator, Optional

import numpy as np

from repro.io.block_source import BlockSource, WindowData
from repro.io.faults import FetchCancelled, find_resilient

__all__ = ["PrefetchSource"]

logger = logging.getLogger(__name__)


class PrefetchSource:
    """Wrap any `BlockSource`; `stream` overlaps fetch with consumption.

    ``join_timeout`` bounds how long closing a stream waits for the
    worker thread (it is a daemon, so an over-timeout worker cannot
    hang interpreter exit — but it IS still running, which is why the
    timeout warns instead of passing silently).
    """

    def __init__(self, inner: BlockSource, *, depth: int = 2,
                 join_timeout: float = 10.0, telemetry=None):
        if depth < 1:
            raise ValueError(f"need depth >= 1, got {depth}")
        self.inner = inner
        self.depth = depth
        self.join_timeout = join_timeout
        self.telemetry = telemetry
        self.num_blocks = inner.num_blocks
        self.block_size = inner.block_size
        self.v_z = inner.v_z
        self.v_x = inner.v_x
        self.tuples_per_block = inner.tuples_per_block
        if telemetry is not None:
            reg = telemetry.registry
            self._h_wait = reg.histogram(
                "prefetch_wait_seconds",
                help="consumer stall per window (0 = fully hidden)")
            self._h_fetch = reg.histogram(
                "prefetch_fetch_seconds",
                help="producer-side gather cost per window")
            self._g_depth = reg.gauge(
                "prefetch_queue_depth", "staged windows at last hand-off")
            self._c_errors = reg.counter(
                "prefetch_worker_errors_total", "prefetch worker exceptions")
            self._c_timeouts = reg.counter(
                "prefetch_join_timeouts_total",
                "stream closes that abandoned a still-running worker")
            self._c_dropped = reg.counter(
                "prefetch_dropped_errors_total",
                "worker errors that surfaced only after stream close")

    def fetch(self, win: np.ndarray, pad_to: Optional[int] = None) -> WindowData:
        return self.inner.fetch(win, pad_to)

    def stream(
        self, windows: Iterable[np.ndarray], pad_to: Optional[int] = None
    ) -> Iterator[WindowData]:
        windows = list(windows)
        tel = self.telemetry
        q: queue.Queue = queue.Queue(maxsize=self.depth)
        stop = threading.Event()
        # Cooperative cancellation: hand the stop flag to a wrapped
        # ResilientSource so a worker "blocked" in inner.fetch is really
        # blocked in a cancellable backoff wait — stream close then stops
        # the retry loop at its next boundary instead of riding out the
        # remaining backoff schedule past join_timeout.
        resilient = find_resilient(self.inner)
        if resilient is not None:
            resilient.set_cancel_event(stop)
        failure: list = []  # the worker's exception, whether or not it queued
        # Stall-vs-hide accounting. Lock-free by construction in the
        # hot path: each list/counter has exactly one writer thread
        # (fetch_times/produced — worker; wait_times — consumer), so no
        # registry or stats lock is touched per window. Shared locks
        # here ping-pong the GIL against the dispatch loop — measured
        # at several % of round throughput. Flushed into the registry
        # once, at stream close.
        fetch_times: list = []  # worker-owned
        wait_times: list = []  # consumer-owned
        produced = [0]  # worker-owned; consumer reads it to estimate depth
        depth_last = 0

        def _put(item) -> bool:
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def worker():
            try:
                for win in windows:
                    if stop.is_set():
                        return
                    if tel is None:
                        wd = self.inner.fetch(win, pad_to)
                    else:
                        t0 = time.perf_counter()
                        wd = self.inner.fetch(win, pad_to)
                        fetch_times.append(time.perf_counter() - t0)
                    if not _put(("data", wd)):
                        return
                    produced[0] += 1
                _put(("done", None))
            except FetchCancelled:
                # The consumer closed the stream and the resilient layer
                # abandoned the in-flight fetch — a clean shutdown, not
                # an error.
                return
            except BaseException as exc:
                # Recorded unconditionally: the queued ("error", ...) item
                # is lost when the consumer is already closing (stop set,
                # queue being drained), and an error must never vanish.
                failure.append(exc)
                if tel is not None:
                    self._c_errors.inc(1)
                    tel.tracer.emit(
                        "prefetch_worker_error",
                        source=type(self.inner).__name__, error=repr(exc),
                    )
                _put(("error", exc))

        t = threading.Thread(target=worker, name="block-prefetch", daemon=True)
        t.start()
        raised = False
        try:
            while True:
                if tel is None:
                    kind, payload = q.get()
                else:
                    t0 = time.perf_counter()
                    kind, payload = q.get()
                    wait_times.append(time.perf_counter() - t0)
                    # produced - consumed, sans the queue's mutex: the
                    # worker's counter may lag a put by an instant, so
                    # this is an estimate — fine for a gauge.
                    depth_last = max(produced[0] - len(wait_times), 0)
                if kind == "done":
                    break
                if kind == "error":
                    raised = True
                    raise payload
                yield payload
        finally:
            stop.set()
            try:
                while True:
                    q.get_nowait()
            except queue.Empty:
                pass
            t.join(timeout=self.join_timeout)
            if t.is_alive():
                logger.warning(
                    "prefetch worker still running %.1fs after stream close "
                    "(blocked in %s.fetch?); abandoning daemon thread",
                    self.join_timeout, type(self.inner).__name__,
                )
                if tel is not None:
                    self._c_timeouts.inc(1)
                    tel.tracer.emit(
                        "prefetch_join_timeout",
                        source=type(self.inner).__name__,
                        timeout_s=self.join_timeout,
                    )
            elif failure and not raised:
                logger.warning(
                    "prefetch worker failed after the stream was closed; "
                    "dropping: %r", failure[0],
                )
                if tel is not None:
                    self._c_dropped.inc(1)
                    tel.tracer.emit(
                        "prefetch_dropped_error",
                        source=type(self.inner).__name__,
                        error=repr(failure[0]),
                    )
            if resilient is not None and resilient.cancel_event is stop:
                resilient.set_cancel_event(None)
            if tel is not None:
                # Registry flush, off the hot path. The worker has
                # exited (or been abandoned past join_timeout — its
                # list stays safely readable, appends are atomic).
                self._h_fetch.observe_many(fetch_times)
                self._h_wait.observe_many(wait_times)
                self._g_depth.set(depth_last)
                snap = {
                    "windows": len(wait_times),
                    "wait_s": float(sum(wait_times)),
                    "fetch_s": float(sum(fetch_times)),
                }
                # The double buffer's report card: hidden_s is gather
                # wall the consumer never waited for; stall_frac is the
                # share that leaked through as stalls.
                snap["hidden_s"] = max(snap["fetch_s"] - snap["wait_s"], 0.0)
                # min(…, 1.0): hand-off/scheduling overhead can make the
                # measured wait exceed the fetch wall it is charged to
                snap["stall_frac"] = min(
                    snap["wait_s"] / snap["fetch_s"] if snap["fetch_s"] > 0 else 0.0,
                    1.0,
                )
                tel.tracer.emit(
                    "prefetch_stream", source=type(self.inner).__name__, **snap
                )
