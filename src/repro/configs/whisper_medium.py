"""whisper-medium [audio] — enc-dec, conv frontend stub [arXiv:2212.04356].

24 encoder + 24 decoder layers, d_model 1024, 16 heads (MHA), GELU MLPs,
LayerNorm, tied unembedding. The conv/mel frontend is a STUB:
input_specs() provides precomputed frame embeddings (1500 frames).
"""

from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch_id="whisper_medium",
        family="audio",
        num_layers=24,  # decoder layers
        encoder_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=51865,
        encoder_seq=1500,
        frontend="audio_stub",
        norm_eps=1e-5,
        tie_embeddings=True,
        optimizer="adamw",
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="whisper_medium_smoke",
        family="audio",
        num_layers=2,
        encoder_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        encoder_seq=32,
        frontend="audio_stub",
        tie_embeddings=True,
    )
