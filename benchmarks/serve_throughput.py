"""Serving throughput: `MatchServer` vs one `run_engine` per query, and
the device-resident loop's host-sync amortization.

Two acceptance measurements for the serving subsystem:

  1. I/O amortization — N = 8 concurrent queries over the same dataset
     must read FEWER total tuples through the shared-counts scheduler
     than 8 sequential `run_engine` calls, with identical top-k accuracy
     against planted ground truth.
  2. Host-sync amortization — the fused device-resident round at
     ``poll_every=8`` must perform >= 4x fewer device<->host transfers
     per 64 windows than the per-window host-stepped cadence
     (``poll_every=1``, what the PR-1 loop did after every window), at
     identical top-k recall. The shared run uses `PrefetchSource` so
     window gathering overlaps the round.

Reported rows (benchmarks/run.py CSV schema):

  serve_solo_total        — us per solo batch, derived = total tuples read
  serve_shared_total      — us per served batch, derived = total tuples read
  serve_io_amortization   — derived = solo_tuples / shared_tuples (>1 = win)
  serve_qps               — derived = queries/sec through the server
  serve_accuracy          — derived = "shared_acc/solo_acc" top-k recall
  serve_late_query        — derived = new tuples read for a warm-cache query
  serve_syncs_per64_poll1 — derived = host syncs per 64 windows, poll_every=1
  serve_syncs_per64_poll8 — derived = host syncs per 64 windows, poll_every=8
  serve_sync_reduction    — derived = poll1/poll8 ratio (>=4 = pass)

Set SERVE_BENCH_SMOKE=1 for the tiny CI configuration (same code path,
~20x smaller dataset).
"""

from __future__ import annotations

import os
import pathlib
import time

import numpy as np

from benchmarks.common import EPS_DEFAULT
from repro.core.engine import EngineConfig, run_engine
from repro.core.histsim import HistSimParams
from repro.data.layout import block_layout
from repro.data.synth import SynthSpec, make_dataset, perturb_distribution
from repro.io import InMemorySource, PrefetchSource
from repro.obs import Telemetry
from repro.serve.fastmatch_server import MatchServer

N_QUERIES = 8
K = 10
DELTA = 0.01
EPS = max(EPS_DEFAULT, 0.07)
SMOKE = bool(int(os.environ.get("SERVE_BENCH_SMOKE", "0")))

SPEC = SynthSpec(
    v_z=161, v_x=24, num_tuples=300_000 if SMOKE else 6_000_000, k=K, n_close=10,
    close_distance=0.02, far_distance=0.3, zipf_a=1.0, close_rank="head", seed=42,
)
LOOKAHEAD = 16 if SMOKE else 512  # smoke: enough windows to see cadence


def _targets(ds, n: int):
    """n distinct targets near the dataset's base target."""
    rng = np.random.default_rng(7)
    out = [ds.target]
    for d in np.linspace(0.004, 0.04, n - 1):
        out.append(perturb_distribution(ds.target, d, rng))
    return out


def _true_top_k(ds, target, k: int) -> set:
    dists = np.abs(ds.true_hists - np.asarray(target)[None, :]).sum(axis=1)
    return set(np.argsort(dists, kind="stable")[:k].tolist())


def _recall(ids, truth: set) -> float:
    return len(set(ids.tolist()) & truth) / len(truth)


def _serve(blocked, targets, *, poll_every: int, prefetch: bool, telemetry=None):
    """One full shared-serving run; returns (server, rids, results, wall,
    loop_syncs_per64)."""
    source = InMemorySource(blocked)
    if prefetch:
        source = PrefetchSource(source, telemetry=telemetry)
    server = MatchServer(
        source, max_queries=N_QUERIES, lookahead=LOOKAHEAD, seed=200,
        poll_every=poll_every, k_cap=K,  # static k bound -> top_k selection
        telemetry=telemetry,
    )
    sched = server.scheduler
    t0 = time.perf_counter()
    rids = [server.submit(t, k=K, eps=EPS, delta=DELTA) for t in targets]
    # At submit time every request is still QUEUED (none admitted yet):
    # the split metrics distinguish queue depth from slot occupancy.
    m = server.metrics
    assert m["queries_queued"] == len(targets) and m["queries_live"] == 0, m
    syncs0, rounds0 = sched.loop_syncs, sched.rounds
    results = server.run_until_idle()
    m = server.metrics
    assert m["queries_queued"] == m["queries_live"] == m["queries_pending"] == 0, m
    wall = time.perf_counter() - t0
    rounds = max(sched.rounds - rounds0, 1)
    syncs_per64 = (sched.loop_syncs - syncs0) / rounds * 64
    return server, rids, results, wall, syncs_per64


def run(rows: list) -> None:
    ds = make_dataset(SPEC)
    blocked = block_layout(ds.z, ds.x, v_z=SPEC.v_z, v_x=SPEC.v_x, block_size=512, seed=42)
    targets = _targets(ds, N_QUERIES)
    params = HistSimParams(v_z=SPEC.v_z, v_x=SPEC.v_x, k=K, eps=EPS, delta=DELTA)

    # jit warmup for both paths (compile the fused round / marking once)
    run_engine(blocked, targets[0], params,
               EngineConfig(variant="fastmatch", lookahead=LOOKAHEAD, seed=999, max_rounds=1))
    warm = MatchServer(blocked, max_queries=N_QUERIES, lookahead=LOOKAHEAD, seed=999)
    warm.submit(targets[0], k=K, eps=EPS, delta=DELTA)
    warm.run_until_idle(max_rounds=1)

    # -- solo: one engine per query -------------------------------------
    t0 = time.perf_counter()
    solo = [
        run_engine(blocked, t, params,
                   EngineConfig(variant="fastmatch", lookahead=LOOKAHEAD, seed=100 + i))
        for i, t in enumerate(targets)
    ]
    solo_wall = time.perf_counter() - t0
    solo_tuples = sum(r.tuples_read for r in solo)

    # -- shared: one MatchServer, all queries concurrent ----------------
    # poll_every=1 is the PR-1 host-stepped cadence (one poll per window);
    # poll_every=8 + PrefetchSource is the device-resident configuration.
    _, rids1, results1, _, syncs64_poll1 = _serve(
        blocked, targets, poll_every=1, prefetch=False)
    # The device-resident run carries telemetry: its JSONL trace is the
    # CI serve-smoke artifact (and `repro.obs` is bit-equivalence-tested,
    # so the observed run IS the benchmarked run).
    telemetry = Telemetry()
    server, rids, results, shared_wall, syncs64_poll8 = _serve(
        blocked, targets, poll_every=8, prefetch=True, telemetry=telemetry)
    shared_tuples = server.metrics["total_tuples_read"]

    truths = [_true_top_k(ds, t, K) for t in targets]
    solo_acc = float(np.mean([_recall(r.ids, tr) for r, tr in zip(solo, truths)]))
    shared_acc = float(np.mean(
        [_recall(results[rid].ids, tr) for rid, tr in zip(rids, truths)]
    ))
    poll1_acc = float(np.mean(
        [_recall(results1[rid].ids, tr) for rid, tr in zip(rids1, truths)]
    ))
    sync_reduction = syncs64_poll1 / max(syncs64_poll8, 1e-9)

    # -- late query against the warm cache ------------------------------
    before = server.metrics["total_tuples_read"]
    late = server.submit(targets[1], k=K, eps=EPS, delta=DELTA)
    server.run_until_idle()[late]
    late_tuples = server.metrics["total_tuples_read"] - before

    # the full lifecycle trace of the shared run (incl. the late query)
    results_dir = pathlib.Path(__file__).parent / "results"
    results_dir.mkdir(exist_ok=True)
    n_events = server.export_trace(results_dir / "serve_trace.jsonl")

    rows.append(dict(name="serve_solo_total",
                     us_per_call=1e6 * solo_wall, derived=solo_tuples))
    rows.append(dict(name="serve_shared_total",
                     us_per_call=1e6 * shared_wall, derived=int(shared_tuples)))
    rows.append(dict(name="serve_io_amortization", us_per_call=0.0,
                     derived=round(solo_tuples / max(shared_tuples, 1), 2)))
    rows.append(dict(name="serve_qps", us_per_call=1e6 * shared_wall / N_QUERIES,
                     derived=round(N_QUERIES / shared_wall, 2)))
    rows.append(dict(name="serve_accuracy", us_per_call=0.0,
                     derived=f"{shared_acc:.3f}/{solo_acc:.3f}"))
    rows.append(dict(name="serve_late_query", us_per_call=0.0, derived=int(late_tuples)))
    rows.append(dict(name="serve_syncs_per64_poll1", us_per_call=0.0,
                     derived=round(syncs64_poll1, 2)))
    rows.append(dict(name="serve_syncs_per64_poll8", us_per_call=0.0,
                     derived=round(syncs64_poll8, 2)))
    rows.append(dict(name="serve_sync_reduction", us_per_call=0.0,
                     derived=round(sync_reduction, 2)))

    ok = (shared_tuples < solo_tuples and shared_acc >= solo_acc
          and sync_reduction >= 4.0 and shared_acc == poll1_acc)
    print(f"# serve_throughput: shared={int(shared_tuples):,} tuples vs "
          f"solo={solo_tuples:,} ({solo_tuples / max(shared_tuples, 1):.1f}x), "
          f"recall {shared_acc:.3f} vs {solo_acc:.3f} (poll1 {poll1_acc:.3f}), "
          f"syncs/64win {syncs64_poll1:.1f} -> {syncs64_poll8:.1f} "
          f"({sync_reduction:.1f}x), trace {n_events} events -> "
          f"{'PASS' if ok else 'FAIL'}")
    if SMOKE and not ok:
        raise SystemExit("serve_throughput smoke FAILED")


if __name__ == "__main__":
    rows: list = []
    run(rows)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
