"""Telemetry for the FastMatch serving stack — what each signal measures.

FastMatch's claims are rate claims: tuples drawn per query, rounds to
retirement, speedup at equal recall (paper Sec 5-6). This package is the
measurement layer that makes those rates first-class at serve time
instead of post-hoc benchmark artifacts: a `MetricsRegistry` of
counters/gauges/latency histograms with Prometheus-text and JSON
exporters, a `Tracer` recording per-query lifecycle and per-round-batch
events into a bounded ring with a JSONL sink, and per-query
tuples-to-confidence trajectories (`Telemetry`). Everything records at
existing host-sync/poll boundaries — the jitted `fused_round` /
pump-round path is untouched, and a telemetry-on run is bit-identical
to a telemetry-off run (tests/test_obs.py; gated <2% round-throughput
overhead in benchmarks/telemetry_overhead.py).

Metric ↔ paper-quantity map
===========================

Registry metrics (``MatchServer(telemetry=True)``):

  fastmatch_tuples_read_total      — m, the number of samples drawn: the
                                     sample complexity Theorem 1 bounds
                                     and Fig. 6/Table 4 speedups count
  fastmatch_blocks_read_total      — block-granular reads of the Sec 4.2
                                     bitmap-driven I/O manager (the unit
                                     AnyActive decides on)
  fastmatch_rounds_total           — statistics-engine iterations /
                                     windows dispatched: the x-axis of
                                     Fig. 5's per-round view of HistSim
  fastmatch_host_syncs_total       — device↔host polls: the asynchrony
                                     cost the Sec 4.2 relaxation (and
                                     poll_every) amortizes
  fastmatch_passes_total           — cyclic passes over the block layout
  fastmatch_queries_submitted_total/_admitted_total/_retired_total
                                   — the query population the serving
                                     layer multiplexes onto one stream
  fastmatch_query_tuples           — histogram of per-query tuples drawn
                                     while live: the per-query m whose
                                     1/N amortization is the serving win
  fastmatch_query_rounds           — histogram of rounds-to-retirement
                                     (paper Fig. 5: how many rounds
                                     HistSim needs before delta_upper
                                     crosses delta)
  fastmatch_query_wall_seconds     — submit→retire latency (the
                                     interactivity budget of Sec 1)
  fastmatch_round_batch_seconds    — host-side wall per dispatched
                                     round batch (gather+dispatch+sync)
  prefetch_wait_seconds            — consumer stalls waiting on the
                                     sampling engine: Sec 4.2's "must
                                     never stall the statistics engine",
                                     measured (0 wait = fully hidden)
  prefetch_fetch_seconds           — producer-side gather cost the
                                     double buffer is hiding
  prefetch_queue_depth             — staged windows at the last hand-off
  prefetch_worker_errors_total / prefetch_join_timeouts_total
                                   — structured forms of the prefetch
                                     failure warnings
  checkpoint_save_seconds / checkpoint_save_bytes_total /
  checkpoint_saves_total / checkpoint_save_failures_total /
  checkpoint_gc_swept_total        — warm-start persistence cost and
                                     hygiene (PR 4's cache layer)

Confidence-trajectory columns (`Telemetry.confidence_curve`):

  tuples        — m so far (shared; ``tuples_live`` = charged to the query)
  n_min         — min_i n_i: the worst-sampled candidate, the binding
                  term in every per-candidate Theorem 1 bound
  eps_n         — Theorem 1 eps(n_min) at per-candidate budget
                  delta/|V_Z| (the AnyActive threshold of Sec 4.2):
                  the l1 deviation currently guaranteed for the
                  worst-sampled candidate
  tau_min       — the running distance estimate of the current best
                  candidate (Alg. 1's tau_i for the head of M)
  delta_upper   — sum_i delta_i, the stats tail's failure bound
                  (Alg. 1 line 6 terminates on delta_upper < delta)
  confidence    — 1 - delta_upper: the anytime guarantee level a client
                  could be handed mid-query

Trace events (`Tracer`, JSONL): ``query_enqueue`` → ``query_admit`` →
``round_batch``* (windows, gather/dispatch/sync wall; pump adds
per-worker gather + assemble) → ``query_retire`` → ``query_done``
(the rid↔qid join, emitted by `MatchServer`) (+ ``pass_start``,
``exact_completion``, ``budget_exhausted``, ``checkpoint_save``,
``checkpoint_gc``, ``prefetch_stream``, ``prefetch_worker_error``,
``prefetch_join_timeout``). The skeleton (timing fields stripped) is
deterministic for a seeded workload — the golden span-tree contract.
"""

from repro.obs.registry import (
    DEFAULT_LATENCY_BINS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.telemetry import CURVE_COLUMNS, Telemetry
from repro.obs.tracer import TIMING_FIELDS, Tracer

__all__ = [
    "CURVE_COLUMNS",
    "Counter",
    "DEFAULT_LATENCY_BINS",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "TIMING_FIELDS",
    "Telemetry",
    "Tracer",
]
