"""Distributed components. Multi-device cases run in subprocesses with
their own XLA_FLAGS (the main test process must keep 1 device)."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_subprocess(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.mark.slow
class TestDistributedHistSim:
    def test_unified_round_matches_single_device_scheduler(self):
        """The unified make_distributed_round over MultiQueryState (counts
        sharded over "model", one psum per round, vmapped per-query stats)
        must reproduce the single-device SharedCountsScheduler for 4
        concurrent queries: ingesting exactly the blocks the scheduler
        read yields identical counts and per-slot tau/bounds/top-k."""
        out = _run_subprocess("""
            import jax, jax.numpy as jnp, numpy as np, json
            from jax.sharding import Mesh, PartitionSpec as P, NamedSharding
            from repro.core import histsim
            from repro.core import multiquery as mq
            from repro.core.distributed import make_distributed_round, multi_state_pspecs
            from repro.data.layout import block_layout
            from repro.data.synth import SynthSpec, make_dataset, perturb_distribution

            mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
            spec_s = SynthSpec(v_z=64, v_x=16, num_tuples=300_000, k=5, n_close=5, seed=3)
            ds = make_dataset(spec_s)
            blocked = block_layout(ds.z, ds.x, v_z=64, v_x=16, block_size=512, seed=3)
            spec = mq.MultiQuerySpec(v_z=64, v_x=16, max_queries=4)
            rng = np.random.default_rng(9)
            targets = [ds.target] + [
                perturb_distribution(ds.target, d, rng) for d in (0.01, 0.03, 0.05)
            ]

            # single-device scheduler: 4 live queries, a few fused windows
            sched = mq.SharedCountsScheduler(blocked, spec, window=64, seed=0, start_block=0)
            for t in targets:
                sched.admit(t, k=5, eps=0.08, delta=0.05)
            for p in range(0, 6 * 64, 64):
                sched.run_window(sched.order[p : p + 64])

            # distributed: fresh state, same queries, the same tuples the
            # scheduler read, ingested in ONE sharded round
            state = mq.init_multi_state(spec)
            for slot, t in enumerate(targets):
                q = np.asarray(t, np.float64).ravel()
                q = (q / q.sum()).astype(np.float32)
                state = mq.admit_slot(
                    state, jnp.asarray(slot, jnp.int32), jnp.asarray(q),
                    jnp.asarray(5, jnp.int32), jnp.asarray(0.08, jnp.float32),
                    jnp.asarray(0.05, jnp.float32), spec=spec)
            read = np.where(sched.read_mask)[0]
            z = blocked.z_blocks[read].reshape(-1)
            x = blocked.x_blocks[read].reshape(-1)
            pad = (-len(z)) % 4  # data-axis divisibility
            z = np.concatenate([z, np.full(pad, -1, np.int32)])
            x = np.concatenate([x, np.full(pad, -1, np.int32)])
            specs = multi_state_pspecs()
            state = jax.device_put(
                state, jax.tree.map(lambda s: NamedSharding(mesh, s), specs))
            zs = jax.device_put(jnp.asarray(z), NamedSharding(mesh, P("data")))
            xs = jax.device_put(jnp.asarray(x), NamedSharding(mesh, P("data")))
            rnd = make_distributed_round(mesh, spec)
            with mesh:
                out = rnd(state, zs, xs)

            ids_ok = all(
                np.array_equal(
                    np.asarray(histsim.top_k_ids(mq.slot_state(out, s), 5)),
                    np.asarray(histsim.top_k_ids(mq.slot_state(sched.state, s), 5)))
                for s in range(4))
            result = {
                "counts": bool(np.array_equal(
                    np.asarray(out.counts), np.asarray(sched.state.counts))),
                "n": bool(np.array_equal(np.asarray(out.n), np.asarray(sched.state.n))),
                "tau": bool(np.allclose(
                    np.asarray(out.tau), np.asarray(sched.state.tau), atol=1e-5)),
                "du": bool(np.allclose(
                    np.asarray(out.delta_upper), np.asarray(sched.state.delta_upper),
                    rtol=1e-4, atol=1e-6)),
                "ids": bool(ids_ok),
            }
            result["ok"] = all(result.values())
            print(json.dumps(result))
        """)
        res = json.loads(out.strip().splitlines()[-1])
        assert res["ok"], res

    def test_mesh_server_matches_single_device(self):
        """MatchServer(mesh=...) — counts candidate-sharded via GSPMD —
        must resolve the same queries to the same matching sets as the
        unsharded server."""
        out = _run_subprocess("""
            import jax, jax.numpy as jnp, numpy as np, json
            from jax.sharding import Mesh
            from repro.data.layout import block_layout
            from repro.data.synth import SynthSpec, make_dataset, perturb_distribution
            from repro.serve.fastmatch_server import MatchServer

            mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
            spec_s = SynthSpec(v_z=64, v_x=16, num_tuples=300_000, k=5, n_close=5, seed=3)
            ds = make_dataset(spec_s)
            blocked = block_layout(ds.z, ds.x, v_z=64, v_x=16, block_size=512, seed=3)
            rng = np.random.default_rng(9)
            targets = [ds.target] + [
                perturb_distribution(ds.target, d, rng) for d in (0.01, 0.03, 0.05)
            ]

            ref = MatchServer(blocked, max_queries=4, lookahead=128, seed=11)
            rids_ref = [ref.submit(t, k=5, eps=0.08, delta=0.05) for t in targets]
            res_ref = ref.run_until_idle()

            srv = MatchServer(blocked, max_queries=4, lookahead=128, seed=11, mesh=mesh)
            rids = [srv.submit(t, k=5, eps=0.08, delta=0.05) for t in targets]
            res = srv.run_until_idle()

            ok = all(
                sorted(res[r].ids.tolist()) == sorted(res_ref[rr].ids.tolist())
                and res[r].exact == res_ref[rr].exact
                for r, rr in zip(rids, rids_ref))
            print(json.dumps({"ok": bool(ok),
                              "tuples": srv.metrics["total_tuples_read"],
                              "tuples_ref": ref.metrics["total_tuples_read"]}))
        """)
        res = json.loads(out.strip().splitlines()[-1])
        assert res["ok"], res


@pytest.mark.slow
class TestPipelineParallel:
    def test_gpipe_matches_sequential(self):
        out = _run_subprocess("""
            import jax, jax.numpy as jnp, numpy as np, json
            from jax.sharding import Mesh, PartitionSpec as P, NamedSharding
            from repro.distributed.pipeline import make_pipeline_forward, stack_stage_params, transformer_stage_fn

            mesh = Mesh(np.array(jax.devices()).reshape(4, 2, 1), ("pod", "data", "model"))
            D = 16
            def layer_fn(lp, x):
                return jnp.tanh(x @ lp["w"] + lp["b"])
            rng = np.random.default_rng(0)
            n_stages, layers_per_stage = 4, 2
            stages = []
            for s in range(n_stages):
                lw = jnp.asarray(rng.normal(size=(layers_per_stage, D, D)).astype(np.float32) * 0.3)
                lb = jnp.asarray(np.zeros((layers_per_stage, D), np.float32))
                stages.append({"w": lw, "b": lb})
            stacked = stack_stage_params(stages)

            fwd = make_pipeline_forward(
                transformer_stage_fn(layer_fn, layers_per_stage), mesh,
                n_stages=n_stages, n_microbatches=4,
            )
            x = jnp.asarray(rng.normal(size=(8, D)).astype(np.float32))
            with mesh:
                y = jax.jit(fwd)(stacked, x)

            # sequential reference
            ref = x
            for s in range(n_stages):
                for l in range(layers_per_stage):
                    ref = jnp.tanh(ref @ stages[s]["w"][l] + stages[s]["b"][l])
            ok = np.allclose(np.asarray(y), np.asarray(ref), atol=1e-5)
            print(json.dumps({"ok": bool(ok)}))
        """)
        assert json.loads(out.strip().splitlines()[-1])["ok"]


class TestShardingRules:
    def test_param_specs_resolution(self):
        import jax
        from jax.sharding import Mesh, PartitionSpec as P

        from repro.configs import get_smoke_config
        from repro.distributed.sharding import param_pspecs
        from repro.models.model_zoo import get_model

        mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        cfg = get_smoke_config("granite_8b")
        model = get_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        specs = param_pspecs(shapes, mesh)
        flat = {
            "/".join(str(getattr(k, "key", getattr(k, "idx", ""))) for k in path): s
            for path, s in jax.tree_util.tree_flatten_with_path(specs)[0]
        }
        assert flat["embed/table"] == P("model", "data")
        assert flat["layers/0/attn/wq"] == P("data", "model")
        assert flat["layers/0/attn/wo"] == P("model", "data")
        assert flat["layers/0/mlp/w_down"] == P("model", "data")
        assert flat["layers/0/attn_norm/scale"] == P(None)
        assert flat["lm_head/w"] == P("data", "model")

    def test_stacked_scan_params_get_layer_dim_none(self):
        import dataclasses

        import jax
        from jax.sharding import Mesh, PartitionSpec as P

        from repro.configs import get_smoke_config
        from repro.distributed.sharding import param_pspecs
        from repro.models.model_zoo import get_model

        mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        cfg = dataclasses.replace(get_smoke_config("granite_8b"), scan_layers=True)
        model = get_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        specs = param_pspecs(shapes, mesh)
        assert specs["layers"]["attn"]["wq"] == P(None, "data", "model")

    def test_divisibility_guard(self):
        import jax
        from jax.sharding import Mesh, PartitionSpec as P

        from repro.distributed.sharding import guard_pspec

        mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        # mesh axes have size 1 -> everything divisible
        assert guard_pspec((7, 3), P("data", "model"), mesh) == P("data", "model")

    def test_batch_pspec_fallbacks(self):
        import jax
        from jax.sharding import Mesh

        from repro.distributed.sharding import batch_pspec

        mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        spec = batch_pspec(mesh, batch_size=4)
        assert spec[0] in ("data", ("data",), None)  # divisible by 1
