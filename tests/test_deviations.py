"""Deviation selection (Sec 3.3): split point + eps assignment invariants."""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; skip on minimal installs
from hypothesis import given, settings, strategies as st

from repro.core import deviations as dev

taus = st.lists(st.floats(0.0, 2.0), min_size=4, max_size=64).map(np.asarray)


class TestTopKMask:
    @given(tau=taus, k_frac=st.floats(0.1, 0.9))
    @settings(deadline=None, max_examples=150)
    def test_exactly_k_selected(self, tau, k_frac):
        k = max(1, int(len(tau) * k_frac))
        m = np.asarray(dev.top_k_mask(jnp.asarray(tau, jnp.float32), k))
        assert m.sum() == k

    @given(tau=taus)
    @settings(deadline=None, max_examples=100)
    def test_selected_are_smallest(self, tau):
        k = len(tau) // 2
        m = np.asarray(dev.top_k_mask(jnp.asarray(tau, jnp.float32), k))
        inside = np.sort(tau[m])
        outside = np.sort(tau[~m])
        if len(inside) and len(outside):
            assert inside[-1] <= outside[0] + 1e-6


class TestSplitPoint:
    def test_midpoint(self):
        tau = jnp.asarray([0.1, 0.2, 0.5, 0.9])
        s = float(dev.split_point(tau, 2))
        assert s == pytest.approx((0.2 + 0.5) / 2)

    @given(tau=taus)
    @settings(deadline=None, max_examples=100)
    def test_between_boundary_candidates(self, tau):
        k = max(1, len(tau) // 3)
        t = np.sort(tau)
        s = float(dev.split_point(jnp.asarray(tau, jnp.float32), k))
        assert t[k - 1] - 1e-5 <= s <= t[k] + 1e-5


class TestAssignDeviations:
    @given(tau=taus, seed=st.integers(0, 1000))
    @settings(deadline=None, max_examples=150)
    def test_lemma2_constraints(self, tau, seed):
        """The chosen eps_i must satisfy Lemma 2's constraint (1) & (2)."""
        rng = np.random.default_rng(seed)
        eps, delta, v_x = 0.06, 0.01, 24
        k = max(1, len(tau) // 3)
        n = rng.integers(1, 10**6, size=len(tau))
        d = dev.assign_deviations(
            jnp.asarray(tau, jnp.float32), jnp.asarray(n, jnp.float32),
            k=k, eps=eps, delta=delta, v_x=v_x,
        )
        tau_j = np.asarray(d.tau)
        eps_i = np.asarray(d.eps_i)
        in_m = np.asarray(d.in_top_k)
        # constraint (2): eps_i <= eps for i in M (reconstruction)
        assert (eps_i[in_m] <= eps + 1e-6).all()
        # constraint (1): max_{i in M}(tau_i + eps_i) - max(min_{j notin M}(tau_j - eps_j), 0) < eps
        if in_m.any() and (~in_m).any():
            lhs = (tau_j[in_m] + eps_i[in_m]).max() - max(
                (tau_j[~in_m] - eps_i[~in_m]).min(), 0.0
            )
            assert lhs < eps + 1e-5

    @given(tau=taus)
    @settings(deadline=None, max_examples=100)
    def test_delta_upper_is_sum(self, tau):
        n = np.full(len(tau), 10_000)
        d = dev.assign_deviations(
            jnp.asarray(tau, jnp.float32), jnp.asarray(n, jnp.float32),
            k=max(1, len(tau) // 4), eps=0.06, delta=0.01, v_x=24,
        )
        assert float(d.delta_upper) == pytest.approx(
            float(np.exp(np.asarray(d.log_delta_i)).sum()), rel=1e-4
        )

    def test_more_samples_smaller_delta_upper(self):
        tau = jnp.asarray([0.02, 0.03, 0.4, 0.5, 0.6], jnp.float32)
        d1 = dev.assign_deviations(tau, jnp.full((5,), 1e3), k=2, eps=0.06, delta=0.01, v_x=24)
        d2 = dev.assign_deviations(tau, jnp.full((5,), 1e5), k=2, eps=0.06, delta=0.01, v_x=24)
        assert float(d2.delta_upper) < float(d1.delta_upper)

    def test_active_set_shrinks_with_samples(self):
        tau = jnp.asarray([0.02, 0.03, 0.4, 0.5, 0.6], jnp.float32)
        d = dev.assign_deviations(tau, jnp.full((5,), 1e6), k=2, eps=0.06, delta=0.01, v_x=8)
        # far candidates have big eps_j -> tiny delta_j -> inactive
        assert not bool(d.active[4])

    def test_slowmatch_stricter(self):
        """SlowMatch's criterion needs at least as many samples: its
        delta_upper >= HistSim's at the same state."""
        tau = jnp.asarray([0.02, 0.05, 0.3, 0.55, 0.6, 0.9], jnp.float32)
        n = jnp.full((6,), 5e4)
        h = dev.assign_deviations(tau, n, k=2, eps=0.06, delta=0.01, v_x=24)
        s = dev.slowmatch_deviations(tau, n, k=2, eps=0.06, delta=0.01, v_x=24)
        assert float(s.delta_upper) >= float(h.delta_upper) - 1e-9
