"""Metrics registry: counters, gauges, fixed-bin histograms + exporters.

A deliberately small Prometheus-shaped metrics core for the serving
stack. Three metric kinds, all host-side and lock-protected (the
prefetch worker thread records from off the main thread):

  Counter   — monotone float; ``inc`` only
  Gauge     — last-write-wins float
  Histogram — fixed upper-bound bins (Prometheus ``le`` semantics) with
              running sum/count; observations are O(1) appends on the
              hot path and are BINNED LAZILY at snapshot time through
              the repo's own histogram kernel (`repro.kernels.ops`) —
              the same one-hot-contraction op the sampling engine uses
              for tuple ingest, here counting latency samples into
              latency bins (V_Z=1, V_X=num_bins)

`MetricsRegistry` is the factory/namespace: ``registry.counter(name)``
returns the existing metric or creates it (re-registering under a
different kind raises). Export formats:

  to_prometheus() — text exposition format (scrape-able / pushable)
  snapshot()      — plain-JSON dict, one entry per metric, used by the
                    BENCH_telemetry report and test assertions

Nothing here touches jitted code: the engine records at host-sync/poll
boundaries only (see `repro.obs` package docstring).
"""

from __future__ import annotations

import json
import math
import threading
from typing import Dict, List, Optional, Sequence

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "DEFAULT_LATENCY_BINS"]

# Upper bin edges (seconds) for latency histograms: 100us .. ~100s,
# roughly x3 steps — wide enough for both a fused-round dispatch and an
# exact-completion pass.
DEFAULT_LATENCY_BINS = (
    1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 0.1, 0.3, 1.0, 3.0, 10.0, 30.0, 100.0
)


def _check_name(name: str) -> str:
    if not name or not all(c.isalnum() or c in "_:" for c in name):
        raise ValueError(f"invalid metric name {name!r}")
    return name


class Counter:
    """Monotone counter (use a ``_total`` suffix by convention)."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = _check_name(name)
        self.help = help
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease (inc {amount})")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> dict:
        return {"kind": self.kind, "value": self._value}


class Gauge:
    """Last-write-wins instantaneous value."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = _check_name(name)
        self.help = help
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> dict:
        return {"kind": self.kind, "value": self._value}


class Histogram:
    """Fixed-bin histogram with Prometheus ``le`` bucket semantics.

    ``observe`` is an O(1) list append; samples are binned lazily by
    `_flush` — ``np.searchsorted`` assigns each sample its bin index and
    the repo's histogram kernel counts them (one candidate row, one bin
    per x-value: exactly the ingest op at V_Z=1). Bin counts are stored
    NON-cumulative per bin plus an overflow bin; the exporter emits the
    cumulative ``le`` form.
    """

    kind = "histogram"

    def __init__(self, name: str, edges: Sequence[float] = DEFAULT_LATENCY_BINS, help: str = ""):
        if not edges or list(edges) != sorted(edges):
            raise ValueError(f"histogram {name}: edges must be sorted and non-empty")
        self.name = _check_name(name)
        self.help = help
        self.edges = tuple(float(e) for e in edges)
        self._counts = np.zeros(len(self.edges) + 1, np.int64)  # [+Inf] last
        self._sum = 0.0
        self._count = 0
        self._pending: List[float] = []
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        with self._lock:
            self._pending.append(float(value))
            self._sum += float(value)
            self._count += 1

    def observe_many(self, values: Sequence[float]) -> None:
        """Batch observe under one lock acquisition — for call sites that
        accumulate samples lock-free in a hot path (e.g. the prefetch
        stream's per-window timings) and flush once at a boundary."""
        vals = [float(v) for v in values]
        if not vals:
            return
        with self._lock:
            self._pending.extend(vals)
            self._sum += sum(vals)
            self._count += len(vals)

    def _flush(self) -> None:
        """Bin pending samples through the repo's histogram kernel."""
        with self._lock:
            pending, self._pending = self._pending, []
        if not pending:
            return
        from repro.kernels import ops  # deferred: registry core is jax-free

        vals = np.asarray(pending, np.float64)
        # side="left": v == edge lands in that edge's bucket (v <= le).
        bins = np.searchsorted(self.edges, vals, side="left").astype(np.int32)
        counts = ops.histogram(
            np.zeros(len(bins), np.int32), bins, v_z=1, v_x=len(self.edges) + 1
        )
        with self._lock:
            self._counts += np.asarray(counts, np.int64)[0]

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def bucket_counts(self) -> np.ndarray:
        """Per-bin (non-cumulative) counts, overflow last."""
        self._flush()
        return self._counts.copy()

    def snapshot(self) -> dict:
        self._flush()
        return {
            "kind": self.kind,
            "edges": list(self.edges),
            "buckets": self._counts.tolist(),
            "sum": self._sum,
            "count": self._count,
        }


class MetricsRegistry:
    """Get-or-create namespace of metrics + the two exporters."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, cls, name: str, *args, **kwargs):
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if not isinstance(m, cls):
                    raise ValueError(
                        f"metric {name!r} already registered as {m.kind}, not {cls.kind}"
                    )
                return m
            m = cls(name, *args, **kwargs)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(
        self, name: str, edges: Sequence[float] = DEFAULT_LATENCY_BINS, help: str = ""
    ) -> Histogram:
        return self._get_or_create(Histogram, name, edges, help)

    def get(self, name: str) -> Optional[object]:
        return self._metrics.get(name)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    # -- exporters ---------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-able {name: metric snapshot} of every registered metric."""
        return {name: self._metrics[name].snapshot() for name in self.names()}

    def to_json(self) -> str:
        return json.dumps(self.snapshot(), indent=2, sort_keys=True)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (one scrape body)."""
        lines: List[str] = []
        for name in self.names():
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            if isinstance(m, Histogram):
                cum = 0
                for edge, c in zip(m.edges, m.bucket_counts()):
                    cum += int(c)
                    lines.append(f'{name}_bucket{{le="{_fmt(edge)}"}} {cum}')
                lines.append(f'{name}_bucket{{le="+Inf"}} {m.count}')
                lines.append(f"{name}_sum {_fmt(m.sum)}")
                lines.append(f"{name}_count {m.count}")
            else:
                lines.append(f"{name} {_fmt(m.value)}")
        return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    """Prometheus float rendering: integers without trailing .0 noise."""
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)
