"""I/O fault injection and the resilient source boundary.

FastMatch's premise (paper Sec 5) is many asynchronous block samplers
feeding one statistics engine — which makes the statistics engine's
correctness hostage to every sampler's I/O path. Two failure classes
matter:

  availability — a fetch raises or stalls. Untreated, one flaky fetch
      kills the whole window stream and every live query with it.
  integrity    — a fetch *returns*, but the window is truncated or
      corrupted. Untreated, bad tuples reach `ingest` and silently
      poison the DURABLE shared counts matrix that `CacheSnapshot`
      persists across restarts — the worst failure mode this repo has,
      because a poisoned cache invalidates every Theorem-1 bound ever
      derived from it, including after the fault is long gone.

This module provides both sides of the contract:

`FaultySource` (+ `FaultInjector`) is the seeded, deterministic chaos
wrapper used by tests, the FASTMATCH_CHAOS CI lane, and
`benchmarks/fault_recovery.py`: transient fetch exceptions, latency
stalls, truncated windows, corrupted windows, one mid-stream EOF, and
one unrecoverable crash, each drawn from a seeded per-attempt RNG so a
run is reproducible fault for fault.

`ResilientSource` is the production-side boundary every window passes
through before it may reach ingest:

  * bounded retries with exponential backoff + seeded jitter and an
    optional per-fetch deadline; transient errors (`TransientIOError`,
    `TimeoutError`, `ConnectionError`, `EOFError`, `InterruptedError`)
    are retried, anything else propagates — a programming error must
    never be eaten by a retry loop;
  * `validate_window` integrity validation (shapes, dtypes,
    bitmap/valid-mask consistency) at the source boundary;
  * quarantine instead of poison: a window that exhausts its retries or
    fails validation NEVER reaches ingest — its blocks are recorded as
    quarantined (a structured ``window_quarantine`` event + counters),
    `stream` skips the window, and `fetch` raises `WindowQuarantined`
    so random-access callers can do the same. The scheduler drains
    `take_quarantined()` at poll boundaries and re-derives the paper
    guarantee over the surviving block population (see
    `repro.core.multiquery.SharedCountsScheduler.quarantine_blocks`).

With zero faults injected the wrapper is bit-invisible:
``ResilientSource(FaultySource(inner, p=0))`` streams the exact same
`WindowData` leaves as ``inner`` (property-tested in
tests/test_faults.py), and a run whose transient faults all retry to
success is bit-identical to a fault-free run end to end — retrying a
fetch re-reads the same immutable blocks, and the engine never sees
the difference (the golden contract the CHAOS lane enforces).

Validation levels (``validate=``):

  "structural" — shapes/dtypes/window-length only; O(1), safe on
      device-resident leaves (no host sync).
  "content"    — structural plus value ranges, z/x padding pairing,
      and an exact bitmap rebuild; O(window bytes), host-side.
  "auto"       — "content" when the leaves are already host numpy
      arrays (a host/disk/remote source — exactly where corruption
      lives), "structural" when they are device arrays (forcing a
      device_get per window would stall the async dispatch pipeline
      the fused round exists for).
"""

from __future__ import annotations

import dataclasses
import logging
import threading
import time
from typing import Iterable, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.bitmap import build_block_bitmap, words_for
from repro.io.block_source import BlockSource, WindowData

__all__ = [
    "CorruptWindowError",
    "FaultInjector",
    "FaultPlan",
    "FaultySource",
    "FetchCancelled",
    "ResilientSource",
    "RetryPolicy",
    "TransientIOError",
    "TruncatedStreamError",
    "UnrecoverableIOError",
    "WindowQuarantined",
    "maybe_chaos",
    "validate_window",
]

logger = logging.getLogger(__name__)


# --------------------------------------------------------------------------
# Exception taxonomy
# --------------------------------------------------------------------------


class TransientIOError(IOError):
    """A fetch failure expected to heal on retry (flaky NFS, throttled
    object store, dropped connection)."""


class TruncatedStreamError(EOFError):
    """Mid-stream EOF: the source ended before the window was served
    (a dropped connection; reopening usually heals it — transient)."""


class UnrecoverableIOError(RuntimeError):
    """A failure no retry can heal (device lost, bad file descriptor).
    Deliberately NOT in the transient set: it propagates out of
    `ResilientSource` and crashes the round — the `ServeSupervisor`'s
    job, not the retry loop's."""


class CorruptWindowError(ValueError):
    """`validate_window` verdict: the window's bytes are not a valid
    `WindowData` for this source (wrong shape/dtype, out-of-range ids,
    bitmap inconsistent with the tuples)."""


class WindowQuarantined(RuntimeError):
    """Raised by `ResilientSource.fetch` after a window is quarantined:
    retries exhausted, deadline passed, or validation failed. Carries
    the global block ids so the caller can drop them from its probe
    set. `ResilientSource.stream` absorbs this itself (skips the
    window); random-access callers catch it."""

    def __init__(self, block_ids: np.ndarray, cause: BaseException):
        self.block_ids = np.asarray(block_ids, np.int64).ravel()
        self.cause = cause
        super().__init__(
            f"window of {self.block_ids.size} blocks quarantined: {cause!r}"
        )


class FetchCancelled(RuntimeError):
    """The cooperative cancellation flag fired mid-retry — the consumer
    (e.g. a closing `PrefetchSource` stream) no longer wants the
    window. Not a fault: nothing is quarantined, nothing is logged as
    an error."""


# --------------------------------------------------------------------------
# Window integrity validation
# --------------------------------------------------------------------------


def _is_host(wd: WindowData) -> bool:
    return all(isinstance(leaf, np.ndarray) for leaf in wd)


def validate_window(
    wd: WindowData,
    *,
    num_blocks: int,
    block_size: int,
    v_z: int,
    v_x: int,
    pad_to: Optional[int] = None,
    level: str = "auto",
) -> None:
    """Raise `CorruptWindowError` unless ``wd`` is a well-formed window
    of this source. See module docstring for the three levels."""
    if level not in ("auto", "structural", "content"):
        raise ValueError(f"unknown validation level {level!r}")
    checks = (
        ("indices", wd.indices, 1, ("int32", "int64")),
        ("z", wd.z, 2, ("int32",)),
        ("x", wd.x, 2, ("int32",)),
        ("bitmap", wd.bitmap, 2, ("uint32",)),
        ("valid", wd.valid, 1, ("bool",)),
    )
    for name, leaf, ndim, dtypes in checks:
        shape = getattr(leaf, "shape", None)
        if shape is None or len(shape) != ndim:
            raise CorruptWindowError(
                f"{name}: expected {ndim}-d array, got "
                f"{type(leaf).__name__} shape {shape}"
            )
        if str(getattr(leaf, "dtype", "?")) not in dtypes:
            raise CorruptWindowError(
                f"{name}: dtype {getattr(leaf, 'dtype', '?')} not in {dtypes}"
            )
    length = wd.indices.shape[0]
    if pad_to is not None and length != pad_to:
        raise CorruptWindowError(f"window length {length} != pad_to {pad_to} (truncated?)")
    for name, leaf in (("z", wd.z), ("x", wd.x), ("bitmap", wd.bitmap), ("valid", wd.valid)):
        if leaf.shape[0] != length:
            raise CorruptWindowError(
                f"{name}: {leaf.shape[0]} rows, indices has {length} (truncated?)"
            )
    if wd.z.shape != (length, block_size) or wd.x.shape != wd.z.shape:
        raise CorruptWindowError(
            f"z/x shape {wd.z.shape}/{wd.x.shape} != ({length}, {block_size})"
        )
    if wd.bitmap.shape[1] != words_for(v_z):
        raise CorruptWindowError(
            f"bitmap width {wd.bitmap.shape[1]} != words_for({v_z})={words_for(v_z)}"
        )
    if level == "structural" or (level == "auto" and not _is_host(wd)):
        return
    # -- content checks (host numpy, one pass over the window bytes) -------
    idx = np.asarray(wd.indices)
    if idx.size and (int(idx.min()) < 0 or int(idx.max()) >= num_blocks):
        raise CorruptWindowError(
            f"block ids outside [0, {num_blocks}): [{idx.min()}, {idx.max()}]"
        )
    z, x = np.asarray(wd.z), np.asarray(wd.x)
    if z.size and (int(z.min()) < -1 or int(z.max()) >= v_z):
        raise CorruptWindowError(f"z values outside [-1, {v_z}): [{z.min()}, {z.max()}]")
    if x.size and (int(x.min()) < -1 or int(x.max()) >= v_x):
        raise CorruptWindowError(f"x values outside [-1, {v_x}): [{x.min()}, {x.max()}]")
    if ((z >= 0) != (x >= 0)).any():
        raise CorruptWindowError("z/x padding mismatch: (z >= 0) != (x >= 0) somewhere")
    valid = np.asarray(wd.valid)
    if valid.any():
        rebuilt = build_block_bitmap(z[valid], v_z)
        if not np.array_equal(rebuilt, np.asarray(wd.bitmap)[valid]):
            raise CorruptWindowError("bitmap inconsistent with window tuples")


# --------------------------------------------------------------------------
# Deterministic fault injection
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Per-attempt fault probabilities + one-shot fault positions.

    Probabilities are judged per fetch ATTEMPT (retries draw fresh),
    from a seeded RNG — a transient fault can therefore heal on retry,
    which is the whole point. ``eof_at`` / ``crash_at`` name a single
    0-based global attempt index each; they fire exactly once.
    """

    p_transient: float = 0.0  # raise TransientIOError (retry heals)
    p_stall: float = 0.0      # serve the window after sleeping stall_s
    stall_s: float = 0.005
    p_corrupt: float = 0.0    # serve a window with out-of-range ids
    p_truncate: float = 0.0   # serve a window with a missing row
    eof_at: Optional[int] = None    # one TruncatedStreamError (transient)
    crash_at: Optional[int] = None  # one UnrecoverableIOError (fatal)

    def __post_init__(self):
        total = self.p_transient + self.p_stall + self.p_corrupt + self.p_truncate
        if not (0.0 <= total <= 1.0):
            raise ValueError(f"fault probabilities sum to {total}, need [0, 1]")


class FaultInjector:
    """Seeded per-attempt fault schedule. One global attempt counter —
    the draw sequence is a pure function of (plan, seed, call order),
    so a seeded run injects the same faults every time."""

    def __init__(self, plan: FaultPlan, *, seed: int = 0):
        self.plan = plan
        self._rng = np.random.default_rng(seed)
        self.attempts = 0
        self.injected: dict = {
            "transient": 0, "stall": 0, "corrupt": 0, "truncate": 0,
            "eof": 0, "crash": 0,
        }

    def next_fault(self) -> Optional[str]:
        i = self.attempts
        self.attempts += 1
        p = self.plan
        # One-shot faults fire at their attempt index regardless of the
        # probability draws (which are still consumed, keeping the rest
        # of the schedule aligned with the no-one-shot run).
        u = self._rng.random()
        if p.crash_at is not None and i == p.crash_at:
            kind = "crash"
        elif p.eof_at is not None and i == p.eof_at:
            kind = "eof"
        else:
            kind, acc = None, 0.0
            for name, prob in (
                ("transient", p.p_transient), ("stall", p.p_stall),
                ("corrupt", p.p_corrupt), ("truncate", p.p_truncate),
            ):
                acc += prob
                if u < acc:
                    kind = name
                    break
        if kind is not None:
            self.injected[kind] += 1
        return kind


class FaultySource:
    """Chaos wrapper: serve ``inner``'s windows through the injector's
    fault schedule. Corruption/truncation are applied to host copies of
    the leaves (a corrupted window is by definition no longer the
    device-resident original)."""

    def __init__(self, inner: BlockSource, plan: FaultPlan = FaultPlan(), *, seed: int = 0):
        self.inner = inner
        self.injector = FaultInjector(plan, seed=seed)
        self.num_blocks = inner.num_blocks
        self.block_size = inner.block_size
        self.v_z = inner.v_z
        self.v_x = inner.v_x
        self.tuples_per_block = inner.tuples_per_block

    def _host(self, wd: WindowData) -> WindowData:
        import jax

        return WindowData(*(np.array(jax.device_get(leaf)) for leaf in wd))

    def _corrupt(self, wd: WindowData) -> WindowData:
        wd = self._host(wd)
        z = wd.z.copy()
        if z.size:
            z[0, : max(1, z.shape[1] // 8)] = self.v_z + 7  # out of range
        return wd._replace(z=z)

    def _truncate(self, wd: WindowData) -> WindowData:
        wd = self._host(wd)
        return WindowData(*(leaf[:-1] for leaf in wd))

    def fetch(self, win: np.ndarray, pad_to: Optional[int] = None) -> WindowData:
        kind = self.injector.next_fault()
        if kind == "crash":
            raise UnrecoverableIOError("injected: device lost")
        if kind == "eof":
            raise TruncatedStreamError("injected: mid-stream EOF")
        if kind == "transient":
            raise TransientIOError("injected: transient fetch failure")
        wd = self.inner.fetch(win, pad_to)
        if kind == "stall":
            time.sleep(self.injector.plan.stall_s)
        elif kind == "corrupt":
            wd = self._corrupt(wd)
        elif kind == "truncate":
            wd = self._truncate(wd)
        return wd

    def stream(
        self, windows: Iterable[np.ndarray], pad_to: Optional[int] = None
    ) -> Iterator[WindowData]:
        # Window-by-window through our own fetch, so every stream window
        # passes the fault schedule too.
        for win in windows:
            yield self.fetch(win, pad_to)


# --------------------------------------------------------------------------
# The resilient boundary
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff + seeded jitter.

    ``deadline_s`` bounds one fetch's total wall (attempts + backoff);
    exceeding it escalates to permanent even with retries left.
    Jitter is drawn from the policy's own seeded RNG stream so two
    identically-seeded runs back off identically (determinism) while
    distinct sources de-synchronize (no retry stampede)."""

    max_retries: int = 4
    backoff_s: float = 0.02
    backoff_mult: float = 2.0
    jitter: float = 0.25  # +- fraction of the delay
    deadline_s: Optional[float] = None
    seed: int = 0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(f"need max_retries >= 0, got {self.max_retries}")
        if not (0.0 <= self.jitter <= 1.0):
            raise ValueError(f"need 0 <= jitter <= 1, got {self.jitter}")


class ResilientSource:
    """Retry + validate + quarantine wrapper around any `BlockSource`.

    The serving invariant this class owns: NOTHING that fails the
    integrity validation, and nothing from a fetch that could not be
    completed, ever reaches ingest. The failure surface is explicit —
    `stream` skips quarantined windows, `fetch` raises
    `WindowQuarantined` — and every quarantined block id is queued for
    `take_quarantined()` so the scheduler can re-derive its guarantees
    over the surviving population instead of lying.

    ``cancel_event`` (see `set_cancel_event`) is the cooperative
    cancellation hook: a backoff sleep waits on the event instead of
    sleeping blind, and each attempt checks it first, so a consumer
    that no longer wants the window (a closing `PrefetchSource`) stops
    the retry loop at the next boundary instead of riding out the full
    backoff schedule. Cancellation raises `FetchCancelled` and
    quarantines nothing.
    """

    TRANSIENT = (
        TransientIOError,
        TimeoutError,
        ConnectionError,
        InterruptedError,
        EOFError,  # covers TruncatedStreamError
    )

    def __init__(
        self,
        inner: BlockSource,
        *,
        policy: RetryPolicy = RetryPolicy(),
        validate: str = "auto",
        telemetry=None,
        clock=time.monotonic,
        sleep=None,
    ):
        if validate not in ("auto", "structural", "content", "off"):
            raise ValueError(f"unknown validation level {validate!r}")
        self.inner = inner
        self.policy = policy
        self.validate = validate
        self.telemetry = telemetry
        self._clock = clock
        self._sleep = sleep
        self._rng = np.random.default_rng(policy.seed)
        self.num_blocks = inner.num_blocks
        self.block_size = inner.block_size
        self.v_z = inner.v_z
        self.v_x = inner.v_x
        self.tuples_per_block = inner.tuples_per_block
        self.cancel_event: Optional[threading.Event] = None
        # Host-observable fault accounting (works without telemetry).
        self.retries_total = 0
        self.transient_faults = 0
        self.permanent_faults = 0
        self.validation_failures = 0
        self.windows_quarantined = 0
        self.blocks_quarantined = 0
        self._lock = threading.Lock()
        self._pending: List[Tuple[np.ndarray, str]] = []
        if telemetry is not None:
            reg = telemetry.registry
            self._c_retries = reg.counter(
                "io_fetch_retries_total", "fetch attempts repeated after a transient fault")
            self._c_transient = reg.counter(
                "io_transient_faults_total", "transient fetch failures observed")
            self._c_permanent = reg.counter(
                "io_permanent_faults_total",
                "fetches escalated to permanent (retries/deadline exhausted)")
            self._c_validation = reg.counter(
                "io_validation_failures_total", "windows that failed integrity validation")
            self._c_quarantined = reg.counter(
                "io_blocks_quarantined_total", "blocks quarantined at the source boundary")

    def set_cancel_event(self, event: Optional[threading.Event]) -> None:
        """Install (or clear, with None) the cooperative cancellation
        flag checked between attempts and during backoff sleeps.
        Propagates to any nested `ResilientSource` (stacked wrappers,
        e.g. a chaos lane around an already-resilient source) so the
        innermost retry loop — where the blocking actually happens —
        also sees the flag."""
        self.cancel_event = event
        nested = find_resilient(self.inner)
        if nested is not None:
            nested.set_cancel_event(event)

    # -- quarantine bookkeeping --------------------------------------------

    def _quarantine(self, win: np.ndarray, cause: BaseException, kind: str) -> WindowQuarantined:
        ids = np.asarray(win, np.int64).ravel()
        with self._lock:
            self._pending.append((ids, kind))
            self.windows_quarantined += 1
            self.blocks_quarantined += int(ids.size)
        logger.warning(
            "quarantining window of %d blocks (%s): %r", ids.size, kind, cause
        )
        if self.telemetry is not None:
            self._c_quarantined.inc(int(ids.size))
            self.telemetry.tracer.emit(
                "window_quarantine", blocks=int(ids.size), why=kind,
                cause=repr(cause),
            )
        return WindowQuarantined(ids, cause)

    def take_quarantined(self) -> np.ndarray:
        """Drain and return the block ids quarantined since the last
        call (thread-safe — the producer may be a prefetch worker).
        Includes ids quarantined by any nested `ResilientSource`: a
        scheduler draining the outermost wrapper must see the whole
        stack's verdicts, wherever in the chain they were issued."""
        with self._lock:
            pending, self._pending = self._pending, []
        chunks = [ids for ids, _ in pending]
        nested = find_resilient(self.inner)
        if nested is not None:
            inner_ids = nested.take_quarantined()
            if inner_ids.size:
                chunks.append(inner_ids)
        if not chunks:
            return np.zeros(0, np.int64)
        return np.unique(np.concatenate(chunks))

    # -- the retry loop ----------------------------------------------------

    def _cancelled(self) -> bool:
        ev = self.cancel_event
        return ev is not None and ev.is_set()

    def _wait(self, delay: float) -> None:
        ev = self.cancel_event
        if ev is not None:
            ev.wait(delay)  # returns early when cancellation fires
        elif self._sleep is not None:
            self._sleep(delay)
        else:
            time.sleep(delay)

    def _validate(self, wd: WindowData, pad_to: Optional[int]) -> None:
        if self.validate == "off":
            return
        validate_window(
            wd, num_blocks=self.num_blocks, block_size=self.block_size,
            v_z=self.v_z, v_x=self.v_x, pad_to=pad_to, level=self.validate,
        )

    def fetch(self, win: np.ndarray, pad_to: Optional[int] = None) -> WindowData:
        win = np.asarray(win, np.int64).ravel()
        policy = self.policy
        t0 = self._clock()
        delay = policy.backoff_s
        retries = 0
        while True:
            if self._cancelled():
                raise FetchCancelled("fetch cancelled by consumer")
            try:
                wd = self.inner.fetch(win, pad_to)
            except self.TRANSIENT as exc:
                self.transient_faults += 1
                if self.telemetry is not None:
                    self._c_transient.inc(1)
                deadline_hit = (
                    policy.deadline_s is not None
                    and self._clock() - t0 >= policy.deadline_s
                )
                if retries >= policy.max_retries or deadline_hit:
                    self.permanent_faults += 1
                    if self.telemetry is not None:
                        self._c_permanent.inc(1)
                    why = "deadline" if deadline_hit else "retries-exhausted"
                    raise self._quarantine(win, exc, why) from exc
                retries += 1
                self.retries_total += 1
                if self.telemetry is not None:
                    self._c_retries.inc(1)
                jitter = 1.0 + policy.jitter * (2.0 * self._rng.random() - 1.0)
                self._wait(delay * jitter)
                delay *= policy.backoff_mult
                continue
            try:
                self._validate(wd, pad_to)
            except CorruptWindowError as exc:
                # Integrity failure is judged permanent for this window:
                # the bytes are wrong, not late — a re-read of corrupt
                # storage returns the same corruption, and one poisoned
                # ingest outlives any retry budget via the durable cache.
                self.validation_failures += 1
                self.permanent_faults += 1
                if self.telemetry is not None:
                    self._c_validation.inc(1)
                    self._c_permanent.inc(1)
                raise self._quarantine(win, exc, "validation") from exc
            return wd

    def stream(
        self, windows: Iterable[np.ndarray], pad_to: Optional[int] = None
    ) -> Iterator[WindowData]:
        """Serve each window through the resilient fetch; a quarantined
        window is skipped (its blocks are already recorded) so one bad
        window degrades coverage instead of killing the stream."""
        for win in windows:
            try:
                yield self.fetch(win, pad_to)
            except WindowQuarantined:
                continue


def find_resilient(source) -> Optional[ResilientSource]:
    """The `ResilientSource` in a wrapper chain (e.g. under a
    `PrefetchSource`), or None."""
    seen = 0
    while source is not None and seen < 8:
        if isinstance(source, ResilientSource):
            return source
        source = getattr(source, "inner", None)
        seen += 1
    return None


# --------------------------------------------------------------------------
# FASTMATCH_CHAOS: the CI chaos lane
# --------------------------------------------------------------------------


def maybe_chaos(source: BlockSource, *, env: Optional[dict] = None):
    """Wrap ``source`` in transient-only injected faults when
    ``FASTMATCH_CHAOS=1`` — the CI chaos lane.

    Only retry-heals-it faults are injected (transient errors + short
    stalls, generous retry budget), so every serve run under chaos must
    stay bit-identical to the fault-free run: retried fetches re-read
    the same immutable blocks. Any behavioral difference under this
    flag is therefore a real fault-handling bug, which is exactly what
    the lane exists to catch. ``FASTMATCH_CHAOS_SEED`` varies the
    schedule without touching the test matrix.
    """
    import os

    e = os.environ if env is None else env
    if e.get("FASTMATCH_CHAOS", "0") != "1":
        return source
    seed = int(e.get("FASTMATCH_CHAOS_SEED", "0"))
    plan = FaultPlan(p_transient=0.05, p_stall=0.01, stall_s=0.001)
    return ResilientSource(
        FaultySource(source, plan, seed=seed),
        policy=RetryPolicy(max_retries=16, backoff_s=0.001, seed=seed),
    )
