"""Model/arch configuration schema and the assigned input-shape grid.

Every assigned architecture has a module ``repro.configs.<arch_id>``
exposing ``full_config()`` (the exact published dimensions) and
``smoke_config()`` (a reduced same-family config for CPU smoke tests).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Tuple

__all__ = ["ModelConfig", "Shape", "SHAPES", "get_config", "get_smoke_config", "list_archs"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---
    num_experts: int = 0  # 0 = dense FFN
    experts_per_token: int = 2
    expert_capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    moe_impl: str = "gather"  # gather (baseline) | local (shard-local dispatch, §Perf)

    # --- attention pattern ---
    sliding_window: int = 0  # 0 = full; > 0 = sliding-window attention
    attn_chunk: int = 1024  # KV-chunk size for the online-softmax path
    attn_impl: str = "auto"  # auto | direct | chunked
    decode_seq_shard: bool = False  # flash-decoding cache layout (§Perf opt)
    attn_gqa_grouped: bool = False  # grouped-GQA einsum, no kv repeat (§Perf opt)

    # --- hybrid (recurrentgemma) ---
    # pattern of temporal-mixing blocks, cycled over layers:
    # "a"=attention (local), "r"=RG-LRU recurrent
    block_pattern: str = ""  # "" = all attention
    lru_width: int = 0  # 0 -> d_model
    conv_width: int = 4
    local_window: int = 2048

    # --- xLSTM ---
    slstm_every: int = 0  # 0 = no sLSTM blocks; else 1 sLSTM per N blocks
    mlstm_chunk: int = 128
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.3333

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0  # 0 = decoder-only
    encoder_seq: int = 1500  # stub conv frontend output frames
    frontend: str = "none"  # none | audio_stub | vision_stub

    # --- vlm ---
    vision_tokens: int = 0  # prefix positions fed from the vision stub

    # --- numerics / execution ---
    dtype: str = "bfloat16"
    scan_layers: bool = False  # scan for production training; unrolled dry-run
    remat: str = "none"  # none | full | dots
    optimizer: str = "adamw"  # adamw | adafactor

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run 500k-token contexts? (SSM / hybrid w/ local attn)"""
        if self.family == "ssm":
            return True
        if self.family == "hybrid" and "r" in self.block_pattern:
            return True
        return False

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode path (whisper = enc-dec)

    @property
    def param_count(self) -> int:
        """Analytic parameter count (embedding + per-layer weights)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        attn = q + kv + o
        if self.num_experts > 0:
            ffn = self.num_experts * 3 * d * f + d * self.num_experts  # experts + router
        elif self.family == "ssm":
            pf = self.proj_factor_mlstm
            ffn = int(2 * d * pf * d + 4 * (pf * d) * hd)  # rough mLSTM block
        else:
            ffn = 3 * d * f  # SwiGLU/GeGLU
        layers = self.num_layers * (attn + ffn + 2 * d)
        if self.encoder_layers:
            layers += self.encoder_layers * (attn + ffn + 2 * d)
        emb = v * d * (1 if self.tie_embeddings else 2)
        return emb + layers

    @property
    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if self.num_experts == 0:
            return self.param_count
        d, f = self.d_model, self.d_ff
        inactive = (self.num_experts - self.experts_per_token) * 3 * d * f
        return self.param_count - self.num_layers * inactive


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = (
    "internvl2_76b",
    "qwen2_5_3b",
    "granite_8b",
    "llama3_405b",
    "codeqwen1_5_7b",
    "recurrentgemma_2b",
    "mixtral_8x7b",
    "grok_1_314b",
    "xlstm_125m",
    "whisper_medium",
)

# CLI aliases (the assignment's hyphenated ids).
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
ALIASES.update({a: a for a in ARCH_IDS})
ALIASES.update({
    "internvl2-76b": "internvl2_76b",
    "qwen2.5-3b": "qwen2_5_3b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "grok-1-314b": "grok_1_314b",
})


def list_archs() -> Tuple[str, ...]:
    return ARCH_IDS


def _module(arch: str):
    arch = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{arch}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).full_config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()
