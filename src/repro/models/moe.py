"""Top-k token-choice Mixture-of-Experts FFN (Mixtral / Grok-1 style).

TPU-native dispatch: tokens are routed to per-expert capacity-bounded
buffers via cumulative-sum slotting (no data-dependent shapes), experts
run as one batched einsum over the expert dimension, and results are
combined with routing weights. Capacity factor > 1 keeps drops rare;
dropped tokens pass through the residual stream untouched (standard
practice). Router runs in f32 with an optional z-loss for stability.

Sharding: expert weights (E, d, f) are FSDP-sharded on d and TP-sharded
on f; the expert dim stays local so the dispatch is a gather, not an
all-to-all (at E=8 << chips, expert-dim sharding would idle most chips;
see DESIGN.md). Aux losses (load balance, z-loss) are returned for the
train step.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init, shard

__all__ = ["init_moe", "moe_ffn"]


def init_moe(key, d_model: int, d_ff: int, num_experts: int, dtype) -> dict:
    kr, kg, ku, kd = jax.random.split(key, 4)
    e = num_experts
    return {
        "router": dense_init(kr, (d_model, e), jnp.float32),
        "w_gate": dense_init(kg, (e, d_model, d_ff), dtype),
        "w_up": dense_init(ku, (e, d_model, d_ff), dtype),
        "w_down": dense_init(kd, (e, d_ff, d_model), dtype),
    }


def moe_ffn(
    params: dict,
    x: jax.Array,
    *,
    num_experts: int,
    top_k: int,
    capacity_factor: float,
) -> Tuple[jax.Array, dict]:
    """x: (B, S, D) -> (out (B,S,D), aux {load_balance_loss, router_z_loss, drop_frac})."""
    b, s, d = x.shape
    t = b * s
    e = num_experts
    xt = x.reshape(t, d)

    # --- router (f32) ---
    logits = jnp.dot(xt.astype(jnp.float32), params["router"])  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, top_k)  # (T, K)
    weights = weights / jnp.maximum(jnp.sum(weights, axis=-1, keepdims=True), 1e-9)

    # --- aux losses ---
    # load balance (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)  # (E,)
    assign1 = jax.nn.one_hot(experts[:, 0], e, dtype=jnp.float32)
    fe = jnp.mean(assign1, axis=0)
    load_balance = e * jnp.sum(fe * me)
    z = jax.nn.logsumexp(logits, axis=-1)
    z_loss = jnp.mean(z * z)

    # --- capacity slotting ---
    capacity = int(max(1, round(t * top_k / e * capacity_factor)))
    # flatten (token, k) pairs, expert-major position via cumsum
    flat_expert = experts.reshape(-1)  # (T*K,)
    onehot = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)  # (T*K, E)
    pos_in_expert = jnp.cumsum(onehot, axis=0) - onehot  # exclusive cumsum
    pos = jnp.sum(pos_in_expert * onehot, axis=1)  # (T*K,)
    keep = pos < capacity
    slot = flat_expert * capacity + pos  # (T*K,) in [0, E*capacity)
    slot = jnp.where(keep, slot, e * capacity)  # overflow slot dropped below

    token_of_pair = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)
    # buffer of token ids per slot; final extra slot swallows drops
    slot_token = jnp.full((e * capacity + 1,), 0, jnp.int32).at[slot].set(token_of_pair)
    slot_used = jnp.zeros((e * capacity + 1,), bool).at[slot].set(keep)
    slot_token = jnp.where(slot_used, slot_token, 0)

    xe = xt[slot_token[:-1]]  # (E*C, D) gather
    xe = xe * slot_used[:-1, None].astype(xe.dtype)
    xe = xe.reshape(e, capacity, d)
    xe = shard(xe, "expert", None, "embed")

    # --- expert FFN (batched einsum over E) ---
    from repro.models.layers import _out_proj_dtype, boundary_cast

    g = boundary_cast(
        jnp.einsum("ecd,edf->ecf", xe, params["w_gate"], preferred_element_type=jnp.float32),
        x.dtype,
    )
    u = boundary_cast(
        jnp.einsum("ecd,edf->ecf", xe, params["w_up"], preferred_element_type=jnp.float32),
        x.dtype,
    )
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    h = shard(h, "expert", None, "ff")
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"], preferred_element_type=_out_proj_dtype())
    ye = ye.reshape(e * capacity, d)

    # --- combine: scatter-add back with routing weights ---
    pair_w = jnp.where(keep, weights.reshape(-1), 0.0)  # (T*K,)
    # map each kept pair to its slot's output row
    safe_slot = jnp.minimum(slot, e * capacity - 1)
    y_pair = ye[safe_slot] * keep[:, None]
    out = jnp.zeros((t, d), jnp.float32).at[token_of_pair].add(y_pair * pair_w[:, None])

    drop_frac = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux = {"load_balance_loss": load_balance, "router_z_loss": z_loss, "drop_frac": drop_frac}
    return out.astype(x.dtype).reshape(b, s, d), aux


def moe_ffn_local(
    params: dict,
    x: jax.Array,
    *,
    num_experts: int,
    top_k: int,
    capacity_factor: float,
) -> Tuple[jax.Array, dict]:
    """Shard-local MoE dispatch (§Perf optimization, 'moe_impl=local').

    The baseline `moe_ffn` gathers tokens by data-dependent slot indices;
    under SPMD the partitioner cannot prove the gather is shard-local, so
    it ALL-GATHERS the full (T, D) token buffer per layer per direction
    (measured: ~0.5 GB/layer at mixtral train_4k — the dominant collective
    of every MoE cell). Here the dispatch/combine runs inside shard_map
    over the data axes: every token is slotted into ITS OWN shard's
    capacity buffers, so no token ever crosses the network. Expert weights
    arrive TP-sharded on the ff dim (one FSDP all-gather per matrix, ~58MB
    — 9x less wire than the token gather) and the down-projection's
    contraction over ff is completed with a single psum over "model".

    Trade-off vs the baseline: capacity is enforced per shard (drops
    depend on the local token mix, like per-worker capacity in production
    EP systems); routing weights are identical.
    """
    from jax.sharding import PartitionSpec as P

    from repro.models import layers as L

    mesh = L._ACTIVE_MESH
    if mesh is None:  # no mesh (CPU smoke) -> identical math, one shard
        return moe_ffn(
            params, x,
            num_experts=num_experts, top_k=top_k, capacity_factor=capacity_factor,
        )
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tp = "model" if "model" in mesh.axis_names else None

    def inner(router, wg, wu, wd, xl):
        out, aux = _moe_core(
            {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd},
            xl,
            num_experts=num_experts,
            top_k=top_k,
            capacity_factor=capacity_factor,
        )
        if tp is not None:
            out = jax.lax.psum(out, tp)  # complete the ff contraction
        if dp:
            aux = {k: jax.lax.pmean(v, dp) for k, v in aux.items()}
        return out, aux

    b = x.shape[0]
    dp_ok = dp and b % int(np.prod([mesh.shape[a] for a in dp])) == 0
    x_spec = P(dp if dp_ok else None, None, None)
    fspec = P(None, None, tp)  # (E, D, F) — ff TP-sharded, D replicated
    dspec = P(None, tp, None)  # (E, F, D)
    out_specs = (x_spec, {k: P() for k in ("load_balance_loss", "router_z_loss", "drop_frac")})
    from repro.core.distributed import shard_map_compat

    fn = shard_map_compat(
        inner,
        mesh,
        in_specs=(P(None, None), fspec, fspec, dspec, x_spec),
        out_specs=out_specs,
    )
    return fn(params["router"], params["w_gate"], params["w_up"], params["w_down"], x)


def _moe_core(params, x, *, num_experts, top_k, capacity_factor):
    """The dispatch/compute/combine body shared by local mode.

    Identical math to moe_ffn but with the down-projection left PARTIAL
    over the ff dimension (caller completes it with psum when TP-sharded)
    and sharding constraints disabled (we are inside a manual region).
    """
    from repro.models.layers import manual_mode

    with manual_mode():
        return moe_ffn(
            params, x,
            num_experts=num_experts, top_k=top_k, capacity_factor=capacity_factor,
        )
