"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finiteness; decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, list_archs
from repro.models.model_zoo import get_model
from repro.models.transformer import embed_tokens
from repro.optimizer import get_optimizer
from repro.train import TrainState, make_train_step

ARCHS = list_archs()
B, S = 2, 32


def _extras(model, params, tokens, rng):
    cfg = model.cfg
    if cfg.frontend == "vision_stub":
        return {"vision_embeds": embed_tokens(params, tokens[:, : cfg.vision_tokens], cfg)}
    if cfg.frontend == "audio_stub":
        return {
            "encoder_frames": jax.random.normal(
                rng, (tokens.shape[0], cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype)
            )
            * 0.02
        }
    return {}


@pytest.fixture(scope="module")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_shapes_finite(self, arch, rng_key):
        cfg = get_smoke_config(arch)
        model = get_model(cfg)
        params = model.init(rng_key)
        tokens = jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)
        logits, aux = model.forward(params, tokens, **_extras(model, params, tokens, rng_key))
        assert logits.shape == (B, S, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_train_step(self, arch, rng_key):
        cfg = get_smoke_config(arch)
        model = get_model(cfg)
        params = model.init(rng_key)
        optimizer = get_optimizer(cfg.optimizer, 1e-3)
        state = TrainState.create(params, optimizer)
        step = jax.jit(make_train_step(model, optimizer))
        tokens = jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)
        batch = {"tokens": tokens, **_extras(model, params, tokens, rng_key)}
        state, metrics = step(state, batch)
        assert bool(jnp.isfinite(metrics["loss"]))
        assert int(state.step) == 1
        assert float(metrics["step_ok"]) == 1.0

    def test_decode_consistency(self, arch, rng_key):
        """prefill(first half) + decode(second half) == teacher-forced fwd."""
        cfg = get_smoke_config(arch)
        model = get_model(cfg)
        params = model.init(rng_key)
        tokens = jax.random.randint(rng_key, (B, 16), 0, cfg.vocab_size)
        extras = _extras(model, params, tokens, rng_key)
        full, _ = model.forward(params, tokens, **extras)
        pf_extras = {k: v for k, v in extras.items() if k == "encoder_frames"}
        lg, cache = model.prefill(params, tokens[:, :8], 16, **pf_extras)
        np.testing.assert_allclose(
            np.asarray(full[:, :8], np.float32), np.asarray(lg[:, :8], np.float32), atol=0.06
        )
        outs = []
        for t in range(8, 16):
            step_lg, cache = model.decode_step(params, cache, tokens[:, t])
            outs.append(step_lg)
        dec = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(full[:, 8:], np.float32), np.asarray(dec, np.float32), atol=0.06
        )


class TestParamCounts:
    @pytest.mark.parametrize(
        "arch,expected_b",
        [
            ("llama3_405b", 405),
            ("grok_1_314b", 314),
            ("mixtral_8x7b", 46),
            ("qwen2_5_3b", 3),
            ("granite_8b", 8),
            ("internvl2_76b", 69),  # LLM backbone only (vision tower stubbed)
            ("codeqwen1_5_7b", 7),
            ("recurrentgemma_2b", 2.7),
            ("whisper_medium", 0.76),
            ("xlstm_125m", 0.125),
        ],
    )
    def test_analytic_param_count(self, arch, expected_b):
        cfg = get_config(arch)
        got = cfg.param_count / 1e9
        assert got == pytest.approx(expected_b, rel=0.30), got

    def test_moe_active_smaller(self):
        cfg = get_config("mixtral_8x7b")
        assert cfg.active_param_count < cfg.param_count / 2


class TestScanLayers:
    def test_scan_equals_unrolled(self, rng_key):
        import dataclasses

        cfg = get_smoke_config("granite_8b")
        model_u = get_model(cfg)
        params_u = model_u.init(rng_key)
        cfg_s = dataclasses.replace(cfg, scan_layers=True)
        model_s = get_model(cfg_s)
        params_s = model_s.init(rng_key)  # same rng -> same stacked weights
        tokens = jax.random.randint(rng_key, (2, 16), 0, cfg.vocab_size)
        lu, _ = model_u.forward(params_u, tokens)
        ls, _ = model_s.forward(params_s, tokens)
        np.testing.assert_allclose(
            np.asarray(lu, np.float32), np.asarray(ls, np.float32), atol=0.05
        )


class TestLongContextArchs:
    def test_sub_quadratic_flags(self):
        assert get_config("recurrentgemma_2b").sub_quadratic
        assert get_config("xlstm_125m").sub_quadratic
        for a in ("llama3_405b", "qwen2_5_3b", "mixtral_8x7b", "whisper_medium"):
            assert not get_config(a).sub_quadratic

    def test_hybrid_cache_is_windowed(self):
        """recurrentgemma decode memory must be O(window), not O(seq)."""
        cfg = get_smoke_config("recurrentgemma_2b")
        model = get_model(cfg)
        cache = jax.eval_shape(lambda: model.init_cache(1, 8192))
        max_kv = max(
            (leaf.shape[1] for leaf in jax.tree.leaves(cache)
             if hasattr(leaf, "shape") and len(leaf.shape) == 4),
            default=0,
        )
        assert max_kv <= cfg.local_window
