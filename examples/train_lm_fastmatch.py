"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
FastMatch distribution-matched data selection in the input pipeline.

Uses the xlstm-125m assigned architecture at full width (12 layers,
d_model 768) with a reduced vocab so the run fits a CPU box; the data
pipeline first runs the paper's engine to pick the corpus domains whose
token distribution matches a reference mix, then streams batches only
from those domains.

  PYTHONPATH=src python examples/train_lm_fastmatch.py --steps 200
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.data.corpus import CorpusSpec, make_corpus
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--ckpt-dir", type=str, default="/tmp/repro_ckpt")
    args = ap.parse_args()

    # xlstm-125m at full depth/width; vocab reduced for the CPU demo
    cfg = dataclasses.replace(get_config("xlstm_125m"), vocab_size=args.vocab)
    n_params = cfg.param_count
    print(f"arch=xlstm_125m layers={cfg.num_layers} d_model={cfg.d_model} "
          f"~{n_params/1e6:.0f}M params (vocab reduced to {args.vocab})")

    corpus = make_corpus(
        CorpusSpec(
            num_domains=64, num_buckets=128, vocab_size=args.vocab,
            num_blocks=2048, block_tokens=2048, n_reference=8,
            reference_alpha=0.15, seed=0,
        )
    )
    out = train_loop(
        cfg=cfg,
        steps=args.steps,
        batch_size=args.batch,
        seq_len=args.seq,
        lr=3e-4,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=100,
        corpus=corpus,
        select_k=8,
    )
    print(f"\nfinal loss {out['final_loss']:.4f} after {args.steps} steps")
    print(f"checkpoints in {args.ckpt_dir} (auto-resume on rerun)")


if __name__ == "__main__":
    main()
