"""Packed bitmap index: build/query/pack/unpack properties."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need it; skip on minimal installs
from hypothesis import given, settings, strategies as st

from repro.core import bitmap


class TestPackUnpack:
    @given(seed=st.integers(0, 500), v_z=st.integers(1, 300))
    @settings(deadline=None, max_examples=100)
    def test_roundtrip(self, seed, v_z):
        rng = np.random.default_rng(seed)
        active = rng.random(v_z) < 0.3
        words = bitmap.pack_active_mask(jnp.asarray(active))
        back = np.asarray(bitmap.unpack_mask(words, v_z))
        np.testing.assert_array_equal(back, active)

    def test_words_for(self):
        assert bitmap.words_for(1) == 1
        assert bitmap.words_for(32) == 1
        assert bitmap.words_for(33) == 2
        assert bitmap.words_for(7548) == 236


class TestBuildBitmap:
    @given(seed=st.integers(0, 200))
    @settings(deadline=None, max_examples=50)
    def test_presence_semantics(self, seed):
        rng = np.random.default_rng(seed)
        nb, bs, v_z = 20, 16, 50
        z = rng.integers(-1, v_z, size=(nb, bs)).astype(np.int32)
        bm = bitmap.build_block_bitmap(z, v_z)
        assert bm.shape == (nb, bitmap.words_for(v_z))
        for b in range(nb):
            present = np.asarray(bitmap.unpack_mask(jnp.asarray(bm[b]), v_z))
            expected = np.zeros(v_z, bool)
            vals = z[b][(z[b] >= 0) & (z[b] < v_z)]
            expected[vals] = True
            np.testing.assert_array_equal(present, expected)

    def test_padding_ignored(self):
        z = np.full((3, 8), -1, np.int32)
        bm = bitmap.build_block_bitmap(z, 40)
        assert (bm == 0).all()

    def test_anyactive_consistency(self):
        """bitmap AND active-mask must equal per-block set intersection."""
        rng = np.random.default_rng(3)
        nb, bs, v_z = 50, 32, 100
        z = rng.integers(0, v_z, size=(nb, bs)).astype(np.int32)
        bm = bitmap.build_block_bitmap(z, v_z)
        active = rng.random(v_z) < 0.1
        words = bitmap.pack_active_mask(jnp.asarray(active))
        from repro.kernels import ref

        marks = np.asarray(ref.anyactive_ref(jnp.asarray(bm), words))
        for b in range(nb):
            expect = bool(np.intersect1d(z[b], np.where(active)[0]).size)
            assert marks[b] == expect
