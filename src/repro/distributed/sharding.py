"""Parameter/activation sharding rules (DP + FSDP + TP, optional pod DP).

Policy (MaxText-flavored):
  * activations: batch over ("pod","data"); model-parallel dims over "model"
  * weights: FSDP-shard the d_model-like dim over "data", TP-shard the
    heads/ff/vocab-like dim over "model" (Megatron layout)
  * MoE experts: expert dim local, (d_model -> "data", d_ff -> "model")
  * norms / biases / small tables: replicated (or TP where they align
    with a TP-sharded matmul output)

Every rule is divisibility-guarded: if a dim does not divide the mesh
axis size (e.g. whisper's 51865 vocab over 16-way TP, or batch 1 on the
500k-context decode), that dim falls back to replicated instead of
erroring — the dry-run surfaces the fallback in its report.

The name->rule table keys on parameter leaf names (and parent names for
disambiguation). Anything unmatched is replicated — visible in dry-run
output, so silent mis-sharding of a new layer type gets caught.
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

__all__ = [
    "param_pspecs",
    "param_shardings",
    "batch_pspec",
    "guard_pspec",
    "data_axes",
    "cache_pspecs",
]


def data_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def guard_pspec(shape, spec: P, mesh: Mesh) -> P:
    """Drop spec entries whose dim is not divisible by the mesh axes."""
    out = []
    for i, axes in enumerate(spec):
        if axes is None:
            out.append(None)
            continue
        present = axes if isinstance(axes, tuple) else (axes,)
        present = tuple(a for a in present if a in mesh.axis_names)
        if not present:
            out.append(None)
            continue
        size = _axis_size(mesh, present)
        if i < len(shape) and shape[i] % size == 0 and shape[i] > 0:
            out.append(present if len(present) > 1 else present[0])
        else:
            out.append(None)
    out += [None] * (len(shape) - len(out))
    return P(*out[: len(shape)])


# (parent_hint, name) -> logical spec builder by ndim. None parent = any.
# Conventions: "D"=d_model-like (FSDP/"data"), "T"=TP/"model", "-"=replicated.
_RULES = [
    # embeddings / unembeddings
    ("embed", "table", ("T", "D")),  # (vocab, d): vocab TP, d FSDP
    ("lm_head", "w", ("D", "T")),
    (None, "dec_pos", ("-", "-")),
    # attention
    (None, "wq", ("D", "T")),
    (None, "wk", ("D", "T")),
    (None, "wv", ("D", "T")),
    (None, "wo", ("T", "D")),
    (None, "bq", ("T",)),
    (None, "bk", ("T",)),
    (None, "bv", ("T",)),
    # dense MLPs
    (None, "w_gate", ("D", "T")),
    (None, "w_up", ("D", "T")),
    (None, "w_down", ("T", "D")),
    (None, "b_up", ("T",)),
    (None, "b_down", ("-",)),
    # MoE (3D expert weights) — expert dim local
    ("moe", "w_gate", ("-", "D", "T")),
    ("moe", "w_up", ("-", "D", "T")),
    ("moe", "w_down", ("-", "T", "D")),
    ("moe", "router", ("D", "-")),
    # RG-LRU
    (None, "w_in", ("D", "T")),
    (None, "w_gate_branch", ("D", "T")),
    (None, "conv_w", ("-", "T")),
    (None, "conv_b", ("T",)),
    (None, "w_a", ("D", "T")),
    (None, "w_x", ("D", "T")),
    (None, "b_a", ("T",)),
    (None, "b_x", ("T",)),
    (None, "lam", ("T",)),
    (None, "w_out", ("T", "D")),
    # xLSTM
    (None, "w_if", ("D", "-")),
    (None, "w_gates", ("D", "T")),
    (None, "r_gates", ("-", "T", "-", "-")),
    (None, "b_gates", ("-",)),
    (None, "w_ff_gate", ("D", "T")),
    (None, "w_ff_up", ("D", "T")),
    (None, "w_ff_down", ("T", "D")),
]

_LOGICAL = {"D": "data", "T": "model", "-": None}


def _path_names(path) -> list:
    names = []
    for k in path:
        if isinstance(k, DictKey):
            names.append(str(k.key))
        elif isinstance(k, SequenceKey):
            names.append(f"[{k.idx}]")
    return names


def _match(names: list, shape) -> Optional[tuple]:
    leaf = names[-1] if names else ""
    parent = names[-2] if len(names) >= 2 else ""
    best = None
    for hint, name, spec in _RULES:
        if name != leaf:
            continue
        if hint is not None and hint != parent:
            continue
        if len(spec) != len(shape):
            continue
        if hint is not None:
            return spec  # exact parent match wins immediately
        best = best or spec
    return best


def _resolve(spec_letters, mesh: Mesh) -> P:
    axes = []
    for s in spec_letters:
        logical = _LOGICAL[s]
        if logical is None:
            axes.append(None)
        elif logical == "data":
            axes.append("data" if "data" in mesh.axis_names else None)
        else:
            axes.append("model" if "model" in mesh.axis_names else None)
    return P(*axes)


def serving_param_pspecs(params, mesh: Mesh):
    """TP-only parameter sharding for serving (§Perf optimization).

    Training uses FSDP("data") x TP("model"): every matmul all-gathers its
    weight shards, amortized over the giant per-step compute. At decode,
    per-step compute is 2*N*B FLOPs — the FSDP all-gather of the FULL
    weight matrix per layer per token dominates everything (measured: the
    baseline llama3-405b decode cell is collective-bound at ~7 s/step of
    wire time). Serving therefore shards weights over "model" ONLY and
    replicates over "data"; weight movement per step drops to zero and
    the only collectives left are the small activation reductions of TP.
    """
    base = param_pspecs(params, mesh)

    def strip_data(path, spec, leaf):
        entries = [
            None if ax == "data" or (isinstance(ax, tuple) and "data" in ax) else ax
            for ax in spec
        ]
        return guard_pspec(np.shape(leaf), P(*entries), mesh)

    return jax.tree_util.tree_map_with_path(
        lambda path, spec, leaf: strip_data(path, spec, leaf), base, params
    )


def param_pspecs(params, mesh: Mesh):
    """Tree of PartitionSpecs matching the params tree."""

    def per_leaf(path, leaf):
        names = _path_names(path)
        shape = np.shape(leaf)
        if len(shape) <= 0:
            return P()
        m = _match(names, shape)
        if m is not None:
            return guard_pspec(shape, _resolve(m, mesh), mesh)
        # scan-stacked layer weights: (num_layers, *param_shape) — match the
        # tail and keep the stack dim unsharded.
        if len(shape) >= 2:
            m = _match(names, shape[1:])
            if m is not None:
                spec = _resolve(m, mesh)
                return guard_pspec(shape, P(None, *spec), mesh)
        # norms / scalars / unknown: replicate
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(per_leaf, params)


def param_shardings(params, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), param_pspecs(params, mesh))


def batch_pspec(mesh: Mesh, batch_size: int, ndim: int = 2) -> P:
    """Batch sharded over ("pod","data") when divisible, else replicated."""
    axes = data_axes(mesh)
    if not axes or batch_size % _axis_size(mesh, axes) != 0:
        # try "data" alone (pod replicated)
        if "data" in mesh.axis_names and batch_size % mesh.shape["data"] == 0:
            axes = ("data",)
        else:
            axes = ()
    lead = axes if len(axes) > 1 else (axes[0] if axes else None)
    return P(lead, *([None] * (ndim - 1)))


def cache_pspecs(cache, mesh: Mesh, batch_size: int, *, seq_shard: bool = False):
    """KV caches: batch over data axes; head or sequence dim over model.

    seq_shard=False (baseline): kv-head dim over "model" where divisible.
    GQA archs with Hkv < |model| (llama 8 < 16) cannot shard it, and the
    SPMD partitioner then ALL-GATHERS the full cache in f32 every decode
    step — measured 4 x 1 GiB per layer on llama3-405b decode_32k, the
    dominant collective of every baseline decode cell.

    seq_shard=True (§Perf "opt" profile): shard the SEQUENCE dim over
    "model" (flash-decoding): the q.K and p.V contractions partition over
    the 32k cache length, leaving only softmax-stat and output partial
    all-reduces (KBs, not GBs) on the wire. Works for every Hkv.
    """

    def per_leaf(leaf):
        shape = np.shape(leaf)
        if len(shape) == 0:
            return P()
        if len(shape) == 4:  # (B, S, Hkv, hd)
            if seq_shard:
                spec = P(batch_pspec(mesh, batch_size, 1)[0], "model", None, None)
            else:
                spec = P(batch_pspec(mesh, batch_size, 1)[0], None, "model", None)
        elif len(shape) >= 2:
            spec = P(batch_pspec(mesh, batch_size, 1)[0], *([None] * (len(shape) - 1)))
        else:
            spec = P(None)
        return guard_pspec(shape, spec, mesh)

    return jax.tree.map(per_leaf, cache)
