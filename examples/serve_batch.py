"""Batched serving example: prefill + decode over a request queue.

Serves a reduced qwen2.5-family model with the ServeEngine (the component
the decode_32k dry-run shape lowers at production scale).

  PYTHONPATH=src python examples/serve_batch.py
"""

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.model_zoo import get_model
from repro.serve import Request, ServeEngine


def main():
    cfg = get_smoke_config("qwen2_5_3b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    eng = ServeEngine(model, params, slots=8, max_len=128)
    rng = np.random.default_rng(0)
    n_requests = 24
    for i in range(n_requests):
        eng.submit(
            Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 32))).astype(np.int32),
                max_new_tokens=16,
            )
        )
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    print(f"served {len(done)} requests in {dt:.2f}s")
    print(f"prefills={eng.metrics['prefills']} decode_ticks={eng.metrics['decode_ticks']} "
          f"tokens_out={eng.metrics['tokens_out']} ({eng.metrics['tokens_out']/dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.output}")


if __name__ == "__main__":
    main()
