"""qwen2.5-3b [dense] — GQA with QKV bias [hf:Qwen/Qwen2.5-*; hf]."""

from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen2_5_3b",
        family="dense",
        num_layers=36,
        d_model=2048,
        num_heads=16,
        num_kv_heads=2,
        d_ff=11008,
        vocab_size=151936,
        qkv_bias=True,
        rope_theta=1e6,
        norm_eps=1e-6,
        optimizer="adamw",
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen2_5_3b_smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        qkv_bias=True,
        rope_theta=1e6,
        norm_eps=1e-6,
    )
