"""RecurrentGemma-style hybrid: RG-LRU recurrent blocks + local attention.

Block pattern (config.block_pattern, default "rra"): two RG-LRU
recurrence blocks followed by one local (sliding-window) MQA attention
block, cycled over layers — the Griffin 2:1 temporal-mixing pattern.

RG-LRU (Real-Gated Linear Recurrent Unit, De et al. 2024):
    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    a_t = exp(c * softplus(Lambda) * (-r_t))   per-channel decay in (0,1)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is evaluated with `jax.lax.associative_scan` for
training/prefill (log-depth on TPU) and as a single fused step for
decode — O(1) state, which is why this arch runs the 500k-context shape.
A short depthwise temporal conv (width 4) precedes the RG-LRU, as in
the paper.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.layers import AttnSpec, shard

__all__ = [
    "init_params",
    "forward",
    "init_cache",
    "prefill",
    "decode_step",
    "block_kind",
]

_C = 8.0  # RG-LRU decay sharpness constant (Griffin)


def block_kind(cfg: ModelConfig, layer_idx: int) -> str:
    pattern = cfg.block_pattern or "a"
    return {"r": "recurrent", "a": "attention"}[pattern[layer_idx % len(pattern)]]


def _lru_width(cfg: ModelConfig) -> int:
    return cfg.lru_width or cfg.d_model


def _attn_spec(cfg: ModelConfig) -> AttnSpec:
    return AttnSpec(
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim,
        causal=True,
        sliding_window=cfg.local_window,
        chunk=cfg.attn_chunk,
        impl=cfg.attn_impl,
    )


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_recurrent_block(key, cfg: ModelConfig, dt) -> dict:
    d, w = cfg.d_model, _lru_width(cfg)
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {
        "w_in": L.dense_init(k1, (d, w), dt),  # branch input proj
        "w_gate_branch": L.dense_init(k2, (d, w), dt),  # GeLU gating branch
        "conv_w": (jax.random.normal(k3, (cfg.conv_width, w), jnp.float32) * 0.02).astype(dt),
        "conv_b": jnp.zeros((w,), dt),
        "w_a": L.dense_init(k4, (w, w), dt),  # recurrence gate
        "b_a": jnp.zeros((w,), dt),
        "w_x": L.dense_init(k5, (w, w), dt),  # input gate
        "b_x": jnp.zeros((w,), dt),
        "lam": jnp.full((w,), 2.0, jnp.float32),  # softplus(2)≈2.1 -> slow decay
        "w_out": L.dense_init(k6, (w, d), dt),
    }


def init_layer(key, cfg: ModelConfig, layer_idx: int) -> dict:
    dt = jnp.dtype(cfg.dtype)
    ka, km = jax.random.split(key)
    p = {
        "temporal_norm": L.init_rmsnorm(cfg.d_model, dt),
        "mlp_norm": L.init_rmsnorm(cfg.d_model, dt),
        "mlp": L.init_mlp(km, cfg.d_model, cfg.d_ff, dt),  # GeGLU applied below
    }
    if block_kind(cfg, layer_idx) == "attention":
        p["attn"] = L.init_attention(ka, cfg.d_model, _attn_spec(cfg), dt, False)
    else:
        p["rglru"] = init_recurrent_block(ka, cfg, dt)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, cfg.num_layers + 2)
    dt = jnp.dtype(cfg.dtype)
    return {
        "embed": {"table": L.embed_init(keys[0], (cfg.vocab_size, cfg.d_model), dt)},
        "final_norm": L.init_rmsnorm(cfg.d_model, dt),
        "layers": [init_layer(keys[i + 1], cfg, i) for i in range(cfg.num_layers)],
        # RecurrentGemma ties embeddings (2B model); keep a separate head
        # only if config says so.
    }


# ---------------------------------------------------------------------------
# RG-LRU core
# ---------------------------------------------------------------------------

def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, state: Optional[jax.Array] = None):
    """Depthwise causal temporal conv. x: (B,S,W); w: (K,W).

    With `state` (B, K-1, W) this is the streaming form (decode): returns
    (y, new_state). Without, the full-sequence form with left padding.
    """
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
        y = sum(
            xp[:, i : i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
            for i in range(k)
        )
        return y + b.astype(x.dtype), None
    xs = jnp.concatenate([state, x], axis=1)  # (B, K-1+1, W)
    y = sum(xs[:, i : i + 1, :] * w[i][None, None, :].astype(x.dtype) for i in range(k))
    return y + b.astype(x.dtype), xs[:, 1:, :]


def _rg_lru_scan(x: jax.Array, a: jax.Array, h0: Optional[jax.Array] = None):
    """h_t = a_t h_{t-1} + x_t via associative scan over seq. (B,S,W) f32."""
    if h0 is not None:
        # fold initial state into the first input
        x = x.at[:, 0, :].add(a[:, 0, :] * h0)
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(combine, (a, x), axis=1)
    return h


def rg_lru_block(p: dict, x: jax.Array, *, decode_state=None):
    """The full recurrent temporal-mixing block.

    train/prefill: decode_state=None -> returns (y, (h_last, conv_state)).
    decode: decode_state=(h, conv_state), x is (B,1,D) -> (y, new_state).
    """
    dt = x.dtype
    branch = jnp.dot(x, p["w_in"], preferred_element_type=jnp.float32).astype(dt)
    gate = jnp.dot(x, p["w_gate_branch"], preferred_element_type=jnp.float32)
    gate = jax.nn.gelu(gate).astype(dt)

    if decode_state is None:
        u, _ = _causal_conv(branch, p["conv_w"], p["conv_b"])
        conv_tail = branch[:, -(p["conv_w"].shape[0] - 1) :, :]
        h_prev = None
    else:
        h_prev, conv_state = decode_state
        u, conv_tail = _causal_conv(branch, p["conv_w"], p["conv_b"], conv_state)

    r = jax.nn.sigmoid(
        jnp.dot(u, p["w_a"], preferred_element_type=jnp.float32) + p["b_a"].astype(jnp.float32)
    )
    i = jax.nn.sigmoid(
        jnp.dot(u, p["w_x"], preferred_element_type=jnp.float32) + p["b_x"].astype(jnp.float32)
    )
    log_a = -_C * jax.nn.softplus(p["lam"]) * r  # (B,S,W) f32, <= 0
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u.astype(jnp.float32))

    if decode_state is None:
        h = _rg_lru_scan(gated_in, a)
        new_state = (h[:, -1, :], conv_tail)
    else:
        h = a * h_prev[:, None, :] + gated_in  # single step, (B,1,W)
        new_state = (h[:, -1, :], conv_tail)

    y = (h.astype(dt) * gate)
    y = jnp.dot(y, p["w_out"], preferred_element_type=jnp.float32).astype(dt)
    return y, new_state


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def _mlp(p, x):
    return L.mlp_geglu(p, x)


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig, **_) -> tuple:
    b, s = tokens.shape
    x = params["embed"]["table"][tokens]
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = shard(x, "batch", "seq", None)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    spec = _attn_spec(cfg)

    for li, lp in enumerate(params["layers"]):
        h = L.rms_norm(lp["temporal_norm"], x, cfg.norm_eps)
        if block_kind(cfg, li) == "attention":
            q, k, v = L.qkv_proj(lp["attn"], h, spec)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
            y = L.attention_out(lp["attn"], L.attention(q, k, v, spec, positions[0], positions[0]))
        else:
            y, _ = rg_lru_block(lp["rglru"], h)
        x = x + y
        h = L.rms_norm(lp["mlp_norm"], x, cfg.norm_eps)
        x = x + _mlp(lp["mlp"], h)
        x = shard(x, "batch", "seq", None)

    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.dot(
        x, params["embed"]["table"].T, preferred_element_type=jnp.float32
    )  # tied embeddings
    return shard(logits, "batch", "seq", "vocab"), {}


class HybridCache(NamedTuple):
    """Per-layer state: KV cache for attention layers, (h, conv) for LRU."""

    attn_k: list
    attn_v: list
    lru_h: list  # (B, W) f32 per recurrent layer (None slots for attn layers)
    conv: list  # (B, K-1, W)
    length: jax.Array


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> HybridCache:
    dt = jnp.dtype(cfg.dtype)
    w = _lru_width(cfg)
    # attention layers only cache the local window (sub-quadratic memory!)
    window = min(cfg.local_window, max_len)
    kshape = (batch, window, cfg.num_kv_heads, cfg.head_dim)
    attn_k, attn_v, lru_h, conv = [], [], [], []
    for li in range(cfg.num_layers):
        if block_kind(cfg, li) == "attention":
            attn_k.append(jnp.zeros(kshape, dt))
            attn_v.append(jnp.zeros(kshape, dt))
            lru_h.append(jnp.zeros((batch, 0), jnp.float32))
            conv.append(jnp.zeros((batch, 0, w), dt))
        else:
            attn_k.append(jnp.zeros((batch, 0, cfg.num_kv_heads, cfg.head_dim), dt))
            attn_v.append(jnp.zeros((batch, 0, cfg.num_kv_heads, cfg.head_dim), dt))
            lru_h.append(jnp.zeros((batch, w), jnp.float32))
            conv.append(jnp.zeros((batch, cfg.conv_width - 1, w), dt))
    return HybridCache(attn_k, attn_v, lru_h, conv, jnp.asarray(0, jnp.int32))


def prefill(params: dict, tokens: jax.Array, cfg: ModelConfig, max_len: int) -> tuple:
    """Prefill: full forward, capturing terminal recurrent/conv/KV state."""
    b, s = tokens.shape
    x = params["embed"]["table"][tokens] * jnp.asarray(cfg.d_model ** 0.5, jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    spec = _attn_spec(cfg)
    window = min(cfg.local_window, max_len)
    cache = init_cache(cfg, b, max_len)
    attn_k, attn_v = list(cache.attn_k), list(cache.attn_v)
    lru_h, conv = list(cache.lru_h), list(cache.conv)

    for li, lp in enumerate(params["layers"]):
        h = L.rms_norm(lp["temporal_norm"], x, cfg.norm_eps)
        if block_kind(cfg, li) == "attention":
            q, k, v = L.qkv_proj(lp["attn"], h, spec)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
            y = L.attention_out(lp["attn"], L.attention(q, k, v, spec, positions[0], positions[0]))
            # keep only the trailing window in the cache
            tail = min(window, s)
            attn_k[li] = attn_k[li].at[:, :tail].set(k[:, -tail:])
            attn_v[li] = attn_v[li].at[:, :tail].set(v[:, -tail:])
        else:
            y, (h_last, conv_tail) = rg_lru_block(lp["rglru"], h)
            lru_h[li] = h_last
            kw = cfg.conv_width - 1
            conv[li] = conv_tail[:, -kw:, :] if s >= kw else jnp.pad(
                conv_tail, ((0, 0), (kw - s, 0), (0, 0))
            )
        x = x + y
        h = L.rms_norm(lp["mlp_norm"], x, cfg.norm_eps)
        x = x + _mlp(lp["mlp"], h)

    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.dot(x, params["embed"]["table"].T, preferred_element_type=jnp.float32)
    return logits, HybridCache(attn_k, attn_v, lru_h, conv, jnp.asarray(s, jnp.int32))


def decode_step(params: dict, cache: HybridCache, token: jax.Array, cfg: ModelConfig) -> tuple:
    b = token.shape[0]
    dt = jnp.dtype(cfg.dtype)
    x = params["embed"]["table"][token[:, None]] * jnp.asarray(cfg.d_model ** 0.5, dt)
    pos = jnp.broadcast_to(cache.length, (b,))
    spec = _attn_spec(cfg)
    window = cache.attn_k[_first_attn_idx(cfg)].shape[1] if _first_attn_idx(cfg) >= 0 else 0

    attn_k, attn_v = list(cache.attn_k), list(cache.attn_v)
    lru_h, conv = list(cache.lru_h), list(cache.conv)
    for li, lp in enumerate(params["layers"]):
        h = L.rms_norm(lp["temporal_norm"], x, cfg.norm_eps)
        if block_kind(cfg, li) == "attention":
            # ring-buffer local window: slot = pos % window
            q, k, v = L.qkv_proj(lp["attn"], h, spec)
            q = L.apply_rope(q, pos[:, None], cfg.rope_theta)
            k = L.apply_rope(k, pos[:, None], cfg.rope_theta)
            slot = pos[0] % window
            attn_k[li] = jax.lax.dynamic_update_slice_in_dim(attn_k[li], k, slot, axis=1)
            attn_v[li] = jax.lax.dynamic_update_slice_in_dim(attn_v[li], v, slot, axis=1)
            kk = jnp.repeat(attn_k[li], spec.num_heads // spec.num_kv_heads, axis=2)
            vv = jnp.repeat(attn_v[li], spec.num_heads // spec.num_kv_heads, axis=2)
            s = jnp.einsum("bqhd,bkhd->bhqk", q, kk, preferred_element_type=jnp.float32)
            s = s * (spec.head_dim ** -0.5)
            ring_pos = jnp.arange(window, dtype=jnp.int32)
            # a ring slot holds position p iff p <= pos and p > pos - window;
            # recover the stored position from the slot index
            stored = pos[:, None] - ((pos[:, None] - ring_pos[None, :]) % window)
            valid = (stored >= 0) & (stored <= pos[:, None])
            s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
            p_ = jax.nn.softmax(s, axis=-1).astype(dt)
            o = jnp.einsum("bhqk,bkhd->bqhd", p_, vv, preferred_element_type=jnp.float32)
            y = L.attention_out(lp["attn"], o.astype(dt))
        else:
            y, (h_new, conv_new) = rg_lru_block(
                lp["rglru"], h, decode_state=(lru_h[li], conv[li])
            )
            lru_h[li], conv[li] = h_new, conv_new
        x = x + y
        h = L.rms_norm(lp["mlp_norm"], x, cfg.norm_eps)
        x = x + _mlp(lp["mlp"], h)

    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.dot(x, params["embed"]["table"].T, preferred_element_type=jnp.float32)[:, 0]
    return logits, HybridCache(attn_k, attn_v, lru_h, conv, cache.length + 1)


def _first_attn_idx(cfg: ModelConfig) -> int:
    for li in range(cfg.num_layers):
        if block_kind(cfg, li) == "attention":
            return li
    return -1
