"""Anytime serving curve: progressive answers, SLA stops, native pruning.

One top-k workload is served per non-l1 registry metric under three
bound arms on the SAME seeded stream:

  conservative — bounds_mode="conservative": the uniform per-metric l1
      budgets (chi2: eps/3, hellinger: eps^2/4) of the original metric
      layer.
  native       — bounds_mode="native": tau-aware Canonne-style budgets
      (core/bounds.py `metric_native_l1_budget`). Native budgets
      dominate the uniform ones BY CONSTRUCTION (each is a max over
      the uniform budget and tighter tau-aware routes), so termination
      can only come earlier — gated as ``native_no_slower_*``.
  native+prune — native + early-reject pruning (`deviations.prune_far`):
      candidates provably far from the split stop being marked for
      I/O. Soundness (a pruned candidate never re-enters the best set,
      and the final answer is unchanged vs the native arm) is gated as
      ``prune_sound_*``; the pruned count is reported.

The anytime API itself is exercised two ways:

  * every arm is driven through `MatchServer.iter_results`, recording
    the (round, tuples, delta_upper) confidence trajectory — the
    reported ``curve_*`` arrays are the benchmark's namesake plot;
  * one query runs under a tuples `StopPolicy` next to an unstopped
    twin stepped to the same round; the stopped answer must be
    bit-identical to the twin's `poll_result` at that round
    (``stop_poll_identical``, gated exact).

Set ANYTIME_BENCH_SMOKE=1 for the CI configuration (same code paths,
smaller dataset; exits non-zero via ``ok`` if a contract fails).
Machine-readable report: benchmarks/results/BENCH_anytime.json,
regression-gated on the deterministic keys by check_regression.py.
"""

from __future__ import annotations

import json
import os
import pathlib
import time

import numpy as np

from benchmarks.common import env_stamp
from benchmarks.metrics_matrix import _brute
from repro.data.layout import block_layout
from repro.data.synth import SynthSpec, make_dataset
from repro.serve.fastmatch_server import MatchServer, StopPolicy

SMOKE = bool(int(os.environ.get("ANYTIME_BENCH_SMOKE", "0")))
K, DELTA, SEED = 5, 0.05, 3
LOOKAHEAD = 16 if SMOKE else 64
# Same comparable-radius table as metrics_matrix (chi2 taus live in
# [0, 2], squared-Hellinger in [0, 1]).
EPS = {"chi2": 0.15, "hellinger": 0.25}
ARMS = ("conservative", "native", "native+prune")

SPEC = SynthSpec(
    v_z=48, v_x=16, num_tuples=120_000 if SMOKE else 600_000, k=K, n_close=6,
    close_distance=0.03, far_distance=0.4, zipf_a=1.0, seed=SEED,
)

RESULTS = pathlib.Path(__file__).parent / "results"


def _serve_arm(blocked, ds, metric: str, arm: str) -> dict:
    """One query through `iter_results`; returns counters + trajectory
    + pruning soundness evidence."""
    srv = MatchServer(
        blocked, max_queries=2, lookahead=LOOKAHEAD, seed=SEED, metric=metric,
        bounds_mode="conservative" if arm == "conservative" else "native",
        prune=arm == "native+prune",
    )
    rid = srv.submit(ds.target, k=K, eps=EPS[metric], delta=DELTA)
    t0 = time.perf_counter()
    curve = []
    best_sets = []
    pruned_masks = []
    for ans in srv.iter_results(rid):
        curve.append(
            [ans.round, ans.tuples, round(float(ans.delta_upper), 6)]
        )
        if ans.status == "live":
            best_sets.append(set(ans.ids.tolist()))
            pruned_masks.append(srv.scheduler._pruned_host[0].copy())
    wall = time.perf_counter() - t0
    res = srv.results[rid]

    # Pruning soundness: sticky mask, and a pruned candidate never
    # reappears in ANY later best set (including the final answer).
    sticky = all(
        not (a & ~b).any() for a, b in zip(pruned_masks, pruned_masks[1:])
    )
    final_set = set(res.ids.tolist())
    disjoint = all(
        not (set(np.flatnonzero(m).tolist()) & later)
        for i, m in enumerate(pruned_masks)
        for later in best_sets[i:] + [final_set]
    )
    want = set(
        np.argsort(_brute(ds.true_hists, ds.target, metric), kind="stable")[
            :K
        ].tolist()
    )
    return {
        "rounds": int(res.rounds),
        "tuples": int(res.tuples_read),
        "exact": bool(res.exact),
        "recall": len(final_set & want) / K,
        "ids": sorted(final_set),
        "pruned_count": int(pruned_masks[-1].sum()) if pruned_masks else 0,
        "prune_sticky": bool(sticky),
        "prune_disjoint": bool(disjoint),
        "curve": curve,
        "wall_s": round(wall, 4),
    }


def _stop_vs_poll(blocked, ds) -> dict:
    """A tuples-SLA stop vs an unstopped twin polled at the same round:
    the two statements must agree bit for bit."""
    budget = 6 * LOOKAHEAD * 512  # fires mid-stream, well before exhaustion
    kw = dict(max_queries=2, lookahead=LOOKAHEAD, seed=SEED)
    a = MatchServer(blocked, **kw)
    rid_a = a.submit(ds.target, k=K, eps=0.02, delta=0.01,
                     stop=StopPolicy(tuples=budget))
    res = a.run_until_idle()[rid_a]
    ans_a = a.poll_result(rid_a)

    b = MatchServer(blocked, **kw)
    rid_b = b.submit(ds.target, k=K, eps=0.02, delta=0.01)
    while b.scheduler.rounds < ans_a.round and rid_b not in b.results:
        b.step()
    ans_b = b.poll_result(rid_b)
    identical = (
        ans_a.round == ans_b.round
        and ans_a.tuples == ans_b.tuples
        and ans_a.ids.tobytes() == ans_b.ids.tobytes()
        and ans_a.tau.tobytes() == ans_b.tau.tobytes()
        and ans_a.margin.tobytes() == ans_b.margin.tobytes()
        and ans_a.split == ans_b.split
        and ans_a.delta_upper == ans_b.delta_upper
        and ans_a.n_min == ans_b.n_min
    )
    # free the twin's slot so the process exits cleanly
    b.run_until_idle()
    return {
        "stop_poll_identical": bool(identical),
        "stop_reason": res.stop_reason,
        "stop_round": int(ans_a.round),
        "stop_tuples": int(res.tuples_read),
        "stop_delta_upper": round(float(ans_a.delta_upper), 6),
        "stopped_not_exact": bool(res.stopped and not res.exact),
    }


def run(rows: list) -> None:
    ds = make_dataset(SPEC)
    blocked = block_layout(
        ds.z, ds.x, v_z=SPEC.v_z, v_x=SPEC.v_x, block_size=512, seed=SEED
    )
    report = {
        "config": {
            "v_z": SPEC.v_z, "v_x": SPEC.v_x, "num_tuples": SPEC.num_tuples,
            "k": K, "delta": DELTA, "lookahead": LOOKAHEAD, "seed": SEED,
            "smoke": SMOKE, "eps": EPS, **env_stamp(),
        },
    }
    ok = True
    for metric in EPS:
        arms = {arm: _serve_arm(blocked, ds, metric, arm) for arm in ARMS}
        report[metric] = arms
        no_slower = arms["native"]["rounds"] <= arms["conservative"]["rounds"]
        prune_sound = (
            arms["native+prune"]["prune_sticky"]
            and arms["native+prune"]["prune_disjoint"]
            and arms["native+prune"]["ids"] == arms["native"]["ids"]
        )
        # flat keys for check_regression gates
        report[f"native_no_slower_{metric}"] = bool(no_slower)
        report[f"prune_sound_{metric}"] = bool(prune_sound)
        report[f"recall_{metric}_native"] = arms["native"]["recall"]
        report[f"rounds_{metric}_native"] = arms["native"]["rounds"]
        report[f"pruned_{metric}"] = arms["native+prune"]["pruned_count"]
        ok = ok and no_slower and prune_sound
        ok = ok and arms["native"]["recall"] >= 0.8
        for arm in ARMS:
            m = arms[arm]
            rows.append({
                "name": f"anytime_{metric}_{arm.replace('+', '_')}",
                "us_per_call": m["wall_s"] * 1e6,
                "derived": (
                    f"rounds={m['rounds']} recall={m['recall']:.2f} "
                    f"pruned={m['pruned_count']}"
                ),
            })

    stop = _stop_vs_poll(blocked, ds)
    report.update(stop)
    ok = ok and stop["stop_poll_identical"] and stop["stopped_not_exact"]
    rows.append({
        "name": "anytime_stop_sla",
        "us_per_call": 0.0,
        "derived": (
            f"reason={stop['stop_reason']} round={stop['stop_round']} "
            f"identical={stop['stop_poll_identical']}"
        ),
    })

    report["ok"] = bool(ok)
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "BENCH_anytime.json").write_text(json.dumps(report, indent=2))
    if not ok:
        raise SystemExit("anytime_curve: a deterministic contract failed")
