"""The FastMatch engine: HistSim + block policies + lookahead staleness.

This is the executable analogue of the paper's Figure 5 architecture.
The three components map onto the execution model as follows:

  I/O manager        — gathers marked blocks from the blocked dataset
                       (host memory here; disk/remote-FS in production)
  sampling engine    — AnyActive marking of a lookahead window of blocks
                       against the packed bitmap, using the FRESHEST
                       delta_i posted so far (which is one window stale —
                       the paper's asynchronous relaxation, Sec 4.2)
  statistics engine  — the jitted HistSim ingest+stats round

Variants (paper Sec 5.2) are configuration points of this single engine:

  variant     policy      lookahead   stats cadence        criterion
  ---------   ---------   ---------   ------------------   ---------
  fastmatch   anyactive   L (512)     once per window      histsim
  syncmatch   anyactive   1           once per block       histsim
  scanmatch   scan        L           once per window      histsim
  slowmatch   scan        L           once per window      slowmatch
  scan        scan        —           exact full pass      —

Sampling is WITHOUT replacement from a random start position in the
pre-shuffled layout. A pass visits every not-yet-read block in cyclic
order; AnyActive may skip blocks, and skipped blocks remain eligible for
later passes (candidates can re-activate when the split point moves).
If a whole pass reads nothing and HistSim still has not terminated, the
engine completes exactly (reads the remainder) — at that point empirical
counts equal the true ones and the guarantees hold deterministically.

The window-marking/ingest loop itself lives in `repro.core.multiquery`
(`SharedCountsScheduler`): `run_engine` is its ``max_queries=1``
specialization, and the N-query serving frontend over the same loop is
`repro.serve.fastmatch_server.MatchServer`.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import histsim
from repro.core.histsim import HistSimParams, HistSimState
from repro.core.multiquery import MultiQuerySpec, SharedCountsScheduler
from repro.data.layout import BlockedDataset

__all__ = ["EngineConfig", "MatchResult", "run_engine", "VARIANTS"]

VARIANTS = ("fastmatch", "syncmatch", "scanmatch", "slowmatch", "scan")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    variant: str = "fastmatch"
    lookahead: int = 512
    seed: int = 0
    max_rounds: int = 1_000_000
    max_passes: int = 4
    start_block: Optional[int] = None  # None -> random

    def __post_init__(self):
        if self.variant not in VARIANTS:
            raise ValueError(f"unknown variant {self.variant!r}")

    @property
    def policy(self) -> str:
        return "anyactive" if self.variant in ("fastmatch", "syncmatch") else "scan"

    @property
    def window(self) -> int:
        return 1 if self.variant == "syncmatch" else self.lookahead

    @property
    def criterion(self) -> str:
        return "slowmatch" if self.variant == "slowmatch" else "histsim"


@dataclasses.dataclass
class MatchResult:
    ids: np.ndarray  # (k,) matching candidate ids, closest first
    state: HistSimState
    rounds: int
    blocks_read: int
    blocks_considered: int
    tuples_read: int
    wall_time_s: float
    exact: bool  # True iff the answer rests on a COMPLETE read of the data
    passes: int

    @property
    def delta_upper(self) -> float:
        return float(self.state.delta_upper)


def _run_exact_scan(dataset: BlockedDataset, state, params, t0) -> "MatchResult":
    """The paper's Scan baseline: complete heap scan, exact answer."""
    z_blocks = jnp.asarray(dataset.z_blocks)
    x_blocks = jnp.asarray(dataset.x_blocks)
    nb = dataset.num_blocks
    chunk = 4096
    for s in range(0, nb, chunk):
        cj = jnp.arange(s, min(s + chunk, nb), dtype=jnp.int32)
        state = histsim.ingest(
            state, z_blocks[cj].reshape(-1), x_blocks[cj].reshape(-1), params=params
        )
    state = histsim.stats_step(state, params=params)
    ids = np.asarray(histsim.top_k_ids(state, params.k))
    return MatchResult(
        ids=ids,
        state=state,
        rounds=-(-nb // chunk),
        blocks_read=nb,
        blocks_considered=nb,
        tuples_read=dataset.num_tuples,
        wall_time_s=time.perf_counter() - t0,
        exact=True,
        passes=1,
    )


def run_engine(
    dataset: BlockedDataset,
    target: np.ndarray,
    params: HistSimParams,
    config: EngineConfig = EngineConfig(),
) -> MatchResult:
    """Run one matching query to termination. Returns the top-k + stats.

    This is the ``max_queries=1`` specialization of the shared
    window-marking/ingest loop (`multiquery.SharedCountsScheduler`);
    `MatchServer` runs the same loop with many concurrent queries.

    ``exact`` in the result means what the docstring says: True iff the
    answer rests on a complete read of the dataset (either the exact
    fallback fired, or sampling happened to exhaust every block). A
    ``max_rounds`` budget cut returns the best-effort sampled answer
    with ``exact=False`` — it never silently completes the scan.
    """
    if params.v_z != dataset.v_z or params.v_x != dataset.v_x:
        raise ValueError("params/dataset dimension mismatch")
    if config.criterion != params.criterion:
        params = dataclasses.replace(params, criterion=config.criterion)

    t0 = time.perf_counter()

    if config.variant == "scan":
        state = histsim.init_state(params, jnp.asarray(target))
        return _run_exact_scan(dataset, state, params, t0)

    spec = MultiQuerySpec(
        v_z=params.v_z, v_x=params.v_x, max_queries=1, criterion=params.criterion
    )
    sched = SharedCountsScheduler(
        dataset,
        spec,
        policy=config.policy,
        window=config.window,
        seed=config.seed,
        start_block=config.start_block,
    )
    qid = sched.admit(target, k=params.k, eps=params.eps, delta=params.delta)
    sched.pump(max_rounds=config.max_rounds, max_passes=config.max_passes)
    if qid not in sched.outcomes:
        # max_rounds budget cut: best-effort sampled answer, NOT exact.
        out = sched.retire(0, exact=False, terminated=False)
    else:
        out = sched.outcomes[qid]

    return MatchResult(
        ids=out.ids,
        state=out.state,
        rounds=out.rounds,
        blocks_read=out.blocks_read,
        blocks_considered=out.blocks_considered,
        tuples_read=out.tuples_read,
        wall_time_s=time.perf_counter() - t0,
        exact=out.exact,
        passes=out.passes,
    )
