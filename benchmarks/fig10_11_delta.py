"""Figures 10 & 11: effect of delta on latency and Delta_d.

Paper claim: both are more-or-less constant in delta — inherited from
Theorem 1's insensitivity to delta (the 1/|V_X| exponent in the log).
"""

from __future__ import annotations


from benchmarks.common import delta_d, get_query, run_variant

GRID = (0.001, 0.01, 0.05, 0.2)
QUERY = "flights_q1"


def run(csv_rows: list) -> None:
    spec, _, blocked = get_query(QUERY)
    for delta in GRID:
        res, wall, ds = run_variant(QUERY, "fastmatch", delta=delta)
        dd = delta_d(res, ds)
        csv_rows.append(
            dict(
                name=f"fig10_11.delta_{delta}",
                us_per_call=wall * 1e6,
                derived=(
                    f"blocks_frac={res.blocks_read / blocked.num_blocks:.3f}"
                    f" delta_d={dd:.4f}"
                ),
            )
        )
