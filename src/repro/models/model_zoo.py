"""Unified model interface over all assigned architecture families.

    model = get_model(cfg)
    params = model.init(rng)
    logits, aux = model.forward(params, tokens, **extras)
    cache = model.init_cache(batch, max_len)
    logits, cache = model.decode_step(params, cache, token)
    extras = model.extra_inputs(batch, seq)   # frontend stubs (vlm/audio)
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import rglru, transformer, whisper, xlstm

__all__ = ["Model", "get_model"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable  # rng -> params
    forward: Callable  # (params, tokens, **extras) -> (logits, aux)
    prefill: Callable  # (params, tokens, max_len, **extras) -> (logits, cache)
    decode_step: Callable  # (params, cache, token) -> (logits, cache)
    init_cache: Callable  # (batch, max_len) -> cache
    extra_input_shapes: Callable  # (batch, seq) -> {name: ShapeDtypeStruct}


def _stub_extras(cfg: ModelConfig):
    """ShapeDtypeStructs for the modality-frontend stub inputs."""
    dt = jnp.dtype(cfg.dtype)

    def fn(batch: int, seq: int):
        if cfg.frontend == "vision_stub" and cfg.vision_tokens:
            return {
                "vision_embeds": jax.ShapeDtypeStruct(
                    (batch, cfg.vision_tokens, cfg.d_model), dt
                )
            }
        if cfg.frontend == "audio_stub":
            return {
                "encoder_frames": jax.ShapeDtypeStruct(
                    (batch, cfg.encoder_seq, cfg.d_model), dt
                )
            }
        return {}

    return fn


def get_model(cfg: ModelConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        mod = transformer
    elif cfg.family == "hybrid":
        mod = rglru
    elif cfg.family == "ssm":
        mod = xlstm
    elif cfg.family == "audio":
        mod = whisper
    else:
        raise ValueError(f"unknown family {cfg.family!r}")

    return Model(
        cfg=cfg,
        init=lambda rng: mod.init_params(rng, cfg),
        forward=lambda params, tokens, **kw: mod.forward(params, tokens, cfg, **kw),
        prefill=lambda params, tokens, max_len, **kw: mod.prefill(
            params, tokens, cfg, max_len, **kw
        ),
        decode_step=lambda params, cache, token: mod.decode_step(params, cache, token, cfg),
        init_cache=lambda batch, max_len: mod.init_cache(cfg, batch, max_len),
        extra_input_shapes=_stub_extras(cfg),
    )
