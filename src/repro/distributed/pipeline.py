"""Pipeline parallelism over the "pod" mesh axis (GPipe-style).

For multi-pod jobs the cheapest inter-pod link is the pod-to-pod DCI, so
the natural decomposition is one PIPELINE STAGE per pod: layer stack
split into `n_stages` groups, stage s owned by pod s, activations
handed off with `jax.lax.ppermute` once per microbatch tick. Data
parallelism ("data") and tensor parallelism ("model") continue INSIDE
each pod, nested in the same shard_map.

Schedule: GPipe with M microbatches — M + S - 1 ticks; bubble fraction
(S-1)/(M+S-1). Backward is jax.grad through the forward loop (ppermute
transposes to the reverse shift automatically).

This module is deliberately generic: `make_pipeline_forward` takes any
per-stage apply function. The dense transformer adapter
(`transformer_stage_fn`) groups its layers into contiguous stages. Used
by tests/test_pipeline.py and launch/dryrun.py --pipeline.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["make_pipeline_forward", "stack_stage_params", "transformer_stage_fn"]


def stack_stage_params(per_stage_params: list):
    """Stack a list of per-stage param pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_stage_params)


def make_pipeline_forward(
    stage_fn: Callable,  # (stage_params, x, stage_idx) -> y
    mesh: Mesh,
    *,
    n_stages: int,
    n_microbatches: int,
    pod_axis: str = "pod",
    data_axes: tuple = ("data",),
    model_axis: str = "model",
):
    """Returns f(stacked_stage_params, x) -> y running the GPipe schedule.

    x: (B, ...) global batch; B must divide by n_microbatches. The
    returned function must be called under `jax.jit` with the mesh's
    shardings; stacked_stage_params' leading axis is sharded over
    `pod_axis`, so each pod materializes only its own stage weights.
    """
    if mesh.shape[pod_axis] != n_stages:
        raise ValueError(f"n_stages={n_stages} != pod axis size {mesh.shape[pod_axis]}")

    def pipelined(stage_params_local, x_local):
        # Inside shard_map: stage_params_local has leading dim 1 (this
        # pod's stage); x_local is this data-shard's slice of the batch.
        stage_idx = jax.lax.axis_index(pod_axis)
        sp = jax.tree.map(lambda a: a[0], stage_params_local)

        b = x_local.shape[0]
        mb = b // n_microbatches
        micro = x_local.reshape(n_microbatches, mb, *x_local.shape[1:])

        n_ticks = n_microbatches + n_stages - 1
        buf = jnp.zeros_like(micro[0])
        outs = jnp.zeros_like(micro)
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (when in range)
            inject = jnp.where(t < n_microbatches, t, 0)
            x_in = jnp.where(stage_idx == 0, micro[inject], buf)
            y = stage_fn(sp, x_in, stage_idx)
            # last stage collects its finished microbatch (t - (S-1))
            out_slot = t - (n_stages - 1)
            collect = (stage_idx == n_stages - 1) & (out_slot >= 0)
            outs = jax.lax.cond(
                collect,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(out_slot, 0), 0
                ),
                lambda o: o,
                outs,
            )
            # hand off to the next stage
            buf = jax.lax.ppermute(y, pod_axis, perm)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(n_ticks))
        # broadcast final outputs from the last stage to all pods so the
        # loss is computable everywhere (one extra psum of activations).
        is_last = (stage_idx == n_stages - 1).astype(outs.dtype)
        outs = jax.lax.psum(outs * is_last, pod_axis)
        return outs.reshape(b, *outs.shape[2:])

    in_specs = (
        P(pod_axis),  # stage-stacked params: stage dim over pods
        P(data_axes),  # batch over data axes (pods all see their slice? no:
        # batch replicated across pods, sharded over data inside the pod)
    )
    out_specs = P(data_axes)
    from repro.core.distributed import shard_map_compat

    return shard_map_compat(pipelined, mesh, in_specs=in_specs, out_specs=out_specs)


def transformer_stage_fn(layer_fn: Callable, layers_per_stage: int):
    """Adapter: run `layers_per_stage` stacked layers as one stage.

    stage_params: pytree with leading dim = layers_per_stage.
    """

    def fn(stage_params, x, stage_idx):
        def body(h, lp):
            return layer_fn(lp, h), None

        y, _ = jax.lax.scan(body, x, stage_params)
        return y

    return fn
