"""Shared-counts multi-query HistSim — the FastMatch serving core.

The key structural fact enabling a serving layer on top of HistSim: the
counts matrix ``r_i`` accumulated by `ingest` is *target-independent* —
only ``q_hat``, ``tau``, ``eps_i`` and ``delta_i`` depend on the query.
N concurrent queries over the same dataset can therefore share ONE
counts matrix and ONE I/O stream:

  shared   — counts (V_Z, V_X), n (V_Z,), the block read_mask / cursor
  per-query — q_hat, (k, eps, delta), query type (top-k | closeness)
              and its gap, tau, eps_i, log_delta_i, delta_upper,
              active set, matching set M (close set for closeness)

`ingest` runs once per window for everybody (reusing the one-hot-
contraction histogram kernel); `stats_step` is vmapped over the query
axis, so each query keeps its own Problem 1 parameters and its own
termination bound. The union active set — the bitwise OR of the
per-query packed ``active_words`` — feeds the AnyActive kernel, so the
I/O manager reads a block iff *any* live query still needs it.

Sample-complexity intuition (Diakonikolas et al., Canonne et al.: the
cost of testing closeness is driven by the number of samples, not the
number of hypotheses tested against them): every tuple read is charged
once but advances all N queries, so the per-query I/O cost shrinks
roughly as 1/N, and queries admitted late start from the accumulated
shared counts instead of from zero. Soundness of a late query using
the full accumulated ``n_i`` for its Theorem 1 bounds: WHICH blocks
were read does depend on the earlier queries' targets (AnyActive marks
via their active sets), but the layout pre-shuffle assigns tuples to
blocks independently of their x-values, so for each candidate any
block-granular read policy yields a uniform without-replacement sample
of that candidate's tuples — the same paper-Sec 4.2 property the
single-query engine already relies on when AnyActive is driven by its
OWN target. Hence a late query's ``n_i`` IS the shared ``n_i``, with
no discounting. (This rests on the shuffle; on a non-shuffled layout
neither the single- nor the multi-query bounds are valid.)

Query slots are padded to a fixed ``max_queries`` so every jitted
function sees stable shapes; empty slots are masked out of the active
union and report delta_upper = 0.

Device residency (paper Sec 4.2's asynchronous relaxation, taken to
its hardware conclusion): one jitted `fused_round` runs mark + masked
gather + ingest + vmapped stats AND the read bookkeeping — a
`SampleCursor` holding the without-replacement ``read_mask`` and the
blocks/tuples counters — entirely on device. The host loop in
`SharedCountsScheduler.pump` dispatches rounds back-to-back and only
polls ``delta_upper`` and the counters every ``poll_every`` windows
(`host_syncs` counts those polls). ``poll_every=1`` reproduces the
per-window host-stepped loop bit-for-bit; larger values trade bounded
retirement staleness (a query may read up to ``poll_every - 1`` extra
windows after its bound fires) for ~``poll_every``x fewer device↔host
round-trips. Block data arrives through the pluggable `repro.io`
`BlockSource` layer, so gathering the next window can overlap the
current round (`PrefetchSource`).

`SharedCountsScheduler` below is the window-marking/ingest loop that
used to live inline in `engine.run_engine`; the single-query engine is
now the ``max_queries=1`` specialization of this loop, and
`repro.serve.fastmatch_server.MatchServer` is the many-query frontend
with admission/retirement.

Pluggable metrics and query types: the spec's static ``metric`` ("l1" |
"chi2" | "hellinger") selects WHICH registry distance the shared tau
pass computes — threaded through `stats_step` exactly like the tuned
kernel plan, so one scheduler serves one metric with per-metric
autotune keys. Query TYPE is per-slot and dynamic: every slot carries a
``qtype`` (0 = top-k, 1 = closeness) and a ``gap``, and `apply_stats`
evaluates both retirement rules and selects per slot — admitting a
closeness query next to live top-k queries therefore triggers NO
recompilation and both share the same counts matrix mid-stream. The
l1 top-k default compiles to the exact pre-metric-layer program (the
closeness branch is selected away; selects are value-exact).
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
import time
from functools import partial
from typing import Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import config_hash as _checkpoint_config_hash
from repro.core import deviations as dev
from repro.core import histsim
from repro.core.bitmap import pack_active_mask, words_for
from repro.core.histsim import HistSimState
from repro.core.policies import mark_window
from repro.io import BlockSource, WindowData, as_block_source
from repro.io.faults import WindowQuarantined, find_resilient
from repro.kernels import autotune, ops
from repro.obs.telemetry import Telemetry

__all__ = [
    "AnytimeAnswer",
    "CacheSnapshot",
    "MultiQuerySpec",
    "MultiQueryState",
    "QTYPE_TOPK",
    "QTYPE_CLOSENESS",
    "QueryOutcome",
    "SampleCursor",
    "SharedCountsScheduler",
    "StopPolicy",
    "apply_stats",
    "cache_config_hash",
    "fused_round",
    "ingest_round",
    "init_cursor",
    "init_multi_state",
    "admit_slot",
    "clear_slot",
    "ingest",
    "stats_step",
    "run_round",
    "slot_state",
]


# Per-slot query types (MultiQueryState.qtype values). Dynamic — a
# traced i32 per slot, NOT a static spec field — so mixed top-k +
# closeness workloads share one compiled program.
QTYPE_TOPK = 0
QTYPE_CLOSENESS = 1


@dataclasses.dataclass(frozen=True)
class StopPolicy:
    """SLA-driven early stopping for one query (or a whole scheduler via
    ``MultiQuerySpec.default_stop``). A stopped query retires with its
    honest anytime answer — ``exact=False``, ``terminated=False``, the
    achieved ``delta_upper`` attached — bit-identical to what
    `SharedCountsScheduler.peek` would have reported at that poll.

    Fields left None never fire; the statistical retirement rule
    (delta_upper < delta) always takes precedence, so a query that
    converges before its SLA returns the normal terminated answer.

      wall_ms    — stop once the query has been live this many ms
                   (evaluated at poll boundaries, so the overshoot is
                   bounded by one poll interval, like PR-8 deadlines).
      confidence — stop once 1 - delta_upper reaches this level (a
                   weaker-than-delta "good enough" bound).
      tuples     — stop once this many tuples were read while live
                   (a hard sampling-cost SLA).
    """

    wall_ms: Optional[float] = None
    confidence: Optional[float] = None
    tuples: Optional[int] = None

    def __post_init__(self):
        if self.wall_ms is None and self.confidence is None and self.tuples is None:
            raise ValueError(
                "StopPolicy needs at least one of wall_ms/confidence/tuples"
            )
        if self.wall_ms is not None and not self.wall_ms >= 0.0:
            raise ValueError(f"need wall_ms >= 0, got {self.wall_ms}")
        if self.confidence is not None and not (0.0 < self.confidence <= 1.0):
            raise ValueError(f"need 0 < confidence <= 1, got {self.confidence}")
        if self.tuples is not None and not self.tuples >= 0:
            raise ValueError(f"need tuples >= 0, got {self.tuples}")

    def fired(self, *, wall_s: float, confidence: float, tuples: int) -> str:
        """The reason this policy fires on the given live-query gauges,
        or "" if it does not. Checked cheapest-guarantee-loss first:
        a confidence stop yields the strongest answer, so when several
        criteria fire at the same poll that is the reason reported."""
        if self.confidence is not None and confidence >= self.confidence:
            return "confidence"
        if self.tuples is not None and tuples >= self.tuples:
            return "tuples"
        if self.wall_ms is not None and wall_s * 1000.0 >= self.wall_ms:
            return "wall_ms"
        return ""


@dataclasses.dataclass(frozen=True)
class MultiQuerySpec:
    """Static shape/criterion/metric configuration shared by all query
    slots."""

    v_z: int
    v_x: int
    max_queries: int = 8
    criterion: str = "histsim"  # "histsim" | "slowmatch", applies to all slots
    # Static upper bound on any slot's k. When set, the per-slot
    # deviation assignment selects M via a (k_cap+1)-element lax.top_k
    # instead of a V_Z-sized sort; admission validates k <= k_cap.
    # None = no bound known (selection falls back to V_Z order stats).
    k_cap: Optional[int] = None
    # Registry distance the shared tau pass computes (and the bound
    # family deviations go through) — static per scheduler, threaded
    # like the kernel plan. "l1" reproduces the pre-metric-layer
    # program bit for bit.
    metric: str = "l1"
    # Failure-bound routing: "native" evaluates Theorem 1 at the
    # observation-aware ℓ1 budget (tighter for chi2/hellinger, never
    # looser; the l1 arm is bit-identical under both modes),
    # "conservative" keeps the PR-9 uniform budgets.
    bounds_mode: str = "native"
    # Early-reject pruning: retire clearly-far candidates from the
    # union-active set (I/O marking only — the failure bounds keep
    # summing over everyone). False compiles the exact pre-pruning
    # active-set expression; the flag is static, so flipping it is a
    # (deliberate) recompile, never a mid-stream shape change.
    prune: bool = False
    # Scheduler-wide default StopPolicy for queries admitted without
    # their own. compare=False keeps it out of __eq__/__hash__: stop
    # policies are host-loop decisions, so two specs differing only
    # here share every jit cache entry.
    default_stop: Optional[StopPolicy] = dataclasses.field(
        default=None, compare=False
    )

    def __post_init__(self):
        if self.max_queries < 1:
            raise ValueError(f"need max_queries >= 1, got {self.max_queries}")
        if self.criterion not in ("histsim", "slowmatch"):
            raise ValueError(self.criterion)
        if self.k_cap is not None and not (0 < self.k_cap <= self.v_z):
            raise ValueError(f"need 0 < k_cap <= V_Z, got k_cap={self.k_cap}")
        if self.bounds_mode not in ("native", "conservative"):
            raise ValueError(
                f"bounds_mode must be 'native' or 'conservative', "
                f"got {self.bounds_mode!r}"
            )
        if self.default_stop is not None and not isinstance(
            self.default_stop, StopPolicy
        ):
            raise TypeError(
                f"default_stop must be a StopPolicy, got {self.default_stop!r}"
            )
        from repro.kernels import metrics as _metrics

        _metrics.coerce_metric(self.metric)  # fail construction, not trace


class MultiQueryState(NamedTuple):
    """One shared counts matrix + per-slot query statistics (Q = max_queries)."""

    counts: jax.Array  # (V_Z, V_X) f32 — SHARED empirical counts r_i
    n: jax.Array  # (V_Z,) f32 — SHARED samples per candidate n_i
    q_hat: jax.Array  # (Q, V_X) f32 normalized targets
    k: jax.Array  # (Q,) i32 per-query k
    eps: jax.Array  # (Q,) f32 per-query eps
    delta: jax.Array  # (Q,) f32 per-query delta
    gap: jax.Array  # (Q,) f32 — closeness promise gap (0 for top-k slots)
    qtype: jax.Array  # (Q,) i32 — QTYPE_TOPK | QTYPE_CLOSENESS per slot
    tau: jax.Array  # (Q, V_Z) f32 per-query distance estimates
    eps_i: jax.Array  # (Q, V_Z) f32 assigned deviations
    log_delta_i: jax.Array  # (Q, V_Z) f32
    delta_upper: jax.Array  # (Q,) f32 — 0 for empty slots
    active: jax.Array  # (Q, V_Z) bool — per-query AnyActive candidates
    active_words: jax.Array  # (Q, W) uint32 packed per-query active masks
    union_words: jax.Array  # (W,) uint32 — OR over slots; drives block marking
    in_top_k: jax.Array  # (Q, V_Z) bool — per-query matching set M
    # Sticky early-reject mask (all-False unless spec.prune): candidates
    # certified clearly-far, dropped from the I/O marking only — the
    # failure bounds keep summing over every candidate.
    pruned: jax.Array  # (Q, V_Z) bool
    occupied: jax.Array  # (Q,) bool — slot holds a live query
    round_idx: jax.Array  # () i32 — statistics iterations so far


class SampleCursor(NamedTuple):
    """Device-resident sampling-side state: the without-replacement
    read_mask plus the monotone read counters, updated inside the fused
    round so the host never has to sync to account for a window."""

    read_mask: jax.Array  # (num_blocks,) bool
    blocks_read: jax.Array  # () i32
    blocks_considered: jax.Array  # () i32
    tuples_read: jax.Array  # () i32
    rounds: jax.Array  # () i32 — windows dispatched


class CacheSnapshot(NamedTuple):
    """The serving loop's durable warm-start state — everything a
    restarted server needs to answer future queries from the
    accumulated sample instead of from zero.

    Only TARGET-INDEPENDENT state is here: the shared counts matrix and
    per-candidate row sums (sufficient statistics for every future
    query — the closeness-testing view), the without-replacement
    ``read_mask`` plus its monotone counters, and the host-side pass /
    visit-order bookkeeping. Live query slots are deliberately NOT part
    of a snapshot: in-flight queries re-enter the serving queue after a
    restart, and because sampling is target-independent they lose
    nothing by re-admitting against the restored counts.

    A snapshot is a flat pytree of arrays so `CheckpointManager` can
    save it crash-atomically and `restore_resharded` can re-place the
    candidate-sharded leaves onto a different mesh shape
    (`repro.core.distributed.cache_pspecs`).
    """

    counts: jax.Array  # (V_Z, V_X) f32 shared empirical counts r_i
    n: jax.Array  # (V_Z,) f32 shared samples per candidate n_i
    read_mask: jax.Array  # (num_blocks,) bool without-replacement state
    blocks_read: jax.Array  # () i32
    blocks_considered: jax.Array  # () i32
    tuples_read: jax.Array  # () i32
    rounds: jax.Array  # () i32 — windows dispatched
    passes: jax.Array  # () i32 — host-side pass counter
    start: jax.Array  # () i32 — cyclic visit-order offset


def cache_config_hash(source, spec: MultiQuerySpec) -> str:
    """Fingerprint binding a `CacheSnapshot` to (dataset layout, spec).

    Accumulated counts are sufficient statistics for any future query
    ONLY over the exact blocked layout they were sampled from: under a
    different shuffle, block size, or attribute arity the restored
    ``read_mask``/counts pair silently invalidates every Theorem-1
    bound. The hash covers the layout dimensions, the per-block tuple
    counts, the content of up to 64 probe blocks spread evenly across
    the whole layout, and the `MultiQuerySpec`, so a stale snapshot is
    REJECTED at restore (ValueError from `CheckpointManager`) instead
    of corrupting bounds.

    The probe reads O(64) blocks, never the dataset — hashing all
    content at every warm construction would cost the cold scan the
    warm start exists to avoid. The even spread catches reshuffles,
    re-blockings and bulk rewrites anywhere in the layout; an edit
    confined to unprobed blocks that also preserves every per-block
    tuple count is the accepted residual risk of this trade.
    """
    src = as_block_source(source)
    probe = np.unique(
        np.linspace(0, src.num_blocks - 1, min(src.num_blocks, 64)).astype(np.int64)
    )
    wd = src.fetch(probe, pad_to=len(probe))
    fp = hashlib.sha256()
    fp.update(np.ascontiguousarray(np.asarray(src.tuples_per_block, np.int64)).tobytes())
    for leaf in (wd.z, wd.x, wd.bitmap):
        fp.update(np.ascontiguousarray(jax.device_get(leaf)).tobytes())
    payload = (
        "fastmatch-cache-v1",
        (spec.v_z, spec.v_x, spec.max_queries, spec.criterion, spec.k_cap),
        (src.num_blocks, src.block_size),
        fp.hexdigest(),
    )
    return _checkpoint_config_hash(payload)


def init_cursor(num_blocks: int) -> SampleCursor:
    zero = jnp.asarray(0, jnp.int32)
    return SampleCursor(
        read_mask=jnp.zeros((num_blocks,), bool),
        blocks_read=zero,
        blocks_considered=zero,
        tuples_read=zero,
        rounds=zero,
    )


def init_multi_state(spec: MultiQuerySpec) -> MultiQueryState:
    """All slots empty, counts at zero."""
    q, v_z, v_x = spec.max_queries, spec.v_z, spec.v_x
    w = words_for(v_z)
    return MultiQueryState(
        counts=jnp.zeros((v_z, v_x), jnp.float32),
        n=jnp.zeros((v_z,), jnp.float32),
        q_hat=jnp.full((q, v_x), 1.0 / v_x, jnp.float32),
        k=jnp.ones((q,), jnp.int32),
        eps=jnp.ones((q,), jnp.float32),
        delta=jnp.ones((q,), jnp.float32),
        gap=jnp.zeros((q,), jnp.float32),
        qtype=jnp.zeros((q,), jnp.int32),
        tau=jnp.ones((q, v_z), jnp.float32),
        eps_i=jnp.zeros((q, v_z), jnp.float32),
        log_delta_i=jnp.zeros((q, v_z), jnp.float32),
        delta_upper=jnp.zeros((q,), jnp.float32),
        active=jnp.zeros((q, v_z), bool),
        active_words=jnp.zeros((q, w), jnp.uint32),
        union_words=jnp.zeros((w,), jnp.uint32),
        in_top_k=jnp.zeros((q, v_z), bool),
        pruned=jnp.zeros((q, v_z), bool),
        occupied=jnp.zeros((q,), bool),
        round_idx=jnp.asarray(0, jnp.int32),
    )


@partial(jax.jit, static_argnames=("spec",))
def admit_slot(
    state: MultiQueryState,
    slot: jax.Array,
    q_hat: jax.Array,
    k: jax.Array,
    eps: jax.Array,
    delta: jax.Array,
    *,
    spec: MultiQuerySpec,
    qtype: jax.Array = QTYPE_TOPK,
    gap: jax.Array = 0.0,
) -> MultiQueryState:
    """Install a query into `slot`. Run `stats_step` before the next marking
    so the new query's active set reflects the accumulated shared counts.

    ``qtype``/``gap`` default to a top-k query (the pre-closeness
    signature — existing positional callers are unchanged); pass
    ``qtype=QTYPE_CLOSENESS`` with a positive ``gap`` for a tolerant
    closeness test (eps = the "close" radius, eps + gap = the "far"
    radius; k is ignored for such slots)."""
    del spec  # shapes carried by state
    slot = jnp.asarray(slot, jnp.int32)
    return state._replace(
        q_hat=state.q_hat.at[slot].set(jnp.asarray(q_hat, jnp.float32)),
        k=state.k.at[slot].set(jnp.asarray(k, jnp.int32)),
        eps=state.eps.at[slot].set(jnp.asarray(eps, jnp.float32)),
        delta=state.delta.at[slot].set(jnp.asarray(delta, jnp.float32)),
        gap=state.gap.at[slot].set(jnp.asarray(gap, jnp.float32)),
        qtype=state.qtype.at[slot].set(jnp.asarray(qtype, jnp.int32)),
        pruned=state.pruned.at[slot].set(False),
        occupied=state.occupied.at[slot].set(True),
    )


@partial(jax.jit, static_argnames=("spec",))
def clear_slot(state: MultiQueryState, slot: jax.Array, *, spec: MultiQuerySpec) -> MultiQueryState:
    """Free a slot (query retired): drop it from the active union.

    tau is pinned back to the init value 1.0 — the batched `stats_step`
    masks unoccupied slots out of the tau update, so whatever a freed
    slot holds would otherwise linger as a stale snapshot."""
    del spec
    slot = jnp.asarray(slot, jnp.int32)
    active_words = state.active_words.at[slot].set(jnp.uint32(0))
    return state._replace(
        occupied=state.occupied.at[slot].set(False),
        active=state.active.at[slot].set(False),
        active_words=active_words,
        tau=state.tau.at[slot].set(1.0),
        delta_upper=state.delta_upper.at[slot].set(0.0),
        gap=state.gap.at[slot].set(0.0),
        qtype=state.qtype.at[slot].set(QTYPE_TOPK),
        pruned=state.pruned.at[slot].set(False),
        union_words=_or_reduce(active_words),
    )


def _or_reduce(words: jax.Array) -> jax.Array:
    """(Q, W) uint32 -> (W,) bitwise OR over the query axis."""
    return jax.lax.reduce(words, jnp.uint32(0), jax.lax.bitwise_or, dimensions=[0])


@partial(jax.jit, static_argnames=("spec", "plan"))
def ingest(
    state: MultiQueryState,
    z_idx: jax.Array,
    x_idx: jax.Array,
    *,
    spec: MultiQuerySpec,
    plan=None,
) -> MultiQueryState:
    """Accumulate a padded sample batch into the SHARED counts — one
    histogram-kernel launch serves every live query. The kernel emits
    the per-candidate row-sum delta from the same pass (or via the
    two-step form when the tuned ``plan`` measured it faster), so
    advancing ``n_i`` costs no second sweep over the delta matrix."""
    delta_counts, delta_n = ops.histogram_with_rowsums(
        z_idx, x_idx, v_z=spec.v_z, v_x=spec.v_x, plan=plan if plan is not None else "auto"
    )
    return state._replace(
        counts=state.counts + delta_counts,
        n=state.n + delta_n,
    )


def apply_stats(
    state: MultiQueryState, tau: jax.Array, n: jax.Array, *, spec: MultiQuerySpec
) -> MultiQueryState:
    """Per-slot deviation assignment from precomputed distances.

    The shared tail of the statistics engine: given (Q, V_Z) distances
    and the full (V_Z,) sample counts, run the vmapped per-query
    assignment with each slot's (k, eps, delta) and rebuild the active
    union. Both `stats_step` (single device) and the unified
    `repro.core.distributed.make_distributed_round` (tau/n arriving via
    all-gather from candidate shards) end in this function, so the two
    paths cannot drift.

    Each slot's RETIREMENT RULE follows its dynamic ``qtype``: both the
    top-k deviation assignment and the closeness margins are evaluated
    (each is O(V_Z) per slot — negligible next to the (V_Z, V_X) tau
    pass) and per-slot selected, so mixing query types never
    recompiles. The select is value-exact: an all-top-k workload
    produces bit-identical results to the pre-closeness engine.

    With ``spec.prune`` the sticky per-slot ``pruned`` mask is OR-grown
    with `dev.prune_far` — candidates whose lower confidence bound
    clears the far edge (eps + gap for closeness, split + eps/2 for
    top-k) — and subtracted from the I/O marking. A Python-level
    branch: prune=False compiles the exact pre-pruning active-set
    expression, and the mask is fixed-shape so flipping candidates
    never recompiles.
    """

    def one(tau_q, k, eps, delta, gap, qtype, occupied, pruned_q):
        d_top = dev.assign_deviations_dynamic(
            tau_q, n, k=k, eps=eps, delta=delta, v_x=spec.v_x,
            criterion=spec.criterion, k_cap=spec.k_cap, metric=spec.metric,
            bounds_mode=spec.bounds_mode,
        )
        d_close = dev.assign_closeness(
            tau_q, n, eps=eps, gap=gap, delta=delta, v_x=spec.v_x,
            metric=spec.metric, bounds_mode=spec.bounds_mode,
        )
        is_close = qtype == QTYPE_CLOSENESS
        d = jax.tree.map(
            lambda a, b: jnp.where(is_close, a, b), d_close, d_top
        )
        if spec.prune:
            far_edge = jnp.where(is_close, eps + gap, d.split + 0.5 * eps)
            pruned_q = pruned_q | (
                dev.prune_far(
                    tau_q, n, far_edge=far_edge, delta=delta, v_x=spec.v_x,
                    metric=spec.metric,
                )
                & occupied
            )
            active = d.active & occupied & ~pruned_q
        else:
            active = d.active & occupied
        return (
            d.eps_i,
            d.log_delta_i,
            jnp.where(occupied, d.delta_upper, 0.0),
            active,
            pack_active_mask(active),
            d.in_top_k & occupied,
            pruned_q,
        )

    eps_i, log_delta_i, delta_upper, active, words, in_top_k, pruned = (
        jax.vmap(one)(
            tau, state.k, state.eps, state.delta, state.gap, state.qtype,
            state.occupied, state.pruned,
        )
    )
    return state._replace(
        tau=tau,
        eps_i=eps_i,
        log_delta_i=log_delta_i,
        delta_upper=delta_upper,
        active=active,
        active_words=words,
        union_words=_or_reduce(words),
        in_top_k=in_top_k,
        pruned=pruned,
        round_idx=state.round_idx + 1,
    )


@partial(jax.jit, static_argnames=("spec", "plan"))
def stats_step(
    state: MultiQueryState, *, spec: MultiQuerySpec, plan=None
) -> MultiQueryState:
    """One statistics-engine iteration for every slot — no Python loop.

    tau for ALL slots comes from ONE `ops.distance_multi` call (the
    spec's static metric — "l1" by default): the
    shared counts matrix is streamed once and scored against the whole
    (Q, V_X) target batch, so the statistics cost per round is
    independent of the number of query slots (the PR-2 path unrolled Q
    single-query kernel calls, re-reading counts per slot — and empty
    slots burned a full pass against a stale q_hat). Unoccupied slots
    are masked out of the tau update (pinned at the init value 1.0);
    the deviation assignment with each slot's (k, eps, delta) is
    vmapped over the query axis via `apply_stats`. ``plan`` pins the
    tuned tau variant (`autotune.TauPlan`); None consults the plan
    registry at trace time.
    """
    tau = ops.distance_multi(
        state.counts, state.q_hat, metric=spec.metric,
        plan=plan if plan is not None else "auto",
    )
    tau = jnp.where(state.occupied[:, None], tau, 1.0)
    return apply_stats(state, tau, state.n, spec=spec)


def run_round(
    state: MultiQueryState,
    z_idx: jax.Array,
    x_idx: jax.Array,
    *,
    spec: MultiQuerySpec,
    plans: Optional[autotune.PlanPair] = None,
) -> MultiQueryState:
    """Shared ingest + vmapped stats — one full multi-query round."""
    return stats_step(
        ingest(state, z_idx, x_idx, spec=spec, plan=plans.ingest if plans else None),
        spec=spec,
        plan=plans.tau if plans else None,
    )


def _advance_cursor(cursor: SampleCursor, wd: WindowData, marks: jax.Array) -> SampleCursor:
    """Read bookkeeping shared by the sampling and exact-completion
    rounds — any change to the accounting applies to both paths."""
    # scatter-add (duplicate-safe: padding repeats a real id with a zero
    # contribution) then re-binarize — bool scatter-or is not available
    read_mask = (
        cursor.read_mask.astype(jnp.int32).at[wd.indices].add(marks.astype(jnp.int32)) > 0
    )
    return SampleCursor(
        read_mask=read_mask,
        blocks_read=cursor.blocks_read + jnp.sum(marks.astype(jnp.int32)),
        blocks_considered=cursor.blocks_considered + jnp.sum(wd.valid.astype(jnp.int32)),
        tuples_read=cursor.tuples_read
        + jnp.sum(jnp.where(marks, jnp.sum((wd.z >= 0).astype(jnp.int32), axis=1), 0)),
        rounds=cursor.rounds + 1,
    )


@partial(jax.jit, static_argnames=("spec", "policy", "plans"))
def fused_round(
    state: MultiQueryState,
    cursor: SampleCursor,
    wd: WindowData,
    *,
    spec: MultiQuerySpec,
    policy: str,
    plans: Optional[autotune.PlanPair] = None,
) -> tuple:
    """One device-resident sampling round: mark + gather-mask + ingest +
    vmapped stats + read bookkeeping, one dispatch, zero host syncs.

    Marking uses the union active words (stale by up to ``poll_every``
    windows of retirements — the generalized Sec 4.2 relaxation) and is
    masked by the window's padding validity and the device read_mask, so
    a block can never be double-counted even if the host hands out an
    overlapping window. Ingest+stats are skipped branchlessly (lax.cond)
    when nothing was marked, matching the host-stepped loop's cadence
    (stats run only after windows that read something).
    """
    marks = mark_window(wd.bitmap, state.union_words, policy=policy)
    marks = marks & wd.valid & ~cursor.read_mask[wd.indices]
    n_marked = jnp.sum(marks.astype(jnp.int32))

    def with_round(st: MultiQueryState) -> MultiQueryState:
        zw = jnp.where(marks[:, None], wd.z, jnp.int32(-1)).reshape(-1)
        xw = jnp.where(marks[:, None], wd.x, jnp.int32(-1)).reshape(-1)
        return stats_step(
            ingest(st, zw, xw, spec=spec, plan=plans.ingest if plans else None),
            spec=spec,
            plan=plans.tau if plans else None,
        )

    state = jax.lax.cond(n_marked > 0, with_round, lambda st: st, state)
    return state, _advance_cursor(cursor, wd, marks)


@partial(jax.jit, static_argnames=("spec", "plans"))
def ingest_round(
    state: MultiQueryState,
    cursor: SampleCursor,
    wd: WindowData,
    *,
    spec: MultiQuerySpec,
    plans: Optional[autotune.PlanPair] = None,
) -> tuple:
    """Exact-completion round: ingest every unread block of the window
    into the shared counts, no marking, no stats (the caller runs one
    `stats_step` after the last chunk — statistics are a pure function
    of the counts, so per-chunk stats would be wasted work)."""
    marks = wd.valid & ~cursor.read_mask[wd.indices]
    zw = jnp.where(marks[:, None], wd.z, jnp.int32(-1)).reshape(-1)
    xw = jnp.where(marks[:, None], wd.x, jnp.int32(-1)).reshape(-1)
    state = ingest(state, zw, xw, spec=spec, plan=plans.ingest if plans else None)
    return state, _advance_cursor(cursor, wd, marks)


def slot_state(state: MultiQueryState, slot: int) -> HistSimState:
    """Single-query `HistSimState` view of one slot (counts/n are shared)."""
    return HistSimState(
        counts=state.counts,
        n=state.n,
        q_hat=state.q_hat[slot],
        tau=state.tau[slot],
        eps_i=state.eps_i[slot],
        log_delta_i=state.log_delta_i[slot],
        delta_upper=state.delta_upper[slot],
        active=state.active[slot],
        active_words=state.active_words[slot],
        in_top_k=state.in_top_k[slot],
        round_idx=state.round_idx,
    )


# ---------------------------------------------------------------------------
# The shared window-marking / ingest loop
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Ticket:
    """Host-side bookkeeping for one live query slot."""

    qid: int
    slot: int
    k: int
    eps: float
    delta: float
    qtype: str  # "topk" | "closeness"
    gap: float  # closeness promise gap; 0.0 for top-k
    admit_time: float
    admit_rounds: int
    admit_passes: int
    admit_blocks_read: int
    admit_blocks_considered: int
    admit_tuples_read: int
    stop: Optional[StopPolicy] = None  # SLA policy; None = run to the bound


@dataclasses.dataclass
class QueryOutcome:
    """Per-query result produced at retirement."""

    qid: int
    ids: np.ndarray  # (k,) matching ids, closest first; for a closeness
    # query, ALL candidates labeled close (variable length, tau order)
    state: HistSimState  # single-query view snapshot at retirement
    delta_upper: float
    exact: bool  # the answer rests on a complete read of the data
    terminated: bool  # the statistical rule delta_upper < delta fired
    rounds: int  # windows processed while this query was live
    passes: int
    blocks_read: int
    blocks_considered: int
    tuples_read: int  # tuples ingested while this query was live
    wall_time_s: float
    # Degradation contract (I/O quarantine). ``degraded`` is True when
    # any block was quarantined while the scheduler served this query:
    # the (eps, delta) guarantee then holds over the SURVIVING block
    # population (``exact`` likewise means a complete read of the
    # survivors), and ``eps_effective`` is the honestly widened L1
    # radius vs the FULL dataset — eps + 2 * (quarantined tuple
    # fraction), since dropping a content-independent fraction q of any
    # candidate's tuples moves its empirical histogram by at most 2q in
    # L1. Fault-free: degraded=False and eps_effective == query eps.
    degraded: bool = False
    eps_effective: float = float("nan")
    blocks_quarantined: int = 0
    qtype: str = "topk"  # "topk" | "closeness"
    # SLA early stop: ``stopped`` is True when a StopPolicy (or a
    # supervisor deadline) retired the query before its statistical
    # bound fired; the answer is then exactly the anytime statement at
    # that poll (exact=False, terminated=False, achieved delta_upper).
    stopped: bool = False
    stop_reason: str = ""  # "confidence" | "tuples" | "wall_ms" | "deadline"
    # The poll-boundary anytime statement assembled at retirement by
    # `SharedCountsScheduler.peek` — the SAME host code path serving
    # live polls, so a stopped answer is bit-identical to what
    # poll_result would have said at that round.
    anytime: Optional["AnytimeAnswer"] = None


@dataclasses.dataclass
class AnytimeAnswer:
    """A progressive (poll-boundary) answer with its Theorem-1-style
    confidence statement — what `MatchServer.poll_result` returns.

    The statement reads: "the current best set is ``ids`` (closest
    first); every candidate's empirical distance is within ``eps_n`` of
    its true one w.p. > 1 - delta/|V_Z| each, the probability that the
    set is not (eps, k)-correct is at most ``delta_upper``, and each
    listed candidate would have to move by its ``margin`` (in metric
    space) for its membership promise to break."

    All quantities are the CURVE_COLUMNS trajectory quantities promoted
    from telemetry to API (`curve_point` is the inverse promotion), so
    a recorded confidence curve and a sequence of polls agree exactly.
    """

    qid: int
    qtype: str  # "topk" | "closeness"
    status: str  # "queued" | "live" | "done"
    ids: np.ndarray  # current best set, closest first
    tau: np.ndarray  # (len(ids),) empirical distances of the best set
    margin: np.ndarray  # (len(ids),) per-candidate decision margin
    split: float  # current split point / closeness threshold
    n_min: float  # weakest per-candidate sample count
    tau_min: float
    eps_n: float  # metric-space eps(n_min) at per-candidate budget delta/V_Z
    delta_upper: float  # union failure bound of the CURRENT labeling
    confidence: float  # max(0, 1 - delta_upper)
    round: int
    tuples: int
    tuples_live: int  # tuples read while this query was live
    eps: float
    delta: float
    metric: str
    exact: bool = False
    stopped: bool = False
    stop_reason: str = ""
    result: Optional[object] = None  # final MatchResult once status == "done"

    def curve_point(self) -> dict:
        """This answer as a CURVE_COLUMNS trajectory point — the exact
        dict `Telemetry.record_curve_point` stores, so polls can be
        appended to the same confidence curves telemetry records."""
        return dict(
            round=self.round,
            tuples=self.tuples,
            tuples_live=self.tuples_live,
            n_min=self.n_min,
            tau_min=self.tau_min,
            eps_n=self.eps_n,
            delta_upper=self.delta_upper,
            confidence=self.confidence,
        )


def _theorem1_eps_np(n: float, delta_i: float, v_x: int) -> float:
    """Host-side Theorem 1 eps(n) — scalar mirror of
    `repro.core.bounds.theorem1_epsilon` so recording a trajectory point
    never dispatches device work (tests pin the two against each other).
    `math` rather than numpy: this runs per live query per poll, and
    numpy scalar ops are ~10x slower than libm calls.
    """
    n = max(float(n), 1.0)
    return math.sqrt((2.0 / n) * (v_x * math.log(2.0) - math.log(delta_i)))


def _metric_eps_np(n: float, delta_i: float, v_x: int, metric: str) -> float:
    """`_theorem1_eps_np` pushed through the metric's budget inverse —
    the host-side scalar mirror of `bounds.metric_epsilon` (same
    derivations). The l1 branch is the identity, keeping the default
    telemetry path byte-identical."""
    eps1 = _theorem1_eps_np(n, delta_i, v_x)
    if metric == "l1":
        return eps1
    if metric == "chi2":
        return 3.0 * eps1
    if metric == "hellinger":
        return 2.0 * math.sqrt(eps1)
    raise ValueError(f"unknown metric {metric!r}")


class _BatchAcc:
    """Host-side wall-time accumulators for one poll's round batch.

    Filled between polls (two `perf_counter` reads per window — the only
    telemetry cost off the poll boundary), drained into one
    ``round_batch`` trace event at each poll.
    """

    __slots__ = ("windows", "gather_s", "dispatch_s", "sync_s")

    def __init__(self):
        self.reset()

    def reset(self) -> None:
        self.windows = 0
        self.gather_s = 0.0
        self.dispatch_s = 0.0
        self.sync_s = 0.0


def _timed_iter(stream, acc: _BatchAcc):
    """Yield from ``stream`` accumulating per-window gather wall time
    (time spent waiting on the source — with `PrefetchSource` underneath
    this is the residual stall, not the full fetch cost)."""
    it = iter(stream)
    while True:
        t0 = time.perf_counter()
        try:
            wd = next(it)
        except StopIteration:
            return
        acc.gather_s += time.perf_counter() - t0
        yield wd


class SharedCountsScheduler:
    """The FastMatch execution loop over a shared counts matrix.

    Owns the dataset-side sampling state — the cyclic visit order, the
    device-resident `SampleCursor` (global without-replacement
    ``read_mask`` + read counters), and pass structure — plus the
    `MultiQueryState`. Queries enter via `admit` (any time, into a free
    slot), leave via `retire` (collected in `outcomes`), and `pump`
    drives windows until every live query resolves:

      mark   — AnyActive over the UNION active words
      ingest — marked blocks into the shared counts
      stats  — vmapped per-query deviation assignment + bounds

    all three fused into one jitted `fused_round` dispatch per window;
    block data arrives through the `repro.io.BlockSource` given at
    construction (a `BlockedDataset` is wrapped in `InMemorySource`;
    pass a `PrefetchSource` to overlap next-window gathering with the
    current round). The host polls ``delta_upper`` + counters only
    every ``poll_every`` windows — `host_syncs` counts these polls, and
    host-side mirrors (``read_mask``, ``rounds``, ``blocks_read``, …)
    are refreshed at each one. ``poll_every=1`` reproduces the
    host-stepped loop exactly; larger values defer retirement/admission
    by at most ``poll_every - 1`` windows (bounded staleness) and let
    the budget overshoot by the same amount.

    A pass visits every not-yet-read block in cyclic order; blocks
    skipped by AnyActive stay eligible for later passes (a newly
    admitted query can re-activate them). If a whole pass reads nothing
    while queries remain live, the scheduler completes exactly — reads
    the remainder so empirical counts equal the true ones — and retires
    the stragglers with ``exact=True``. A `max_rounds` budget instead
    stops the loop with live queries left best-effort (the caller
    retires them with ``exact=False``).

    With ``mesh`` given, the shared counts matrix is placed sharded
    ``P(model_axis, None)`` (samples-per-candidate ``P(model_axis)``)
    and every jitted step runs SPMD across the mesh — the GSPMD
    counterpart of the explicit-collective
    `repro.core.distributed.make_distributed_round`.
    """

    def __init__(
        self,
        dataset,
        spec: MultiQuerySpec,
        *,
        policy: str = "anyactive",
        window: int = 512,
        seed: int = 0,
        start_block: Optional[int] = None,
        poll_every: int = 1,
        mesh=None,
        model_axis: str = "model",
        telemetry: Optional[Telemetry] = None,
        plans: Optional[autotune.PlanPair] = None,
    ):
        source: BlockSource = as_block_source(dataset)
        if spec.v_z != source.v_z or spec.v_x != source.v_x:
            raise ValueError("spec/dataset dimension mismatch")
        if getattr(source, "lo", 0) != 0:
            # A ShardedSource speaks GLOBAL block ids while the scheduler
            # owns a 0-based visit order/read_mask — shard sources feed
            # the manually driven distributed round, not this loop.
            raise ValueError(
                "SharedCountsScheduler needs a 0-based source (whole dataset); "
                "use ShardedSource with make_distributed_round instead"
            )
        if policy not in ("anyactive", "scan"):
            raise ValueError(f"unknown policy {policy!r}")
        if poll_every < 1:
            raise ValueError(f"need poll_every >= 1, got {poll_every}")
        self.source = source
        self.spec = spec
        self.policy = policy
        self.poll_every = poll_every
        # Tuned kernel plans, resolved ONCE here (eagerly — with
        # FASTMATCH_AUTOTUNE=1 this may measure and persist missing
        # keys) and threaded statically through every jitted round, so
        # one scheduler's whole lifetime runs one consistent plan.
        self.plans = (
            plans
            if plans is not None
            else autotune.resolve_plans(
                spec.v_z, spec.v_x, spec.max_queries, metric=spec.metric
            )
        )
        nb = source.num_blocks
        self.window = max(1, min(window, nb))

        rng = np.random.default_rng(seed)
        start = start_block if start_block is not None else int(rng.integers(nb))
        self._start = start  # persisted by export_cache: the visit order
        self.order = np.roll(np.arange(nb), -start)  # cyclic visit order

        self.state = init_multi_state(spec)
        self.cursor = self._place_cursor(init_cursor(nb))
        if mesh is not None:
            from jax.sharding import NamedSharding
            from repro.core.distributed import multi_state_pspecs

            specs = multi_state_pspecs(model_axis=model_axis)
            self.state = jax.device_put(
                self.state, jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
            )
        self.tickets: Dict[int, _Ticket] = {}  # slot -> ticket
        self.outcomes: Dict[int, QueryOutcome] = {}  # qid -> outcome
        self._next_qid = 0

        # host mirrors of the device cursor + per-slot bounds, refreshed
        # by `_sync()` (per-query numbers are deltas vs admit)
        self.read_mask = np.zeros(nb, dtype=bool)
        self.rounds = 0
        self.passes = 0  # host-side pass structure, not device state
        self.blocks_read = 0
        self.blocks_considered = 0
        self.tuples_read = 0
        self._delta_upper = np.zeros(spec.max_queries, np.float32)
        # Anytime-answer mirrors (always refreshed — `peek` assembles
        # progressive answers from these between dispatches).
        self._tel_tau = np.ones((spec.max_queries, spec.v_z), np.float32)
        self._tel_n = np.zeros(spec.v_z, np.float32)
        self._in_top_k_host = np.zeros((spec.max_queries, spec.v_z), bool)
        self._pruned_host = np.zeros((spec.max_queries, spec.v_z), bool)
        # Quarantine state (host-side — quarantined blocks never reach a
        # device dispatch, they are simply excluded from every future
        # pass order). All-False in the fault-free path, in which case
        # every eligibility mask below reduces to the pre-quarantine
        # expression bit for bit.
        self.quarantined = np.zeros(nb, dtype=bool)
        self.blocks_quarantined = 0
        self.tuples_quarantined = 0
        self.total_tuples = int(np.sum(np.asarray(source.tuples_per_block, np.int64)))
        self.budget_exhausted = False
        self.host_syncs = 0  # number of device->host polls performed
        # polls made by the window loop itself (pump/run_window), i.e.
        # the steady-state cadence poll_every controls — excludes the
        # per-query fixed polls at admission
        self.loop_syncs = 0

        # Telemetry is poll-boundary only: every record below rides an
        # existing host sync, so the jitted round path and the dispatch
        # sequence are identical with telemetry on and off (the
        # bit-equivalence guard in tests/test_obs.py).
        self.telemetry = telemetry
        if telemetry is not None:
            reg = telemetry.registry
            self._tel_last = {"rounds": 0, "blocks": 0, "tuples": 0, "passes": 0}
            # Poll-time recording is two appends (see `_record_poll`);
            # everything dict/registry-shaped happens in
            # `flush_telemetry`, batched, at lifecycle boundaries or on
            # first read — per-poll python shaping runs cache-cold right
            # after a device phase and costs ~10x its warm price.
            self._poll_buf: list = []
            self._tel_pending = {"syncs": 0, "rounds": 0, "blocks": 0,
                                 "tuples": 0, "passes": 0}
            telemetry.add_flush_hook(self.flush_telemetry)
            self._c_syncs = reg.counter(
                "fastmatch_host_syncs_total", "device-host polls performed")
            self._c_rounds = reg.counter(
                "fastmatch_rounds_total", "windows dispatched (stats iterations)")
            self._c_blocks = reg.counter(
                "fastmatch_blocks_read_total", "blocks ingested into shared counts")
            self._c_tuples = reg.counter(
                "fastmatch_tuples_read_total", "tuples drawn (m of Theorem 1)")
            self._c_passes = reg.counter(
                "fastmatch_passes_total", "cyclic passes over the block layout")
            self._c_admitted = reg.counter(
                "fastmatch_queries_admitted_total", "queries admitted into slots")
            self._c_retired = reg.counter(
                "fastmatch_queries_retired_total", "queries retired with an answer")
            self._c_quarantined = reg.counter(
                "fastmatch_blocks_quarantined_total",
                "blocks dropped from the probe set after I/O quarantine")
            self._h_batch = reg.histogram(
                "fastmatch_round_batch_seconds",
                help="host wall per round batch (gather+dispatch+sync)")
            self._h_q_tuples = reg.histogram(
                "fastmatch_query_tuples", edges=tuple(float(10 ** e) for e in range(2, 11)),
                help="tuples read while a query was live (per-query m)")
            self._h_q_rounds = reg.histogram(
                "fastmatch_query_rounds", edges=tuple(float(2 ** e) for e in range(0, 14)),
                help="rounds to retirement (paper Fig. 5)")
            self._h_q_wall = reg.histogram(
                "fastmatch_query_wall_seconds", help="admit-to-retire wall time")

    # -- device placement hooks (overridden by the data-parallel pump) -----

    def _place_cursor(self, cursor: SampleCursor) -> SampleCursor:
        """Place a freshly built (host-side) sampling cursor on device.

        The base scheduler keeps the cursor on the default device;
        `repro.core.pump.DistributedPump` overrides this to pad the
        ``read_mask`` to the worker grid and shard it over the data
        axes (`distributed.cursor_pspecs`). Called from __init__ and
        `import_cache`, so a restored snapshot always lands with the
        same placement as a fresh cursor."""
        return cursor

    def _global_read_mask(self) -> jax.Array:
        """The (num_blocks,) global read_mask view of the device cursor
        — what `export_cache` persists. The pump overrides this to
        gather its data-sharded mask and strip the worker-grid padding,
        so snapshots stay interchangeable across pump widths and with
        the single-stream scheduler."""
        return self.cursor.read_mask

    # -- quarantine (degraded guarantees) ----------------------------------

    def _quarantine_sources(self) -> tuple:
        """The sources whose `ResilientSource` layers (if any) this
        scheduler drains for quarantined block ids. The data-parallel
        pump overrides this to add its per-worker stream sources."""
        return (self.source,)

    def quarantine_blocks(self, ids, *, reason: str = "io") -> int:
        """Drop blocks from the probe set (an I/O quarantine verdict —
        see `repro.io.faults.ResilientSource`). Returns how many blocks
        newly left the population.

        Already-read blocks are NOT quarantined: their tuples were
        validated at fetch time and already sit in the shared counts —
        the quarantine protects coverage accounting, not history.
        Every (eps, delta) derived after this call is over the
        surviving population; `eps_inflation` is the widened-L1 margin
        vs the full dataset that retirement folds into
        ``QueryOutcome.eps_effective``.
        """
        ids = np.asarray(ids, np.int64).ravel()
        if ids.size:
            ids = ids[~self.quarantined[ids] & ~self.read_mask[ids]]
        if ids.size == 0:
            return 0
        self.quarantined[ids] = True
        tuples = int(np.sum(np.asarray(self.source.tuples_per_block, np.int64)[ids]))
        self.blocks_quarantined += int(ids.size)
        self.tuples_quarantined += tuples
        if self.telemetry is not None:
            self._c_quarantined.inc(int(ids.size))
            self.telemetry.tracer.emit(
                "blocks_quarantine", blocks=int(ids.size), tuples=tuples,
                reason=reason, total_blocks=self.blocks_quarantined,
                population_frac=self.quarantine_fraction,
            )
        return int(ids.size)

    def _drain_quarantine(self) -> None:
        """Pull quarantined block ids out of every `ResilientSource` in
        the source chains (rides the poll boundary: fault-free this is
        a handful of attribute probes, no device work)."""
        for src in self._quarantine_sources():
            resilient = find_resilient(src)
            if resilient is not None:
                ids = resilient.take_quarantined()
                if ids.size:
                    self.quarantine_blocks(ids, reason="source")

    @property
    def quarantine_fraction(self) -> float:
        """Fraction of the dataset's TUPLES lost to quarantine (the q
        in the eps + 2q widened bound)."""
        return self.tuples_quarantined / max(self.total_tuples, 1)

    @property
    def eps_inflation(self) -> float:
        """Additive L1 widening vs the full dataset: dropping a
        content-independent tuple fraction q (the layout pre-shuffle
        assigns tuples to blocks independently of content) changes any
        candidate's normalized histogram by at most 2q in L1, so a
        query guaranteed eps over the survivors is guaranteed
        eps + 2q over the full data."""
        return 2.0 * self.quarantine_fraction

    # -- host/device synchronisation --------------------------------------

    def _sync(self) -> None:
        """One batched device->host poll: cursor + per-slot bounds.

        Everything the host loop decides on (termination, budget, pass
        structure, counters) is refreshed here and ONLY here, so
        `host_syncs` is an exact count of device↔host round-trips the
        loop performs. Retirement snapshots (`retire`) transfer result
        data per retired query and are not part of the loop cadence.
        """
        # ONE batched poll. Beyond the cursor + bounds the host loop
        # decides on, the per-slot tau/n/in_top_k/pruned leaves feed the
        # anytime `peek` assembly and the confidence-trajectory points —
        # pure reads riding the same transfer, so device state and the
        # dispatch sequence are untouched whether or not anyone polls.
        cursor, delta_upper, tau, n, in_top_k, pruned = jax.device_get(
            (self.cursor, self.state.delta_upper, self.state.tau,
             self.state.n, self.state.in_top_k, self.state.pruned)
        )
        self._tel_tau = np.asarray(tau)
        self._tel_n = np.asarray(n)
        self._in_top_k_host = np.asarray(in_top_k)
        self._pruned_host = np.asarray(pruned)
        self.read_mask = np.asarray(cursor.read_mask)
        self.rounds = int(cursor.rounds)
        self.blocks_read = int(cursor.blocks_read)
        self.blocks_considered = int(cursor.blocks_considered)
        self.tuples_read = int(cursor.tuples_read)
        self._delta_upper = np.asarray(delta_upper)
        self.host_syncs += 1
        self._drain_quarantine()
        if self.telemetry is not None:
            self._record_poll()

    def _record_poll(self) -> None:
        """Stage this poll's mirrors for telemetry (called from `_sync`
        only). Deliberately minimal — counter deltas into plain ints and
        one tuple of array refs into the poll buffer (`_sync` rebinds
        fresh arrays each poll, so refs are stable snapshots); all
        shaping happens batched in `flush_telemetry`."""
        last = self._tel_last
        p = self._tel_pending
        p["syncs"] += 1
        p["rounds"] += self.rounds - last["rounds"]
        p["blocks"] += self.blocks_read - last["blocks"]
        p["tuples"] += self.tuples_read - last["tuples"]
        p["passes"] += self.passes - last["passes"]
        last.update(rounds=self.rounds, blocks=self.blocks_read,
                    tuples=self.tuples_read, passes=self.passes)
        if self.tickets:
            # The entry carries its own snapshot of the live ticket set
            # (shallow copy — tickets are immutable after admit), so
            # admit/retire never need to drain the buffer: each staged
            # poll is shaped under the set that was live when it was
            # sampled, no matter when the flush runs.
            self._poll_buf.append(
                (self.rounds, self.tuples_read, self._tel_n,
                 self._tel_tau, self._delta_upper, list(self.tickets.items()))
            )
            if len(self._poll_buf) >= 256:
                self.flush_telemetry()  # bound buffer memory on long pumps

    def flush_telemetry(self) -> None:
        """Drain staged polls into the registry and the per-query
        trajectories.

        Each buffered poll carries its own snapshot of the then-live
        ticket set, so the flush needs no relationship to admit/retire
        boundaries: it runs at pump() exit, when the buffer hits its
        memory bound, and lazily from `Telemetry`'s read accessors —
        large warm batches instead of per-poll (or per-boundary)
        shaping on the serve loop's cache-cold path.
        """
        tel = self.telemetry
        if tel is None:
            return
        p = self._tel_pending
        if p["syncs"]:
            self._c_syncs.inc(p["syncs"])
            self._c_rounds.inc(p["rounds"])
            self._c_blocks.inc(p["blocks"])
            self._c_tuples.inc(p["tuples"])
            self._c_passes.inc(p["passes"])
            for key in p:
                p[key] = 0
        buf = self._poll_buf
        if not buf:
            return
        self._poll_buf = []
        # vectorize the per-poll reductions across the whole batch
        n_mins = np.stack([b[2] for b in buf]).min(axis=1)  # (P,)
        tau_mins = np.stack([b[3] for b in buf]).min(axis=2)  # (P, Q)
        v_z, v_x = self.spec.v_z, self.spec.v_x
        for i, (rounds, tuples, _n, _tau, du, live) in enumerate(buf):
            n_min = float(n_mins[i])
            for slot, t in live:
                d_up = float(du[slot])
                tel.record_curve_point(t.qid, dict(
                    round=rounds,
                    tuples=tuples,
                    tuples_live=tuples - t.admit_tuples_read,
                    n_min=n_min,
                    tau_min=float(tau_mins[i, slot]),
                    # eps(n) at the per-candidate failure budget
                    # delta/|V_Z| — the AnyActive threshold the stats
                    # tail compares against.
                    eps_n=_metric_eps_np(
                        n_min, t.delta / v_z, v_x, self.spec.metric),
                    delta_upper=d_up,
                    confidence=max(0.0, 1.0 - d_up),
                ))

    def _round_batch_extra(self) -> dict:
        """Extra ``round_batch`` fields — the data-parallel pump adds
        per-worker gather and assembly timing here."""
        return {}

    def _emit_round_batch(self, acc: _BatchAcc) -> None:
        """Drain one poll's timing accumulators into a trace event."""
        self._h_batch.observe(acc.gather_s + acc.dispatch_s + acc.sync_s)
        self.telemetry.tracer.emit(
            "round_batch", windows=acc.windows, rounds=self.rounds,
            blocks_read=self.blocks_read, tuples_read=self.tuples_read,
            gather_s=acc.gather_s, dispatch_s=acc.dispatch_s,
            sync_s=acc.sync_s, **self._round_batch_extra(),
        )
        acc.reset()

    # -- warm-start persistence --------------------------------------------

    def export_cache(self) -> CacheSnapshot:
        """Snapshot the durable (target-independent) serving state.

        Consistent by construction at any time: counts and cursor are
        both outputs of the same fused dispatch, and the host handles
        here always point at the LATEST dispatched round — so even with
        ``poll_every > 1`` a snapshot never interleaves a round's counts
        with a different round's read_mask. Live query slots are not
        exported (see `CacheSnapshot`).
        """
        return CacheSnapshot(
            counts=self.state.counts,
            n=self.state.n,
            read_mask=self._global_read_mask(),
            blocks_read=self.cursor.blocks_read,
            blocks_considered=self.cursor.blocks_considered,
            tuples_read=self.cursor.tuples_read,
            rounds=self.cursor.rounds,
            passes=jnp.asarray(self.passes, jnp.int32),
            start=jnp.asarray(self._start, jnp.int32),
        )

    def import_cache(self, snap: CacheSnapshot) -> None:
        """Adopt a restored warm cache: shared counts + sampling cursor +
        pass/visit-order bookkeeping.

        Must run before any admission — importing under live tickets
        would invalidate their admission-time counter snapshots, so that
        is refused. Counts/n are placed with the scheduler's existing
        sharding (the GSPMD mesh placement when constructed with
        ``mesh=``); cursor leaves are re-materialized host-side so their
        placement matches a freshly constructed scheduler's.
        """
        if self.tickets:
            raise RuntimeError("import_cache requires a scheduler with no live queries")
        nb = self.source.num_blocks
        counts = jnp.asarray(snap.counts)
        if counts.shape != (self.spec.v_z, self.spec.v_x):
            raise ValueError(
                f"snapshot counts shape {counts.shape} != "
                f"{(self.spec.v_z, self.spec.v_x)} — wrong dataset/spec for this cache"
            )
        read_mask, blocks_read, blocks_considered, tuples_read, rounds, passes, start = (
            jax.device_get(
                (snap.read_mask, snap.blocks_read, snap.blocks_considered,
                 snap.tuples_read, snap.rounds, snap.passes, snap.start)
            )
        )
        read_mask = np.asarray(read_mask, bool)
        if read_mask.shape != (nb,):
            raise ValueError(
                f"snapshot read_mask covers {read_mask.shape[0]} blocks, "
                f"dataset has {nb} — wrong layout for this cache"
            )
        self.state = self.state._replace(
            counts=jax.device_put(counts.astype(jnp.float32), self.state.counts.sharding),
            n=jax.device_put(jnp.asarray(snap.n, jnp.float32), self.state.n.sharding),
        )
        self.cursor = self._place_cursor(SampleCursor(
            read_mask=jnp.asarray(read_mask),
            blocks_read=jnp.asarray(blocks_read, jnp.int32),
            blocks_considered=jnp.asarray(blocks_considered, jnp.int32),
            tuples_read=jnp.asarray(tuples_read, jnp.int32),
            rounds=jnp.asarray(rounds, jnp.int32),
        ))
        self._start = int(start)
        self.order = np.roll(np.arange(nb), -self._start)
        self.passes = int(passes)
        self._sync()  # refresh every host mirror from the restored cursor

    # -- admission / retirement -------------------------------------------

    @property
    def free_slots(self) -> list:
        return [s for s in range(self.spec.max_queries) if s not in self.tickets]

    @property
    def num_live(self) -> int:
        return len(self.tickets)

    def admit(
        self,
        target: np.ndarray,
        *,
        k: int,
        eps: float,
        delta: float,
        qtype: str = "topk",
        gap: float = 0.0,
        stop: Optional[StopPolicy] = None,
    ) -> int:
        """Place a query into a free slot; returns its qid.

        ``stop`` attaches an SLA `StopPolicy` (None inherits
        ``spec.default_stop``; pass a policy explicitly to override
        per query). Stop criteria are evaluated at poll boundaries,
        after the statistical rule.

        The immediate `stats_step` makes the query see the accumulated
        shared counts — with its full shared ``n_i`` — before the next
        window is marked, so a late query never starts from zero.
        Admission is a poll boundary (the ticket snapshots counters).

        ``qtype="closeness"`` admits a tolerant closeness test sharing
        the same counts matrix: every candidate within ``eps`` of the
        target (in the spec's metric) is labeled close, every one beyond
        ``eps + gap`` far, w.p. >= 1 - delta; inside the gap either
        label is allowed. ``k`` is ignored for closeness slots (pass 1).
        Mixing types triggers no recompilation — the type is a traced
        per-slot field.
        """
        free = self.free_slots
        if not free:
            raise RuntimeError("no free query slot; retire a query first")
        if qtype not in ("topk", "closeness"):
            raise ValueError(f"qtype must be 'topk' or 'closeness', got {qtype!r}")
        if qtype == "closeness":
            if not gap > 0.0:
                raise ValueError(f"closeness needs gap > 0, got gap={gap}")
            if not eps >= 0.0:
                raise ValueError(f"closeness needs eps >= 0, got eps={eps}")
        else:
            if gap != 0.0:
                raise ValueError("gap is only meaningful for qtype='closeness'")
            if not (0 < k <= self.spec.v_z):
                raise ValueError(f"need 0 < k <= V_Z, got k={k}")
            if self.spec.k_cap is not None and k > self.spec.k_cap:
                raise ValueError(f"k={k} exceeds spec.k_cap={self.spec.k_cap}")
        slot = free[0]
        target = np.asarray(target, np.float64).ravel()
        if target.shape != (self.spec.v_x,):
            raise ValueError(f"target must have shape ({self.spec.v_x},)")
        q_hat = (target / max(target.sum(), 1e-30)).astype(np.float32)
        self.state = admit_slot(
            self.state,
            jnp.asarray(slot, jnp.int32),
            jnp.asarray(q_hat),
            jnp.asarray(k, jnp.int32),
            jnp.asarray(eps, jnp.float32),
            jnp.asarray(delta, jnp.float32),
            spec=self.spec,
            qtype=jnp.asarray(
                QTYPE_CLOSENESS if qtype == "closeness" else QTYPE_TOPK,
                jnp.int32,
            ),
            gap=jnp.asarray(gap, jnp.float32),
        )
        self.state = stats_step(self.state, spec=self.spec, plan=self.plans.tau)
        self._sync()  # fresh counters for the ticket + fresh delta_upper
        qid = self._next_qid
        self._next_qid += 1
        self.tickets[slot] = _Ticket(
            qid=qid,
            slot=slot,
            k=int(k),
            eps=float(eps),
            delta=float(delta),
            qtype=qtype,
            gap=float(gap),
            admit_time=time.perf_counter(),
            admit_rounds=self.rounds,
            admit_passes=self.passes,
            admit_blocks_read=self.blocks_read,
            admit_blocks_considered=self.blocks_considered,
            admit_tuples_read=self.tuples_read,
            stop=stop if stop is not None else self.spec.default_stop,
        )
        if self.telemetry is not None:
            self._c_admitted.inc(1)
            self.telemetry.tracer.emit(
                "query_admit", qid=qid, slot=slot, k=int(k), eps=float(eps),
                delta=float(delta), qtype=qtype, gap=float(gap),
                round=self.rounds, tuples=self.tuples_read,
            )
            # The ticket didn't exist yet when admission's _sync polled
            # (its buffer entry's snapshot predates the insert) — stage
            # a first point (possibly already terminal on the warm
            # cache) from those same fresh mirrors, shaped later with
            # the rest of the buffer.
            self._poll_buf.append(
                (self.rounds, self.tuples_read, self._tel_n,
                 self._tel_tau, self._delta_upper,
                 [(slot, self.tickets[slot])])
            )
        return qid

    def peek(self, slot: int) -> AnytimeAnswer:
        """The current anytime answer for a LIVE slot, assembled purely
        from the last-polled host mirrors — no device work, no
        dispatch, so polling between rounds never perturbs the loop.

        Selection and margins mirror the device statistics in f32 with
        the device's exact tie rule (np stable argsort ascending ==
        `lax.top_k(-tau)`: equal values lower-index first) and the
        device's exact operation association, so at a poll boundary the
        assembled set is bit-identical to what retirement would report.
        `retire` itself calls this with the same fresh mirrors — a
        stopped query's final answer IS the poll at its stopping round.
        """
        t = self.tickets[slot]
        tau = self._tel_tau[slot]
        du = float(self._delta_upper[slot])
        eps32 = np.float32(t.eps)
        if t.qtype == "closeness":
            close = np.flatnonzero(self._in_top_k_host[slot])
            ids = close[np.argsort(tau[close], kind="stable")]
            gap32 = np.float32(t.gap)
            split32 = eps32 + np.float32(0.5) * gap32
            sel = tau[ids]
            margin = np.maximum(
                np.maximum(sel - eps32, (eps32 + gap32) - sel), np.float32(0.0)
            )
        else:
            order = np.argsort(tau, kind="stable")
            ids = order[: t.k].copy()
            if t.k >= self.spec.v_z:
                split32 = np.float32(tau.max())
            else:
                split32 = np.float32(0.5) * (tau[order[t.k - 1]] + tau[order[t.k]])
            sel = tau[ids]
            margin = np.maximum(
                np.minimum(eps32, (split32 + np.float32(0.5) * eps32) - sel),
                np.float32(0.0),
            )
        n_min = float(self._tel_n.min())
        return AnytimeAnswer(
            qid=t.qid,
            qtype=t.qtype,
            status="live",
            ids=ids,
            tau=sel.copy(),
            margin=margin,
            split=float(split32),
            n_min=n_min,
            tau_min=float(tau.min()),
            eps_n=_metric_eps_np(
                n_min, t.delta / self.spec.v_z, self.spec.v_x, self.spec.metric
            ),
            delta_upper=du,
            confidence=max(0.0, 1.0 - du),
            round=self.rounds,
            tuples=self.tuples_read,
            tuples_live=self.tuples_read - t.admit_tuples_read,
            eps=t.eps,
            delta=t.delta,
            metric=self.spec.metric,
        )

    def retire(
        self,
        slot: int,
        *,
        exact: bool,
        terminated: bool,
        stopped: bool = False,
        stop_reason: str = "",
    ) -> QueryOutcome:
        """Snapshot a slot's answer, free the slot, record the outcome.

        ``exact`` is forced True whenever the whole surviving population
        has been read — the answer then rests on a complete read no
        matter why the query is retiring (MatchResult.exact's contract;
        with quarantined blocks "complete" means complete over the
        survivors and the outcome says so via ``degraded``). Callers
        must be at a poll boundary (mirrors fresh, i.e. after `_sync`).

        ``stopped``/``stop_reason`` record an SLA early stop (StopPolicy
        or supervisor deadline); the outcome then carries the honest
        anytime statement of that poll.
        """
        anytime = self.peek(slot)
        t = self.tickets.pop(slot)
        degraded = self.blocks_quarantined > 0
        if degraded:
            exact = exact or bool(self.read_mask[~self.quarantined].all())
        else:
            exact = exact or bool(self.read_mask.all())
        view = slot_state(self.state, slot)
        if t.qtype == "closeness":
            # The close set, nearest first — in_top_k holds the close
            # labels for closeness slots (`dev.assign_closeness`); its
            # size is data-dependent, not k.
            close = np.flatnonzero(np.asarray(view.in_top_k))
            order = np.argsort(
                np.asarray(view.tau)[close], kind="stable"
            )
            ids = close[order]
        else:
            ids = np.asarray(histsim.top_k_ids(view, t.k))
        # A query admitted and retired inside one running pass still
        # saw sampling activity — count that partial pass; a query that
        # retired before any window ran while it was live saw none.
        passes = self.passes - t.admit_passes
        if passes == 0 and self.rounds > t.admit_rounds:
            passes = 1
        outcome = QueryOutcome(
            qid=t.qid,
            ids=ids,
            state=view,
            delta_upper=float(view.delta_upper),
            exact=exact,
            terminated=terminated,
            rounds=self.rounds - t.admit_rounds,
            passes=passes,
            blocks_read=self.blocks_read - t.admit_blocks_read,
            blocks_considered=self.blocks_considered - t.admit_blocks_considered,
            tuples_read=self.tuples_read - t.admit_tuples_read,
            wall_time_s=time.perf_counter() - t.admit_time,
            degraded=degraded,
            eps_effective=t.eps + (self.eps_inflation if degraded else 0.0),
            blocks_quarantined=self.blocks_quarantined,
            qtype=t.qtype,
            stopped=stopped,
            stop_reason=stop_reason,
            anytime=anytime,
        )
        anytime.status = "done"
        anytime.exact = outcome.exact
        anytime.stopped = stopped
        anytime.stop_reason = stop_reason
        self.state = clear_slot(self.state, jnp.asarray(slot, jnp.int32), spec=self.spec)
        self.outcomes[t.qid] = outcome
        if self.telemetry is not None:
            self._c_retired.inc(1)
            self._h_q_tuples.observe(outcome.tuples_read)
            self._h_q_rounds.observe(outcome.rounds)
            self._h_q_wall.observe(outcome.wall_time_s)
            self.telemetry.tracer.emit(
                "query_retire", qid=t.qid, slot=slot, exact=outcome.exact,
                terminated=outcome.terminated, rounds=outcome.rounds,
                passes=outcome.passes, blocks=outcome.blocks_read,
                tuples=outcome.tuples_read,
                delta_upper=outcome.delta_upper, wall_s=outcome.wall_time_s,
                stopped=outcome.stopped, stop_reason=outcome.stop_reason,
            )
        return outcome

    def _poll_terminated(self) -> None:
        """Retire every live query whose termination bound has fired
        (judged on the last-polled bounds — call after `_sync`), then
        every one whose SLA StopPolicy fires. The statistical rule is
        checked FIRST, so a query that converges at the same poll its
        SLA would trip returns the normal terminated answer."""
        if not self.tickets:
            return
        du = self._delta_upper
        now = time.perf_counter()
        for slot in list(self.tickets):
            t = self.tickets[slot]
            if du[slot] < t.delta:
                self.retire(slot, exact=False, terminated=True)
                continue
            if t.stop is None:
                continue
            reason = t.stop.fired(
                wall_s=now - t.admit_time,
                confidence=max(0.0, 1.0 - float(du[slot])),
                tuples=self.tuples_read - t.admit_tuples_read,
            )
            if reason:
                self.retire(
                    slot, exact=False, terminated=False,
                    stopped=True, stop_reason=reason,
                )

    # -- the loop ----------------------------------------------------------

    def _open_pass_stream(self, pass_order: np.ndarray) -> tuple:
        """(round stream, number of rounds) for one pass over
        ``pass_order``. The base scheduler chunks the global visit
        order into lookahead windows served by its single source; the
        data-parallel pump overrides this to zip one shard-local window
        stream per worker. The returned stream must support .close()."""
        windows = [
            pass_order[p : p + self.window]
            for p in range(0, pass_order.size, self.window)
        ]
        return self.source.stream(windows, pad_to=self.window), len(windows)

    def _dispatch_round(self, wd: WindowData) -> None:
        """One fused sampling round over prepared window data (no host
        sync — polling is the loop's cadence decision)."""
        self.state, self.cursor = fused_round(
            self.state, self.cursor, wd,
            spec=self.spec, policy=self.policy, plans=self.plans,
        )

    def _fetch_window(self, win: np.ndarray) -> WindowData:
        """Window data for one ad-hoc (global-id) window — the pump
        overrides this to split the window by block ownership and
        assemble the per-worker shards."""
        return self.source.fetch(win, pad_to=max(self.window, win.size))

    def _dispatch_ingest(self, wd: WindowData) -> None:
        """One exact-completion ingest round over prepared window data."""
        self.state, self.cursor = ingest_round(
            self.state, self.cursor, wd, spec=self.spec, plans=self.plans
        )

    def run_window(self, win: np.ndarray) -> int:
        """Mark one lookahead window against the union active set and
        ingest the marked blocks; polls immediately (poll_every=1
        semantics — the incremental-serving unit `MatchServer.step`
        builds on). Returns the number of blocks read."""
        win = np.asarray(win)
        if win.size == 0:
            return 0
        before = self.blocks_read
        if self.telemetry is None:
            wd = self._fetch_window_or_quarantine(win)
            if wd is not None:
                self._dispatch_round(wd)
            self._sync()
        else:
            acc = _BatchAcc()
            t0 = time.perf_counter()
            wd = self._fetch_window_or_quarantine(win)
            acc.gather_s = time.perf_counter() - t0
            if wd is not None:
                t0 = time.perf_counter()
                self._dispatch_round(wd)
                acc.dispatch_s = time.perf_counter() - t0
                acc.windows = 1
            t0 = time.perf_counter()
            self._sync()
            acc.sync_s = time.perf_counter() - t0
            self._emit_round_batch(acc)
        self.loop_syncs += 1
        return self.blocks_read - before

    def _fetch_window_or_quarantine(self, win: np.ndarray) -> Optional[WindowData]:
        """Fetch an ad-hoc window, converting a `WindowQuarantined`
        verdict into probe-set removal (None = the window is gone; the
        caller's next poll sees the degraded population)."""
        try:
            return self._fetch_window(win)
        except WindowQuarantined as exc:
            self.quarantine_blocks(exc.block_ids, reason="fetch")
            return None

    def complete_remaining(self) -> None:
        """Exact completion: read every unread block into the shared counts.

        Afterwards the empirical counts equal the true ones, so every
        answer drawn from them is exact and the guarantees hold
        deterministically. Counts as one pass (over the remainder) and
        one round per chunk — the Scan baseline in `engine.run_engine`
        is exactly this path on a fresh scheduler.
        """
        self._sync()
        remaining = np.where(~self.read_mask & ~self.quarantined)[0]
        if remaining.size == 0:
            return
        self.passes += 1
        t0 = time.perf_counter()
        windows = 0
        stream, _ = self._open_pass_stream(remaining)
        try:
            for wd in stream:
                self._dispatch_ingest(wd)
                windows += 1
        finally:
            stream.close()
        self.state = stats_step(self.state, spec=self.spec, plan=self.plans.tau)
        self._sync()
        if self.telemetry is not None:
            self.telemetry.tracer.emit(
                "exact_completion", windows=windows, blocks=int(remaining.size),
                rounds=self.rounds, tuples_read=self.tuples_read,
                dur_s=time.perf_counter() - t0,
            )

    def pump(
        self,
        *,
        max_rounds: int = 1_000_000,
        max_passes: int = 4,
        on_round: Optional[Callable[["SharedCountsScheduler"], None]] = None,
    ) -> None:
        """Drive windows until every live query resolves.

        Dispatches `fused_round`s back-to-back through the source's
        `stream` (overlapped gathering with `PrefetchSource`) and only
        polls the device every ``poll_every`` windows; retirement,
        admission (via on_round) and the budget check happen at poll
        boundaries, so with ``poll_every > 1`` each may lag the device
        by up to ``poll_every - 1`` windows.

        on_round: called at each poll (post-retirement) — the serving
        frontend uses it to admit pending queries into slots freed
        mid-stream.

        max_rounds/max_passes budget THIS call, not the scheduler's
        lifetime: a long-lived server calling pump per batch gets the
        full budget every time.
        """
        tel = self.telemetry
        self.budget_exhausted = False
        try:
            self._pump(max_rounds=max_rounds, max_passes=max_passes,
                       on_round=on_round)
        finally:
            # one batched drain per pump call — counters and curves are
            # current whenever the loop hands control back
            self.flush_telemetry()

    def _pump(
        self,
        *,
        max_rounds: int,
        max_passes: int,
        on_round: Optional[Callable[["SharedCountsScheduler"], None]],
    ) -> None:
        tel = self.telemetry
        self._sync()
        rounds0, passes0 = self.rounds, self.passes
        # A late-admitted query may already terminate on the accumulated
        # shared counts, before any new window is read.
        self._poll_terminated()
        while self.tickets and self.passes - passes0 < max_passes:
            pass_order = self.order[
                ~self.read_mask[self.order] & ~self.quarantined[self.order]
            ]
            if pass_order.size == 0:
                break
            self.passes += 1
            pass_start_rounds = self.rounds
            pass_start_blocks = self.blocks_read
            stream, n_rounds = self._open_pass_stream(pass_order)
            dispatched = 0
            if tel is None:
                acc = None
                rounds_iter = stream
            else:
                tel.tracer.emit("pass_start", passes=self.passes,
                                windows=n_rounds, unread=int(pass_order.size))
                acc = _BatchAcc()
                rounds_iter = _timed_iter(stream, acc)
            try:
                for dispatched, wd in enumerate(rounds_iter, start=1):
                    if acc is None:
                        self._dispatch_round(wd)
                    else:
                        t0 = time.perf_counter()
                        self._dispatch_round(wd)
                        acc.dispatch_s += time.perf_counter() - t0
                        acc.windows += 1
                    if dispatched % self.poll_every == 0 or dispatched == n_rounds:
                        if acc is None:
                            self._sync()
                        else:
                            t0 = time.perf_counter()
                            self._sync()
                            acc.sync_s += time.perf_counter() - t0
                            self._emit_round_batch(acc)
                        self.loop_syncs += 1
                        self._poll_terminated()
                        if on_round is not None:
                            on_round(self)
                        if self.rounds - rounds0 >= max_rounds:
                            # Budget cut: live queries stay best-effort
                            # (the caller decides; no silent exact
                            # completion).
                            self.budget_exhausted = True
                            if tel is not None:
                                tel.tracer.emit(
                                    "budget_exhausted", rounds=self.rounds,
                                    live=len(self.tickets),
                                )
                            return
                        if not self.tickets:
                            break
            finally:
                stream.close()
            if dispatched == 0 or (
                dispatched % self.poll_every != 0 and dispatched != n_rounds
            ):
                # The stream ended short of the final scheduled poll —
                # only possible when a resilient source quarantined (and
                # skipped) trailing windows of the pass (fault-free, the
                # ``dispatched == n_rounds`` poll always fires). Without
                # this catch-up poll the zero-progress check below would
                # judge stale mirrors and the drained quarantine mask
                # would lag a pass behind.
                if acc is None:
                    self._sync()
                else:
                    t0 = time.perf_counter()
                    self._sync()
                    acc.sync_s += time.perf_counter() - t0
                    self._emit_round_batch(acc)
                self.loop_syncs += 1
                self._poll_terminated()
                if on_round is not None:
                    on_round(self)
            if self.blocks_read - pass_start_blocks == 0 and self.tickets:
                # "No unread block can help" was judged against the
                # active sets live DURING the pass — a query admitted in
                # its final windows deserves one fresh pass of its own
                # before we give up on sampling.
                fresh = any(
                    t.admit_rounds >= pass_start_rounds for t in self.tickets.values()
                )
                if not fresh:
                    break
        if self.tickets:
            # Exact fallback for the stragglers.
            self.complete_remaining()
            du = self._delta_upper
            for slot in list(self.tickets):
                fired = bool(du[slot] < self.tickets[slot].delta)
                self.retire(slot, exact=True, terminated=fired)
            if on_round is not None:
                on_round(self)
