"""Measurement-driven kernel plans: pick the wall-clock winner, not the
byte winner.

`BENCH_stats.json` exposed the gap this module closes: the Q-batched tau
kernel cuts HBM bytes 7.6x at Q=8 yet *loses* wall-clock to the
Q-unrolled path on XLA:CPU at Q>=4, and the fused ingest+rowsum pass
loses to the two-step form — the serving loop was hard-coded to the
theoretically-leanest variant instead of the measured-fastest one. Here
every dispatch decision the kernels package makes becomes a *plan*
looked up per shape key, and plans come from measurement:

  tau   — per ``(backend, V_Z, V_X, Q, dtype)``:
            * variant: "batched" (one counts pass scores all Q targets),
              "unrolled" (Q single-query passes — the PR-2 path), or
              "xla" (the fused 3D broadcast form, XLA's choice of
              schedule);
            * z_tile / x_tile Pallas tile sizes and the single- vs
              forced two-sweep V_X phase (TPU knobs; the CPU ref path
              has no tiling, so CPU plans keep the defaults);
            * lowprec: stream the counts matrix as uint16 (halving tau
              HBM traffic) behind a runtime overflow gate — the counts
              are integer-valued f32, and any entry above the uint16
              range falls back to the full-precision path via lax.cond,
              so results stay exact (an in-range uint16 round-trip of an
              integer-valued f32 is the identity).
  ingest — per ``(backend, V_Z, V_X, dtype)``: fused histogram+rowsums
           (one pass, rows reduced from the VMEM-resident block) vs the
           two-step form (histogram, then a separate row reduction),
           plus the histogram kernel's s_tile / z_tile.

Every candidate is bit-identical to the pre-autotune kernels on
integer-valued counts (enforced by tests/test_autotune.py, which sweeps
the full candidate space); the tuner is therefore free to pick purely
by measured wall time. Selection is noise-robust: the fastest candidate
wins only if it beats the "unrolled" (tau) / "fused" (ingest) reference
comparator by ``margin`` — otherwise the comparator is kept, so a
within-noise measurement can never flip the serving loop onto a variant
that merely tied.

Plan persistence (CI determinism):

  * `PlanRegistry` serializes to ``benchmarks/results/tuned/<backend>.json``
    — COMMITTED to the repo, so every CI run and every process dispatches
    from the same bytes instead of re-measuring on a noisy shared runner.
  * Lookups that miss the file fall back to `DEFAULT_TAU` /
    `DEFAULT_INGEST` (exactly the pre-autotune dispatch) silently; a
    stale schema, corrupt file, or malformed entry falls back with a
    ``warnings.warn`` — never a crash.
  * ``FASTMATCH_AUTOTUNE=1`` makes `resolve_plans` (the eager,
    scheduler-construction entry point) tune-on-miss and persist the
    result; ``FASTMATCH_PLANS_DIR`` points the registry somewhere else
    (tests use a tmpdir).
  * After changing the plan file on disk call `reload()`: it swaps the
    process registry AND clears the jax jit caches, because "auto" plan
    arguments are resolved at trace time and baked into compiled
    programs.

`repro.kernels.ops` routes `l1_distance_multi` / `histogram_with_rowsums`
through `run_tau` / `run_ingest`, and the three round-builders
(`multiquery.fused_round`, `distributed.make_distributed_round`,
`distributed.make_pump_round`) thread a `PlanPair` through, so the
serving loop, the explicit-collective mesh round, and the data-parallel
pump all run the measured-fastest configuration — and a real GPU/TPU
gets a correct tuned plan on first contact by committing its own
``<backend>.json`` instead of inheriting XLA:CPU's compromises.
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import time
import warnings
from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import metrics, ref
from repro.kernels.histogram import histogram_pallas, histogram_with_rowsums_pallas

# Single-block V_X bound of the Q=1 kernel the "unrolled" variant stacks.
_UNROLLED_MAX_VX = metrics.MAX_SINGLE_BLOCK_VX

__all__ = [
    "DEFAULT_INGEST",
    "DEFAULT_TAU",
    "IngestPlan",
    "PlanPair",
    "PlanRegistry",
    "TauPlan",
    "get_ingest_plan",
    "get_tau_plan",
    "ingest_key",
    "plan_path",
    "registry",
    "reload",
    "resolve_plans",
    "run_ingest",
    "run_tau",
    "tau_bytes",
    "tau_key",
    "tune_ingest",
    "tune_tau",
    "tau_candidates",
    "ingest_candidates",
]

# Schema 2: tau keys carry a ``metric`` field (the pluggable-metric
# layer tunes each distance separately — variant tradeoffs shift with
# the score's VPU cost). Schema-1 files warn-and-default on load.
PLAN_SCHEMA = 2
TAU_VARIANTS = ("batched", "unrolled", "xla")
# uint16 overflow gate for the low-precision counts path. 2**16 - 1;
# every integer-valued f32 at or below this round-trips exactly.
_U16_MAX = 65535.0
# A non-comparator candidate must beat the comparator by this fraction
# of wall time to be selected — measured deltas inside the margin are
# indistinguishable from run-to-run noise on a shared host.
DEFAULT_MARGIN = 0.07


# ---------------------------------------------------------------------------
# Plans
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TauPlan:
    """One tau (distance) dispatch decision. Hashable: jit-static."""

    variant: str = "batched"  # "batched" | "unrolled" | "xla"
    z_tile: int = 256  # Pallas candidate-row tile
    x_tile: int = 4096  # Pallas lane tile (single-sweep bound)
    sweeps: int = 0  # 0 = auto (by padded V_X), 1 = single, 2 = forced two-sweep
    lowprec: bool = False  # uint16 counts traffic behind the overflow gate

    def validate(self) -> None:
        if self.variant not in TAU_VARIANTS:
            raise ValueError(f"unknown tau variant {self.variant!r}; have {TAU_VARIANTS}")
        if self.z_tile < 8:
            raise ValueError(f"need z_tile >= 8, got {self.z_tile}")
        if self.x_tile % 128 != 0 or self.x_tile <= 0:
            raise ValueError(f"x_tile must be a positive lane multiple of 128, got {self.x_tile}")
        if self.sweeps not in (0, 1, 2):
            raise ValueError(f"sweeps must be 0 (auto), 1 or 2, got {self.sweeps}")


@dataclasses.dataclass(frozen=True)
class IngestPlan:
    """One ingest (histogram + row-sums) dispatch decision."""

    fused: bool = True  # one fused pass vs histogram + separate reduction
    s_tile: int = 512  # Pallas sample tile
    z_tile: int = 256  # Pallas candidate-row tile

    def validate(self) -> None:
        if self.s_tile < 8:
            raise ValueError(f"need s_tile >= 8, got {self.s_tile}")
        if self.z_tile < 8:
            raise ValueError(f"need z_tile >= 8, got {self.z_tile}")


@dataclasses.dataclass(frozen=True)
class PlanPair:
    """The (tau, ingest) pair one serving round consumes."""

    tau: TauPlan = dataclasses.field(default_factory=lambda: DEFAULT_TAU)
    ingest: IngestPlan = dataclasses.field(default_factory=lambda: DEFAULT_INGEST)


# The defaults reproduce the pre-autotune dispatch bit for bit: batched
# tau with the kernel's own tile constants, fused ingest.
DEFAULT_TAU = TauPlan()
DEFAULT_INGEST = IngestPlan()


def tau_key(v_z: int, v_x: int, q: int, dtype: str = "float32", metric: str = "l1") -> str:
    return f"vz={v_z},vx={v_x},q={q},dtype={dtype},metric={metric}"


def ingest_key(v_z: int, v_x: int, dtype: str = "float32") -> str:
    return f"vz={v_z},vx={v_x},dtype={dtype}"


def tau_bytes(v_z: int, v_x: int, q: int, plan: TauPlan, metric: str = "l1") -> int:
    """Analytic HBM bytes per tau round under ``plan`` (the roofline
    model `benchmarks/stats_throughput.py` reports), via the metric's
    registry ``bytes_model`` — every shipped metric streams identically
    (they differ in VPU flops only), so the model is shared.

    counts traffic: 1 pass (batched single-sweep / xla), 2 passes
    (batched forced- or auto- two-sweep), Q passes (unrolled); targets +
    output are Q * (V_X + V_Z) either way. lowprec halves the counts
    term (uint16 vs f32 is 2 bytes vs 4).
    """
    vx_pad = max(128, -(-v_x // 128) * 128)
    if plan.variant == "unrolled":
        passes = q
    elif plan.variant == "xla":
        passes = 1
    else:
        passes = 2 if plan.sweeps == 2 or (plan.sweeps == 0 and vx_pad > plan.x_tile) else 1
    return metrics.coerce_metric(metric).bytes_model(
        v_z, v_x, q, passes=passes, counts_itemsize=(2 if plan.lowprec else 4)
    )


# ---------------------------------------------------------------------------
# Executors — the ONLY code paths plans dispatch to; the tuner measures
# through these same functions, so "measured fastest" is "what runs".
# ---------------------------------------------------------------------------


def _tau_inner(plan: TauPlan, *, engine: str, interpret: bool,
               metric: str = "l1") -> Callable:
    """(counts, q_hat) -> (Q, V_Z) tau for one variant, full precision.

    Every branch normalizes in f32 with the exact elementwise sequence
    of `metrics.distance_ref` (row sum -> max(row, 1) divide -> score ->
    lane reduce), so on integer-valued counts all variants of one metric
    are bit-identical (tests/test_autotune.py and tests/test_metrics.py
    sweep the space).
    """
    if plan.variant == "xla":
        return partial(metrics.distance_multi_xla, metric=metric)
    if engine == "pallas":
        if plan.variant == "unrolled":
            def unrolled_pallas(counts, q_hat):
                return jnp.stack([
                    metrics.distance_pallas(
                        counts, q_hat[i], metric=metric,
                        z_tile=plan.z_tile, interpret=interpret,
                    )
                    for i in range(q_hat.shape[0])
                ])
            return unrolled_pallas
        return partial(
            metrics.distance_multi_pallas,
            metric=metric,
            z_tile=plan.z_tile,
            x_tile=plan.x_tile,
            sweeps=plan.sweeps,
            interpret=interpret,
        )
    if plan.variant == "unrolled":
        def unrolled_ref(counts, q_hat):
            return jnp.stack([
                metrics.distance_ref(counts, q_hat[i], metric=metric)
                for i in range(q_hat.shape[0])
            ])
        return unrolled_ref
    return partial(metrics.distance_multi_ref, metric=metric)


def _tau_usable(plan: TauPlan, *, engine: str, v_x: int) -> bool:
    """Whether ``plan`` can run at all for this engine/shape (the
    single-query Pallas kernel rejects V_X past one VMEM block)."""
    if engine == "pallas" and plan.variant == "unrolled" and v_x > _UNROLLED_MAX_VX:
        return False
    if plan.sweeps == 1 and max(128, -(-v_x // 128) * 128) > plan.x_tile:
        return False  # forced single-sweep cannot cover a lane-tiled V_X
    return True


def run_tau(
    counts: jax.Array,
    q_hat: jax.Array,
    *,
    plan: TauPlan,
    engine: str,
    interpret: bool = False,
    metric: str = "l1",
) -> jax.Array:
    """Dispatch one (Q, V_Z) tau computation per ``plan`` and ``metric``.

    An unusable plan (e.g. a TPU-tuned unrolled plan hitting a
    lane-tiled V_X) falls back to `DEFAULT_TAU` with a warning — plans
    steer performance, never correctness or availability. The metric is
    orthogonal to the plan: every variant runs every registry metric
    (the lowprec uint16 counts gate below is metric-agnostic too — all
    kernels upcast to f32 before normalizing).
    """
    plan.validate()
    if not _tau_usable(plan, engine=engine, v_x=counts.shape[1]):
        _warn_once(
            f"tau plan {plan} unusable for engine={engine} "
            f"V_X={counts.shape[1]}; falling back to defaults"
        )
        plan = DEFAULT_TAU
    inner = _tau_inner(plan, engine=engine, interpret=interpret, metric=metric)
    if not plan.lowprec:
        return inner(counts, q_hat)
    # uint16 overflow gate: in-range integer-valued f32 counts stream as
    # uint16 (the kernels upcast per tile, so the halved traffic is
    # real); any entry past the uint16 range takes the full-precision
    # branch — exactness is never data-dependent.
    fits = jnp.max(counts) <= _U16_MAX
    return jax.lax.cond(
        fits,
        lambda c: inner(c.astype(jnp.uint16), q_hat),
        lambda c: inner(c, q_hat),
        counts,
    )


def run_ingest(
    z_idx: jax.Array,
    x_idx: jax.Array,
    *,
    v_z: int,
    v_x: int,
    plan: IngestPlan,
    engine: str,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Dispatch one ((V_Z, V_X), (V_Z,)) histogram + row-sums pass.

    fused=True is the one-pass kernel (rows reduced from the resident
    counts block); fused=False is the PR-2 two-step (histogram, then a
    separate row reduction). Both are exact on integer counts, so the
    plan is free to pick the measured-fastest form.
    """
    plan.validate()
    if engine == "pallas":
        if plan.fused:
            return histogram_with_rowsums_pallas(
                z_idx, x_idx, v_z=v_z, v_x=v_x,
                s_tile=plan.s_tile, z_tile=plan.z_tile, interpret=interpret,
            )
        counts = histogram_pallas(
            z_idx, x_idx, v_z=v_z, v_x=v_x,
            s_tile=plan.s_tile, z_tile=plan.z_tile, interpret=interpret,
        )
        return counts, jnp.sum(counts, axis=1)
    if plan.fused:
        return ref.histogram_with_rowsums_ref(z_idx, x_idx, v_z=v_z, v_x=v_x)
    counts = ref.histogram_ref(z_idx, x_idx, v_z=v_z, v_x=v_x)
    return counts, jnp.sum(counts, axis=1)


_warned: set = set()


def _warn_once(msg: str) -> None:
    if msg not in _warned:
        _warned.add(msg)
        warnings.warn(msg, stacklevel=3)


# ---------------------------------------------------------------------------
# Registry: the committed JSON artifact
# ---------------------------------------------------------------------------


def plans_dir() -> pathlib.Path:
    """``FASTMATCH_PLANS_DIR`` or the committed repo location."""
    env = os.environ.get("FASTMATCH_PLANS_DIR")
    if env:
        return pathlib.Path(env)
    # src/repro/kernels/autotune.py -> repo root / benchmarks/results/tuned
    return pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "tuned"


def plan_path(backend: Optional[str] = None) -> pathlib.Path:
    backend = backend or jax.default_backend()
    return plans_dir() / f"{backend}.json"


def _plan_from_entry(entry: dict, cls):
    fields = {f.name for f in dataclasses.fields(cls)}
    plan = cls(**{k: v for k, v in entry.items() if k in fields})
    plan.validate()
    return plan


class PlanRegistry:
    """All tuned plans for one backend, plus their provenance.

    Lookup misses return the defaults silently (an untuned shape is
    normal); structural problems — stale schema, corrupt JSON, a
    malformed entry — fall back with a warning, never an exception, so
    a bad plan file can degrade dispatch but not availability.
    """

    def __init__(self, backend: Optional[str] = None):
        self.backend = backend or jax.default_backend()
        self.tau: Dict[str, TauPlan] = {}
        self.ingest: Dict[str, IngestPlan] = {}
        self.meta: dict = {}
        self.path: Optional[pathlib.Path] = None

    # -- persistence -------------------------------------------------------

    @classmethod
    def load(cls, path: Optional[pathlib.Path] = None, backend: Optional[str] = None
             ) -> "PlanRegistry":
        reg = cls(backend=backend)
        reg.path = pathlib.Path(path) if path is not None else plan_path(reg.backend)
        if not reg.path.exists():
            return reg
        try:
            doc = json.loads(reg.path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            _warn_once(f"unreadable kernel-plan file {reg.path}: {e}; using default plans")
            return reg
        if not isinstance(doc, dict) or doc.get("schema") != PLAN_SCHEMA:
            _warn_once(
                f"kernel-plan file {reg.path} has schema "
                f"{doc.get('schema') if isinstance(doc, dict) else '<not a dict>'!r}, "
                f"expected {PLAN_SCHEMA}; using default plans"
            )
            return reg
        if doc.get("backend") not in (None, reg.backend):
            _warn_once(
                f"kernel-plan file {reg.path} was tuned for backend "
                f"{doc.get('backend')!r}, running on {reg.backend!r}; using default plans"
            )
            return reg
        reg.meta = {k: v for k, v in doc.items() if k not in ("tau", "ingest")}
        for key, entry in (doc.get("tau") or {}).items():
            try:
                reg.tau[key] = _plan_from_entry(entry, TauPlan)
            except (TypeError, ValueError) as e:
                _warn_once(f"dropping malformed tau plan {key!r} in {reg.path}: {e}")
        for key, entry in (doc.get("ingest") or {}).items():
            try:
                reg.ingest[key] = _plan_from_entry(entry, IngestPlan)
            except (TypeError, ValueError) as e:
                _warn_once(f"dropping malformed ingest plan {key!r} in {reg.path}: {e}")
        return reg

    def save(self, path: Optional[pathlib.Path] = None) -> pathlib.Path:
        path = pathlib.Path(path) if path is not None else (self.path or plan_path(self.backend))
        path.parent.mkdir(parents=True, exist_ok=True)
        doc = dict(schema=PLAN_SCHEMA, backend=self.backend, **{
            k: v for k, v in self.meta.items() if k not in ("schema", "backend")
        })
        doc["tau"] = {k: dataclasses.asdict(v) for k, v in sorted(self.tau.items())}
        doc["ingest"] = {k: dataclasses.asdict(v) for k, v in sorted(self.ingest.items())}
        path.write_text(json.dumps(doc, indent=2, sort_keys=False) + "\n")
        self.path = path
        return path

    # -- lookup ------------------------------------------------------------

    def tau_plan(self, v_z: int, v_x: int, q: int, dtype: str = "float32",
                 metric: str = "l1") -> TauPlan:
        return self.tau.get(tau_key(v_z, v_x, q, dtype, metric), DEFAULT_TAU)

    def ingest_plan(self, v_z: int, v_x: int, dtype: str = "float32") -> IngestPlan:
        return self.ingest.get(ingest_key(v_z, v_x, dtype), DEFAULT_INGEST)

    def decisions(self) -> str:
        """Canonical serialization of every dispatch decision this
        registry would make — the byte-stable artifact the determinism
        tests compare across loads and processes (timing metadata is
        deliberately NOT part of it)."""
        return json.dumps(
            dict(
                backend=self.backend,
                tau={k: dataclasses.asdict(v) for k, v in sorted(self.tau.items())},
                ingest={k: dataclasses.asdict(v) for k, v in sorted(self.ingest.items())},
            ),
            sort_keys=True,
        )


_registry: Optional[PlanRegistry] = None


def registry() -> PlanRegistry:
    """The process-wide plan registry, loaded lazily from `plan_path()`."""
    global _registry
    if _registry is None:
        _registry = PlanRegistry.load()
    return _registry


def reload(path: Optional[pathlib.Path] = None, backend: Optional[str] = None) -> PlanRegistry:
    """Swap the process registry for a fresh load AND clear the jax jit
    caches: "auto" plan lookups happen at trace time, so compiled
    programs hold the plans that were loaded when they were traced."""
    global _registry
    _registry = PlanRegistry.load(path=path, backend=backend)
    jax.clear_caches()
    return _registry


def get_tau_plan(v_z: int, v_x: int, q: int, dtype: str = "float32",
                 metric: str = "l1") -> TauPlan:
    return registry().tau_plan(v_z, v_x, q, dtype, metric)


def get_ingest_plan(v_z: int, v_x: int, dtype: str = "float32") -> IngestPlan:
    return registry().ingest_plan(v_z, v_x, dtype)


def coerce_tau_plan(plan, v_z: int, v_x: int, q: int, metric: str = "l1") -> TauPlan:
    """Resolve an ops-level ``plan`` argument: "auto" consults the
    registry (at trace time — shapes are concrete there), None/"default"
    pins the pre-autotune dispatch, a `TauPlan` passes through."""
    if plan == "auto":
        return get_tau_plan(v_z, v_x, q, metric=metric)
    if plan is None or plan == "default":
        return DEFAULT_TAU
    if isinstance(plan, TauPlan):
        return plan
    raise TypeError(f"plan must be 'auto', 'default', None or TauPlan, got {plan!r}")


def coerce_ingest_plan(plan, v_z: int, v_x: int) -> IngestPlan:
    if plan == "auto":
        return get_ingest_plan(v_z, v_x)
    if plan is None or plan == "default":
        return DEFAULT_INGEST
    if isinstance(plan, IngestPlan):
        return plan
    raise TypeError(f"plan must be 'auto', 'default', None or IngestPlan, got {plan!r}")


def resolve_plans(
    v_z: int,
    v_x: int,
    q: int,
    *,
    n_samples: Optional[int] = None,
    dtype: str = "float32",
    metric: str = "l1",
) -> PlanPair:
    """The eager (host-side) plan resolution the round-builders use at
    construction: registry lookup, with ``FASTMATCH_AUTOTUNE=1``
    additionally tuning any missing key on the spot and persisting the
    result. Never called at trace time, so tune-on-miss may freely run
    device code. Tau keys are per-metric (the score shifts the
    variant tradeoff); the ingest plan is metric-independent (counts
    are shared by every metric and query type)."""
    reg = registry()
    tkey, ikey = tau_key(v_z, v_x, q, dtype, metric), ingest_key(v_z, v_x, dtype)
    if os.environ.get("FASTMATCH_AUTOTUNE") == "1":
        dirty = False
        if tkey not in reg.tau:
            reg.tau[tkey], _ = tune_tau(v_z, v_x, q, metric=metric)
            dirty = True
        if ikey not in reg.ingest:
            reg.ingest[ikey], _ = tune_ingest(
                v_z, v_x, n_samples=n_samples or _default_ingest_samples(v_z, v_x)
            )
            dirty = True
        if dirty:
            reg.save()
    return PlanPair(tau=reg.tau.get(tkey, DEFAULT_TAU), ingest=reg.ingest.get(ikey, DEFAULT_INGEST))


# ---------------------------------------------------------------------------
# The tuner
# ---------------------------------------------------------------------------


def _default_ingest_samples(v_z: int, v_x: int) -> int:
    # lookahead-window-sized batches dominate production ingest; scale
    # with the matrix so tiny test shapes stay fast to tune.
    return int(min(65_536, max(4_096, v_z * v_x // 16)))


def _measure(fn: Callable, args: tuple, *, reps: int) -> float:
    """Median seconds per call, jit-warmed (same harness the stats
    benchmark uses, so tuner-measured == benchmark-measured)."""
    jax.block_until_ready(fn(*args))
    t = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        t.append(time.perf_counter() - t0)
    return float(np.median(t))


def tau_candidates(engine: str, v_z: int, v_x: int, q: int) -> list:
    """The candidate space for one tau key. CPU: variants x lowprec
    (the ref path has no tiling). TPU: additionally tile sizes and the
    forced two-sweep phase for the batched kernel."""
    cands = []
    for variant in TAU_VARIANTS:
        base = TauPlan(variant=variant)
        if not _tau_usable(base, engine=engine, v_x=v_x):
            continue
        cands.append(base)
        cands.append(dataclasses.replace(base, lowprec=True))
        if engine == "pallas" and variant == "batched":
            for z_tile in (128, 256, 512):
                for x_tile in (1024, 2048, 4096):
                    for sweeps in (0, 2):
                        c = TauPlan(variant="batched", z_tile=z_tile,
                                    x_tile=x_tile, sweeps=sweeps)
                        if c not in cands:
                            cands.append(c)
    return cands


def ingest_candidates(engine: str, v_z: int, v_x: int) -> list:
    cands = [IngestPlan(fused=True), IngestPlan(fused=False)]
    if engine == "pallas":
        for s_tile in (256, 512, 1024):
            for z_tile in (128, 256, 512):
                for fused in (True, False):
                    c = IngestPlan(fused=fused, s_tile=s_tile, z_tile=z_tile)
                    if c not in cands:
                        cands.append(c)
    return cands


def _pick(timed: Dict, comparator, *, margin: float):
    """Fastest candidate, unless the comparator is within ``margin`` of
    it — measured deltas inside the margin are noise, and keeping the
    comparator makes the tuned-vs-reference benchmark comparison exact
    (same program) instead of a coin flip."""
    best = min(timed, key=timed.get)
    if comparator in timed and timed[comparator] <= timed[best] * (1.0 + margin):
        return comparator
    return best


def tune_tau(
    v_z: int,
    v_x: int,
    q: int,
    *,
    engine: Optional[str] = None,
    reps: int = 15,
    seed: int = 0,
    margin: float = DEFAULT_MARGIN,
    metric: str = "l1",
) -> Tuple[TauPlan, Dict[TauPlan, float]]:
    """Measure every tau candidate for one (key, metric); return
    (winner, timings).

    The comparator biased toward under ``margin`` is the "unrolled"
    full-precision plan — the PR-2 reference path every speedup in
    `BENCH_stats.json` is quoted against. The candidate space is
    metric-independent; the measurement runs the requested metric's
    score, so e.g. hellinger (two sqrts per lane) may tune differently
    from l1 on the same shape.
    """
    engine = engine or ("pallas" if jax.default_backend() == "tpu" else "ref")
    rng = np.random.default_rng(seed)
    counts = jnp.asarray(rng.integers(0, 50, size=(v_z, v_x)).astype(np.float32))
    q_hat = jnp.asarray(
        np.stack([rng.dirichlet(np.ones(v_x)).astype(np.float32) for _ in range(q)])
    )
    timed: Dict[TauPlan, float] = {}
    for cand in tau_candidates(engine, v_z, v_x, q):
        fn = jax.jit(partial(run_tau, plan=cand, engine=engine, metric=metric))
        timed[cand] = _measure(fn, (counts, q_hat), reps=reps)
    comparator = TauPlan(variant="unrolled")
    return _pick(timed, comparator, margin=margin), timed


def tune_ingest(
    v_z: int,
    v_x: int,
    *,
    n_samples: Optional[int] = None,
    engine: Optional[str] = None,
    reps: int = 15,
    seed: int = 0,
    margin: float = DEFAULT_MARGIN,
) -> Tuple[IngestPlan, Dict[IngestPlan, float]]:
    """Measure every ingest candidate for one key; comparator biased
    toward under ``margin`` is the fused (pre-autotune default) plan."""
    engine = engine or ("pallas" if jax.default_backend() == "tpu" else "ref")
    n = n_samples or _default_ingest_samples(v_z, v_x)
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.integers(-1, v_z, size=n).astype(np.int32))
    x = jnp.asarray(rng.integers(-1, v_x, size=n).astype(np.int32))
    timed: Dict[IngestPlan, float] = {}
    for cand in ingest_candidates(engine, v_z, v_x):
        fn = jax.jit(partial(run_ingest, v_z=v_z, v_x=v_x, plan=cand, engine=engine))
        timed[cand] = _measure(fn, (z, x), reps=reps)
    return _pick(timed, IngestPlan(fused=True), margin=margin), timed
