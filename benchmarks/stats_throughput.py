"""Statistics-engine throughput: Q-batched tau vs unrolled vs the TUNED plan.

The multi-query statistics iteration is tau for every live slot. PR-2
unrolled one `ops.l1_distance` call per slot — Q HBM passes over the
shared (V_Z, V_X) counts matrix per round. The Q-batched
`ops.l1_distance_multi` streams the counts once for all slots, so the
tau bytes moved per round are independent of Q — but bytes are not wall
time (the committed history shows batched LOSING wall-clock on XLA:CPU
at Q>=4), which is why the serving loop now dispatches through
`repro.kernels.autotune` plans. This benchmark measures all three arms
for Q in {1, 2, 4, 8}:

  * tau HBM bytes/round — the roofline bytes-moved model of each path
    (f32; unrolled: Q * (V_Z*V_X + V_X + V_Z); batched:
    sweeps * V_Z*V_X + Q * (V_X + V_Z); tuned: whatever the committed
    plan selects, via `autotune.tau_bytes` — uint16 counts halve the
    counts term). The statistics engine is memory-bound, so bytes
    moved IS the roofline-projected round time on TPU.
  * rounds/sec — measured wall-clock of the jitted stats step on this
    host for the unrolled and batched arms, PLUS the ``tau_tuned`` arm:
    the variant the COMMITTED plan file dispatches for this exact
    (backend, V_Z, V_X, Q) key — i.e. what `multiquery.stats_step`
    actually runs in production. When the plan selects the unrolled
    variant the tuned arm is the same arithmetic program as the
    reference arm, so its speedup is 1.0 by construction
    (``same_program`` marks these rows; ``us_tuned`` still reports the
    independent measurement).

Plus the ingest row-sum delta, now plan-dispatched: fused
`ops.histogram_with_rowsums` vs the PR-2 two-step (histogram + separate
reduction) vs the tuned plan's choice — ``ingest.winner`` records which
form the committed plan runs (the fix for the fused-753us-vs-two-step-
716us regression this file used to document).

Reported rows (benchmarks/run.py CSV schema):

  stats_tau_q{Q}_unrolled  — us per stats round, derived = MB moved
  stats_tau_q{Q}_batched   — us per stats round, derived = MB moved
  stats_tau_q{Q}_tuned     — us per stats round, derived = MB moved
  stats_tau_bytes_q8       — derived = unrolled/batched bytes ratio (>=4 = pass)
  stats_tau_speedup_q8     — derived = measured unrolled/batched wall ratio
  stats_ingest_fused       — us per fused ingest, derived = MB saved/round
  stats_ingest_tuned       — us per tuned ingest, derived = 1.0 if winner=fused

Machine-readable results land in benchmarks/results/BENCH_stats.json
(config stamped with backend/device/jax via `common.env_stamp` so
`check_regression.py` can refuse cross-hardware comparisons). The
regression-gated tuned keys are DETERMINISTIC given the committed plan
file: the chosen variant per Q and the analytic tuned bytes — never the
tuned wall-clock, which shared runners cannot reproduce.

Set STATS_BENCH_SMOKE=1 for the tiny CI configuration (same code path;
exits non-zero if any tau arm is not bit-identical to the unrolled
reference on the production engine or the q=8 bytes reduction drops
below 4x).
"""

from __future__ import annotations

import json
import os
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import env_stamp
from repro.kernels import autotune, ops
from repro.kernels.l1_distance_multi import _X_TILE as _X_BLOCK  # single-sweep lane bound

SMOKE = bool(int(os.environ.get("STATS_BENCH_SMOKE", "0")))
QS = (1, 2, 4, 8)
V_Z, V_X = (256, 256) if SMOKE else (4096, 1024)
N_SAMPLES = 4_096 if SMOKE else 65_536
# smoke kernels are microseconds — reps are nearly free, and the tuned
# arm's measured speedup needs the same noise floor the tuner had
REPS = 25 if SMOKE else 10

RESULTS = pathlib.Path(__file__).parent / "results"


@jax.jit
def _tau_unrolled(counts, q_hat):
    """The PR-2 statistics tau: one kernel call-site per slot."""
    return jnp.stack(
        [ops.l1_distance(counts, q_hat[i]) for i in range(q_hat.shape[0])]
    )


@jax.jit
def _tau_batched(counts, q_hat):
    return ops.l1_distance_multi(counts, q_hat, plan="default")


def _tau_tuned_fn(plan):
    @jax.jit
    def fn(counts, q_hat):
        return ops.l1_distance_multi(counts, q_hat, plan=plan)
    return fn


def _time(fn, *args) -> float:
    """Median seconds per call, jit-warmed."""
    jax.block_until_ready(fn(*args))
    t = []
    for _ in range(REPS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        t.append(time.perf_counter() - t0)
    return float(np.median(t))


def _tau_bytes(q: int) -> tuple:
    """(unrolled, batched) analytic HBM bytes per stats round, f32."""
    vx_pad = max(128, -(-V_X // 128) * 128)
    sweeps = 1 if vx_pad <= _X_BLOCK else 2
    unrolled = q * (V_Z * V_X + V_X + V_Z) * 4
    batched = (sweeps * V_Z * V_X + q * (V_X + V_Z)) * 4
    return unrolled, batched


def run(rows: list) -> None:
    rng = np.random.default_rng(12)
    counts = jnp.asarray(rng.integers(0, 50, size=(V_Z, V_X)).astype(np.float32))
    z = jnp.asarray(rng.integers(-1, V_Z, size=N_SAMPLES).astype(np.int32))
    x = jnp.asarray(rng.integers(-1, V_X, size=N_SAMPLES).astype(np.int32))

    registry = autotune.registry()
    plan_file = registry.path if registry.path and registry.path.exists() else None

    tau_rows, identical, tuned_identical = [], True, True
    tuned_variants = {}
    for q in QS:
        q_hat = jnp.asarray(
            np.stack([rng.dirichlet(np.ones(V_X)).astype(np.float32) for _ in range(q)])
        )
        plan = registry.tau_plan(V_Z, V_X, q)
        tuned_from_file = autotune.tau_key(V_Z, V_X, q) in registry.tau
        # The plan matching the unrolled reference arm means the tuned
        # arm IS the reference arm (same arithmetic program): report
        # speedup 1.0 by construction, not a noisy self-measurement.
        same_program = plan == autotune.TauPlan(variant="unrolled")

        t_unrolled = _time(_tau_unrolled, counts, q_hat)
        t_batched = _time(_tau_batched, counts, q_hat)
        tuned_fn = _tau_tuned_fn(plan)
        t_tuned = _time(tuned_fn, counts, q_hat)

        want = np.asarray(_tau_unrolled(counts, q_hat))
        identical &= bool(np.array_equal(want, np.asarray(_tau_batched(counts, q_hat))))
        tuned_identical &= bool(np.array_equal(want, np.asarray(tuned_fn(counts, q_hat))))

        b_unrolled, b_batched = _tau_bytes(q)
        b_tuned = autotune.tau_bytes(V_Z, V_X, q, plan)
        speedup_tuned = 1.0 if same_program else round(t_unrolled / max(t_tuned, 1e-12), 3)
        tuned_variants[f"q{q}"] = plan.variant + ("+lowprec" if plan.lowprec else "")
        tau_rows.append(
            dict(
                q=q,
                bytes_unrolled=b_unrolled,
                bytes_batched=b_batched,
                bytes_tuned=b_tuned,
                bytes_reduction=round(b_unrolled / b_batched, 3),
                us_unrolled=round(1e6 * t_unrolled, 1),
                us_batched=round(1e6 * t_batched, 1),
                us_tuned=round(1e6 * t_tuned, 1),
                speedup=round(t_unrolled / max(t_batched, 1e-12), 3),
                speedup_tuned=speedup_tuned,
                tuned_variant=tuned_variants[f"q{q}"],
                tuned_from_file=tuned_from_file,
                same_program=same_program,
                rounds_per_sec_unrolled=round(1.0 / max(t_unrolled, 1e-12), 1),
                rounds_per_sec_batched=round(1.0 / max(t_batched, 1e-12), 1),
                rounds_per_sec_tuned=round(1.0 / max(t_tuned, 1e-12), 1),
            )
        )
        rows.append(dict(name=f"stats_tau_q{q}_unrolled",
                         us_per_call=1e6 * t_unrolled,
                         derived=round(b_unrolled / 2**20, 3)))
        rows.append(dict(name=f"stats_tau_q{q}_batched",
                         us_per_call=1e6 * t_batched,
                         derived=round(b_batched / 2**20, 3)))
        rows.append(dict(name=f"stats_tau_q{q}_tuned",
                         us_per_call=1e6 * t_tuned,
                         derived=round(b_tuned / 2**20, 3)))

    # ingest: two-step vs fused vs what the committed plan dispatches
    def two_step(z, x):
        c = ops.histogram(z, x, v_z=V_Z, v_x=V_X)
        return c, jnp.sum(c, axis=1)

    ingest_plan = registry.ingest_plan(V_Z, V_X)
    t_two = _time(jax.jit(two_step), z, x)
    t_fused = _time(
        jax.jit(lambda z, x: ops.histogram_with_rowsums(z, x, v_z=V_Z, v_x=V_X,
                                                        plan="default")), z, x
    )
    t_ingest_tuned = _time(
        jax.jit(lambda z, x: ops.histogram_with_rowsums(z, x, v_z=V_Z, v_x=V_X,
                                                        plan=ingest_plan)), z, x
    )
    ingest_winner = "fused" if ingest_plan.fused else "two_step"
    ingest_saved = V_Z * V_X * 4  # the avoided delta-matrix re-read (fused form)

    by_q = {r["q"]: r for r in tau_rows}
    reduction_q8 = by_q[8]["bytes_reduction"]
    speedup_q8 = by_q[8]["speedup"]
    tuned_speedup_min = min(r["speedup_tuned"] for r in tau_rows)
    tuned_bytes_reduction_q8 = round(
        by_q[8]["bytes_unrolled"] / by_q[8]["bytes_tuned"], 3
    )
    # "independent of Q": the counts-stream term doesn't scale with Q —
    # going 1 -> 8 queries grows batched bytes only by the tiny targets
    # term, so the q8/q1 ratio stays near 1 (vs 8 for unrolled).
    batched_growth = by_q[8]["bytes_batched"] / by_q[1]["bytes_batched"]

    rows.append(dict(name="stats_tau_bytes_q8", us_per_call=0.0, derived=reduction_q8))
    rows.append(dict(name="stats_tau_speedup_q8", us_per_call=0.0, derived=speedup_q8))
    rows.append(dict(name="stats_ingest_fused", us_per_call=1e6 * t_fused,
                     derived=round(ingest_saved / 2**20, 3)))
    rows.append(dict(name="stats_ingest_tuned", us_per_call=1e6 * t_ingest_tuned,
                     derived=1.0 if ingest_plan.fused else 0.0))

    ok = identical and tuned_identical and reduction_q8 >= 4.0 and batched_growth < 2.0
    report = dict(
        config=dict(v_z=V_Z, v_x=V_X, n_samples=N_SAMPLES, reps=REPS,
                    smoke=SMOKE, **env_stamp()),
        plan_file=str(plan_file) if plan_file else None,
        tau=tau_rows,
        ingest=dict(us_two_step=round(1e6 * t_two, 1),
                    us_fused=round(1e6 * t_fused, 1),
                    us_tuned=round(1e6 * t_ingest_tuned, 1),
                    speedup=round(t_two / max(t_fused, 1e-12), 3),
                    winner=ingest_winner,
                    tuned_from_file=autotune.ingest_key(V_Z, V_X) in registry.ingest,
                    bytes_saved_per_round=ingest_saved),
        batched_bit_identical=identical,
        tuned_bit_identical=tuned_identical,
        tuned_variants=tuned_variants,
        tuned_speedup_min=tuned_speedup_min,
        tuned_tau_bytes_reduction_q8=tuned_bytes_reduction_q8,
        ingest_winner=ingest_winner,
        batched_bytes_growth_q1_to_q8=round(batched_growth, 3),
        tau_bytes_reduction_q8=reduction_q8,
        ok=ok,
    )
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "BENCH_stats.json").write_text(json.dumps(report, indent=2) + "\n")

    print(f"# stats_throughput: q8 tau bytes {by_q[8]['bytes_unrolled'] / 2**20:.1f}MB "
          f"-> {by_q[8]['bytes_batched'] / 2**20:.1f}MB ({reduction_q8:.1f}x, "
          f"growth q1->q8 {batched_growth:.2f}x), wall speedup {speedup_q8:.2f}x, "
          f"tuned variants {tuned_variants} (speedup_min {tuned_speedup_min:.2f}), "
          f"ingest winner {ingest_winner}, bit-identical={identical and tuned_identical}"
          f" -> {'PASS' if ok else 'FAIL'}")
    if SMOKE and not ok:
        raise SystemExit("stats_throughput smoke FAILED")


if __name__ == "__main__":
    rows: list = []
    run(rows)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
