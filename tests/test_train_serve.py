"""Train loop (loss decreases, NaN-skip, resume) + serving engine."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.corpus import CorpusSpec, make_corpus
from repro.launch.train import train_loop
from repro.models.model_zoo import get_model
from repro.optimizer import get_optimizer
from repro.serve import Request, ServeEngine
from repro.train import TrainState, make_train_step


@pytest.fixture(scope="module")
def tiny_corpus():
    # reference_alpha=0.08: very peaked token mix -> strong learnable
    # unigram signal for the loss-decrease check
    return make_corpus(
        CorpusSpec(num_domains=16, num_buckets=32, vocab_size=256, num_blocks=256,
                   block_tokens=512, n_reference=4, reference_alpha=0.08, seed=1)
    )


class TestTrainLoop:
    def test_loss_decreases(self, tiny_corpus):
        cfg = get_smoke_config("qwen2_5_3b")
        out = train_loop(
            cfg=cfg, steps=30, batch_size=8, seq_len=64, lr=1e-2,
            corpus=tiny_corpus, select_k=4, log_every=1, log_fn=lambda *_: None,
        )
        first = out["history"][0]["ce"]  # after 1 update: ~ln(vocab)
        last = min(h["ce"] for h in out["history"][-5:])
        assert last < first - 0.3, (first, last)

    def test_selection_finds_reference_domains(self, tiny_corpus):
        cfg = get_smoke_config("qwen2_5_3b")
        out = train_loop(
            cfg=cfg, steps=2, batch_size=2, seq_len=64, corpus=tiny_corpus,
            select_k=4, log_fn=lambda *_: None,
        )
        assert set(out["selection"].selected_domains.tolist()) == set(
            tiny_corpus.close_ids.tolist()
        )

    def test_checkpoint_resume_matches(self, tiny_corpus, tmp_path):
        cfg = get_smoke_config("xlstm_125m")
        cfg = dataclasses.replace(cfg, vocab_size=256)
        kw = dict(cfg=cfg, batch_size=4, seq_len=64, lr=1e-3, corpus=tiny_corpus,
                  select_k=4, log_fn=lambda *_: None, seed=3)
        full = train_loop(steps=20, **kw)
        # run 10, "crash", resume to 20
        train_loop(steps=10, ckpt_dir=str(tmp_path / "ck"), ckpt_every=10, **kw)
        resumed = train_loop(steps=20, ckpt_dir=str(tmp_path / "ck"), ckpt_every=10, **kw)
        w_full = jax.tree.leaves(full["state"].params)[0]
        w_res = jax.tree.leaves(resumed["state"].params)[0]
        # same data order (deterministic stream by (seed, worker, epoch)) ->
        # identical trajectories up to bf16 nondeterminism
        np.testing.assert_allclose(
            np.asarray(w_full, np.float32), np.asarray(w_res, np.float32), atol=2e-2
        )

    def test_nan_batch_skipped(self):
        cfg = get_smoke_config("granite_8b")
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = get_optimizer("adamw", 1e-3)
        state = TrainState.create(params, opt)
        step = jax.jit(make_train_step(model, opt))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
        tokens = tokens.at[0, 0].set(0)  # ensure the poisoned row is hit
        # poison the embedding to force NaN loss
        bad_params = jax.tree.map(lambda x: x, params)
        bad_params["embed"]["table"] = bad_params["embed"]["table"].at[0, 0].set(jnp.nan)
        bad_state = TrainState(bad_params, state.opt_state, state.step)
        new_state, metrics = step(bad_state, {"tokens": tokens})
        assert float(metrics["step_ok"]) == 0.0
        # params unchanged by the skipped step
        np.testing.assert_array_equal(
            np.asarray(new_state.params["final_norm"]["scale"], np.float32),
            np.asarray(bad_params["final_norm"]["scale"], np.float32),
        )


class TestServeEngine:
    def test_greedy_batch_serving(self):
        cfg = get_smoke_config("granite_8b")
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(model, params, slots=4, max_len=64)
        rng = np.random.default_rng(0)
        reqs = [
            Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=8).astype(np.int32),
                    max_new_tokens=5)
            for i in range(6)
        ]
        for r in reqs:
            eng.submit(r)
        done = eng.run()
        assert len(done) == 6
        assert all(len(r.output) == 5 for r in done)
        assert eng.metrics["tokens_out"] == 30

    def test_greedy_matches_manual_decode(self):
        cfg = get_smoke_config("qwen2_5_3b")
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        prompt = np.arange(1, 9, dtype=np.int32)
        eng = ServeEngine(model, params, slots=1, max_len=32)
        req = Request(rid=0, prompt=prompt, max_new_tokens=4)
        eng.submit(req)
        eng.run()
        # manual greedy
        logits, cache = model.prefill(params, jnp.asarray(prompt[None]), 32)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        manual = []
        for _ in range(4):
            manual.append(int(tok[0]))
            lg, cache = model.decode_step(params, cache, tok)
            tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        assert req.output == manual
