"""Pluggable block I/O for the sampling engine (paper Fig. 5 "I/O manager").

The statistics engine must never stall on block gathering (Sec 4.2's
asynchronous relaxation). This package isolates WHERE window data comes
from behind the `BlockSource` protocol so the device-resident sampling
loop in `repro.core.multiquery` is agnostic to it:

  InMemorySource  — whole blocked dataset resident on device; a fetch is
                    a device-side gather (no host traffic at all)
  ShardedSource   — one data-parallel worker's contiguous block range
                    (reuses `BlockedDataset.shard`), global indices in,
                    local gathers out
  PrefetchSource  — double-buffered background-thread wrapper: the next
                    window's blocks are fetched while the current round's
                    ingest+stats run on device
  ResilientSource — retry/backoff + integrity validation + block
                    quarantine at the source boundary (repro.io.faults;
                    FaultySource is the matching seeded chaos wrapper)
"""

from repro.io.block_source import (
    BlockSource,
    InMemorySource,
    ShardedSource,
    WindowData,
    as_block_source,
)
from repro.io.faults import (
    FaultInjector,
    FaultPlan,
    FaultySource,
    ResilientSource,
    RetryPolicy,
    WindowQuarantined,
    maybe_chaos,
    validate_window,
)
from repro.io.prefetch import PrefetchSource

__all__ = [
    "BlockSource",
    "FaultInjector",
    "FaultPlan",
    "FaultySource",
    "InMemorySource",
    "PrefetchSource",
    "ResilientSource",
    "RetryPolicy",
    "ShardedSource",
    "WindowData",
    "WindowQuarantined",
    "as_block_source",
    "maybe_chaos",
    "validate_window",
]
