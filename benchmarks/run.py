"""Benchmark aggregator: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (one per measurement) and
writes the same to benchmarks/results/bench_results.csv.

  PYTHONPATH=src python -m benchmarks.run              # everything
  PYTHONPATH=src python -m benchmarks.run fig4 table4  # subset
"""

from __future__ import annotations

import pathlib
import sys
import time

from benchmarks import (
    anytime_curve,
    autotune_smoke,
    fault_recovery,
    fig4_bound_ratio,
    fig7_8_epsilon,
    fig9_lookahead,
    fig10_11_delta,
    guarantees,
    metrics_matrix,
    pump_throughput,
    roofline_report,
    serve_throughput,
    stats_throughput,
    table4_speedups,
    telemetry_overhead,
    warm_restart,
)

SUITES = {
    "fig4": fig4_bound_ratio.run,
    "table4": table4_speedups.run,
    "fig7_8": fig7_8_epsilon.run,
    "fig9": fig9_lookahead.run,
    "fig10_11": fig10_11_delta.run,
    "guarantees": guarantees.run,
    "roofline": roofline_report.run,
    "serve": serve_throughput.run,
    "stats": stats_throughput.run,
    "restart": warm_restart.run,
    "pump": pump_throughput.run,
    "telemetry": telemetry_overhead.run,
    "anytime": anytime_curve.run,
    "autotune": autotune_smoke.run,
    "faults": fault_recovery.run,
    "metrics": metrics_matrix.run,
}


def main() -> None:
    wanted = sys.argv[1:] or list(SUITES)
    # Validate the whole request up front: a typo'd name must exit
    # non-zero BEFORE any suite runs, not after minutes of earlier
    # suites (a CI step asking for a renamed benchmark must fail the
    # workflow, never silently measure the wrong thing).
    unknown = [name for name in wanted if name not in SUITES]
    if unknown:
        raise SystemExit(
            f"unknown suite(s) {unknown}; have {sorted(SUITES)}"
        )
    rows: list = []
    for name in wanted:
        t0 = time.time()
        SUITES[name](rows)
        print(f"# suite {name} done in {time.time() - t0:.1f}s", file=sys.stderr)

    print("name,us_per_call,derived")
    lines = []
    for r in rows:
        line = f"{r['name']},{r['us_per_call']:.1f},{r['derived']}"
        print(line)
        lines.append(line)
    out = pathlib.Path(__file__).parent / "results"
    out.mkdir(exist_ok=True)
    (out / "bench_results.csv").write_text("name,us_per_call,derived\n" + "\n".join(lines) + "\n")


if __name__ == "__main__":
    main()
