"""Multi-pod distributed HistSim — the unified MULTI-QUERY round.

One round over `repro.core.multiquery.MultiQueryState` on a
("pod", "data", "model") mesh; the single-query case is just
``max_queries=1`` (the parallel single-query `ShardedHistSimState` this
module used to carry is gone — one loop, one state, every width):

  * corpus blocks   — range-sharded over ("pod", "data"): each worker
                      owns a contiguous range of the shuffled layout
                      (`repro.io.ShardedSource`, locality, Challenge 1)
                      and ingests only its own blocks.
  * counts matrix   — candidate-sharded over "model": each model shard
                      owns V_Z / |model| rows of the SHARED counts —
                      P("model", None) — and of n — P("model").
  * per round       — each (pod, data) shard histograms its local
                      samples *restricted to the candidate rows of its
                      model shard* (one-hot matmul, so restriction is an
                      index shift, not a gather; the kernel emits the
                      row-sum delta from the same pass), then a single
                      psum over ("pod", "data") merges the partial
                      (counts, rows) pair: the paper's r_partial
                      spinlock handoff becomes one fused all-reduce of
                      a (V_Z/m, V_X) f32 tile.
  * statistics      — per-query tau rows computed locally per model
                      shard with ONE Q-batched `l1_distance_multi`
                      call (the shard's counts rows are streamed once
                      for all query slots; unoccupied slots masked),
                      then one tiled all-gather of (Q, V_Z) + (V_Z,)
                      floats and the same vmapped per-query deviation
                      assignment the single-device scheduler uses
                      (`multiquery.apply_stats` — the two paths share
                      the code, so they cannot drift). The per-query
                      active words and their union (V_Z bits packed)
                      return to every shard — the only "control plane"
                      traffic.

Communication per round: one psum of the (counts, row-sum) delta pair
+ one all-gather of (Q+1) x V_Z f32 — independent of the number of
samples ingested AND of the number of query slots (the batched tau
reads each shard's counts rows once, not Q times).
Sample bytes never cross the network; this is what makes the engine
scale to 1000+ nodes. `SharedCountsScheduler(mesh=...)` is the GSPMD
(sharding-propagation) counterpart for serving; this explicit
shard_map round is the collective-auditable data-parallel ingest path.
"""

from __future__ import annotations

import inspect

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.multiquery import CacheSnapshot, MultiQuerySpec, MultiQueryState, apply_stats
from repro.kernels import ops

__all__ = [
    "cache_pspecs",
    "make_distributed_round",
    "multi_state_pspecs",
    "place_cache",
    "shard_map_compat",
]


def shard_map_compat(f, mesh, in_specs, out_specs):
    """shard_map across jax versions (jax.shard_map / experimental;
    check_vma / check_rep) with replication checking off — the round's
    replicated outputs come out of collectives the checker can't see
    through on every version we support."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    params = inspect.signature(sm).parameters
    kwargs = {}
    if "check_vma" in params:
        kwargs["check_vma"] = False
    elif "check_rep" in params:
        kwargs["check_rep"] = False
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def multi_state_pspecs(model_axis: str = "model") -> MultiQueryState:
    """PartitionSpecs for MultiQueryState: shared counts candidate-sharded
    over the model axis, all per-query statistics replicated."""
    return MultiQueryState(
        counts=P(model_axis, None),
        n=P(model_axis),
        q_hat=P(),
        k=P(),
        eps=P(),
        delta=P(),
        tau=P(),
        eps_i=P(),
        log_delta_i=P(),
        delta_upper=P(),
        active=P(),
        active_words=P(),
        union_words=P(),
        in_top_k=P(),
        occupied=P(),
        round_idx=P(),
    )


def cache_pspecs(model_axis: str = "model") -> CacheSnapshot:
    """PartitionSpecs for the warm-start `CacheSnapshot`: the shared
    counts/n leaves carry the SAME candidate sharding as the live
    `MultiQueryState` (derived from `multi_state_pspecs`, so the two
    cannot drift); the sampling cursor and host bookkeeping replicate.

    This is the elastic-restart contract: a snapshot host-gathered from
    one mesh shape is re-placed onto another by
    ``CheckpointManager.restore_resharded(like, mesh, cache_pspecs())``
    — e.g. a cache accumulated on 1 device restored candidate-sharded
    onto 8, or an 8-way cache restored onto a 4-device mesh."""
    ms = multi_state_pspecs(model_axis=model_axis)
    return CacheSnapshot(
        counts=ms.counts,
        n=ms.n,
        read_mask=P(),
        blocks_read=P(),
        blocks_considered=P(),
        tuples_read=P(),
        rounds=P(),
        passes=P(),
        start=P(),
    )


def place_cache(snap: CacheSnapshot, mesh, model_axis: str = "model") -> CacheSnapshot:
    """Host-gather a (possibly sharded) snapshot and re-place it on
    ``mesh`` per `cache_pspecs` — the in-memory reshard twin of the
    checkpoint round-trip, for handing a live scheduler's cache to a
    differently-shaped mesh without touching disk."""
    from jax.sharding import NamedSharding

    host = jax.device_get(snap)  # gather: full leaves on host
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), cache_pspecs(model_axis=model_axis)
    )
    return jax.tree.map(jax.device_put, host, shardings)


def make_distributed_round(
    mesh,
    spec: MultiQuerySpec,
    *,
    data_axes=("data",),
    model_axis: str = "model",
    histogram_impl: str = "auto",
    onehot_dtype=jnp.float32,
):
    """Build the jitted shard_map multi-query round for a given mesh.

    The returned function has signature (state, z_idx, x_idx) -> state,
    where state is a `MultiQueryState` placed per `multi_state_pspecs`
    and z_idx/x_idx are (N,) int32 sharded over ``data_axes`` — the
    samples each worker read from its own block range this round
    (padding = -1). All-reduce structure is as documented above; the
    statistics tail is `multiquery.apply_stats`, identical to the
    single-device scheduler's.
    """
    model_size = mesh.shape[model_axis]
    if spec.v_z % model_size != 0:
        raise ValueError(
            f"V_Z={spec.v_z} must divide by model axis size {model_size} "
            "(pad candidates to a multiple; padded rows are never sampled)"
        )
    vz_shard = spec.v_z // model_size
    sample_axes = tuple(data_axes)

    def round_fn(state: MultiQueryState, z_idx: jax.Array, x_idx: jax.Array):
        # ---- ingest: local histogram restricted to this model shard's rows,
        # row-sum delta emitted from the same kernel pass
        shard_id = jax.lax.axis_index(model_axis)
        z_local = z_idx - shard_id * vz_shard
        z_local = jnp.where((z_local >= 0) & (z_local < vz_shard), z_local, -1)
        h, rows = ops.histogram_with_rowsums(
            z_local, x_idx, v_z=vz_shard, v_x=spec.v_x,
            impl=histogram_impl, onehot_dtype=onehot_dtype,
        )
        # one fused all-reduce of the (counts, row-sum) delta pair over
        # the data axes — a single psum call, XLA fuses the pytree
        h, rows = jax.lax.psum((h, rows), sample_axes)
        counts = state.counts + h
        n = state.n + rows

        # ---- statistics: row-local Q-batched tau (ONE kernel pass over
        # this shard's counts rows scores every slot; unoccupied slots
        # masked to the init value), tiny all-gather, then the shared
        # vmapped per-query assignment
        tau_shard = ops.l1_distance_multi(counts, state.q_hat)  # (Q, vz_shard)
        tau_shard = jnp.where(state.occupied[:, None], tau_shard, 1.0)
        tau = jax.lax.all_gather(tau_shard, model_axis, axis=1, tiled=True)
        n_full = jax.lax.all_gather(n, model_axis, axis=0, tiled=True)
        state = state._replace(counts=counts, n=n)
        return apply_stats(state, tau, n_full, spec=spec)

    specs = multi_state_pspecs(model_axis=model_axis)
    sample_spec = P(sample_axes)
    shmapped = shard_map_compat(
        round_fn, mesh, in_specs=(specs, sample_spec, sample_spec), out_specs=specs
    )
    return jax.jit(shmapped)
