"""Golden equivalence: fused device-resident loop vs the host-stepped loop.

The PR-1 scheduler round-tripped to host after every window (marks to
numpy, host-side read_mask, separate jit dispatches for mark / ingest /
stats). The fused loop runs one jitted `fused_round` per window with a
device-resident `SampleCursor` and polls only every `poll_every`
windows. This suite pins the refactor to the old semantics:

  * at poll_every=1 the fused loop must produce IDENTICAL counts / n /
    read_mask / per-query top-k ids to a host-stepped reference loop
    (reimplemented here from the primitives, exactly as PR-1 ran it) —
    including mid-stream admission and the exact-completion fallback;
  * at poll_every>1 retirement staleness may change WHICH blocks are
    read, but the answers (top-k ids) must not change on these seeds;
  * everything holds with `PrefetchSource` (background-thread gathers
    from host-resident block arrays) swapped in.

Plus contract tests for the new `repro.io` layer itself.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import histsim
from repro.core import multiquery as mq
from repro.core.policies import mark_window
from repro.data.layout import block_layout
from repro.data.synth import SynthSpec, make_dataset, perturb_distribution
from repro.io import InMemorySource, PrefetchSource, ShardedSource

K, EPS, DELTA = 5, 0.08, 0.05


@pytest.fixture(scope="module")
def dataset():
    spec = SynthSpec(
        v_z=48, v_x=16, num_tuples=800_000, k=K, n_close=5,
        close_distance=0.02, far_distance=0.3, zipf_a=0.9, seed=13,
    )
    ds = make_dataset(spec)
    blocked = block_layout(ds.z, ds.x, v_z=spec.v_z, v_x=spec.v_x, block_size=512, seed=13)
    return spec, ds, blocked


@pytest.fixture(scope="module")
def targets(dataset):
    _, ds, _ = dataset
    rng = np.random.default_rng(21)
    return [ds.target] + [perturb_distribution(ds.target, d, rng) for d in (0.01, 0.04)]


def run_reference(
    blocked,
    initial,
    *,
    window,
    start_block,
    max_passes=4,
    admit_plan=(),
):
    """The PR-1 host-stepped shared-counts loop, from the primitives.

    initial: [(slot, target, k, eps, delta)] admitted before round 0.
    admit_plan: [(at_round, slot, target, k, eps, delta)] admitted at
    the first retirement-poll at or after `at_round` (PR-1's on_round
    admission point). Returns (state, read_mask, outcomes) with
    outcomes[slot] = top-k ids snapshotted at retirement.
    """
    spec = mq.MultiQuerySpec(v_z=blocked.v_z, v_x=blocked.v_x, max_queries=4)
    state = mq.init_multi_state(spec)
    z_blocks = jnp.asarray(blocked.z_blocks)
    x_blocks = jnp.asarray(blocked.x_blocks)
    bitmap = jnp.asarray(blocked.bitmap)
    nb = blocked.num_blocks
    order = np.roll(np.arange(nb), -start_block)
    read_mask = np.zeros(nb, bool)
    live, admit_rounds, outcomes = {}, {}, {}
    rounds = 0
    pending = sorted(admit_plan)

    def admit(slot, target, k, eps, delta):
        nonlocal state
        q = np.asarray(target, np.float64).ravel()
        q = (q / q.sum()).astype(np.float32)
        state = mq.admit_slot(
            state, jnp.asarray(slot, jnp.int32), jnp.asarray(q),
            jnp.asarray(k, jnp.int32), jnp.asarray(eps, jnp.float32),
            jnp.asarray(delta, jnp.float32), spec=spec,
        )
        state = mq.stats_step(state, spec=spec)
        live[slot] = (k, eps, delta)
        admit_rounds[slot] = rounds

    def snapshot(slot):
        view = mq.slot_state(state, slot)
        outcomes[slot] = np.asarray(histsim.top_k_ids(view, live[slot][0]))

    def poll():
        nonlocal state, pending
        du = np.asarray(state.delta_upper)
        for slot in list(live):
            if du[slot] < live[slot][2]:
                snapshot(slot)
                state = mq.clear_slot(state, jnp.asarray(slot, jnp.int32), spec=spec)
                del live[slot]
        while pending and pending[0][0] <= rounds:
            _, slot, t, k, e, d = pending.pop(0)
            admit(slot, t, k, e, d)

    for slot, t, k, e, d in initial:
        admit(slot, t, k, e, d)
    poll()
    passes = 0
    while live and passes < max_passes:
        pass_order = order[~read_mask[order]]
        if pass_order.size == 0:
            break
        passes += 1
        pass_start_rounds = rounds
        read_this = 0
        pos = 0
        while pos < pass_order.size and live:
            win = pass_order[pos : pos + window]
            pos += len(win)
            wj = jnp.asarray(win, jnp.int32)
            marks = np.asarray(
                mark_window(bitmap[wj], state.union_words, policy="anyactive")
            )
            nm = int(marks.sum())
            if nm:
                mj = jnp.asarray(marks)
                zw = jnp.where(mj[:, None], z_blocks[wj], jnp.int32(-1))
                xw = jnp.where(mj[:, None], x_blocks[wj], jnp.int32(-1))
                state = mq.run_round(state, zw.reshape(-1), xw.reshape(-1), spec=spec)
                read_mask[win[marks]] = True
                read_this += nm
            rounds += 1
            poll()
        if read_this == 0 and live:
            if not any(admit_rounds[s] >= pass_start_rounds for s in live):
                break
    if live:
        remaining = np.where(~read_mask)[0]
        for s in range(0, remaining.size, window):
            cj = jnp.asarray(remaining[s : s + window], jnp.int32)
            state = mq.ingest(
                state, z_blocks[cj].reshape(-1), x_blocks[cj].reshape(-1), spec=spec
            )
        read_mask[remaining] = True
        state = mq.stats_step(state, spec=spec)
        for slot in list(live):
            snapshot(slot)
            state = mq.clear_slot(state, jnp.asarray(slot, jnp.int32), spec=spec)
            del live[slot]
    assert not pending, "admit_plan rounds were never reached; tune the plan"
    return state, read_mask, outcomes


def run_fused(
    blocked_or_source,
    initial,
    *,
    window,
    start_block,
    poll_every=1,
    max_passes=4,
    admit_plan=(),
):
    """Same workload through the fused SharedCountsScheduler."""
    src = blocked_or_source
    spec = mq.MultiQuerySpec(
        v_z=src.v_z, v_x=src.v_x, max_queries=4
    )
    sched = mq.SharedCountsScheduler(
        src, spec, window=window, seed=0, start_block=start_block, poll_every=poll_every
    )
    pending = sorted(admit_plan)
    slot_of_qid = {}

    def on_round(s):
        while pending and pending[0][0] <= s.rounds and s.free_slots:
            _, slot, t, k, e, d = pending.pop(0)
            # `admit` fills the lowest free slot; the plan must agree or
            # the comparison with the reference is apples-to-oranges.
            assert s.free_slots[0] == slot
            qid = s.admit(t, k=k, eps=e, delta=d)
            slot_of_qid[qid] = slot

    for slot, t, k, e, d in initial:
        qid = sched.admit(t, k=k, eps=e, delta=d)
        slot_of_qid[qid] = slot
    sched.pump(max_passes=max_passes, on_round=on_round)
    assert not pending, "admit_plan rounds were never reached; tune the plan"
    outcomes = {
        slot_of_qid[qid]: out.ids for qid, out in sched.outcomes.items()
    }
    return sched, outcomes


class TestGoldenEquivalence:
    def test_identical_to_host_stepped_loop(self, dataset, targets):
        """poll_every=1: counts, n, read_mask and every query's top-k ids
        must match the PR-1 host-stepped loop bit for bit."""
        _, _, blocked = dataset
        initial = [
            (s, t, K, EPS, DELTA) for s, t in enumerate(targets)
        ]
        ref_state, ref_mask, ref_out = run_reference(
            blocked, initial, window=64, start_block=17
        )
        sched, out = run_fused(blocked, initial, window=64, start_block=17)
        np.testing.assert_array_equal(
            np.asarray(sched.state.counts), np.asarray(ref_state.counts)
        )
        np.testing.assert_array_equal(np.asarray(sched.state.n), np.asarray(ref_state.n))
        np.testing.assert_array_equal(sched.read_mask, ref_mask)
        assert set(out) == set(ref_out)
        for slot in ref_out:
            np.testing.assert_array_equal(out[slot], ref_out[slot])

    def test_identical_with_mid_stream_admission(self, dataset, targets):
        _, _, blocked = dataset
        initial = [(0, targets[0], K, EPS, DELTA)]
        plan = [(2, 1, targets[1], K, EPS, DELTA), (4, 2, targets[2], 3, 0.1, DELTA)]
        ref_state, ref_mask, ref_out = run_reference(
            blocked, initial, window=48, start_block=5, admit_plan=plan
        )
        sched, out = run_fused(
            blocked, initial, window=48, start_block=5, admit_plan=plan
        )
        np.testing.assert_array_equal(
            np.asarray(sched.state.counts), np.asarray(ref_state.counts)
        )
        np.testing.assert_array_equal(sched.read_mask, ref_mask)
        assert set(out) == set(ref_out)
        for slot in ref_out:
            np.testing.assert_array_equal(out[slot], ref_out[slot])

    def test_identical_on_exact_completion_fallback(self):
        """Unreachable bound: both loops must fall back to the complete
        read and answer from true counts."""
        spec = SynthSpec(v_z=24, v_x=8, num_tuples=30_000, k=3, n_close=3, seed=4)
        ds = make_dataset(spec)
        blocked = block_layout(ds.z, ds.x, v_z=spec.v_z, v_x=spec.v_x, block_size=256, seed=4)
        initial = [(0, ds.target, 3, 0.02, 1e-9)]
        ref_state, ref_mask, ref_out = run_reference(
            blocked, initial, window=32, start_block=3
        )
        sched, out = run_fused(blocked, initial, window=32, start_block=3)
        assert ref_mask.all() and sched.read_mask.all()
        np.testing.assert_array_equal(
            np.asarray(sched.state.counts), np.asarray(ref_state.counts)
        )
        np.testing.assert_array_equal(out[0], ref_out[0])
        assert sched.outcomes[0].exact

    def test_identical_with_prefetch_source(self, dataset, targets):
        """The background-thread double buffer must not change a single
        bit — host-resident arrays force real per-window transfers."""
        _, _, blocked = dataset
        initial = [(s, t, K, EPS, DELTA) for s, t in enumerate(targets)]
        ref_state, ref_mask, ref_out = run_reference(
            blocked, initial, window=64, start_block=17
        )
        src = PrefetchSource(InMemorySource(blocked, device_resident=False))
        sched, out = run_fused(src, initial, window=64, start_block=17)
        np.testing.assert_array_equal(
            np.asarray(sched.state.counts), np.asarray(ref_state.counts)
        )
        np.testing.assert_array_equal(sched.read_mask, ref_mask)
        for slot in ref_out:
            np.testing.assert_array_equal(out[slot], ref_out[slot])

    def test_poll_every_staleness_preserves_answers(self, dataset, targets):
        """poll_every=8 defers retirement (may read MORE blocks) but the
        returned top-k ids must match poll_every=1 on these seeds, and
        host polls must drop ~8x."""
        _, _, blocked = dataset
        initial = [(s, t, K, EPS, DELTA) for s, t in enumerate(targets)]
        # window=16 so the workload spans enough windows for the poll
        # cadence to be visible
        s1, out1 = run_fused(blocked, initial, window=16, start_block=17, poll_every=1)
        s8, out8 = run_fused(blocked, initial, window=16, start_block=17, poll_every=8)
        for slot in out1:
            # extra samples can reorder within the matching set; the SET
            # (hence recall against any ground truth) must be unchanged
            assert sorted(out1[slot].tolist()) == sorted(out8[slot].tolist()), slot
        assert s8.blocks_read >= s1.blocks_read  # staleness never reads less
        # per-window poll cadence: ~1 sync per round vs ~1 per 8 rounds
        assert s1.host_syncs >= s1.rounds
        assert s8.host_syncs < s1.host_syncs / 2


class TestBlockSourceContract:
    def test_fetch_pads_and_masks(self, dataset):
        _, _, blocked = dataset
        src = InMemorySource(blocked)
        wd = src.fetch(np.array([3, 7, 11]), pad_to=8)
        assert wd.z.shape == (8, blocked.block_size)
        np.testing.assert_array_equal(np.asarray(wd.valid), [True] * 3 + [False] * 5)
        np.testing.assert_array_equal(np.asarray(wd.indices[:3]), [3, 7, 11])
        np.testing.assert_array_equal(np.asarray(wd.z[1]), blocked.z_blocks[7])
        np.testing.assert_array_equal(np.asarray(wd.bitmap[2]), blocked.bitmap[11])

    def test_host_and_device_resident_agree(self, dataset):
        _, _, blocked = dataset
        dev = InMemorySource(blocked).fetch(np.arange(5), pad_to=6)
        host = InMemorySource(blocked, device_resident=False).fetch(np.arange(5), pad_to=6)
        for a, b in zip(dev, host):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_sharded_source_speaks_global_ids(self, dataset):
        _, _, blocked = dataset
        num_shards = 4
        shards = [ShardedSource(blocked, num_shards, i) for i in range(num_shards)]
        assert sum(s.num_blocks for s in shards) == blocked.num_blocks
        # contiguous, disjoint, covering ranges
        assert shards[0].lo == 0 and shards[-1].hi == blocked.num_blocks
        for a, b in zip(shards, shards[1:]):
            assert a.hi == b.lo
        s1 = shards[1]
        gids = np.arange(s1.lo, min(s1.lo + 3, s1.hi))
        wd = s1.fetch(gids, pad_to=4)
        np.testing.assert_array_equal(np.asarray(wd.indices[:3]), gids)
        np.testing.assert_array_equal(np.asarray(wd.z[0]), blocked.z_blocks[gids[0]])
        with pytest.raises(ValueError):
            s1.fetch(np.array([s1.hi]))  # out of range
        win = np.array([0, s1.lo, s1.hi - 1, blocked.num_blocks - 1])
        np.testing.assert_array_equal(s1.owned(win), [s1.lo, s1.hi - 1])

    def test_scheduler_rejects_sharded_source(self, dataset):
        """Global-id shard feeds belong to the distributed round; the
        0-based scheduler must refuse them instead of crashing mid-pass."""
        _, _, blocked = dataset
        src = ShardedSource(blocked, 2, 1)
        spec = mq.MultiQuerySpec(v_z=blocked.v_z, v_x=blocked.v_x, max_queries=1)
        with pytest.raises(ValueError, match="0-based"):
            mq.SharedCountsScheduler(src, spec)

    def test_prefetch_stream_matches_plain_stream(self, dataset):
        _, _, blocked = dataset
        inner = InMemorySource(blocked, device_resident=False)
        windows = [np.arange(i, i + 4) for i in range(0, 32, 4)]
        plain = list(inner.stream(windows, pad_to=4))
        pre = list(PrefetchSource(inner).stream(windows, pad_to=4))
        assert len(plain) == len(pre)
        for a, b in zip(plain, pre):
            for fa, fb in zip(a, b):
                np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))

    def test_prefetch_abandoned_stream_cleans_up(self, dataset):
        """Closing the generator mid-stream (a retirement ends the pass
        early) must not hang or leak the worker thread."""
        import threading

        _, _, blocked = dataset
        src = PrefetchSource(InMemorySource(blocked), depth=1)
        windows = [np.arange(i, i + 2) for i in range(0, 40, 2)]
        before = threading.active_count()
        g = src.stream(windows, pad_to=2)
        next(g)
        g.close()
        assert threading.active_count() <= before + 1  # worker gone (or dying)

    def test_prefetch_propagates_fetch_errors(self, dataset):
        # host-resident arrays: an out-of-bounds window raises in the
        # worker thread and must surface in the consumer
        _, _, blocked = dataset
        src = PrefetchSource(InMemorySource(blocked, device_resident=False))
        windows = [np.arange(2), np.array([blocked.num_blocks + 5])]  # 2nd is OOB
        with pytest.raises(IndexError):
            list(src.stream(windows, pad_to=2))

    def test_prefetch_error_after_close_is_logged_not_lost(self, dataset, caplog):
        """A worker exception racing the generator's close has nowhere to
        re-raise — it must be logged, never silently dropped."""
        import logging
        import threading

        _, _, blocked = dataset
        release = threading.Event()
        inner = InMemorySource(blocked, device_resident=False)

        class FailsAfterClose:
            num_blocks = inner.num_blocks
            block_size = inner.block_size
            v_z = inner.v_z
            v_x = inner.v_x
            tuples_per_block = inner.tuples_per_block

            def fetch(self, win, pad_to=None):
                if len(win) == 1:  # the second (sentinel) window
                    release.wait(5)  # don't fail until the consumer closed
                    raise RuntimeError("backend fell over")
                return inner.fetch(win, pad_to)

            def stream(self, windows, pad_to=None):
                for w in windows:
                    yield self.fetch(w, pad_to)

        src = PrefetchSource(FailsAfterClose(), depth=1)
        g = src.stream([np.arange(2), np.array([0])], pad_to=2)
        next(g)
        with caplog.at_level(logging.WARNING, logger="repro.io.prefetch"):
            release.set()
            g.close()
        assert any("prefetch worker failed" in r.message for r in caplog.records)

    def test_prefetch_join_timeout_warns(self, dataset, caplog):
        """A worker stuck in a slow inner.fetch outlives the closing
        join; that must produce a warning, not a silent abandon."""
        import logging
        import threading

        _, _, blocked = dataset
        hang = threading.Event()
        inner = InMemorySource(blocked, device_resident=False)

        class SlowSource:
            num_blocks = inner.num_blocks
            block_size = inner.block_size
            v_z = inner.v_z
            v_x = inner.v_x
            tuples_per_block = inner.tuples_per_block

            def fetch(self, win, pad_to=None):
                if len(win) == 1:
                    hang.wait(5)  # longer than join_timeout below
                return inner.fetch(win, pad_to)

            def stream(self, windows, pad_to=None):
                for w in windows:
                    yield self.fetch(w, pad_to)

        src = PrefetchSource(SlowSource(), depth=1, join_timeout=0.2)
        g = src.stream([np.arange(2), np.array([0])], pad_to=2)
        next(g)
        with caplog.at_level(logging.WARNING, logger="repro.io.prefetch"):
            g.close()
        hang.set()  # let the worker finish and exit
        assert any("still running" in r.message for r in caplog.records)
