"""Pallas TPU kernel: Q-batched row-normalized l1 distances (one HBM pass).

Computes, for every query slot q and every candidate row i of a shared
(V_Z, V_X) counts matrix,

    tau[q, i] = || counts_i / max(sum_x counts_i, 1)  -  q_hat_q ||_1

The multi-query serving loop used to unroll `l1_distance_pallas` once
per query slot, re-streaming the same counts matrix from HBM Q times
per statistics iteration. Here each (Z_TILE, V_X) counts tile is loaded
into VMEM ONCE, row-normalized once, and scored against the whole
(Q, V_X) target matrix (VMEM-resident) before the next tile is fetched:
HBM traffic drops from Q * V_Z * V_X to V_Z * V_X + Q * V_X, i.e. the
statistics engine's cost per round is independent of the number of live
queries (the paper's O(|V_Z| * |V_X|) per iteration, not Q times it).

Two layouts, chosen by the padded V_X:

  * single-sweep  — V_X fits one VMEM block (<= `_X_TILE` lanes, the
    old `_MAX_VX` bound): grid (z_tiles,), row sums computed in-block,
    exactly one HBM read of counts.
  * lane-tiled    — V_X > `_X_TILE`: grid (z_tiles, 2, x_tiles). The
    row sum needs the full row before ANY lane tile can be normalized,
    so each z tile makes two sweeps over its x tiles: phase 0
    accumulates row sums into a VMEM scratch, phase 1 accumulates the
    per-query |r_hat - q| partials into the (Q, Z_TILE) output block.
    Counts are read twice — still independent of Q. This is what lifts
    the single-query kernel's `_MAX_VX = 4096` rejection.

Rows with zero mass return ||q_hat_q||_1 (= 1), matching ref.py.
Q is a static shape: the per-query scoring loop is unrolled inside the
kernel, so the counts tile in VMEM is reused Q times per load.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["l1_distance_multi_pallas"]

_Z_TILE = 256
# Lane-tile width: one (Z_TILE x X_TILE) f32 block must fit VMEM with
# headroom (256 x 4096 x 4B = 4 MiB). V_X beyond this is lane-tiled.
_X_TILE = 4096


def _l1_multi_kernel(counts_ref, q_ref, out_ref, *, num_q: int):
    """Single-sweep: whole (padded) V_X in one block."""
    counts = counts_ref[...].astype(jnp.float32)  # (Z_TILE, V_X)
    row = jnp.sum(counts, axis=1, keepdims=True)
    r_hat = counts / jnp.maximum(row, 1.0)
    q = q_ref[...].astype(jnp.float32)  # (Q, V_X)
    for i in range(num_q):  # unrolled: counts tile stays VMEM-resident
        out_ref[i, :] = jnp.sum(jnp.abs(r_hat - q[i][None, :]), axis=1)


def _l1_multi_tiled_kernel(counts_ref, q_ref, out_ref, row_ref, *, num_q: int):
    """Lane-tiled: phase 0 row sums, phase 1 per-query tau partials."""
    phase = pl.program_id(1)
    xb = pl.program_id(2)
    counts = counts_ref[...].astype(jnp.float32)  # (Z_TILE, X_TILE)

    @pl.when((phase == 0) & (xb == 0))
    def _init_row():
        row_ref[...] = jnp.zeros_like(row_ref)

    @pl.when(phase == 0)
    def _accum_row():
        row_ref[...] += jnp.sum(counts, axis=1, keepdims=True)

    @pl.when((phase == 1) & (xb == 0))
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(phase == 1)
    def _accum_tau():
        r_hat = counts / jnp.maximum(row_ref[:, 0:1], 1.0)
        q = q_ref[...].astype(jnp.float32)  # (Q, X_TILE)
        for i in range(num_q):
            out_ref[i, :] += jnp.sum(jnp.abs(r_hat - q[i][None, :]), axis=1)


def l1_distance_multi_pallas(
    counts: jax.Array,
    q_hat: jax.Array,
    *,
    z_tile: int = _Z_TILE,
    x_tile: int = _X_TILE,
    sweeps: int = 0,
    interpret: bool = False,
) -> jax.Array:
    """(Q, V_Z) float32 distances tau[q, i] for a (Q, V_X) target batch.

    V_X and V_Z are padded internally; q_hat padding is 0 so padded
    lanes contribute |0 - 0| = 0. Any V_X is accepted (lane-tiled past
    ``x_tile``); Q must be the leading q_hat dimension (static).

    ``sweeps`` selects the layout (an autotuner knob — both layouts are
    bit-identical): 0 picks by padded V_X as described above, 1 forces
    single-sweep (raises if V_X does not fit one ``x_tile`` block), 2
    forces the two-sweep lane-tiled form even when V_X would fit —
    smaller working set per grid step, counts read twice.
    """
    v_z, v_x = counts.shape
    num_q, v_xq = q_hat.shape
    if v_xq != v_x:
        raise ValueError(f"q_hat V_X={v_xq} does not match counts V_X={v_x}")
    if x_tile % 128 != 0:
        raise ValueError(f"x_tile must be a lane multiple of 128, got {x_tile}")
    if sweeps not in (0, 1, 2):
        raise ValueError(f"sweeps must be 0 (auto), 1 or 2, got {sweeps}")

    z_tile = min(z_tile, v_z)
    vz_pad = -(-v_z // z_tile) * z_tile
    vx_pad = max(128, -(-v_x // 128) * 128)
    if sweeps == 1 and vx_pad > x_tile:
        raise ValueError(
            f"sweeps=1 needs padded V_X ({vx_pad}) <= x_tile ({x_tile})"
        )
    if vx_pad <= x_tile and sweeps != 2:
        x_tile, tiled = vx_pad, False
    else:
        x_tile = min(x_tile, vx_pad)  # forced two-sweep on a small V_X
        vx_pad, tiled = -(-v_x // x_tile) * x_tile, True
    if (vz_pad, vx_pad) != (v_z, v_x):
        counts = jnp.pad(counts, ((0, vz_pad - v_z), (0, vx_pad - v_x)))
        q_hat = jnp.pad(q_hat, ((0, 0), (0, vx_pad - v_x)))

    out_shape = jax.ShapeDtypeStruct((num_q, vz_pad), jnp.float32)
    if not tiled:
        out = pl.pallas_call(
            functools.partial(_l1_multi_kernel, num_q=num_q),
            grid=(vz_pad // z_tile,),
            in_specs=[
                pl.BlockSpec((z_tile, vx_pad), lambda zb: (zb, 0)),
                pl.BlockSpec((num_q, vx_pad), lambda zb: (0, 0)),
            ],
            out_specs=pl.BlockSpec((num_q, z_tile), lambda zb: (0, zb)),
            out_shape=out_shape,
            interpret=interpret,
        )(counts, q_hat)
    else:
        out = pl.pallas_call(
            functools.partial(_l1_multi_tiled_kernel, num_q=num_q),
            grid=(vz_pad // z_tile, 2, vx_pad // x_tile),
            in_specs=[
                pl.BlockSpec((z_tile, x_tile), lambda zb, ph, xb: (zb, xb)),
                pl.BlockSpec((num_q, x_tile), lambda zb, ph, xb: (0, xb)),
            ],
            out_specs=pl.BlockSpec((num_q, z_tile), lambda zb, ph, xb: (0, zb)),
            out_shape=out_shape,
            scratch_shapes=[pltpu.VMEM((z_tile, 128), jnp.float32)],
            interpret=interpret,
        )(counts, q_hat)
    return out[:, :v_z]
