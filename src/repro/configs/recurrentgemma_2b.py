"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 2 recurrent : 1
attention block pattern [arXiv:2402.19427 (Griffin); hf].

MQA (kv=1), head_dim 256, GeGLU MLP, local window 2048. Sub-quadratic:
runs the long_500k shape (recurrent state is O(1); attention caches only
the 2048-token window).
"""

from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch_id="recurrentgemma_2b",
        family="hybrid",
        num_layers=26,
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256000,
        block_pattern="rra",
        lru_width=2560,
        conv_width=4,
        local_window=2048,
        rope_theta=1e4,
        norm_eps=1e-6,
        optimizer="adamw",
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="recurrentgemma_2b_smoke",
        family="hybrid",
        num_layers=3,
        d_model=64,
        num_heads=2,
        num_kv_heads=1,
        head_dim=32,
        d_ff=192,
        vocab_size=512,
        block_pattern="rra",
        lru_width=64,
        conv_width=4,
        local_window=16,
        norm_eps=1e-6,
    )
