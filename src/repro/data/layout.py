"""Randomized block layout (paper Sec 4.2, Challenge 1).

"To maximize performance benefits from locality, we randomly permute the
tuples of our dataset as a preprocessing step, and to 'sample' we may
then simply perform a linear scan of the shuffled data starting from any
point."  Sampling without replacement from the permuted layout keeps
Theorem 1 valid (the Lipschitz constant only tightens).

A BlockedDataset is the unit every sampling policy operates on: blocked
(z, x) tuple ids plus the packed presence bitmap for AnyActive.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.bitmap import build_block_bitmap

__all__ = ["BlockedDataset", "block_layout"]

# The paper uses 4 KiB disk blocks; at 8 bytes per (z, x) tuple that is
# ~512 tuples. Tunable; roofline-neutral since policies see only blocks.
DEFAULT_BLOCK_TUPLES = 512


@dataclasses.dataclass
class BlockedDataset:
    z_blocks: np.ndarray  # (num_blocks, block_size) int32, -1 padded
    x_blocks: np.ndarray  # (num_blocks, block_size) int32, -1 padded
    bitmap: np.ndarray  # (num_blocks, W) uint32
    v_z: int
    v_x: int

    @property
    def num_blocks(self) -> int:
        return self.z_blocks.shape[0]

    @property
    def block_size(self) -> int:
        return self.z_blocks.shape[1]

    @property
    def num_tuples(self) -> int:
        return int((self.z_blocks >= 0).sum())

    def shard(self, num_shards: int, shard_id: int) -> "BlockedDataset":
        """Contiguous block range owned by one data-parallel worker."""
        nb = self.num_blocks
        per = -(-nb // num_shards)
        lo, hi = shard_id * per, min((shard_id + 1) * per, nb)
        return BlockedDataset(
            z_blocks=self.z_blocks[lo:hi],
            x_blocks=self.x_blocks[lo:hi],
            bitmap=self.bitmap[lo:hi],
            v_z=self.v_z,
            v_x=self.v_x,
        )


def block_layout(
    z: np.ndarray,
    x: np.ndarray,
    *,
    v_z: int,
    v_x: int,
    block_size: int = DEFAULT_BLOCK_TUPLES,
    seed: int = 0,
    shuffle: bool = True,
) -> BlockedDataset:
    """Random permutation + blocking + bitmap build."""
    n = len(z)
    rng = np.random.default_rng(seed)
    order = rng.permutation(n) if shuffle else np.arange(n)
    z = np.asarray(z, np.int32)[order]
    x = np.asarray(x, np.int32)[order]

    nb = -(-n // block_size)
    pad = nb * block_size - n
    if pad:
        z = np.concatenate([z, np.full(pad, -1, np.int32)])
        x = np.concatenate([x, np.full(pad, -1, np.int32)])
    z_blocks = z.reshape(nb, block_size)
    x_blocks = x.reshape(nb, block_size)
    bitmap = build_block_bitmap(z_blocks, v_z)
    return BlockedDataset(z_blocks=z_blocks, x_blocks=x_blocks, bitmap=bitmap, v_z=v_z, v_x=v_x)
