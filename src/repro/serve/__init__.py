from repro.serve.engine import ServeEngine, Request
from repro.serve.fastmatch_server import MatchQuery, MatchServer
