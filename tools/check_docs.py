"""Execute every fenced ```python block in README.md and docs/*.md.

Documentation that shows code rots the moment an API drifts; this
gate keeps the user-facing docs layer honest by actually running it.
Rules:

  * only blocks fenced exactly as ```python are executed — use ```text
    (diagrams, shell transcripts) or ```bash for anything illustrative;
  * blocks within one file share a namespace and run top to bottom, so
    a document can build an example incrementally (imports first,
    results later) the way a reader reads it;
  * each FILE gets a fresh namespace — no cross-document coupling;
  * any exception fails the run (non-zero exit), with the file name
    and block number in the traceback.

CI runs this from the repo root on the tier-1 lane:

    PYTHONPATH=src python tools/check_docs.py
"""

from __future__ import annotations

import pathlib
import re
import sys
import time
import traceback

FENCE = re.compile(r"^```python[ \t]*\r?\n(.*?)^```[ \t]*$", re.M | re.S)


def python_blocks(path: pathlib.Path) -> list:
    return [m.group(1) for m in FENCE.finditer(path.read_text())]


def run_file(path: pathlib.Path) -> int:
    """Execute one document's blocks in a shared namespace; returns the
    number of failed blocks."""
    blocks = python_blocks(path)
    if not blocks:
        print(f"# {path.name}: no python blocks")
        return 0
    ns: dict = {"__name__": f"docs_{path.stem}"}
    failures = 0
    for i, block in enumerate(blocks, 1):
        label = f"{path.name} block {i}/{len(blocks)}"
        t0 = time.perf_counter()
        try:
            exec(compile(block, f"<{label}>", "exec"), ns)
        except Exception:
            traceback.print_exc()
            print(f"# {label}: FAILED")
            failures += 1
        else:
            print(f"# {label}: ok ({time.perf_counter() - t0:.1f}s)")
    return failures


def main() -> None:
    root = pathlib.Path(__file__).resolve().parents[1]
    files = [root / "README.md", *sorted((root / "docs").glob("*.md"))]
    missing = [f.name for f in files[:1] if not f.exists()]
    if missing:
        sys.exit(f"missing: {missing}")
    failures = sum(run_file(f) for f in files if f.exists())
    if failures:
        sys.exit(f"{failures} documentation block(s) failed")
    print("# docs: all executable blocks passed")


if __name__ == "__main__":
    main()
