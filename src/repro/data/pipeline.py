"""FastMatch-driven training data pipeline (the paper as a data-layer
feature of the training framework).

Phase 1 — SELECT: run the FastMatch engine over the corpus blocks
(Z = domain, X = token bucket, target = reference token mix) to find the
top-k domains whose token distribution matches the reference, touching a
sublinear fraction of blocks (Guarantees 1 & 2 at the given eps/delta).

Phase 2 — STREAM: an infinite batch iterator over the selected domains'
blocks, with:
  * deterministic shard ownership: worker w of W owns blocks where
    block_idx % W == w (contiguous ranges in production; modular here so
    a single process can emulate many workers);
  * straggler mitigation by WORK STEALING: a worker that exhausts its
    queue steals unread blocks from the global remainder — statistically
    harmless because blocks of the shuffled layout are exchangeable
    (paper Sec 4.2 Challenge 1);
  * checkpointable cursor state (resume exactly after preemption).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.core.engine import EngineConfig, MatchResult, run_engine
from repro.core.histsim import HistSimParams
from repro.data.corpus import TokenCorpus
from repro.data.layout import BlockedDataset
from repro.core.bitmap import build_block_bitmap
from repro.io import BlockSource, InMemorySource, PrefetchSource

__all__ = ["SelectionReport", "select_domains", "TokenStream"]


@dataclasses.dataclass
class SelectionReport:
    selected_domains: np.ndarray
    result: MatchResult
    blocks_scanned_frac: float


def corpus_as_blocked(corpus: TokenCorpus) -> BlockedDataset:
    """View the token corpus as the paper's (z, x) blocked dataset."""
    nb, bt = corpus.tokens.shape
    z_blocks = np.repeat(corpus.domains[:, None], bt, axis=1).astype(np.int32)
    x_blocks = corpus.bucket_of(corpus.tokens).astype(np.int32)
    bitmap = build_block_bitmap(z_blocks, corpus.spec.num_domains)
    return BlockedDataset(
        z_blocks=z_blocks,
        x_blocks=x_blocks,
        bitmap=bitmap,
        v_z=corpus.spec.num_domains,
        v_x=corpus.spec.num_buckets,
    )


def select_domains(
    corpus: TokenCorpus,
    *,
    k: int = 8,
    eps: float = 0.06,
    delta: float = 0.01,
    lookahead: int = 256,
    seed: int = 0,
    poll_every: int = 1,
    prefetch: bool = False,
    source: Optional[BlockSource] = None,
) -> SelectionReport:
    """Phase-1 SELECT through the engine's `BlockSource` I/O layer.

    ``source`` overrides where block data comes from (default: the
    corpus view wrapped in `InMemorySource`); ``prefetch`` adds the
    double-buffered background gather; ``poll_every`` is the engine's
    device-poll cadence.
    """
    if source is None:
        source = InMemorySource(corpus_as_blocked(corpus))
    if prefetch and not isinstance(source, PrefetchSource):
        source = PrefetchSource(source)
    params = HistSimParams(
        v_z=corpus.spec.num_domains, v_x=corpus.spec.num_buckets, k=k, eps=eps, delta=delta
    )
    res = run_engine(
        source,
        corpus.reference,
        params,
        EngineConfig(
            variant="fastmatch", lookahead=lookahead, seed=seed, poll_every=poll_every
        ),
    )
    return SelectionReport(
        selected_domains=res.ids,
        result=res,
        blocks_scanned_frac=res.blocks_read / source.num_blocks,
    )


@dataclasses.dataclass
class StreamState:
    """Checkpointable cursor (resume-exact after preemption)."""

    epoch: int = 0
    cursor: int = 0  # index into this worker's permuted block list
    stolen: int = 0


class TokenStream:
    """Batched (B, S) token iterator over selected domains' blocks."""

    def __init__(
        self,
        corpus: TokenCorpus,
        selected_domains: np.ndarray,
        *,
        batch_size: int,
        seq_len: int,
        worker: int = 0,
        num_workers: int = 1,
        seed: int = 0,
        state: Optional[StreamState] = None,
    ):
        self.corpus = corpus
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.worker = worker
        self.num_workers = num_workers
        self.seed = seed
        sel = np.isin(corpus.domains, selected_domains)
        all_blocks = np.where(sel)[0]
        self.owned = all_blocks[all_blocks % num_workers == worker]
        self.others = all_blocks[all_blocks % num_workers != worker]
        if self.owned.size == 0:
            raise ValueError("worker owns no blocks; reduce num_workers")
        self.state = state or StreamState()
        self._reshuffle()

    def _reshuffle(self):
        rng = np.random.default_rng((self.seed, self.worker, self.state.epoch))
        self._order = rng.permutation(self.owned)
        # Stolen blocks come WITHOUT replacement from a per-epoch seeded
        # permutation of the remainder — drawing each steal independently
        # could hand the same block to this worker twice in one epoch.
        steal_rng = np.random.default_rng((self.seed, self.worker, self.state.epoch, 1))
        self._steal_order = steal_rng.permutation(self.others)

    def _next_block(self) -> np.ndarray:
        if self.state.cursor >= self._order.size:
            # work stealing first (emulated: walk a permutation of other
            # workers' pools), then wrap to a new epoch.
            if self.state.stolen < self.others.size // max(self.num_workers, 1):
                blk = self._steal_order[self.state.stolen]
                self.state.stolen += 1
                return self.corpus.tokens[blk]
            self.state.epoch += 1
            self.state.cursor = 0
            self.state.stolen = 0
            self._reshuffle()
        blk = self._order[self.state.cursor]
        self.state.cursor += 1
        return self.corpus.tokens[blk]

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        need = self.batch_size * self.seq_len
        buf = []
        have = 0
        while have < need:
            blk = self._next_block()
            buf.append(blk)
            have += blk.size
        flat = np.concatenate(buf)[:need]
        return {"tokens": flat.reshape(self.batch_size, self.seq_len).astype(np.int32)}
