"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attn [arXiv:2401.04088; hf]."""

from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch_id="mixtral_8x7b",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        num_experts=8,
        experts_per_token=2,
        sliding_window=4096,
        rope_theta=1e6,
        norm_eps=1e-5,
        optimizer="adamw",
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch_id="mixtral_8x7b_smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        num_experts=4,
        experts_per_token=2,
        expert_capacity_factor=4.0,  # dropless in smoke tests
        sliding_window=32,
    )
