"""Fault injection / recovery benchmark: the PR-8 robustness contracts.

Four measured sections, one machine-readable report
(benchmarks/results/BENCH_faults.json, regression-gated by
benchmarks/check_regression.py on the DETERMINISTIC keys):

  1. transient golden — a serve run whose every injected fault is
     transient (each retry re-reads the same immutable blocks) must end
     BIT-IDENTICAL to the fault-free run: same top-k ids, same rounds,
     same tuples read. Gated exact (``transient_bit_identical``).
  2. kill-mid-round recovery — an injected `UnrecoverableIOError`
     crashes the serving loop mid-run; `ServeSupervisor` restores the
     last autosaved snapshot, re-submits, completes. Gated exact
     (``recovered``, ``recovery_answers_match``); the number of rounds
     replayed after restore (``recovery_replay_rounds``) is the
     snapshot-staleness cost and is reported.
  3. recall under degradation — permanent faults (corrupt windows,
     exhausted retries) quarantine blocks; the scheduler re-derives the
     guarantee over the surviving population. Seeded, so
     ``degraded_ran`` / ``blocks_quarantined`` are deterministic;
     ``recall_degraded`` (top-k overlap vs the fault-free answers) is
     gated as a floor.
  4. fault-free wrapper overhead — `ResilientSource` around a
     device-resident source (auto validation = structural, O(1)) must
     cost < 2% of serving wall. The gate (folded into ``ok``) is the
     ACCOUNTED overhead: per-fetch wrapper cost measured by direct
     microbenchmark x windows fetched, over the serve wall — stable
     where a one-process wall A/B on a shared runner is not. The
     interleaved wall A/B is reported as corroboration.

Set FAULTS_BENCH_SMOKE=1 for the CI configuration (same code paths;
exits non-zero via ``ok`` if any contract fails).
"""

from __future__ import annotations

import json
import os
import pathlib
import time

import numpy as np

from benchmarks.common import env_stamp
from repro.data.layout import block_layout
from repro.data.synth import SynthSpec, make_dataset, perturb_distribution
from repro.io import InMemorySource
from repro.io.faults import (
    FaultPlan,
    FaultySource,
    ResilientSource,
    RetryPolicy,
)
from repro.serve import ServeSupervisor, SupervisorPolicy
from repro.serve.fastmatch_server import MatchServer

SMOKE = bool(int(os.environ.get("FAULTS_BENCH_SMOKE", "0")))
K, EPS, DELTA = 10, 0.06, 0.01
N_QUERIES = 4 if SMOKE else 8
MAX_ACTIVE = 2
LOOKAHEAD = 64 if SMOKE else 128
POLL_EVERY = 2
SEED = 11
REPEATS = 5 if SMOKE else 7
OVERHEAD_LIMIT = 0.02

SPEC = SynthSpec(
    v_z=64, v_x=16, num_tuples=400_000 if SMOKE else 2_000_000, k=K, n_close=10,
    close_distance=0.02, far_distance=0.3, zipf_a=1.0, close_rank="head", seed=42,
)

RESULTS = pathlib.Path(__file__).parent / "results"


def _build():
    ds = make_dataset(SPEC)
    blocked = block_layout(
        ds.z, ds.x, v_z=SPEC.v_z, v_x=SPEC.v_x, block_size=512, seed=5
    )
    rng = np.random.default_rng(7)
    targets = [
        perturb_distribution(ds.target, d, rng)
        for d in np.linspace(0.005, 0.05, N_QUERIES)
    ]
    return blocked, targets


_SERVER_KW = dict(
    max_queries=MAX_ACTIVE, lookahead=LOOKAHEAD, poll_every=POLL_EVERY,
    seed=SEED, k_cap=K,
)


def _serve(source, targets):
    server = MatchServer(source, **_SERVER_KW)
    t0 = time.perf_counter()
    rids = [server.submit(t, k=K, eps=EPS, delta=DELTA) for t in targets]
    results = server.run_until_idle()
    wall = time.perf_counter() - t0
    return server, [results[r] for r in rids], wall


def _host_chaos(blocked, plan, *, seed, retries=32):
    return ResilientSource(
        FaultySource(InMemorySource(blocked, device_resident=False), plan, seed=seed),
        policy=RetryPolicy(max_retries=retries, backoff_s=0.0),
    )


def _same_answers(a, b):
    return all(
        np.array_equal(ra.ids, rb.ids) for ra, rb in zip(a, b)
    )


def _recall(got, ref):
    overlaps = [
        len(set(ra.ids.tolist()) & set(rb.ids.tolist())) / len(rb.ids)
        for ra, rb in zip(got, ref)
    ]
    return float(np.mean(overlaps))


def run(rows: list) -> None:
    blocked, targets = _build()

    # ---- reference: fault-free serve ----------------------------------
    ref_srv, ref, ref_wall = _serve(blocked, targets)
    ref_rounds = ref_srv.scheduler.rounds
    n_windows = ref_rounds  # one fetch per dispatched window

    # ---- 1. transient faults are bit-invisible ------------------------
    chaos = _host_chaos(blocked, FaultPlan(p_transient=0.3), seed=1)
    srv_t, got_t, _ = _serve(chaos, targets)
    transient_bit_identical = bool(
        _same_answers(got_t, ref)
        and srv_t.scheduler.rounds == ref_rounds
        and srv_t.scheduler.tuples_read == ref_srv.scheduler.tuples_read
        and srv_t.scheduler.blocks_quarantined == 0
    )
    retries_healed = int(chaos.retries_total)

    # ---- 2. kill mid-round + supervisor recovery ----------------------
    # Crash halfway through the deterministic fetch schedule: count the
    # fault-free run's attempts first (seeded => reproducible).
    probe = _host_chaos(blocked, FaultPlan(), seed=0)
    sup_kw = dict(autosave_rounds=2, telemetry=True, **_SERVER_KW)
    ck_dir = RESULTS / "faults_ckpt"
    if ck_dir.exists():
        for p in sorted(ck_dir.rglob("*"), reverse=True):
            p.unlink() if p.is_file() else p.rmdir()
    sup_probe = ServeSupervisor(probe, checkpoint_dir=ck_dir / "probe", **sup_kw)
    for t in targets:
        sup_probe.submit(t, k=K, eps=EPS, delta=DELTA)
    probe_res = sup_probe.run_until_idle()
    attempts = int(probe.inner.injector.attempts)
    crash_at = max(1, attempts // 2)

    crash_src = _host_chaos(blocked, FaultPlan(crash_at=crash_at), seed=0, retries=2)
    sup = ServeSupervisor(
        crash_src, policy=SupervisorPolicy(max_restarts=2),
        checkpoint_dir=ck_dir / "crash", **sup_kw,
    )
    rids = [sup.submit(t, k=K, eps=EPS, delta=DELTA) for t in targets]
    t0 = time.perf_counter()
    res = sup.run_until_idle()
    recovery_wall = time.perf_counter() - t0
    recovered = bool(sup.restarts == 1 and len(res) == len(targets))
    recovery_answers_match = bool(
        _same_answers([res[r] for r in rids], [probe_res[r] for r in rids])
    )
    (rec_ev,) = sup.telemetry.tracer.events("serve_recovered")
    # rounds the recovered server replayed past the restored snapshot
    recovery_replay_rounds = int(sup.server.scheduler.rounds - rec_ev["resumed_step"])

    # ---- 3. recall under degradation ----------------------------------
    degraded_src = _host_chaos(
        blocked, FaultPlan(p_transient=0.1, p_corrupt=0.25), seed=3, retries=1
    )
    srv_d, got_d, _ = _serve(degraded_src, targets)
    blocks_quarantined = int(srv_d.scheduler.blocks_quarantined)
    degraded_ran = bool(blocks_quarantined > 0 and srv_d.metrics["degraded"])
    recall_degraded = _recall(got_d, ref)
    eps_inflation = float(srv_d.scheduler.eps_inflation)

    # ---- 4. fault-free wrapper overhead -------------------------------
    # Device-resident source: auto validation degrades to structural
    # (no device sync), the production fast path.
    dev_src = InMemorySource(blocked)
    wrapped = ResilientSource(dev_src)
    win = np.arange(min(LOOKAHEAD, blocked.num_blocks))
    wd = wrapped.fetch(win, pad_to=LOOKAHEAD)  # warm + a window to validate

    # Accounted: the wrapper's OWN per-fetch code (argument
    # normalization + structural validation on the already-fetched
    # window), timed directly — differencing two full multi-ms device
    # fetches would bury the ~20us wrapper inside fetch-wall noise.
    def _wrapper_us(iters=200):
        t0 = time.perf_counter()
        for _ in range(iters):
            np.asarray(win, np.int64).ravel()
            wrapped._validate(wd, LOOKAHEAD)
        return (time.perf_counter() - t0) / iters * 1e6

    wrapper_us = min(_wrapper_us() for _ in range(3))
    accounted_s = wrapper_us * 1e-6 * n_windows
    # Corroborating wall A/B, interleaved, floors = mean of 3 fastest.
    walls = {"plain": [], "wrapped": []}
    for _ in range(REPEATS):
        _, _, w = _serve(dev_src, targets)
        walls["plain"].append(w)
        _, _, w = _serve(ResilientSource(dev_src), targets)
        walls["wrapped"].append(w)
    floor = {k: float(np.mean(sorted(v)[:3])) for k, v in walls.items()}
    wall_overhead_frac = (floor["wrapped"] - floor["plain"]) / floor["plain"]
    accounted_frac = accounted_s / floor["plain"]
    overhead_ok = bool(accounted_frac < OVERHEAD_LIMIT)

    ok = bool(
        transient_bit_identical and recovered and recovery_answers_match
        and degraded_ran and overhead_ok
    )

    report = {
        "config": {
            "v_z": SPEC.v_z, "v_x": SPEC.v_x, "num_tuples": SPEC.num_tuples,
            "n_queries": N_QUERIES, "max_active": MAX_ACTIVE,
            "lookahead": LOOKAHEAD, "poll_every": POLL_EVERY,
            "k": K, "eps": EPS, "delta": DELTA,
            "crash_at": crash_at, "repeats": REPEATS, "smoke": SMOKE,
            **env_stamp(),
        },
        "transient_bit_identical": transient_bit_identical,
        "transient_retries_healed": retries_healed,
        "recovered": recovered,
        "recovery_answers_match": recovery_answers_match,
        "recovery_replay_rounds": recovery_replay_rounds,
        "recovery_wall_s": round(recovery_wall, 4),
        "degraded_ran": degraded_ran,
        "blocks_quarantined": blocks_quarantined,
        "recall_degraded": round(recall_degraded, 4),
        "eps_inflation": round(eps_inflation, 6),
        "wrapper_us_per_fetch": round(wrapper_us, 2),
        "windows_per_serve": int(n_windows),
        "accounted_overhead_s": round(accounted_s, 6),
        "accounted_frac": round(accounted_frac, 4),
        "wall_overhead_frac": round(wall_overhead_frac, 4),
        "overhead_limit": OVERHEAD_LIMIT,
        "ok": ok,
    }
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "BENCH_faults.json").write_text(json.dumps(report, indent=2))

    rows.append({
        "name": "faults_transient_golden",
        "us_per_call": 0.0,
        "derived": f"bit_identical={transient_bit_identical} retries={retries_healed}",
    })
    rows.append({
        "name": "faults_recovery",
        "us_per_call": recovery_wall * 1e6,
        "derived": (
            f"recovered={recovered} match={recovery_answers_match} "
            f"replay_rounds={recovery_replay_rounds}"
        ),
    })
    rows.append({
        "name": "faults_degraded_recall",
        "us_per_call": 0.0,
        "derived": (
            f"recall={recall_degraded:.3f} quarantined={blocks_quarantined} "
            f"eps_inflation={eps_inflation:.4f}"
        ),
    })
    rows.append({
        "name": "faults_wrapper_overhead",
        "us_per_call": wrapper_us,
        "derived": f"accounted_frac={accounted_frac:.4f} ok={overhead_ok}",
    })
    if not ok:
        raise SystemExit(f"fault_recovery contracts failed: {report}")
