"""Single-query l1 distance: thin alias over the metric registry at Q=1.

Historically this module held its own Pallas kernel (the statistics
engine's original hot loop, paper Sec 3). The metric layer
(`repro.kernels.metrics`) now owns ONE score-generic kernel family and
the l1 instance of its Q=1 form emits the exact op sequence of the old
kernel (load tile -> row sum -> max(row, 1) divide -> |diff| -> lane
reduce), so this alias is bit-identical to the kernel it replaced.
Kept for its import surface (`l1_distance_pallas`, `_MAX_VX`) — the
autotuner's "unrolled" variant and older tests import it directly.

Rows with zero mass return ||q_hat||_1 (= 1), matching ref.py.
"""

from __future__ import annotations

import jax

from repro.kernels import metrics

__all__ = ["l1_distance_pallas"]

_Z_TILE = 256
# Single-block V_X bound: (Z_TILE x V_X) f32 must fit VMEM with headroom.
_MAX_VX = metrics.MAX_SINGLE_BLOCK_VX


def l1_distance_pallas(
    counts: jax.Array,
    q_hat: jax.Array,
    *,
    z_tile: int = _Z_TILE,
    interpret: bool = False,
) -> jax.Array:
    """(V_Z,) float32 distances tau_i. V_X must be <= 4096 (one VMEM block).

    V_X and V_Z are padded internally; q_hat padding is 0 so padded lanes
    contribute |0 - 0| = 0.
    """
    return metrics.distance_pallas(
        counts, q_hat, metric="l1", z_tile=z_tile, interpret=interpret
    )
