"""HistSim + FastMatch engine: end-to-end correctness and guarantees."""


import numpy as np
import pytest

from repro.core.engine import EngineConfig, run_engine
from repro.core.histsim import HistSimParams
from repro.data.layout import block_layout
from repro.data.synth import SynthSpec, make_dataset


@pytest.fixture(scope="module")
def dataset():
    spec = SynthSpec(
        v_z=80, v_x=16, num_tuples=3_000_000, k=8, n_close=8,
        close_distance=0.02, far_distance=0.3, zipf_a=0.9, seed=7,
    )
    ds = make_dataset(spec)
    blocked = block_layout(ds.z, ds.x, v_z=spec.v_z, v_x=spec.v_x, block_size=512, seed=7)
    return spec, ds, blocked


PARAMS = dict(k=8, eps=0.08, delta=0.05)


class TestGuarantees:
    def test_separation_guarantee(self, dataset):
        """Guarantee 1: any true-top-k candidate missing from the output is
        < eps further than the furthest returned candidate."""
        spec, ds, blocked = dataset
        params = HistSimParams(v_z=spec.v_z, v_x=spec.v_x, **PARAMS)
        for seed in range(5):
            res = run_engine(blocked, ds.target, params, EngineConfig(variant="fastmatch", seed=seed))
            returned = set(res.ids.tolist())
            true_top = set(ds.true_top_k.tolist())
            worst_returned = max(ds.true_dists[i] for i in res.ids)
            for j in true_top - returned:
                assert worst_returned - ds.true_dists[j] < params.eps, (seed, j)

    def test_reconstruction_guarantee(self, dataset):
        """Guarantee 2: returned empirical histograms are eps-close to truth."""
        spec, ds, blocked = dataset
        params = HistSimParams(v_z=spec.v_z, v_x=spec.v_x, **PARAMS)
        res = run_engine(blocked, ds.target, params, EngineConfig(variant="fastmatch", seed=1))
        counts = np.asarray(res.state.counts)
        for i in res.ids:
            r_hat = counts[i] / max(counts[i].sum(), 1)
            assert np.abs(r_hat - ds.true_hists[i]).sum() < params.eps

    def test_delta_upper_below_delta_on_termination(self, dataset):
        spec, ds, blocked = dataset
        params = HistSimParams(v_z=spec.v_z, v_x=spec.v_x, **PARAMS)
        res = run_engine(blocked, ds.target, params, EngineConfig(variant="fastmatch", seed=2))
        if not res.exact:
            assert res.delta_upper < params.delta


class TestSublinearity:
    def test_fastmatch_sublinear(self, dataset):
        spec, ds, blocked = dataset
        params = HistSimParams(v_z=spec.v_z, v_x=spec.v_x, **PARAMS)
        res = run_engine(blocked, ds.target, params, EngineConfig(variant="fastmatch", seed=3))
        assert not res.exact
        assert res.blocks_read < blocked.num_blocks * 0.5

    def test_slowmatch_needs_more_samples(self, dataset):
        """The paper's central ordering: SlowMatch's termination criterion
        reads at least as much data as ScanMatch's."""
        spec, ds, blocked = dataset
        params = HistSimParams(v_z=spec.v_z, v_x=spec.v_x, **PARAMS)
        scan = run_engine(blocked, ds.target, params, EngineConfig(variant="scanmatch", seed=4, start_block=0))
        slow = run_engine(blocked, ds.target, params, EngineConfig(variant="slowmatch", seed=4, start_block=0))
        assert slow.blocks_read >= scan.blocks_read

    def test_scan_reads_everything(self, dataset):
        spec, ds, blocked = dataset
        params = HistSimParams(v_z=spec.v_z, v_x=spec.v_x, **PARAMS)
        res = run_engine(blocked, ds.target, params, EngineConfig(variant="scan"))
        assert res.blocks_read == blocked.num_blocks
        assert sorted(res.ids.tolist()) == sorted(ds.true_top_k.tolist())


class TestEngineMechanics:
    def test_exact_fallback_when_data_insufficient(self):
        """Tiny dataset: engine must fall back to exact and match Scan."""
        spec = SynthSpec(v_z=30, v_x=8, num_tuples=20_000, k=3, n_close=3, seed=11)
        ds = make_dataset(spec)
        blocked = block_layout(ds.z, ds.x, v_z=spec.v_z, v_x=spec.v_x, block_size=256, seed=11)
        params = HistSimParams(v_z=spec.v_z, v_x=spec.v_x, k=3, eps=0.02, delta=0.001)
        res = run_engine(blocked, ds.target, params, EngineConfig(variant="fastmatch", seed=0))
        assert res.exact
        assert sorted(res.ids.tolist()) == sorted(ds.true_top_k.tolist())

    def test_budget_cut_is_best_effort_not_exact(self, dataset):
        """Regression: a max_rounds budget cut must return the sampled
        best-effort answer with exact=False — the seed engine silently
        completed a full read and stamped exact=True regardless."""
        spec, ds, blocked = dataset
        params = HistSimParams(v_z=spec.v_z, v_x=spec.v_x, **PARAMS)
        res = run_engine(
            blocked, ds.target, params,
            EngineConfig(variant="fastmatch", seed=0, max_rounds=1),
        )
        assert res.rounds == 1
        assert not res.exact  # budget cut != complete read
        assert res.blocks_read < blocked.num_blocks  # no silent full scan

    def test_exact_flag_set_only_on_complete_read(self, dataset):
        """exact=True must mean the whole dataset was read; a normally
        terminated sampling run reports exact=False."""
        spec, ds, blocked = dataset
        params = HistSimParams(v_z=spec.v_z, v_x=spec.v_x, **PARAMS)
        res = run_engine(blocked, ds.target, params, EngineConfig(variant="fastmatch", seed=6))
        assert not res.exact
        assert res.blocks_read < blocked.num_blocks
        assert res.delta_upper < params.delta

    def test_start_position_invariance_of_correctness(self, dataset):
        spec, ds, blocked = dataset
        params = HistSimParams(v_z=spec.v_z, v_x=spec.v_x, **PARAMS)
        outs = []
        for start in (0, blocked.num_blocks // 3, blocked.num_blocks - 1):
            res = run_engine(
                blocked, ds.target, params,
                EngineConfig(variant="fastmatch", start_block=start, seed=0),
            )
            # Guarantee 1 check (allowing eps-mistakes)
            worst = max(ds.true_dists[i] for i in res.ids)
            for j in set(ds.true_top_k.tolist()) - set(res.ids.tolist()):
                assert worst - ds.true_dists[j] < params.eps
            outs.append(res.blocks_read)
        assert all(b > 0 for b in outs)

    def test_syncmatch_equals_lookahead_one(self, dataset):
        spec, ds, blocked = dataset
        params = HistSimParams(v_z=spec.v_z, v_x=spec.v_x, **PARAMS)
        res = run_engine(
            blocked, ds.target, params,
            EngineConfig(variant="syncmatch", seed=5, max_rounds=3000),
        )
        # must produce a correct-enough answer like the others
        worst = max(ds.true_dists[i] for i in res.ids)
        for j in set(ds.true_top_k.tolist()) - set(res.ids.tolist()):
            assert worst - ds.true_dists[j] < params.eps


class TestDistanceEstimates:
    def test_tau_converges_to_truth(self, dataset):
        spec, ds, blocked = dataset
        params = HistSimParams(v_z=spec.v_z, v_x=spec.v_x, **PARAMS)
        res = run_engine(blocked, ds.target, params, EngineConfig(variant="scan"))
        tau = np.asarray(res.state.tau)
        np.testing.assert_allclose(tau, ds.true_dists, atol=0.02)
