"""Gradient compression for cross-pod all-reduce.

Two schemes, both with the all-reduce-friendly property that compression
commutes with summation:

* bf16 — cast gradients to bf16 before the (pod-crossing) reduction.
  With pjit this is what `cast_grads_dtype` achieves: the SPMD
  partitioner then moves bf16, halving DCI/ICI gradient bytes.
* int8 + error feedback — per-tensor max-abs scaling to int8 with a
  persistent residual (the classic EF-SGD trick) so quantization error
  is fed back rather than lost. Exposed for the shard_map training path
  where the reduction is explicit.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress_gradients", "init_error_feedback", "quantize_int8", "dequantize_int8"]


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_gradients(grads, *, scheme: str = "bf16", error_feedback=None):
    """Returns (compressed_grads, new_error_feedback).

    scheme="bf16": plain cast (residual unused).
    scheme="int8": quantize(g + residual); residual = (g + residual) - dq.
    scheme="none": passthrough.
    """
    if scheme == "none":
        return grads, error_feedback
    if scheme == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads), error_feedback
    if scheme == "int8":
        if error_feedback is None:
            error_feedback = init_error_feedback(grads)

        def q(g, r):
            tot = g.astype(jnp.float32) + r
            qv, scale = quantize_int8(tot)
            dq = dequantize_int8(qv, scale)
            return dq.astype(g.dtype), tot - dq

        out = jax.tree.map(q, grads, error_feedback)
        newg = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        newr = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return newg, newr
    raise ValueError(f"unknown scheme {scheme!r}")
